"""Sharded streaming-engine throughput: the data-parallel lane mesh.

Drives ``serve.ShardedSNNStreamEngine`` over every visible device (run
under ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` to get a real
N-way mesh on a CPU host — the CI multi-device lane uses 4) and reports

  * aggregate and **per-device lane throughput** (images/s),
  * **admission-overlap timing** — wall-clock with and without the
    speculative chunk-(k+1) dispatch, plus the speculation hit counters,
  * a bit-identity spot check against the single-device engine on the
    same seeds (the sharding equivalence guarantee, cheaply re-verified
    where the numbers are produced).

Saves results/bench/BENCH_engine_sharded.json (uploaded as a CI
artifact).  REPRO_BENCH_TINY=1 shrinks sizes for the smoke lane.
"""

from __future__ import annotations

import dataclasses
import os
import time

import jax.numpy as jnp
import numpy as np

from repro.configs.snn_mnist import (SNN_CONFIG, SNN_STREAM_MESH,
                                     make_stream_engine, make_stream_mesh)
from repro.serve import SNNStreamEngine

from .common import emit, save_json


def _params(rng, sizes):
    return {"layers": [
        {"w_q": jnp.asarray(rng.integers(-256, 256, (a, b)), jnp.int16),
         "scale": jnp.float32(1.0)}
        for a, b in zip(sizes[:-1], sizes[1:])]}


def _drive(eng, imgs) -> tuple[float, dict]:
    """Submit ``imgs``, run to completion, return (seconds, results)."""
    for im in imgs:
        eng.submit(im)
    t0 = time.perf_counter()
    res = eng.run()
    return time.perf_counter() - t0, res


def run():
    tiny = bool(os.environ.get("REPRO_BENCH_TINY"))
    sizes = (64, 10) if tiny else (784, 10)
    T = 8 if tiny else 20
    chunk = 4
    lanes_per_device = 4 if tiny else 8
    mesh = make_stream_mesh()
    n_dev = int(mesh.devices.size)
    n_imgs = 4 * lanes_per_device * n_dev

    rng = np.random.default_rng(0)
    cfg = dataclasses.replace(SNN_CONFIG, layer_sizes=sizes, num_steps=T)
    params_q = _params(rng, sizes)
    imgs = rng.integers(0, 256, (2 * n_imgs, sizes[0]), dtype=np.uint8)

    # patience ~T/2: some lanes exit early (compaction happens), some run
    # to T (steady chunks where the speculative dispatch actually lands)
    patience = max(2, T // 2)
    knobs = dataclasses.replace(SNN_STREAM_MESH, num_devices=n_dev,
                                lanes_per_device=lanes_per_device,
                                chunk_steps=chunk)

    def make(overlap):
        return make_stream_engine(
            params_q, cfg, dataclasses.replace(knobs, overlap=overlap),
            patience=patience, seed=0, backend="reference")

    timings, engines = {}, {}
    for overlap in (True, False):
        eng = make(overlap)
        _drive(eng, imgs[:n_imgs])              # warm-up: compile + caches
        eng.stats = {k: 0 for k in eng.stats}
        dt, _ = _drive(eng, imgs[n_imgs:])      # steady-state measurement
        timings[overlap], engines[overlap] = dt, eng
        ips = n_imgs / dt
        emit(f"engine_sharded.overlap_{overlap}", dt * 1e6 / n_imgs,
             f"devices={n_dev} imgs_per_s={ips:.0f} "
             f"per_device={ips / n_dev:.0f} stats={eng.stats}")

    # Equivalence spot check: per-request results vs the single-device
    # engine on an identical submission stream (same rids ⇒ same seeds).
    ref = SNNStreamEngine(params_q, cfg, batch_size=lanes_per_device,
                          chunk_steps=chunk, patience=patience, seed=0,
                          backend="reference")
    _, ref_res = _drive(ref, imgs[:n_imgs])
    sh = make(True)
    _, sh_res = _drive(sh, imgs[:n_imgs])
    identical = set(ref_res) == set(sh_res) and all(
        r.pred == sh_res[rid].pred and r.steps == sh_res[rid].steps
        and r.adds == sh_res[rid].adds
        and (r.spike_counts == sh_res[rid].spike_counts).all()
        for rid, r in ref_res.items())
    emit("engine_sharded.bit_identical", None, f"vs_single_dev={identical}")

    stats = engines[True].stats
    ips = n_imgs / timings[True]
    save_json({
        "devices": n_dev,
        "layer_sizes": list(sizes),
        "num_steps": T,
        "chunk_steps": chunk,
        "lanes_per_device": lanes_per_device,
        "imgs_per_s": ips,
        "per_device_lane_imgs_per_s": ips / n_dev,
        "overlap": {
            "seconds_with": timings[True],
            "seconds_without": timings[False],
            "speedup": timings[False] / timings[True],
            "spec_used": stats["spec_used"],
            "spec_wasted": stats["spec_wasted"],
            "chunks": stats["chunks"],
        },
        "bit_identical": identical,
    }, "bench", "BENCH_engine_sharded.json")
    assert identical
    return timings


if __name__ == "__main__":
    run()
