"""Model-axis sharding benchmark: the 2-D (data × model) mesh contract,
re-verified where the numbers are produced.

Spawns a 4-device forced-host subprocess (the same trick as the CI
multidevice lane) and reports

  * **bit-identity across mesh shapes** — 2×2 (data × model) and 1×4
    (pure model) engines reproduce the single-device engine
    prediction-for-prediction for both chunk backends,
  * **telemetry-for-telemetry** — per-lane spike/enable counts from the
    model-sharded step match the unsharded step bit-for-bit, and on
    128-aligned shard widths the per-shard skipped-tile counts sum to
    exactly the unsharded layer count,
  * **failover placement-independence** — lanes snapshot from a
    model-sharded engine adopt onto a plain single-device engine and
    finish bit-identical (the PR-7 contract, extended),
  * **WIDE feasibility** — SNN_CONFIG_WIDE (784-2048-2048-10) exceeds
    the VMEM budget single-device but each 4-way model shard fits:
    per-device resident weight bytes ≤ budget, and backend resolution
    lands on the resident ``fused`` megakernel instead of
    ``fused_streamed``.

Saves results/bench/BENCH_model_sharded.json (contract fields diffed
against the committed copy by benchmarks.check_tracked).
REPRO_BENCH_TINY=1 shrinks the mesh workload for the smoke lane.
"""

from __future__ import annotations

import json
import os
import subprocess
import sys
import textwrap
import time

from .common import emit, save_json

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

_SUB = """
    import dataclasses, json, time
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import PartitionSpec as P
    from repro.configs.snn_mnist import (SNN_CONFIG, SNNStreamMeshConfig,
                                         make_stream_engine)
    from repro.core import prng, snn
    from repro.core.lif import LIFStateInt
    from repro.distributed.sharding import (make_2d_device_mesh,
                                            shard_map_compat)
    from repro.kernels.fused_snn import layer_shard_ways
    from repro.serve import SNNStreamEngine

    assert len(jax.devices()) == 4, jax.devices()
    tiny = TINY
    sizes = (24, 16, 10) if tiny else (784, 256, 128, 10)
    T = 8 if tiny else 12
    n_imgs = 12 if tiny else 20

    def small_net(rng, sizes):
        return {"layers": [
            {"w_q": jnp.asarray(rng.integers(-256, 256, (a, b)), jnp.int16),
             "scale": jnp.float32(1.0)}
            for a, b in zip(sizes[:-1], sizes[1:])]}

    def sig(r):
        return (r.pred, r.steps, r.adds, r.early_exit,
                tuple(r.spike_counts.tolist()))

    rng = np.random.default_rng(0)
    cfg = dataclasses.replace(SNN_CONFIG, layer_sizes=sizes, num_steps=T)
    params_q = small_net(rng, sizes)
    imgs = rng.integers(0, 256, (n_imgs, sizes[0]), dtype=np.uint8)

    # ---- bit-identity across mesh shapes vs single-device --------------
    identical, t_mesh = True, None
    for backend in ("reference", "fused"):
        ref = SNNStreamEngine(params_q, cfg, batch_size=8, chunk_steps=3,
                              patience=1, seed=11, backend=backend)
        for im in imgs:
            ref.submit(im)
        r1 = ref.run()
        for nd, md, lpd in ((2, 2, 4), (1, 4, 8)):
            knobs = SNNStreamMeshConfig(num_devices=nd, model_devices=md,
                                        lanes_per_device=lpd, chunk_steps=3)
            eng = make_stream_engine(params_q, cfg, knobs, patience=1,
                                     seed=11, backend=backend)
            for im in imgs:
                eng.submit(im)
            t0 = time.perf_counter()
            r2 = eng.run()
            dt = time.perf_counter() - t0
            if backend == "reference" and (nd, md) == (2, 2):
                t_mesh = dt
            identical &= (set(r1) == set(r2) and
                          all(sig(r1[k]) == sig(r2[k]) for k in r1))

    # ---- telemetry bit-identity (128-aligned shard widths) -------------
    tsz = (784, 512, 512, 10)
    tw = {"layers": [
        {"w_q": jnp.asarray(rng.integers(-256, 256, (a, b)), jnp.int16),
         "scale": jnp.float32(1.0)}
        for a, b in zip(tsz[:-1], tsz[1:])]}
    weights = tuple(jnp.asarray(l["w_q"], jnp.int32) for l in tw["layers"])
    B = 8
    pixels = jnp.asarray(rng.integers(0, 256, (B, tsz[0]), np.uint8))
    rng_state = prng.seed_state(3, (B, tsz[0]))
    states = tuple(LIFStateInt(v=jnp.zeros((B, n), jnp.int32),
                               enable=jnp.ones((B, n), bool))
                   for n in tsz[1:])
    _, st1, x1, adds1, tel1 = snn.snn_int_stack_step(
        rng_state, pixels, states, weights, cfg.lif, active_pruning=True)
    mesh = make_2d_device_mesh(1, 4)
    ways = layer_shard_ways(tsz, 4)

    def body(rng_state, pixels, states, weights):
        return snn.snn_int_stack_step_sharded(
            rng_state, pixels, states, weights, cfg.lif,
            model_axis="model", ways=ways, active_pruning=True,
            contraction="jnp")

    rep = P()
    w_specs = tuple(P(None, "model") if w > 1 else P() for w in ways)
    st_specs = tuple(LIFStateInt(v=rep, enable=rep) for _ in states)
    tel_spec = {"n_spk": rep, "n_en": rep,
                "tiles": P(None, ("data", "model"))}
    f = shard_map_compat(body, mesh,
                         in_specs=(rep, rep, st_specs, w_specs),
                         out_specs=(rep, st_specs, rep, rep, tel_spec))
    _, st2, x2, adds2, tel2 = f(rng_state, pixels, states, weights)
    t1t = np.asarray(tel1["tiles"])
    t2t = np.asarray(tel2["tiles"])
    nb = t1t.shape[1]
    per_shard = t2t.reshape(t1t.shape[0], 4, nb)
    tiles_ok = all(
        (per_shard[l].sum(axis=0) == t1t[l]).all() if w > 1
        else (per_shard[l] == t1t[l][None, :]).all()
        for l, w in enumerate(ways))
    tel_identical = bool(
        (np.asarray(x1) == np.asarray(x2)).all()
        and (np.asarray(adds1) == np.asarray(adds2)).all()
        and (np.asarray(tel1["n_spk"]) == np.asarray(tel2["n_spk"])).all()
        and (np.asarray(tel1["n_en"]) == np.asarray(tel2["n_en"])).all()
        and tiles_ok)

    # ---- failover: model-sharded snapshot → single-device adopt --------
    base = SNNStreamEngine(params_q, cfg, batch_size=8, chunk_steps=3,
                           patience=10_000, seed=9, backend="reference")
    for im in imgs[:8]:
        base.submit(im)
    want = base.run()
    knobs = SNNStreamMeshConfig(num_devices=2, model_devices=2,
                                lanes_per_device=4, chunk_steps=3)
    src = make_stream_engine(params_q, cfg, knobs, patience=10_000,
                             seed=9, backend="reference")
    for im in imgs[:8]:
        src.submit(im)
    src.run(max_chunks=2)
    rows = src.snapshot_lanes()
    dst = SNNStreamEngine(params_q, cfg, batch_size=8, chunk_steps=3,
                          patience=10_000, seed=9, backend="reference")
    for rid, row in rows:
        dst.adopt(rid, row)
    got = dst.run()
    failover_identical = (set(got) == set(want) and
                          all(sig(got[k]) == sig(want[k]) for k in want))

    print("RESULT " + json.dumps({
        "model_sharded_bit_identical": identical,
        "telemetry_bit_identical_model": tel_identical,
        "failover_bit_identical": failover_identical,
        "mesh_seconds_2x2": t_mesh,
        "n_imgs": n_imgs,
        "layer_sizes": list(sizes),
    }))
"""


def _wide_feasibility() -> dict:
    """Host-side VMEM math + backend resolution for SNN_CONFIG_WIDE on a
    4-way model axis (no devices needed — the estimate is pure)."""
    import jax

    from repro.configs.snn_mnist import SNN_CONFIG_WIDE
    from repro.core.snn import resolve_backend
    from repro.kernels.fused_snn import (VMEM_BUDGET_BYTES, _pad128,
                                         layer_shard_ways,
                                         stack_vmem_bytes)
    sizes = SNN_CONFIG_WIDE.layer_sizes
    n_layers = len(sizes) - 1
    ways = layer_shard_ways(sizes, 4)
    shard_weight_bytes = sum(
        _pad128(a) * _pad128(b // w) * 2
        for a, b, w in zip(sizes[:-1], sizes[1:], ways))
    full = stack_vmem_bytes(sizes, num_steps=4)
    shard = stack_vmem_bytes(sizes, num_steps=4, model_shards=4)
    orig = jax.default_backend
    jax.default_backend = lambda: "tpu"       # resolution is host math
    try:
        kw = dict(layer_sizes=sizes, trace_steps=4, local_batch=256)
        single = resolve_backend(SNN_CONFIG_WIDE, "auto", n_layers, **kw)
        sharded = resolve_backend(SNN_CONFIG_WIDE, "auto", n_layers,
                                  model_shards=4, **kw)
    finally:
        jax.default_backend = orig
    return {
        "layer_shard_ways": list(ways),
        "per_device_resident_weight_bytes": shard_weight_bytes,
        "vmem_budget_bytes": VMEM_BUDGET_BYTES,
        "stack_vmem_bytes_full": full,
        "stack_vmem_bytes_4way_shard": shard,
        "single_device_backend": single,
        "model_sharded_backend": sharded,
        "wide_fused_resident": (single == "fused_streamed"
                                and sharded == "fused"),
        "wide_shard_fits_vmem": (full > VMEM_BUDGET_BYTES
                                 and shard <= VMEM_BUDGET_BYTES
                                 and shard_weight_bytes
                                 <= VMEM_BUDGET_BYTES),
    }


def run():
    tiny = bool(os.environ.get("REPRO_BENCH_TINY"))
    wide = _wide_feasibility()
    emit("model_sharded.wide_feasibility", None,
         f"shard_weight_bytes={wide['per_device_resident_weight_bytes']} "
         f"budget={wide['vmem_budget_bytes']} "
         f"single={wide['single_device_backend']} "
         f"4way={wide['model_sharded_backend']}")

    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=4",
               JAX_PLATFORMS="cpu",
               PYTHONPATH=os.path.join(REPO_ROOT, "src"))
    code = textwrap.dedent(_SUB).replace("TINY", repr(tiny))
    t0 = time.perf_counter()
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=900)
    dt = time.perf_counter() - t0
    if out.returncode != 0:
        raise RuntimeError(f"mesh subprocess failed:\n{out.stderr[-3000:]}")
    line = [ln for ln in out.stdout.splitlines()
            if ln.startswith("RESULT ")][-1]
    mesh = json.loads(line[len("RESULT "):])
    emit("model_sharded.mesh_identity",
         dt * 1e6 / mesh["n_imgs"],
         f"bit_identical={mesh['model_sharded_bit_identical']} "
         f"telemetry={mesh['telemetry_bit_identical_model']} "
         f"failover={mesh['failover_bit_identical']}")

    save_json({
        "mesh_shape": [2, 2],
        "devices": 4,
        "layer_sizes": mesh["layer_sizes"],
        "wide": wide,
        "model_sharded_bit_identical": mesh["model_sharded_bit_identical"],
        "telemetry_bit_identical_model":
            mesh["telemetry_bit_identical_model"],
        "failover_bit_identical": mesh["failover_bit_identical"],
        "wide_fused_resident": wide["wide_fused_resident"],
        "wide_shard_fits_vmem": wide["wide_shard_fits_vmem"],
        "mesh_seconds_2x2": mesh["mesh_seconds_2x2"],
    }, "bench", "BENCH_model_sharded.json")
    assert mesh["model_sharded_bit_identical"]
    assert mesh["telemetry_bit_identical_model"]
    assert mesh["failover_bit_identical"]
    assert wide["wide_fused_resident"] and wide["wide_shard_fits_vmem"]
    return mesh


if __name__ == "__main__":
    run()
