"""Diff the COMMITTED root-level BENCH_*.json artifacts against a fresh
benchmark run.

``benchmarks/run.py`` mirrors every fresh ``results/bench/BENCH_*.json``
to the repo root so the perf trajectory is committed and reviewable
across PRs (results/ itself is gitignored).  This checker keeps those
tracked copies honest.  Crucially, the baseline is read from **git HEAD**
(``git show HEAD:<name>``), NOT from the working-tree root file — the
bench run that just executed has already overwritten the working-tree
copy with the fresh artifact, so comparing the file on disk would be a
tautology.  For every requested artifact the checker asserts that

  * a committed copy exists at HEAD (the trajectory is actually
    recorded),
  * the fresh counterpart from this run exists in results/bench/, and
  * every CONTRACT field present in the committed copy matches the fresh
    run bit-for-bit.

Contract fields are the run-invariant claims — bit-identity, zero fused
hop bytes, the int8 resident-byte reduction, adds-vs-density scaling,
single-launch streaming, device counts — never wall-clock timings, which
legitimately drift between runners.  A contract mismatch means a kernel
or accounting regression (or a stale committed artifact: re-run the
suite and commit the refreshed root copies).

  PYTHONPATH=src python -m benchmarks.check_tracked \\
      BENCH_fused.json BENCH_fused_multilayer.json
  PYTHONPATH=src python -m benchmarks.check_tracked --all

``--all`` (or no arguments) checks **every** BENCH_*.json committed at
HEAD — discovered with ``git ls-tree``, not hand-listed.  This closes
the hole where a newly committed artifact whose producing suite silently
stopped running would never be diffed: an explicit CI list only checks
what someone remembered to add, the glob checks what the repo actually
claims.  A committed artifact with no fresh results/bench counterpart is
a failure, not a skip.
"""

from __future__ import annotations

import json
import os
import subprocess
import sys

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
BENCH_DIR = os.path.join(REPO_ROOT, "results", "bench")

# Dotted paths compared when present in the tracked copy.
CONTRACT_FIELDS = [
    "bit_identical",
    "hop_bytes.fused",
    "hop_bytes.fused_total",
    "fused_single_launch",
    "resident_weight_bytes.reduction",
    "sparse.scaling_ok",
    "single_launch",
    "explicit_fused_raises",
    "devices",
    # telemetry / adaptive-dispatch contract (BENCH_telemetry.json)
    "telemetry_bit_identical",
    "adds_match",
    "density_estimate_ok",
    "adaptive_matches_frozen",
    # serving-tier contract (BENCH_router.json)
    "tier_bit_identical",
    "shed_accounting_ok",
    "rollout_preserves_inflight",
    "rollout_completed",
    # fault-tolerance contract (BENCH_faults.json)
    "evacuation_bit_identical",
    "ladder_bit_identical",
    "ladder_repromoted",
    "replay_deterministic",
    "no_silent_loss",
    "process_failover_bit_identical",
    "ledger_survives_coordinator_restart",
    "process_replay_deterministic",
    # model-axis sharding contract (BENCH_model_sharded.json)
    "model_sharded_bit_identical",
    "telemetry_bit_identical_model",
    "wide_fused_resident",
    "wide_shard_fits_vmem",
    "failover_bit_identical",
    "mesh_shape",
    # autotuner / dispatch-cache contract (BENCH_autotune.json) — the
    # tuned wall-clock itself is provenance, never compared
    "tuned_bit_identical",
    "tuned_not_slower",
    "cache_roundtrip_ok",
]


def _get(obj, dotted):
    for part in dotted.split("."):
        if not isinstance(obj, dict) or part not in obj:
            return None, False
        obj = obj[part]
    return obj, True


def _committed_json(name: str, repo_root: str = REPO_ROOT):
    """The artifact as committed at git HEAD, or None with a reason.

    The working-tree root copy is NOT a usable baseline here: the bench
    run mirrors its fresh output over it before this checker runs.
    """
    try:
        out = subprocess.run(
            ["git", "show", f"HEAD:{name}"], cwd=repo_root,
            capture_output=True, text=True, timeout=60)
    except (OSError, subprocess.TimeoutExpired) as e:
        return None, f"git unavailable ({e})"
    if out.returncode != 0:
        return None, "not committed at HEAD — run the suite and commit " \
                     "the mirrored root artifact"
    try:
        return json.loads(out.stdout), None
    except json.JSONDecodeError as e:
        return None, f"committed copy is not valid JSON ({e})"


def check(names: list[str], repo_root: str = REPO_ROOT) -> list[str]:
    bench_dir = os.path.join(repo_root, "results", "bench")
    errors = []
    for name in names:
        tracked, why = _committed_json(name, repo_root)
        if tracked is None:
            errors.append(f"{name}: {why}")
            continue
        fresh_p = os.path.join(bench_dir, name)
        if not os.path.exists(fresh_p):
            errors.append(f"{name}: no fresh results/bench copy — the "
                          f"producing suite did not run (re-run "
                          f"`python -m benchmarks.run` or drop the stale "
                          f"committed artifact)")
            continue
        try:
            with open(fresh_p) as f:
                fresh = json.load(f)
        except (OSError, json.JSONDecodeError) as e:
            errors.append(f"{name}: fresh results/bench copy unreadable "
                          f"({e}) — the producing suite crashed mid-write; "
                          f"re-run it")
            continue
        for field in CONTRACT_FIELDS:
            tv, present = _get(tracked, field)
            fv, fresh_present = _get(fresh, field)
            if not present:
                if fresh_present:
                    # The reverse hole: a contract field the bench now
                    # emits but the committed baseline predates.  Skipping
                    # it silently would let the new claim go untracked
                    # forever — the artifact must be re-committed.
                    errors.append(
                        f"{name}: contract field {field!r} added to the "
                        f"bench but missing from the committed copy — "
                        f"re-run the suite and commit the refreshed root "
                        f"artifact")
                continue
            if not fresh_present:
                errors.append(f"{name}: contract field {field!r} vanished "
                              f"from the fresh run")
            elif tv != fv:
                errors.append(f"{name}: contract field {field!r} tracked="
                              f"{tv!r} fresh={fv!r}")
    return errors


def committed_artifacts(repo_root: str = REPO_ROOT) -> list[str]:
    """Every root-level BENCH_*.json tracked at git HEAD."""
    try:
        out = subprocess.run(
            ["git", "ls-tree", "--name-only", "HEAD"], cwd=repo_root,
            capture_output=True, text=True, timeout=60)
    except (OSError, subprocess.TimeoutExpired) as e:
        raise SystemExit(f"TRACKED-ARTIFACT MISMATCH: git unavailable "
                         f"({e}) — run from a git checkout")
    if out.returncode != 0:
        raise SystemExit(f"TRACKED-ARTIFACT MISMATCH: git ls-tree failed "
                         f"({out.stderr.strip()}) — run from a git "
                         f"checkout with at least one commit")
    return sorted(n for n in out.stdout.splitlines()
                  if n.startswith("BENCH_") and n.endswith(".json"))


def main(argv=None, repo_root: str = REPO_ROOT) -> None:
    names = (argv if argv is not None else sys.argv[1:])
    if not names or names == ["--all"]:
        names = committed_artifacts(repo_root)
        print(f"# checking all {len(names)} BENCH_*.json committed at "
              f"HEAD: {', '.join(names)}")
        if not names:
            print("usage: python -m benchmarks.check_tracked "
                  "[BENCH_x.json ... | --all]  (no artifacts at HEAD)")
            sys.exit(2)
    errors = check(list(names), repo_root)
    for e in errors:
        print(f"TRACKED-ARTIFACT MISMATCH: {e}")
    if errors:
        sys.exit(1)
    print(f"# {len(names)} tracked benchmark artifact(s) match the fresh "
          f"run on all contract fields")


if __name__ == "__main__":
    main()
