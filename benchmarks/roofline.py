"""Roofline analysis from the dry-run's compiled artifacts (deliverable g).

For every (arch × shape) cell on the single-pod mesh:

    compute    = HLO_FLOPs  / (chips × peak_FLOP/s)      [s]
    memory     = HLO_bytes  / (chips × HBM_bw)           [s]
    collective = coll_bytes / (chips × link_bw)          [s]

cost_analysis() reports PER-DEVICE flops/bytes of the partitioned module,
so the chip-normalised terms are simply per-device values over per-chip
peaks.  Collective bytes come from the partitioned-HLO parse done by
launch/dryrun.py (per-device traffic with ring multipliers).

Also reported per cell: the dominant term, MODEL_FLOPS (6·N_active·D for
training, 2·N_active·D for prefill/decode forward), and the
MODEL_FLOPS/HLO_FLOPS ratio (useful-compute fraction — catches remat
recompute and head/vocab padding waste).
"""

from __future__ import annotations

import glob
import json
import os

from repro.configs import SHAPES, get_config

from .common import emit, save_json

# TPU v5e hardware constants (assignment-specified)
PEAK_FLOPS = 197e12          # bf16 FLOP/s per chip
HBM_BW = 819e9               # bytes/s per chip
LINK_BW = 50e9               # bytes/s per ICI link


def model_flops(arch: str, shape_name: str) -> float:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    n = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    # decode: one token per sequence per step
    return 2.0 * n * shape.global_batch


def analyse(rec: dict) -> dict:
    n_dev = rec["devices"]
    fl = rec["cost"]["flops_per_device"]
    by = rec["cost"]["bytes_per_device"]
    co = rec["collectives_per_device"]["total"]

    compute_s = fl / PEAK_FLOPS
    memory_s = by / HBM_BW
    coll_s = co / LINK_BW
    terms = {"compute_s": compute_s, "memory_s": memory_s,
             "collective_s": coll_s}
    dominant = max(terms, key=terms.get)

    mf = model_flops(rec["arch"], rec["shape"])
    useful = mf / (fl * n_dev) if fl else 0.0
    bound_s = max(terms.values())
    # roofline fraction: useful model FLOPs per second achievable at the
    # bound, over the fleet's peak FLOPs.
    frac = (mf / bound_s) / (n_dev * PEAK_FLOPS) if bound_s else 0.0

    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "devices": n_dev, **terms,
        "dominant": dominant.replace("_s", ""),
        "model_flops": mf,
        "hlo_flops_total": fl * n_dev,
        "useful_fraction": useful,
        "roofline_fraction": frac,
        "peak_gib": rec["memory"]["peak_bytes"] / 2**30,
        "collective_breakdown": {
            k: v for k, v in rec["collectives_per_device"].items()
            if isinstance(v, (int, float)) and k != "total"},
    }


def suggestion(row: dict) -> str:
    d = row["dominant"]
    if d == "compute":
        if row["useful_fraction"] < 0.5:
            return ("compute-bound with low useful fraction — cut remat "
                    "recompute / padding before anything else")
        return "compute-bound near useful peak — only algorithmic wins left"
    if d == "memory":
        return ("memory-bound — raise arithmetic intensity: larger "
                "microbatch, fuse elementwise chains, cache-resident KV")
    return ("collective-bound — reshard to cut the largest all-gather, "
            "overlap collectives with compute, or compress the payload")


def run(dryrun_dir: str = "results/dryrun", mesh: str = "single"):
    rows = []
    for f in sorted(glob.glob(os.path.join(dryrun_dir, f"*.{mesh}.json"))):
        rec = json.load(open(f))
        rows.append(analyse(rec))

    rows.sort(key=lambda r: r["roofline_fraction"])
    save_json(rows, "bench", f"roofline_{mesh}.json")

    for r in rows:
        emit(f"roofline.{r['arch']}.{r['shape']}", None,
             f"compute={r['compute_s']:.3g}s memory={r['memory_s']:.3g}s "
             f"collective={r['collective_s']:.3g}s dom={r['dominant']} "
             f"useful={r['useful_fraction']:.2f} "
             f"roofline_frac={r['roofline_fraction']:.3f}")
    return rows


def markdown_table(rows) -> str:
    hdr = ("| arch | shape | compute s | memory s | collective s | dominant "
           "| useful | roofline | next move |\n|---|---|---|---|---|---|---|---|---|")
    lines = [hdr]
    for r in sorted(rows, key=lambda x: (x["arch"], x["shape"])):
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.3g} "
            f"| {r['memory_s']:.3g} | {r['collective_s']:.3g} "
            f"| **{r['dominant']}** | {r['useful_fraction']:.2f} "
            f"| {r['roofline_fraction']:.3f} | {suggestion(r)} |")
    return "\n".join(lines)


if __name__ == "__main__":
    rows = run()
    print(markdown_table(rows))
