"""Paper Table II: TinyML ANN vs proposed SNN.

Reproduces every row with measured quantities where possible:
  * arithmetic: multiplications per inference (ANN dense MAC grid vs the
    SNN's measured event-driven adds — zero multiplies by construction),
  * model size: fp32 MLP bytes vs 9-bit fixed-point codes,
  * latency: documented ESP32 baselines vs a cycle model of the RTL core
    at 40 MHz (both the paper's parallel-array bound and a per-row
    serialised FSM),
  * energy: Horowitz-cost op accounting (core.energy).
"""

from __future__ import annotations


from repro.configs.snn_mnist import SNN_CONFIG
from repro.core import energy
from repro.core.train_snn import int_accuracy

from .common import emit, save_json, trained_snn

CLOCK_HZ = 40e6
# Documented ESP32 measurements from the paper (not reproducible here):
ESP32_NO_DSP_S = 3.0
ESP32_DSP_US = 5130.0


def rtl_latency_us(T: int, n_rows: int = 28) -> dict:
    """Cycle model of the RTL core at 40 MHz.

    parallel: all 784 synapse lanes + 10 neurons update in one cycle per
    timestep (the paper's "<1 µs" bound);
    row-serial: the FSM integrates one 28-pixel row per cycle (Fig. 1's
    shared-adder datapath), leak+fire once per timestep.
    """
    parallel = T / CLOCK_HZ * 1e6
    row_serial = T * (n_rows + 2) / CLOCK_HZ * 1e6
    return {"parallel_us": parallel, "row_serial_us": row_serial}


def run(T: int = 10):
    params, params_q, ds = trained_snn()
    acc, aux = int_accuracy(params_q, SNN_CONFIG, ds.x_test, ds.y_test,
                            num_steps=T)

    ann_ops = energy.ann_op_counts()                    # 784→32→10 baseline
    snn_adds = aux["adds_per_img"]
    snn_ops = energy.OpCounts(multiplications=0, additions=int(snn_adds),
                              shifts=T * 10, comparisons=T * 10)
    em = energy.EnergyModel(ann=ann_ops, snn=snn_ops)

    size_ann = energy.ann_memory_bytes()
    size_snn = energy.snn_memory_bytes(weight_bits=9)
    lat = rtl_latency_us(T)

    table = {
        "arithmetic": {"ann": "fp32 MAC", "snn": "fixed-point add/shift"},
        "multiplications": {"ann": ann_ops.multiplications, "snn": 0},
        "additions": {"ann": ann_ops.additions, "snn": int(snn_adds)},
        "model_bytes": {"ann": size_ann, "snn": size_snn,
                        "ratio": size_ann / size_snn},
        "latency_us": {"ann_no_dsp": ESP32_NO_DSP_S * 1e6,
                       "ann_dsp": ESP32_DSP_US, **lat},
        "energy_pj": {"ann": em.ann_energy_pj, "snn": em.snn_energy_pj,
                      "ratio": em.energy_ratio},
        "accuracy_at_T": {"T": T, "acc": acc},
    }
    save_json(table, "bench", "table2_ann_vs_snn.json")

    emit("table2.mults", None,
         f"ann={ann_ops.multiplications} snn=0")
    emit("table2.adds", None,
         f"ann={ann_ops.additions} snn={int(snn_adds)} "
         f"(sparsity saves {100*(1-snn_adds/(T*784*10)):.0f}% of dense)")
    emit("table2.model_size", None,
         f"ann={size_ann/1024:.1f}KB snn={size_snn/1024:.1f}KB "
         f"ratio={size_ann/size_snn:.1f}x (paper: 11.3x)")
    emit("table2.latency", lat["parallel_us"],
         f"rtl_parallel={lat['parallel_us']:.2f}us "
         f"rtl_rowserial={lat['row_serial_us']:.1f}us "
         f"esp32_dsp={ESP32_DSP_US}us esp32={ESP32_NO_DSP_S}s")
    emit("table2.energy", None,
         f"ann={em.ann_energy_pj:.0f}pJ snn={em.snn_energy_pj:.0f}pJ "
         f"ratio={em.energy_ratio:.0f}x")

    # paper-claim checks
    assert table["model_bytes"]["ratio"] > 10     # paper: 11.3×
    assert lat["parallel_us"] < 1.0               # paper: < 1 µs
    assert em.energy_ratio > 10
    return table


if __name__ == "__main__":
    run()
