"""Paper Fig. 4: membrane potential evolution — integrate, fire at the
threshold (128), hard reset to V_rest, exponential shift-decay."""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.configs.snn_mnist import SNN_CONFIG
from repro.core import prng
from repro.core.lif import run_lif_int
from repro.core.encoding import poisson_encode_hw

from .common import emit, save_json, trained_snn


def run(T: int = 40):
    params, params_q, ds = trained_snn()
    w_q = params_q["layers"][0]["w_q"]

    i = int(np.where(ds.y_test == 7)[0][0])
    px = jnp.asarray((ds.x_test[i:i + 1] * 255).astype(np.uint8))
    st = prng.seed_state(4, px.shape)
    spikes, _ = poisson_encode_hw(px, st, T)
    res = run_lif_int(spikes, w_q, SNN_CONFIG.lif)

    v = np.asarray(res["v_trace"])[:, 0, 7]       # label neuron
    spk = np.asarray(res["spikes"])[:, 0, 7]
    fires = int(spk.sum())
    th = SNN_CONFIG.lif.v_threshold

    # Fig-4 invariants: fires happen, reset follows each fire, V stays
    # bounded, sub-threshold between fires.
    assert fires >= 2, "trace should show repeated fire/reset"
    reset_ok = all(v[t] == SNN_CONFIG.lif.v_rest for t in range(T) if spk[t])
    assert reset_ok, "hard reset to V_rest after every fire"
    assert v.max() < th, "stored potential is post-fire (reset) or sub-threshold"

    trace = {"v": v.tolist(), "spikes": spk.astype(int).tolist(),
             "threshold": th, "fires": fires}
    save_json(trace, "bench", "fig4_membrane_trace.json")

    # ascii sparkline for the log
    blocks = " ▁▂▃▄▅▆▇█"
    lo, hi = v.min(), max(v.max(), 1)
    line = "".join(blocks[int((x - lo) / (hi - lo + 1e-9) * 8)] for x in v)
    emit("fig4.membrane", None,
         f"fires={fires} reset_ok={reset_ok} trace={line}")
    return trace


if __name__ == "__main__":
    run()
