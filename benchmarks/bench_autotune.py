"""Wall-clock autotuner + persisted dispatch cache (§ROADMAP "wall-clock
autotuning of the dispatch shapes").

Runs the telemetry-seeded search of ``repro.tune`` against a
deterministic open-loop arrival schedule, persists the winner to the
versioned JSON dispatch cache under ``results/tune/``, then proves the
three contract claims the CI gate diffs (benchmarks/check_tracked.py):

  * ``tuned_bit_identical`` — the tuned shapes reproduce the default
    engine's predictions and retirement steps exactly, on the reference
    AND fused backends, single-device AND sharded.  The cache may only
    change *when* work happens, never *what* is computed.
  * ``tuned_not_slower`` — median tuned seconds-per-retired-request is
    within 5% of the default shapes measured in the same session.  The
    default is always a candidate and the winner is the argmin over all
    candidates including it, so this holds by construction; the field
    records that the invariant actually survived measurement noise.
  * ``cache_roundtrip_ok`` — the persisted file reloads to a hit on the
    same key, arms engines (single-device, sharded, and the serving
    tier) whose startup decisions record the hit, and a corrupted copy
    is rejected with a warning while the engine falls back to static
    defaults instead of crashing.

Wall-clock numbers are measurement provenance, tagged with
``{device_kind, interpret}`` — never contract fields.
"""

from __future__ import annotations

import dataclasses
import json
import os
import warnings

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.snn_mnist import SNN_CONFIG
from repro.tune import (ArrivalSchedule, AutotuneConfig, DispatchCache,
                        autotune_engine, config_fingerprint,
                        serve_schedule, write_cache)

from .common import emit, results_path, save_json


def _sizes():
    if os.environ.get("REPRO_BENCH_TINY"):
        return dict(T=6, n_requests=8, per_round=2, repeats=2,
                    chunk_grid=(2, 3), lanes_grid=(4, 8),
                    max_candidates=5, check_requests=6)
    return dict(T=10, n_requests=24, per_round=2, repeats=3,
                chunk_grid=(2, 3, 4, 6), lanes_grid=(4, 8, 16),
                max_candidates=10, check_requests=10)


def _net(rng, cfg):
    n_in, n_out = cfg.layer_sizes[0], cfg.layer_sizes[-1]
    w = jnp.asarray(rng.integers(-256, 256, (n_in, n_out)), jnp.int16)
    return {"layers": [{"w_q": w, "scale": jnp.float32(1.0)}]}


def _bits(results: dict) -> dict:
    return {int(rid): (int(r.pred), int(r.steps))
            for rid, r in results.items()}


def _serve(engine, sched, pixels):
    return _bits(serve_schedule(engine, sched, pixels))


def run():
    from repro.serve import ShardedSNNStreamEngine, SNNStreamEngine
    s = _sizes()
    rng = np.random.default_rng(17)
    cfg = dataclasses.replace(SNN_CONFIG, num_steps=s["T"],
                              sparse_skip=True)
    params_q = _net(rng, cfg)
    sched = ArrivalSchedule(n_requests=s["n_requests"],
                            per_round=s["per_round"], seed=97)
    tc = AutotuneConfig(chunk_steps_grid=s["chunk_grid"],
                        lanes_grid=s["lanes_grid"], schedule=sched,
                        repeats=s["repeats"],
                        max_candidates=s["max_candidates"])

    # ---- the measured search (auto backend: reference on CPU hosts) -----
    result = autotune_engine(params_q, cfg, tune_cfg=tc, patience=2,
                             seed=3)
    tuned = result.tuned
    ratio = (tuned.seconds_per_retired_request
             / max(result.baseline_spr, 1e-12))
    tuned_not_slower = ratio <= 1.05
    emit("autotune.search", None,
         f"candidates={len(result.records)} "
         f"pruned={result.pruned} probe_density="
         f"{result.probe['density_ewma']:.4f}")
    emit("autotune.winner", tuned.seconds_per_retired_request * 1e6,
         f"chunk={tuned.chunk_steps} block_b={tuned.block_b} "
         f"lanes={tuned.lanes_per_device} "
         f"threshold={tuned.spike_density_threshold} "
         f"backend={tuned.backend} "
         f"s_per_req_vs_default={ratio:.3f}x")
    assert result.bit_identical, \
        "a measured candidate changed predictions — dispatch knobs must " \
        "be value-neutral"
    assert tuned_not_slower, \
        f"winner slower than the default it was measured against " \
        f"({ratio:.3f}x)"

    # ---- persist: single-device key + this host's sharded mesh key ------
    n_dev = len(jax.devices())
    path = results_path("tune", "dispatch_cache.json")
    write_cache(result, path, backend_request="auto",
                mesh_shapes=((1,), (n_dev, 1)))
    emit("autotune.cache_written", None,
         f"path=results/tune/dispatch_cache.json "
         f"fingerprint={result.fingerprint} meshes=1,{n_dev}x1")

    # ---- tuned shapes are value-neutral per backend, per topology -------
    check_sched = ArrivalSchedule(n_requests=s["check_requests"],
                                  per_round=2, seed=53)
    pixels = check_sched.pixels(cfg.layer_sizes[0])
    tuned_cfg = dataclasses.replace(
        cfg, spike_density_threshold=tuned.spike_density_threshold)
    identity = {}
    for backend in ("reference", "fused"):
        base = SNNStreamEngine(params_q, cfg, backend=backend, patience=2,
                               seed=3, dispatch_cache=False)
        tuned_eng = SNNStreamEngine(
            params_q, tuned_cfg, batch_size=tuned.lanes_per_device,
            chunk_steps=tuned.chunk_steps, block_b=tuned.block_b,
            backend=backend, patience=2, seed=3, dispatch_cache=False)
        identity[f"single.{backend}"] = (
            _serve(base, check_sched, pixels)
            == _serve(tuned_eng, check_sched, pixels))
        base_sh = ShardedSNNStreamEngine(
            params_q, cfg, backend=backend, patience=2, seed=3,
            dispatch_cache=False)
        tuned_sh = ShardedSNNStreamEngine(
            params_q, tuned_cfg, lanes_per_device=tuned.lanes_per_device,
            chunk_steps=tuned.chunk_steps, block_b=tuned.block_b,
            backend=backend, patience=2, seed=3, dispatch_cache=False)
        identity[f"sharded.{backend}"] = (
            _serve(base_sh, check_sched, pixels)
            == _serve(tuned_sh, check_sched, pixels))
    tuned_bit_identical = result.bit_identical and all(identity.values())
    for k, ok in identity.items():
        emit(f"autotune.identity.{k}", None, f"tuned==default={ok}")
    assert tuned_bit_identical, f"tuned shapes changed results: {identity}"

    # ---- the persisted cache arms engines and records the hit -----------
    loaded = DispatchCache.load(path)
    decision = loaded.lookup(
        fingerprint=result.fingerprint, device_kind=result.device_kind,
        mesh_shape=(1,), backend="auto")
    armed = SNNStreamEngine(params_q, cfg, patience=2, seed=3,
                            dispatch_cache=path)
    armed_sh = ShardedSNNStreamEngine(params_q, cfg, patience=2, seed=3,
                                      dispatch_cache=path)
    plain = SNNStreamEngine(params_q, cfg, patience=2, seed=3,
                            dispatch_cache=False)
    armed_hits = (decision.hit and armed.cache_decision.hit
                  and armed_sh.cache_decision.hit)
    armed_identical = (_serve(plain, check_sched, pixels)
                       == _serve(armed, check_sched, pixels))
    emit("autotune.cache_armed", None,
         f"lookup_hit={decision.hit} engine_hit={armed.cache_decision.hit} "
         f"sharded_hit={armed_sh.cache_decision.hit} "
         f"armed==static={armed_identical} "
         f"armed_chunk={armed.controller.chunk_steps}")
    assert armed_identical, "cache-armed engine changed predictions"

    # ---- corrupt copies are rejected loudly, never crash startup --------
    corrupt = results_path("tune", "dispatch_cache_corrupt.json")
    with open(corrupt, "w") as f:
        f.write("{not json")
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        fallback = SNNStreamEngine(params_q, cfg, patience=2, seed=3,
                                   dispatch_cache=corrupt)
    rejects_corrupt = (not fallback.cache_decision.hit
                       and len(caught) >= 1)
    emit("autotune.corrupt_fallback", None,
         f"hit={fallback.cache_decision.hit} warned={len(caught) >= 1} "
         f"reason={fallback.cache_decision.reason[:60]!r}")
    assert rejects_corrupt, "corrupt cache must warn and fall back"

    cache_roundtrip_ok = bool(armed_hits and armed_identical
                              and rejects_corrupt)
    assert cache_roundtrip_ok

    with open(path) as f:
        persisted = json.load(f)
    save_json({
        "sizes": {k: v for k, v in s.items()},
        "fingerprint": result.fingerprint,
        "device_kind": result.device_kind,
        "fingerprint_matches": config_fingerprint(cfg) == result.fingerprint,
        "tuned": tuned.to_json(),
        "default": result.default.to_json(),
        "baseline_seconds_per_retired_request": result.baseline_spr,
        "tuned_vs_default_ratio": ratio,
        "tuned_bit_identical": bool(tuned_bit_identical),
        "tuned_not_slower": bool(tuned_not_slower),
        "cache_roundtrip_ok": cache_roundtrip_ok,
        "identity_matrix": {k: bool(v) for k, v in identity.items()},
        "candidates": result.records,
        "probe": result.probe,
        "pruned": result.pruned,
        "cache_codec_version": persisted.get("codec_version"),
        "cache_entries": sorted(persisted.get("entries", {})),
        "backend_platform": jax.default_backend(),
    }, "bench", "BENCH_autotune.json")
    return result


if __name__ == "__main__":
    run()
