"""Paper Fig. 7: efficiency score = accuracy(%) / inference time.

The paper's point: efficiency peaks at the earliest timesteps — the
exponential drop justifies active pruning / early exit.  Also measures the
early-exit (stability) timestep distribution, the serving-layer analogue."""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.configs.snn_mnist import SNN_CONFIG
from repro.core import encoding, lif as lif_mod, prng
from repro.core.pruning import stability_early_exit
from repro.core.train_snn import int_accuracy

from .bench_ann_vs_snn import rtl_latency_us
from .common import emit, save_json, trained_snn


def run(T: int = 20):
    params, params_q, ds = trained_snn()
    x, y = ds.x_test[:1000], ds.y_test[:1000]

    rows = []
    for t in (1, 2, 3, 5, 8, 10, 15, 20):
        acc, _ = int_accuracy(params_q, SNN_CONFIG, x, y, num_steps=t)
        lat_us = rtl_latency_us(t)["row_serial_us"]
        eff = (acc * 100) / (lat_us * 1e-6)          # %/s (paper's metric)
        rows.append({"T": t, "acc": acc, "latency_us": lat_us,
                     "efficiency_pct_per_s": eff})
        emit(f"fig7.T{t}", lat_us, f"acc={acc:.3f} eff={eff:.3g}%/s")

    # early-exit timestep distribution (stability patience 3): per-step
    # running prediction from cumulative output-spike counts.
    px = jnp.asarray((x * 255).astype(np.uint8))
    spikes_in, _ = encoding.poisson_encode_hw(px, prng.seed_state(7, px.shape),
                                              T)
    res = lif_mod.run_lif_int(spikes_in, params_q["layers"][0]["w_q"],
                              SNN_CONFIG.lif)
    cum_counts = np.cumsum(np.asarray(res["spikes"]).astype(np.int32), 0)
    pred_t = jnp.asarray(cum_counts.argmax(-1))      # (T, n)
    t_exit = np.asarray(stability_early_exit(pred_t, patience=3))

    save_json({"rows": rows,
               "early_exit_mean": float(t_exit.mean()),
               "early_exit_p90": float(np.percentile(t_exit, 90))},
              "bench", "fig7_efficiency.json")
    emit("fig7.early_exit", None,
         f"mean_exit_t={t_exit.mean():.1f} p90={np.percentile(t_exit, 90):.0f} "
         f"of T={T}")

    # the paper's qualitative claim: efficiency decays with T
    effs = [r["efficiency_pct_per_s"] for r in rows]
    assert effs[0] > effs[-1] * 2, "efficiency must peak early"
    return rows


if __name__ == "__main__":
    run()
