"""Paper Fig. 5/6: classification accuracy vs simulation timesteps, and
accuracy vs (hardware-model) inference time.

Claim under test: rapid convergence — ≈89% by timestep 10 on the MNIST
stand-in, stable thereafter."""

from __future__ import annotations


from repro.configs.snn_mnist import SNN_CONFIG
from repro.core.train_snn import int_accuracy

from .bench_ann_vs_snn import rtl_latency_us
from .common import emit, save_json, trained_snn


def run():
    params, params_q, ds = trained_snn()
    ts = [1, 2, 3, 5, 8, 10, 15, 20]
    rows = []
    for T in ts:
        acc, aux = int_accuracy(params_q, SNN_CONFIG, ds.x_test, ds.y_test,
                                num_steps=T)
        lat = rtl_latency_us(T)
        rows.append({"T": T, "acc": acc,
                     "adds_per_img": aux["adds_per_img"],
                     "latency_us": lat["row_serial_us"]})
        emit(f"fig5.T{T}", lat["row_serial_us"], f"acc={acc:.4f}")

    save_json(rows, "bench", "fig5_accuracy_vs_T.json")

    acc10 = next(r["acc"] for r in rows if r["T"] == 10)
    acc20 = rows[-1]["acc"]
    emit("fig5.claim", None,
         f"acc@10={acc10:.3f} (paper ~0.89) acc@20={acc20:.3f} "
         f"converged={abs(acc20 - acc10) < 0.02}")
    assert acc10 >= 0.89, f"paper claims ~89% by T=10; got {acc10:.3f}"
    assert abs(acc20 - acc10) < 0.02, "stable prediction after convergence"
    return rows


if __name__ == "__main__":
    run()
