"""Serving-tier benchmark: routing throughput, spray balance, shed
accounting, and the tier-level contract re-verified where the numbers
are produced.

Drives ``serve.SNNServingTier`` (N in-process engines, reference
backend — the routing layer under test is pure host code) and reports

  * **admission throughput** — submissions/s through the least-loaded
    router, including the load-score evaluation per engine,
  * **serve throughput** and the resulting **spray balance** across
    engines (max/min routed per engine),
  * **shed accounting** under deadline + overload pressure: every
    submitted id lands in exactly one of results/shed (nothing silently
    dropped), with the per-reason counters,
  * the two tier contracts: **bit-identity** (tier == single-engine
    serving per request) and **rollout-preserves-inflight** (mid-stream
    weight rollout never changes pre-rollout windows).

Saves results/bench/BENCH_router.json (uploaded as a CI artifact; the
contract fields are diffed against the committed copy by
benchmarks.check_tracked).  REPRO_BENCH_TINY=1 shrinks sizes for the
smoke lane.
"""

from __future__ import annotations

import dataclasses
import os
import time

import jax.numpy as jnp
import numpy as np

from repro.configs.snn_mnist import (SNN_CONFIG, SNN_SERVING_TIER,
                                     make_serving_tier)
from repro.serve import SNNStreamEngine

from .common import emit, save_json


def _params(rng, sizes):
    return {"layers": [
        {"w_q": jnp.asarray(rng.integers(-256, 256, (a, b)), jnp.int16),
         "scale": jnp.float32(1.0)}
        for a, b in zip(sizes[:-1], sizes[1:])]}


def _sig(r):
    return (r.pred, r.steps, r.adds, r.early_exit,
            tuple(r.spike_counts.tolist()))


def run():
    tiny = bool(os.environ.get("REPRO_BENCH_TINY"))
    sizes = (64, 10) if tiny else (784, 10)
    T = 8 if tiny else 20
    chunk = 3 if tiny else 4
    n_engines = 3
    lanes = 4 if tiny else 8
    n_imgs = 6 * n_engines * lanes

    rng = np.random.default_rng(0)
    cfg = dataclasses.replace(SNN_CONFIG, layer_sizes=sizes, num_steps=T)
    params_q = _params(rng, sizes)
    imgs = rng.integers(0, 256, (n_imgs, sizes[0]), dtype=np.uint8)
    patience = max(1, T // 4)       # early exit live → real load variance

    def make(**kw):
        knobs = dataclasses.replace(
            SNN_SERVING_TIER, num_engines=n_engines,
            lanes_per_engine=lanes, chunk_steps=chunk, queue_limit=None,
            shedding=False)
        return make_serving_tier(params_q, cfg, knobs, patience=patience,
                                 seed=0, backend="reference", **kw)

    # --- admission throughput + spray balance ---------------------------
    tier = make()
    t0 = time.perf_counter()
    for im in imgs:
        tier.submit(im)
    dt_admit = time.perf_counter() - t0
    emit("router.admit", dt_admit * 1e6 / n_imgs,
         f"engines={n_engines} submits_per_s={n_imgs / dt_admit:.0f}")
    t0 = time.perf_counter()
    res = tier.run()
    dt_serve = time.perf_counter() - t0
    spray = tier.stats["routed_per_engine"]
    balance = max(spray) / max(1, min(spray))
    emit("router.serve", dt_serve * 1e6 / n_imgs,
         f"imgs_per_s={n_imgs / dt_serve:.0f} spray={spray} "
         f"balance={balance:.2f}")

    # --- tier bit-identity vs single-engine serving ---------------------
    ref = SNNStreamEngine(params_q, cfg, batch_size=lanes,
                          chunk_steps=chunk, patience=patience, seed=0,
                          backend="reference")
    for im in imgs:
        ref.submit(im)
    ref_res = ref.run()
    tier_bit_identical = set(res) == set(ref_res) and all(
        _sig(res[rid]) == _sig(ref_res[rid]) for rid in ref_res)
    emit("router.bit_identical", None, f"vs_single_engine="
         f"{tier_bit_identical}")

    # --- shed accounting under deadline + overload pressure -------------
    shed_tier = make_serving_tier(
        params_q, cfg,
        dataclasses.replace(SNN_SERVING_TIER, num_engines=n_engines,
                            lanes_per_engine=lanes, chunk_steps=chunk,
                            queue_limit=2, shedding=True),
        patience=10_000, seed=0, backend="reference")
    for k, im in enumerate(imgs):
        shed_tier.submit(
            im, priority=("batch", "standard", "interactive")[k % 3],
            deadline_steps=(2 if k % 7 == 0 else None))
    shed_res = shed_tier.run()
    served, shed = set(shed_res), set(shed_tier.shed)
    shed_accounting_ok = (served | shed == set(range(n_imgs))
                          and not (served & shed))
    emit("router.shed", None,
         f"served={len(served)} shed_deadline="
         f"{shed_tier.stats['shed_deadline']} shed_overload="
         f"{shed_tier.stats['shed_overload']} displaced="
         f"{shed_tier.stats['displaced']} partition={shed_accounting_ok}")

    # --- zero-drain rollout preserves in-flight windows -----------------
    params_new = _params(np.random.default_rng(7), sizes)
    roll = make()
    # "in-flight" means IN A LANE: the pre set must fit the tier's lane
    # capacity, else the overflow queues and (correctly) binds the new
    # weights at its later admission.
    n_pre = n_engines * lanes
    pre = [roll.submit(im) for im in imgs[:n_pre]]
    roll.step()                     # admits every pre request on version 0
    t0 = time.perf_counter()
    new_version = roll.begin_rollout(params_new)
    dt_roll = time.perf_counter() - t0
    post = [roll.submit(im) for im in imgs[n_pre:]]
    roll_res = roll.run()
    base = make()
    for im in imgs[:n_pre]:
        base.submit(im)
    base_res = base.run()
    rollout_preserves_inflight = all(
        _sig(roll_res[rid]) == _sig(base_res[rid]) for rid in pre)
    rollout_completed = not roll.rollout_active and all(
        [e.kind for e in h] == ["begin", "complete"]
        for h in roll.rollout_history())
    new_bound = all(roll_res[rid].weight_version == new_version
                    for rid in post)
    emit("router.rollout", dt_roll * 1e6,
         f"preserves_inflight={rollout_preserves_inflight} "
         f"completed={rollout_completed} new_bound={new_bound}")

    save_json({
        "engines": n_engines,
        "lanes_per_engine": lanes,
        "layer_sizes": list(sizes),
        "num_steps": T,
        "chunk_steps": chunk,
        "admit_us_per_request": dt_admit * 1e6 / n_imgs,
        "imgs_per_s": n_imgs / dt_serve,
        "spray": spray,
        "spray_balance": balance,
        "shed": {
            "served": len(served),
            "deadline": shed_tier.stats["shed_deadline"],
            "overload": shed_tier.stats["shed_overload"],
            "displaced": shed_tier.stats["displaced"],
        },
        "tier_bit_identical": tier_bit_identical,
        "shed_accounting_ok": shed_accounting_ok,
        "rollout_preserves_inflight": rollout_preserves_inflight,
        "rollout_completed": rollout_completed,
    }, "bench", "BENCH_router.json")
    assert tier_bit_identical and shed_accounting_ok
    assert rollout_preserves_inflight and rollout_completed and new_bound
    return {"admit": dt_admit, "serve": dt_serve}


if __name__ == "__main__":
    run()
