"""Benchmark harness: one module per paper table/figure + the roofline
report.  Prints ``name,us_per_call,derived`` CSV lines; artifacts land in
results/bench/ AND — so the perf trajectory survives the gitignored
results/ dir — every fresh ``BENCH_*.json`` is mirrored to the repo root,
where it is committed and diffed by CI (benchmarks/check_tracked.py).

  PYTHONPATH=src python -m benchmarks.run            # everything
  PYTHONPATH=src python -m benchmarks.run --only fig5,roofline
  PYTHONPATH=src python -m benchmarks.run --only fused --tiny   # CI smoke
"""

from __future__ import annotations

import argparse
import glob
import os
import shutil
import sys
import time
import traceback

SUITES = {
    "table1": ("bench_input_stats", "Table I — stochastic input current"),
    "table2": ("bench_ann_vs_snn", "Table II — ANN vs SNN"),
    "fig4": ("bench_membrane", "Fig 4 — membrane trace"),
    "fig5": ("bench_accuracy", "Fig 5/6 — accuracy vs timesteps"),
    "fig7": ("bench_efficiency", "Fig 7 — efficiency score"),
    "fig8": ("bench_robustness", "Fig 8 — robustness"),
    "engine": ("bench_engine", "SNN engine throughput (JAX/kernels)"),
    "engine_sharded": ("bench_engine_sharded",
                       "Sharded streaming engine (lane mesh + overlap)"),
    "router": ("bench_router",
               "Serving tier (routing, shedding, weight rollout)"),
    "faults": ("bench_faults",
               "Fault tolerance (failover latency, ladder, accounting)"),
    "model_sharded": ("bench_model_sharded",
                      "Model-axis sharding (2-D data×model mesh)"),
    "fused": ("bench_fused", "Fused vs staged encode→LIF (time + bytes)"),
    "autotune": ("bench_autotune",
                 "Wall-clock autotuner + persisted dispatch cache"),
    "roofline": ("roofline", "Roofline terms from the dry-run"),
}

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
BENCH_DIR = os.path.join(REPO_ROOT, "results", "bench")


def _mirror_fresh_artifacts(since: float) -> list[str]:
    """Copy BENCH_*.json files (re)written after ``since`` to the repo
    root, where they are git-tracked — results/ itself is gitignored."""
    copied = []
    for p in sorted(glob.glob(os.path.join(BENCH_DIR, "BENCH_*.json"))):
        if os.path.getmtime(p) >= since:
            shutil.copy(p, os.path.join(REPO_ROOT, os.path.basename(p)))
            copied.append(os.path.basename(p))
    return copied


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated suite names")
    ap.add_argument("--tiny", action="store_true",
                    help="shrink problem sizes (CI kernel-regression smoke)")
    args = ap.parse_args(argv)
    if args.tiny:
        os.environ["REPRO_BENCH_TINY"] = "1"
    want = args.only.split(",") if args.only else list(SUITES)

    failures = []
    for name in want:
        mod_name, desc = SUITES[name]
        print(f"# === {name}: {desc} ===", flush=True)
        t0 = time.time()
        try:
            mod = __import__(f"benchmarks.{mod_name}", fromlist=["run"])
            mod.run()
            copied = _mirror_fresh_artifacts(t0)
            if copied:
                print(f"# tracked artifact copies at repo root: "
                      f"{', '.join(copied)}", flush=True)
            print(f"# {name} done in {time.time()-t0:.1f}s", flush=True)
        except Exception as e:  # noqa: BLE001
            failures.append((name, e))
            traceback.print_exc()
    if failures:
        print(f"# {len(failures)} suite(s) failed: "
              f"{[n for n, _ in failures]}")
        sys.exit(1)
    print("# all benchmark suites passed")


if __name__ == "__main__":
    main()
