"""Paper Table I: stochastic input current statistics.

First-timestep synaptic current into the label neuron, 300 samples/digit:
avg/min/max and an OK status (finite, sane range).  The paper's values
(avg ≈ 176–301, negative minima from signed weights) are the qualitative
targets; exact values depend on trained weights.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core import encoding, prng

from .common import emit, save_json, trained_snn


def run():
    params, params_q, ds = trained_snn()
    w_q = np.asarray(params_q["layers"][0]["w_q"]).astype(np.int64)

    rows = []
    for digit in range(10):
        idx = np.where(ds.y_test == digit)[0]
        # top up from train split to reach 300 samples (paper's count)
        if len(idx) < 300:
            extra = np.where(ds.y_train == digit)[0][: 300 - len(idx)]
            x = np.concatenate([ds.x_test[idx], ds.x_train[extra]])
        else:
            x = ds.x_test[idx[:300]]
        px = jnp.asarray((x * 255).astype(np.uint8))
        st = prng.seed_state(99 + digit, px.shape)
        spikes, _ = encoding.poisson_encode_hw(px, st, 1)   # first timestep
        s0 = np.asarray(spikes[0]).astype(np.int64)          # (n, 784)
        current = s0 @ w_q[:, digit]                         # into label neuron
        ok = np.isfinite(current).all() and current.mean() > 0
        rows.append({"digit": digit, "avg": float(current.mean()),
                     "min": int(current.min()), "max": int(current.max()),
                     "status": "OK" if ok else "CHECK", "n": len(current)})

    save_json(rows, "bench", "table1_input_stats.json")
    for r in rows:
        emit(f"table1.digit{r['digit']}", None,
             f"avg={r['avg']:.1f} min={r['min']} max={r['max']} {r['status']}")
    assert all(r["status"] == "OK" for r in rows)
    return rows


if __name__ == "__main__":
    run()
