"""Paper Fig. 8: robustness under rotation (15°), pixel shift (20%),
Gaussian noise, and partial occlusion.

Paper's qualitative result: resilient (>83%) to rotation and occlusion;
degrades under heavy shift/noise."""

from __future__ import annotations


from repro.configs.snn_mnist import SNN_CONFIG
from repro.data import digits
from repro.core.train_snn import int_accuracy

from .common import emit, save_json, trained_snn

KINDS = ("clean", "rotation", "occlusion", "shift", "noise")


def run(T: int = 10):
    params, params_q, ds = trained_snn()
    x, y = ds.x_test, ds.y_test
    rows = {}
    for kind in KINDS:
        xp = digits.corrupt(x, kind, seed=0)
        acc, _ = int_accuracy(params_q, SNN_CONFIG, xp, y, num_steps=T)
        rows[kind] = acc
        emit(f"fig8.{kind}", None, f"acc={acc:.3f}")

    save_json(rows, "bench", "fig8_robustness.json")

    # qualitative ordering from the paper
    assert rows["rotation"] > 0.83, rows
    assert rows["occlusion"] > 0.83, rows
    assert rows["noise"] < rows["rotation"], "noise should hurt most"
    assert rows["shift"] < rows["occlusion"], "heavy shift degrades"
    return rows


if __name__ == "__main__":
    run()
