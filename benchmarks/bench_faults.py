"""Fault-tolerance benchmark: failover latency, evacuation bit-identity,
degradation-ladder behaviour, and the never-silent accounting contract —
re-verified where the numbers are produced.

Drives ``serve.SNNServingTier`` / ``serve.SNNStreamEngine`` under seeded
``serve.faults.FaultPlan`` schedules and reports

  * **failover recovery latency in chunks** — rounds from the engine
    failure to the evacuated lanes being re-dispatched on a healthy
    engine, plus the total extra rounds the faulted tier needs versus
    the never-faulted baseline,
  * **evacuation bit-identity** — every request served across a
    mid-window engine loss matches the no-fault tier
    prediction-for-prediction (the LaneState row at a chunk boundary is
    a complete checkpoint),
  * **degradation ladder** — persistent fused launch faults demote the
    engine down the resumable backend chain and clean chunks re-promote
    it, with results bit-identical to the never-faulted fused engine,
  * **never-silent accounting** — under a chaos plan mixing transient
    dispatch faults, a poison request, and a state-losing device loss,
    ``results ∪ shed ∪ faulted`` partitions the submitted ids exactly,
    and a replay of the same (plan, schedule) reproduces every record,
  * **process-level failover** — a real subprocess worker is killed
    mid-window and the coordinator crashes mid-run; ledger recovery plus
    wire-checkpoint evacuation finishes the workload bit-identical to
    the no-fault engine, and replaying the whole kill+crash+recover
    schedule reproduces every record exactly.

Saves results/bench/BENCH_faults.json (contract fields diffed against
the committed copy by benchmarks.check_tracked).  REPRO_BENCH_TINY=1
shrinks sizes for the smoke lane.
"""

from __future__ import annotations

import dataclasses
import os
import tempfile
import time

import jax.numpy as jnp
import numpy as np

from repro.configs.snn_mnist import SNN_CONFIG, SNN_SERVING_TIER, \
    make_serving_tier
from repro.serve import (ClusterCoordinator, CoordinatorCrash, FaultEvent,
                         FaultInjector, FaultPlan, FaultToleranceConfig,
                         SNNStreamEngine, read_ledger)

from .common import emit, save_json


def _params(rng, sizes):
    return {"layers": [
        {"w_q": jnp.asarray(rng.integers(-256, 256, (a, b)), jnp.int16),
         "scale": jnp.float32(1.0)}
        for a, b in zip(sizes[:-1], sizes[1:])]}


def _sig(r):
    return (r.pred, r.steps, r.adds, r.early_exit,
            tuple(r.spike_counts.tolist()))


def _drive(tier):
    """Step a tier to completion by hand, watching the failover rounds.

    Returns (results, total_rounds, fail_round, evacuation_latency) with
    the latency counted in tier rounds (== chunks per surviving engine)
    between the failure being detected and the evacuated lanes leaving
    the adoption queue for a healthy engine's batch tile.
    """
    rounds, r_fail, r_adopted = 0, None, None
    while tier.pending and rounds < 100_000:
        tier.step()
        rounds += 1
        if r_fail is None and tier.stats["engines_failed"]:
            r_fail = rounds
        if (r_fail is not None and r_adopted is None
                and not any(e._adoptions for e in tier.engines)):
            r_adopted = rounds
    for i in tier._alive():
        tier.engines[i].run(max_chunks=0)
    latency = None if r_fail is None else (r_adopted - r_fail)
    return tier.results, rounds, r_fail, latency


def run():
    tiny = bool(os.environ.get("REPRO_BENCH_TINY"))
    sizes = (32, 10) if tiny else (784, 10)
    T = 8 if tiny else 20
    chunk = 2 if tiny else 4
    lanes = 2 if tiny else 4
    n_imgs = 6 * lanes

    rng = np.random.default_rng(0)
    cfg = dataclasses.replace(SNN_CONFIG, layer_sizes=sizes, num_steps=T)
    params_q = _params(rng, sizes)
    imgs = rng.integers(0, 256, (n_imgs, sizes[0]), dtype=np.uint8)

    def make(**knob_kw):
        knobs = dataclasses.replace(
            SNN_SERVING_TIER, num_engines=2, lanes_per_engine=lanes,
            chunk_steps=chunk, queue_limit=None, shedding=False, **knob_kw)
        return make_serving_tier(params_q, cfg, knobs, patience=10_000,
                                 seed=0, backend="reference")

    # --- failover: recovery latency + evacuation bit-identity -----------
    plan = FaultPlan(events=(
        FaultEvent(kind="device_loss", engine=1, first_chunk=2),))
    tier = make(fault_plan=plan)
    rids = [tier.submit(im) for im in imgs]
    t0 = time.perf_counter()
    res, rounds, fail_round, evac_latency = _drive(tier)
    dt = time.perf_counter() - t0
    base = make()
    for im in imgs:
        base.submit(im)
    base_res, base_rounds, _, _ = _drive(base)
    overhead = rounds - base_rounds
    evacuation_bit_identical = set(res) == set(base_res) == set(rids) and \
        all(_sig(res[rid]) == _sig(base_res[rid]) for rid in rids)
    failover_partition_ok = (
        set(res) | set(tier.shed) | set(tier.faulted) == set(rids)
        and not tier.shed and not tier.faulted)
    emit("faults.failover", dt * 1e6 / n_imgs,
         f"fail_round={fail_round} evac_latency_chunks={evac_latency} "
         f"overhead_chunks={overhead} evacuated={tier.stats['evacuated']} "
         f"requeued={tier.stats['requeued']} "
         f"bit_identical={evacuation_bit_identical}")

    # --- degradation ladder (fused engine, fault window then recovery) --
    fplan = FaultPlan(events=(FaultEvent(
        kind="dispatch", first_chunk=0, last_chunk=3, backends=("fused",)),))
    ft = FaultToleranceConfig(demote_after=2, promote_after=3)
    eng = SNNStreamEngine(params_q, cfg, batch_size=lanes,
                          chunk_steps=chunk, patience=10_000, seed=0,
                          backend="fused", injector=FaultInjector(fplan, 0),
                          fault_cfg=ft)
    for im in imgs[:2 * lanes]:
        eng.submit(im)
    t0 = time.perf_counter()
    lres = eng.run()
    dt_ladder = time.perf_counter() - t0
    ref = SNNStreamEngine(params_q, cfg, batch_size=lanes,
                          chunk_steps=chunk, patience=10_000, seed=0,
                          backend="fused")
    for im in imgs[:2 * lanes]:
        ref.submit(im)
    lref = ref.run()
    demotes = [e for e in eng.controller.history
               if isinstance(e, dict) and e.get("event") == "demote"]
    promotes = [e for e in eng.controller.history
                if isinstance(e, dict) and e.get("event") == "promote"]
    ladder_bit_identical = set(lres) == set(lref) and all(
        _sig(lres[rid]) == _sig(lref[rid]) for rid in lref)
    ladder_repromoted = (bool(demotes) and bool(promotes)
                         and eng.health.demotion_level == 0
                         and eng.backend_effective == "fused")
    emit("faults.ladder", dt_ladder * 1e6 / (2 * lanes),
         f"demoted_to={demotes[0]['to'] if demotes else None} "
         f"faults={eng.health.total_faults} "
         f"repromoted={ladder_repromoted} "
         f"bit_identical={ladder_bit_identical}")

    # --- chaos accounting: partition + deterministic replay -------------
    chaos = FaultPlan(events=(
        FaultEvent(kind="poison", request_id=5, first_chunk=0),
        FaultEvent(kind="device_loss", engine=0, first_chunk=4,
                   state_lost=True)),
        seed=13, dispatch_rate=0.02)

    def chaos_once():
        t = make_serving_tier(
            params_q, cfg,
            dataclasses.replace(SNN_SERVING_TIER, num_engines=2,
                                lanes_per_engine=lanes, chunk_steps=chunk,
                                queue_limit=3, shedding=True,
                                fault_plan=chaos),
            patience=10_000, seed=0, backend="reference")
        crids = [t.submit(im, deadline_steps=(8 if k % 5 == 0 else None))
                 for k, im in enumerate(imgs)]
        cres = t.run()
        partition = (
            set(cres) | set(t.shed) | set(t.faulted) == set(crids)
            and not (set(cres) & set(t.shed))
            and not (set(cres) & set(t.faulted))
            and not (set(t.shed) & set(t.faulted)))
        return ({r: _sig(v) for r, v in cres.items()}, dict(t.shed),
                dict(t.faulted), dict(t.stats,
                                      routed_per_engine=tuple(
                                          t.stats["routed_per_engine"])),
                partition)

    first = chaos_once()
    second = chaos_once()
    replay_deterministic = first == second
    no_silent_loss = failover_partition_ok and first[4]
    faulted = first[2]
    emit("faults.chaos", None,
         f"served={len(first[0])} shed={len(first[1])} "
         f"faulted={len(faulted)} "
         f"reasons={sorted({r.reason for r in faulted.values()})} "
         f"replay_deterministic={replay_deterministic} "
         f"partition={no_silent_loss}")

    # --- process failover: worker kill + coordinator crash + recover ----
    # Small subprocess cluster (spawn cost, not compute, dominates) driven
    # through the full contract schedule: worker 1 is SIGKILLed mid-window
    # at round 2, the coordinator dies at round 4, and a fresh coordinator
    # rebuilds accounting from the replicated JSONL ledgers and finishes
    # the workload.  The whole sequence runs twice for replay determinism.
    proc_plan = "seed=0,worker_kill=1@2,coordinator_kill=4"
    proc_imgs = imgs[:2 * lanes + 2]
    ckw = dict(num_workers=2, lanes_per_worker=lanes, chunk_steps=chunk,
               patience=10_000, seed=0, backend="reference",
               fault_plan=proc_plan)
    peng = SNNStreamEngine(params_q, cfg, batch_size=lanes,
                           chunk_steps=chunk, patience=10_000, seed=0,
                           backend="reference")
    for i, im in enumerate(proc_imgs):
        peng.submit(im, request_id=i)
    proc_base = {r: _sig(v) for r, v in peng.run().items()}

    def process_failover_once():
        with tempfile.TemporaryDirectory() as d:
            co = ClusterCoordinator(params_q, cfg, ledger_dir=d, **ckw)
            try:
                for i, im in enumerate(proc_imgs):
                    co.submit(im, request_id=i)
                try:
                    co.run()
                    crashed = False
                except CoordinatorCrash:
                    crashed = True
            finally:
                co.close()
            submits = {r["rid"] for r in read_ledger(
                os.path.join(d, "coordinator.jsonl")) if r["kind"] == "submit"}
            t0 = time.perf_counter()
            with ClusterCoordinator.recover(params_q, cfg, ledger_dir=d,
                                            **ckw) as co2:
                res = co2.run()
                dt = time.perf_counter() - t0
                return ({r: _sig(v) for r, v in res.items()},
                        dict(co2.shed), dict(co2.faulted), dict(co2.stats),
                        crashed, submits == set(range(len(proc_imgs))),
                        co2.round, dt)

    p1 = process_failover_once()
    p2 = process_failover_once()
    process_partition = (set(p1[0]) | set(p1[1]) | set(p1[2])
                         == set(range(len(proc_imgs)))
                         and not (set(p1[0]) & set(p1[2])))
    process_failover_bit_identical = (
        p1[4] and process_partition and not p1[1] and not p1[2]
        and p1[0] == proc_base)          # lossless schedule, every sig equal
    ledger_survives_coordinator_restart = p1[4] and p1[5]
    process_replay_deterministic = p1[:7] == p2[:7]   # all but wall time
    emit("faults.process", p1[7] * 1e6 / len(proc_imgs),
         f"recovery_rounds={p1[6]} "
         f"workers_failed={p1[3]['workers_failed']} "
         f"respawned={p1[3]['respawned']} evacuated={p1[3]['evacuated']} "
         f"bit_identical={process_failover_bit_identical} "
         f"ledger_recovered={ledger_survives_coordinator_restart} "
         f"replay_deterministic={process_replay_deterministic}")

    save_json({
        "layer_sizes": list(sizes),
        "num_steps": T,
        "chunk_steps": chunk,
        "lanes_per_engine": lanes,
        "failover": {
            "fail_round": fail_round,
            "evacuation_latency_chunks": evac_latency,
            "recovery_overhead_chunks": overhead,
            "evacuated": tier.stats["evacuated"],
            "requeued": tier.stats["requeued"],
        },
        "ladder": {
            "demoted_to": demotes[0]["to"] if demotes else None,
            "faults_absorbed": eng.health.total_faults,
            "serve_us_per_img": dt_ladder * 1e6 / (2 * lanes),
        },
        "chaos": {
            "served": len(first[0]),
            "shed": len(first[1]),
            "faulted": len(faulted),
            "quarantined": first[3]["quarantined"],
            "engines_failed": first[3]["engines_failed"],
        },
        "process": {
            "recovery_rounds": p1[6],
            "recovery_us_per_img": p1[7] * 1e6 / len(proc_imgs),
            "workers_failed": p1[3]["workers_failed"],
            "respawned": p1[3]["respawned"],
            "evacuated": p1[3]["evacuated"],
            "requeued": p1[3]["requeued"],
        },
        "evacuation_bit_identical": evacuation_bit_identical,
        "ladder_bit_identical": ladder_bit_identical,
        "ladder_repromoted": ladder_repromoted,
        "replay_deterministic": replay_deterministic,
        "no_silent_loss": no_silent_loss,
        "process_failover_bit_identical": process_failover_bit_identical,
        "ledger_survives_coordinator_restart":
            ledger_survives_coordinator_restart,
        "process_replay_deterministic": process_replay_deterministic,
    }, "bench", "BENCH_faults.json")
    assert evacuation_bit_identical and ladder_bit_identical
    assert ladder_repromoted and replay_deterministic and no_silent_loss
    assert process_failover_bit_identical
    assert ledger_survives_coordinator_restart
    assert process_replay_deterministic
    return {"failover_rounds": rounds, "overhead": overhead}


if __name__ == "__main__":
    run()
