"""SNN engine throughput on this host: pure-JAX scan engine vs the Pallas
kernels (interpret mode on CPU — correctness path; the BlockSpecs target
TPU VMEM).  Reports images/s and µs per inference for the paper topology.

Single-device only — the data-parallel lane-mesh numbers (per-device
throughput, admission-overlap timing) live in bench_engine_sharded.py
(suite ``engine_sharded``)."""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.snn_mnist import SNN_CONFIG
from repro.core import prng, snn
from repro.kernels import ops

from .common import emit, save_json, time_record, trained_snn


def run(batch: int = 256, T: int = 10):
    params, params_q, ds = trained_snn()
    cfg = dataclasses.replace(SNN_CONFIG, num_steps=T)
    px = jnp.asarray((ds.x_test[:batch] * 255).astype(np.uint8))
    st = prng.seed_state(3, px.shape)

    # backend pinned to "reference": these two rows are the jnp-scan-engine
    # baselines, and on TPU the "auto" default would silently dispatch both
    # to the fused Pallas kernel, timing it against itself.
    engine = jax.jit(lambda p, a, b: snn.snn_apply_int(
        p, a, b, cfg, backend="reference")["pred"])
    recs = {}
    recs["jax_scan"] = time_record(engine, params_q, px, st)
    us = recs["jax_scan"].us
    ips = batch / (us * 1e-6)
    emit("engine.jax_scan", us / batch,
         f"batch={batch} T={T} imgs_per_s={ips:.0f}")

    # §Perf-optimized engine: f32-unit synaptic sum (bit-exact: |Σ|<2^24)
    # + encoder fused into the LIF scan (no spike-train round-trip).
    fast_cfg = dataclasses.replace(cfg, dot_impl="f32", fuse_encoder=True)
    fast = jax.jit(lambda p, a, b: snn.snn_apply_int(
        p, a, b, fast_cfg, backend="reference")["pred"])
    recs["fused_f32"] = time_record(fast, params_q, px, st)
    us_fast = recs["fused_f32"].us
    emit("engine.fused_f32", us_fast / batch,
         f"imgs_per_s={batch/(us_fast*1e-6):.0f} "
         f"speedup={us/us_fast:.2f}x (bit-identical)")
    same = bool((np.asarray(engine(params_q, px, st))
                 == np.asarray(fast(params_q, px, st))).all())
    emit("engine.fused_f32_exact", None, f"bit_identical={same}")
    assert same

    # staged Pallas path: encoder kernel launch + T-step LIF kernel launch
    # (the (T, B, N_in) spike tensor round-trips between the launches)
    w_q = params_q["layers"][0]["w_q"]

    def pallas_engine(px, st):
        spikes, _ = ops.poisson_encode_op(px, st, T)
        spk, vtr, vfin = ops.lif_forward_op(
            spikes, w_q, decay_shift=cfg.lif.decay_shift,
            v_threshold=cfg.lif.v_threshold)
        return jnp.argmax(jnp.sum(spk.astype(jnp.int32), 0), -1)

    interp = jax.default_backend() != "tpu"
    recs["pallas_staged"] = time_record(pallas_engine, px, st,
                                        interpret=interp)
    us_k = recs["pallas_staged"].us
    emit("engine.pallas_staged", us_k / batch,
         f"batch={batch} T={T} imgs_per_s={batch/(us_k*1e-6):.0f} "
         f"(interpret mode — CPU correctness path)")

    # fused Pallas megakernel: whole window in one launch, spikes on-chip
    fused = jax.jit(lambda p, a, b: snn.snn_apply_int(
        p, a, b, cfg, backend="fused")["pred"])
    recs["pallas_fused"] = time_record(fused, params_q, px, st,
                                       interpret=interp)
    us_f = recs["pallas_fused"].us
    emit("engine.pallas_fused", us_f / batch,
         f"batch={batch} T={T} imgs_per_s={batch/(us_f*1e-6):.0f} "
         f"(interpret mode — CPU correctness path)")

    # agreement across the paths
    a = np.asarray(engine(params_q, px, st))
    b = np.asarray(pallas_engine(px, st))
    c = np.asarray(fused(params_q, px, st))
    agree = float(((a == b) & (a == c)).mean())
    emit("engine.agreement", None, f"jax_vs_pallas_pred_agree={agree:.4f}")
    save_json({"jax_us_per_img": us / batch,
               "pallas_staged_us_per_img": us_k / batch,
               "pallas_fused_us_per_img": us_f / batch,
               "agreement": agree,
               "timing": {k: r.to_json() for k, r in recs.items()},
               }, "bench", "engine_throughput.json")
    assert agree == 1.0
    return {"jax": us, "pallas": us_k, "fused": us_f}


if __name__ == "__main__":
    run()
