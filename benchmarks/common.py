"""Shared benchmark plumbing: trained-weight cache, timing, CSV emission.

Timing is delegated to ``repro.tune.timing`` — the same deterministic
harness (warmup, median-of-k, monotonic clock) the autotuner measures
candidates with, so benchmark numbers and tuner decisions come from one
code path.  :func:`time_call` keeps the historical µs-median signature;
:func:`time_record` returns the full :class:`~repro.tune.timing.TimingRecord`
(median, stddev, samples, ``device_kind``, ``interpret``) for benches
that tag their saved JSON with measurement provenance.
"""

from __future__ import annotations

import json
import os

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results")


def results_path(*parts) -> str:
    p = os.path.join(RESULTS, *parts)
    os.makedirs(os.path.dirname(p), exist_ok=True)
    return p


def emit(name: str, us_per_call: float | None, derived: str) -> None:
    """One CSV line per the harness contract: name,us_per_call,derived."""
    us = "" if us_per_call is None else f"{us_per_call:.1f}"
    print(f"{name},{us},{derived}")


def time_record(fn, *args, repeats: int = 3, warmup: int = 1,
                interpret: bool = False):
    """Measure fn(*args) via the shared harness → TimingRecord."""
    from repro.tune.timing import measure
    return measure(fn, *args, repeats=repeats, warmup=warmup,
                   interpret=interpret)


def time_call(fn, *args, repeats: int = 3, warmup: int = 1) -> float:
    """Median wall-time of fn(*args) in microseconds."""
    return time_record(fn, *args, repeats=repeats, warmup=warmup).us


def save_json(obj, *parts) -> str:
    p = results_path(*parts)
    with open(p, "w") as f:
        json.dump(obj, f, indent=1, default=float)
    return p


_CACHE = {}


def trained_snn(steps: int = 1500):
    """Train-or-load the paper-topology SNN once per process."""
    if "snn" not in _CACHE:
        from repro.core.train_snn import fit_or_load
        _CACHE["snn"] = fit_or_load(steps=steps)
    return _CACHE["snn"]
