"""Shared benchmark plumbing: trained-weight cache, timing, CSV emission."""

from __future__ import annotations

import json
import os
import time

import numpy as np

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results")


def results_path(*parts) -> str:
    p = os.path.join(RESULTS, *parts)
    os.makedirs(os.path.dirname(p), exist_ok=True)
    return p


def emit(name: str, us_per_call: float | None, derived: str) -> None:
    """One CSV line per the harness contract: name,us_per_call,derived."""
    us = "" if us_per_call is None else f"{us_per_call:.1f}"
    print(f"{name},{us},{derived}")


def time_call(fn, *args, repeats: int = 3, warmup: int = 1) -> float:
    """Median wall-time of fn(*args) in microseconds."""
    import jax
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts) * 1e6)


def save_json(obj, *parts) -> str:
    p = results_path(*parts)
    with open(p, "w") as f:
        json.dump(obj, f, indent=1, default=float)
    return p


_CACHE = {}


def trained_snn(steps: int = 1500):
    """Train-or-load the paper-topology SNN once per process."""
    if "snn" not in _CACHE:
        from repro.core.train_snn import fit_or_load
        _CACHE["snn"] = fit_or_load(steps=steps)
    return _CACHE["snn"]
