"""Staged vs fused encode→LIF: throughput and HBM bytes moved (§V-B).

The staged path launches the Poisson-encoder kernel and the LIF kernel
separately, materialising the full (T, B, N_in) uint8 spike tensor in HBM
between them — written once by the encoder, read once by the LIF layer.
The fused megakernel (kernels/fused_snn.py) keeps the spike stream in
VMEM/registers for the whole window, so the encoder→layer-1 hop moves
ZERO HBM bytes; only pixels, PRNG state and the small per-neuron outputs
cross the memory boundary.  That is the paper's "no external memory
access" property, and the acceptance bar here: the spike tensor the staged
path moves is ≥ T× the pixel stream itself.

Runs on random weights (no training needed) so it doubles as the CI
kernel-regression smoke: REPRO_BENCH_TINY=1 shrinks sizes.  Emits CSV
lines and saves results/bench/BENCH_fused.json (uploaded as a CI
artifact).
"""

from __future__ import annotations

import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.snn_mnist import SNN_CONFIG
from repro.core import prng, snn

from .common import emit, save_json, time_call


def _sizes():
    if os.environ.get("REPRO_BENCH_TINY"):
        return dict(batch=16, T=5, n_in=784, n_out=10, repeats=2)
    return dict(batch=128, T=20, n_in=784, n_out=10, repeats=3)


def run():
    s = _sizes()
    batch, T, n_in, n_out = s["batch"], s["T"], s["n_in"], s["n_out"]
    rng = np.random.default_rng(0)
    w = jnp.asarray(rng.integers(-256, 256, (n_in, n_out)), jnp.int16)
    params_q = {"layers": [{"w_q": w, "scale": jnp.float32(1.0)}]}
    px = jnp.asarray(rng.integers(0, 256, (batch, n_in), dtype=np.uint8))
    st = prng.seed_state(11, px.shape)
    cfg = dataclasses.replace(SNN_CONFIG, num_steps=T)

    # --- bit-exactness across backends (same PRNG seeds) -----------------
    outs = {}
    times = {}
    for backend in ("reference", "staged", "fused"):
        fn = jax.jit(lambda p, a, b, bk=backend:
                     snn.snn_apply_int(p, a, b, cfg, backend=bk)
                     ["spike_counts"])
        times[backend] = time_call(fn, params_q, px, st,
                                   repeats=s["repeats"])
        outs[backend] = np.asarray(fn(params_q, px, st))
        emit(f"fused.{backend}", times[backend] / batch,
             f"batch={batch} T={T} "
             f"imgs_per_s={batch / (times[backend] * 1e-6):.0f}"
             + ("" if jax.default_backend() == "tpu"
                else " (Pallas interpret on CPU)" if backend != "reference"
                else ""))
    exact = (np.array_equal(outs["staged"], outs["fused"])
             and np.array_equal(outs["reference"], outs["fused"]))
    emit("fused.bit_identical", None, f"staged==fused==reference={exact}")
    assert exact, "backends disagree on spike counts"

    # --- HBM bytes moved for the encoder→layer-1 hop ---------------------
    # Staged: the (T, B, N_in) uint8 spike tensor is written by the encoder
    # launch and read back by the LIF launch.
    staged_hop = 2 * T * batch * n_in
    # Fused: the spike stream never leaves the core.
    fused_hop = 0
    # Common traffic both paths pay (pixels in, PRNG state in+out):
    stream = batch * n_in * (1 + 4 + 4)
    ratio_vs_pixels = staged_hop / (batch * n_in)
    emit("fused.hop_bytes_staged", None, f"{staged_hop}")
    emit("fused.hop_bytes_fused", None, f"{fused_hop}")
    emit("fused.hop_reduction", None,
         f"spike_tensor_vs_pixel_stream={ratio_vs_pixels:.0f}x "
         f"(>=T={T}x required) total_encoder_traffic="
         f"{(stream + staged_hop) / stream:.1f}x_less_when_fused")
    assert fused_hop == 0, "fused path must not materialise spikes"
    assert staged_hop >= T * batch * n_in, "hop accounting inconsistent"

    save_json({
        "sizes": {k: v for k, v in s.items() if k != "repeats"},
        "us_per_image": {k: v / batch for k, v in times.items()},
        "bit_identical": bool(exact),
        "hop_bytes": {"staged": staged_hop, "fused": fused_hop},
        "hop_reduction_vs_pixels": ratio_vs_pixels,
        "backend_platform": jax.default_backend(),
    }, "bench", "BENCH_fused.json")
    return times


if __name__ == "__main__":
    run()
