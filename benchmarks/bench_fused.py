"""Staged vs fused encode→LIF: throughput and HBM bytes moved (§V-B).

The staged path launches the Poisson-encoder kernel and the LIF kernel
separately, materialising the full (T, B, N_in) uint8 spike tensor in HBM
between them — written once by the encoder, read once by the LIF layer.
The fused megakernel (kernels/fused_snn.py) keeps the spike stream in
VMEM/registers for the whole window, so the encoder→layer-1 hop moves
ZERO HBM bytes; only pixels, PRNG state and the small per-neuron outputs
cross the memory boundary.  That is the paper's "no external memory
access" property, and the acceptance bar here: the spike tensor the staged
path moves is ≥ T× the pixel stream itself.

Runs on random weights (no training needed) so it doubles as the CI
kernel-regression smoke: REPRO_BENCH_TINY=1 shrinks sizes.  Emits CSV
lines and saves results/bench/BENCH_fused.json (uploaded as a CI
artifact).
"""

from __future__ import annotations

import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.snn_mnist import (SNN_CONFIG, SNN_CONFIG_DEEP,
                                     SNN_CONFIG_WIDE)
from repro.core import prng, snn
from repro.kernels import fused_snn, ops

from .common import emit, save_json, time_record


def _interp(backend: str) -> bool:
    """True when this backend's timing ran Pallas interpret mode (CPU)."""
    return backend.startswith("fused") or backend == "staged" \
        if jax.default_backend() != "tpu" else False


def _resident_weight_bytes(weights):
    """Per-program resident weight bytes, packed vs the pre-packing layout.

    MEASURED, not assumed: the packed figure is the actual ``nbytes`` of
    the plane arrays ``kernels.fused_snn.pack_weights`` emits for the
    128-padded shapes the kernel allocates — if packing ever regresses to
    a wider dtype or an extra plane, this number (and the CI gate on it)
    moves.  Legacy is the pre-PR layout over the same padded shapes:
    int16 storage plus the whole-matrix int32 cast the first kernel
    revision held live for the entire launch (6 B/weight).
    """
    pad = fused_snn._pad128
    packed_bytes = legacy_bytes = 0
    for w in weights:
        wp = jnp.pad(w, [(0, pad(w.shape[0]) - w.shape[0]),
                         (0, pad(w.shape[1]) - w.shape[1])])
        packed = fused_snn.pack_weights(wp)
        packed_bytes += packed.size * packed.dtype.itemsize
        legacy_bytes += wp.size * (2 + 4)       # int16 + resident i32 cast
    return {"packed_int8": int(packed_bytes),
            "legacy_int16_cast": int(legacy_bytes),
            "reduction": round(legacy_bytes / packed_bytes, 3)}


def _sizes():
    if os.environ.get("REPRO_BENCH_TINY"):
        return dict(batch=16, T=5, n_in=784, n_out=10, repeats=2)
    return dict(batch=128, T=20, n_in=784, n_out=10, repeats=3)


def _sizes_multilayer():
    if os.environ.get("REPRO_BENCH_TINY"):
        return dict(batch=8, T=4, layer_sizes=(784, 64, 32, 10), repeats=2)
    return dict(batch=64, T=20, layer_sizes=SNN_CONFIG_DEEP.layer_sizes,
                repeats=3)


def run():
    s = _sizes()
    batch, T, n_in, n_out = s["batch"], s["T"], s["n_in"], s["n_out"]
    rng = np.random.default_rng(0)
    w = jnp.asarray(rng.integers(-256, 256, (n_in, n_out)), jnp.int16)
    params_q = {"layers": [{"w_q": w, "scale": jnp.float32(1.0)}]}
    px = jnp.asarray(rng.integers(0, 256, (batch, n_in), dtype=np.uint8))
    st = prng.seed_state(11, px.shape)
    cfg = dataclasses.replace(SNN_CONFIG, num_steps=T)

    # --- bit-exactness across backends (same PRNG seeds) -----------------
    outs = {}
    times = {}
    recs = {}
    for backend in ("reference", "staged", "fused"):
        fn = jax.jit(lambda p, a, b, bk=backend:
                     snn.snn_apply_int(p, a, b, cfg, backend=bk)
                     ["spike_counts"])
        recs[backend] = time_record(fn, params_q, px, st,
                                    repeats=s["repeats"],
                                    interpret=_interp(backend))
        times[backend] = recs[backend].us
        outs[backend] = np.asarray(fn(params_q, px, st))
        emit(f"fused.{backend}", times[backend] / batch,
             f"batch={batch} T={T} "
             f"imgs_per_s={batch / (times[backend] * 1e-6):.0f}"
             + ("" if jax.default_backend() == "tpu"
                else " (Pallas interpret on CPU)" if backend != "reference"
                else ""))
    exact = (np.array_equal(outs["staged"], outs["fused"])
             and np.array_equal(outs["reference"], outs["fused"]))
    emit("fused.bit_identical", None, f"staged==fused==reference={exact}")
    assert exact, "backends disagree on spike counts"

    # --- HBM bytes moved for the encoder→layer-1 hop ---------------------
    # Staged: the (T, B, N_in) uint8 spike tensor is written by the encoder
    # launch and read back by the LIF launch.
    staged_hop = 2 * T * batch * n_in
    # Fused: the spike stream never leaves the core.
    fused_hop = 0
    # Common traffic both paths pay (pixels in, PRNG state in+out):
    stream = batch * n_in * (1 + 4 + 4)
    ratio_vs_pixels = staged_hop / (batch * n_in)
    emit("fused.hop_bytes_staged", None, f"{staged_hop}")
    emit("fused.hop_bytes_fused", None, f"{fused_hop}")
    emit("fused.hop_reduction", None,
         f"spike_tensor_vs_pixel_stream={ratio_vs_pixels:.0f}x "
         f"(>=T={T}x required) total_encoder_traffic="
         f"{(stream + staged_hop) / stream:.1f}x_less_when_fused")
    assert fused_hop == 0, "fused path must not materialise spikes"
    assert staged_hop >= T * batch * n_in, "hop accounting inconsistent"

    # --- resident weight bytes: int8-packed planes vs int16+cast ---------
    resident = _resident_weight_bytes((w,))
    emit("fused.resident_weight_bytes", None,
         f"packed_int8={resident['packed_int8']} "
         f"legacy_int16_cast={resident['legacy_int16_cast']} "
         f"reduction={resident['reduction']:.1f}x")
    assert resident["legacy_int16_cast"] >= 2 * resident["packed_int8"], \
        "packing must at least halve resident weight bytes"

    sparse = run_sparse(params_q, cfg, batch)

    save_json({
        "sizes": {k: v for k, v in s.items() if k != "repeats"},
        "us_per_image": {k: v / batch for k, v in times.items()},
        "bit_identical": bool(exact),
        "hop_bytes": {"staged": staged_hop, "fused": fused_hop},
        "hop_reduction_vs_pixels": ratio_vs_pixels,
        "resident_weight_bytes": resident,
        "sparse": sparse,
        "timing": {k: r.to_json() for k, r in recs.items()},
        "backend_platform": jax.default_backend(),
    }, "bench", "BENCH_fused.json")

    run_multilayer()
    run_streamed()
    run_telemetry()
    return times


def run_sparse(params_q, cfg, batch):
    """Executed-adds vs spike density under event-driven tile skipping.

    The kernel's energy counter counts ``input spikes × enabled outputs``
    — on the sparse path a skipped tile pair carries zero of either, so
    the counter measures exactly the adds the event-driven datapath
    executes, and must scale linearly with the Poisson density px/256
    (the analytic (1 − sparsity) law the paper's Table II argues from).
    """
    n_in, n_out = cfg.layer_sizes[0], cfg.layer_sizes[-1]
    T = cfg.num_steps
    weights = tuple(l["w_q"] for l in params_q["layers"])
    st = prng.seed_state(29, (batch, n_in))
    levels = [0, 33, 128, 255]
    dense_cap = T * batch * n_in * n_out        # every line spiking
    adds, fracs = [], []
    for px_level in levels:
        px = jnp.full((batch, n_in), px_level, jnp.uint8)
        out = ops.fused_snn_stack_op(
            px, st, weights, num_steps=T,
            decay_shift=cfg.lif.decay_shift,
            v_threshold=cfg.lif.v_threshold, sparse_skip=True)
        total = int(np.asarray(out["active_adds"]).sum())
        adds.append(total)
        fracs.append(total / dense_cap)
        emit(f"fused.sparse_adds@{px_level}", None,
             f"density={px_level / 256:.3f} executed_adds={total} "
             f"fraction_of_dense={total / dense_cap:.3f}")
    # executed adds must track density: fraction ≈ px/256 per level
    scaling_ok = all(abs(f - lv / 256) < 0.05
                     for f, lv in zip(fracs, levels))
    emit("fused.sparse_scaling", None,
         f"adds_track_density={scaling_ok} "
         f"(fractions={[round(f, 3) for f in fracs]})")
    assert adds[0] == 0, "zero-density input must execute zero adds"
    assert scaling_ok, "executed adds do not scale with spike density"
    return {"px_levels": levels,
            "densities": [lv / 256 for lv in levels],
            "executed_adds": adds,
            "fraction_of_dense": fracs,
            "scaling_ok": bool(scaling_ok)}


def run_multilayer():
    """Hidden-layer stacks: per-hop HBM spike bytes, staged vs fused.

    Bouvier et al. (arXiv:2005.01467) identify inter-layer spike traffic
    as the dominant cost of multi-layer SNN hardware.  The staged path
    materialises every hop — the encoder output AND each hidden
    activation train — as a (T, B, N) tensor written+read through HBM
    (2·T·B·N bytes per hop); the multi-layer megakernel carries all of it
    in VMEM scratch across the static layer loop, so every hop moves ZERO
    HBM bytes.  Acceptance bar: fused per-hop bytes are exactly 0 on a
    ≥2-hidden-layer stack while the backends stay bit-identical.
    """
    s = _sizes_multilayer()
    batch, T, sizes = s["batch"], s["T"], tuple(s["layer_sizes"])
    rng = np.random.default_rng(1)
    params_q = {"layers": [
        {"w_q": jnp.asarray(rng.integers(-256, 256, (n_in, n_out)),
                            jnp.int16),
         "scale": jnp.float32(1.0)}
        for n_in, n_out in zip(sizes[:-1], sizes[1:])]}
    px = jnp.asarray(rng.integers(0, 256, (batch, sizes[0]), dtype=np.uint8))
    st = prng.seed_state(23, px.shape)
    cfg = dataclasses.replace(SNN_CONFIG_DEEP, layer_sizes=sizes,
                              num_steps=T)

    outs, adds, times, recs = {}, {}, {}, {}
    for backend in ("reference", "staged", "fused"):
        fn = jax.jit(lambda p, a, b, bk=backend:
                     snn.snn_apply_int(p, a, b, cfg, backend=bk))
        recs[backend] = time_record(
            lambda p, a, b: fn(p, a, b)["spike_counts"], params_q, px, st,
            repeats=s["repeats"], interpret=_interp(backend))
        times[backend] = recs[backend].us
        out = fn(params_q, px, st)
        outs[backend] = np.asarray(out["spike_counts"])
        adds[backend] = np.asarray(out["active_adds"])
        emit(f"fused_ml.{backend}", times[backend] / batch,
             f"layers={len(sizes) - 1} batch={batch} T={T}"
             + ("" if jax.default_backend() == "tpu"
                else " (Pallas interpret on CPU)"
                if backend != "reference" else ""))
    exact = all(np.array_equal(outs["reference"], outs[b])
                and np.array_equal(adds["reference"], adds[b])
                for b in ("staged", "fused"))
    emit("fused_ml.bit_identical", None,
         f"counts+adds staged==fused==reference={exact}")
    assert exact, "multi-layer backends disagree"

    # Per-hop HBM spike bytes: hop 0 is encoder→layer1, hop l is
    # layer l→layer l+1.  Staged writes then reads each (T, B, N) uint8
    # spike train.  The fused path's zero is OBSERVED, not assumed: the
    # whole stack must lower to exactly one pallas_call (no inter-launch
    # tensor to round-trip) and must never materialise an input spike
    # train — if a regression reintroduces staged launches under the
    # fused backend, this gate (and the CI assert on the JSON) goes red.
    fused_jaxpr = str(jax.make_jaxpr(
        lambda p, a, b: snn.snn_apply_int(p, a, b, cfg, backend="fused")
        ["spike_counts"])(params_q, px, st))
    n_launches = fused_jaxpr.count("pallas_call")
    fused_out = snn.snn_apply_int(params_q, px, st, cfg, backend="fused")
    fused_is_one_launch = (n_launches == 1
                           and fused_out["input_spikes"] is None)
    emit("fused_ml.launches", None,
         f"fused_pallas_calls={n_launches} input_spikes_materialised="
         f"{fused_out['input_spikes'] is not None}")
    assert fused_is_one_launch, \
        f"fused path no longer a single launch ({n_launches} pallas_calls)"
    staged_hops = [2 * T * batch * n for n in sizes[:-1]]
    fused_hops = [0 if fused_is_one_launch else h for h in staged_hops]
    for i, (sh, fh) in enumerate(zip(staged_hops, fused_hops)):
        emit(f"fused_ml.hop{i}_bytes", None, f"staged={sh} fused={fh}")
    emit("fused_ml.hop_bytes_total", None,
         f"staged={sum(staged_hops)} fused={sum(fused_hops)} "
         f"({sum(staged_hops) / (batch * sizes[0]):.0f}x the pixel stream)")
    assert sum(fused_hops) == 0, "fused path must not materialise spikes"
    assert len(staged_hops) >= 3, "need >=2 hidden layers for this bench"

    resident = _resident_weight_bytes(
        tuple(l["w_q"] for l in params_q["layers"]))
    emit("fused_ml.resident_weight_bytes", None,
         f"packed_int8={resident['packed_int8']} "
         f"legacy_int16_cast={resident['legacy_int16_cast']} "
         f"reduction={resident['reduction']:.1f}x")

    save_json({
        "sizes": {"batch": batch, "T": T, "layer_sizes": list(sizes)},
        "us_per_image": {k: v / batch for k, v in times.items()},
        "bit_identical": bool(exact),
        "hop_bytes": {"staged": staged_hops, "fused": fused_hops,
                      "staged_total": sum(staged_hops),
                      "fused_total": sum(fused_hops)},
        "fused_single_launch": bool(fused_is_one_launch),
        "resident_weight_bytes": resident,
        "timing": {k: r.to_json() for k, r in recs.items()},
        "backend_platform": jax.default_backend(),
    }, "bench", "BENCH_fused_multilayer.json")
    return times


def _sizes_streamed():
    if os.environ.get("REPRO_BENCH_TINY"):
        return dict(batch=8, T=2, repeats=1)
    return dict(batch=16, T=8, repeats=2)


def run_streamed():
    """VMEM-oversized stack through the ``fused_streamed`` backend.

    ``SNN_CONFIG_WIDE``'s packed resident footprint (~13.5 MiB padded)
    exceeds the 12 MiB residency budget, so an explicit ``fused`` request
    must raise — and ``fused_streamed`` must run the whole stack in ONE
    Pallas launch anyway (packed weights double-buffered out of HBM),
    bit-identical to the reference scan.  Interpret mode on CPU; the
    wall-clock win is a TPU measurement (ROADMAP's on-TPU item).
    """
    s = _sizes_streamed()
    batch, T = s["batch"], s["T"]
    cfg = dataclasses.replace(SNN_CONFIG_WIDE, num_steps=T)
    sizes = cfg.layer_sizes
    rng = np.random.default_rng(5)
    params_q = {"layers": [
        {"w_q": jnp.asarray(rng.integers(-256, 256, (a, b)), jnp.int16),
         "scale": jnp.float32(1.0)}
        for a, b in zip(sizes[:-1], sizes[1:])]}
    px = jnp.asarray(rng.integers(0, 256, (batch, sizes[0]),
                                  dtype=np.uint8))
    st = prng.seed_state(31, px.shape)

    resident_mib = fused_snn.stack_vmem_bytes(sizes, 8, T) / 2**20
    streamed_mib = fused_snn.stack_vmem_bytes(sizes, 8, T,
                                              streamed=True) / 2**20
    budget_mib = fused_snn.VMEM_BUDGET_BYTES / 2**20
    emit("fused_streamed.vmem", None,
         f"resident={resident_mib:.1f}MiB streamed={streamed_mib:.1f}MiB "
         f"budget={budget_mib:.0f}MiB")
    assert resident_mib > budget_mib, \
        "streamed bench stack must exceed the residency budget"
    assert streamed_mib <= budget_mib, \
        "streamed working set must fit the budget"

    fused_raises = False
    try:
        snn.snn_apply_int(params_q, px, st, cfg, backend="fused")
    except ValueError:
        fused_raises = True
    emit("fused_streamed.fused_raises", None,
         f"explicit_fused_raises={fused_raises}")
    assert fused_raises, "oversized stack must reject backend='fused'"

    outs, times, recs = {}, {}, {}
    for backend in ("reference", "fused_streamed"):
        fn = jax.jit(lambda p, a, b, bk=backend:
                     snn.snn_apply_int(p, a, b, cfg, backend=bk))
        recs[backend] = time_record(
            lambda p, a, b: fn(p, a, b)["spike_counts"], params_q, px, st,
            repeats=s["repeats"], interpret=_interp(backend))
        times[backend] = recs[backend].us
        out = fn(params_q, px, st)
        outs[backend] = (np.asarray(out["spike_counts"]),
                         np.asarray(out["active_adds"]))
        emit(f"fused_streamed.{backend}", times[backend] / batch,
             f"layer_sizes={sizes} batch={batch} T={T}"
             + ("" if jax.default_backend() == "tpu"
                else " (Pallas interpret on CPU)"
                if backend != "reference" else ""))
    exact = all(np.array_equal(a, b) for a, b in
                zip(outs["reference"], outs["fused_streamed"]))
    emit("fused_streamed.bit_identical", None,
         f"counts+adds reference==fused_streamed={exact}")
    assert exact, "streamed backend disagrees with reference"

    jaxpr = str(jax.make_jaxpr(
        lambda p, a, b: snn.snn_apply_int(p, a, b, cfg,
                                          backend="fused_streamed")
        ["spike_counts"])(params_q, px, st))
    n_launches = jaxpr.count("pallas_call")
    emit("fused_streamed.launches", None, f"pallas_calls={n_launches}")
    assert n_launches == 1, "streamed stack must stay a single launch"

    save_json({
        "sizes": {"batch": batch, "T": T, "layer_sizes": list(sizes)},
        "us_per_image": {k: v / batch for k, v in times.items()},
        "bit_identical": bool(exact),
        "single_launch": n_launches == 1,
        "explicit_fused_raises": bool(fused_raises),
        "vmem_mib": {"resident": resident_mib, "streamed": streamed_mib,
                     "budget": budget_mib},
        "timing": {k: r.to_json() for k, r in recs.items()},
        "backend_platform": jax.default_backend(),
    }, "bench", "BENCH_fused_streamed.json")
    return times


def _sizes_telemetry():
    if os.environ.get("REPRO_BENCH_TINY"):
        return dict(batch=4, T=8, n_imgs=8, chunk=3)
    return dict(batch=8, T=20, n_imgs=24, chunk=4)


def run_telemetry():
    """Telemetry side channel + adaptive dispatch controller (§ROADMAP
    "runtime density telemetry for dispatch thresholds").

    Three contract claims, all run-invariant and diffed by
    check_tracked / the CI gate:

      * ``telemetry_bit_identical`` — the ChunkTelemetry record
        (per-step/layer spike counts, prune occupancy, skipped MXU tile
        pairs) is bit-identical across the reference / staged / fused
        backends, and its adds equal the frozen energy counters
        (``adds_match``);
      * ``density_estimate_ok`` — driving the streaming engine on
        constant-level traffic, the controller's EWMA density estimate
        lands on the analytic px/256 Poisson rate for every level;
      * ``adaptive_matches_frozen`` — the same request stream served with
        the controller adaptive (live chunk lengths + threshold) returns
        bit-identical results to frozen mode: adaptivity only moves
        wall-clock.  The threshold/chunk trajectories are recorded so the
        tuning behavior itself is reviewable across PRs.
    """
    from repro.serve import AdaptiveDispatchConfig, SNNStreamEngine

    s = _sizes_telemetry()
    batch, T = s["batch"], s["T"]
    rng = np.random.default_rng(7)
    cfg = dataclasses.replace(SNN_CONFIG, num_steps=T, sparse_skip=True)
    n_in, n_out = cfg.layer_sizes[0], cfg.layer_sizes[-1]
    w = jnp.asarray(rng.integers(-256, 256, (n_in, n_out)), jnp.int16)
    params_q = {"layers": [{"w_q": w, "scale": jnp.float32(1.0)}]}

    # --- cross-backend bit-identity of the side channel ------------------
    px = jnp.asarray(np.minimum(rng.integers(0, 256, (batch, n_in)), 5)
                     .astype(np.uint8))                # sparse → tiles skip
    st = prng.seed_state(19, px.shape)
    outs = {b: snn.snn_apply_int(params_q, px, st, cfg, backend=b)
            for b in ("reference", "staged", "fused")}
    tel_identical = all(
        np.array_equal(np.asarray(getattr(outs["reference"]["telemetry"], f)),
                       np.asarray(getattr(outs[b]["telemetry"], f)))
        for b in ("staged", "fused") for f in ("n_spk", "n_en",
                                               "tiles_skipped"))
    adds_match = all(
        np.array_equal(np.asarray(outs[b]["telemetry"].adds).sum(axis=1),
                       np.asarray(outs[b]["active_adds"]))
        for b in outs)
    skipped = int(np.asarray(outs["fused"]["telemetry"].tiles_skipped).sum())
    obs_density = float(np.asarray(
        outs["fused"]["telemetry"].densities(cfg.layer_sizes))[:, 0].mean())
    emit("telemetry.bit_identical", None,
         f"staged==fused==reference={tel_identical} adds_match={adds_match} "
         f"tiles_skipped={skipped} layer0_density={obs_density:.4f}")
    assert tel_identical, "telemetry diverges across backends"
    assert adds_match, "telemetry adds != energy counters"
    assert skipped > 0, "sparse input must skip tiles"

    # --- controller: density estimate vs analytic ground truth -----------
    levels = [16, 64, 128]
    estimates, truths = [], []
    for level in levels:
        eng = SNNStreamEngine(
            params_q, cfg, batch_size=batch, chunk_steps=s["chunk"],
            patience=10_000, seed=level, backend="reference",
            adaptive=AdaptiveDispatchConfig(adaptive=True, ewma_alpha=0.5))
        for _ in range(s["n_imgs"]):
            eng.submit(np.full(n_in, level, np.uint8))
        eng.run()
        est = eng.controller.density_ewma
        estimates.append(float(est))
        truths.append(level / 256)
        emit(f"telemetry.density@{level}", None,
             f"truth={level / 256:.3f} ewma_estimate={est:.3f} "
             f"threshold={eng.dispatch_threshold:.3f}")
    density_ok = all(abs(e - t) < 0.05 for e, t in zip(estimates, truths))
    assert density_ok, f"density estimates off: {estimates} vs {truths}"

    # --- adaptivity is value-neutral + trajectory record -----------------
    imgs = rng.integers(0, 256, (s["n_imgs"], n_in), dtype=np.uint8)

    def serve(adaptive):
        eng = SNNStreamEngine(params_q, cfg, batch_size=batch,
                              chunk_steps=s["chunk"], patience=2, seed=3,
                              backend="reference", adaptive=adaptive)
        ids = [eng.submit(im) for im in imgs]
        res = eng.run()
        return {i: (res[i].pred, res[i].steps, res[i].adds,
                    tuple(res[i].spike_counts.tolist())) for i in ids}, eng

    frozen_res, frozen_eng = serve(AdaptiveDispatchConfig(adaptive=False))
    adaptive_res, adaptive_eng = serve(AdaptiveDispatchConfig(
        adaptive=True, min_chunk_steps=2, max_chunk_steps=8))
    matches = frozen_res == adaptive_res
    thr_traj = [round(h["dispatch_threshold"], 4)
                for h in adaptive_eng.controller.history]
    chunk_traj = [h["chunk_steps"] for h in adaptive_eng.controller.history]
    emit("telemetry.adaptive_matches_frozen", None,
         f"{matches} threshold_trajectory={thr_traj[:8]}... "
         f"chunk_trajectory={chunk_traj[:8]}...")
    assert matches, "adaptive mode changed predictions"
    assert frozen_eng.controller.history == [], \
        "frozen controller must record nothing (no readbacks)"

    # close the dispatch loop: route a batch through spike_matmul_op on
    # the engine's RETUNED boundary and record which datapath it picked —
    # the traced-operand threshold means this never recompiles as the
    # controller walks it
    spikes = jnp.asarray(
        (np.random.default_rng(1).random((batch, n_in)) < 0.1)
        .astype(np.uint8))
    routed, mm_tel = ops.spike_matmul_op(
        spikes, w, mode="auto",
        density_threshold=adaptive_eng.dispatch_threshold,
        with_telemetry=True)
    forced = np.asarray(ops.spike_matmul_op(spikes, w, mode="mxu"))
    dispatch_neutral = np.array_equal(np.asarray(routed), forced)
    emit("telemetry.retuned_dispatch", None,
         f"threshold={adaptive_eng.dispatch_threshold:.3f} "
         f"density={float(mm_tel.density):.3f} "
         f"used_masked={bool(mm_tel.used_masked)} "
         f"value_neutral={dispatch_neutral}")
    assert dispatch_neutral, "dispatch boundary changed results"

    save_json({
        "sizes": {"batch": batch, "T": T, "n_imgs": s["n_imgs"]},
        "telemetry_bit_identical": bool(tel_identical),
        "adds_match": bool(adds_match),
        "tiles_skipped": skipped,
        "density_estimate_ok": bool(density_ok),
        "density": {"levels": levels, "truth": truths,
                    "ewma_estimate": estimates},
        "adaptive_matches_frozen": bool(matches),
        "retuned_dispatch_value_neutral": bool(dispatch_neutral),
        "static_threshold": float(frozen_eng.dispatch_threshold),
        "threshold_trajectory": thr_traj,
        "chunk_trajectory": chunk_traj,
        "backend_platform": jax.default_backend(),
    }, "bench", "BENCH_telemetry.json")


if __name__ == "__main__":
    run()
