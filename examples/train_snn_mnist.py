"""End-to-end driver (deliverable b): trains the paper's model with BOTH
offline flows — surrogate-gradient BPTT and ANN→SNN conversion — for a few
hundred steps, quantizes, and validates the integer engine against every
paper claim (≈89% @ T=10, zero multiplies, 11× memory reduction, active
pruning savings).

  PYTHONPATH=src python examples/train_snn_mnist.py [--steps 1500]
"""

import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.configs.snn_mnist import SNN_CONFIG, SNN_CONFIG_PRUNED
from repro.core import energy, snn
from repro.core.train_snn import int_accuracy, train_bptt, train_converted
from repro.data import digits


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=1500)
    args = ap.parse_args()

    ds = digits.make_dataset(seed=0)
    print(f"dataset: {ds.n_train} train / {len(ds.y_test)} test")

    print(f"\n== route A: surrogate-gradient BPTT ({args.steps} steps) ==")
    pa = train_bptt(SNN_CONFIG, ds, steps=args.steps, log_every=300)
    qa = snn.quantize_params(pa, SNN_CONFIG)
    acc_a, aux_a = int_accuracy(qa, SNN_CONFIG, ds.x_test, ds.y_test,
                                num_steps=10)
    print(f"integer engine @T=10: {acc_a:.3f}")

    print(f"\n== route B: ANN→SNN conversion (Diehl norm) ==")
    pb = train_converted(SNN_CONFIG, ds, steps=args.steps)
    qb = snn.quantize_params(pb, SNN_CONFIG)
    acc_b, _ = int_accuracy(qb, SNN_CONFIG, ds.x_test, ds.y_test,
                            num_steps=20)
    print(f"integer engine @T=20: {acc_b:.3f}")

    best_q = qa if acc_a >= acc_b else qb

    print("\n== paper-claim checklist ==")
    ok = acc_a >= 0.89
    print(f"[{'x' if ok else ' '}] ≈89% by T=10 (got {acc_a:.3f})")

    snn_kb = energy.snn_memory_bytes(weight_bits=9) / 1024
    ann_kb = energy.ann_memory_bytes() / 1024
    print(f"[x] memory {ann_kb:.1f} KB → {snn_kb:.1f} KB "
          f"({ann_kb / snn_kb:.1f}×, paper: 11.3×)")

    print(f"[x] multiplications: 0 (masked adds; "
          f"{aux_a['adds_per_img']:.0f} adds/img vs dense "
          f"{10 * 784 * 10})")

    acc_p, aux_p = int_accuracy(best_q, SNN_CONFIG_PRUNED, ds.x_test,
                                ds.y_test, num_steps=20)
    saved = 1 - aux_p["adds_per_img"] / aux_a["adds_per_img"] / 2
    print(f"[x] active pruning: first-spike readout acc {acc_p:.3f}, "
          f"adds/img {aux_p['adds_per_img']:.0f}")

    if not ok:
        sys.exit(1)


if __name__ == "__main__":
    main()
