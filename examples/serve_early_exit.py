"""Early-exit serving (the paper's active pruning at the request level).

Three demos:
  1. SNN classification with per-image early exit: an image whose running
     prediction has been stable for `patience` timesteps stops consuming
     timesteps — the latency/energy histogram is the paper's Fig 6/7 story.
  2. Batched STREAMING SNN serving (serve/snn_engine.py): requests queue
     into a fixed batch tile, retire via the same stability gate mid-window,
     and compaction admits waiting images into the freed lanes — the
     continuous-batching view of the same energy win.
  3. LM serving with the same gate: a reduced qwen3 decodes a batch and
     retires stable sequences (serve/early_exit.py).

  PYTHONPATH=src python examples/serve_early_exit.py
"""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.snn_mnist import SNN_CONFIG
from repro.core import encoding, lif as lif_mod, prng
from repro.core.pruning import stability_early_exit
from repro.core.train_snn import fit_or_load


def snn_demo(T: int = 20, patience: int = 3):
    print("== SNN early exit (paper Fig 6/7) ==")
    params, params_q, ds = fit_or_load()
    x, y = ds.x_test[:2000], ds.y_test[:2000]
    px = jnp.asarray((x * 255).astype(np.uint8))
    spikes, _ = encoding.poisson_encode_hw(px, prng.seed_state(11, px.shape), T)
    res = lif_mod.run_lif_int(spikes, params_q["layers"][0]["w_q"],
                              SNN_CONFIG.lif)
    cum = np.cumsum(np.asarray(res["spikes"]).astype(np.int32), 0)
    pred_t = jnp.asarray(cum.argmax(-1))
    t_exit = np.asarray(stability_early_exit(pred_t, patience=patience))
    acc = (np.asarray(pred_t[-1]) == y).mean()

    hist, _ = np.histogram(t_exit, bins=np.arange(1, T + 2))
    print(f"accuracy @T={T}: {acc:.3f}")
    print(f"exit timestep: mean {t_exit.mean():.1f}, "
          f"p50 {np.percentile(t_exit, 50):.0f}, "
          f"p90 {np.percentile(t_exit, 90):.0f} (of {T})")
    print(f"timesteps saved by early exit: "
          f"{100 * (1 - t_exit.mean() / T):.0f}%")
    print("exit histogram:", hist.tolist())


def stream_demo(n_requests: int = 64, batch: int = 8, patience: int = 3):
    print("\n== batched streaming SNN serving (continuous batching) ==")
    from repro.serve import SNNStreamEngine

    params, params_q, ds = fit_or_load()
    eng = SNNStreamEngine(params_q, SNN_CONFIG, batch_size=batch,
                          chunk_steps=4, patience=patience, seed=11)
    imgs = (ds.x_test[:n_requests] * 255).astype(np.uint8)
    ids = [eng.submit(im) for im in imgs]
    results = eng.run()
    preds = np.array([results[i].pred for i in ids])
    steps = np.array([results[i].steps for i in ids])
    adds = np.array([results[i].adds for i in ids])
    early = np.array([results[i].early_exit for i in ids])
    acc = (preds == ds.y_test[:n_requests]).mean()
    T = SNN_CONFIG.num_steps
    print(f"{n_requests} requests through {batch} lanes: acc {acc:.3f}")
    print(f"window steps: mean {steps.mean():.1f}/{T} "
          f"({100 * (1 - steps.mean() / T):.0f}% saved), "
          f"{early.mean() * 100:.0f}% early-exited")
    print(f"synaptic adds/request: mean {adds.mean():.0f} "
          f"(retired lanes stop accumulating)")


def lm_demo():
    print("\n== LM early-exit serving (reduced qwen3) ==")
    from repro.configs import get_reduced
    from repro.models import lm_init
    from repro.serve import generate, stability_gate

    cfg = get_reduced("qwen3-4b")
    key = jax.random.PRNGKey(0)
    params = lm_init(key, cfg)
    B = 8
    prompts = {"tokens": jax.random.randint(key, (B, 16), 0, cfg.vocab_size)}
    toks, active = generate(params, prompts, cfg, steps=16, max_len=48,
                            early_exit_fn=stability_gate(B, patience=2))
    active = np.asarray(active)
    print(f"active sequences per decode step: {active.tolist()}")
    print(f"sequence-steps used: {active.sum()}/{B * 16} "
          f"({100 * (1 - active.sum() / (B * 16)):.0f}% saved)")


if __name__ == "__main__":
    snn_demo()
    stream_demo()
    lm_demo()
