"""Train a ~100M-param LM for a few hundred steps (end-to-end driver for
the LM side of the framework): reduced llama3 config scaled up to ~100M,
synthetic structured token stream, full production train_step (grad accum,
clipping, checkpointing, straggler detection).

  PYTHONPATH=src python examples/lm_train_smoke.py --steps 300
"""

import argparse
import dataclasses
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.configs import get_config
from repro.configs.base import reduced
from repro.launch.train import train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_lm_smoke")
    args = ap.parse_args()

    # ~100M params: 8 layers, d=512, 8 heads, vocab 8192
    cfg = dataclasses.replace(
        reduced(get_config("llama3-8b"), layers=8, d_model=512, vocab=8192),
        num_heads=8, num_kv_heads=4, head_dim=64, d_ff=2048,
        name="llama3-100m",
    )
    n = cfg.param_count()
    print(f"model: {cfg.name}, {n/1e6:.1f}M params")

    def hook(rec):
        if rec["step"] % 25 == 0 or rec["step"] <= 3:
            print(f"step {rec['step']:4d}  loss {rec['loss']:.4f}  "
                  f"acc {rec['acc']:.3f}  {rec['wall_s']*1e3:.0f} ms")

    # train() resolves the arch by name; pass the custom cfg via registry
    from repro.configs.registry import register
    register(cfg)
    final, hist = train(cfg.name, steps=args.steps, batch=args.batch,
                        seq=args.seq, reduced=False,
                        ckpt_dir=args.ckpt_dir, ckpt_every=100,
                        lr=3e-4, microbatches=2, metrics_hook=hook)
    first, last = hist[0]["loss"], hist[-1]["loss"]
    print(f"\nloss {first:.3f} → {last:.3f} "
          f"({'improved' if last < first else 'NO IMPROVEMENT'})")
    assert last < first, "training must reduce loss"


if __name__ == "__main__":
    main()
