"""Quickstart: the paper's system in ~60 lines.

Trains the Poisson-encoded LIF classifier (784→10) with surrogate
gradients, quantizes to the 9-bit fixed-point codes the RTL uses, runs the
bit-exact integer engine, and prints the Fig-4-style membrane trace plus
accuracy-vs-timesteps (Fig 5).

  PYTHONPATH=src python examples/quickstart.py
"""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax.numpy as jnp
import numpy as np

from repro.configs.snn_mnist import SNN_CONFIG
from repro.core import prng, snn
from repro.core.train_snn import int_accuracy, train_bptt
from repro.data import digits


def main():
    print("1) dataset (procedural MNIST stand-in)")
    ds = digits.make_dataset(n_train=3000, n_test=500, seed=0)

    print("2) surrogate-gradient BPTT training (QAT, ~1 min on CPU)")
    params = train_bptt(SNN_CONFIG, ds, steps=600, log_every=200)

    print("3) quantize to 9-bit fixed-point codes (the RTL's weight format)")
    params_q = snn.quantize_params(params, SNN_CONFIG)
    w = np.asarray(params_q["layers"][0]["w_q"])
    print(f"   codes in [{w.min()}, {w.max()}], "
          f"{w.size * 9 / 8 / 1024:.1f} KB at 9 bits")

    print("4) bit-exact integer inference (Poisson encoder + LIF core)")
    for T in (5, 10, 20):
        acc, aux = int_accuracy(params_q, SNN_CONFIG, ds.x_test, ds.y_test,
                                num_steps=T)
        print(f"   T={T:2d}: accuracy {acc:.3f}   "
              f"adds/image {aux['adds_per_img']:.0f} (zero multiplies)")

    print("5) single-neuron membrane trace (paper Fig. 4)")
    i = int(np.where(ds.y_test == 3)[0][0])
    px = jnp.asarray((ds.x_test[i:i + 1] * 255).astype(np.uint8))
    out = snn.snn_apply_int(params_q, px, prng.seed_state(1, px.shape),
                            SNN_CONFIG)
    vt = np.asarray(out["v_trace"])[:, 0, :]
    v = vt[:, vt.var(axis=0).argmax()]   # most dynamic neuron for display
    blocks = " ▁▂▃▄▅▆▇█"
    lo, hi = v.min(), max(v.max(), 1)
    print("   V(t):", "".join(
        blocks[int((x - lo) / (hi - lo + 1e-9) * 8)] for x in v),
        f" (threshold {SNN_CONFIG.lif.v_threshold}, hard reset on fire)")


if __name__ == "__main__":
    main()
