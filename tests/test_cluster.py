"""Process-level failover: heartbeat coordinator, wire checkpoints, ledger.

Contracts under test (serve.cluster / serve.wire / serve.ledger):
  * **process failover == no-fault run** — with a seeded plan killing a
    worker process mid-window and the coordinator once, the cluster run
    equals the no-fault single-engine run prediction-for-prediction
    (reference AND fused backends), every surviving id bit-identical;
  * **wire codec** — a ``LaneState`` checkpoint roundtrips through
    ``lane_to_wire``/``lane_from_wire`` (via real JSON) bit-identically,
    and rows stamped with a future codec version are rejected with an
    actionable message instead of being misinterpreted;
  * **crash-proof accounting** — the write-ahead JSONL ledger restores
    ``results ∪ shed ∪ faulted`` as an exact partition after the
    coordinator dies (including mid-evacuation), tolerates a torn final
    line, and raises on any other corruption;
  * **restart-and-readopt** — a killed worker is respawned, re-probed
    and re-enters routing; with the respawn budget exhausted the
    survivors absorb its lanes instead;
  * **never-silent loss** — ``state_lost`` kills surface as
    ``FaultRecord("state_lost")``, and replaying the same plan
    reproduces every record exactly;
  * **config threading** — the recovery knobs on ``SNNServingTierConfig``
    resolve into one validated ``FaultToleranceConfig`` shared by the
    in-process tier and the cluster path.
"""

import dataclasses
import json

import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.snn_mnist import (SNN_CONFIG, SNNClusterConfig,
                                     SNNServingTierConfig, make_cluster,
                                     make_serving_tier)
from repro.core.telemetry import (EngineLoad, engine_load_from_wire,
                                  engine_load_to_wire)
from repro.serve import (ClusterCoordinator, CoordinatorCrash, FaultEvent,
                         FaultPlan, FaultToleranceConfig, Ledger,
                         LedgerCorruptError, SNNStreamEngine,
                         WIRE_CODEC_VERSION, WireError, lane_from_wire,
                         lane_to_wire, read_ledger, recover_accounting)


def small_net(rng, sizes):
    return {"layers": [
        {"w_q": jnp.asarray(rng.integers(-256, 256, (a, b)), jnp.int16),
         "scale": jnp.float32(1.0)}
        for a, b in zip(sizes[:-1], sizes[1:])]}


def as_tuple(r):
    return (r.pred, r.steps, r.adds, r.early_exit, r.spike_counts.tolist())


_RNG = np.random.default_rng(17)
CFG = dataclasses.replace(SNN_CONFIG, layer_sizes=(12, 6), num_steps=8)
PARAMS = small_net(_RNG, CFG.layer_sizes)
IMGS = _RNG.integers(0, 256, (10, 12), dtype=np.uint8)
KW = dict(num_workers=2, lanes_per_worker=2, chunk_steps=2,
          patience=10_000, seed=0)

_BASELINE: dict = {}


@pytest.fixture(autouse=True)
def _no_env_plan(monkeypatch):
    # a suite-wide REPRO_FAULT_PLAN (the chaos CI lane) must not arm the
    # in-process baseline engines these tests compare against
    monkeypatch.delenv("REPRO_FAULT_PLAN", raising=False)


def baseline(backend):
    """No-fault single-engine signatures (the bit-identity reference)."""
    if backend not in _BASELINE:
        eng = SNNStreamEngine(PARAMS, CFG, batch_size=2, chunk_steps=2,
                              patience=10_000, seed=0, backend=backend)
        for i, im in enumerate(IMGS):
            eng.submit(im, request_id=i)
        _BASELINE[backend] = {r: as_tuple(v) for r, v in eng.run().items()}
    return _BASELINE[backend]


def make_co(ledger_dir, backend="reference", plan=None, fault_cfg=None):
    return ClusterCoordinator(PARAMS, CFG, backend=backend, fault_plan=plan,
                              fault_cfg=fault_cfg, ledger_dir=str(ledger_dir),
                              **KW)


def _partition_ok(co, submitted):
    res, shed, faulted = set(co.results), set(co.shed), set(co.faulted)
    assert res | shed | faulted == set(submitted)
    assert not (res & shed) and not (res & faulted) and not (shed & faulted)


def _assert_matches_baseline(co, backend):
    base = baseline(backend)
    assert set(co.results) == set(base) - set(co.faulted) - set(co.shed)
    for rid, r in co.results.items():
        assert as_tuple(r) == base[rid], rid


# ---- wire codec -----------------------------------------------------------

def _lane_rows():
    eng = SNNStreamEngine(PARAMS, CFG, batch_size=2, chunk_steps=2,
                          patience=10_000, seed=0, backend="reference")
    for i in range(4):
        eng.submit(IMGS[i], request_id=i)
    eng.step()
    eng.step()
    return eng, eng.checkpoint_lanes()


def test_lane_wire_roundtrip_bit_identical():
    """checkpoint → wire → JSON text → wire → LaneState: every leaf keeps
    its dtype, shape and bytes exactly."""
    _, rows = _lane_rows()
    assert rows, "mid-window checkpoint should have active lanes"
    for rid, row in rows:
        back = lane_from_wire(json.loads(json.dumps(lane_to_wire(row))))
        for f in row._fields:
            a, b = getattr(row, f), getattr(back, f)
            if isinstance(a, tuple):
                for x, y in zip(a, b):
                    assert np.asarray(x).dtype == np.asarray(y).dtype
                    assert np.array_equal(x, y), (rid, f)
            else:
                assert np.asarray(a).dtype == np.asarray(b).dtype
                assert np.array_equal(a, b), (rid, f)


def test_checkpoint_lanes_is_non_destructive():
    """Shipping checkpoints every round must not perturb the engine."""
    eng, _ = _lane_rows()
    res = eng.run()
    base = baseline("reference")
    for rid in res:
        assert as_tuple(res[rid]) == base[rid]


def test_lane_wire_rejects_future_codec_version():
    _, rows = _lane_rows()
    w = lane_to_wire(rows[0][1])
    w["codec"] = WIRE_CODEC_VERSION + 1
    with pytest.raises(WireError, match="upgrade this coordinator/worker"):
        lane_from_wire(w)


def test_lane_wire_rejects_malformed_rows():
    with pytest.raises(WireError, match="codec"):
        lane_from_wire({"leaves": {}})           # no version stamp
    with pytest.raises(WireError, match="invalid codec version"):
        lane_from_wire({"codec": 0, "leaves": {}})
    with pytest.raises(WireError, match="missing"):
        lane_from_wire({"codec": WIRE_CODEC_VERSION, "leaves": {}})


def test_write_msg_times_out_on_full_pipe():
    """The heartbeat deadline covers the write side too: a hung worker
    that stops draining its pipe fills the kernel buffer, and a large
    frame must raise TimeoutError instead of blocking the coordinator
    inside os.write forever (which would defeat the watchdog)."""
    import os
    import time

    from repro.serve.wire import write_msg
    r, w = os.pipe()
    try:
        os.set_blocking(w, False)
        try:
            while True:
                os.write(w, b"\0" * 65536)
        except BlockingIOError:
            pass   # pipe buffer is now full
        os.set_blocking(w, True)
        t0 = time.monotonic()
        with pytest.raises(TimeoutError, match="frame write"):
            write_msg(w, {"px": "y" * 4096}, timeout_s=0.1)
        assert time.monotonic() - t0 < 5.0
    finally:
        os.close(r)
        os.close(w)


def test_write_msg_with_deadline_roundtrips():
    """A live peer: the deadline path must still deliver the frame
    byte-exactly (chunked writes included)."""
    import os

    from repro.serve.wire import read_msg, write_msg
    r, w = os.pipe()
    try:
        obj = {"op": "submit", "px": list(range(100))}
        write_msg(w, obj, timeout_s=5.0)
        assert read_msg(r, 5.0) == obj
    finally:
        os.close(r)
        os.close(w)


def test_engine_load_wire_roundtrip():
    load = EngineLoad(lanes_total=8, lanes_busy=3, queue_depth=2,
                      mean_service_steps=5.5, retired_total=7,
                      density_ewma=0.125, consecutive_faults=1,
                      demotion_level=2, watchdog_margin=None, alive=False)
    back = engine_load_from_wire(json.loads(json.dumps(
        engine_load_to_wire(load))))
    assert back == load


# ---- ledger ---------------------------------------------------------------

def test_ledger_drops_torn_final_line(tmp_path):
    p = str(tmp_path / "l.jsonl")
    led = Ledger(p)
    led.append({"kind": "submit", "rid": 0})
    led.append({"kind": "result", "rid": 0})
    led.close()
    with open(p, "a", encoding="utf-8") as f:
        f.write('{"kind": "fault", "rid": 1, "rea')   # crash mid-append
    recs = read_ledger(p)
    assert [r["kind"] for r in recs] == ["submit", "result"]


def test_ledger_reopen_repairs_torn_tail(tmp_path):
    """A recovered process reopening a ledger whose last append was torn
    must truncate the partial line first — appending straight onto it
    would weld two records into one corrupt mid-file line, silently
    dropping the new record (if last) or poisoning the whole ledger with
    LedgerCorruptError (if not)."""
    p = str(tmp_path / "l.jsonl")
    led = Ledger(p)
    led.append({"kind": "submit", "rid": 0})
    led.close()
    with open(p, "a", encoding="utf-8") as f:
        f.write('{"kind": "result", "rid": 0, "pre')   # crash mid-append
    led2 = Ledger(p)   # the respawned incarnation reopens the same file
    led2.append({"kind": "result", "rid": 0})
    led2.append({"kind": "fault", "rid": 1, "reason": "state_lost"})
    led2.close()
    recs = read_ledger(p)
    assert [(r["kind"], r["rid"]) for r in recs] == [
        ("submit", 0), ("result", 0), ("fault", 1)]
    acc = recover_accounting([p])
    assert set(acc["results"]) == {0} and set(acc["faulted"]) == {1}


def test_ledger_reopen_keeps_clean_file_intact(tmp_path):
    p = str(tmp_path / "l.jsonl")
    led = Ledger(p)
    led.append({"kind": "submit", "rid": 0})
    led.close()
    led2 = Ledger(p)
    led2.append({"kind": "result", "rid": 0})
    led2.close()
    assert [r["kind"] for r in read_ledger(p)] == ["submit", "result"]


def test_ledger_raises_on_mid_file_corruption(tmp_path):
    p = str(tmp_path / "l.jsonl")
    with open(p, "w", encoding="utf-8") as f:
        f.write('{"kind": "submit", "rid": 0}\n')
        f.write('garbage{\n')
        f.write('{"kind": "result", "rid": 0}\n')
    with pytest.raises(LedgerCorruptError, match=r"l\.jsonl:2"):
        read_ledger(p)


def test_recover_accounting_result_beats_fault(tmp_path):
    """A worker-replicated result must win over the coordinator's fault
    record for the same id — the computed answer is the truth."""
    cp, wp = str(tmp_path / "c.jsonl"), str(tmp_path / "w.jsonl")
    c = Ledger(cp)
    for rid in (0, 1, 2):
        c.append({"kind": "submit", "rid": rid, "px": "x"})
    c.append({"kind": "fault", "rid": 1, "reason": "state_lost"})
    c.append({"kind": "shed", "rid": 2, "reason": "deadline"})
    c.close()
    w = Ledger(wp)
    w.append({"kind": "result", "rid": 1, "pred": 3})
    w.close()
    acc = recover_accounting([cp, wp])
    assert set(acc["results"]) == {1}
    assert set(acc["shed"]) == {2}
    assert acc["faulted"] == {}
    assert acc["outstanding"] == [0]
    assert [rid for rid, _ in acc["submitted"]] == [0, 1, 2]


# ---- cluster: no-fault ----------------------------------------------------

def test_cluster_matches_single_engine(tmp_path):
    with make_co(tmp_path) as co:
        for i, im in enumerate(IMGS):
            co.submit(im, request_id=i)
        res = co.run()
        assert {r: as_tuple(v) for r, v in res.items()} == baseline(
            "reference")
        _partition_ok(co, range(len(IMGS)))
        assert not co.faulted and not co.shed
    # write-ahead + replication: the coordinator logged every submit
    # before routing it, and each worker replicated its own results
    recs = read_ledger(str(tmp_path / "coordinator.jsonl"))
    assert {r["rid"] for r in recs if r["kind"] == "submit"} == set(
        range(len(IMGS)))
    assert all("deadline_steps" in r for r in recs if r["kind"] == "submit")
    wrecs = [r for i in range(KW["num_workers"])
             for r in read_ledger(str(tmp_path / f"worker-{i}.jsonl"))]
    assert {r["rid"] for r in wrecs if r["kind"] == "result"} == set(
        range(len(IMGS)))


# ---- cluster: the process-failover contract -------------------------------

CONTRACT_PLAN = "seed=0,worker_kill=1@2,coordinator_kill=4"


@pytest.mark.parametrize("backend", ["reference", "fused"])
def test_process_failover_contract(tmp_path, backend):
    """Worker 1 killed mid-window at round 2, coordinator killed at round
    4; ledger recovery re-runs the outstanding ids — final accounting is
    a lossless, bit-identical match of the no-fault run."""
    co = make_co(tmp_path, backend, plan=CONTRACT_PLAN)
    try:
        for i, im in enumerate(IMGS):
            co.submit(im, request_id=i)
        with pytest.raises(CoordinatorCrash):
            co.run()
        assert co.stats["workers_failed"] >= 1
        assert co.stats["evacuated"] >= 1
        # the submit lines were write-ahead: all durable before the crash
        recs = read_ledger(str(tmp_path / "coordinator.jsonl"))
        assert {r["rid"] for r in recs if r["kind"] == "submit"} == set(
            range(len(IMGS)))
        with ClusterCoordinator.recover(
                PARAMS, CFG, ledger_dir=str(tmp_path), backend=backend,
                fault_plan=CONTRACT_PLAN, **KW) as co2:
            co2.run()
            _partition_ok(co2, range(len(IMGS)))
            assert not co2.faulted and not co2.shed   # lossless schedule
            _assert_matches_baseline(co2, backend)
    finally:
        co.close()


def test_worker_hang_detected_by_heartbeat(tmp_path):
    """A worker that stops responding mid-round trips the heartbeat
    deadline, is killed and respawned, and its lanes resume losslessly
    from the shipped checkpoints."""
    cfg = FaultToleranceConfig(heartbeat_interval_s=0.02,
                               heartbeat_deadline_s=1.5)
    with make_co(tmp_path, plan="seed=0,worker_hang=0@2",
                 fault_cfg=cfg) as co:
        for i, im in enumerate(IMGS):
            co.submit(im, request_id=i)
        co.run()
        assert co.stats["workers_failed"] == 1
        assert co.stats["respawned"] == 1
        _partition_ok(co, range(len(IMGS)))
        assert not co.faulted
        _assert_matches_baseline(co, "reference")


def test_respawn_budget_exhausted_survivors_absorb(tmp_path):
    cfg = FaultToleranceConfig(max_respawns=0)
    with make_co(tmp_path, plan="seed=0,worker_kill=1@2",
                 fault_cfg=cfg) as co:
        for i, im in enumerate(IMGS):
            co.submit(im, request_id=i)
        co.run()
        assert co.stats["respawned"] == 0
        assert [i for i, h in enumerate(co.workers) if h.alive] == [0]
        _partition_ok(co, range(len(IMGS)))
        assert not co.faulted
        _assert_matches_baseline(co, "reference")


def test_coordinator_crash_mid_evacuation_exactly_once(tmp_path):
    """The coordinator dies after landing ONE evacuated lane — recovery
    must account every id exactly once (results or faulted, never both,
    never neither)."""
    co = make_co(tmp_path, plan="seed=0,worker_kill=1@2")
    co._crash_after_evacuations = 1
    try:
        for i, im in enumerate(IMGS):
            co.submit(im, request_id=i)
        with pytest.raises(CoordinatorCrash):
            co.run()
        with ClusterCoordinator.recover(
                PARAMS, CFG, ledger_dir=str(tmp_path),
                backend="reference", fault_plan="seed=0,worker_kill=1@2",
                **KW) as co2:
            co2.run()
            _partition_ok(co2, range(len(IMGS)))
            _assert_matches_baseline(co2, "reference")
    finally:
        co.close()


def test_rollout_survives_coordinator_crash(tmp_path):
    """Weight rollouts are ledgered and replayed on recovery: with four
    requests outstanding at the crash and a rollout that preceded it,
    the recovered coordinator must re-run them against the pre-crash
    fleet version, not version 0 of the caller-supplied params."""
    params2 = small_net(np.random.default_rng(99), CFG.layer_sizes)
    co = make_co(tmp_path)
    try:
        for i, im in enumerate(IMGS[:4]):
            co.submit(im, request_id=i)
        assert co.begin_rollout(params2) == 1
        with pytest.raises(CoordinatorCrash):
            co._crash(co.round)
    finally:
        co.close()
    recs = read_ledger(str(tmp_path / "coordinator.jsonl"))
    assert [r["version"] for r in recs if r["kind"] == "rollout"] == [1]
    with ClusterCoordinator.recover(
            PARAMS, CFG, ledger_dir=str(tmp_path), backend="reference",
            **KW) as co2:
        assert co2._current_version == 1
        res = co2.run()
        assert set(res) == set(range(4))
        assert all(r.weight_version == 1 for r in res.values())
    # the replay itself must not re-append the rollout record — a second
    # recovery would otherwise replay it twice and land at version 2
    recs = read_ledger(str(tmp_path / "coordinator.jsonl"))
    assert [r["version"] for r in recs if r["kind"] == "rollout"] == [1]


def _dead_slot(self, idx, incarnation=0):
    from repro.serve.cluster import WorkerHandle
    return WorkerHandle(proc=None, rfd=-1, wfd=-1, alive=False)


def test_begin_rollout_requires_live_workers(tmp_path, monkeypatch):
    """With zero live workers the rollout must fail loudly (a typed
    RuntimeError) — not KeyError off an empty version set, and not an
    assert that python -O strips."""
    monkeypatch.setattr(ClusterCoordinator, "_spawn", _dead_slot)
    co = ClusterCoordinator(PARAMS, CFG, ledger_dir=str(tmp_path), **KW)
    with pytest.raises(RuntimeError, match="no live worker"):
        co.begin_rollout(PARAMS)
    co.close()


def test_recover_redispatch_preserves_deadline(tmp_path, monkeypatch):
    """deadline_steps rides the write-ahead submit record: recovery must
    re-dispatch an outstanding SLO-bounded request with its original
    deadline, not silently upgrade it to unbounded."""
    from repro.serve.wire import array_to_wire
    led = Ledger(str(tmp_path / "coordinator.jsonl"))
    led.append({"kind": "submit", "rid": 0, "px": array_to_wire(IMGS[0]),
                "deadline_steps": 7})
    led.append({"kind": "submit", "rid": 1, "px": array_to_wire(IMGS[1]),
                "deadline_steps": None})
    led.close()
    captured = {}

    def fake_dispatch(self, rid, px, *, deadline_steps=None, **kw):
        captured[rid] = deadline_steps

    monkeypatch.setattr(ClusterCoordinator, "_spawn", _dead_slot)
    monkeypatch.setattr(ClusterCoordinator, "_dispatch", fake_dispatch)
    co = ClusterCoordinator.recover(PARAMS, CFG, ledger_dir=str(tmp_path),
                                    **KW)
    co.close()
    assert captured == {0: 7, 1: None}


STATE_LOST_PLAN = FaultPlan(events=(
    FaultEvent(kind="worker_kill", engine=1, first_chunk=2, last_chunk=2,
               state_lost=True),))


def test_state_lost_kill_records_fault_records(tmp_path):
    """A kill that also destroys the replica checkpoint surfaces every
    lost window as FaultRecord("state_lost") — never a silent drop."""
    with make_co(tmp_path, plan=STATE_LOST_PLAN) as co:
        for i, im in enumerate(IMGS):
            co.submit(im, request_id=i)
        co.run()
        _partition_ok(co, range(len(IMGS)))
        assert co.faulted, "worker 1 had in-flight lanes at round 2"
        assert all(f.reason == "state_lost" and f.replay_seed == rid
                   for rid, f in co.faulted.items())
        _assert_matches_baseline(co, "reference")


def test_replay_reproduces_every_record_exactly(tmp_path):
    """Same plan, same submissions, fresh cluster: identical results,
    identical FaultRecords, identical routing stats."""
    runs = []
    for sub in ("a", "b"):
        d = tmp_path / sub
        d.mkdir()
        with make_co(d, plan=STATE_LOST_PLAN) as co:
            for i, im in enumerate(IMGS):
                co.submit(im, request_id=i)
            co.run()
            runs.append(({r: as_tuple(v) for r, v in co.results.items()},
                         dict(co.faulted), dict(co.shed), co.stats))
    assert runs[0] == runs[1]


# ---- config threading -----------------------------------------------------

def test_tier_config_recovery_knob_validation():
    with pytest.raises(ValueError, match="heartbeat_deadline_s"):
        SNNServingTierConfig(heartbeat_interval_s=0.5,
                             heartbeat_deadline_s=0.1)
    with pytest.raises(ValueError, match="watchdog_chunks"):
        SNNServingTierConfig(watchdog_chunks=0)
    with pytest.raises(ValueError, match="one source of truth"):
        SNNServingTierConfig(fault_cfg=FaultToleranceConfig(),
                             demote_after=2)
    knobs = SNNServingTierConfig(watchdog_chunks=5, demote_after=2,
                                 heartbeat_interval_s=0.01,
                                 heartbeat_deadline_s=3.0)
    eff = knobs.resolve_fault_cfg()
    assert eff.watchdog_chunks == 5 and eff.demote_after == 2
    assert eff.heartbeat_deadline_s == 3.0
    # unset knobs keep the FaultToleranceConfig defaults
    assert eff.max_retries == FaultToleranceConfig().max_retries


def test_tier_config_threads_fault_cfg_to_engines():
    knobs = SNNServingTierConfig(num_engines=1, lanes_per_engine=2,
                                 chunk_steps=2, shedding=False,
                                 watchdog_chunks=7)
    tier = make_serving_tier(PARAMS, CFG, knobs, patience=10_000, seed=0,
                             backend="reference")
    assert tier.fault_cfg.watchdog_chunks == 7
    assert all(e.fault_cfg.watchdog_chunks == 7 for e in tier.engines)


def test_cluster_config_validation_and_factory(tmp_path):
    with pytest.raises(ValueError, match="num_workers"):
        SNNClusterConfig(num_workers=0)
    with pytest.raises(ValueError, match="ledger_dir"):
        make_cluster(PARAMS, CFG, SNNClusterConfig(num_workers=1))
    knobs = SNNClusterConfig(num_workers=1, lanes_per_worker=2,
                             chunk_steps=2, backend="reference",
                             ledger_dir=str(tmp_path))
    tier_knobs = SNNServingTierConfig(heartbeat_interval_s=0.01,
                                      heartbeat_deadline_s=5.0)
    with make_cluster(PARAMS, CFG, knobs, tier_knobs,
                      patience=10_000, seed=0) as co:
        assert co.fault_cfg.heartbeat_deadline_s == 5.0
        co.submit(IMGS[0], request_id=0)
        res = co.run()
        assert as_tuple(res[0]) == baseline("reference")[0]
