"""Event-driven sparse megakernel: int8 packing, tile skipping, streaming.

Contracts under test:
  * the int8 weight packing (hi/lo planes) is exact over the full signed
    9-bit code range and matches the independent ``ref.weight_pack_ref``
    oracle;
  * sparse-skipping fused == dense fused == reference — predictions,
    spike counts, first-spike latches, membrane traces AND the
    executed-add energy counter — across spike densities (0%,
    paper-typical, ~100%), random pruning masks and random window chunk
    splits (property test);
  * the same bit-identity holds through the single-device and sharded
    streaming engines, including early-exit retirement;
  * ``fused_streamed`` (weights double-buffered out of HBM) matches the
    reference on oversized stacks in ONE Pallas launch, while an explicit
    ``fused`` request raises; ``resolve_backend`` walks the
    fused → fused_streamed → staged chain on TPU;
  * ``ops.spike_matmul_op``'s runtime density dispatch (``mode="auto"``,
    a ``lax.cond`` over the masked/MXU kernels) is bit-identical to both
    forced kernels across densities, including all-zero spike tiles.

The suite is REPRO_SPARSE_SKIP-sensitive by design: CI runs it twice with
the env default forced on and off (plus the explicit parametrisations
below), so a regression in either tile path cannot hide behind the other.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs.snn_mnist import (SNN_CONFIG, SNN_CONFIG_DEEP,
                                     SNN_CONFIG_WIDE)
from repro.core import prng, snn
from repro.kernels import fused_snn, ops, ref
from repro.serve import ShardedSNNStreamEngine, SNNStreamEngine

_KEYS = ["spike_counts", "v_trace", "first_spike_t", "v_final",
         "active_adds", "prng_state", "steps"]

# pixel levels spanning the density axis: px > r (uniform u8) spikes with
# probability px/256 — 0%, the paper-typical MNIST foreground rate, ~100%
DENSITY_PX = {"zero": 0, "paper": 33, "full": 255}


def _net(rng, sizes):
    return {"layers": [
        {"w_q": jnp.asarray(rng.integers(-256, 256, (a, b)), jnp.int16),
         "scale": jnp.float32(1.0)}
        for a, b in zip(sizes[:-1], sizes[1:])]}


def test_weight_packing_roundtrip():
    """Every signed 9-bit code packs/unpacks exactly, and the kernel's
    packer agrees with the independent oracle plane-for-plane."""
    codes = np.arange(-256, 256, dtype=np.int16).reshape(32, 16)
    hi_ref, lo_ref = ref.weight_pack_ref(codes)
    packed = np.asarray(fused_snn.pack_weights(jnp.asarray(codes)))
    np.testing.assert_array_equal(packed[0], hi_ref)
    np.testing.assert_array_equal(packed[1], lo_ref)
    rebuilt = 2 * packed[0].astype(np.int32) + packed[1]
    np.testing.assert_array_equal(rebuilt, codes.astype(np.int32))
    assert set(np.unique(packed[1])) <= {0, 1}
    with pytest.raises(ValueError, match="9-bit"):
        ref.weight_pack_ref(np.asarray([256], np.int16))


def test_fused_rejects_unpackable_codes(rng):
    """Codes outside the signed 9-bit range would wrap the int8 hi plane
    silently — the fused backends must refuse them where the weights are
    concrete (the pre-packing kernel was exact on full int16)."""
    params_q = _net(rng, (32, 10))
    params_q["layers"][0]["w_q"] = jnp.full((32, 10), 300, jnp.int16)
    px = jnp.zeros((2, 32), jnp.uint8)
    state = prng.seed_state(1, px.shape)
    cfg = dataclasses.replace(SNN_CONFIG, layer_sizes=(32, 10), num_steps=4)
    with pytest.raises(ValueError, match="9-bit"):
        snn.snn_apply_int(params_q, px, state, cfg, backend="fused")
    with pytest.raises(ValueError, match="9-bit"):
        SNNStreamEngine(params_q, cfg, batch_size=2, backend="fused")
    # the un-packing backends still accept wider codes
    snn.snn_apply_int(params_q, px, state, cfg, backend="reference")
    SNNStreamEngine(params_q, cfg, batch_size=2, backend="reference")


@pytest.mark.parametrize("density", sorted(DENSITY_PX))
@pytest.mark.parametrize("sparse_skip", [False, True])
@pytest.mark.parametrize("prune", [False, True])
def test_sparse_dense_ref_bit_identity(rng, density, sparse_skip, prune):
    """Kernel vs oracle at the density extremes and the paper-typical
    rate, dense and sparse tile paths, with and without active pruning."""
    sizes = (300, 140, 10)
    b = 5
    px = jnp.full((b, sizes[0]), DENSITY_PX[density], jnp.uint8)
    state = prng.seed_state(11, (b, sizes[0]))
    weights = tuple(l["w_q"] for l in _net(rng, sizes)["layers"])
    kw = dict(num_steps=7, decay_shift=4, v_threshold=128,
              active_pruning=prune)
    got = ops.fused_snn_stack_op(px, state, weights,
                                 sparse_skip=sparse_skip, interpret=True,
                                 **kw)
    want = ref.fused_snn_stack_ref(px, state, weights, **kw)
    for key in _KEYS:
        np.testing.assert_array_equal(np.asarray(got[key]),
                                      np.asarray(want[key]), err_msg=key)


@pytest.mark.parametrize("streamed", [False, True])
def test_random_pruning_masks_skip_paths(rng, streamed):
    """Carried enable masks with randomly pruned neurons (whole output
    tiles included) drive the prune-skip predicate without changing
    results vs the oracle."""
    sizes = (256, 256, 10)
    b = 4
    weights = tuple(l["w_q"] for l in _net(rng, sizes)["layers"])
    px = jnp.asarray(rng.integers(0, 256, (b, sizes[0]), dtype=np.uint8))
    state = prng.seed_state(5, (b, sizes[0]))
    # layer-0 mask prunes one whole 128-lane tile (fully-skippable output
    # tile) plus random scatter; layer-1 mask is random scatter only
    en0 = np.ones((b, sizes[1]), bool)
    en0[:, :128] = False
    en0 &= rng.random((b, sizes[1])) < 0.7
    en1 = rng.random((b, sizes[2])) < 0.5
    init = {
        "v": (jnp.zeros((b, sizes[1]), jnp.int32),
              jnp.zeros((b, sizes[2]), jnp.int32)),
        "en": (jnp.asarray(en0), jnp.asarray(en1)),
        "counts": jnp.zeros((b, sizes[2]), jnp.int32),
        "first": jnp.full((b, sizes[2]), 6, jnp.int32),
        "steps": jnp.zeros((b,), jnp.int32),
    }
    kw = dict(num_steps=6, decay_shift=4, v_threshold=128,
              active_pruning=True)
    want = ref.fused_snn_stack_ref(px, state, weights, init=init, **kw)
    for sparse_skip in (False, True):
        got = ops.fused_snn_stack_op(px, state, weights, init=init,
                                     sparse_skip=sparse_skip,
                                     streamed=streamed, interpret=True,
                                     **kw)
        for key in _KEYS:
            np.testing.assert_array_equal(
                np.asarray(got[key]), np.asarray(want[key]),
                err_msg=f"{key} sparse_skip={sparse_skip}")


@settings(max_examples=6, deadline=None)
@given(seed=st.integers(1, 2**31),
       density=st.sampled_from(sorted(DENSITY_PX)),
       n_chunks=st.integers(1, 4),
       backend=st.sampled_from(["fused", "fused_streamed"]))
def test_sparse_chunked_property(seed, density, n_chunks, backend):
    """Property: sparse-skipping fused == dense fused == reference over
    random window chunk splits, at every density level, on the resident
    AND weight-streamed kernels — state, traces and add counters."""
    rng = np.random.default_rng(seed % (2**31))
    cfg = dataclasses.replace(SNN_CONFIG_DEEP, num_steps=8)
    params_q = _net(rng, cfg.layer_sizes)
    px = jnp.asarray(
        np.minimum(rng.integers(0, 256, (4, cfg.n_in)),
                   DENSITY_PX[density]).astype(np.uint8))
    state0 = prng.seed_state(seed, px.shape)
    T = cfg.num_steps
    cuts = sorted(rng.choice(np.arange(1, T), size=min(n_chunks - 1, T - 1),
                             replace=False).tolist()) if n_chunks > 1 else []
    bounds = [0] + cuts + [T]

    def run(cfg_v, be):
        st_ = snn.snn_window_init(params_q, state0, cfg_v)
        traces, adds = [], []
        for lo, hi in zip(bounds[:-1], bounds[1:]):
            st_, out = snn.snn_window_chunk(params_q, px, st_, cfg_v,
                                            chunk_steps=hi - lo, backend=be)
            traces.append(np.asarray(out["v_trace"]))
            adds.append(np.asarray(out["active_adds"]))
        return st_, np.concatenate(traces), np.concatenate(adds)

    ref_state, ref_tr, ref_adds = run(
        dataclasses.replace(cfg, sparse_skip=False), "reference")
    for sparse_skip in (False, True):
        got_state, got_tr, got_adds = run(
            dataclasses.replace(cfg, sparse_skip=sparse_skip), backend)
        np.testing.assert_array_equal(got_tr, ref_tr)
        np.testing.assert_array_equal(got_adds, ref_adds)
        for field in snn.SNNWindowState._fields:
            a, b = getattr(got_state, field), getattr(ref_state, field)
            for x, y in zip(a if isinstance(a, tuple) else [a],
                            b if isinstance(b, tuple) else [b]):
                np.testing.assert_array_equal(
                    np.asarray(x), np.asarray(y),
                    err_msg=f"{field} skip={sparse_skip} split={bounds}")


def _engine_results(eng, imgs):
    ids = [eng.submit(im) for im in imgs]
    res = eng.run()
    return {i: (res[i].pred, res[i].steps, res[i].adds, res[i].early_exit,
                tuple(res[i].spike_counts.tolist())) for i in ids}


@settings(max_examples=4, deadline=None)
@given(seed=st.integers(1, 2**31),
       density=st.sampled_from(sorted(DENSITY_PX)))
def test_engines_sparse_bit_identity(seed, density):
    """Single-device AND sharded streaming engines: the sparse and dense
    fused chunk paths reproduce the reference engine request-for-request
    (early-exit steps and frozen add counters included)."""
    rng = np.random.default_rng(seed % (2**31))
    cfg = dataclasses.replace(SNN_CONFIG, num_steps=8)
    params_q = _net(rng, cfg.layer_sizes)
    imgs = np.minimum(rng.integers(0, 256, (5, cfg.n_in)),
                      DENSITY_PX[density]).astype(np.uint8)

    want = _engine_results(
        SNNStreamEngine(params_q, dataclasses.replace(cfg, sparse_skip=False),
                        batch_size=2, chunk_steps=3, patience=2, seed=seed,
                        backend="reference"),
        imgs)
    for sparse_skip in (False, True):
        cfg_v = dataclasses.replace(cfg, sparse_skip=sparse_skip)
        got = _engine_results(
            SNNStreamEngine(params_q, cfg_v, batch_size=2, chunk_steps=3,
                            patience=2, seed=seed, backend="fused"), imgs)
        assert got == want, f"single-device sparse_skip={sparse_skip}"
        n_dev = len(jax.devices())
        sharded = _engine_results(
            ShardedSNNStreamEngine(params_q, cfg_v,
                                   lanes_per_device=2, chunk_steps=3,
                                   patience=2, seed=seed, backend="fused"),
            imgs)
        assert sharded == want, \
            f"sharded({n_dev} dev) sparse_skip={sparse_skip}"


def test_streamed_gated_engine_matches_reference(rng):
    """fused_streamed through the streaming engine (gate in-kernel,
    double-buffered weights) == reference engine, incl. early exit."""
    cfg = dataclasses.replace(SNN_CONFIG_DEEP, num_steps=8)
    params_q = _net(rng, cfg.layer_sizes)
    imgs = rng.integers(0, 256, (5, cfg.n_in), dtype=np.uint8)
    want = _engine_results(
        SNNStreamEngine(params_q, cfg, batch_size=2, chunk_steps=3,
                        patience=2, seed=3, backend="reference"), imgs)
    got = _engine_results(
        SNNStreamEngine(params_q, cfg, batch_size=2, chunk_steps=3,
                        patience=2, seed=3, backend="fused_streamed"), imgs)
    assert got == want
    assert any(r[3] for r in want.values()), \
        "test should exercise early exit"


def test_streamed_oversized_single_launch(rng, monkeypatch):
    """With the VMEM budget shrunk so SNN_CONFIG_DEEP no longer fits
    resident, explicit fused raises, fused_streamed still runs the whole
    stack in ONE Pallas launch, bit-identical to the reference."""
    cfg = dataclasses.replace(SNN_CONFIG_DEEP, num_steps=4)
    params_q = _net(rng, cfg.layer_sizes)
    assert snn.fused_unsupported_reason(cfg, 3, cfg.layer_sizes) is None
    monkeypatch.setattr(fused_snn, "VMEM_BUDGET_BYTES", 400_000)
    assert snn.fused_unsupported_reason(cfg, 3, cfg.layer_sizes) is not None
    assert snn.fused_unsupported_reason(cfg, 3, cfg.layer_sizes,
                                        streamed=True) is None
    px = jnp.asarray(rng.integers(0, 256, (3, cfg.n_in), dtype=np.uint8))
    state = prng.seed_state(17, px.shape)
    with pytest.raises(ValueError, match="fused_streamed"):
        snn.snn_apply_int(params_q, px, state, cfg, backend="fused")
    out_s = snn.snn_apply_int(params_q, px, state, cfg,
                              backend="fused_streamed")
    out_r = snn.snn_apply_int(params_q, px, state, cfg, backend="reference")
    for key in ("pred", "spike_counts", "v_trace", "first_spike_t",
                "active_adds", "prng_state"):
        np.testing.assert_array_equal(np.asarray(out_s[key]),
                                      np.asarray(out_r[key]), err_msg=key)
    jaxpr = str(jax.make_jaxpr(
        lambda p, a, b: snn.snn_apply_int(p, a, b, cfg,
                                          backend="fused_streamed")
        ["spike_counts"])(params_q, px, state))
    assert jaxpr.count("pallas_call") == 1


def test_resolve_backend_streamed_chain(monkeypatch):
    """On TPU, ``auto`` walks fused → fused_streamed → staged by VMEM
    feasibility; explicit requests raise exactly when their realisation
    cannot run."""
    monkeypatch.setattr(jax, "default_backend", lambda: "tpu")
    # fits resident
    assert snn.resolve_backend(SNN_CONFIG, "auto", 1) == "fused"
    # over the residency budget, streaming working set fits
    wide = SNN_CONFIG_WIDE.layer_sizes
    assert snn.resolve_backend(SNN_CONFIG_WIDE, "auto", 3,
                               layer_sizes=wide) == "fused_streamed"
    assert snn.resolve_backend(SNN_CONFIG_WIDE, "fused_streamed", 3,
                               layer_sizes=wide) == "fused_streamed"
    with pytest.raises(ValueError, match="fused_streamed"):
        snn.resolve_backend(SNN_CONFIG_WIDE, "fused", 3, layer_sizes=wide)
    # so wide even the 2-slot slab scratch blows the budget → staged
    huge = (784, 1 << 16, 10)
    cfg_huge = dataclasses.replace(SNN_CONFIG, layer_sizes=huge)
    assert snn.resolve_backend(cfg_huge, "auto", 2,
                               layer_sizes=huge) == "staged"
    with pytest.raises(ValueError, match="staged"):
        snn.resolve_backend(cfg_huge, "fused_streamed", 2, layer_sizes=huge)


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 2**31), b=st.integers(1, 9),
       n_in=st.integers(1, 300), n_out=st.integers(1, 140),
       density=st.sampled_from([0.0, 0.1, 0.5, 1.0]))
def test_spike_matmul_runtime_dispatch(seed, b, n_in, n_out, density):
    """Property: mode="auto" (runtime lax.cond on observed density) ==
    masked == mxu == oracle across densities, incl. all-zero tiles."""
    rng = np.random.default_rng(seed)
    spikes = jnp.asarray(
        (rng.random((b, n_in)) < density).astype(np.uint8))
    w = jnp.asarray(rng.integers(-256, 256, (n_in, n_out)), jnp.int16)
    want = np.asarray(ref.spike_matmul_ref(spikes, w))
    for mode in ("auto", "masked", "mxu"):
        got = np.asarray(ops.spike_matmul_op(spikes, w, mode=mode,
                                             interpret=True))
        np.testing.assert_array_equal(got, want, err_msg=mode)
