"""Training-step semantics (microbatching, streaming optimizer) and the
serving engine (generate, early exit, straggler detection)."""


import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.serve import generate, stability_gate
from repro.train import StragglerDetector, TrainSettings, init_state
from repro.train.step import cross_entropy, make_train_step


@pytest.fixture(scope="module")
def setup():
    cfg = get_reduced("qwen3-4b")
    key = jax.random.PRNGKey(0)
    toks = jax.random.randint(key, (8, 17), 0, cfg.vocab_size)
    batch = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
    return cfg, key, batch


def test_microbatched_grads_match_full_batch(setup):
    cfg, key, batch = setup
    s1 = TrainSettings(num_microbatches=1)
    s4 = TrainSettings(num_microbatches=4)
    st = init_state(key, cfg, s1)
    a, ma = jax.jit(make_train_step(cfg, s1))(st, batch)
    b, mb = jax.jit(make_train_step(cfg, s4))(st, batch)
    np.testing.assert_allclose(float(ma["loss"]), float(mb["loss"]), rtol=1e-5)
    for x, y in zip(jax.tree.leaves(a.params), jax.tree.leaves(b.params)):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                   rtol=1e-4, atol=1e-5)


def test_loss_decreases_over_steps(setup):
    cfg, key, batch = setup
    s = TrainSettings(learning_rate=3e-3, warmup_steps=1)
    st = init_state(key, cfg, s)
    step = jax.jit(make_train_step(cfg, s))
    losses = []
    for _ in range(15):
        st, m = step(st, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] * 0.8


def test_cross_entropy_masks_padded_vocab():
    logits = jnp.zeros((1, 2, 8))
    # put huge mass on a padded slot — must not affect loss with vocab=4
    logits = logits.at[..., 6].set(50.0)
    labels = jnp.zeros((1, 2), jnp.int32)
    nll, acc = cross_entropy(logits, labels, vocab_size=4)
    np.testing.assert_allclose(float(nll), np.log(4), rtol=1e-5)
    assert float(acc) == 1.0     # all unpadded logits equal ⇒ label is argmax


def test_cross_entropy_matches_naive():
    rng = np.random.default_rng(0)
    logits = jnp.asarray(rng.normal(0, 2, (3, 5, 11)).astype(np.float32))
    labels = jnp.asarray(rng.integers(0, 11, (3, 5)))
    nll, _ = cross_entropy(logits, labels, vocab_size=11)
    lp = jax.nn.log_softmax(logits, -1)
    want = -np.take_along_axis(np.asarray(lp), np.asarray(labels)[..., None],
                               axis=-1).mean()
    np.testing.assert_allclose(float(nll), want, rtol=1e-6)


def test_generate_with_early_exit(setup):
    cfg, key, _ = setup
    st = init_state(key, cfg, TrainSettings())
    prompt = {"tokens": jax.random.randint(key, (4, 8), 0, cfg.vocab_size)}
    toks, active = generate(st.params, prompt, cfg, steps=8, max_len=32,
                            early_exit_fn=stability_gate(4, patience=1))
    assert toks.shape == (4, 8)
    active = np.asarray(active)
    assert (np.diff(active) <= 0).all()        # retired sequences stay retired
    # an untrained model decodes near-constant tokens ⇒ someone retires
    assert active[-1] < 4


def test_early_exit_frozen_sequences_stop_changing(setup):
    cfg, key, _ = setup
    st = init_state(key, cfg, TrainSettings())
    prompt = {"tokens": jax.random.randint(key, (4, 8), 0, cfg.vocab_size)}
    toks, active = generate(st.params, prompt, cfg, steps=10, max_len=32,
                            early_exit_fn=stability_gate(4, patience=1))
    toks = np.asarray(toks)
    # once a sequence's token repeats to the end, it was retired & held
    for b in range(4):
        tail = toks[b, -3:]
        if (tail == tail[0]).all():
            assert (toks[b, -2:] == tail[0]).all()


def test_straggler_detector_flags_slow_step():
    det = StragglerDetector(warmup=3, k_sigma=2.0)
    flagged = []
    for i, dt in enumerate([1.0, 1.0, 1.0, 1.0, 1.0, 1.0, 5.0, 1.0]):
        flagged.append(det.observe(i, dt))
    assert flagged[6] is True and sum(flagged) == 1


def test_straggler_detector_tolerates_noise():
    rng = np.random.default_rng(0)
    det = StragglerDetector(warmup=5, k_sigma=4.0)
    flags = [det.observe(i, 1.0 + 0.05 * rng.standard_normal())
             for i in range(100)]
    assert sum(flags) <= 2
