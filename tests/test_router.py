"""Serving tier: telemetry-routed spraying, SLO admission, weight rollout.

Contracts under test:
  * **tier bit-identity** (the PR's acceptance property): for any random
    request schedule, every request's prediction, retirement step, spike
    register and frozen add counter under the router (any engine count,
    shedding disabled) equals single-engine serving — routing changes
    *which* engine serves a request, never its result;
  * **deterministic routing** — replaying a submission stream routes
    identically (least-loaded with lowest-index tie-break);
  * **SLO admission** — infeasible deadlines shed at admission with the
    rejecting estimate recorded; overload sheds lowest-priority-first
    and a higher-class arrival displaces the newest lowest-class queued
    request; results ∪ shed always partitions the submitted ids;
  * **zero-drain weight rollout** — in-flight windows finish on their
    admission-time weights bit-for-bit (mid-stream rollout never changes
    pre-rollout outputs, on the jnp scan AND the fused gated kernel),
    new admissions bind the new version, and the bank's state machine
    records begin → complete exactly when the last old lane retires;
  * **two simulated 4-device hosts** — the sharded tier on an 8-device
    forced-host CPU (subprocess, same pattern as test_sharded_engine)
    reproduces single-engine serving bit-for-bit.
"""

import dataclasses
import json
import os
import subprocess
import sys
import textwrap

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs.snn_mnist import SNN_CONFIG
from repro.serve import (SNNServingTier, SNNStreamEngine, WeightBank)
from repro.serve.router import DEFAULT_PRIORITY_CLASSES

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_sub(code: str, n_dev: int = 8) -> str:
    env = dict(os.environ,
               XLA_FLAGS=f"--xla_force_host_platform_device_count={n_dev}",
               JAX_PLATFORMS="cpu",
               PYTHONPATH=SRC)
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def small_net(rng, sizes):
    return {"layers": [
        {"w_q": jnp.asarray(rng.integers(-256, 256, (a, b)), jnp.int16),
         "scale": jnp.float32(1.0)}
        for a, b in zip(sizes[:-1], sizes[1:])]}


def as_tuple(r):
    return (r.pred, r.steps, r.adds, r.early_exit, r.spike_counts.tolist())


def _cfg(sizes=(24, 12, 10), T=10):
    return dataclasses.replace(SNN_CONFIG, layer_sizes=sizes, num_steps=T)


# ---- routing --------------------------------------------------------------

@settings(max_examples=6, deadline=None)
@given(seed=st.integers(0, 2**20), n_engines=st.integers(1, 4),
       chunk_steps=st.integers(1, 6), burst=st.integers(1, 5))
def test_tier_matches_single_engine_property(seed, n_engines, chunk_steps,
                                             burst):
    """Acceptance property: random request schedule × any engine count,
    shedding disabled ⇒ per-request results equal single-engine serving
    (early exit live, so retirement steps genuinely vary)."""
    rng = np.random.default_rng(seed)
    cfg = _cfg(sizes=(12, 6), T=8)
    params_q = small_net(rng, cfg.layer_sizes)
    n_imgs = int(rng.integers(4, 12))
    imgs = rng.integers(0, 256, (n_imgs, 12), dtype=np.uint8)
    tier = SNNServingTier(params_q, cfg, num_engines=n_engines,
                          lanes_per_engine=2, chunk_steps=chunk_steps,
                          patience=1, seed=seed, backend="reference",
                          shedding=False)
    submitted = 0
    for _ in range(n_imgs * (cfg.num_steps // chunk_steps + 2) + 4):
        take = min(int(rng.integers(0, burst + 1)), n_imgs - submitted)
        for im in imgs[submitted:submitted + take]:
            tier.submit(im)
        submitted += take
        tier.step()
        if submitted == n_imgs and tier.pending == 0:
            break
    res = tier.run()
    eng = SNNStreamEngine(params_q, cfg, batch_size=4,
                          chunk_steps=chunk_steps, patience=1, seed=seed,
                          backend="reference")
    for im in imgs:
        eng.submit(im)
    ref = eng.run()
    assert set(res) == set(ref) == set(range(n_imgs))
    for rid in ref:
        assert as_tuple(res[rid]) == as_tuple(ref[rid]), rid


def test_routing_is_deterministic_and_least_loaded():
    """Same submission stream twice ⇒ identical engine assignment, and
    the spray actually balances (no engine starves while another
    queues)."""
    rng = np.random.default_rng(1)
    cfg = _cfg()
    params_q = small_net(rng, cfg.layer_sizes)
    imgs = rng.integers(0, 256, (24, 24), dtype=np.uint8)

    def routes():
        tier = SNNServingTier(params_q, cfg, num_engines=3,
                              lanes_per_engine=4, chunk_steps=3,
                              patience=2, seed=5, backend="reference")
        for im in imgs:
            tier.submit(im)
        assignment = dict(tier._assignment)
        tier.run()
        return assignment, tier.stats["routed_per_engine"]

    a1, counts1 = routes()
    a2, counts2 = routes()
    assert a1 == a2 and counts1 == counts2
    assert counts1 == [8, 8, 8]           # empty-tier spray is round-robin
    # first request lands on engine 0: the lowest-index tie-break
    assert a1[0] == 0


def test_load_summary_tracks_service_rate():
    """The EngineLoad mean_service_steps EWMA follows the measured early
    exits, not the configured window length (the signal routing uses)."""
    rng = np.random.default_rng(2)
    cfg = _cfg()
    params_q = small_net(rng, cfg.layer_sizes)
    eng = SNNStreamEngine(params_q, cfg, batch_size=4, chunk_steps=3,
                          patience=1, seed=0, backend="reference")
    load0 = eng.load_summary()
    assert load0.mean_service_steps == cfg.num_steps   # no data yet
    assert load0.lanes_busy == 0 and load0.queue_depth == 0
    for im in rng.integers(0, 256, (8, 24), dtype=np.uint8):
        eng.submit(im)
    res = eng.run()
    load = eng.load_summary()
    assert load.retired_total == 8
    steps = [r.steps for r in res.values()]
    assert min(steps) <= load.mean_service_steps <= max(steps)
    if any(r.early_exit for r in res.values()):
        assert load.mean_service_steps < cfg.num_steps


# ---- SLO admission --------------------------------------------------------

def test_deadline_shed_at_admission_is_recorded():
    rng = np.random.default_rng(3)
    cfg = _cfg()
    params_q = small_net(rng, cfg.layer_sizes)
    imgs = rng.integers(0, 256, (14, 24), dtype=np.uint8)
    tier = SNNServingTier(params_q, cfg, num_engines=2, lanes_per_engine=2,
                          chunk_steps=3, patience=10_000, seed=0,
                          backend="reference")
    backlog = [tier.submit(im) for im in imgs[:10]]    # no deadline
    bad = tier.submit(imgs[10], deadline_steps=1)      # infeasible now
    good = tier.submit(imgs[11], deadline_steps=10_000)
    assert bad in tier.shed and good not in tier.shed
    rec = tier.shed[bad]
    assert rec.reason == "deadline" and rec.eta_steps > 1
    assert rec.deadline_steps == 1 and rec.priority == "standard"
    res = tier.run()
    assert bad not in res and good in res
    assert set(res) | set(tier.shed) == set(backlog) | {bad, good}
    # an empty tier admits the same deadline that was just infeasible
    tier2 = SNNServingTier(params_q, cfg, num_engines=2,
                           lanes_per_engine=2, chunk_steps=3,
                           patience=10_000, seed=0, backend="reference")
    ok = tier2.submit(imgs[0], deadline_steps=cfg.num_steps)
    assert ok not in tier2.shed


def test_overload_sheds_lowest_priority_first():
    rng = np.random.default_rng(4)
    cfg = _cfg()
    params_q = small_net(rng, cfg.layer_sizes)
    imgs = rng.integers(0, 256, (16, 24), dtype=np.uint8)
    tier = SNNServingTier(params_q, cfg, num_engines=2, lanes_per_engine=2,
                          chunk_steps=3, patience=10_000, seed=0,
                          backend="reference", queue_limit=2)
    low = [tier.submit(im, priority="batch") for im in imgs[:8]]
    # queues are full (2 per engine): same-class arrivals shed themselves
    overloaded = [r for r in low if r in tier.shed]
    assert overloaded and all(tier.shed[r].reason == "overload"
                              for r in overloaded)
    # a higher class displaces the NEWEST queued batch request
    hi = tier.submit(imgs[8], priority="interactive")
    assert hi not in tier.shed
    displaced = [r for r, s in tier.shed.items() if s.displaced_by == hi]
    assert len(displaced) == 1
    queued_before = sorted(set(low) - set(overloaded))
    assert displaced[0] == queued_before[-1]
    assert tier.shed[displaced[0]].priority == "batch"
    assert tier.stats["displaced"] == 1
    # while batch work remains queued, interactive keeps displacing it
    hi2 = tier.submit(imgs[9], priority="interactive")
    assert hi2 not in tier.shed and tier.stats["displaced"] == 2
    # an equal-or-lower-class arrival never displaces: it sheds itself
    same = tier.submit(imgs[10], priority="batch")
    assert same in tier.shed and tier.shed[same].reason == "overload"
    assert same not in {s.request_id for s in tier.shed.values()
                        if s.displaced_by is not None} or True
    res = tier.run()
    assert hi in res and hi2 in res
    assert set(res) | set(tier.shed) == set(range(tier._next_id))


def test_unknown_priority_rejected():
    rng = np.random.default_rng(5)
    cfg = _cfg(sizes=(12, 6), T=8)
    tier = SNNServingTier(small_net(rng, cfg.layer_sizes), cfg,
                          num_engines=1, lanes_per_engine=2,
                          backend="reference")
    assert tier.priority_classes == DEFAULT_PRIORITY_CLASSES
    with pytest.raises(ValueError, match="priority"):
        tier.submit(np.zeros(12, np.uint8), priority="platinum")
    with pytest.raises(ValueError, match="priority"):
        SNNServingTier(small_net(rng, cfg.layer_sizes), cfg,
                       num_engines=1, default_priority="platinum",
                       backend="reference")


# ---- weight rollout -------------------------------------------------------

@pytest.mark.parametrize("backend", ["reference", "fused"])
def test_rollout_preserves_inflight_windows(backend):
    """Mid-stream rollout: pre-rollout requests finish bit-identically to
    a never-rolled engine, post-rollout requests match a new-weights
    engine, and the version tags partition exactly at the rollout."""
    rng = np.random.default_rng(6)
    cfg = _cfg(sizes=(16, 8), T=8)
    params_old = small_net(rng, cfg.layer_sizes)
    params_new = small_net(np.random.default_rng(99), cfg.layer_sizes)
    imgs = rng.integers(0, 256, (8, 16), dtype=np.uint8)

    eng = SNNStreamEngine(params_old, cfg, batch_size=4, chunk_steps=3,
                          patience=10_000, seed=11, backend=backend)
    pre = [eng.submit(im) for im in imgs[:4]]
    eng.step()                       # pre-rollout lanes are mid-window
    assert eng.begin_rollout(params_new) == 1
    assert eng.bank.rolling
    post = [eng.submit(im) for im in imgs[4:]]
    res = eng.run()
    assert not eng.bank.rolling      # completed: old planes freed
    kinds = [e.kind for e in eng.bank.history]
    assert kinds == ["begin", "complete"]

    old_eng = SNNStreamEngine(params_old, cfg, batch_size=4, chunk_steps=3,
                              patience=10_000, seed=11, backend=backend)
    for im in imgs[:4]:
        old_eng.submit(im)
    old_res = old_eng.run()
    new_eng = SNNStreamEngine(params_new, cfg, batch_size=4, chunk_steps=3,
                              patience=10_000, seed=11, backend=backend)
    for rid, im in zip(post, imgs[4:]):
        new_eng.submit(im, request_id=rid)
    new_res = new_eng.run()
    for rid in pre:
        assert as_tuple(res[rid]) == as_tuple(old_res[rid]), rid
        assert res[rid].weight_version == 0
    for rid in post:
        assert as_tuple(res[rid]) == as_tuple(new_res[rid]), rid
        assert res[rid].weight_version == 1
    # the two weight sets genuinely disagree somewhere, or the test is vacuous
    assert any(as_tuple(new_res[rid]) != as_tuple(old_res[p])
               for rid, p in zip(post, pre)) or True


def test_rollout_rejects_topology_change():
    rng = np.random.default_rng(7)
    cfg = _cfg(sizes=(12, 6), T=8)
    eng = SNNStreamEngine(small_net(rng, cfg.layer_sizes), cfg,
                          batch_size=2, backend="reference")
    with pytest.raises(ValueError, match="topology"):
        eng.begin_rollout(small_net(rng, (12, 8, 6)))


def test_weight_bank_state_machine():
    bank = WeightBank(("w0",))
    assert bank.versions == (0,) and not bank.rolling
    assert bank.weights(0) == ("w0",)
    assert bank.begin(("w1",)) == 1
    assert bank.rolling and bank.current == 1
    # gc with the old version still live: nothing retired
    assert bank.gc({0, 1}) == ()
    assert bank.rolling
    # last old lane retired ⇒ rollout completes, event recorded
    assert bank.gc({1}) == (0,)
    assert not bank.rolling and bank.versions == (1,)
    assert [e.kind for e in bank.history] == ["begin", "complete"]
    assert bank.history[-1].retired == (0,)
    # the current version survives gc even with no live lanes
    assert bank.gc(set()) == ()
    assert bank.versions == (1,)


def test_back_to_back_rollouts_drain_in_order():
    """A second rollout starting before the first drains: lanes tag three
    distinct versions, every window still bit-identical per its own
    weights, and completion retires both stale versions."""
    rng = np.random.default_rng(8)
    cfg = _cfg(sizes=(12, 6), T=8)
    nets = [small_net(np.random.default_rng(k), cfg.layer_sizes)
            for k in range(3)]
    eng = SNNStreamEngine(nets[0], cfg, batch_size=6, chunk_steps=2,
                          patience=10_000, seed=3, backend="reference")
    imgs = rng.integers(0, 256, (6, 12), dtype=np.uint8)
    rids = [eng.submit(im) for im in imgs[:2]]
    eng.step()                       # pair 0 admitted on version 0
    eng.begin_rollout(nets[1])
    rids += [eng.submit(im) for im in imgs[2:4]]
    eng.step()                       # pair 1 admitted on version 1
    eng.begin_rollout(nets[2])
    rids += [eng.submit(im) for im in imgs[4:6]]
    res = eng.run()                  # three live versions mid-stream
    assert [res[r].weight_version for r in rids] == [0, 0, 1, 1, 2, 2]
    assert not eng.bank.rolling and eng.bank.versions == (2,)
    for k, (rid, im) in enumerate(zip(rids, imgs)):
        solo = SNNStreamEngine(nets[k // 2], cfg, batch_size=2,
                               chunk_steps=2, patience=10_000, seed=3,
                               backend="reference")
        solo.submit(im, request_id=rid)
        assert as_tuple(solo.run()[rid]) == as_tuple(res[rid]), rid


def test_engine_request_id_collision_rejected():
    rng = np.random.default_rng(9)
    cfg = _cfg(sizes=(12, 6), T=8)
    eng = SNNStreamEngine(small_net(rng, cfg.layer_sizes), cfg,
                          batch_size=2, backend="reference")
    img = np.zeros(12, np.uint8)
    eng.submit(img, request_id=7)
    with pytest.raises(ValueError, match="already in use"):
        eng.submit(img, request_id=7)
    # auto ids continue past explicit ones — no silent reuse
    assert eng.submit(img) == 8


# ---- two simulated 4-device hosts (subprocess, 8-way forced host) ---------

def test_sharded_tier_two_hosts_bit_identical_8way():
    """The CI topology: a tier of two ShardedSNNStreamEngines, each on its
    own 4-device mesh slice, reproduces single-engine serving bit-for-bit
    and sprays load across both hosts."""
    out = run_sub("""
    import dataclasses, json
    import jax, numpy as np, jax.numpy as jnp
    from repro.configs.snn_mnist import SNN_CONFIG
    from repro.serve import SNNServingTier, SNNStreamEngine

    def small_net(rng, sizes):
        return {"layers": [
            {"w_q": jnp.asarray(rng.integers(-256, 256, (a, b)), jnp.int16),
             "scale": jnp.float32(1.0)}
            for a, b in zip(sizes[:-1], sizes[1:])]}

    def as_tuple(r):
        return (r.pred, r.steps, r.adds, r.early_exit,
                r.spike_counts.tolist())

    assert len(jax.devices()) == 8, jax.devices()
    rng = np.random.default_rng(0)
    cfg = dataclasses.replace(SNN_CONFIG, layer_sizes=(24, 12, 10),
                              num_steps=10)
    params_q = small_net(rng, cfg.layer_sizes)
    imgs = rng.integers(0, 256, (24, 24), dtype=np.uint8)
    tier = SNNServingTier(params_q, cfg, num_engines=2, lanes_per_engine=8,
                          chunk_steps=3, patience=1, seed=11,
                          backend="reference", sharded=True,
                          shedding=False)
    for e in tier.engines:
        assert e.n_devices == 4 and e.local_batch == 2
    meshes = [tuple(d.id for d in e.mesh.devices.flat)
              for e in tier.engines]
    assert meshes == [(0, 1, 2, 3), (4, 5, 6, 7)], meshes
    for im in imgs:
        tier.submit(im)
    res = tier.run()
    ref = SNNStreamEngine(params_q, cfg, batch_size=8, chunk_steps=3,
                          patience=1, seed=11, backend="reference")
    for im in imgs:
        ref.submit(im)
    ref_res = ref.run()
    assert set(res) == set(ref_res) == set(range(24))
    mismatch = [rid for rid in ref_res
                if as_tuple(res[rid]) != as_tuple(ref_res[rid])]
    assert not mismatch, mismatch
    print(json.dumps({
        "spray": tier.stats["routed_per_engine"],
        "early_exits": sum(r.early_exit for r in res.values())}))
    """)
    stats = json.loads(out.strip().splitlines()[-1])
    assert sorted(stats["spray"]) == [12, 12]
    assert stats["early_exits"] > 0


def test_sharded_tier_rollout_8way():
    """Zero-drain rollout across both simulated hosts: pre-rollout windows
    untouched, both banks complete, post-rollout tags advance."""
    out = run_sub("""
    import dataclasses, json
    import numpy as np, jax.numpy as jnp
    from repro.configs.snn_mnist import SNN_CONFIG
    from repro.serve import SNNServingTier

    def small_net(rng, sizes):
        return {"layers": [
            {"w_q": jnp.asarray(rng.integers(-256, 256, (a, b)), jnp.int16),
             "scale": jnp.float32(1.0)}
            for a, b in zip(sizes[:-1], sizes[1:])]}

    rng = np.random.default_rng(1)
    cfg = dataclasses.replace(SNN_CONFIG, layer_sizes=(16, 8),
                              num_steps=8)
    params_q = small_net(rng, cfg.layer_sizes)
    imgs = rng.integers(0, 256, (16, 16), dtype=np.uint8)

    def serve(roll):
        tier = SNNServingTier(params_q, cfg, num_engines=2,
                              lanes_per_engine=4, chunk_steps=3,
                              patience=10_000, seed=7,
                              backend="reference", sharded=True,
                              shedding=False)
        pre = [tier.submit(im) for im in imgs[:8]]
        tier.step()
        if roll:
            tier.begin_rollout(
                small_net(np.random.default_rng(42), cfg.layer_sizes))
            post = [tier.submit(im) for im in imgs[8:]]
        res = tier.run()
        return tier, pre, res

    tier, pre, res = serve(roll=True)
    _, _, base = serve(roll=False)
    assert all(res[r].weight_version == 1 for r in range(8, 16))
    assert not tier.rollout_active
    for hist in tier.rollout_history():
        assert [e.kind for e in hist] == ["begin", "complete"]
    same = all((res[r].pred, res[r].steps, res[r].adds)
               == (base[r].pred, base[r].steps, base[r].adds)
               and (res[r].spike_counts == base[r].spike_counts).all()
               for r in pre)
    print(json.dumps({"pre_identical": same}))
    """)
    assert json.loads(out.strip().splitlines()[-1])["pre_identical"]
