"""Trip-count-aware HLO cost model: exactness on known programs."""

import jax
import jax.numpy as jnp
import pytest

from repro.launch.hlo_cost import hlo_cost


def compile_cost(f, *args):
    c = jax.jit(f).lower(*args).compile()
    return hlo_cost(c.as_text())


def test_single_matmul():
    n = 128
    hc = compile_cost(lambda a, b: a @ b,
                      jax.ShapeDtypeStruct((n, n), jnp.float32),
                      jax.ShapeDtypeStruct((n, n), jnp.float32))
    assert hc.flops == pytest.approx(2 * n**3, rel=0.01)


@pytest.mark.parametrize("L", [1, 3, 17])
def test_scan_multiplies_by_trip_count(L):
    n = 64

    def f(x, w):
        def body(c, _):
            return c @ w, None
        y, _ = jax.lax.scan(body, x, None, length=L)
        return y.sum()

    hc = compile_cost(f, jax.ShapeDtypeStruct((n, n), jnp.float32),
                      jax.ShapeDtypeStruct((n, n), jnp.float32))
    assert hc.flops == pytest.approx(2 * n**3 * L, rel=0.02)


def test_nested_scans():
    n = 64

    def f(x, w):
        def outer(c, _):
            def inner(c2, _):
                return c2 @ w, None
            c2, _ = jax.lax.scan(inner, c, None, length=4)
            return c2, None
        y, _ = jax.lax.scan(outer, x, None, length=3)
        return y.sum()

    hc = compile_cost(f, jax.ShapeDtypeStruct((n, n), jnp.float32),
                      jax.ShapeDtypeStruct((n, n), jnp.float32))
    assert hc.flops == pytest.approx(2 * n**3 * 12, rel=0.02)


def test_fori_loop_counted():
    n = 64

    def f(x, w):
        return jax.lax.fori_loop(0, 7, lambda i, c: c @ w, x).sum()

    hc = compile_cost(f, jax.ShapeDtypeStruct((n, n), jnp.float32),
                      jax.ShapeDtypeStruct((n, n), jnp.float32))
    assert hc.flops == pytest.approx(2 * n**3 * 7, rel=0.02)


def test_bytes_scale_with_trips():
    n = 64

    def f(x):
        def body(c, _):
            return jnp.tanh(c) * 2.0, None
        y, _ = jax.lax.scan(body, x, None, length=10)
        return y

    hc1 = compile_cost(f, jax.ShapeDtypeStruct((n, n), jnp.float32))

    def g(x):
        def body(c, _):
            return jnp.tanh(c) * 2.0, None
        y, _ = jax.lax.scan(body, x, None, length=20)
        return y

    hc2 = compile_cost(g, jax.ShapeDtypeStruct((n, n), jnp.float32))
    assert hc2.bytes > hc1.bytes * 1.5
