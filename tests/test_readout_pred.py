"""readout_pred edge cases.

The helper is the single source of truth for predictions across
snn_apply_int, the streaming engine's gate/harvest paths and the fused
kernel's in-kernel mirror — previously its corner semantics were only
exercised indirectly through the engine e2e test.  Contracts:

  * ``count`` with all-zero registers degenerates to argmax-of-zeros
    (class 0) — callers that must not act on it guard with their own
    has-spike check (the engine's gate does exactly that);
  * ``first_spike`` ties break lowest-index-wins, matching jnp.argmax and
    the kernel's iota+min implementation;
  * any spiked class outranks every membrane-only class (the two score
    tiers), which is the count/first-spike tiebreak the active-pruning
    config relies on (a pruned neuron fires at most once, so counts alone
    cannot rank spiked classes — arrival order must).
"""

import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.configs.snn_mnist import SNN_CONFIG_PRUNED
from repro.core import prng, snn
from repro.core.snn import readout_pred

T = 20
SENT = T  # first-spike sentinel: "never spiked"


def _first(*ts):
    return jnp.asarray([list(ts)], jnp.int32)


def test_count_all_zero_registers_is_class_zero():
    counts = jnp.zeros((3, 5), jnp.int32)
    first = jnp.full((3, 5), SENT, jnp.int32)
    v = jnp.asarray(np.arange(15).reshape(3, 5), jnp.int32)
    pred = readout_pred(counts, first, v, "count", T)
    assert (np.asarray(pred) == 0).all()


def test_first_spike_all_zero_counts_falls_back_to_membrane():
    counts = jnp.zeros((1, 4), jnp.int32)
    first = jnp.full((1, 4), SENT, jnp.int32)
    v = jnp.asarray([[5, -3, 9, 2]], jnp.int32)
    assert int(readout_pred(counts, first, v, "first_spike", T)[0]) == 2


def test_first_spike_membrane_tiebreak_lowest_index():
    counts = jnp.zeros((1, 4), jnp.int32)
    first = jnp.full((1, 4), SENT, jnp.int32)
    v = jnp.asarray([[5, 9, 9, 2]], jnp.int32)
    assert int(readout_pred(counts, first, v, "first_spike", T)[0]) == 1


def test_first_spike_tie_lowest_index_wins():
    counts = jnp.asarray([[0, 1, 1, 0]], jnp.int32)
    first = _first(SENT, 3, 3, SENT)
    v = jnp.asarray([[0, 0, 10_000, 0]], jnp.int32)  # membrane must not rank
    assert int(readout_pred(counts, first, v, "first_spike", T)[0]) == 1


def test_first_spike_earliest_beats_higher_count():
    counts = jnp.asarray([[0, 1, 7, 0]], jnp.int32)
    first = _first(SENT, 2, 9, SENT)
    v = jnp.zeros((1, 4), jnp.int32)
    assert int(readout_pred(counts, first, v, "first_spike", T)[0]) == 1


def test_spiked_class_outranks_any_membrane():
    """Two score tiers: a last-step spike beats a near-threshold silent
    class, for any realistic window length."""
    counts = jnp.asarray([[0, 0, 0, 1]], jnp.int32)
    first = _first(SENT, SENT, SENT, T - 1)
    v = jnp.asarray([[(1 << 24) - 2, 127, 0, -5]], jnp.int32)
    assert int(readout_pred(counts, first, v, "first_spike", T)[0]) == 3


def test_count_vs_first_spike_tiebreak_on_pruned_config():
    """Active pruning clamps every register to {0, 1}: the count readout
    degenerates to lowest-index-of-the-spiked-set while the pruned
    config's first_spike readout ranks by arrival — the exact divergence
    the paper's §III-D readout swap exists for."""
    counts = jnp.asarray([[1, 1, 1, 0]], jnp.int32)
    first = _first(5, 2, 9, SENT)
    v = jnp.zeros((1, 4), jnp.int32)
    assert int(readout_pred(counts, first, v, "count", T)[0]) == 0
    assert SNN_CONFIG_PRUNED.readout == "first_spike"
    assert int(readout_pred(counts, first, v,
                            SNN_CONFIG_PRUNED.readout, T)[0]) == 1


def test_membrane_peak_tiebreak_lowest_index():
    """The streamed membrane path ranks by the carried peak accumulator:
    ties break lowest-index-wins (jnp.argmax), matching the gated
    kernel's iota+min mirror."""
    counts = jnp.zeros((1, 4), jnp.int32)
    first = jnp.full((1, 4), SENT, jnp.int32)
    v_final = jnp.asarray([[0, 0, 0, 99]], jnp.int32)   # must not rank
    v_peak = jnp.asarray([[3, 9, 9, 3]], jnp.int32)
    assert int(readout_pred(counts, first, v_final, "membrane", T,
                            v_peak=v_peak)[0]) == 1


def test_membrane_pred_follows_peak_not_final_or_trace_sum():
    """Peak semantics: a class whose membrane spiked high once and decayed
    outranks a class that ends higher (v_final) or integrates higher —
    and the v_peak accumulator path agrees with the v_trace path."""
    v_trace = jnp.asarray([[[0, 50], [100, 60], [0, 70]]], jnp.int32)
    v_trace = jnp.swapaxes(v_trace, 0, 1)              # (T=3, B=1, 2)
    counts = jnp.zeros((1, 2), jnp.int32)
    first = jnp.full((1, 2), SENT, jnp.int32)
    v_final = jnp.asarray([[0, 70]], jnp.int32)
    from_trace = readout_pred(counts, first, v_final, "membrane", T,
                              v_trace=v_trace)
    from_peak = readout_pred(counts, first, v_final, "membrane", T,
                             v_peak=jnp.max(v_trace, axis=0))
    assert int(from_trace[0]) == int(from_peak[0]) == 0


def test_membrane_chunked_peak_matches_one_shot_pred(rng):
    """The carried v_peak of a chunked window reproduces the one-shot
    membrane prediction — the streamed path of the readout contract."""
    cfg = dataclasses.replace(SNN_CONFIG_PRUNED, layer_sizes=(24, 8),
                              num_steps=10, readout="membrane",
                              active_pruning=False)
    params_q = {"layers": [{
        "w_q": jnp.asarray(rng.integers(-200, 200, (24, 8)), jnp.int16),
        "scale": jnp.float32(1.0)}]}
    px = jnp.asarray(rng.integers(0, 256, (5, 24), dtype=np.uint8))
    state0 = prng.seed_state(41, px.shape)
    one_shot = snn.snn_apply_int(params_q, px, state0, cfg,
                                 backend="reference")
    ws = snn.snn_window_init(params_q, state0, cfg)
    for chunk in (4, 3, 3):
        ws, _ = snn.snn_window_chunk(params_q, px, ws, cfg,
                                     chunk_steps=chunk, backend="reference")
    streamed = readout_pred(ws.counts, ws.first, ws.v[-1], "membrane",
                            cfg.num_steps, v_peak=ws.v_peak[-1])
    np.testing.assert_array_equal(np.asarray(streamed),
                                  np.asarray(one_shot["pred"]))


def test_pruned_engine_counts_are_saturated(rng):
    """End-to-end guard for the tiebreak above: under the pruned config
    every neuron fires at most once, so the registers really are 0/1 and
    first-spike times are the only ranking signal among spiked classes."""
    cfg = dataclasses.replace(SNN_CONFIG_PRUNED, layer_sizes=(16, 6),
                              num_steps=12)
    params_q = {"layers": [{
        "w_q": jnp.asarray(rng.integers(-64, 256, (16, 6)), jnp.int16),
        "scale": jnp.float32(1.0)}]}
    px = jnp.asarray(rng.integers(64, 256, (4, 16), dtype=np.uint8))
    out = snn.snn_apply_int(params_q, px, prng.seed_state(9, px.shape),
                            cfg, backend="reference")
    counts = np.asarray(out["spike_counts"])
    first = np.asarray(out["first_spike_t"])
    assert counts.max() <= 1 and counts.max() == 1
    np.testing.assert_array_equal(
        np.asarray(out["pred"]),
        np.asarray(readout_pred(out["spike_counts"], out["first_spike_t"],
                                out["v_final"], cfg.readout,
                                cfg.num_steps)))
    # spiked ⇔ a real first-spike time; silent ⇔ sentinel
    assert ((first < cfg.num_steps) == (counts == 1)).all()
