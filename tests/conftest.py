"""Shared fixtures. NOTE: no XLA_FLAGS device-count forcing here — smoke
tests and benchmarks must see the real (single) CPU device; only the
dry-run and the subprocess-based distributed tests use fake device counts."""

import os
import sys

import numpy as np
import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.dirname(__file__))

# Property tests import hypothesis; fall back to the deterministic stub so
# the suite collects/runs in environments without it (CI installs the real
# thing via the dev extras).
import _hypothesis_stub  # noqa: E402

_hypothesis_stub.install()


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
