"""Shared fixtures. NOTE: no XLA_FLAGS device-count forcing here — smoke
tests and benchmarks must see the real (single) CPU device; only the
dry-run and the subprocess-based distributed tests use fake device counts."""

import os
import sys

import numpy as np
import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
