"""Per-architecture smoke tests: reduced config, one forward + one train
step on CPU, asserting output shapes and finiteness (assignment req.)."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced, list_archs
from repro.models import lm_apply, lm_init
from repro.train import TrainSettings, init_state
from repro.train.step import make_train_step

ARCHS = [a for a in list_archs() if a != "snn-mnist"]


def make_batch(cfg, key, B=2, S=16, labels=True):
    batch = {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab_size)}
    if cfg.frontend == "vision":
        p = min(cfg.num_patches, S // 2)
        batch["patches"] = jnp.ones((B, p, cfg.d_model), jnp.float32) * 0.02
        batch["tokens"] = batch["tokens"][:, : S - p]
    if cfg.is_encdec:
        batch["frames"] = jnp.ones((B, cfg.encoder_seq, cfg.d_model),
                                   jnp.float32) * 0.02
    if labels:
        batch["labels"] = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_and_finite(arch):
    cfg = get_reduced(arch)
    key = jax.random.PRNGKey(0)
    params = lm_init(key, cfg)
    batch = make_batch(cfg, key, labels=False)
    logits, _, aux = lm_apply(params, batch, cfg, mode="train")
    assert logits.shape == (2, 16, cfg.padded_vocab)
    assert bool(jnp.isfinite(logits).all())
    if cfg.moe_num_experts:
        assert float(aux["lb_loss"]) > 0.0


@pytest.mark.parametrize("arch", ARCHS)
def test_one_train_step_no_nans(arch):
    cfg = get_reduced(arch)
    s = TrainSettings(num_microbatches=1, learning_rate=1e-3)
    key = jax.random.PRNGKey(1)
    state = init_state(key, cfg, s)
    batch = make_batch(cfg, key)
    step = jax.jit(make_train_step(cfg, s))
    new_state, metrics = step(state, batch)
    assert int(new_state.step) == 1
    assert np.isfinite(float(metrics["loss"]))
    finite = jax.tree.map(lambda x: bool(jnp.isfinite(x).all()),
                          new_state.params)
    assert all(jax.tree.leaves(finite))


@pytest.mark.parametrize("arch", ["qwen3-4b", "gemma2-9b", "mamba2-1.3b",
                                  "jamba-v0.1-52b", "whisper-small"])
def test_decode_matches_prefill_next_logits(arch):
    """Greedy decode step t must reproduce the prefill logits at t."""
    cfg = get_reduced(arch)
    key = jax.random.PRNGKey(2)
    params = lm_init(key, cfg)
    B, S = 2, 12
    batch = make_batch(cfg, key, B, S, labels=False)

    full_logits, _, _ = lm_apply(params, batch, cfg, mode="train")

    # prefill on the first S-1 tokens, then decode token S-1
    pre = dict(batch)
    pre["tokens"] = batch["tokens"][:, :-1]
    logits_p, cache, _ = lm_apply(params, pre, cfg, mode="prefill")
    from repro.serve.engine import pad_cache_to
    cache = pad_cache_to(cache, S + 4)
    cur = jnp.full((B,), full_logits.shape[1] - 1, jnp.int32)
    dec = {"tokens": batch["tokens"][:, -1:]}
    logits_d, _, _ = lm_apply(params, dec, cfg, mode="decode",
                              cache=cache, cur_len=cur)
    np.testing.assert_allclose(np.asarray(logits_d[:, 0]),
                               np.asarray(full_logits[:, -1]),
                               rtol=0.15, atol=0.15)


def test_gemma2_softcaps_bound_logits():
    cfg = get_reduced("gemma2-9b")
    key = jax.random.PRNGKey(3)
    params = lm_init(key, cfg)
    batch = make_batch(cfg, key, labels=False)
    logits, _, _ = lm_apply(params, batch, cfg, mode="train")
    assert float(jnp.max(jnp.abs(logits))) <= cfg.final_softcap + 1e-3


def test_mamba_chunked_equals_small_chunk():
    """SSD output must be chunk-size invariant."""
    cfg = get_reduced("mamba2-1.3b")
    cfg8 = dataclasses.replace(cfg, ssm_chunk=8)
    cfg4 = dataclasses.replace(cfg, ssm_chunk=4)
    key = jax.random.PRNGKey(4)
    params = lm_init(key, cfg8)
    batch = make_batch(cfg8, key, labels=False)
    a, _, _ = lm_apply(params, batch, cfg8, mode="train")
    b, _, _ = lm_apply(params, batch, cfg4, mode="train")
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=2e-2, atol=2e-2)


def test_jamba_layer_plan():
    from repro.configs import get_config
    from repro.models.transformer import block_size, layer_plan
    cfg = get_config("jamba-v0.1-52b")
    plan = layer_plan(cfg)
    assert len(plan) == 32
    assert sum(p.kind == "attn" for p in plan) == 4        # 1:7 ratio
    assert sum(p.ffn == "moe" for p in plan) == 16         # every other
    assert block_size(plan) == 8


def test_gemma2_layer_plan_alternates():
    from repro.configs import get_config
    from repro.models.transformer import block_size, layer_plan
    cfg = get_config("gemma2-9b")
    plan = layer_plan(cfg)
    assert plan[0].window == cfg.sliding_window and plan[1].window is None
    assert block_size(plan) == 2
