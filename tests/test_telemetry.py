"""Telemetry side channel + adaptive dispatch controller.

Contracts under test:

  * telemetry executed adds ARE the energy counters: summed over layers
    they equal ``active_adds`` on every backend, and through the gated
    streaming chunk they equal the frozen per-lane add deltas;
  * the telemetry record is bit-identical across the
    fused / fused_streamed / staged / reference backends and across
    random window chunk splits (concatenation == one-shot) — the side
    channel is cross-checkable exactly like the datapath;
  * the tile-skip mirror (``core.telemetry.layer_tile_skips``) agrees
    with the independently-derived ``kernels.ref.tile_skips_ref`` oracle
    (double-entry bookkeeping for the launch geometry);
  * the dispatch threshold resolves config → env → the historical
    ``kernels.ops.SPIKE_DENSITY_THRESHOLD`` constant, and
    ``spike_matmul_op`` honors the boundary + reports its density
    telemetry;
  * the controller in frozen mode reproduces the static choices exactly
    (and never syncs), while adaptive mode — property-tested over random
    traffic — changes ONLY performance-facing knobs: engine results are
    bit-identical with adaptivity on and off.
"""

import dataclasses
import pickle

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs.snn_mnist import SNN_CONFIG, SNN_CONFIG_DEEP
from repro.core import prng, snn
from repro.core.telemetry import (concat_telemetry, layer_tile_skips,
                                  resolve_density_threshold, tiles_total)
from repro.kernels import ops, ref
from repro.serve import (AdaptiveDispatchConfig, SNNStreamEngine,
                         TelemetryController, summarize_chunk)
from repro.serve.telemetry import make_controller

_TEL_FIELDS = ("n_spk", "n_en", "tiles_skipped")


def _net(rng, sizes):
    return {"layers": [
        {"w_q": jnp.asarray(rng.integers(-256, 256, (a, b)), jnp.int16),
         "scale": jnp.float32(1.0)}
        for a, b in zip(sizes[:-1], sizes[1:])]}


# ---------------------------------------------------------------------------
# telemetry invariants
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("prune", [False, True])
def test_telemetry_adds_equal_energy_counters(rng, prune):
    """Σ_layers telemetry adds == the frozen active_adds channel, every
    backend — the invariant that keeps the side channel honest instead of
    being a second, separately-buggy accounting."""
    cfg = dataclasses.replace(SNN_CONFIG_DEEP, num_steps=6,
                              active_pruning=prune)
    params_q = _net(rng, cfg.layer_sizes)
    px = jnp.asarray(rng.integers(0, 256, (5, cfg.n_in), dtype=np.uint8))
    state = prng.seed_state(13, px.shape)
    for backend in ("reference", "staged", "fused"):
        out = snn.snn_apply_int(params_q, px, state, cfg, backend=backend)
        tel = out["telemetry"]
        np.testing.assert_array_equal(
            np.asarray(tel.adds).sum(axis=1),
            np.asarray(out["active_adds"]), err_msg=backend)


@pytest.mark.parametrize("sparse_skip", [False, True])
def test_telemetry_bit_identical_across_backends(rng, sparse_skip):
    """fused == fused(streamed init path) == staged == reference on every
    telemetry leaf — including nonzero tile-skip counts (sparse input)."""
    cfg = dataclasses.replace(SNN_CONFIG_DEEP, num_steps=7,
                              active_pruning=True, sparse_skip=sparse_skip)
    params_q = _net(rng, cfg.layer_sizes)
    # very sparse pixels → zero-spike K-tiles actually occur
    px = jnp.asarray(np.minimum(rng.integers(0, 256, (4, cfg.n_in)), 3)
                     .astype(np.uint8))
    state = prng.seed_state(29, px.shape)
    outs = {b: snn.snn_apply_int(params_q, px, state, cfg, backend=b)
            for b in ("reference", "staged", "fused")}
    for f in _TEL_FIELDS:
        a = np.asarray(getattr(outs["reference"]["telemetry"], f))
        for b in ("staged", "fused"):
            np.testing.assert_array_equal(
                a, np.asarray(getattr(outs[b]["telemetry"], f)),
                err_msg=f"{f} on {b}")
    for lx in range(len(cfg.layer_sizes) - 1):
        a = np.asarray(outs["reference"]["v_peak"][lx])
        for b in ("staged", "fused"):
            np.testing.assert_array_equal(
                a, np.asarray(outs[b]["v_peak"][lx]),
                err_msg=f"v_peak[{lx}] on {b}")
    skipped = int(np.asarray(outs["fused"]["telemetry"].tiles_skipped).sum())
    if sparse_skip:
        assert skipped > 0, "sparse input should skip some tiles"
    else:
        assert skipped == 0, "dense mode must report zero skipped tiles"


@settings(max_examples=6, deadline=None)
@given(seed=st.integers(1, 2**31), n_chunks=st.integers(1, 4),
       backend=st.sampled_from(["fused", "fused_streamed", "reference"]))
def test_telemetry_chunk_split_property(seed, n_chunks, backend):
    """Property: telemetry concatenated over any random split of the
    window == the one-shot record, and == the reference record — on the
    resident AND weight-streamed kernels and the jnp scan."""
    rng = np.random.default_rng(seed % (2**31))
    cfg = dataclasses.replace(SNN_CONFIG_DEEP, num_steps=8,
                              sparse_skip=True)
    params_q = _net(rng, cfg.layer_sizes)
    px = jnp.asarray(np.minimum(rng.integers(0, 256, (4, cfg.n_in)), 20)
                     .astype(np.uint8))
    state0 = prng.seed_state(seed, px.shape)
    T = cfg.num_steps
    cuts = sorted(rng.choice(np.arange(1, T), size=min(n_chunks - 1, T - 1),
                             replace=False).tolist()) if n_chunks > 1 else []
    bounds = [0] + cuts + [T]

    def run(be, splits):
        st_ = snn.snn_window_init(params_q, state0, cfg)
        tels = []
        for lo, hi in zip(splits[:-1], splits[1:]):
            st_, out = snn.snn_window_chunk(params_q, px, st_, cfg,
                                            chunk_steps=hi - lo, backend=be)
            tels.append(out["telemetry"])
        return st_, concat_telemetry(tels)

    _, one_shot = run(backend, [0, T])
    chunk_state, chunked = run(backend, bounds)
    _, ref_tel = run("reference", [0, T])
    for f in _TEL_FIELDS:
        np.testing.assert_array_equal(
            np.asarray(getattr(chunked, f)), np.asarray(getattr(one_shot, f)),
            err_msg=f"{f} split={bounds} backend={backend}")
        np.testing.assert_array_equal(
            np.asarray(getattr(chunked, f)), np.asarray(getattr(ref_tel, f)),
            err_msg=f"{f} vs reference backend={backend}")


def test_tile_skip_mirror_matches_ref_oracle(rng):
    """core.telemetry.layer_tile_skips (kernel-geometry mirror) and
    kernels.ref.tile_skips_ref (independently re-derived oracle) agree —
    the double-entry check that a silent geometry change cannot pass."""
    for b, n_in, n_out in ((1, 32, 10), (5, 300, 140), (9, 784, 10),
                           (16, 256, 256)):
        x = rng.random((b, n_in)) < 0.02
        en = rng.random((b, n_out)) < 0.5
        en[:, : min(128, n_out)] = False        # a fully-pruned tile
        for ss in (False, True):
            got = np.asarray(layer_tile_skips(
                jnp.asarray(x), jnp.asarray(en), sparse_skip=ss))
            want = np.asarray(ref.tile_skips_ref(
                jnp.asarray(x), jnp.asarray(en), sparse_skip=ss))
            np.testing.assert_array_equal(got, want,
                                          err_msg=f"{b}x{n_in}x{n_out}")
    # sanity: totals are bounded by the static tile grid
    tt = tiles_total((300, 140, 10))
    assert tt == (3 * 2, 2 * 1)


# ---------------------------------------------------------------------------
# dispatch threshold resolution + spike_matmul telemetry
# ---------------------------------------------------------------------------

def test_density_threshold_resolution(monkeypatch):
    """Explicit config value → env override → the historical constant."""
    monkeypatch.delenv("REPRO_SPIKE_DENSITY_THRESHOLD", raising=False)
    assert resolve_density_threshold(None) == ops.SPIKE_DENSITY_THRESHOLD
    monkeypatch.setenv("REPRO_SPIKE_DENSITY_THRESHOLD", "0.4")
    assert resolve_density_threshold(None) == 0.4
    assert resolve_density_threshold(0.1) == 0.1        # explicit wins
    cfg = dataclasses.replace(SNN_CONFIG, spike_density_threshold=0.33)
    assert resolve_density_threshold(cfg.spike_density_threshold) == 0.33


def test_spike_matmul_threshold_and_telemetry(rng):
    """The dispatch boundary is honored and reported: threshold 1.0 forces
    the masked kernel, 0.0 forces MXU, and the result never changes."""
    spikes = jnp.asarray((rng.random((6, 96)) < 0.3).astype(np.uint8))
    w = jnp.asarray(rng.integers(-256, 256, (96, 40)), jnp.int16)
    want = np.asarray(ref.spike_matmul_ref(spikes, w))
    outs = {}
    for thr in (1.0, 0.0):
        out, tel = ops.spike_matmul_op(spikes, w, mode="auto",
                                       density_threshold=thr,
                                       with_telemetry=True, interpret=True)
        outs[thr] = np.asarray(out)
        np.testing.assert_array_equal(outs[thr], want)
        assert bool(tel.used_masked) == (thr == 1.0)
        np.testing.assert_allclose(float(tel.density),
                                   float(np.mean(np.asarray(spikes) != 0)),
                                   rtol=1e-6)
    np.testing.assert_array_equal(outs[1.0], outs[0.0])


# ---------------------------------------------------------------------------
# adaptive controller
# ---------------------------------------------------------------------------

def test_frozen_controller_reproduces_static_choices(monkeypatch):
    """Frozen mode IS today's behavior: the static threshold and chunk
    length come back verbatim and observations are no-ops."""
    monkeypatch.delenv("REPRO_ADAPTIVE_DISPATCH", raising=False)
    monkeypatch.delenv("REPRO_SPIKE_DENSITY_THRESHOLD", raising=False)
    ctl = make_controller(None, spike_density_threshold=None,
                          chunk_steps=4, num_steps=20)
    assert ctl.frozen
    assert ctl.dispatch_threshold == ops.SPIKE_DENSITY_THRESHOLD
    assert ctl.chunk_steps == 4 and ctl.min_chunk_steps == 4
    ctl.observe(None)           # frozen observe never touches the summary
    assert ctl.history == [] and ctl.density_ewma is None
    ctl2 = make_controller(None, spike_density_threshold=0.4,
                           chunk_steps=6, num_steps=20)
    assert ctl2.dispatch_threshold == 0.4 and ctl2.chunk_steps == 6


def test_adaptive_controller_tracks_density_and_retunes():
    """Deterministic control law: the EWMA converges toward the observed
    density, the threshold follows it within bounds, and the chunk length
    shrinks under retirement pressure / grows in quiet steady state."""
    cfg = AdaptiveDispatchConfig(adaptive=True, ewma_alpha=0.5,
                                 min_chunk_steps=2, max_chunk_steps=8,
                                 grow_patience=2)
    ctl = TelemetryController(cfg=cfg, static_threshold=0.25,
                              static_chunk_steps=4, num_steps=20)

    def summary(density, retired, active):
        from repro.serve import ChunkSummary
        return ChunkSummary(density_in=density, layer_densities=(density,),
                            executed_adds=0, tiles_skipped=0,
                            lanes_retired=retired, lanes_active=active,
                            active_lane_steps=max(1, active) * 4)

    for _ in range(8):
        ctl.observe(summary(0.04, retired=4, active=8))
    assert abs(ctl.density_ewma - 0.04) < 1e-3
    # gain 1.5 × 0.04 = 0.06 — the boundary walked down toward the traffic
    assert 0.05 <= ctl.dispatch_threshold < 0.25
    assert ctl.chunk_steps == cfg.min_chunk_steps   # retirement pressure
    for _ in range(10):
        ctl.observe(summary(0.04, retired=0, active=8))
    assert ctl.chunk_steps > cfg.min_chunk_steps    # quiet → grow
    assert len(ctl.history) == 18
    # trajectory is replayable: same observations → same decisions
    ctl2 = TelemetryController(cfg=cfg, static_threshold=0.25,
                               static_chunk_steps=4, num_steps=20)
    for _ in range(8):
        ctl2.observe(summary(0.04, retired=4, active=8))
    for _ in range(10):
        ctl2.observe(summary(0.04, retired=0, active=8))
    assert [h["chunk_steps"] for h in ctl2.history] == \
        [h["chunk_steps"] for h in ctl.history]


def test_summarize_chunk_measures_known_density(rng):
    """Constant-level pixels: the summary's density estimate must land on
    the analytic px/256 Poisson rate (occupancy-weighted)."""
    level = 128
    cfg = dataclasses.replace(SNN_CONFIG, num_steps=16)
    params_q = _net(rng, cfg.layer_sizes)
    px = jnp.full((4, cfg.n_in), level, jnp.uint8)
    state = prng.seed_state(3, px.shape)
    out = snn.snn_apply_int(params_q, px, state, cfg, backend="reference")
    steps = np.full((4,), cfg.num_steps, np.int32)
    s = summarize_chunk(out["telemetry"], cfg.layer_sizes,
                        steps_before=np.zeros((4,), np.int32),
                        steps_after=steps,
                        active_before=np.ones((4,), bool),
                        active_after=np.zeros((4,), bool))
    assert abs(s.density_in - level / 256) < 0.03
    assert s.lanes_retired == 4 and s.active_lane_steps == 4 * cfg.num_steps
    assert s.executed_adds == int(np.asarray(out["active_adds"]).sum())


@settings(max_examples=4, deadline=None)
@given(seed=st.integers(1, 2**31),
       level=st.sampled_from([0, 33, 128, 255]),
       patience=st.sampled_from([1, 2, 10_000]))
def test_adaptive_never_changes_predictions(seed, level, patience):
    """THE acceptance property: with the controller adaptive (live chunk
    lengths + threshold) every request's prediction, retirement step,
    spike registers and frozen add counter are bit-identical to frozen
    mode — adaptivity moves wall-clock only."""
    rng = np.random.default_rng(seed % (2**31))
    cfg = dataclasses.replace(SNN_CONFIG, num_steps=10)
    params_q = _net(rng, cfg.layer_sizes)
    imgs = np.minimum(rng.integers(0, 256, (6, cfg.n_in)),
                      level).astype(np.uint8)

    def run(adaptive):
        eng = SNNStreamEngine(params_q, cfg, batch_size=2, chunk_steps=4,
                              patience=patience, seed=seed,
                              backend="reference", adaptive=adaptive)
        ids = [eng.submit(im) for im in imgs]
        res = eng.run()
        return {i: (res[i].pred, res[i].steps, res[i].adds,
                    res[i].early_exit, tuple(res[i].spike_counts.tolist()))
                for i in ids}, eng

    frozen, _ = run(AdaptiveDispatchConfig(adaptive=False))
    adaptive, eng = run(AdaptiveDispatchConfig(adaptive=True,
                                               min_chunk_steps=2,
                                               max_chunk_steps=7,
                                               grow_patience=1))
    assert adaptive == frozen
    assert not eng.controller.frozen
    assert len(eng.controller.history) > 0


# ---------------------------------------------------------------------------
# controller edges: zero-signal chunks, clamp bounds, pickle determinism
# ---------------------------------------------------------------------------

def _summary(density, retired, active, lane_steps=None):
    from repro.serve import ChunkSummary
    return ChunkSummary(
        density_in=density, layer_densities=(density,), executed_adds=0,
        tiles_skipped=0, lanes_retired=retired, lanes_active=active,
        active_lane_steps=(active * 4 if lane_steps is None else lane_steps))


def test_summarize_chunk_all_frozen_lanes_no_blowup():
    """A chunk dispatched with every lane already frozen consumes zero
    lane-steps — densities must come back exactly 0.0 (finite, no
    division blow-up), not NaN/inf from a 0/0."""
    from repro.core.telemetry import ChunkTelemetry
    chunk, L, B = 3, 2, 4
    tel = ChunkTelemetry(
        n_spk=jnp.zeros((chunk, L, B), jnp.int32),
        n_en=jnp.zeros((chunk, L, B), jnp.int32),
        tiles_skipped=jnp.zeros((chunk, L, 1), jnp.int32))
    s = summarize_chunk(tel, (784, 128, 10),
                        steps_before=np.full((B,), 5, np.int32),
                        steps_after=np.full((B,), 5, np.int32),
                        active_before=np.zeros((B,), bool),
                        active_after=np.zeros((B,), bool))
    assert s.active_lane_steps == 0 and s.lanes_active == 0
    assert s.density_in == 0.0 and all(np.isfinite(s.layer_densities))
    assert s.executed_adds == 0 and s.lanes_retired == 0


def test_zero_signal_chunks_leave_estimator_untouched():
    """Zero-lane-step / zero-active observations carry no information:
    the EWMA, threshold and chunk length must not move (in particular the
    retirement fraction 0/0 must not be computed)."""
    cfg = AdaptiveDispatchConfig(adaptive=True, ewma_alpha=0.5)
    ctl = TelemetryController(cfg=cfg, static_threshold=0.25,
                              static_chunk_steps=4, num_steps=20)
    ctl.observe(_summary(0.1, retired=0, active=8))
    ewma, thr, chunk, quiet = (ctl.density_ewma, ctl.dispatch_threshold,
                               ctl.chunk_steps, ctl._quiet)
    for _ in range(5):
        ctl.observe(_summary(0.0, retired=0, active=0, lane_steps=0))
    assert ctl.density_ewma == ewma
    assert ctl.dispatch_threshold == thr and ctl.chunk_steps == chunk
    assert ctl._quiet == quiet      # empty chunks are not "quiet traffic"


def test_chunk_length_clamps_at_bounds():
    """Sustained pressure can never walk the chunk length past its
    configured bounds, and the dispatched length is additionally capped
    by the window itself (num_steps)."""
    cfg = AdaptiveDispatchConfig(adaptive=True, min_chunk_steps=2,
                                 max_chunk_steps=12, grow_patience=1)
    ctl = TelemetryController(cfg=cfg, static_threshold=0.25,
                              static_chunk_steps=4, num_steps=8)
    for _ in range(50):             # retirement storm, far past the clamp
        ctl.observe(_summary(0.1, retired=8, active=8))
    assert ctl._chunk == cfg.min_chunk_steps
    assert ctl.chunk_steps == cfg.min_chunk_steps
    for _ in range(50):             # quiet steady state, far past the clamp
        ctl.observe(_summary(0.1, retired=0, active=8))
    assert ctl._chunk == cfg.max_chunk_steps
    assert ctl.chunk_steps == min(cfg.max_chunk_steps, ctl.num_steps) == 8
    # threshold clamp: an absurd density pins at threshold_max, silence
    # at threshold_min
    for _ in range(20):
        ctl.observe(_summary(1.0, retired=0, active=8))
    assert ctl.dispatch_threshold == cfg.threshold_max
    for _ in range(200):
        ctl.observe(_summary(0.0, retired=0, active=8))
    assert ctl.dispatch_threshold == cfg.threshold_min


def test_controller_pickle_restore_determinism():
    """A controller pickled mid-trajectory and restored continues the
    exact decision sequence of the uninterrupted original — frozen mode
    stays static across the round-trip, adaptive mode replays."""
    def drive(ctl, summaries):
        for s in summaries:
            ctl.observe(s)
        return [(h["chunk_steps"], h["dispatch_threshold"])
                for h in ctl.history]

    traffic = ([_summary(0.05, retired=2, active=8)] * 6
               + [_summary(0.3, retired=0, active=8)] * 6)
    cfg = AdaptiveDispatchConfig(adaptive=True, ewma_alpha=0.5,
                                 min_chunk_steps=2, max_chunk_steps=8,
                                 grow_patience=2)
    a = TelemetryController(cfg=cfg, static_threshold=0.25,
                            static_chunk_steps=4, num_steps=20)
    full = drive(a, traffic)
    b = TelemetryController(cfg=cfg, static_threshold=0.25,
                            static_chunk_steps=4, num_steps=20)
    drive(b, traffic[:5])
    b2 = pickle.loads(pickle.dumps(b))
    assert (b2.density_ewma, b2._chunk, b2._quiet) == \
        (b.density_ewma, b._chunk, b._quiet)
    resumed = drive(b2, traffic[5:])
    assert resumed == full
    # frozen controller: the round-trip preserves the static choices and
    # observe stays a no-op
    f = make_controller(AdaptiveDispatchConfig(adaptive=False),
                        spike_density_threshold=0.4, chunk_steps=6,
                        num_steps=20)
    f2 = pickle.loads(pickle.dumps(f))
    assert f2.frozen and f2.dispatch_threshold == 0.4
    assert f2.chunk_steps == 6 and f2.min_chunk_steps == 6
    f2.observe(None)
    assert f2.history == [] and f2.density_ewma is None
