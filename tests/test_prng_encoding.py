"""xorshift32 bit-exactness + Poisson-encoder statistics (paper §III-C),
including hypothesis property tests on the encoding invariants."""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core import encoding, prng


def numpy_xorshift32(x: np.ndarray, steps: int):
    x = x.astype(np.uint32).copy()
    outs = []
    for _ in range(steps):
        x ^= (x << np.uint32(13)) & np.uint32(0xFFFFFFFF)
        x ^= x >> np.uint32(17)
        x ^= (x << np.uint32(5)) & np.uint32(0xFFFFFFFF)
        outs.append(x.copy())
    return np.stack(outs)


def test_xorshift32_bit_exact_vs_numpy():
    seeds = np.array([1, 2, 0xDEADBEEF, 0x9E3779B9, 2**32 - 1], np.uint32)
    want = numpy_xorshift32(seeds, 64)
    _, got = prng.xorshift32_sequence(jnp.asarray(seeds), 64)
    np.testing.assert_array_equal(np.asarray(got), want)


def test_known_xorshift32_sequence():
    # canonical Marsaglia 13/17/5 from seed 1: first value is 270369
    _, seq = prng.xorshift32_sequence(jnp.asarray([1], jnp.uint32), 3)
    assert int(seq[0, 0]) == 270369


def test_zero_seed_is_remapped():
    s = prng.seed_state(0, (4,))
    assert (np.asarray(s) != 0).all()


def test_xorshift_period_no_short_cycles():
    """No state revisits within 10k steps (period is 2^32-1)."""
    _, seq = prng.xorshift32_sequence(jnp.asarray([12345], jnp.uint32), 10000)
    vals = np.asarray(seq).ravel()
    assert len(np.unique(vals)) == len(vals)


def test_encoder_rate_tracks_intensity():
    """P(spike) ≈ I/256 — the paper's rate-coding contract."""
    levels = np.array([0, 32, 64, 128, 200, 255], np.uint8)
    px = jnp.asarray(np.repeat(levels, 200).reshape(-1))
    state = prng.seed_state(7, px.shape)
    spikes, _ = encoding.poisson_encode_hw(px, state, 400)
    rate = np.asarray(encoding.spike_train_rates(spikes)).reshape(6, 200).mean(1)
    want = levels / 256.0
    np.testing.assert_allclose(rate, want, atol=0.02)
    assert rate[0] == 0.0                      # intensity 0 never spikes
    # monotone in intensity
    assert (np.diff(rate) >= -0.005).all()


@settings(max_examples=30, deadline=None)
@given(intensity=st.integers(0, 255), seed=st.integers(1, 2**31))
def test_encoding_spike_probability_property(intensity, seed):
    """For any intensity & seed: empirical rate within 5σ of I/256."""
    n, t = 64, 64
    px = jnp.full((n,), intensity, jnp.uint8)
    state = prng.seed_state(seed, (n,))
    spikes, _ = encoding.poisson_encode_hw(px, state, t)
    rate = float(np.asarray(spikes).mean())
    p = intensity / 256.0
    sigma = max((p * (1 - p) / (n * t)) ** 0.5, 1e-6)
    assert abs(rate - p) <= 5 * sigma + 1e-9


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(1, 2**31))
def test_encoder_state_continuation(seed):
    """Encoding 2×T steps == encoding T then continuing from the state."""
    px = jnp.asarray(np.arange(32) * 8, jnp.uint8)
    s0 = prng.seed_state(seed, px.shape)
    full, _ = encoding.poisson_encode_hw(px, s0, 16)
    a, s_mid = encoding.poisson_encode_hw(px, s0, 8)
    b, _ = encoding.poisson_encode_hw(px, s_mid, 8)
    np.testing.assert_array_equal(np.asarray(full),
                                  np.concatenate([a, b], axis=0))


def test_hw_and_jax_encoders_same_distribution():
    px01 = jnp.linspace(0, 1, 256)
    import jax
    sp = encoding.poisson_encode_jax(px01, jax.random.PRNGKey(0), 512)
    rate = np.asarray(sp.mean(axis=0))
    np.testing.assert_allclose(rate, np.asarray(px01), atol=0.08)
