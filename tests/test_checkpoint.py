"""Checkpoint manager: roundtrip, atomicity, integrity, resume, GC."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (CheckpointManager, latest_step, restore_pytree,
                              save_pytree)


def make_tree(key):
    return {
        "a": jax.random.normal(key, (8, 16)),
        "nested": {"b": jnp.arange(12, dtype=jnp.int32),
                   "c": jnp.float32(3.5)},
    }


def assert_trees_equal(a, b):
    flat_a, flat_b = jax.tree.leaves(a), jax.tree.leaves(b)
    for x, y in zip(flat_a, flat_b):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_roundtrip(tmp_path):
    tree = make_tree(jax.random.PRNGKey(0))
    d = str(tmp_path / "ck")
    save_pytree(tree, d)
    got = restore_pytree(tree, d)
    assert_trees_equal(tree, got)


def test_atomic_no_tmp_left(tmp_path):
    tree = make_tree(jax.random.PRNGKey(0))
    d = str(tmp_path / "ck")
    save_pytree(tree, d)
    assert not os.path.exists(d + ".tmp")
    assert os.path.exists(os.path.join(d, "manifest.json"))


def test_overwrite_is_atomic(tmp_path):
    t1 = make_tree(jax.random.PRNGKey(0))
    t2 = make_tree(jax.random.PRNGKey(1))
    d = str(tmp_path / "ck")
    save_pytree(t1, d)
    save_pytree(t2, d)
    assert_trees_equal(t2, restore_pytree(t1, d))


def test_corruption_detected(tmp_path):
    tree = make_tree(jax.random.PRNGKey(0))
    d = str(tmp_path / "ck")
    save_pytree(tree, d)
    with open(os.path.join(d, "manifest.json")) as f:
        first = json.load(f)["leaves"]["a"]["shards"][0]["file"]
    with open(os.path.join(d, first), "r+b") as f:
        f.seek(200)
        f.write(b"\xff\xff\xff")
    with pytest.raises(IOError, match="checksum"):
        restore_pytree(tree, d)


def test_manager_async_save_restore_and_gc(tmp_path):
    mgr = CheckpointManager(str(tmp_path), max_to_keep=2)
    tree = make_tree(jax.random.PRNGKey(0))
    for step in (10, 20, 30):
        t = jax.tree.map(lambda x: x + step, tree)
        mgr.save(step, t)
    mgr.wait()
    assert latest_step(str(tmp_path)) == 30
    got, step = mgr.restore(tree)
    assert step == 30
    assert_trees_equal(got, jax.tree.map(lambda x: x + 30, tree))
    kept = sorted(os.listdir(str(tmp_path)))
    assert kept == ["step_20", "step_30"]       # GC kept last 2


def test_restore_with_shardings_elastic(tmp_path):
    """Restore onto an explicit sharding (single-device 'new mesh')."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.distributed.sharding import make_device_mesh
    tree = make_tree(jax.random.PRNGKey(0))
    d = str(tmp_path / "ck")
    save_pytree(tree, d)
    mesh = make_device_mesh((1,), ("data",))
    sh = jax.tree.map(lambda x: NamedSharding(mesh, P()), tree)
    got = restore_pytree(tree, d, shardings=sh)
    assert_trees_equal(tree, got)
    assert all(l.sharding == NamedSharding(mesh, P())
               for l in jax.tree.leaves(got))


def test_train_resume_bit_identical(tmp_path):
    """Crash + restore ⇒ identical continuation (fault-tolerance contract)."""
    from repro.configs import get_reduced
    from repro.train import TrainLoop, TrainSettings, init_state
    from repro.train.step import make_train_step

    cfg = get_reduced("qwen3-4b")
    s = TrainSettings(learning_rate=1e-3)
    key = jax.random.PRNGKey(0)
    state = init_state(key, cfg, s)
    step = jax.jit(make_train_step(cfg, s))

    def batches():
        k = jax.random.PRNGKey(42)
        while True:
            k, sub = jax.random.split(k)
            toks = jax.random.randint(sub, (2, 17), 0, cfg.vocab_size)
            yield {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    mgr = CheckpointManager(str(tmp_path / "run"))
    loop = TrainLoop(step, state, ckpt_manager=mgr, ckpt_every=2)
    with pytest.raises(RuntimeError, match="injected failure"):
        loop.run(batches(), 10, fail_at_step=4)
    mgr.wait()

    # uninterrupted reference: 6 steps straight
    ref_state = init_state(key, cfg, s)
    gen = batches()
    for _ in range(6):
        ref_state, _ = step(ref_state, next(gen))

    # resume from step-4 checkpoint, replay the stream from step 4
    restored, at = mgr.restore(state)
    assert at == 4
    gen2 = batches()
    for _ in range(4):
        next(gen2)                      # data pipeline skips replayed steps
    loop2 = TrainLoop(step, restored)
    final = loop2.run(gen2, 2)
    for a, b in zip(jax.tree.leaves(ref_state.params),
                    jax.tree.leaves(final.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
