"""Per-kernel validation: Pallas (interpret mode) vs the pure-jnp oracles.

Each kernel is swept over shapes/dtypes and asserted EXACTLY equal to
ref.py (all three kernels are integer/bitwise datapaths — no tolerance)."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.core import prng


def _pixels_state(rng, b, n):
    px = jnp.asarray(rng.integers(0, 256, (b, n), dtype=np.uint8))
    st = prng.seed_state(1234, (b, n))
    return px, st


@pytest.mark.parametrize("b,n,t", [
    (1, 784, 5), (8, 784, 20), (3, 100, 7), (16, 128, 1), (5, 1, 3),
])
def test_poisson_encode_matches_ref(rng, b, n, t):
    px, st = _pixels_state(rng, b, n)
    got_s, got_st = ops.poisson_encode_op(px, st, t, interpret=True)
    want_s, want_st = ref.poisson_encode_ref(px, st, t)
    np.testing.assert_array_equal(np.asarray(got_s), np.asarray(want_s))
    np.testing.assert_array_equal(np.asarray(got_st), np.asarray(want_st))


@pytest.mark.parametrize("b,n_in,n_out,t,shift,prune", [
    (4, 784, 10, 20, 4, False),
    (4, 784, 10, 20, 4, True),
    (2, 64, 128, 8, 2, False),
    (1, 32, 10, 5, 6, True),
    (9, 100, 200, 3, 4, False),
])
def test_lif_forward_matches_ref(rng, b, n_in, n_out, t, shift, prune):
    spikes = jnp.asarray(rng.integers(0, 2, (t, b, n_in), dtype=np.uint8))
    w = jnp.asarray(rng.integers(-128, 128, (n_in, n_out), dtype=np.int16))
    got = ops.lif_forward_op(spikes, w, decay_shift=shift, v_threshold=128,
                             active_pruning=prune, interpret=True)
    want = ref.lif_forward_ref(spikes, w, decay_shift=shift, v_threshold=128,
                               active_pruning=prune)
    for g, we in zip(got, want):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(we))


@pytest.mark.parametrize("mode", ["masked", "mxu"])
@pytest.mark.parametrize("b,n_in,n_out", [
    (8, 784, 10), (4, 256, 384), (1, 300, 7), (16, 64, 128),
])
def test_spike_matmul_matches_ref(rng, mode, b, n_in, n_out):
    spikes = jnp.asarray(rng.integers(0, 2, (b, n_in), dtype=np.uint8))
    w = jnp.asarray(rng.integers(-128, 128, (n_in, n_out), dtype=np.int8))
    got = ops.spike_matmul_op(spikes, w, mode=mode, interpret=True)
    want = ref.spike_matmul_ref(spikes, w)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_spike_matmul_modes_agree(rng):
    spikes = jnp.asarray(rng.integers(0, 2, (8, 512), dtype=np.uint8))
    w = jnp.asarray(rng.integers(-100, 100, (512, 64), dtype=np.int8))
    a = ops.spike_matmul_op(spikes, w, mode="masked", interpret=True)
    b = ops.spike_matmul_op(spikes, w, mode="mxu", interpret=True)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_lif_kernel_weight_dtypes(rng):
    spikes = jnp.asarray(rng.integers(0, 2, (6, 4, 96), dtype=np.uint8))
    for dt in (np.int8, np.int16):
        w = jnp.asarray(rng.integers(-100, 100, (96, 24), dtype=dt))
        got = ops.lif_forward_op(spikes, w, decay_shift=3, v_threshold=64,
                                 interpret=True)
        want = ref.lif_forward_ref(spikes, w, decay_shift=3, v_threshold=64)
        np.testing.assert_array_equal(np.asarray(got[0]), np.asarray(want[0]))
