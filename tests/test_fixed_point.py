"""Quantization invariants (paper §III-A fixed-point datapath), with
hypothesis property tests."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st
from hypothesis.extra import numpy as hnp

from repro.core import fixed_point as fp


@settings(max_examples=50, deadline=None)
@given(w=hnp.arrays(np.float32, hnp.array_shapes(min_dims=1, max_dims=3,
                                                 max_side=16),
                    elements=st.floats(-100, 100, width=32)),
       bits=st.sampled_from([4, 8, 9, 16]))
def test_quantize_roundtrip_error_bounded(w, bits):
    qp = fp.QuantParams(bits=bits)
    q, scale = fp.quantize(jnp.asarray(w), qp)
    deq = np.asarray(fp.dequantize(q, scale))
    # |w - deq| <= scale/2 within the representable range
    err = np.abs(w - deq)
    assert (err <= np.asarray(scale) * 0.5 + 1e-6).all()


@settings(max_examples=50, deadline=None)
@given(bits=st.sampled_from([4, 8, 16]))
def test_quantize_codes_in_range(bits):
    rng = np.random.default_rng(0)
    w = rng.normal(0, 3, (32, 16)).astype(np.float32)
    qp = fp.QuantParams(bits=bits)
    q, _ = fp.quantize(jnp.asarray(w), qp)
    q = np.asarray(q)
    assert q.min() >= qp.qmin and q.max() <= qp.qmax


def test_stochastic_rounding_unbiased():
    w = jnp.full((20000,), 0.3)          # between two codes
    qp = fp.QuantParams(bits=8)
    scale = jnp.asarray(0.1)             # codes 3.0 and 4.0 * 0.1
    q, _ = fp.quantize_stochastic(w, qp, jax.random.PRNGKey(0), scale)
    mean = float(np.asarray(q).mean() * 0.1)
    assert abs(mean - 0.3) < 0.005       # E[deq] == w


def test_fake_quant_straight_through_gradient():
    w = jnp.linspace(-1, 1, 64)
    g = jax.grad(lambda x: jnp.sum(fp.fake_quant(x, 8) * 3.0))(w)
    np.testing.assert_allclose(np.asarray(g), 3.0, rtol=1e-6)


def test_int8_matmul_matches_float():
    rng = np.random.default_rng(1)
    x = rng.normal(0, 1, (8, 64)).astype(np.float32)
    w = rng.normal(0, 1, (64, 32)).astype(np.float32)
    qp = fp.QuantParams(bits=8)
    xq, xs = fp.quantize(jnp.asarray(x), qp)
    wq, ws = fp.quantize(jnp.asarray(w), qp)
    got = np.asarray(fp.int8_matmul(xq, wq, xs, ws))
    want = x @ w
    # int8 quantization error ~ 1% relative on well-scaled data
    assert np.abs(got - want).mean() / np.abs(want).mean() < 0.05


def test_per_axis_scales():
    rng = np.random.default_rng(2)
    w = rng.normal(0, 1, (16, 4)).astype(np.float32) * np.array([1, 10, 100, 1000])
    qp = fp.QuantParams(bits=8, axis=1)
    q, scale = fp.quantize(jnp.asarray(w), qp)
    deq = np.asarray(fp.dequantize(q, scale))
    rel = np.abs(deq - w).max(axis=0) / np.abs(w).max(axis=0)
    assert (rel < 0.01).all()            # each column well-resolved
