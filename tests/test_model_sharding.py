"""Model-axis sharding of the neuron datapath: the 2-D (data × model)
mesh contracts.

Contracts under test:
  * **bit-identity across mesh shapes** — 4×1 (pure data), 1×4 (pure
    model), 2×2 (data × model) forced-host meshes all reproduce the
    single-device engine prediction-for-prediction AND
    telemetry-for-telemetry, for both the fused-gated path and the
    jnp-scan fallback, including mid-chunk retirement / re-admission
    (subprocess, same pattern as test_sharded_engine.py);
  * property: random window splits × random admission schedules on a 2-D
    mesh stay bit-identical to a one-shot single-device reference window
    (in-process — the model axis covers whatever devices exist: 1
    locally, real shards in the CI 4-device lane);
  * **failover placement-independence** (the PR-7 contract, extended):
    lanes snapshot from a model-sharded engine adopt onto a plain
    single-device engine and resume bit-exactly — the LaneState
    checkpoint never encodes the mesh it ran on;
  * **VMEM feasibility is per model shard**: SNN_CONFIG_WIDE
    (784-2048-2048-10) resolves to the VMEM-resident ``fused`` backend
    on a 4-way model axis where single-device resolution must fall back
    to ``fused_streamed``;
  * mesh/spec plumbing: ``make_2d_device_mesh`` validation,
    ``layer_shard_ways`` semantics (non-dividing layers replicate),
    ``stack_vmem_bytes(model_shards=1)`` bit-identical to the historical
    estimate, and the partition-spec helpers (lane state never shards on
    the model axis; weights shard columns only where ways > 1).
"""

import dataclasses
import json
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from jax.sharding import PartitionSpec as P

from repro.configs.snn_mnist import SNN_CONFIG, SNN_CONFIG_WIDE
from repro.core import prng, snn
from repro.kernels import fused_snn

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_sub(code: str, n_dev: int = 4) -> str:
    env = dict(os.environ,
               XLA_FLAGS=f"--xla_force_host_platform_device_count={n_dev}",
               JAX_PLATFORMS="cpu",
               PYTHONPATH=SRC)
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def small_net(rng, sizes):
    layers = []
    for a, b in zip(sizes[:-1], sizes[1:]):
        w = jnp.asarray(rng.integers(-256, 256, (a, b)), jnp.int16)
        layers.append({"w_q": w, "scale": jnp.float32(1.0)})
    return {"layers": layers}


SUB_PRELUDE = """
    import dataclasses, json
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs.snn_mnist import (SNN_CONFIG, SNNStreamMeshConfig,
                                         make_stream_engine)
    from repro.serve import ShardedSNNStreamEngine, SNNStreamEngine

    def small_net(rng, sizes):
        return {"layers": [
            {"w_q": jnp.asarray(rng.integers(-256, 256, (a, b)), jnp.int16),
             "scale": jnp.float32(1.0)}
            for a, b in zip(sizes[:-1], sizes[1:])]}

    def as_tuple(r):
        return (r.pred, r.steps, r.adds, r.early_exit,
                r.spike_counts.tolist())
"""


def test_mesh_shapes_bit_identical_to_single_device():
    """4×1 / 1×4 / 2×2 forced-host meshes vs the single-device engine,
    both backends, with mid-chunk retirement (patience=1) and enough load
    (20 images over 8 global lanes) to force re-admission.  The 1×4 case
    covers the mixed stack: the 16-wide hidden layer shards 4-way while
    the 10-class head does not divide and must replicate."""
    out = run_sub(SUB_PRELUDE + """
    assert len(jax.devices()) == 4, jax.devices()
    rng = np.random.default_rng(0)
    cfg = dataclasses.replace(SNN_CONFIG, layer_sizes=(24, 16, 10),
                              num_steps=10)
    params_q = small_net(rng, cfg.layer_sizes)
    imgs = rng.integers(0, 256, (20, 24), dtype=np.uint8)
    expect_ways = {1: (1, 1), 2: (2, 2), 4: (4, 1)}
    summary = {}
    for backend in ("reference", "fused"):
        ref = SNNStreamEngine(params_q, cfg, batch_size=8, chunk_steps=3,
                              patience=1, seed=11, backend=backend)
        for im in imgs:
            ref.submit(im)
        r1 = ref.run()
        for nd, md, lpd in ((4, 1, 2), (1, 4, 8), (2, 2, 4)):
            knobs = SNNStreamMeshConfig(num_devices=nd, model_devices=md,
                                        lanes_per_device=lpd, chunk_steps=3)
            eng = make_stream_engine(params_q, cfg, knobs, patience=1,
                                     seed=11, backend=backend)
            assert eng.model_devices == md
            assert eng.model_ways == expect_ways[md], eng.model_ways
            for im in imgs:
                eng.submit(im)
            r2 = eng.run()
            assert set(r1) == set(r2) == set(range(20)), (backend, nd, md)
            for rid in r1:
                assert as_tuple(r1[rid]) == as_tuple(r2[rid]), \\
                    (backend, nd, md, rid)
            summary[f"{backend}:{nd}x{md}"] = sum(
                r.early_exit for r in r2.values())
    print(json.dumps(summary))
    """)
    res = json.loads(out.strip().splitlines()[-1])
    # the stability gate actually fired on every mesh shape — the
    # identity above covered the pruning/compaction paths, not a no-op
    assert all(v > 0 for v in res.values()), res


def test_model_sharded_telemetry_bit_identical():
    """Telemetry-for-telemetry: per-lane spike/enable counts (and the
    per-lane executed adds) from a model-sharded step are bit-identical
    to the unsharded step — every model peer derives them from the full
    gathered spike vector.  The per-shard skipped-tile counts concatenate
    model-inner on the block axis; on 128-aligned shard widths
    (512/4 = 128 — the tile grid partitions exactly) they SUM to the
    unsharded layer's count, and a replicated layer's count appears once
    per peer, each copy equal to the unsharded value."""
    out = run_sub(SUB_PRELUDE + """
    from jax.sharding import PartitionSpec as P
    from repro.core import prng, snn
    from repro.core.lif import LIFStateInt
    from repro.distributed.sharding import (make_2d_device_mesh,
                                            shard_map_compat)
    from repro.kernels.fused_snn import layer_shard_ways

    rng = np.random.default_rng(1)
    sizes = (784, 512, 512, 10)
    cfg = dataclasses.replace(SNN_CONFIG, layer_sizes=sizes, num_steps=1,
                              active_pruning=True)
    params_q = small_net(rng, sizes)
    weights = tuple(jnp.asarray(l["w_q"], jnp.int32)
                    for l in params_q["layers"])
    B = 8
    pixels = jnp.asarray(rng.integers(0, 256, (B, sizes[0]), np.uint8))
    rng_state = prng.seed_state(3, (B, sizes[0]))
    states = tuple(LIFStateInt(v=jnp.zeros((B, n), jnp.int32),
                               enable=jnp.ones((B, n), bool))
                   for n in sizes[1:])

    # unsharded oracle
    _, st1, x1, adds1, tel1 = snn.snn_int_stack_step(
        rng_state, pixels, states, weights, cfg.lif, active_pruning=True)

    mesh = make_2d_device_mesh(1, 4)
    ways = layer_shard_ways(sizes, 4)
    assert ways == (4, 4, 1)

    def body(rng_state, pixels, states, weights):
        return snn.snn_int_stack_step_sharded(
            rng_state, pixels, states, weights, cfg.lif,
            model_axis="model", ways=ways, active_pruning=True,
            contraction="pallas", interpret=True)

    rep = P()
    w_specs = tuple(P(None, "model") if w > 1 else P() for w in ways)
    st_specs = tuple(LIFStateInt(v=rep, enable=rep) for _ in states)
    tel_spec = {"n_spk": rep, "n_en": rep,
                "tiles": P(None, ("data", "model"))}
    f = shard_map_compat(
        body, mesh,
        in_specs=(rep, rep, st_specs, w_specs),
        out_specs=(rep, st_specs, rep, rep, tel_spec))
    _, st2, x2, adds2, tel2 = f(rng_state, pixels, states, weights)

    assert (np.asarray(x1) == np.asarray(x2)).all()
    assert (np.asarray(adds1) == np.asarray(adds2)).all()
    for a, b in zip(st1, st2):
        assert (np.asarray(a.v) == np.asarray(b.v)).all()
        assert (np.asarray(a.enable) == np.asarray(b.enable)).all()
    # per-lane counts replicate bit-exactly over the model axis
    assert (np.asarray(tel1["n_spk"]) == np.asarray(tel2["n_spk"])).all()
    assert (np.asarray(tel1["n_en"]) == np.asarray(tel2["n_en"])).all()
    # tile counts: (L, nb) unsharded vs (L, nb*4) model-inner concat
    t1 = np.asarray(tel1["tiles"])
    t2 = np.asarray(tel2["tiles"])
    nb = t1.shape[1]
    assert t2.shape == (t1.shape[0], nb * 4)
    per_shard = t2.reshape(t1.shape[0], 4, nb)
    for l, w in enumerate(ways):
        if w > 1:     # 128-aligned shards partition the tile grid exactly
            assert (per_shard[l].sum(axis=0) == t1[l]).all(), l
        else:         # replicated: every peer counted the full layer
            assert (per_shard[l] == t1[l][None, :]).all(), l
    print("TEL_OK")
    """)
    assert "TEL_OK" in out


def test_failover_from_model_sharded_engine():
    """PR-7 placement-independence, extended to the model axis: lanes
    snapshot from a 2×2 (data × model) engine mid-window adopt onto a
    plain single-device engine and finish bit-identical to a run that
    never moved."""
    out = run_sub(SUB_PRELUDE + """
    rng = np.random.default_rng(4)
    cfg = dataclasses.replace(SNN_CONFIG, layer_sizes=(24, 16, 10),
                              num_steps=12)
    params_q = small_net(rng, cfg.layer_sizes)
    imgs = rng.integers(0, 256, (8, 24), dtype=np.uint8)

    base = SNNStreamEngine(params_q, cfg, batch_size=8, chunk_steps=3,
                           patience=10_000, seed=9, backend="reference")
    for im in imgs:
        base.submit(im)
    want = base.run()

    knobs = SNNStreamMeshConfig(num_devices=2, model_devices=2,
                                lanes_per_device=4, chunk_steps=3)
    src = make_stream_engine(params_q, cfg, knobs, patience=10_000,
                             seed=9, backend="reference")
    assert src.model_devices == 2 and src.model_ways == (2, 2)
    for im in imgs:
        src.submit(im)
    src.run(max_chunks=2)                 # mid-window: 6 of 12 steps done
    rows = src.snapshot_lanes()
    assert len(rows) == 8, len(rows)

    dst = SNNStreamEngine(params_q, cfg, batch_size=8, chunk_steps=3,
                          patience=10_000, seed=9, backend="reference")
    for rid, row in rows:
        dst.adopt(rid, row)
    got = dst.run()
    assert set(got) == set(want)
    for rid in want:
        assert as_tuple(got[rid]) == as_tuple(want[rid]), rid
    print("FAILOVER_OK")
    """)
    assert "FAILOVER_OK" in out


@settings(max_examples=4, deadline=None)
@given(seed=st.integers(0, 2**20), chunk_steps=st.integers(1, 8),
       burst=st.integers(1, 5),
       backend=st.sampled_from(["reference", "fused"]))
def test_random_admission_2d_mesh_matches_one_shot(seed, chunk_steps,
                                                   burst, backend):
    """Property: a random window split × a random admission schedule on a
    2-D (data × model) mesh retires every request bit-identical to a
    one-shot single-device reference window.  The model axis takes as
    many devices as are visible (capped at the hidden width's divisors):
    1 locally — the always-2-D mesh path with a trailing 1 axis — and a
    real 4-way shard in the CI multi-device lane."""
    from repro.configs.snn_mnist import SNNStreamMeshConfig, \
        make_stream_engine
    rng = np.random.default_rng(seed)
    n_dev = len(jax.devices())
    md = 4 if n_dev % 4 == 0 else (2 if n_dev % 2 == 0 else 1)
    cfg = dataclasses.replace(SNN_CONFIG, layer_sizes=(12, 8, 6),
                              num_steps=8)
    params_q = small_net(rng, cfg.layer_sizes)
    n_imgs = int(rng.integers(3, 9))
    imgs = rng.integers(0, 256, (n_imgs, 12), dtype=np.uint8)
    knobs = SNNStreamMeshConfig(num_devices=n_dev // md, model_devices=md,
                                lanes_per_device=2 * md,
                                chunk_steps=chunk_steps)
    eng = make_stream_engine(params_q, cfg, knobs, patience=10_000,
                             seed=seed, backend=backend)
    assert eng.model_devices == md
    submitted = 0
    for _ in range(n_imgs * (cfg.num_steps // chunk_steps + 2) + 4):
        take = min(int(rng.integers(0, burst + 1)), n_imgs - submitted)
        for im in imgs[submitted:submitted + take]:
            eng.submit(im)
        submitted += take
        eng.step()
        if submitted == n_imgs and eng.pending == 0:
            break
    results = eng.run()
    assert set(results) == set(range(n_imgs))
    for rid in range(n_imgs):
        out = snn.snn_apply_int(
            params_q, jnp.asarray(imgs[rid][None]),
            prng.seed_state(seed + rid, (1, cfg.n_in)), cfg,
            backend="reference")
        r = results[rid]
        assert r.pred == int(np.asarray(out["pred"])[0])
        np.testing.assert_array_equal(r.spike_counts,
                                      np.asarray(out["spike_counts"])[0])
        assert r.steps == cfg.num_steps and not r.early_exit
        assert r.adds == int(np.asarray(out["active_adds"]).sum())


# ---- feasibility: WIDE goes resident-fused on a 4-way model axis ----------

def test_wide_resolves_fused_on_model_axis(monkeypatch):
    """The acceptance stack: SNN_CONFIG_WIDE (784-2048-2048-10) exceeds
    the VMEM budget single-device (auto → fused_streamed, explicit fused
    raises) but each 4-way model shard fits, so auto resolves to the
    resident ``fused`` backend."""
    monkeypatch.setattr(jax, "default_backend", lambda: "tpu")
    cfg = SNN_CONFIG_WIDE
    n_layers = len(cfg.layer_sizes) - 1
    kw = dict(layer_sizes=cfg.layer_sizes, trace_steps=4, local_batch=256)
    assert snn.resolve_backend(cfg, "auto", n_layers,
                               **kw) == "fused_streamed"
    assert snn.resolve_backend(cfg, "auto", n_layers, model_shards=4,
                               **kw) == "fused"
    with pytest.raises(ValueError, match="VMEM"):
        snn.resolve_backend(cfg, "fused", n_layers, **kw)
    # the reason string names the model axis it was scoped to
    r = snn.fused_unsupported_reason(cfg, n_layers, cfg.layer_sizes,
                                     trace_steps=4, local_batch=256,
                                     model_shards=2)
    assert r is None or "2-way model axis" in r


def test_wide_shard_fits_vmem_budget():
    """The per-device weight shard of WIDE under a 4-way model axis stays
    inside the VMEM budget — the quantity the bench artifact commits."""
    sizes = SNN_CONFIG_WIDE.layer_sizes
    full = fused_snn.stack_vmem_bytes(sizes, num_steps=4)
    shard = fused_snn.stack_vmem_bytes(sizes, num_steps=4, model_shards=4)
    assert full > fused_snn.VMEM_BUDGET_BYTES
    assert shard <= fused_snn.VMEM_BUDGET_BYTES
    assert shard < full


def test_stack_vmem_bytes_unsharded_is_historical():
    """model_shards=1 must reproduce the historical estimate bit-for-bit
    — the resolution chain of every existing config is frozen."""
    for sizes in ((784, 10), (784, 128, 64, 10), (784, 2048, 2048, 10),
                  (12, 6), (300, 200, 100, 50)):
        for streamed in (False, True):
            a = fused_snn.stack_vmem_bytes(sizes, streamed=streamed)
            b = fused_snn.stack_vmem_bytes(sizes, streamed=streamed,
                                           model_shards=1)
            assert a == b, (sizes, streamed)


def test_layer_shard_ways():
    """Layers shard only where the model width divides the raw output
    size; everything replicates at model_shards<=1."""
    assert fused_snn.layer_shard_ways((784, 2048, 2048, 10), 4) == (4, 4, 1)
    assert fused_snn.layer_shard_ways((784, 2048, 2048, 10), 1) == (1, 1, 1)
    assert fused_snn.layer_shard_ways((24, 16, 10), 2) == (2, 2)
    assert fused_snn.layer_shard_ways((24, 15, 10), 2) == (1, 2)
    assert fused_snn.layer_shard_ways((784, 10), 0) == (1,)


# ---- mesh + partition-spec plumbing ---------------------------------------

def test_make_2d_device_mesh_validation():
    from repro.distributed.sharding import make_2d_device_mesh
    n = len(jax.devices())
    mesh = make_2d_device_mesh(n, 1)
    assert mesh.shape == {"data": n, "model": 1}
    mesh = make_2d_device_mesh(1, n, axis_names=("d", "m"))
    assert mesh.shape == {"d": 1, "m": n}
    # data_devices=None absorbs what the model axis leaves over
    mesh = make_2d_device_mesh(model_devices=n)
    assert mesh.shape == {"data": 1, "model": n}
    with pytest.raises(ValueError, match="distinct"):
        make_2d_device_mesh(1, 1, axis_names=("x", "x"))
    with pytest.raises(ValueError, match=">= 1"):
        make_2d_device_mesh(1, 0)
    with pytest.raises(ValueError, match="devices"):
        make_2d_device_mesh(n + 1, 1)
    with pytest.raises(ValueError, match="divide"):
        make_2d_device_mesh(model_devices=n + 1)


def test_weight_partition_specs():
    from repro.serve.snn_engine import weight_partition_specs
    assert weight_partition_specs((4, 4, 1), None) == (P(), P(), P())
    specs = weight_partition_specs((4, 4, 1), "model")
    assert specs == (P(None, "model"), P(None, "model"), P())


def test_lane_partition_specs_ignore_model_axis():
    """Placement-independence: lane state NEVER shards on the model axis
    — the same LaneState specs with or without one, which is what keeps
    snapshot/adopt rows mesh-agnostic (the failover contract)."""
    from repro.serve.snn_engine import lane_partition_specs
    a = lane_partition_specs(3, "data")
    b = lane_partition_specs(3, "data", model_axis="model")
    assert a == b
    leaves = jax.tree.leaves(b, is_leaf=lambda x: isinstance(x, P))
    assert all(s == P("data") for s in leaves)


def test_telemetry_partition_specs_model_axis():
    from repro.core.telemetry import telemetry_partition_specs
    t = telemetry_partition_specs("data")
    assert t.tiles_skipped == P(None, None, "data")
    t2 = telemetry_partition_specs("data", "model")
    assert t2.n_spk == t.n_spk and t2.n_en == t.n_en
    assert t2.tiles_skipped == P(None, None, ("data", "model"))


def test_engine_rejects_model_axis_equal_to_data_axis():
    from repro.serve import ShardedSNNStreamEngine
    rng = np.random.default_rng(0)
    params_q = small_net(rng, (12, 6))
    with pytest.raises(ValueError, match="differ"):
        ShardedSNNStreamEngine(params_q, SNN_CONFIG, axis_name="data",
                               model_axis_name="data",
                               backend="reference")


def test_partial_contraction_op_matches_dense():
    """The per-shard Pallas partial contraction is bit-identical to the
    dense integer contraction on the same operands, and its skip counter
    matches the jnp tile-geometry mirror."""
    from repro.core import lif
    from repro.core.telemetry import layer_tile_skips
    from repro.kernels import ops
    rng = np.random.default_rng(5)
    for B, n_in, n_out in ((4, 40, 24), (8, 200, 130), (3, 12, 6)):
        x = jnp.asarray(rng.random((B, n_in)) < 0.15)
        en = jnp.asarray(rng.random((B, n_out)) < 0.8)
        w = jnp.asarray(rng.integers(-256, 256, (n_in, n_out)), jnp.int32)
        cur, skipped = ops.partial_contraction_op(x, en, w,
                                                  sparse_skip=True)
        want = lif.synaptic_current_int(x, w, en)
        assert (np.asarray(cur) == np.asarray(want)).all(), (B, n_in)
        mirror = layer_tile_skips(x, en, sparse_skip=True)
        assert (np.asarray(skipped) == np.asarray(mirror)).all()
