"""Multi-layer fused megakernel + resumable (chunked) window execution.

Contracts under test:
  * the multi-layer stack kernel is bit-identical to its independent jnp
    oracle (ref.fused_snn_stack_ref) on hidden-layer topologies, with and
    without active pruning;
  * ``snn_apply_int`` produces identical results on all three backends for
    deep stacks — counts, traces, first-spike times AND the layer-summed
    executed-add energy counter;
  * chunked execution with carried state (``snn_window_chunk``) is
    bit-identical to one T-step launch for every split of the window, on
    both the fused and reference backends (property test);
  * the streaming engine runs multi-layer stacks end-to-end and matches
    the batch engine.
"""

import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs.snn_mnist import SNN_CONFIG_DEEP
from repro.core import prng, snn
from repro.kernels import ops, ref
from repro.serve import SNNStreamEngine

_KEYS = ["spike_counts", "v_trace", "first_spike_t", "v_final",
         "active_adds", "prng_state", "steps"]


def _deep_params(rng, sizes):
    layers = []
    for n_in, n_out in zip(sizes[:-1], sizes[1:]):
        w = jnp.asarray(rng.integers(-256, 256, (n_in, n_out)), jnp.int16)
        layers.append({"w_q": w, "scale": jnp.float32(1.0)})
    return {"layers": layers}


@pytest.mark.parametrize("sizes,t,prune", [
    ((784, 128, 10), 8, False),
    ((784, 128, 10), 8, True),
    ((96, 200, 64, 10), 6, False),
    ((50, 33, 17, 9), 5, True),
])
def test_stack_kernel_matches_ref(rng, sizes, t, prune):
    b = 5
    px = jnp.asarray(rng.integers(0, 256, (b, sizes[0]), dtype=np.uint8))
    state = prng.seed_state(3, (b, sizes[0]))
    weights = tuple(l["w_q"] for l in _deep_params(rng, sizes)["layers"])
    got = ops.fused_snn_stack_op(px, state, weights, num_steps=t,
                                 decay_shift=4, v_threshold=128,
                                 active_pruning=prune, interpret=True)
    want = ref.fused_snn_stack_ref(px, state, weights, num_steps=t,
                                   decay_shift=4, v_threshold=128,
                                   active_pruning=prune)
    for key in _KEYS:
        np.testing.assert_array_equal(np.asarray(got[key]),
                                      np.asarray(want[key]), err_msg=key)
    for l in range(len(weights)):
        np.testing.assert_array_equal(np.asarray(got["v"][l]),
                                      np.asarray(want["v"][l]),
                                      err_msg=f"v[{l}]")
        np.testing.assert_array_equal(np.asarray(got["en"][l]),
                                      np.asarray(want["en"][l]),
                                      err_msg=f"en[{l}]")


@pytest.mark.parametrize("prune", [False, True])
def test_multilayer_backends_bit_identical(rng, prune):
    """Deep stacks: fused == staged == reference on every output, incl.
    the layer-summed executed-add side channel."""
    cfg = dataclasses.replace(SNN_CONFIG_DEEP, num_steps=8,
                              active_pruning=prune)
    params_q = _deep_params(rng, cfg.layer_sizes)
    px = jnp.asarray(rng.integers(0, 256, (6, cfg.n_in), dtype=np.uint8))
    state = prng.seed_state(21, px.shape)
    outs = {b: snn.snn_apply_int(params_q, px, state, cfg, backend=b)
            for b in ("reference", "staged", "fused")}
    for key in ("pred", "spike_counts", "v_trace", "first_spike_t",
                "v_final", "prng_state", "active_adds"):
        a = np.asarray(outs["reference"][key])
        for b in ("staged", "fused"):
            np.testing.assert_array_equal(a, np.asarray(outs[b][key]),
                                          err_msg=f"{key} on {b}")
    # inter-layer spike tensors intentionally never exist on fused
    assert outs["fused"]["input_spikes"] is None


@settings(max_examples=8, deadline=None)
@given(n_chunks=st.integers(1, 5), seed=st.integers(1, 2**31),
       prune=st.sampled_from([False, True]),
       backend=st.sampled_from(["fused", "reference"]))
def test_chunked_equals_one_shot(n_chunks, seed, prune, backend):
    """Property: running the window in k chunks with carried state is
    bit-identical to one T-step launch — spike counts, first-spike times,
    membrane traces, the executed-add counter and the PRNG state all
    match, on both chunk-capable backends."""
    rng = np.random.default_rng(seed % (2**31))
    cfg = dataclasses.replace(SNN_CONFIG_DEEP, num_steps=10,
                              active_pruning=prune)
    params_q = _deep_params(rng, cfg.layer_sizes)
    px = jnp.asarray(rng.integers(0, 256, (4, cfg.n_in), dtype=np.uint8))
    state0 = prng.seed_state(seed, px.shape)
    T = cfg.num_steps

    # one shot
    full_state = snn.snn_window_init(params_q, state0, cfg)
    full_state, full = snn.snn_window_chunk(params_q, px, full_state, cfg,
                                            chunk_steps=T, backend=backend)

    # k chunks with carried state (random split of the window)
    cuts = sorted(rng.choice(np.arange(1, T), size=min(n_chunks - 1, T - 1),
                             replace=False).tolist()) if n_chunks > 1 else []
    bounds = [0] + cuts + [T]
    chunk_state = snn.snn_window_init(params_q, state0, cfg)
    traces, adds = [], []
    for lo, hi in zip(bounds[:-1], bounds[1:]):
        chunk_state, out = snn.snn_window_chunk(
            params_q, px, chunk_state, cfg, chunk_steps=hi - lo,
            backend=backend)
        traces.append(np.asarray(out["v_trace"]))
        adds.append(np.asarray(out["active_adds"]))

    for field in snn.SNNWindowState._fields:
        a, b = getattr(full_state, field), getattr(chunk_state, field)
        if isinstance(a, tuple):
            for l, (x, y) in enumerate(zip(a, b)):
                np.testing.assert_array_equal(
                    np.asarray(x), np.asarray(y),
                    err_msg=f"{field}[{l}] split={bounds}")
        else:
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                          err_msg=f"{field} split={bounds}")
    np.testing.assert_array_equal(np.concatenate(traces, axis=0),
                                  np.asarray(full["v_trace"]))
    np.testing.assert_array_equal(np.concatenate(adds, axis=0),
                                  np.asarray(full["active_adds"]))


def test_chunked_fused_matches_reference(rng):
    """Cross-backend: fused chunks and reference chunks walk through the
    identical state sequence."""
    cfg = dataclasses.replace(SNN_CONFIG_DEEP, num_steps=9)
    params_q = _deep_params(rng, cfg.layer_sizes)
    px = jnp.asarray(rng.integers(0, 256, (3, cfg.n_in), dtype=np.uint8))
    state0 = prng.seed_state(5, px.shape)
    states = {b: snn.snn_window_init(params_q, state0, cfg)
              for b in ("fused", "reference")}
    for chunk in (4, 3, 2):
        outs = {}
        for b in states:
            states[b], outs[b] = snn.snn_window_chunk(
                params_q, px, states[b], cfg, chunk_steps=chunk, backend=b)
        np.testing.assert_array_equal(np.asarray(outs["fused"]["v_trace"]),
                                      np.asarray(outs["reference"]["v_trace"]))
        np.testing.assert_array_equal(
            np.asarray(states["fused"].counts),
            np.asarray(states["reference"].counts))
        np.testing.assert_array_equal(np.asarray(states["fused"].rng),
                                      np.asarray(states["reference"].rng))


def test_chunked_rejects_staged_backend(rng):
    cfg = SNN_CONFIG_DEEP
    params_q = _deep_params(rng, cfg.layer_sizes)
    px = jnp.zeros((2, cfg.n_in), jnp.uint8)
    state = snn.snn_window_init(params_q, prng.seed_state(1, px.shape), cfg)
    with pytest.raises(ValueError, match="staged"):
        snn.snn_window_chunk(params_q, px, state, cfg, chunk_steps=2,
                             backend="staged")


def test_first_spike_readout_no_overflow_on_long_windows():
    """Regression: the first_spike score once multiplied (T - first) by
    2^24, which wraps int32 at T = 128 and made an early-spiking class
    score BELOW a silent class's membrane tiebreak."""
    counts = jnp.asarray([[1, 0]], jnp.int32)
    first = jnp.asarray([[0, 4096]], jnp.int32)       # class 0 spiked at t=0
    v_final = jnp.asarray([[0, (1 << 24) - 2]], jnp.int32)
    for T in (20, 128, 4096):
        pred = snn.readout_pred(counts, first, v_final, "first_spike", T)
        assert int(pred[0]) == 0, T


def test_stream_engine_multilayer_matches_batch_engine(rng):
    """A hidden-layer stack streams through the engine (fused chunk path,
    interpret mode on CPU) and reproduces the batch engine bit-for-bit
    when patience disables early exit."""
    cfg = dataclasses.replace(SNN_CONFIG_DEEP, num_steps=6)
    params_q = _deep_params(rng, cfg.layer_sizes)
    eng = SNNStreamEngine(params_q, cfg, batch_size=2, chunk_steps=4,
                          patience=10_000, seed=43, backend="fused")
    imgs = rng.integers(0, 256, (3, cfg.n_in), dtype=np.uint8)
    ids = [eng.submit(im) for im in imgs]
    results = eng.run()
    assert set(results) == set(ids)
    for rid in ids:
        r = results[rid]
        out = snn.snn_apply_int(params_q, jnp.asarray(imgs[rid][None]),
                                prng.seed_state(43 + rid, (1, cfg.n_in)),
                                cfg, backend="reference")
        assert r.pred == int(np.asarray(out["pred"])[0])
        np.testing.assert_array_equal(r.spike_counts,
                                      np.asarray(out["spike_counts"])[0])
        assert r.adds == int(np.asarray(out["active_adds"]).sum())
