"""Deterministic fallback for ``hypothesis`` when it is not installed.

The property tests in this suite (test_fixed_point, test_perf_variants,
test_prng_encoding) use a small hypothesis subset: ``given``, ``settings``,
``strategies.integers/sampled_from/floats`` and
``hypothesis.extra.numpy.arrays/array_shapes``.  CI installs the real
hypothesis (see pyproject.toml dev extras) and this file is inert; in
hermetic environments without it, :func:`install` registers a minimal
emulation under ``sys.modules['hypothesis']`` so the suite still collects
and the properties still execute — over a fixed-seed sample sweep instead
of hypothesis's adaptive search (no shrinking, no example database).
"""

from __future__ import annotations

import inspect
import sys
import types
import zlib

import numpy as np


class _Strategy:
    def __init__(self, sample):
        self._sample = sample

    def example(self, rng: np.random.Generator):
        return self._sample(rng)


def integers(min_value: int, max_value: int) -> _Strategy:
    return _Strategy(lambda rng: int(rng.integers(min_value, max_value + 1)))


def sampled_from(elements) -> _Strategy:
    elements = list(elements)
    return _Strategy(lambda rng: elements[int(rng.integers(len(elements)))])


def floats(min_value=0.0, max_value=1.0, *, width: int = 64,
           allow_nan: bool = False, allow_infinity: bool = False) -> _Strategy:
    def sample(rng):
        x = float(rng.uniform(min_value, max_value))
        return float(np.float32(x)) if width == 32 else x
    return _Strategy(sample)


def array_shapes(*, min_dims: int = 1, max_dims: int = 3, min_side: int = 1,
                 max_side: int = 16) -> _Strategy:
    def sample(rng):
        nd = int(rng.integers(min_dims, max_dims + 1))
        return tuple(int(s) for s in rng.integers(min_side, max_side + 1, nd))
    return _Strategy(sample)


def arrays(dtype, shape, *, elements: _Strategy | None = None) -> _Strategy:
    def sample(rng):
        shp = shape.example(rng) if isinstance(shape, _Strategy) else shape
        n = int(np.prod(shp)) if shp else 1
        if elements is None:
            flat = rng.standard_normal(n)
        else:
            flat = [elements.example(rng) for _ in range(n)]
        return np.asarray(flat, dtype=dtype).reshape(shp)
    return _Strategy(sample)


_DEFAULT_EXAMPLES = 20


def given(**param_strategies):
    def decorate(fn):
        def wrapper(*args, **kwargs):
            n = getattr(wrapper, "_stub_max_examples", _DEFAULT_EXAMPLES)
            rng = np.random.default_rng(
                zlib.crc32(fn.__qualname__.encode()) & 0x7FFFFFFF)
            for _ in range(n):
                drawn = {k: s.example(rng)
                         for k, s in param_strategies.items()}
                fn(*args, **kwargs, **drawn)

        wrapper.__name__ = fn.__name__
        wrapper.__doc__ = fn.__doc__
        # Hide the drawn parameters from pytest's fixture resolution.
        remaining = [p for name, p in
                     inspect.signature(fn).parameters.items()
                     if name not in param_strategies]
        wrapper.__signature__ = inspect.Signature(remaining)
        wrapper._stub_max_examples = _DEFAULT_EXAMPLES
        return wrapper
    return decorate


def settings(*, max_examples: int | None = None, deadline=None, **_ignored):
    def decorate(fn):
        if max_examples is not None and hasattr(fn, "_stub_max_examples"):
            fn._stub_max_examples = max_examples
        return fn
    return decorate


def install() -> None:
    """Register the emulation as ``hypothesis`` in sys.modules (idempotent;
    a no-op if the real package is importable)."""
    try:
        import hypothesis  # noqa: F401
        return
    except ModuleNotFoundError:
        pass
    if "hypothesis" in sys.modules:
        return

    hyp = types.ModuleType("hypothesis")
    strategies = types.ModuleType("hypothesis.strategies")
    extra = types.ModuleType("hypothesis.extra")
    extra_np = types.ModuleType("hypothesis.extra.numpy")

    strategies.integers = integers
    strategies.sampled_from = sampled_from
    strategies.floats = floats
    extra_np.arrays = arrays
    extra_np.array_shapes = array_shapes
    hyp.given = given
    hyp.settings = settings
    hyp.strategies = strategies
    hyp.extra = extra
    extra.numpy = extra_np
    hyp.__stub__ = True

    sys.modules["hypothesis"] = hyp
    sys.modules["hypothesis.strategies"] = strategies
    sys.modules["hypothesis.extra"] = extra
    sys.modules["hypothesis.extra.numpy"] = extra_np
