"""Sharded streaming SNN engine: data-parallel lane-mesh bit-identity.

Contracts under test:
  * the sharded engine reproduces the single-device engine bit-for-bit on
    shared seeds — predictions, retirement steps, spike registers and the
    frozen executed-add counters — on a 4-way forced-host mesh, including
    mid-chunk retirement and re-admission into freed slots (subprocess,
    same pattern as test_distributed.py so the rest of the suite keeps
    seeing the single real CPU device);
  * property: random window splits × random admission schedules give
    chunked sharded execution bit-identical to one-shot single-device
    execution, for both the fused-gated path and the jnp-scan fallback
    (in-process — the mesh covers whatever devices exist: 1 locally, 4 in
    the CI multi-device lane);
  * admission/compute overlap (speculative chunk dispatch) changes no
    results and actually fires in steady state;
  * mesh plumbing: divisibility validation, lane partition specs, and the
    per-device VMEM scoping of backend resolution.
"""

import dataclasses
import json
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from jax.sharding import PartitionSpec as P

from repro.configs.snn_mnist import SNN_CONFIG
from repro.core import prng, snn
from repro.serve import ShardedSNNStreamEngine
from repro.serve.snn_engine import lane_partition_specs

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_sub(code: str, n_dev: int = 4) -> str:
    env = dict(os.environ,
               XLA_FLAGS=f"--xla_force_host_platform_device_count={n_dev}",
               JAX_PLATFORMS="cpu",
               PYTHONPATH=SRC)
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def small_net(rng, sizes):
    layers = []
    for a, b in zip(sizes[:-1], sizes[1:]):
        w = jnp.asarray(rng.integers(-256, 256, (a, b)), jnp.int16)
        layers.append({"w_q": w, "scale": jnp.float32(1.0)})
    return {"layers": layers}


SUB_PRELUDE = """
    import dataclasses, json
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs.snn_mnist import SNN_CONFIG
    from repro.serve import ShardedSNNStreamEngine, SNNStreamEngine

    def small_net(rng, sizes):
        return {"layers": [
            {"w_q": jnp.asarray(rng.integers(-256, 256, (a, b)), jnp.int16),
             "scale": jnp.float32(1.0)}
            for a, b in zip(sizes[:-1], sizes[1:])]}

    def as_tuple(r):
        return (r.pred, r.steps, r.adds, r.early_exit,
                r.spike_counts.tolist())
"""


def test_sharded_matches_single_device_4way():
    """Bit-identity on a 4-way mesh for BOTH chunk backends, with enough
    load (20 images over 8 slots) to force re-admission into freed slots
    and a patience low enough to retire lanes mid-chunk."""
    out = run_sub(SUB_PRELUDE + """
    assert len(jax.devices()) == 4, jax.devices()
    rng = np.random.default_rng(0)
    cfg = dataclasses.replace(SNN_CONFIG, layer_sizes=(24, 12, 10),
                              num_steps=10)
    params_q = small_net(rng, cfg.layer_sizes)
    imgs = rng.integers(0, 256, (20, 24), dtype=np.uint8)
    summary = {}
    for backend in ("reference", "fused"):
        ref = SNNStreamEngine(params_q, cfg, batch_size=8, chunk_steps=3,
                              patience=1, seed=11, backend=backend)
        for im in imgs:
            ref.submit(im)
        r1 = ref.run()
        sh = ShardedSNNStreamEngine(params_q, cfg, lanes_per_device=2,
                                    chunk_steps=3, patience=1, seed=11,
                                    backend=backend)
        assert sh.n_devices == 4 and sh.local_batch == 2
        for im in imgs:
            sh.submit(im)
        r2 = sh.run()
        assert set(r1) == set(r2) == set(range(20))
        for rid in r1:
            assert as_tuple(r1[rid]) == as_tuple(r2[rid]), (backend, rid)
        summary[backend] = {
            "early_exits": sum(r.early_exit for r in r2.values()),
            "mid_chunk": sum(r.steps % 3 != 0 for r in r2.values()
                             if r.early_exit),
            "frozen_adds": sum(r.adds for r in r2.values()),
        }
    print(json.dumps(summary))
    """)
    res = json.loads(out.strip().splitlines()[-1])
    for backend in ("reference", "fused"):
        s = res[backend]
        assert s["early_exits"] > 0, res          # the gate actually fired
        assert s["mid_chunk"] > 0, res            # and fired mid-chunk
    # both backends walked the identical schedule and froze identical adds
    assert res["reference"] == res["fused"], res


def test_overlap_speculation_fires_and_changes_nothing_4way():
    """Steady state (full tile, gate never fires): the speculative chunk
    k+1 dispatch is used, and overlap=False produces identical results."""
    out = run_sub(SUB_PRELUDE + """
    rng = np.random.default_rng(3)
    cfg = dataclasses.replace(SNN_CONFIG, layer_sizes=(16, 10), num_steps=12)
    params_q = small_net(rng, cfg.layer_sizes)
    imgs = rng.integers(0, 256, (8, 16), dtype=np.uint8)
    runs = {}
    for overlap in (True, False):
        eng = ShardedSNNStreamEngine(params_q, cfg, lanes_per_device=2,
                                     chunk_steps=4, patience=10_000,
                                     seed=5, backend="reference",
                                     overlap=overlap)
        for im in imgs:
            eng.submit(im)
        res = eng.run()
        runs[overlap] = sorted(as_tuple(r) for r in res.values())
        if overlap:
            stats = dict(eng.stats)
    assert runs[True] == runs[False]
    print(json.dumps(stats))
    """)
    stats = json.loads(out.strip().splitlines()[-1])
    assert stats["spec_used"] > 0, stats


@settings(max_examples=6, deadline=None)
@given(seed=st.integers(0, 2**20), chunk_steps=st.integers(1, 8),
       burst=st.integers(1, 5),
       backend=st.sampled_from(["reference", "fused"]))
def test_random_admission_matches_one_shot(seed, chunk_steps, burst,
                                           backend):
    """Property: a random window split (chunk_steps) × a random admission
    schedule (bursty submits interleaved with engine steps) retires every
    request with results bit-identical to a one-shot single-device window
    (the patience sentinel disables early exit, so the fused path still
    runs its in-kernel gate — it just never triggers)."""
    rng = np.random.default_rng(seed)
    cfg = dataclasses.replace(SNN_CONFIG, layer_sizes=(12, 6), num_steps=8)
    params_q = small_net(rng, cfg.layer_sizes)
    n_imgs = int(rng.integers(3, 9))
    imgs = rng.integers(0, 256, (n_imgs, 12), dtype=np.uint8)
    eng = ShardedSNNStreamEngine(params_q, cfg, lanes_per_device=2,
                                 chunk_steps=chunk_steps, patience=10_000,
                                 seed=seed, backend=backend)
    submitted = 0
    for _ in range(n_imgs * (cfg.num_steps // chunk_steps + 2) + 4):
        take = min(int(rng.integers(0, burst + 1)), n_imgs - submitted)
        for im in imgs[submitted:submitted + take]:
            eng.submit(im)
        submitted += take
        eng.step()
        if submitted == n_imgs and eng.pending == 0:
            break
    results = eng.run()
    assert set(results) == set(range(n_imgs))
    for rid in range(n_imgs):
        out = snn.snn_apply_int(
            params_q, jnp.asarray(imgs[rid][None]),
            prng.seed_state(seed + rid, (1, cfg.n_in)), cfg,
            backend="reference")
        r = results[rid]
        assert r.pred == int(np.asarray(out["pred"])[0])
        np.testing.assert_array_equal(r.spike_counts,
                                      np.asarray(out["spike_counts"])[0])
        assert r.steps == cfg.num_steps and not r.early_exit
        assert r.adds == int(np.asarray(out["active_adds"]).sum())


def test_mesh_validation():
    from repro.distributed.sharding import make_device_mesh
    rng = np.random.default_rng(0)
    params_q = small_net(rng, (12, 6))
    mesh = make_device_mesh((len(jax.devices()),), ("data",))
    with pytest.raises(ValueError, match="axis"):
        ShardedSNNStreamEngine(params_q, SNN_CONFIG, mesh=mesh,
                               axis_name="model", backend="reference")
    if len(jax.devices()) > 1:        # 1 divides everything
        with pytest.raises(ValueError, match="divide"):
            ShardedSNNStreamEngine(params_q, SNN_CONFIG, mesh=mesh,
                                   batch_size=len(jax.devices()) + 1,
                                   backend="reference")
    # passing both tile knobs with inconsistent values must fail loudly,
    # not silently prefer one of them
    with pytest.raises(ValueError, match="conflicting"):
        ShardedSNNStreamEngine(params_q, SNN_CONFIG, mesh=mesh,
                               lanes_per_device=16,
                               batch_size=8 * len(jax.devices()),
                               backend="reference")


def test_stream_mesh_knobs_flow_into_engine():
    """configs.snn_mnist.SNNStreamMeshConfig is the deployment surface:
    every knob must actually reach the engine make_stream_engine builds."""
    from repro.configs.snn_mnist import (SNNStreamMeshConfig,
                                         make_stream_engine)
    rng = np.random.default_rng(0)
    params_q = small_net(rng, (12, 6))
    cfg = dataclasses.replace(SNN_CONFIG, layer_sizes=(12, 6), num_steps=8)
    knobs = SNNStreamMeshConfig(num_devices=1, lanes_per_device=3,
                                chunk_steps=7, overlap=False)
    eng = make_stream_engine(params_q, cfg, knobs, patience=5, seed=3,
                             backend="reference")
    assert eng.n_devices == 1 and eng.local_batch == 3
    assert eng.batch_size == 3 * eng.n_devices
    assert eng.chunk_steps == 7 and eng.overlap is False
    assert eng.patience == 5 and eng.axis_name == knobs.axis_name


def test_lane_partition_specs_cover_every_leaf():
    """Every LaneState leaf shards on the mesh batch axis — the structural
    invariant behind collective-free shard_map execution."""
    specs = lane_partition_specs(3, "data")
    leaves = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
    # v/en/v_peak are per-layer tuples
    assert len(leaves) == len(specs._fields) - 3 + 3 * 3
    assert all(s == P("data") for s in leaves)


def test_resolve_backend_vmem_check_is_per_device():
    """The VMEM feasibility estimate uses EXACTLY the batch block the
    per-device launch allocates (fused_snn.block_b_for is the shared
    source of truth), and data sharding never shrinks what fits."""
    from repro.kernels import fused_snn
    for b in (1, 2, 7, 8, 9, 64, 256):
        blk = fused_snn.block_b_for(b)
        assert blk % 8 == 0 or blk == fused_snn.DEFAULT_BLOCK_B
        assert 8 <= blk <= fused_snn.DEFAULT_BLOCK_B
        # shrinking the tile never grows the block (monotone in batch)
        assert fused_snn.block_b_for(max(1, b // 4)) <= blk
    assert fused_snn.block_b_for(None) == fused_snn.DEFAULT_BLOCK_B
    cfg = dataclasses.replace(SNN_CONFIG, layer_sizes=(784, 128, 64, 10))
    for local in (256, 256 // 4):
        assert snn.fused_unsupported_reason(
            cfg, 3, cfg.layer_sizes, trace_steps=4,
            local_batch=local) is None
    # resolve_backend plumbs local_batch through without changing the
    # CPU-host resolution ("auto" stays on the jnp reference scan here)
    assert snn.resolve_backend(cfg, "auto", 3, layer_sizes=cfg.layer_sizes,
                               trace_steps=4, local_batch=64) == "reference"


def test_speculation_discarded_on_chunk_length_retune():
    """Regression: a speculative chunk dispatched at chunk length L must
    be DISCARDED (spec_wasted) when the adaptive controller's chunk
    length moves before the commit — e.g. a tier/coordinator feeding the
    controller an out-of-band observation between engine steps.  With
    the old guard (tile-object identity only) the stale-length chunk was
    committed as if it were the requested one: the lanes silently
    advanced by the WRONG number of window steps for that dispatch."""
    from repro.serve.telemetry import AdaptiveDispatchConfig, ChunkSummary
    rng = np.random.default_rng(2)
    cfg = dataclasses.replace(SNN_CONFIG, layer_sizes=(16, 10),
                              num_steps=24)
    params_q = small_net(rng, cfg.layer_sizes)
    n_lanes = max(1, 8 // len(jax.devices())) * len(jax.devices())
    imgs = rng.integers(0, 256, (n_lanes, 16), dtype=np.uint8)
    adaptive = AdaptiveDispatchConfig(adaptive=True, min_chunk_steps=2,
                                      grow_patience=10_000)
    eng = ShardedSNNStreamEngine(
        params_q, cfg, lanes_per_device=n_lanes // len(jax.devices()),
        chunk_steps=4, patience=10_000, seed=7, backend="reference",
        overlap=True, adaptive=adaptive)
    for im in imgs:
        eng.submit(im)
    eng.step()                       # commit chunk 1, speculate chunk 2
    assert eng._spec is not None and eng._spec_steps == 4
    # external retune mid-speculation: an every-lane-retired observation
    # (frac = 1.0, 3 trigger-widths over the 0.25 trigger) takes the
    # proportional shrink law from 4 straight to the min_chunk_steps
    # clamp at 2 — one observation, not two limping single steps
    eng.controller.observe(ChunkSummary(
        density_in=0.2, layer_densities=(0.2,), executed_adds=0,
        tiles_skipped=0, lanes_retired=n_lanes, lanes_active=n_lanes,
        active_lane_steps=n_lanes * 4))
    assert eng.controller.chunk_steps == 2
    before = dict(eng.stats)
    steps_before = int(np.asarray(eng.lanes.steps).max())
    eng.step()
    # the stale 4-step speculation was discarded, not committed
    assert eng.stats["spec_wasted"] == before["spec_wasted"] + 1
    assert eng.stats["spec_used"] == before["spec_used"]
    # and the committed chunk ran at the retuned length (2 steps)
    assert int(np.asarray(eng.lanes.steps).max()) == steps_before + 2
    # the engine still finishes every request correctly
    res = eng.run()
    assert set(res) == set(range(n_lanes))
    for rid in range(n_lanes):
        out = snn.snn_apply_int(
            params_q, jnp.asarray(imgs[rid][None]),
            prng.seed_state(7 + rid, (1, cfg.n_in)), cfg,
            backend="reference")
        assert res[rid].pred == int(np.asarray(out["pred"])[0])
        assert res[rid].steps == cfg.num_steps


def test_speculation_survives_external_compaction():
    """Regression: a speculative chunk dispatched inside step() must be
    discarded when a LATER _admit_and_compact (e.g. run(max_chunks=1)'s
    trailing harvest, or a fresh run() call) replaces the lane tile —
    the spec is keyed to the exact LaneState object it was computed from,
    not to 'nothing changed during this step'.  (With the old guard this
    exact scenario corrupted 8 of 20 requests — predictions and energy
    counters attributed to the wrong lanes.)"""
    rng = np.random.default_rng(0)
    cfg = dataclasses.replace(SNN_CONFIG, layer_sizes=(24, 12, 10),
                              num_steps=10)
    params_q = small_net(rng, cfg.layer_sizes)
    imgs = rng.integers(0, 256, (20, 24), dtype=np.uint8)
    lanes_per_dev = max(1, 8 // len(jax.devices()))  # global tile of ~8
    runs = {}
    for overlap in (True, False):
        eng = ShardedSNNStreamEngine(params_q, cfg,
                                     lanes_per_device=lanes_per_dev,
                                     chunk_steps=3, patience=1, seed=11,
                                     backend="reference", overlap=overlap)
        for im in imgs:
            eng.submit(im)
        # run(max_chunks=1) strands a dispatched speculative chunk across
        # its trailing _admit_and_compact; the follow-up run() must not
        # adopt it after the tile was compacted
        eng.run(max_chunks=1)
        res = eng.run()
        assert set(res) == set(range(len(imgs)))
        runs[overlap] = [(res[r].pred, res[r].steps, res[r].adds,
                          res[r].spike_counts.tolist())
                         for r in sorted(res)]
    assert runs[True] == runs[False]
