"""benchmarks.check_tracked: the tracked-artifact checker must fail with
clear, actionable messages — never a KeyError or a traceback — on every
degenerate state `--all` can encounter.

Regression contracts (each failed as a raw exception or a silent pass
before the fix):
  * a committed artifact whose fresh results/bench counterpart is missing
    → a "no fresh copy" error naming the recovery action;
  * a fresh counterpart that is corrupt (producing suite crashed
    mid-write) → an "unreadable" error, not a JSONDecodeError traceback;
  * a contract field the bench now emits but the committed baseline
    predates (added but not re-committed) → an explicit re-commit error
    instead of being skipped silently forever;
  * matching copies → zero errors, and `--all` discovery finds exactly
    the BENCH_*.json names committed at HEAD.

All tests run against throwaway git repos so HEAD is controlled.
"""

import json
import os
import subprocess

import pytest

from benchmarks import check_tracked


def _git(repo, *args):
    out = subprocess.run(["git", *args], cwd=repo, capture_output=True,
                         text=True)
    assert out.returncode == 0, out.stderr
    return out.stdout


@pytest.fixture()
def repo(tmp_path):
    """A throwaway git repo with one committed BENCH artifact."""
    _git(tmp_path, "init", "-q")
    _git(tmp_path, "config", "user.email", "t@t")
    _git(tmp_path, "config", "user.name", "t")
    (tmp_path / "BENCH_x.json").write_text(
        json.dumps({"bit_identical": True, "devices": 4,
                    "timing_us": 12.5}))
    _git(tmp_path, "add", "BENCH_x.json")
    _git(tmp_path, "commit", "-qm", "artifact")
    os.makedirs(tmp_path / "results" / "bench")
    return str(tmp_path)


def _fresh(repo_root, name, obj):
    p = os.path.join(repo_root, "results", "bench", name)
    with open(p, "w") as f:
        if isinstance(obj, str):
            f.write(obj)
        else:
            json.dump(obj, f)


def test_all_match_no_errors(repo):
    _fresh(repo, "BENCH_x.json",
           {"bit_identical": True, "devices": 4, "timing_us": 99.0})
    assert check_tracked.check(["BENCH_x.json"], repo) == []


def test_missing_fresh_counterpart_is_actionable(repo):
    errs = check_tracked.check(["BENCH_x.json"], repo)
    assert len(errs) == 1
    assert "no fresh results/bench copy" in errs[0]
    assert "re-run" in errs[0]          # names the recovery action


def test_corrupt_fresh_copy_is_actionable_not_a_traceback(repo):
    _fresh(repo, "BENCH_x.json", '{"bit_identical": tru')   # mid-write
    errs = check_tracked.check(["BENCH_x.json"], repo)
    assert len(errs) == 1
    assert "unreadable" in errs[0] and "re-run" in errs[0]


def test_field_added_but_not_recommitted(repo):
    """The reverse hole: the bench emits a new contract field the
    committed baseline predates — must demand a re-commit, not skip."""
    _fresh(repo, "BENCH_x.json",
           {"bit_identical": True, "devices": 4,
            "mesh_shape": [2, 2]})
    errs = check_tracked.check(["BENCH_x.json"], repo)
    assert len(errs) == 1
    assert "'mesh_shape'" in errs[0]
    assert "missing from the committed copy" in errs[0]
    assert "commit" in errs[0]


def test_contract_mismatch_and_vanished_field(repo):
    _fresh(repo, "BENCH_x.json", {"bit_identical": False, "devices": 4})
    errs = check_tracked.check(["BENCH_x.json"], repo)
    assert any("tracked=True fresh=False" in e for e in errs)
    # a tracked contract field the fresh run stopped emitting
    _fresh(repo, "BENCH_x.json", {"bit_identical": True})
    errs = check_tracked.check(["BENCH_x.json"], repo)
    assert any("'devices' vanished" in e for e in errs)


def test_not_committed_at_head(repo):
    errs = check_tracked.check(["BENCH_nonexistent.json"], repo)
    assert len(errs) == 1
    assert "not committed at HEAD" in errs[0]


def test_all_discovery_finds_committed_artifacts(repo, tmp_path):
    (tmp_path / "BENCH_y.json").write_text(json.dumps({"devices": 1}))
    (tmp_path / "NOT_BENCH.json").write_text("{}")
    _git(repo, "add", "BENCH_y.json", "NOT_BENCH.json")
    _git(repo, "commit", "-qm", "more")
    assert check_tracked.committed_artifacts(repo) == \
        ["BENCH_x.json", "BENCH_y.json"]


def test_all_discovery_outside_git_checkout_is_actionable(tmp_path):
    bare = tmp_path / "notarepo"
    bare.mkdir()
    with pytest.raises(SystemExit) as exc:
        check_tracked.committed_artifacts(str(bare))
    assert "git" in str(exc.value)


def test_main_all_exits_nonzero_with_clear_message(repo, capsys):
    """End-to-end `--all`: a committed artifact with no fresh counterpart
    fails the run with the mismatch message on stdout — the CI surface."""
    with pytest.raises(SystemExit) as exc:
        check_tracked.main(["--all"], repo)
    assert exc.value.code == 1
    out = capsys.readouterr().out
    assert "TRACKED-ARTIFACT MISMATCH" in out
    assert "BENCH_x.json" in out and "no fresh" in out


def test_main_all_passes_when_everything_matches(repo, capsys):
    _fresh(repo, "BENCH_x.json", {"bit_identical": True, "devices": 4})
    check_tracked.main(["--all"], repo)
    out = capsys.readouterr().out
    assert "match the fresh run" in out
