"""Data substrate: procedural digits (MNIST stand-in) and token streams."""

import numpy as np

from repro.data import digits, pipeline, tokens


def test_digits_shapes_and_range():
    ds = digits.make_dataset(n_train=200, n_test=50, seed=0)
    assert ds.x_train.shape == (200, 784) and ds.x_train.dtype == np.float32
    assert ds.x_train.min() >= 0.0 and ds.x_train.max() <= 1.0
    assert set(np.unique(ds.y_train)) <= set(range(10))


def test_digits_deterministic():
    a = digits.make_dataset(n_train=50, n_test=10, seed=3)
    b = digits.make_dataset(n_train=50, n_test=10, seed=3)
    np.testing.assert_array_equal(a.x_train, b.x_train)
    np.testing.assert_array_equal(a.y_train, b.y_train)


def test_digits_classes_distinguishable():
    """Nearest-centroid must beat 60% — classes must be separable enough
    to support the paper's ≈89% claim on this stand-in."""
    ds = digits.make_dataset(n_train=500, n_test=200, seed=0)
    cents = np.stack([ds.x_train[ds.y_train == c].mean(0) for c in range(10)])
    pred = np.argmin(((ds.x_test[:, None] - cents[None]) ** 2).sum(-1), axis=1)
    acc = (pred == ds.y_test).mean()
    assert acc > 0.6, acc


def test_corruption_suite():
    ds = digits.make_dataset(n_train=20, n_test=5, seed=0)
    x = ds.x_train
    for kind in ("rotation", "shift", "noise", "occlusion"):
        xp = digits.corrupt(x, kind, seed=0)
        assert xp.shape == x.shape
        assert not np.array_equal(xp, x)
    np.testing.assert_array_equal(digits.corrupt(x, "clean"), x)


def test_token_stream_deterministic_and_in_range():
    cfg = tokens.TokenStreamConfig(vocab_size=100, seq_len=32,
                                   global_batch=4, seed=7)
    a = next(tokens.token_batches(cfg))
    b = next(tokens.token_batches(cfg))
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    assert a["tokens"].min() >= 0 and a["tokens"].max() < 100
    assert a["tokens"].shape == (4, 32)
    np.testing.assert_array_equal(a["labels"][:, :-1], a["tokens"][:, 1:])


def test_token_stream_host_striping():
    cfg = tokens.TokenStreamConfig(vocab_size=64, seq_len=16,
                                   global_batch=8, seed=1)
    h0 = next(tokens.token_batches(cfg, host_id=0, num_hosts=2))
    h1 = next(tokens.token_batches(cfg, host_id=1, num_hosts=2))
    assert h0["tokens"].shape == (4, 16)
    assert not np.array_equal(h0["tokens"], h1["tokens"])


def test_token_motifs_create_structure():
    """Motif injection must make sequences more predictable than iid Zipf."""
    cfg = tokens.TokenStreamConfig(vocab_size=1000, seq_len=512,
                                   global_batch=8, seed=0, motif_prob=0.5)
    batch = next(tokens.token_batches(cfg))
    t = batch["tokens"]
    # count exact 8-gram repeats within each row
    reps = 0
    for row in t:
        grams = {}
        for i in range(len(row) - 8):
            g = tuple(row[i:i + 8])
            reps += g in grams
            grams[g] = True
    assert reps > 0


def test_host_shard_partitions_batch():
    arr = np.arange(32).reshape(8, 4)
    parts = [pipeline.host_shard(arr, h, 4) for h in range(4)]
    np.testing.assert_array_equal(np.concatenate(parts), arr)


def test_prefetch_preserves_order():
    out = list(pipeline.prefetch(iter(range(50)), depth=4))
    assert out == list(range(50))


def test_digit_batches_iterator():
    ds = digits.make_dataset(n_train=64, n_test=8, seed=0)
    it = pipeline.digit_batches(ds.x_train, ds.y_train, batch=16, epochs=1)
    batches = list(it)
    assert len(batches) == 4
    assert batches[0]["pixels"].shape == (16, 784)
