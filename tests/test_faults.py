"""Fault tolerance: deterministic injection, failover, degradation ladder.

Contracts under test:
  * **failover == no-fault run** (the PR's acceptance property): under a
    seeded FaultPlan killing an engine mid-window, evacuated lanes resume
    on healthy engines and finish prediction-for-prediction bit-identical
    to the never-faulted tier (chunked==one-shot makes the LaneState row
    a perfect checkpoint) — on the jnp reference backend AND the fused
    megakernel;
  * **never-silent accounting** — ``results ∪ shed ∪ faulted`` exactly
    partitions the submitted ids under arbitrary fault plans, and a
    replayed (plan, schedule) pair reproduces every routing/shed/fault
    decision exactly;
  * **degradation ladder** — persistent fused launch faults demote the
    engine down the resumable backend chain, the demotion is recorded in
    the telemetry controller's history, served results stay bit-identical
    (cross-backend identity), and clean chunks re-promote;
  * **retry/backoff/watchdog** — transient faults retry and back off
    deterministically; persistent faults escalate to EngineFailure; a
    hung engine trips the chunk-deadline watchdog with lane state intact;
  * **poison quarantine** — a request that faults everywhere is
    quarantined with its replay seed after K faults, not retried forever;
  * **rollout × faults** — a dead engine's draining versions abort; an
    adopting engine restores garbage-collected versions from the tier's
    host planes (WeightBank.ensure), so a rollout never completes while
    an evacuated old-version lane is still draining;
  * **satellite regressions** — tier.submit validates before any state
    mutation; WeightBank.begin stacks by default and raises the typed
    RolloutInProgressError under exclusive=True.
"""

import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs.snn_mnist import SNN_CONFIG, SNNServingTierConfig
from repro.core.telemetry import EngineLoad, estimate_eta_steps, load_score
from repro.serve import (EngineFailure, FaultEvent, FaultInjector, FaultPlan,
                         FaultPlanSpecError, FaultToleranceConfig,
                         RolloutInProgressError, SNNServingTier,
                         SNNStreamEngine, WeightBank)


def small_net(rng, sizes):
    return {"layers": [
        {"w_q": jnp.asarray(rng.integers(-256, 256, (a, b)), jnp.int16),
         "scale": jnp.float32(1.0)}
        for a, b in zip(sizes[:-1], sizes[1:])]}


def as_tuple(r):
    return (r.pred, r.steps, r.adds, r.early_exit, r.spike_counts.tolist())


def _cfg(sizes=(12, 6), T=8):
    return dataclasses.replace(SNN_CONFIG, layer_sizes=sizes, num_steps=T)


def _partition_ok(tier, submitted):
    """results ∪ shed ∪ faulted partitions the submitted ids exactly."""
    res, shed, faulted = set(tier.results), set(tier.shed), set(tier.faulted)
    assert res | shed | faulted == set(submitted)
    assert not (res & shed) and not (res & faulted) and not (shed & faulted)


# ---- failover contract ----------------------------------------------------

@pytest.mark.parametrize("backend", ["reference", "fused"])
def test_failover_evacuation_bit_identical(backend):
    """Kill engine 1 mid-window: its lanes evacuate to engine 0 and every
    request finishes bit-identical to the never-faulted tier."""
    rng = np.random.default_rng(6)
    cfg = _cfg(sizes=(16, 8), T=8)
    params_q = small_net(rng, cfg.layer_sizes)
    imgs = rng.integers(0, 256, (6, 16), dtype=np.uint8)
    plan = FaultPlan(events=(
        FaultEvent(kind="device_loss", engine=1, first_chunk=2),))

    def serve(fault_plan):
        tier = SNNServingTier(params_q, cfg, num_engines=2,
                              lanes_per_engine=2, chunk_steps=3,
                              patience=10_000, seed=11, backend=backend,
                              shedding=False, fault_plan=fault_plan)
        rids = [tier.submit(im) for im in imgs]
        return tier, rids, tier.run()

    tier, rids, res = serve(plan)
    base, _, ref = serve(None)
    assert tier.stats["engines_failed"] == 1
    assert tier.stats["evacuated"] >= 1      # mid-window lanes moved
    assert tier.faulted == {}                # nothing was unrecoverable
    _partition_ok(tier, rids)
    assert set(res) == set(ref) == set(rids)
    for rid in rids:
        assert as_tuple(res[rid]) == as_tuple(ref[rid]), rid
    assert not tier.load_report()[1].alive
    assert tier.load_report()[0].alive


def test_hang_watchdog_failover_and_requeue():
    """A hung engine makes no chunk progress; the watchdog declares it
    failed after ``watchdog_chunks`` stalls with its lane state intact,
    and both its lanes and its host queue land on the healthy engine."""
    rng = np.random.default_rng(7)
    cfg = _cfg()
    params_q = small_net(rng, cfg.layer_sizes)
    imgs = rng.integers(0, 256, (6, 12), dtype=np.uint8)
    plan = FaultPlan(events=(
        FaultEvent(kind="hang", engine=1, first_chunk=1),))
    ft = FaultToleranceConfig(watchdog_chunks=2)

    def serve(fault_plan):
        tier = SNNServingTier(params_q, cfg, num_engines=2,
                              lanes_per_engine=2, chunk_steps=2,
                              patience=10_000, seed=3, backend="reference",
                              shedding=False, fault_plan=fault_plan,
                              fault_cfg=ft)
        rids = [tier.submit(im) for im in imgs]
        return tier, rids, tier.run()

    tier, rids, res = serve(plan)
    _, _, ref = serve(None)
    assert set(res) == set(rids)
    for rid in rids:
        assert as_tuple(res[rid]) == as_tuple(ref[rid]), rid
    e1 = tier.engines[1]
    fail = [e for e in e1.health.events if e.get("event") == "engine_failure"]
    assert fail and fail[0]["reason"] == "hang"
    assert tier.stats["evacuated"] >= 1
    assert tier.stats["requeued"] >= 1       # e1's host queue re-routed
    # the armed healthy engine reports its full watchdog margin
    assert tier.load_report()[0].watchdog_margin == ft.watchdog_chunks


def test_state_lost_windows_are_recorded_not_silent():
    """Device loss WITH lane state: the in-flight windows cannot be
    evacuated — each gets a FaultRecord; everything else still serves
    bit-identically and the partition invariant holds."""
    rng = np.random.default_rng(8)
    cfg = _cfg()
    params_q = small_net(rng, cfg.layer_sizes)
    imgs = rng.integers(0, 256, (6, 12), dtype=np.uint8)
    plan = FaultPlan(events=(FaultEvent(
        kind="device_loss", engine=1, first_chunk=1, state_lost=True),))
    tier = SNNServingTier(params_q, cfg, num_engines=2, lanes_per_engine=2,
                          chunk_steps=2, patience=10_000, seed=5,
                          backend="reference", shedding=False,
                          fault_plan=plan)
    rids = [tier.submit(im) for im in imgs]
    res = tier.run()
    _partition_ok(tier, rids)
    lost = {rid for rid, rec in tier.faulted.items()
            if rec.reason == "state_lost"}
    assert lost                              # engine 1 held in-flight lanes
    base = SNNServingTier(params_q, cfg, num_engines=2, lanes_per_engine=2,
                          chunk_steps=2, patience=10_000, seed=5,
                          backend="reference", shedding=False)
    for im in imgs:
        base.submit(im)
    ref = base.run()
    for rid in res:
        assert as_tuple(res[rid]) == as_tuple(ref[rid]), rid
    for rec in tier.faulted.values():
        assert rec.replay_seed == 5 + rec.request_id


def test_all_engines_dead_no_capacity():
    """Fleet-wide loss: every window lands in ``faulted`` (never silent)
    and a post-mortem submit is recorded as ``no_capacity``."""
    rng = np.random.default_rng(9)
    cfg = _cfg()
    params_q = small_net(rng, cfg.layer_sizes)
    imgs = rng.integers(0, 256, (3, 12), dtype=np.uint8)
    plan = FaultPlan(events=(FaultEvent(kind="device_loss", first_chunk=0),))
    tier = SNNServingTier(params_q, cfg, num_engines=2, lanes_per_engine=2,
                          chunk_steps=2, patience=10_000, seed=1,
                          backend="reference", shedding=False,
                          fault_plan=plan)
    rids = [tier.submit(im) for im in imgs[:2]]
    res = tier.run()
    assert res == {} and len(tier._dead) == 2
    rids.append(tier.submit(imgs[2]))
    assert tier.faulted[rids[-1]].reason == "no_capacity"
    _partition_ok(tier, rids)


# ---- degradation ladder ---------------------------------------------------

def test_degradation_ladder_demotes_serves_and_repromotes():
    """Persistent fused launch faults: the engine steps down the ladder,
    serves bit-identical results on the demoted rung, records the
    demotion in the telemetry history, and re-promotes after clean
    chunks once the faults stop."""
    rng = np.random.default_rng(10)
    cfg = _cfg(sizes=(16, 8), T=8)
    params_q = small_net(rng, cfg.layer_sizes)
    imgs = rng.integers(0, 256, (6, 16), dtype=np.uint8)
    plan = FaultPlan(events=(FaultEvent(
        kind="dispatch", first_chunk=0, last_chunk=4, backends=("fused",)),))
    ft = FaultToleranceConfig(demote_after=2, promote_after=3)
    eng = SNNStreamEngine(params_q, cfg, batch_size=2, chunk_steps=2,
                          patience=10_000, seed=4, backend="fused",
                          injector=FaultInjector(plan, 0), fault_cfg=ft)
    assert eng._ladder[0] == "fused" and eng._ladder[-1] == "reference"
    rids = [eng.submit(im) for im in imgs]
    res = eng.run()

    ref = SNNStreamEngine(params_q, cfg, batch_size=2, chunk_steps=2,
                          patience=10_000, seed=4, backend="fused")
    for im in imgs:
        ref.submit(im)
    refres = ref.run()
    assert set(res) == set(rids)
    for rid in rids:
        assert as_tuple(res[rid]) == as_tuple(refres[rid]), rid
    demotes = [e for e in eng.controller.history
               if isinstance(e, dict) and e.get("event") == "demote"]
    promotes = [e for e in eng.controller.history
                if isinstance(e, dict) and e.get("event") == "promote"]
    assert demotes and demotes[0]["from"] == "fused"
    assert promotes and promotes[-1]["to"] == "fused"
    assert eng.health.demotion_level == 0    # back on the top rung
    assert eng.backend_effective == "fused"
    assert eng.health.alive


def test_transient_faults_retry_and_backoff_value_neutral():
    """A bounded transient burst: immediate retries + deterministic
    backoff ride it out with zero effect on served results."""
    rng = np.random.default_rng(11)
    cfg = _cfg()
    params_q = small_net(rng, cfg.layer_sizes)
    imgs = rng.integers(0, 256, (4, 12), dtype=np.uint8)
    plan = FaultPlan(events=(FaultEvent(
        kind="dispatch", first_chunk=0, last_chunk=3),))
    eng = SNNStreamEngine(params_q, cfg, batch_size=2, chunk_steps=2,
                          patience=10_000, seed=2, backend="reference",
                          injector=FaultInjector(plan, 0))
    rids = [eng.submit(im) for im in imgs]
    res = eng.run()
    ref = SNNStreamEngine(params_q, cfg, batch_size=2, chunk_steps=2,
                          patience=10_000, seed=2, backend="reference")
    for im in imgs:
        ref.submit(im)
    refres = ref.run()
    for rid in rids:
        assert as_tuple(res[rid]) == as_tuple(refres[rid]), rid
    assert eng.health.total_faults == 4      # consults 0..3 all faulted
    assert eng.health.alive and eng.health.consecutive_faults == 0


def test_persistent_faults_escalate_to_engine_failure():
    rng = np.random.default_rng(12)
    cfg = _cfg()
    eng = SNNStreamEngine(small_net(rng, cfg.layer_sizes), cfg,
                          batch_size=2, chunk_steps=2, patience=10_000,
                          seed=2, backend="reference",
                          injector=FaultInjector(
                              FaultPlan(events=(
                                  FaultEvent(kind="dispatch",
                                             first_chunk=0),)), 0))
    eng.submit(np.zeros(12, np.uint8))
    with pytest.raises(EngineFailure) as ei:
        eng.run()
    assert ei.value.reason == "dispatch_exhausted"
    assert not eng.health.alive
    assert not eng.load_summary().alive
    assert load_score(eng.load_summary()) == float("inf")


def test_corrupted_telemetry_detected_and_dropped():
    """A corrupted side-channel record fails host validation and is
    dropped (counted, never fed to the controller); the datapath result
    is untouched."""
    rng = np.random.default_rng(13)
    cfg = _cfg()
    params_q = small_net(rng, cfg.layer_sizes)
    imgs = rng.integers(0, 256, (2, 12), dtype=np.uint8)
    plan = FaultPlan(events=(FaultEvent(
        kind="telemetry", first_chunk=0, last_chunk=1),))
    eng = SNNStreamEngine(params_q, cfg, batch_size=2, chunk_steps=2,
                          patience=10_000, seed=6, backend="reference",
                          injector=FaultInjector(plan, 0))
    rids = [eng.submit(im) for im in imgs]
    res = eng.run()
    ref = SNNStreamEngine(params_q, cfg, batch_size=2, chunk_steps=2,
                          patience=10_000, seed=6, backend="reference")
    for im in imgs:
        ref.submit(im)
    refres = ref.run()
    for rid in rids:
        assert as_tuple(res[rid]) == as_tuple(refres[rid]), rid
    assert eng.health.telemetry_faults == 2
    assert eng.health.alive


# ---- poison quarantine ----------------------------------------------------

def test_poison_request_quarantined_with_replay_seed():
    """A request that faults on every engine is evicted, retried across
    engines, and quarantined with its replay seed after K faults; every
    other request still serves bit-identically."""
    rng = np.random.default_rng(14)
    cfg = _cfg()
    params_q = small_net(rng, cfg.layer_sizes)
    imgs = rng.integers(0, 256, (5, 12), dtype=np.uint8)
    plan = FaultPlan(events=(
        FaultEvent(kind="poison", request_id=2, first_chunk=0),))
    tier = SNNServingTier(params_q, cfg, num_engines=2, lanes_per_engine=2,
                          chunk_steps=2, patience=10_000, seed=21,
                          backend="reference", shedding=False,
                          fault_plan=plan,
                          fault_cfg=FaultToleranceConfig(quarantine_after=2))
    rids = [tier.submit(im) for im in imgs]
    res = tier.run()
    _partition_ok(tier, rids)
    assert set(tier.faulted) == {2}
    rec = tier.faulted[2]
    assert rec.reason == "quarantined"
    assert rec.faults == 2 and rec.replay_seed == 21 + 2
    assert tier.stats["poison_retries"] == 1
    base = SNNServingTier(params_q, cfg, num_engines=2, lanes_per_engine=2,
                          chunk_steps=2, patience=10_000, seed=21,
                          backend="reference", shedding=False)
    for im in imgs:
        base.submit(im)
    ref = base.run()
    for rid in res:
        assert as_tuple(res[rid]) == as_tuple(ref[rid]), rid


# ---- rollout × faults -----------------------------------------------------

def test_evacuation_restores_gcd_weight_version():
    """Adopting an old-version lane on an engine that finished the
    rollout re-installs the planes (bank.ensure), re-opens the rolling
    state until the lane retires, and resumes bit-exactly."""
    rng = np.random.default_rng(15)
    cfg = _cfg()
    old = small_net(rng, cfg.layer_sizes)
    new = small_net(np.random.default_rng(99), cfg.layer_sizes)
    img = rng.integers(0, 256, (12,), dtype=np.uint8)

    src = SNNStreamEngine(old, cfg, batch_size=2, chunk_steps=2,
                          patience=10_000, seed=30, backend="reference")
    src.submit(img, request_id=7)
    src.step()                               # rid 7 is mid-window on v0
    row = src.evict_lane(7)
    assert int(row.steps) > 0

    tgt = SNNStreamEngine(old, cfg, batch_size=2, chunk_steps=2,
                          patience=10_000, seed=30, backend="reference")
    tgt.begin_rollout(new)
    tgt.bank.gc({1})                         # rollout completed: v0 gone
    assert tgt.bank.versions == (1,)
    with pytest.raises(KeyError, match="version 0"):
        tgt.adopt(7, row)
    assert tgt.bank.ensure(
        0, tgt._place_weights(tuple(l["w_q"] for l in old["layers"])))
    assert tgt.bank.rolling                  # old version live again
    tgt.adopt(7, row)
    res = tgt.run()
    assert not tgt.bank.rolling              # adopted lane retired ⇒ done
    assert [e.kind for e in tgt.bank.history] == [
        "begin", "complete", "restore", "complete"]

    solo = SNNStreamEngine(old, cfg, batch_size=2, chunk_steps=2,
                           patience=10_000, seed=30, backend="reference")
    solo.submit(img, request_id=7)
    assert as_tuple(res[7]) == as_tuple(solo.run()[7])
    assert res[7].weight_version == 0


def test_engine_failure_mid_rollout_aborts_and_fleet_completes():
    """An engine dying mid-rollout: its draining versions abort, the
    evacuated old-version lanes keep tier.rollout_active True on the
    survivors, and every window still finishes on its admission-time
    weights bit-for-bit."""
    rng = np.random.default_rng(16)
    cfg = _cfg()
    old = small_net(rng, cfg.layer_sizes)
    new = small_net(np.random.default_rng(98), cfg.layer_sizes)
    imgs = rng.integers(0, 256, (4, 12), dtype=np.uint8)
    plan = FaultPlan(events=(
        FaultEvent(kind="device_loss", engine=1, first_chunk=2),))
    tier = SNNServingTier(old, cfg, num_engines=2, lanes_per_engine=2,
                          chunk_steps=2, patience=10_000, seed=40,
                          backend="reference", shedding=False,
                          fault_plan=plan)
    pre = [tier.submit(im) for im in imgs[:2]]   # one per engine
    tier.step()                                  # both mid-window on v0
    assert tier.begin_rollout(new) == 1
    post = [tier.submit(im) for im in imgs[2:]]
    tier.step()                                  # post pair admitted on v1
    tier.step()                                  # engine 1 dies here
    assert 1 in tier._dead
    assert tier.engines[1].bank.history[-1].kind == "abort"
    assert not tier.engines[1].bank.rolling
    assert tier.rollout_active                   # old lanes drain elsewhere
    res = tier.run()
    assert not tier.rollout_active
    _partition_ok(tier, pre + post)
    for rid, im, params, v in [(pre[0], imgs[0], old, 0),
                               (pre[1], imgs[1], old, 0),
                               (post[0], imgs[2], new, 1),
                               (post[1], imgs[3], new, 1)]:
        solo = SNNStreamEngine(params, cfg, batch_size=2, chunk_steps=2,
                               patience=10_000, seed=40,
                               backend="reference")
        solo.submit(im, request_id=rid)
        assert as_tuple(solo.run()[rid]) == as_tuple(res[rid]), rid
        assert res[rid].weight_version == v


# ---- satellite: WeightBank begin/abort/exclusive --------------------------

def test_weight_bank_exclusive_begin_and_abort():
    bank = WeightBank(("w0",))
    bank.begin(("w1",))
    with pytest.raises(RolloutInProgressError) as ei:
        bank.begin(("w2",), exclusive=True)
    assert ei.value.versions == (0, 1)
    assert bank.begin(("w2",)) == 2          # stacking stays the default
    assert bank.versions == (0, 1, 2)
    assert bank.abort() == (0, 1)            # dead-engine cleanup
    assert not bank.rolling and bank.versions == (2,)
    assert [e.kind for e in bank.history] == ["begin", "begin", "abort"]
    assert bank.abort() == ()                # idempotent when clean


def test_weight_bank_ensure_contract():
    bank = WeightBank(("w0",))
    bank.begin(("w1",))
    bank.gc({1})
    assert bank.ensure(0, ("w0",)) is True   # restore retired version
    assert bank.rolling
    assert bank.ensure(0, ("w0",)) is False  # already live: no-op
    with pytest.raises(ValueError, match="newer than current"):
        bank.ensure(5, ("w5",))
    assert bank.gc({1}) == (0,)
    assert [e.kind for e in bank.history] == [
        "begin", "complete", "restore", "complete"]


# ---- satellite: submit validates before mutation --------------------------

def test_submit_validation_leaves_tier_untouched():
    """Regression: a rejected submit must consume no id and write no
    bookkeeping — the id counter used to advance before the priority
    check could throw."""
    rng = np.random.default_rng(17)
    cfg = _cfg()
    tier = SNNServingTier(small_net(rng, cfg.layer_sizes), cfg,
                          num_engines=2, lanes_per_engine=2,
                          backend="reference", shedding=False)
    img = np.zeros(12, np.uint8)
    with pytest.raises(ValueError, match="unknown priority"):
        tier.submit(img, priority="platinum")
    assert tier._next_id == 0 and tier._meta == {}
    assert tier.shed == {} and tier.faulted == {}
    assert all(e.pending == 0 for e in tier.engines)
    assert tier.submit(img) == 0             # the id was never burned
    with pytest.raises(ValueError, match="already in use"):
        tier.submit(img, request_id=0)
    assert tier._next_id == 1 and len(tier._meta) == 1
    res = tier.run()
    assert set(res) == {0}
    _partition_ok(tier, [0])


# ---- properties (hypothesis; satellite) -----------------------------------

@settings(max_examples=40, deadline=None)
@given(lanes=st.integers(1, 64), busy=st.integers(0, 64),
       q=st.integers(0, 128), mean=st.floats(0.5, 200.0),
       dq=st.integers(1, 32), dm=st.floats(0.1, 50.0))
def test_eta_monotone_and_nonnegative(lanes, busy, q, mean, dq, dm):
    busy = min(busy, lanes)
    base = EngineLoad(lanes, busy, q, mean, 0, None)
    deeper = EngineLoad(lanes, busy, q + dq, mean, 0, None)
    longer = EngineLoad(lanes, busy, q, mean + dm, 0, None)
    assert 0 <= estimate_eta_steps(base)
    assert estimate_eta_steps(base) <= estimate_eta_steps(deeper)
    assert estimate_eta_steps(base) <= estimate_eta_steps(longer)
    assert load_score(base) >= 0


@settings(max_examples=40, deadline=None)
@given(lanes=st.integers(1, 64), busy=st.integers(0, 64),
       q=st.integers(0, 128), mean=st.floats(0.5, 200.0),
       faults=st.integers(0, 8), level=st.integers(0, 2))
def test_load_score_health_penalty(lanes, busy, q, mean, faults, level):
    """Healthy == the historical six-field score; degradation only ever
    raises the bid; dead is never routable."""
    busy = min(busy, lanes)
    healthy = EngineLoad(lanes, busy, q, mean, 0, None)
    legacy = (0.5 * busy + q) * mean / max(1, lanes)
    assert load_score(healthy) == pytest.approx(legacy)
    degraded = EngineLoad(lanes, busy, q, mean, 0, None,
                          consecutive_faults=faults, demotion_level=level)
    assert load_score(degraded) >= load_score(healthy)
    if faults or level:
        assert load_score(degraded) > load_score(healthy)
    dead = EngineLoad(lanes, busy, q, mean, 0, None, alive=False)
    assert load_score(dead) == float("inf")


@settings(max_examples=40, deadline=None)
@given(lanes=st.integers(1, 64), busy=st.integers(0, 64),
       q=st.integers(0, 128),
       mean=st.sampled_from([0.0, -3.5, float("nan"),
                             float("inf"), float("-inf")]))
def test_eta_and_score_cold_engine_edges(lanes, busy, q, mean):
    """Regression: a cold engine (retired_total == 0, service EWMA still
    empty — serialized as 0 / NaN / inf by external coordinators) must
    yield a finite ETA ≥ 1 and a finite non-negative score.  Pre-fix,
    mean_service_steps=0 collapsed the score to 0 regardless of queue
    depth, so a cold engine with a 100-deep queue spuriously beat every
    warmed healthy engine; NaN poisoned both estimators outright."""
    import math
    busy = min(busy, lanes)
    cold = EngineLoad(lanes, busy, q, mean, 0, None)
    eta = estimate_eta_steps(cold)
    assert math.isfinite(eta) and eta >= 1.0
    score = load_score(cold)
    assert math.isfinite(score) and score >= 0.0
    if busy or q:
        # outstanding work still counts: the cold engine must not tie a
        # warmed, completely idle engine (score 0) in a least-loaded pick
        warmed_idle = EngineLoad(lanes, 0, 0, 20.0, 100, None)
        assert load_score(warmed_idle) == 0.0
        assert score > load_score(warmed_idle)


def test_cold_engine_does_not_beat_warmed_busy_engine():
    """The routing comparison the bug corrupted, pinned directly: a cold
    engine drowning in queued work must score WORSE than a warmed healthy
    engine with a couple of free lanes — not 0 or NaN."""
    cold_drowning = EngineLoad(8, 8, 100, 0.0, 0, None)
    warmed_light = EngineLoad(8, 6, 0, 20.0, 50, None)
    assert load_score(cold_drowning) > load_score(warmed_light) > 0
    # and the admission gate sees a usable wait bound from both
    for load in (cold_drowning, warmed_light):
        eta = estimate_eta_steps(load)
        assert eta == eta and 1.0 <= eta < float("inf")


@settings(max_examples=4, deadline=None)
@given(seed=st.integers(0, 2**16), kill=st.integers(0, 1),
       kchunk=st.integers(1, 5), rate=st.floats(0.0, 0.15),
       state_lost=st.sampled_from([False, True]))
def test_partition_and_replay_under_random_fault_plans(
        seed, kill, kchunk, rate, state_lost):
    """For any (plan, schedule): the partition invariant holds, and a
    replay reproduces every result, shed and fault record exactly."""
    rng = np.random.default_rng(seed)
    cfg = _cfg()
    params_q = small_net(rng, cfg.layer_sizes)
    n = int(rng.integers(4, 10))
    imgs = rng.integers(0, 256, (n, 12), dtype=np.uint8)
    plan = FaultPlan(
        events=(FaultEvent(kind="device_loss", engine=kill,
                           first_chunk=kchunk, state_lost=state_lost),),
        seed=seed, dispatch_rate=rate)

    def run_once():
        tier = SNNServingTier(params_q, cfg, num_engines=2,
                              lanes_per_engine=2, chunk_steps=2, patience=1,
                              seed=seed, backend="reference",
                              default_deadline_steps=40, queue_limit=3,
                              fault_plan=plan)
        rids = [tier.submit(im) for im in imgs]
        res = tier.run()
        _partition_ok(tier, rids)
        return ({r: as_tuple(v) for r, v in res.items()},
                dict(tier.shed), dict(tier.faulted), tier.stats)

    assert run_once() == run_once()


# ---- env-armed chaos ------------------------------------------------------

def test_env_plan_arms_engine_and_stays_value_neutral(monkeypatch):
    """REPRO_FAULT_PLAN arms every engine built without an injector; the
    injected transient faults are absorbed with bit-identical results —
    the property the chaos CI lane leans on suite-wide."""
    rng = np.random.default_rng(18)
    cfg = _cfg()
    params_q = small_net(rng, cfg.layer_sizes)
    imgs = rng.integers(0, 256, (4, 12), dtype=np.uint8)
    monkeypatch.delenv("REPRO_FAULT_PLAN", raising=False)
    ref = SNNStreamEngine(params_q, cfg, batch_size=2, chunk_steps=2,
                          patience=10_000, seed=9, backend="reference")
    assert ref.injector is None              # env cleared ⇒ unarmed
    for im in imgs:
        ref.submit(im)
    refres = ref.run()
    monkeypatch.setenv("REPRO_FAULT_PLAN", "seed=3,dispatch=0.2")
    eng = SNNStreamEngine(params_q, cfg, batch_size=2, chunk_steps=2,
                          patience=10_000, seed=9, backend="reference")
    assert eng.injector is not None
    assert eng.injector.plan.dispatch_rate == 0.2
    for im in imgs:
        eng.submit(im)
    res = eng.run()
    for rid in refres:
        assert as_tuple(res[rid]) == as_tuple(refres[rid]), rid
    with pytest.raises(ValueError, match="unknown"):
        FaultPlan.from_spec("seed=3,bogus=1")


# ---- spec grammar (strict parsing is the chaos lane's safety net) ---------

def test_from_spec_parses_process_faults():
    plan = FaultPlan.from_spec(
        "seed=7,dispatch=0.1,worker_kill=1@3,worker_hang=0@2,"
        "coordinator_kill=5,worker_kill=0@9")
    assert plan.seed == 7 and plan.dispatch_rate == 0.1
    assert plan.worker_kill(1, 3) is not None
    assert plan.worker_kill(1, 2) is None      # windowed [r, r], not >= r
    assert plan.worker_kill(0, 9) is not None  # repeated keys accumulate
    assert plan.worker_hang(0, 2) and not plan.worker_hang(1, 2)
    assert plan.coordinator_kill(5) and not plan.coordinator_kill(4)
    assert plan.engine_relevant(0) and plan.engine_relevant(1)


def test_from_spec_typo_fails_loudly_not_silently():
    """Regression: a typo'd key must never parse to an inert no-op plan —
    a chaos lane that silently tests nothing is worse than none."""
    with pytest.raises(FaultPlanSpecError) as ei:
        FaultPlan.from_spec("seed=11,dipsatch=0.03")
    assert ei.value.key == "dipsatch=0.03"
    msg = str(ei.value)
    assert "dipsatch" in msg and "accepted grammar" in msg
    assert "dispatch" in msg          # known keys listed for the human


@pytest.mark.parametrize("spec, detail", [
    ("worker_kill=1", "'<worker>@<round>'"),
    ("worker_kill=a@3", "'<worker>@<round>'"),
    ("worker_hang=0@", "'<worker>@<round>'"),
    ("worker_kill=-1@3", ">= 0"),
    ("coordinator_kill=x", "integer round"),
    ("coordinator_kill=-2", ">= 0"),
    ("dispatch=1.5", "outside"),
    ("seed=abc", "integer"),
    ("seed", "missing '=<value>'"),
])
def test_from_spec_malformed_values_raise(spec, detail):
    with pytest.raises(FaultPlanSpecError) as ei:
        FaultPlan.from_spec(spec)
    assert detail in str(ei.value)
    assert "accepted grammar" in str(ei.value)


def test_from_env_rejects_bad_spec(monkeypatch):
    monkeypatch.setenv("REPRO_FAULT_PLAN", "seed=11,dipsatch=0.03")
    with pytest.raises(FaultPlanSpecError):
        FaultPlan.from_env()
    monkeypatch.delenv("REPRO_FAULT_PLAN")
    assert FaultPlan.from_env() is None


# ---- recovery knob validation ---------------------------------------------

@pytest.mark.parametrize("bad", [
    dict(heartbeat_interval_s=0.0),
    dict(heartbeat_deadline_s=0.05, heartbeat_interval_s=0.1),
    dict(max_respawns=-1),
    dict(watchdog_chunks=0),
    dict(max_retries=-1),
])
def test_fault_tolerance_config_validates(bad):
    with pytest.raises(ValueError):
        FaultToleranceConfig(**bad)


def test_tier_knobs_resolve_into_fault_cfg():
    knobs = SNNServingTierConfig(max_respawns=3, heartbeat_interval_s=0.01,
                                 heartbeat_deadline_s=2.0)
    eff = knobs.resolve_fault_cfg()
    assert eff.max_respawns == 3 and eff.heartbeat_deadline_s == 2.0
    assert eff.watchdog_chunks == FaultToleranceConfig().watchdog_chunks
    assert SNNServingTierConfig().resolve_fault_cfg() is None
    with pytest.raises(ValueError, match="one source of truth"):
        SNNServingTierConfig(fault_cfg=FaultToleranceConfig(),
                             max_respawns=2)
    # invalid knob combinations fail at config construction, not at the
    # first worker death hours into a run
    with pytest.raises(ValueError, match="heartbeat"):
        SNNServingTierConfig(heartbeat_interval_s=1.0,
                             heartbeat_deadline_s=0.5)
