"""§Perf-optimized code paths must be BIT-IDENTICAL to their faithful
references — the 'debug forward, keep the speedup' contract."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs.snn_mnist import SNN_CONFIG
from repro.core import lif, prng, snn


@pytest.mark.parametrize("prune", [False, True])
def test_fused_engine_bit_identical(rng, prune):
    cfg = dataclasses.replace(SNN_CONFIG, num_steps=12,
                              active_pruning=prune,
                              readout="first_spike" if prune else "count")
    fast = dataclasses.replace(cfg, fuse_encoder=True, dot_impl="f32")
    w = jnp.asarray(rng.integers(-256, 256, (784, 10)), jnp.int16)
    params_q = {"layers": [{"w_q": w, "scale": jnp.float32(1.0)}]}
    px = jnp.asarray(rng.integers(0, 256, (16, 784), dtype=np.uint8))
    s0 = prng.seed_state(77, px.shape)
    a = snn.snn_apply_int(params_q, px, s0, cfg)
    b = snn.snn_apply_int(params_q, px, s0, fast)
    np.testing.assert_array_equal(np.asarray(a["pred"]), np.asarray(b["pred"]))
    np.testing.assert_array_equal(np.asarray(a["v_trace"]),
                                  np.asarray(b["v_trace"]))
    np.testing.assert_array_equal(np.asarray(a["spike_counts"]),
                                  np.asarray(b["spike_counts"]))
    np.testing.assert_array_equal(np.asarray(a["prng_state"]),
                                  np.asarray(b["prng_state"]))


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31), shift=st.integers(1, 7))
def test_f32_dot_bit_exact_property(seed, shift):
    """f32-unit synaptic sum == int32 sum for any 9-bit weights/spikes."""
    r = np.random.default_rng(seed)
    spikes = jnp.asarray(r.integers(0, 2, (4, 784)), bool)
    w = jnp.asarray(r.integers(-256, 256, (784, 32)), jnp.int16)
    a = lif.synaptic_current_int(spikes, w, "int32")
    b = lif.synaptic_current_int(spikes, w, "f32")
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_gqa_decode_attend_vs_oracle(rng):
    """The no-repeat GQA decode path vs a naive full-softmax oracle."""
    from repro.models.attention import _gqa_decode_attend, _repeat_kv
    B, S, KV, G, hd = 3, 24, 2, 4, 16
    q = jnp.asarray(rng.normal(0, 1, (B, 1, KV * G, hd)).astype(np.float32))
    k = jnp.asarray(rng.normal(0, 1, (B, S, KV, hd)).astype(np.float32))
    v = jnp.asarray(rng.normal(0, 1, (B, S, KV, hd)).astype(np.float32))
    pos = jnp.asarray([[20], [5], [23]], jnp.int32)
    valid = pos[:, 0] + 1

    got = _gqa_decode_attend(q, k, v, n_rep=G, q_positions=pos, window=None,
                             cap=None, kv_valid_len=valid, causal=True)
    kr, vr = _repeat_kv(k, G), _repeat_kv(v, G)
    s = jnp.einsum("bqhd,bshd->bhqs", q, kr) / hd ** 0.5
    mask = jnp.arange(S)[None, None, None, :] < valid[:, None, None, None]
    s = jnp.where(mask, s, -1e30)
    want = jnp.einsum("bhqs,bshd->bqhd", jax.nn.softmax(s, -1), vr)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-2, rtol=2e-2)   # bf16 internals


def test_gqa_decode_sliding_window(rng):
    from repro.models.attention import _gqa_decode_attend
    B, S, KV, hd = 2, 32, 2, 8
    q = jnp.asarray(rng.normal(0, 1, (B, 1, KV, hd)).astype(np.float32))
    k = jnp.asarray(rng.normal(0, 1, (B, S, KV, hd)).astype(np.float32))
    v_marked = jnp.zeros((B, S, KV, hd)).at[:, :8].set(1000.0)
    pos = jnp.full((B, 1), 30, jnp.int32)
    out = _gqa_decode_attend(q, k, jnp.asarray(v_marked), n_rep=1,
                             q_positions=pos, window=8, cap=None,
                             kv_valid_len=pos[:, 0] + 1, causal=True)
    # window=8 at pos 30 → keys 23..30 only; marked values (<8) unreachable
    assert float(jnp.max(jnp.abs(out))) < 100.0


def test_train_step_cast_params_close_to_fp32():
    """bf16 shadow training stays close to fp32 over a few steps."""
    from repro.configs import get_reduced
    from repro.train import TrainSettings, init_state
    from repro.train.step import make_train_step
    cfg = get_reduced("llama3-8b")
    key = jax.random.PRNGKey(0)
    toks = jax.random.randint(key, (4, 17), 0, cfg.vocab_size)
    batch = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
    s32 = TrainSettings(num_microbatches=2)
    sbf = TrainSettings(num_microbatches=2, cast_params="bfloat16")
    st = init_state(key, cfg, s32)
    a = jax.jit(make_train_step(cfg, s32))(st, batch)[0]
    b = jax.jit(make_train_step(cfg, sbf))(st, batch)[0]
    for x, y in zip(jax.tree.leaves(a.params), jax.tree.leaves(b.params)):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                   atol=5e-2, rtol=5e-2)
