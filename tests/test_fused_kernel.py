"""Fused encode→LIF megakernel + backend selector + streaming engine.

Contracts under test:
  * the fused Pallas kernel is bit-identical to its independent jnp oracle
    AND to the staged kernel pipeline on shared xorshift seeds (same PRNG
    stream ⇒ identical spike counts/traces);
  * ``snn_apply_int`` produces identical results on all three backends,
    including the executed-add energy side channel;
  * the pure stability gate is scan-safe and equivalent to the legacy
    stateful wrapper;
  * the streaming engine's early-exit compaction freezes a retired lane's
    op counter (the "sleep sooner" energy win) and freed slots admit
    queued images.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.snn_mnist import SNN_CONFIG, SNN_CONFIG_PRUNED
from repro.core import prng, snn
from repro.kernels import ops, ref
from repro.serve import (SNNStreamEngine, stability_gate, stability_init,
                         stability_step)
from repro.serve.snn_engine import LaneState, stream_chunk

_FUSED_KEYS = ["spike_counts", "v_trace", "first_spike_t", "v_final",
               "active_adds", "prng_state"]


@pytest.mark.parametrize("b,n_in,n_out,t,shift,prune", [
    (4, 784, 10, 20, 4, False),
    (4, 784, 10, 20, 4, True),
    (2, 64, 130, 8, 2, False),
    (9, 100, 200, 3, 4, True),
    (1, 32, 10, 5, 6, False),
])
def test_fused_kernel_matches_ref(rng, b, n_in, n_out, t, shift, prune):
    px = jnp.asarray(rng.integers(0, 256, (b, n_in), dtype=np.uint8))
    st = prng.seed_state(99, (b, n_in))
    w = jnp.asarray(rng.integers(-256, 256, (n_in, n_out), dtype=np.int16))
    got = ops.fused_snn_op(px, st, w, num_steps=t, decay_shift=shift,
                           v_threshold=128, active_pruning=prune,
                           interpret=True)
    want = ref.fused_snn_ref(px, st, w, num_steps=t, decay_shift=shift,
                             v_threshold=128, active_pruning=prune)
    for key, w_val in zip(_FUSED_KEYS, want):
        np.testing.assert_array_equal(np.asarray(got[key]),
                                      np.asarray(w_val), err_msg=key)


@pytest.mark.parametrize("prune", [False, True])
def test_fused_kernel_matches_staged_kernels(rng, prune):
    """Same xorshift seeds ⇒ the megakernel and the staged two-launch
    pipeline produce identical spikes — the fusion changes memory traffic,
    not arithmetic."""
    b, n_in, n_out, t = 6, 300, 10, 12
    px = jnp.asarray(rng.integers(0, 256, (b, n_in), dtype=np.uint8))
    st = prng.seed_state(7, (b, n_in))
    w = jnp.asarray(rng.integers(-256, 256, (n_in, n_out), dtype=np.int16))

    fused = ops.fused_snn_op(px, st, w, num_steps=t, decay_shift=4,
                             v_threshold=128, active_pruning=prune,
                             interpret=True)
    spikes, st_out = ops.poisson_encode_op(px, st, t, interpret=True)
    spk, vtr, vfin = ops.lif_forward_op(spikes, w, decay_shift=4,
                                        v_threshold=128,
                                        active_pruning=prune, interpret=True)
    np.testing.assert_array_equal(
        np.asarray(fused["spike_counts"]),
        np.asarray(jnp.sum(spk.astype(jnp.int32), axis=0)))
    np.testing.assert_array_equal(np.asarray(fused["v_trace"]),
                                  np.asarray(vtr))
    np.testing.assert_array_equal(np.asarray(fused["v_final"]),
                                  np.asarray(vfin))
    np.testing.assert_array_equal(np.asarray(fused["prng_state"]),
                                  np.asarray(st_out))


@pytest.mark.parametrize("base_cfg", [SNN_CONFIG, SNN_CONFIG_PRUNED])
def test_backend_selector_bit_identical(rng, base_cfg):
    cfg = dataclasses.replace(base_cfg, num_steps=10)
    w = jnp.asarray(rng.integers(-256, 256, (784, 10)), jnp.int16)
    params_q = {"layers": [{"w_q": w, "scale": jnp.float32(1.0)}]}
    px = jnp.asarray(rng.integers(0, 256, (16, 784), dtype=np.uint8))
    st = prng.seed_state(77, px.shape)
    outs = {b: snn.snn_apply_int(params_q, px, st, cfg, backend=b)
            for b in ("reference", "staged", "fused")}
    for key in ("pred", "spike_counts", "v_trace", "first_spike_t",
                "prng_state", "active_adds"):
        a = np.asarray(outs["reference"][key])
        for b in ("staged", "fused"):
            np.testing.assert_array_equal(a, np.asarray(outs[b][key]),
                                          err_msg=f"{key} on {b}")
    # the fused backend never materialises the input spike train
    assert outs["fused"]["input_spikes"] is None


def test_backend_bit_identical_with_custom_saturation(rng):
    """Non-default accumulator clamp bounds must reach the Pallas backends
    too (regression: fused/staged once silently used the kernel defaults,
    diverging from reference under tight v_min/v_max)."""
    from repro.core.lif import LIFConfig
    cfg = dataclasses.replace(
        SNN_CONFIG, num_steps=8,
        lif=LIFConfig(decay_shift=4, v_threshold=128, v_rest=0,
                      v_min=-256, v_max=255))
    w = jnp.asarray(rng.integers(-256, 256, (784, 10)), jnp.int16)
    params_q = {"layers": [{"w_q": w, "scale": jnp.float32(1.0)}]}
    px = jnp.asarray(rng.integers(0, 256, (8, 784), dtype=np.uint8))
    st = prng.seed_state(13, px.shape)
    ref_out = snn.snn_apply_int(params_q, px, st, cfg, backend="reference")
    for b in ("staged", "fused"):
        out = snn.snn_apply_int(params_q, px, st, cfg, backend=b)
        np.testing.assert_array_equal(np.asarray(ref_out["spike_counts"]),
                                      np.asarray(out["spike_counts"]),
                                      err_msg=b)
        np.testing.assert_array_equal(np.asarray(ref_out["v_trace"]),
                                      np.asarray(out["v_trace"]),
                                      err_msg=b)


def test_backend_auto_resolution():
    on_tpu = jax.default_backend() == "tpu"
    assert snn.resolve_backend(SNN_CONFIG, None, 1) == (
        "fused" if on_tpu else "reference")
    # the fused kernel now covers arbitrary stacks: an explicit request is
    # honoured for deep topologies instead of silently degrading
    assert snn.resolve_backend(SNN_CONFIG, "fused", 2,
                               layer_sizes=(784, 256, 10)) == "fused"
    with pytest.raises(ValueError):
        snn.resolve_backend(SNN_CONFIG, "warp-drive", 1)


def test_backend_fused_rejects_oversized_stack():
    """An explicit backend='fused' request for a stack whose resident
    weights cannot fit VMEM must raise a clear error, not silently fall
    back; auto quietly streams the weights (TPU) or picks reference."""
    huge = (784, 4096, 4096, 10)   # ~42 MB of packed resident weight codes
    with pytest.raises(ValueError, match="VMEM"):
        snn.resolve_backend(SNN_CONFIG, "fused", len(huge) - 1,
                            layer_sizes=huge)
    on_tpu = jax.default_backend() == "tpu"
    assert snn.resolve_backend(SNN_CONFIG, "auto", len(huge) - 1,
                               layer_sizes=huge) == (
        "fused_streamed" if on_tpu else "reference")


# ---------------------------------------------------------------------------
# pure stability gate
# ---------------------------------------------------------------------------

def test_stability_gate_pure_matches_legacy_wrapper(rng):
    batch, steps, patience = 5, 12, 3
    preds = rng.integers(0, 4, (steps, batch))
    legacy = stability_gate(batch, patience=patience)
    state = stability_init(batch)
    for t in range(steps):
        p = jnp.asarray(preds[t], jnp.int32)
        # legacy wrapper consumes logits; one-hot encodes the same pred
        done_legacy = legacy(None, jax.nn.one_hot(p, 4))
        state, done_pure = stability_step(state, p, patience)
        np.testing.assert_array_equal(np.asarray(done_legacy),
                                      np.asarray(done_pure))


def test_stability_gate_is_scan_safe(rng):
    """The refactored gate is a pure (state, pred) -> (state, done) function
    and therefore usable inside jit/scan (the old class held JAX arrays as
    mutable Python attributes and silently broke under tracing)."""
    batch, steps, patience = 4, 10, 2
    preds = jnp.asarray(rng.integers(0, 3, (steps, batch)), jnp.int32)

    @jax.jit
    def run(preds):
        def body(state, p):
            state, done = stability_step(state, p, patience)
            return state, done
        return jax.lax.scan(body, stability_init(batch), preds)[1]

    dones = np.asarray(run(preds))
    # oracle: done[t] iff the last patience+1 predictions are identical
    for t in range(steps):
        for b in range(batch):
            window = preds[max(0, t - patience):t + 1, b]
            expect = (t >= patience
                      and bool((np.asarray(window) ==
                                int(preds[t, b])).all()))
            assert bool(dones[t, b]) == expect, (t, b)


# ---------------------------------------------------------------------------
# streaming engine: early exit, compaction, energy side channel
# ---------------------------------------------------------------------------

def _params(rng, n_in=784, n_out=10):
    w = jnp.asarray(rng.integers(-256, 256, (n_in, n_out)), jnp.int16)
    return {"layers": [{"w_q": w, "scale": jnp.float32(1.0)}]}


def test_stream_engine_matches_batch_engine(rng):
    """Full-window lanes (patience too high to early-exit) are bit-identical
    to snn_apply_int — pred, spike counts AND executed adds."""
    cfg = dataclasses.replace(SNN_CONFIG, num_steps=12)
    params_q = _params(rng)
    eng = SNNStreamEngine(params_q, cfg, batch_size=3, chunk_steps=5,
                          patience=10_000, seed=31)
    imgs = rng.integers(0, 256, (7, 784), dtype=np.uint8)
    ids = [eng.submit(im) for im in imgs]
    results = eng.run()
    assert set(results) == set(ids)        # 7 requests through 3 lanes
    for rid in ids:
        r = results[rid]
        assert r.steps == cfg.num_steps and not r.early_exit
        px = jnp.asarray(imgs[rid][None])
        st = prng.seed_state(31 + rid, (1, 784))
        out = snn.snn_apply_int(params_q, px, st, cfg)
        assert r.pred == int(np.asarray(out["pred"])[0])
        np.testing.assert_array_equal(r.spike_counts,
                                      np.asarray(out["spike_counts"])[0])
        assert r.adds == int(np.asarray(out["active_adds"]).sum())


def test_retired_lane_stops_accumulating_ops(rng):
    """The energy side channel freezes the step a lane retires: a bright
    image whose prediction stabilises immediately must consume far fewer
    adds than the same image run for the full window, while a blank image
    (no output spikes, hence no prediction) must NOT be retired as a
    spurious class 0."""
    cfg = dataclasses.replace(SNN_CONFIG, num_steps=20)
    params_q = _params(rng)
    eng = SNNStreamEngine(params_q, cfg, batch_size=2, chunk_steps=4,
                          patience=2, seed=5)
    blank = eng.submit(np.zeros(784, np.uint8))
    bright = eng.submit(np.full(784, 255, np.uint8))
    results = eng.run()
    rb, rf = results[blank], results[bright]
    # spikeless lane: argmax(zeros)=0 is not a stable prediction
    assert not rb.early_exit and rb.steps == cfg.num_steps
    assert rb.adds == 0                    # no input spikes ⇒ no adds at all
    # bright lane: retired early, add counter frozen at the exit step
    assert rf.early_exit and rf.steps < cfg.num_steps
    full = snn.snn_apply_int(
        params_q, jnp.full((1, 784), 255, jnp.uint8),
        prng.seed_state(5 + bright, (1, 784)), cfg)
    full_adds = int(np.asarray(full["active_adds"]).sum())
    assert 0 < rf.adds < full_adds


def _lanes(px, rng_seed, *, batch, active, adds=None, num_steps=50):
    return LaneState(
        px=px,
        rng=prng.seed_state(rng_seed, (batch, 784)),
        v=(jnp.zeros((batch, 10), jnp.int32),),
        en=(jnp.ones((batch, 10), bool),),
        v_peak=(jnp.full((batch, 10), np.iinfo(np.int32).min, jnp.int32),),
        counts=jnp.zeros((batch, 10), jnp.int32),
        first=jnp.full((batch, 10), num_steps, jnp.int32),
        gate_prev=jnp.full((batch,), -1, jnp.int32),
        gate_streak=jnp.zeros((batch,), jnp.int32),
        steps=jnp.zeros((batch,), jnp.int32),
        adds=(jnp.zeros((batch,), jnp.int32) if adds is None
              else jnp.asarray(adds, jnp.int32)),
        active=jnp.asarray(active),
        weight_version=jnp.zeros((batch,), jnp.int32),
    )


@pytest.mark.parametrize("backend", ["reference", "fused"])
def test_stream_chunk_freezes_inactive_lanes(rng, backend):
    """Direct chunk-level check: an inactive lane's PRNG, membrane, spike
    register and add counter are all frozen while an active lane advances —
    on the jnp fallback AND inside the gated fused kernel."""
    cfg = dataclasses.replace(SNN_CONFIG, num_steps=50)
    params_q = _params(rng)
    weights = (params_q["layers"][0]["w_q"],)
    px = jnp.asarray(rng.integers(128, 256, (2, 784), dtype=np.uint8))
    lanes = _lanes(px, 1, batch=2, active=[True, False], adds=[123, 456])
    out, tel = stream_chunk(lanes, weights, chunk_steps=6,
                            num_steps=cfg.num_steps, lif_cfg=cfg.lif,
                            dot_impl="int32", active_pruning=False,
                            patience=10_000, backend=backend)
    out = jax.tree.map(np.asarray, out)
    # frozen lane reports zero activity; active lane reports its spikes
    tel = jax.tree.map(np.asarray, tel)
    assert (tel.n_spk[:, :, 1] == 0).all() and (tel.n_en[:, :, 1] == 0).all()
    assert tel.n_spk[:, :, 0].sum() > 0
    # active lane advanced
    assert out.steps[0] == 6 and out.adds[0] > 123
    assert (out.rng[0] != np.asarray(lanes.rng)[0]).any()
    # inactive lane fully frozen
    assert out.steps[1] == 0 and out.adds[1] == 456
    np.testing.assert_array_equal(out.rng[1], np.asarray(lanes.rng)[1])
    np.testing.assert_array_equal(out.v[0][1], np.asarray(lanes.v[0])[1])
    np.testing.assert_array_equal(out.counts[1], np.asarray(lanes.counts)[1])


def test_stream_chunk_fused_matches_reference(rng):
    """The gated fused kernel and the jnp fallback must produce identical
    lane-state evolution — including mid-chunk retirement (patience low
    enough that the bright lane retires inside the chunk) and the frozen
    add counters that follow."""
    cfg = dataclasses.replace(SNN_CONFIG, num_steps=20)
    params_q = _params(rng)
    weights = (params_q["layers"][0]["w_q"],)
    px = np.concatenate([
        rng.integers(128, 256, (3, 784), dtype=np.uint8),
        np.zeros((1, 784), np.uint8)])                  # one spikeless lane
    lanes = _lanes(jnp.asarray(px), 9, batch=4, active=[True] * 4,
                   num_steps=cfg.num_steps)
    outs = {b: stream_chunk(lanes, weights, chunk_steps=12,
                            num_steps=cfg.num_steps, lif_cfg=cfg.lif,
                            dot_impl="int32", active_pruning=False,
                            patience=1, backend=b)
            for b in ("reference", "fused")}
    a, tel_a = jax.tree.map(np.asarray, outs["reference"])
    b, tel_b = jax.tree.map(np.asarray, outs["fused"])
    assert a.steps[:3].max() < 12    # bright lanes retired mid-chunk
    assert a.active[3]               # the spikeless lane kept running
    for name in LaneState._fields:
        jax.tree.map(
            lambda x, y: np.testing.assert_array_equal(x, y, err_msg=name),
            getattr(a, name), getattr(b, name))
    # the telemetry side channel is part of the chunk contract too —
    # identical through the gated kernel and the jnp fallback, including
    # the zeroed rows of mid-chunk-retired lanes
    for name in tel_a._fields:
        np.testing.assert_array_equal(getattr(tel_a, name),
                                      getattr(tel_b, name), err_msg=name)


def test_spikeless_lane_gate_stays_armed(rng):
    """A lane with zero output spikes must keep its stability gate at the
    init state — no streak pre-accumulation on argmax(zeros)=0, which would
    otherwise retire the lane the moment its first spike lands on any
    class (observed as spurious class-0 results)."""
    cfg = dataclasses.replace(SNN_CONFIG, num_steps=50)
    weights = (_params(rng)["layers"][0]["w_q"],)
    lanes = _lanes(jnp.zeros((1, 784), jnp.uint8), 4, batch=1,
                   active=[True])
    out, _ = stream_chunk(lanes, weights, chunk_steps=8,
                          num_steps=cfg.num_steps, lif_cfg=cfg.lif,
                          dot_impl="int32", active_pruning=False, patience=2)
    out = jax.tree.map(np.asarray, out)
    assert out.gate_prev[0] == -1 and out.gate_streak[0] == 0
    assert out.active[0]                    # still waiting for evidence


def test_stream_engine_first_spike_readout_matches_batch_engine(rng):
    """SNN_CONFIG_PRUNED (first_spike readout + active pruning) streams:
    with patience too high to early-exit, every prediction and counter is
    bit-identical to the full-window snn_apply_int result."""
    cfg = dataclasses.replace(SNN_CONFIG_PRUNED, num_steps=12)
    params_q = _params(rng)
    eng = SNNStreamEngine(params_q, cfg, batch_size=3, chunk_steps=5,
                          patience=10_000, seed=17)
    imgs = rng.integers(0, 256, (5, 784), dtype=np.uint8)
    ids = [eng.submit(im) for im in imgs]
    results = eng.run()
    assert set(results) == set(ids)
    for rid in ids:
        r = results[rid]
        out = snn.snn_apply_int(params_q, jnp.asarray(imgs[rid][None]),
                                prng.seed_state(17 + rid, (1, 784)), cfg)
        assert r.pred == int(np.asarray(out["pred"])[0])
        np.testing.assert_array_equal(r.spike_counts,
                                      np.asarray(out["spike_counts"])[0])
        assert r.adds == int(np.asarray(out["active_adds"]).sum())


def test_stream_engine_membrane_readout_streams(rng):
    """The membrane readout streams: the per-layer peak accumulator in
    LaneState replaces the per-step trace (max is associative), so chunked
    serving reproduces the one-shot snn_apply_int predictions bit-for-bit
    on both chunk backends — the readout the engine used to reject."""
    cfg = dataclasses.replace(SNN_CONFIG, readout="membrane", num_steps=12)
    params_q = _params(rng)
    imgs = rng.integers(0, 256, (5, 784), dtype=np.uint8)
    want = None
    for backend in ("reference", "fused"):
        eng = SNNStreamEngine(params_q, cfg, batch_size=2, chunk_steps=5,
                              patience=10_000, seed=23, backend=backend)
        ids = [eng.submit(im) for im in imgs]
        results = eng.run()
        got = {rid: (results[rid].pred,
                     tuple(results[rid].spike_counts.tolist()))
               for rid in ids}
        if want is None:
            want = got
        else:
            assert got == want, backend
        for rid in ids:
            out = snn.snn_apply_int(params_q, jnp.asarray(imgs[rid][None]),
                                    prng.seed_state(23 + rid, (1, 784)),
                                    cfg, backend="reference")
            assert got[rid][0] == int(np.asarray(out["pred"])[0]), rid


def test_stream_engine_rejects_unknown_readout(rng):
    cfg = dataclasses.replace(SNN_CONFIG, readout="psychic")
    with pytest.raises(ValueError, match="readout"):
        SNNStreamEngine(_params(rng), cfg, batch_size=2)


def test_compaction_admits_queued_requests(rng):
    """batch_size=1 with 4 requests: each retirement must free the slot for
    the next queued image (continuous batching), and every request ends
    with a result."""
    cfg = dataclasses.replace(SNN_CONFIG, num_steps=8)
    params_q = _params(rng)
    eng = SNNStreamEngine(params_q, cfg, batch_size=1, chunk_steps=4,
                          patience=10_000, seed=2)
    imgs = rng.integers(0, 256, (4, 784), dtype=np.uint8)
    ids = [eng.submit(im) for im in imgs]
    assert eng.pending == 4
    results = eng.run()
    assert set(results) == set(ids)
    assert eng.pending == 0
    for rid in ids:
        assert results[rid].steps == cfg.num_steps
