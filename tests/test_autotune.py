"""Wall-clock autotuner + persisted dispatch cache (repro.tune).

Contracts under test:

  * the config fingerprint is stable across processes for the same
    serving identity, diverges on any identity-bearing field, and
    ignores training-only fields — tuned shapes can never leak across
    networks (a wrong-config lookup is a miss, not an adoption);
  * the versioned cache codec round-trips; corrupt, stale-codec and
    future-codec files are rejected with actionable messages and the
    engines FALL BACK to static defaults instead of crashing (mirroring
    the ``serve.wire`` codec pattern);
  * ``REPRO_DISPATCH_CACHE`` arms the single-device engine, the sharded
    engine (keyed by its 2-D mesh shape) and the serving tier, each
    recording a :class:`CacheDecision`;
  * a cache-armed engine is prediction-bit-identical to the static
    default engine — the cache may only change *when* work happens;
  * explicit constructor arguments beat tuned values knob by knob;
  * ``block_b`` plumbs through the fused stack op value-neutrally and
    invalid blocks are rejected;
  * the proportional controller shrink converges in one observation
    under heavy retirement, is exactly one step AT the trigger
    fraction, clamps at ``min_chunk_steps``, and remains a frozen-mode
    no-op (the PR 8 speculation-discard guard only needs *any* retune
    to land between dispatches — pinned in test_sharded_engine);
  * ``resolve_backend`` consults a cache hit under ``auto`` and ignores
    the cache for explicit backend requests;
  * the tuner itself: default measured first, winner never slower than
    the default, every candidate bit-identical to the baseline.
"""

import dataclasses
import json
import os
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.snn_mnist import SNN_CONFIG
from repro.core import snn
from repro.serve import (AdaptiveDispatchConfig, ShardedSNNStreamEngine,
                         SNNStreamEngine, TelemetryController)
from repro.serve.router import SNNServingTier
from repro.serve.telemetry import ChunkSummary, make_controller
from repro.tune import (ArrivalSchedule, AutotuneConfig, CacheDecision,
                        DispatchCache, DispatchCacheError, TunedShapes,
                        autotune_engine, cache_key, config_fingerprint,
                        decide_dispatch, device_kind_now,
                        fingerprint_payload, measure, serve_schedule,
                        write_cache)
from repro.tune.cache import CACHE_CODEC_VERSION, ENV_DISPATCH_CACHE


def _net(rng, sizes):
    return {"layers": [
        {"w_q": jnp.asarray(rng.integers(-256, 256, (a, b)), jnp.int16),
         "scale": jnp.float32(1.0)}
        for a, b in zip(sizes[:-1], sizes[1:])]}


def _small_cfg(**kw):
    kw.setdefault("layer_sizes", (16, 10))
    kw.setdefault("num_steps", 8)
    return dataclasses.replace(SNN_CONFIG, **kw)


def _tuned(**kw):
    base = dict(chunk_steps=3, block_b=8, lanes_per_device=4,
                spike_density_threshold=0.2, backend="reference")
    base.update(kw)
    return TunedShapes(**base)


def _write(tmp_path, cfg, tuned=None, mesh_shapes=((1,),),
           name="cache.json", backend="auto"):
    """Persist a cache armed for ``cfg`` on this host; returns the path."""
    tuned = tuned or _tuned()
    cache = DispatchCache()
    fp = config_fingerprint(cfg)
    for mesh in mesh_shapes:
        cache.put(cache_key(fp, device_kind_now(), mesh, backend), tuned)
    return cache.save(str(tmp_path / name))


def _bits(results):
    return {int(rid): (int(r.pred), int(r.steps),
                       tuple(r.spike_counts.tolist()))
            for rid, r in results.items()}


# ---------------------------------------------------------------------------
# fingerprint
# ---------------------------------------------------------------------------

def test_fingerprint_stable_and_diverges():
    cfg = _small_cfg()
    assert config_fingerprint(cfg) == config_fingerprint(
        dataclasses.replace(cfg))
    # every identity-bearing axis moves the fingerprint
    for other in (dataclasses.replace(cfg, num_steps=9),
                  dataclasses.replace(cfg, layer_sizes=(16, 12, 10)),
                  dataclasses.replace(cfg, readout="first_spike"),
                  dataclasses.replace(cfg, spike_density_threshold=0.3)):
        assert config_fingerprint(other) != config_fingerprint(cfg)
    # training-only fields do not (two configs that SERVE identically
    # share tuned shapes even if trained differently)
    assert config_fingerprint(dataclasses.replace(cfg, qat=not cfg.qat)) \
        == config_fingerprint(cfg)
    payload = fingerprint_payload(cfg)
    assert "qat" not in payload and payload["num_steps"] == 8


# ---------------------------------------------------------------------------
# cache codec: roundtrip + rejection ladder
# ---------------------------------------------------------------------------

def test_cache_roundtrip(tmp_path):
    cfg = _small_cfg()
    path = _write(tmp_path, cfg, mesh_shapes=((1,), (2, 1)))
    loaded = DispatchCache.load(path)
    d = loaded.lookup(fingerprint=config_fingerprint(cfg),
                      device_kind=device_kind_now(), mesh_shape=(1,),
                      backend=None)       # None normalizes to "auto"
    assert d.hit and d.tuned == _tuned() and d.source == path
    miss = loaded.lookup(fingerprint=config_fingerprint(cfg),
                         device_kind=device_kind_now(), mesh_shape=(4, 1),
                         backend="auto")
    assert not miss.hit and "static defaults" in miss.reason


def test_cache_rejects_corrupt_stale_future(tmp_path):
    corrupt = tmp_path / "corrupt.json"
    corrupt.write_text("{nope")
    with pytest.raises(DispatchCacheError, match="not valid JSON"):
        DispatchCache.load(str(corrupt))

    future = tmp_path / "future.json"
    future.write_text(json.dumps(
        {"codec_version": CACHE_CODEC_VERSION + 1, "entries": {}}))
    with pytest.raises(DispatchCacheError, match="newer build"):
        DispatchCache.load(str(future))

    stale = tmp_path / "stale.json"
    stale.write_text(json.dumps({"codec_version": 0, "entries": {}}))
    with pytest.raises(DispatchCacheError, match="regenerate"):
        DispatchCache.load(str(stale))

    noversion = tmp_path / "nover.json"
    noversion.write_text(json.dumps({"entries": {}}))
    with pytest.raises(DispatchCacheError, match="codec_version"):
        DispatchCache.load(str(noversion))

    badentry = tmp_path / "badentry.json"
    badentry.write_text(json.dumps({
        "codec_version": CACHE_CODEC_VERSION,
        "entries": {"k": {"chunk_steps": 0, "block_b": 8,
                          "lanes_per_device": 4,
                          "spike_density_threshold": 0.2,
                          "backend": "reference"}}}))
    with pytest.raises(DispatchCacheError, match="chunk_steps"):
        DispatchCache.load(str(badentry))
    badblock = tmp_path / "badblock.json"
    badblock.write_text(json.dumps({
        "codec_version": CACHE_CODEC_VERSION,
        "entries": {"k": {"chunk_steps": 2, "block_b": 12,
                          "lanes_per_device": 4,
                          "spike_density_threshold": 0.2,
                          "backend": "reference"}}}))
    with pytest.raises(DispatchCacheError, match="multiple of"):
        DispatchCache.load(str(badblock))


def test_engine_falls_back_on_bad_cache_never_crashes(tmp_path, rng):
    """Every rejected-cache shape constructs a working engine on static
    defaults, with one UserWarning and the reason recorded."""
    cfg = _small_cfg()
    params_q = _net(rng, cfg.layer_sizes)
    for blob in ("{nope",
                 json.dumps({"codec_version": CACHE_CODEC_VERSION + 1,
                             "entries": {}}),
                 json.dumps({"codec_version": 0, "entries": {}})):
        p = tmp_path / "bad.json"
        p.write_text(blob)
        with warnings.catch_warnings(record=True) as caught:
            warnings.simplefilter("always")
            eng = SNNStreamEngine(params_q, cfg, patience=10_000, seed=0,
                                  dispatch_cache=str(p))
        assert not eng.cache_decision.hit
        assert "static defaults" in eng.cache_decision.reason
        assert any(issubclass(w.category, UserWarning) for w in caught)
        eng.submit(np.full(cfg.n_in, 40, np.uint8))
        res = eng.run()
        assert res[0].steps == cfg.num_steps
    # a missing file likewise degrades, never raises
    eng = SNNStreamEngine(params_q, cfg, patience=2, seed=0,
                          dispatch_cache=str(tmp_path / "absent.json"))
    assert not eng.cache_decision.hit


def test_no_fingerprint_cross_leak(tmp_path, rng):
    """Shapes tuned for one network must never arm a different one."""
    cfg_a = _small_cfg()
    cfg_b = _small_cfg(num_steps=6)
    path = _write(tmp_path, cfg_a)
    params_b = _net(rng, cfg_b.layer_sizes)
    eng = SNNStreamEngine(params_b, cfg_b, patience=2, seed=0,
                          dispatch_cache=path)
    assert not eng.cache_decision.hit
    assert config_fingerprint(cfg_b) in eng.cache_decision.key
    # the same file is a hit for the config it was tuned for
    params_a = _net(rng, cfg_a.layer_sizes)
    assert SNNStreamEngine(params_a, cfg_a, patience=2, seed=0,
                           dispatch_cache=path).cache_decision.hit


# ---------------------------------------------------------------------------
# env resolution through the engines and the tier
# ---------------------------------------------------------------------------

def test_env_resolution_single_sharded_tier(tmp_path, rng, monkeypatch):
    cfg = _small_cfg()
    params_q = _net(rng, cfg.layer_sizes)
    n_dev = len(jax.devices())
    path = _write(tmp_path, cfg, mesh_shapes=((1,), (n_dev, 1)))
    monkeypatch.setenv(ENV_DISPATCH_CACHE, path)

    eng = SNNStreamEngine(params_q, cfg, patience=2, seed=0)
    assert eng.cache_decision.hit and eng.cache_decision.source == path
    assert eng.batch_size == _tuned().lanes_per_device
    assert eng.controller.chunk_steps == _tuned().chunk_steps
    assert eng.dispatch_threshold == \
        pytest.approx(_tuned().spike_density_threshold)

    sh = ShardedSNNStreamEngine(params_q, cfg, patience=2, seed=0)
    assert sh.cache_decision.hit
    assert f"mesh={n_dev}x1" in sh.cache_decision.key
    assert sh.batch_size == _tuned().lanes_per_device * n_dev

    tier = SNNServingTier(params_q, cfg, num_engines=2)
    assert len(tier.cache_decisions) == 2
    assert all(d.hit for d in tier.cache_decisions)

    # empty env = no cache, decision recorded as a miss
    monkeypatch.setenv(ENV_DISPATCH_CACHE, "")
    eng2 = SNNStreamEngine(params_q, cfg, patience=2, seed=0)
    assert not eng2.cache_decision.hit
    assert "no dispatch cache" in eng2.cache_decision.reason
    # False disables even an armed env (the tuner's own measurement mode)
    monkeypatch.setenv(ENV_DISPATCH_CACHE, path)
    eng3 = SNNStreamEngine(params_q, cfg, patience=2, seed=0,
                           dispatch_cache=False)
    assert not eng3.cache_decision.hit
    assert "explicitly disabled" in eng3.cache_decision.reason


def test_explicit_args_beat_tuned_knob_by_knob(tmp_path, rng):
    cfg = _small_cfg()
    params_q = _net(rng, cfg.layer_sizes)
    path = _write(tmp_path, cfg)
    eng = SNNStreamEngine(params_q, cfg, patience=2, seed=0,
                          chunk_steps=5, dispatch_cache=path)
    assert eng.cache_decision.hit
    assert eng.controller.chunk_steps == 5          # explicit wins
    assert eng.batch_size == _tuned().lanes_per_device  # tuned fills rest
    eng = SNNStreamEngine(params_q, cfg, patience=2, seed=0,
                          batch_size=6, dispatch_cache=path)
    assert eng.batch_size == 6
    assert eng.controller.chunk_steps == _tuned().chunk_steps


def test_cache_armed_engine_bit_identical(tmp_path, rng):
    cfg = _small_cfg()
    params_q = _net(rng, cfg.layer_sizes)
    path = _write(tmp_path, cfg)
    sched = ArrivalSchedule(n_requests=10, per_round=3, seed=5)
    pixels = sched.pixels(cfg.n_in)
    plain = SNNStreamEngine(params_q, cfg, patience=2, seed=0,
                            dispatch_cache=False)
    armed = SNNStreamEngine(params_q, cfg, patience=2, seed=0,
                            dispatch_cache=path)
    assert armed.cache_decision.hit
    assert _bits(serve_schedule(plain, sched, pixels)) \
        == _bits(serve_schedule(armed, sched, pixels))


# ---------------------------------------------------------------------------
# block_b plumb
# ---------------------------------------------------------------------------

def test_block_b_value_neutral_and_validated(rng):
    from repro.core import prng
    from repro.kernels import ops
    cfg = _small_cfg()
    params_q = _net(rng, cfg.layer_sizes)
    weights = tuple(l["w_q"] for l in params_q["layers"])
    px = jnp.asarray(rng.integers(0, 256, (8, cfg.n_in), dtype=np.uint8))
    st = prng.seed_state(3, px.shape)
    base = ops.fused_snn_stack_op(
        px, st, weights, num_steps=cfg.num_steps,
        decay_shift=cfg.lif.decay_shift, v_threshold=cfg.lif.v_threshold)
    for bb in (8, 16):
        out = ops.fused_snn_stack_op(
            px, st, weights, num_steps=cfg.num_steps,
            decay_shift=cfg.lif.decay_shift,
            v_threshold=cfg.lif.v_threshold, block_b=bb)
        np.testing.assert_array_equal(np.asarray(base["spike_counts"]),
                                      np.asarray(out["spike_counts"]))
        np.testing.assert_array_equal(np.asarray(base["active_adds"]),
                                      np.asarray(out["active_adds"]))
    for bad in (4, 12, 0):
        with pytest.raises(ValueError, match="block_b"):
            ops.fused_snn_stack_op(
                px, st, weights, num_steps=cfg.num_steps,
                decay_shift=cfg.lif.decay_shift,
                v_threshold=cfg.lif.v_threshold, block_b=bad)


def test_block_b_engine_bit_identical(rng):
    cfg = _small_cfg()
    params_q = _net(rng, cfg.layer_sizes)
    sched = ArrivalSchedule(n_requests=6, per_round=2, seed=9)
    pixels = sched.pixels(cfg.n_in)
    base = SNNStreamEngine(params_q, cfg, batch_size=4, patience=2, seed=0,
                           backend="fused", dispatch_cache=False)
    alt = SNNStreamEngine(params_q, cfg, batch_size=4, patience=2, seed=0,
                          backend="fused", block_b=16,
                          dispatch_cache=False)
    assert _bits(serve_schedule(base, sched, pixels)) \
        == _bits(serve_schedule(alt, sched, pixels))


# ---------------------------------------------------------------------------
# proportional controller shrink
# ---------------------------------------------------------------------------

def _summary(retired, active, chunk):
    return ChunkSummary(density_in=0.1, layer_densities=(0.1,),
                        executed_adds=0, tiles_skipped=0,
                        lanes_retired=retired, lanes_active=active,
                        active_lane_steps=active * chunk)


def test_proportional_shrink():
    cfg = AdaptiveDispatchConfig(adaptive=True, min_chunk_steps=2,
                                 max_chunk_steps=16,
                                 shrink_retire_frac=0.25)
    ctl = make_controller(cfg, spike_density_threshold=0.25,
                          chunk_steps=12, num_steps=20)
    # exactly AT the trigger fraction: one step, as before this PR
    ctl.observe(_summary(retired=2, active=8, chunk=12))
    assert ctl.chunk_steps == 11
    # every lane retired (frac 1.0 = 3 trigger-widths over): 4 steps
    ctl.observe(_summary(retired=8, active=8, chunk=11))
    assert ctl.chunk_steps == 7
    # half retired (frac 0.5 = 1 width over): 2 steps
    ctl.observe(_summary(retired=4, active=8, chunk=7))
    assert ctl.chunk_steps == 5
    # clamps at min_chunk_steps however heavy the overshoot
    ctl.observe(_summary(retired=8, active=8, chunk=5))
    ctl.observe(_summary(retired=8, active=8, chunk=2))
    assert ctl.chunk_steps == cfg.min_chunk_steps == 2


def test_shrink_frozen_noop():
    ctl = make_controller(AdaptiveDispatchConfig(adaptive=False),
                          spike_density_threshold=0.25, chunk_steps=12,
                          num_steps=20)
    ctl.observe(_summary(retired=8, active=8, chunk=12))
    assert ctl.chunk_steps == 12 and ctl.history == []


def test_controller_from_cache():
    tuned = _tuned(chunk_steps=6, spike_density_threshold=0.11)
    ctl = TelemetryController.from_cache(tuned, num_steps=20)
    assert ctl.frozen                       # env default stays frozen
    assert ctl.chunk_steps == 6
    assert ctl.dispatch_threshold == pytest.approx(0.11)
    adaptive = TelemetryController.from_cache(
        tuned, cfg_adaptive=AdaptiveDispatchConfig(adaptive=True),
        num_steps=20)
    assert not adaptive.frozen and adaptive.chunk_steps == 6


# ---------------------------------------------------------------------------
# resolve_backend cache consult
# ---------------------------------------------------------------------------

def test_resolve_backend_consults_cache():
    cfg = _small_cfg()
    cache = DispatchCache()
    key = cache_key(config_fingerprint(cfg), device_kind_now(), (1,),
                    "auto")
    cache.put(key, _tuned(backend="staged"))
    kw = dict(layer_sizes=cfg.layer_sizes, trace_steps=None)
    # auto + hit: the cached non-fused backend is adopted directly
    assert snn.resolve_backend(cfg, "auto", 1, dispatch_cache=cache,
                               **kw) == "staged"
    # a fused cached backend off-TPU fails its gate → normal chain
    cache.put(key, _tuned(backend="fused"))
    expect = "fused" if jax.default_backend() == "tpu" else "reference"
    assert snn.resolve_backend(cfg, "auto", 1, dispatch_cache=cache,
                               **kw) == expect
    # explicit requests ignore the cache entirely
    cache.put(key, _tuned(backend="staged"))
    assert snn.resolve_backend(cfg, "reference", 1, dispatch_cache=cache,
                               **kw) == "reference"
    # no entry for another mesh shape → normal chain
    assert snn.resolve_backend(cfg, "auto", 1, dispatch_cache=cache,
                               mesh_shape=(4, 1), **kw) \
        in ("reference", "fused", "fused_streamed", "staged")


def test_decide_dispatch_records_miss_reason(tmp_path):
    cfg = _small_cfg()
    d = decide_dispatch(None, cfg=cfg, backend=None, mesh_shape=(1,))
    assert isinstance(d, CacheDecision)
    if not os.environ.get(ENV_DISPATCH_CACHE):
        assert not d.hit and "no dispatch cache" in d.reason


# ---------------------------------------------------------------------------
# timing harness
# ---------------------------------------------------------------------------

def test_measure_contract():
    calls = []
    rec = measure(lambda: calls.append(1), repeats=3, warmup=2)
    assert len(calls) == 5                  # warmup + repeats, all called
    assert rec.repeats == 3 and rec.warmup == 2
    assert len(rec.samples_s) == 3
    assert rec.median_s == sorted(rec.samples_s)[1]
    assert rec.device_kind == device_kind_now()
    assert rec.interpret is False
    assert rec.to_json()["interpret"] is False
    assert rec.us == pytest.approx(rec.median_s * 1e6)
    with pytest.raises(ValueError):
        measure(lambda: None, repeats=0)


# ---------------------------------------------------------------------------
# the tuner end to end (tiny grid)
# ---------------------------------------------------------------------------

def test_autotune_engine_and_write_cache(tmp_path, rng):
    cfg = _small_cfg()
    params_q = _net(rng, cfg.layer_sizes)
    tc = AutotuneConfig(
        chunk_steps_grid=(2, 4), block_b_grid=(8,), lanes_grid=(4,),
        threshold_grid=(0.1, 0.4),
        schedule=ArrivalSchedule(n_requests=6, per_round=2, seed=3),
        repeats=2, warmup=1, max_candidates=4)
    result = autotune_engine(params_q, cfg, tune_cfg=tc, patience=2,
                             seed=0)
    assert result.bit_identical
    assert result.records[0]["candidate"] == result.default.to_json()
    assert result.tuned.seconds_per_retired_request \
        <= result.baseline_spr * (1 + 1e-9)
    assert result.fingerprint == config_fingerprint(cfg)
    # persist + arm an engine from the file the tuner wrote
    path = str(tmp_path / "tuned.json")
    write_cache(result, path, mesh_shapes=((1,),))
    eng = SNNStreamEngine(params_q, cfg, patience=2, seed=0,
                          dispatch_cache=path)
    assert eng.cache_decision.hit
    assert eng.controller.chunk_steps == result.tuned.chunk_steps
