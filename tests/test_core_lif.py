"""Bit-exactness of the integer LIF engine vs an independent NumPy golden
model, plus dynamics/pruning properties (paper §III-A/B/D, Fig. 4)."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import lif
from repro.core.lif import LIFConfig, run_lif_int


def numpy_golden_lif(spikes, w, cfg: LIFConfig, active_pruning=False):
    """Straight-line NumPy transcription of the RTL timestep."""
    T, B, _ = spikes.shape
    n_out = w.shape[1]
    v = np.full((B, n_out), cfg.v_rest, np.int64)
    en = np.ones((B, n_out), bool)
    out_spk = np.zeros((T, B, n_out), bool)
    v_tr = np.zeros((T, B, n_out), np.int64)
    for t in range(T):
        cur = spikes[t].astype(np.int64) @ w.astype(np.int64)
        cur = np.where(en, cur, 0)
        v_int = np.clip(v + cur, cfg.v_min, cfg.v_max)
        v_leak = v_int - (v_int >> cfg.decay_shift)
        fired = (v_leak >= cfg.v_threshold) & en
        v_new = np.where(fired, cfg.v_rest, v_leak)
        v = np.where(en, v_new, v)
        if active_pruning:
            en = en & ~fired
        out_spk[t] = fired
        v_tr[t] = v
    return out_spk, v_tr


@pytest.mark.parametrize("prune", [False, True])
@pytest.mark.parametrize("shift", [1, 4, 7])
def test_bit_exact_vs_numpy_golden(rng, prune, shift):
    T, B, n_in, n_out = 20, 5, 784, 10
    spikes = rng.integers(0, 2, (T, B, n_in)).astype(np.uint8)
    w = rng.integers(-256, 256, (n_in, n_out)).astype(np.int16)
    cfg = LIFConfig(decay_shift=shift, v_threshold=128)
    res = run_lif_int(jnp.asarray(spikes, bool), jnp.asarray(w), cfg,
                      active_pruning=prune)
    want_spk, want_v = numpy_golden_lif(spikes, w, cfg, prune)
    np.testing.assert_array_equal(np.asarray(res["spikes"]), want_spk)
    np.testing.assert_array_equal(np.asarray(res["v_trace"]), want_v)


def test_arithmetic_shift_is_floor_division_for_negatives():
    # two's-complement >> n == floor(x / 2^n), also for negative potentials
    v = jnp.asarray([-255, -17, -1, 0, 1, 17, 255], jnp.int32)
    got = v >> 4
    want = jnp.floor_divide(v, 16)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_membrane_decays_without_input():
    cfg = LIFConfig(decay_shift=2, v_threshold=10**6)
    spikes = jnp.zeros((10, 1, 4), bool)
    w = jnp.zeros((4, 3), jnp.int16)
    init = lif.LIFStateInt(v=jnp.full((1, 3), 1000, jnp.int32),
                           enable=jnp.ones((1, 3), bool))
    res = run_lif_int(spikes, w, cfg, init=init)
    v = np.asarray(res["v_trace"])[:, 0, 0]
    assert (np.diff(v) <= 0).all() and v[-1] < 1000 * 0.1


def test_fire_and_hard_reset(rng):
    cfg = LIFConfig(decay_shift=4, v_threshold=128, v_rest=0)
    # one input line with weight 200: crosses threshold on first spike
    spikes = jnp.ones((3, 1, 1), bool)
    w = jnp.asarray([[200]], jnp.int16)
    res = run_lif_int(spikes, w, cfg)
    spk = np.asarray(res["spikes"])[:, 0, 0]
    v = np.asarray(res["v_trace"])[:, 0, 0]
    assert spk[0] and v[0] == 0            # fired, then hard reset to V_rest


def test_active_pruning_freezes_after_first_spike(rng):
    cfg = LIFConfig(decay_shift=4, v_threshold=64)
    spikes = jnp.ones((10, 2, 8), bool)
    w = jnp.asarray(rng.integers(20, 40, (8, 4)), jnp.int16)
    res = run_lif_int(spikes, w, cfg, active_pruning=True)
    spk = np.asarray(res["spikes"])
    assert spk.sum(axis=0).max() <= 1      # each neuron fires at most once
    # pruned neurons stop accumulating: adds decrease over time
    adds = np.asarray(res["active_adds"]).sum(axis=-1)
    assert adds[-1] < adds[0]


def test_pruning_reduces_active_adds(rng):
    cfg = LIFConfig(decay_shift=4, v_threshold=64)
    spikes = jnp.asarray(rng.integers(0, 2, (20, 4, 100)), bool)
    w = jnp.asarray(rng.integers(-10, 30, (100, 10)), jnp.int16)
    on = run_lif_int(spikes, w, cfg, active_pruning=True)
    off = run_lif_int(spikes, w, cfg, active_pruning=False)
    assert (np.asarray(on["active_adds"]).sum()
            <= np.asarray(off["active_adds"]).sum())


def test_float_int_datapaths_agree_on_dynamics(rng):
    """Float twin follows the same trajectory shape (rate correlation)."""
    T, B, n_in, n_out = 30, 8, 64, 10
    spikes = rng.integers(0, 2, (T, B, n_in)).astype(np.float32)
    w = rng.normal(0, 0.3, (n_in, n_out)).astype(np.float32)
    fcfg = LIFConfig(decay_shift=4, v_threshold=1.0)  # type: ignore
    out_f, _, _ = lif.run_lif_float(jnp.asarray(spikes), jnp.asarray(w), fcfg)
    # integer path with the scaled weights (gain 128 = int threshold)
    w_q = jnp.asarray(np.round(w * 128), jnp.int16)
    icfg = LIFConfig(decay_shift=4, v_threshold=128)
    res = run_lif_int(jnp.asarray(spikes, bool), w_q, icfg)
    rf = np.asarray(out_f).mean(axis=0)
    ri = np.asarray(res["spikes"]).mean(axis=0)
    corr = np.corrcoef(rf.ravel(), ri.ravel())[0, 1]
    assert corr > 0.95
