"""Distribution substrate tests.

The multi-device cases run in a SUBPROCESS with
--xla_force_host_platform_device_count=8 so the rest of the suite keeps
seeing the single real CPU device (per the assignment's dry-run rules)."""

import json
import os
import subprocess
import sys
import textwrap

import jax

from repro.configs import get_config
from repro.distributed.partition import _is_spec_leaf, param_specs
from repro.launch.specs import abstract_params

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

# The mesh-based subprocess tests build their meshes through
# distributed.sharding.make_device_mesh, which falls back to the
# AxisType-free jax.make_mesh/Mesh constructors on the pinned 0.4.x jax —
# so they run (not skip) on every jax this repo supports.


def run_sub(code: str) -> str:
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               PYTHONPATH=SRC)
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def test_param_specs_cover_all_leaves_with_correct_rank():
    """Every param leaf gets a spec tuple with one entry per dim."""
    for arch in ("qwen3-4b", "jamba-v0.1-52b", "whisper-small",
                 "arctic-480b", "mamba2-1.3b"):
        cfg = get_config(arch)
        params = abstract_params(cfg)
        specs = param_specs(cfg, params)
        flat_p = jax.tree_util.tree_leaves(params)
        flat_s = jax.tree_util.tree_leaves(specs, is_leaf=_is_spec_leaf)
        assert len(flat_p) == len(flat_s)
        for p, s in zip(flat_p, flat_s):
            assert len(s) == len(p.shape), (p.shape, s)


def test_full_config_tp_divisibility():
    """Every model-sharded dim of every full config divides the TP=16 axis."""
    for arch in ("qwen3-4b", "nemotron-4-340b", "gemma2-9b", "llama3-8b",
                 "mamba2-1.3b", "jamba-v0.1-52b", "whisper-small",
                 "dbrx-132b", "arctic-480b", "llava-next-34b"):
        cfg = get_config(arch)
        params = abstract_params(cfg)
        specs = param_specs(cfg, params)
        flat = jax.tree_util.tree_flatten_with_path(
            specs, is_leaf=_is_spec_leaf)[0]
        pflat = jax.tree_util.tree_leaves(params)
        for (path, spec), leaf in zip(flat, pflat):
            for dim, ax in zip(leaf.shape, spec):
                if ax in ("heads", "mlp", "vocab", "experts"):
                    assert dim % 16 == 0, (arch, path, leaf.shape, spec)


def test_sharded_train_step_matches_single_device():
    """8-device pjit train step == single-device train step (same math)."""
    out = run_sub("""
        import jax, jax.numpy as jnp, numpy as np, json
        from repro.configs import get_reduced
        from repro.distributed.partition import (batch_specs, to_shardings,
                                                 train_state_specs)
        from repro.distributed.sharding import (make_device_mesh, make_rules,
                                                use_rules)
        from repro.train import TrainSettings, init_state
        from repro.train.step import make_train_step

        cfg = get_reduced("qwen3-4b")
        s = TrainSettings(num_microbatches=2)
        key = jax.random.PRNGKey(0)
        toks = jax.random.randint(key, (8, 17), 0, cfg.vocab_size)
        batch = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

        state = init_state(key, cfg, s)
        ref, mref = jax.jit(make_train_step(cfg, s))(state, batch)

        mesh = make_device_mesh((2, 4), ("data", "model"))
        rules = make_rules(mesh, fsdp=True)
        with mesh, use_rules(rules):
            st_specs = train_state_specs(cfg, cfg.optimizer, state)
            st_sh = to_shardings(mesh, rules, st_specs, state)
            b_sh = to_shardings(mesh, rules, batch_specs(batch), batch)
            state2 = init_state(key, cfg, s)
            state2 = jax.device_put(state2, st_sh)
            batch2 = jax.device_put(batch, b_sh)
            step = jax.jit(make_train_step(cfg, s),
                           in_shardings=(st_sh, b_sh),
                           out_shardings=(st_sh, None))
            got, mgot = step(state2, batch2)
        err = max(float(jnp.max(jnp.abs(a - b)))
                  for a, b in zip(jax.tree.leaves(ref.params),
                                  jax.tree.leaves(got.params)))
        print(json.dumps({"err": err,
                          "loss_ref": float(mref["loss"]),
                          "loss_got": float(mgot["loss"])}))
    """)
    res = json.loads(out.strip().splitlines()[-1])
    assert abs(res["loss_ref"] - res["loss_got"]) < 1e-4
    assert res["err"] < 5e-3


def test_compressed_psum_int8_error_feedback():
    """int8 EF psum over a 'pod' axis: bounded per-step error, and the
    error-feedback residual keeps the *running average* unbiased."""
    out = run_sub("""
        import jax, jax.numpy as jnp, numpy as np, json
        from functools import partial
        from jax.sharding import PartitionSpec as P
        from repro.distributed.sharding import make_device_mesh, shard_map_compat
        from repro.optim import compression

        mesh = make_device_mesh((8,), ("pod",))
        grads = {"w": jnp.asarray(
            np.random.default_rng(0).normal(0, 1, (8, 64, 32)).astype(np.float32))}

        @partial(shard_map_compat, mesh=mesh,
                 in_specs=(P("pod"), P("pod")), out_specs=(P("pod"), P("pod")))
        def step(g, err):
            gl = {"w": g[0]}
            st = compression.CompressionState(error={"w": err[0]})
            out, new_st = compression.compressed_psum(gl, st, "pod")
            return out["w"][None], new_st.error["w"][None]

        err = jnp.zeros_like(grads["w"])
        exact = jnp.mean(grads["w"], axis=0)
        total_comp = 0.0
        for it in range(8):
            comp, err = step(grads["w"], err)
            total_comp = total_comp + comp[0]
        # per-step error bounded by quantization step
        amax = float(jnp.max(jnp.abs(grads["w"])))
        step_err = float(jnp.max(jnp.abs(comp[0] - exact)))
        # running average converges (error feedback keeps it unbiased)
        avg_err = float(jnp.max(jnp.abs(total_comp / 8 - exact)))
        print(json.dumps({"step_err": step_err, "avg_err": avg_err,
                          "scale": amax / 127.0}))
    """)
    res = json.loads(out.strip().splitlines()[-1])
    assert res["step_err"] <= 2.5 * res["scale"]
    assert res["avg_err"] <= res["step_err"] / 2 + res["scale"] * 0.2


def test_shard_helper_drops_nondivisible_axes():
    from repro.distributed.sharding import ShardingRules
    rules = ShardingRules({"batch": "data", "mlp": "model"},
                          {"data": 16, "model": 16})
    spec = rules.spec_for_shape((1, 7, 32), "batch", None, "mlp")
    assert spec == jax.sharding.PartitionSpec(None, None, "model")
