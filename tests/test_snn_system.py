"""End-to-end behaviour of the paper's system: training → quantization →
bit-exact integer inference → paper-claim checks (shortened budgets; the
full-budget numbers live in benchmarks/)."""


import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.snn_mnist import SNN_CONFIG, SNN_CONFIG_PRUNED
from repro.core import prng, snn
from repro.core.train_snn import int_accuracy, train_bptt, train_converted
from repro.data import digits


@pytest.fixture(scope="module")
def trained():
    ds = digits.make_dataset(n_train=2000, n_test=400, seed=0)
    params = train_bptt(SNN_CONFIG, ds, steps=400, seed=0)
    params_q = snn.quantize_params(params, SNN_CONFIG)
    return params, params_q, ds


def test_accuracy_reaches_paper_band(trained):
    """Paper: ~89% by T=10. Short-budget training must clear 85%."""
    _, params_q, ds = trained
    acc, _ = int_accuracy(params_q, SNN_CONFIG, ds.x_test, ds.y_test,
                          num_steps=10)
    assert acc >= 0.85, acc


def test_accuracy_monotone_ish_in_T(trained):
    _, params_q, ds = trained
    accs = [int_accuracy(params_q, SNN_CONFIG, ds.x_test[:200],
                         ds.y_test[:200], num_steps=t)[0]
            for t in (1, 5, 10, 20)]
    assert accs[-1] >= accs[0]
    assert accs[2] >= 0.8


def test_quantized_codes_are_9bit(trained):
    _, params_q, _ = trained
    w = np.asarray(params_q["layers"][0]["w_q"])
    assert w.min() >= -256 and w.max() <= 255      # 9-bit signed codes
    assert w.dtype == np.int16


def test_int_engine_deterministic(trained):
    _, params_q, ds = trained
    px = jnp.asarray((ds.x_test[:32] * 255).astype(np.uint8))
    st = prng.seed_state(5, px.shape)
    a = snn.snn_apply_int(params_q, px, st, SNN_CONFIG)
    b = snn.snn_apply_int(params_q, px, st, SNN_CONFIG)
    np.testing.assert_array_equal(np.asarray(a["pred"]), np.asarray(b["pred"]))
    np.testing.assert_array_equal(np.asarray(a["v_trace"]),
                                  np.asarray(b["v_trace"]))


def test_active_pruning_engine(trained):
    """Pruned engine: ≤1 spike/neuron, fewer adds, sane accuracy."""
    _, params_q, ds = trained
    px = jnp.asarray((ds.x_test[:200] * 255).astype(np.uint8))
    st = prng.seed_state(5, px.shape)
    on = snn.snn_apply_int(params_q, px, st, SNN_CONFIG_PRUNED)
    off = snn.snn_apply_int(params_q, px, st, SNN_CONFIG)
    assert int(np.asarray(on["spike_counts"]).max()) <= 1
    assert (np.asarray(on["active_adds"]).sum()
            < np.asarray(off["active_adds"]).sum())
    acc_on = (np.asarray(on["pred"]) == ds.y_test[:200]).mean()
    assert acc_on >= 0.6        # first-spike readout is coarser but sane


def test_conversion_route_works():
    ds = digits.make_dataset(n_train=2000, n_test=300, seed=1)
    params = train_converted(SNN_CONFIG, ds, steps=400, seed=0)
    params_q = snn.quantize_params(params, SNN_CONFIG)
    acc, _ = int_accuracy(params_q, SNN_CONFIG, ds.x_test, ds.y_test,
                          num_steps=20)
    assert acc >= 0.75, acc     # Diehl conversion, single FC layer


def test_ops_count_zero_multiplications(trained):
    """Table II's headline: the integer engine executes no multiplies —
    structurally true (masked adds); energy model accounts it that way."""
    from repro.core import energy
    _, params_q, ds = trained
    acc, aux = int_accuracy(params_q, SNN_CONFIG, ds.x_test[:100],
                            ds.y_test[:100], num_steps=10)
    ops = energy.snn_op_counts(np.asarray([aux["adds_per_img"]]),
                               num_steps=10)
    assert ops.multiplications == 0
    assert ops.additions < 784 * 10 * 10   # far below the dense MAC grid


def test_seed_changes_spikes_not_accuracy(trained):
    _, params_q, ds = trained
    a, _ = int_accuracy(params_q, SNN_CONFIG, ds.x_test[:300],
                        ds.y_test[:300], seed=1)
    b, _ = int_accuracy(params_q, SNN_CONFIG, ds.x_test[:300],
                        ds.y_test[:300], seed=999)
    assert abs(a - b) < 0.05    # stochastic encoder, stable classifier
