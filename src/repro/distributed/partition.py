"""Parameter / cache / batch partition specs, derived from tree paths.

The model code names its parameters consistently (wq/wk/wv/wo, w1/w2/w3,
router, embed, ...), so partition specs are assigned by a single rule table
keyed on the leaf's path — the t5x/MaxText "named rules" approach, without
maintaining a parallel spec tree by hand.

Logical axes used (resolved to mesh axes by ShardingRules):
  fsdp    → "data"   ZeRO-3 parameter sharding
  heads   → "model"  TP over attention q-heads / mamba heads
  kv      → None     GQA kv-heads replicated (kv < TP degree)
  mlp     → "model"  TP over FFN hidden / mamba inner
  vocab   → "model"  TP over embedding / lm-head vocab
  experts → "model"  EP over MoE experts
  batch   → data axes; kv_seq → "model" (decode-time flash-decoding split)
"""

from __future__ import annotations

from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding

from .sharding import ShardingRules

__all__ = ["param_logical_axes", "param_specs", "cache_specs", "batch_specs",
           "opt_state_specs", "to_shardings", "train_state_specs"]

Pytree = Any


def _is_spec_leaf(x) -> bool:
    """Plain tuple of axis names = a spec leaf (NamedTuples are nodes)."""
    return (isinstance(x, tuple) and not hasattr(x, "_fields")
            and all(e is None or isinstance(e, (str, tuple)) for e in x))


def _path_names(path) -> list[str]:
    names = []
    for entry in path:
        if hasattr(entry, "key"):
            names.append(str(entry.key))
        elif hasattr(entry, "name"):
            names.append(str(entry.name))
        elif hasattr(entry, "idx"):
            names.append(str(entry.idx))
    return names


def param_logical_axes(cfg, path, leaf) -> tuple:
    """Logical axis names for one parameter leaf (without the blocks axis)."""
    names = _path_names(path)
    last = names[-1]
    stacked = "blocks" in names or "layers" in names
    ndim = len(leaf.shape) - (1 if stacked else 0)

    def out(*axes):
        assert len(axes) == ndim, (names, leaf.shape, axes)
        return ((None,) + axes) if stacked else axes

    if last == "embed":
        return ("vocab", "fsdp")
    if last == "pos_embed":
        return (None, "fsdp")
    if last == "lm_head":
        return ("fsdp", "vocab")

    if last == "wq":
        return out("fsdp", "heads", None)
    if last in ("wk", "wv"):
        kvp = leaf.shape[-2]
        ax = "heads" if kvp == cfg.padded_num_heads else "kv"
        return out("fsdp", ax, None)
    if last == "wo":
        return out("heads", None, "fsdp")
    if last in ("q_norm", "k_norm"):
        return out(None)

    if last == "router":
        return out("fsdp", None)
    if last in ("w1", "w3"):
        if ndim == 3:                       # MoE (E, D, F)
            return out("experts", "fsdp", None)
        return out("fsdp", "mlp")
    if last == "w2":
        if ndim == 3:                       # MoE (E, F, D)
            return out("experts", None, "fsdp")
        return out("mlp", "fsdp")

    # mamba
    if last in ("wz", "wx"):
        return out("fsdp", "mlp")
    if last in ("wb", "wc"):
        return out("fsdp", None)
    if last == "wdt":
        return out("fsdp", "heads")
    if last == "conv_x":
        return out(None, "mlp")
    if last in ("conv_b", "conv_c"):
        return out(None, None)
    if last in ("A_log", "D", "dt_bias"):
        return out("heads")
    if last == "out":
        return out("mlp", "fsdp")
    if last == "norm":                      # mamba gated-norm scale (d_inner)
        return out("mlp")

    # norm scales/biases and anything 1-D: replicated
    return out(*([None] * ndim))


def param_specs(cfg, params_shape: Pytree) -> Pytree:
    """PartitionSpec tree (logical axes, unresolved) for a params tree."""
    return jax.tree_util.tree_map_with_path(
        lambda p, l: param_logical_axes(cfg, p, l), params_shape)


def cache_specs(cfg, cache_shape: Pytree, *, decode: bool = True) -> Pytree:
    """Logical axes for a KV/SSM cache tree (stacked over blocks)."""

    def one(path, leaf):
        names = _path_names(path)
        last = names[-1]
        if last in ("k", "v"):
            # (nb, B, S, KV, hd): shard cache sequence for decode (flash-
            # decoding); prefill keeps heads on model via activation specs.
            return (None, "batch", "kv_seq" if decode else None, None, None)
        if last == "ssm":
            return (None, "batch", "heads", None, None)
        if last == "conv_x":
            return (None, "batch", None, "mlp")
        if last in ("conv_b", "conv_c"):
            return (None, "batch", None, None)
        return (None,) * leaf.ndim

    return jax.tree_util.tree_map_with_path(one, cache_shape)


def batch_specs(batch_shape: Pytree) -> Pytree:
    def one(path, leaf):
        return ("batch",) + (None,) * (leaf.ndim - 1)
    return jax.tree_util.tree_map_with_path(one, batch_shape)


def opt_state_specs(opt_name: str, pspecs: Pytree, params_shape: Pytree,
                    min_dim_factored: int = 128) -> Pytree:
    """Spec tree for optimizer state, mirroring optim.optimizer layouts."""
    from ..optim.optimizer import AdafactorState, AdamWState, SGDState

    scalar = ()

    if opt_name == "adamw":
        return AdamWState(step=scalar, mu=pspecs, nu=pspecs)
    if opt_name == "sgd":
        return SGDState(step=scalar, momentum=pspecs)
    if opt_name == "adafactor":
        def factored(l):
            if l.ndim < 2 or l.shape[-1] < min_dim_factored:
                return False
            lead = int(np.prod(l.shape[:-1]))
            return lead >= min_dim_factored

        def vr(spec, l):
            return tuple(spec[:-1]) if factored(l) else tuple(spec)

        def vc(spec, l):
            if factored(l):
                return tuple(spec[:-2]) + tuple(spec[-1:])
            return tuple(spec[:1]) if l.ndim >= 1 else (None,)

        return AdafactorState(
            step=scalar,
            vr=jax.tree.map(vr, pspecs, params_shape,
                            is_leaf=_is_spec_leaf),
            vc=jax.tree.map(vc, pspecs, params_shape,
                            is_leaf=_is_spec_leaf),
        )
    raise ValueError(opt_name)


def train_state_specs(cfg, opt_name: str, state_shape) -> Any:
    """Specs for a train.TrainState (step, params, opt_state[, comp_err])."""
    pspecs = param_specs(cfg, state_shape.params)
    ospecs = opt_state_specs(opt_name, pspecs, state_shape.params)
    comp = pspecs if state_shape.comp_err is not None else None
    return type(state_shape)(step=(), params=pspecs, opt_state=ospecs,
                             comp_err=comp)


def to_shardings(mesh: Mesh, rules: ShardingRules, spec_tree: Pytree,
                 shape_tree: Pytree | None = None):
    """Resolve logical-axis tuples to NamedShardings on ``mesh``.

    With ``shape_tree`` given, axes that don't divide the dim are dropped
    (e.g. "batch" sharding of a global_batch=1 long-context decode).
    """
    if shape_tree is None:
        return jax.tree.map(
            lambda axes: NamedSharding(mesh, rules.spec(*axes)),
            spec_tree, is_leaf=_is_spec_leaf)

    def one(axes, leaf):
        return NamedSharding(mesh, rules.spec_for_shape(leaf.shape, *axes))

    return jax.tree.map(one, spec_tree, shape_tree, is_leaf=_is_spec_leaf)
