"""Logical-axis sharding: the single place where parallelism is decided.

Model code annotates arrays with *logical* axis names ("batch", "seq",
"embed", "heads", "kv", "mlp", "vocab", "experts", "layers", ...).  A
:class:`ShardingRules` maps logical names → mesh axes, and is installed as a
context so the same model code runs (a) unsharded on one CPU device, (b) on
the single-pod 16×16 mesh, (c) on the 2×16×16 multi-pod mesh — only the
rules change.

Default production mapping (see DESIGN.md §4):
  batch   → ("pod","data")   data parallel (pod axis = pure DP)
  vocab   → "model"          TP on embedding/lm-head
  heads   → "model"          TP on attention q-heads (padded to multiples)
  mlp     → "model"          TP on FFN hidden
  experts → "model"          EP (expert parallel)
  kv_seq  → "model"          seq-sharded KV cache (flash-decoding style)
  embed   → None  (activations) / "data" for FSDP'd parameters
  seq     → None  (sequence-parallel variants map it to "model")
"""

from __future__ import annotations

import contextlib
import threading
from dataclasses import dataclass, field

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = ["ShardingRules", "use_rules", "current_rules", "logical_spec",
           "shard", "named_sharding", "DEFAULT_RULES", "FSDP_RULES",
           "make_device_mesh", "make_2d_device_mesh", "shard_map_compat"]


def make_device_mesh(shape: tuple, axis_names: tuple, *,
                     devices=None) -> Mesh:
    """``jax.make_mesh`` with an ``AxisType``-free fallback.

    Newer jax exposes ``jax.sharding.AxisType`` and accepts an
    ``axis_types=`` kwarg; the pinned 0.4.x container has neither.  All
    meshes in this repo use Auto axes (the 0.4.x default), so the fallback
    — plain ``jax.make_mesh(shape, axis_names)``, or a direct ``Mesh`` over
    ``mesh_utils.create_device_mesh`` on releases predating ``make_mesh``
    — constructs the semantically identical mesh.
    """
    if hasattr(jax.sharding, "AxisType"):
        return jax.make_mesh(
            shape, axis_names, devices=devices,
            axis_types=(jax.sharding.AxisType.Auto,) * len(axis_names))
    if hasattr(jax, "make_mesh"):
        return jax.make_mesh(shape, axis_names, devices=devices)
    from jax.experimental import mesh_utils
    devs = mesh_utils.create_device_mesh(shape, devices=devices)
    return Mesh(devs, axis_names)


def make_2d_device_mesh(data_devices: int | None = None,
                        model_devices: int = 1, *,
                        axis_names: tuple[str, str] = ("data", "model"),
                        devices=None) -> Mesh:
    """Validated 2-D (data × model) mesh for the serving engines.

    The data axis shards the lane (batch) tile; the model axis shards
    each layer's output-neuron dimension (weight columns) with spike
    exchange at layer boundaries.  ``data_devices=None`` absorbs every
    device the ``model_devices``-way model axis leaves over, so
    ``make_2d_device_mesh(model_devices=4)`` on an 8-device host yields a
    2×4 mesh.  A ``model_devices=1`` mesh is still built 2-D (a trailing
    1-sized model axis) — the lane partition specs never mention the
    model axis, so every 1-D data-mesh consumer composes unchanged.
    """
    pool = list(jax.devices()) if devices is None else list(devices)
    if len(set(axis_names)) != 2:
        raise ValueError(f"axis_names must be two distinct names, got "
                         f"{axis_names!r}")
    model_devices = int(model_devices)
    if model_devices < 1:
        raise ValueError(f"model_devices={model_devices} must be >= 1")
    if data_devices is None:
        if len(pool) % model_devices:
            raise ValueError(
                f"{len(pool)} devices do not divide over a "
                f"{model_devices}-way model axis — pass data_devices "
                f"explicitly or change the model width")
        data_devices = len(pool) // model_devices
    data_devices = int(data_devices)
    if data_devices < 1:
        raise ValueError(f"data_devices={data_devices} must be >= 1")
    need = data_devices * model_devices
    if need > len(pool):
        raise ValueError(
            f"{data_devices}×{model_devices} (data × model) mesh needs "
            f"{need} devices but only {len(pool)} are visible")
    return make_device_mesh((data_devices, model_devices),
                            tuple(axis_names), devices=pool[:need])


def shard_map_compat(f, mesh: Mesh, in_specs, out_specs):
    """Version-portable ``shard_map`` (per-device SPMD mapping).

    Replication checking is disabled: the streaming SNN chunk runs Pallas
    calls inside the mapped body, which have no replication rule on the
    jax releases this repo pins.
    """
    sm = getattr(jax, "shard_map", None)
    if sm is None:
        from jax.experimental.shard_map import shard_map as sm
    for kw in ({"check_rep": False}, {"check_vma": False}, {}):
        try:
            return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      **kw)
        except TypeError:
            continue
    raise RuntimeError("no usable shard_map in this jax installation")


@dataclass(frozen=True)
class ShardingRules:
    """Mapping logical axis name → mesh axis (str | tuple | None)."""

    rules: dict = field(default_factory=dict)
    axis_sizes: dict = field(default_factory=dict)  # mesh axis → size

    def spec(self, *logical_axes: str | None) -> P:
        return P(*(self.rules.get(a) if a is not None else None
                   for a in logical_axes))

    def ways(self, logical_axis: str | None) -> int:
        """How many shards the resolved mesh axes would create."""
        entry = self.rules.get(logical_axis) if logical_axis else None
        if entry is None:
            return 1
        axes = entry if isinstance(entry, tuple) else (entry,)
        n = 1
        for a in axes:
            n *= self.axis_sizes.get(a, 1)
        return n

    def spec_for_shape(self, shape: tuple, *logical_axes) -> P:
        """Like spec(), but drops axes that do not divide the dim."""
        entries = []
        for dim, a in zip(shape, logical_axes):
            w = self.ways(a)
            ok = w > 1 and dim % w == 0
            entries.append(self.rules.get(a) if (a and ok) else None)
        return P(*entries)

    def with_overrides(self, **kw) -> "ShardingRules":
        new = dict(self.rules)
        new.update(kw)
        return ShardingRules(new, self.axis_sizes)


# Production defaults. "batch" resolves to whatever data axes exist; rules
# are built per-mesh by `make_rules` so single- and multi-pod agree.
def make_rules(mesh: Mesh | None, *, fsdp: bool = True,
               sequence_parallel: bool = False) -> ShardingRules:
    if mesh is None:
        return ShardingRules({})
    axes = mesh.axis_names
    data_axes = tuple(a for a in ("pod", "data") if a in axes) or None
    model = "model" if "model" in axes else None
    rules = {
        "batch": data_axes,
        "seq": model if sequence_parallel else None,
        "seq_act": model if sequence_parallel else None,
        "embed": None,
        "heads": model,
        "kv": None,            # kv heads replicated within a TP group
        "head_dim": None,
        "mlp": model,
        "vocab": model,
        "experts": model,
        "expert_cap": data_axes,   # token capacity dim rides the data axes
        "kv_seq": model,       # decode-time KV cache sequence sharding
        "layers": None,
        "conv": None,
        "state": None,
        # parameter-only axes (FSDP shards the non-TP dim of weights):
        "fsdp": ("data" if (fsdp and "data" in axes) else None),
    }
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    return ShardingRules(rules, sizes)


DEFAULT_RULES = ShardingRules({})
FSDP_RULES = DEFAULT_RULES  # alias; see make_rules(fsdp=True)

_ctx = threading.local()


@contextlib.contextmanager
def use_rules(rules: ShardingRules):
    prev = getattr(_ctx, "rules", None)
    _ctx.rules = rules
    try:
        yield rules
    finally:
        _ctx.rules = prev


def current_rules() -> ShardingRules | None:
    return getattr(_ctx, "rules", None)


def logical_spec(*logical_axes) -> P:
    rules = current_rules()
    if rules is None:
        return P()
    return rules.spec(*logical_axes)


def shard(x: jax.Array, *logical_axes) -> jax.Array:
    """Annotate activation sharding; no-op outside a rules/mesh context.

    Axes that do not evenly divide the corresponding dim are dropped
    (e.g. "batch" on a global_batch=1 long-context decode).
    """
    rules = current_rules()
    if rules is None or not rules.rules:
        return x
    try:
        spec = rules.spec_for_shape(x.shape, *logical_axes)
        return jax.lax.with_sharding_constraint(x, spec)
    except (ValueError, RuntimeError):
        # No mesh context (e.g. pure-CPU unit test): annotation is advisory.
        return x


def named_sharding(mesh: Mesh, *logical_axes) -> NamedSharding:
    rules = current_rules() or ShardingRules({})
    return NamedSharding(mesh, rules.spec(*logical_axes))
