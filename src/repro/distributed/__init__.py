"""Distribution substrate: logical-axis sharding rules and partition specs."""

from . import partition, sharding
from .partition import (batch_specs, cache_specs, opt_state_specs,
                        param_specs, to_shardings, train_state_specs)
from .sharding import (ShardingRules, make_device_mesh, make_rules, shard,
                       shard_map_compat, use_rules)

__all__ = ["partition", "sharding", "batch_specs", "cache_specs",
           "opt_state_specs", "param_specs", "to_shardings",
           "train_state_specs", "ShardingRules", "make_rules", "shard",
           "use_rules", "make_device_mesh", "shard_map_compat"]
