"""dbrx-132b — fine-grained MoE, 16 experts top-4 every layer, GQA 48q/8kv.
[hf:databricks/dbrx-base]"""
from .base import ArchConfig
from .registry import register

CONFIG = register(ArchConfig(
    name="dbrx-132b", family="moe",
    num_layers=40, d_model=6144, num_heads=48, num_kv_heads=8,
    d_ff=10752, vocab_size=100352,
    moe_num_experts=16, moe_top_k=4, moe_period=1,
    activation="silu", rope_theta=5e5,
    optimizer="adafactor",
))
