"""Architecture + run configuration dataclasses.

One :class:`ArchConfig` instance fully determines a model; the 10 assigned
architectures live in sibling modules (``qwen3_4b.py`` …) and register
themselves in ``configs.registry``.  ``reduced()`` derives the CPU-smoke
variant of any config (same family/feature flags, tiny dims).
"""

from __future__ import annotations

from dataclasses import dataclass, replace

import jax.numpy as jnp

__all__ = ["ArchConfig", "ShapeConfig", "SHAPES", "reduced"]


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                     # dense|ssm|hybrid|moe|audio|vlm|snn
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0               # 0 ⇒ d_model // num_heads

    # attention features
    qk_norm: bool = False                  # qwen3
    attn_softcap: float | None = None      # gemma2 (50.0)
    final_softcap: float | None = None     # gemma2 (30.0)
    sliding_window: int | None = None      # gemma2 local layers (4096)
    local_global_period: int = 0           # gemma2: 2 ⇒ alternate local/global
    rope_theta: float = 1e4
    activation: str = "silu"
    norm_type: str = "rmsnorm"             # rmsnorm|layernorm
    tie_embeddings: bool = False
    sandwich_norm: bool = False            # gemma2: post-block norms
    embed_scale: bool = False              # gemma2: ×sqrt(d_model)
    max_position: int = 0                  # >0 ⇒ learned pos-emb, no RoPE

    # MoE
    moe_num_experts: int = 0
    moe_top_k: int = 0
    moe_period: int = 1                    # every k-th layer is MoE (jamba: 2)
    moe_dense_residual: bool = False       # arctic: dense FFN in parallel
    dense_residual_ff: int = 0             # arctic: width of the dense branch
    moe_capacity_factor: float = 1.25
    moe_group: int = 1024                  # dispatch group size (memory knob)

    # SSM (mamba2)
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_conv: int = 4
    ssm_chunk: int = 256
    attn_layer_period: int = 0             # jamba: 8 ⇒ 1 attn per 8 layers
    attn_layer_offset: int = 4             # position of attn inside the block

    # encoder-decoder (whisper)
    encoder_layers: int = 0
    encoder_seq: int = 0                   # frames after conv frontend (stub)

    # frontend stubs
    frontend: str | None = None            # None|"audio"|"vision"
    num_patches: int = 0                   # vision stub: patches per image

    # numerics / memory plan
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    optimizer: str = "adamw"               # adamw|adafactor (giant archs)
    remat: bool = True
    scan_layers: bool = True

    # padding for TP divisibility (0 ⇒ num_heads); see DESIGN.md §8
    padded_num_heads: int = 0

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // max(self.num_heads, 1))
        if self.padded_num_heads == 0:
            object.__setattr__(self, "padded_num_heads", self.num_heads)

    @property
    def padded_vocab(self) -> int:
        """Vocab rounded up to a multiple of 256 for clean TP sharding."""
        return (self.vocab_size + 255) // 256 * 256

    @property
    def d_inner(self) -> int:               # mamba inner width
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def is_encdec(self) -> bool:
        return self.encoder_layers > 0

    @property
    def dtype(self):
        return jnp.dtype(self.compute_dtype)

    def param_count(self) -> int:
        """Analytic parameter count (for 6ND model-FLOPs accounting)."""
        d, L, V = self.d_model, self.num_layers, self.vocab_size
        hd = self.head_dim
        nq, nkv = self.num_heads, self.num_kv_heads
        total = V * d                                     # embed
        if not self.tie_embeddings:
            total += V * d                                # lm head

        def attn_params():
            return d * nq * hd + 2 * d * nkv * hd + nq * hd * d

        n_mats = 3 if self.activation in ("silu", "gelu") else 2

        def dense_ffn(ff=None):
            return n_mats * d * (ff or self.d_ff)

        def moe_ffn():
            per = n_mats * d * self.d_ff
            return self.moe_num_experts * per + d * self.moe_num_experts

        def mamba_params():
            di, N, H = self.d_inner, self.ssm_state, self.ssm_heads
            return (d * (2 * di + 2 * N + H)   # wz,wx,wb,wc,wdt projections
                    + self.ssm_conv * (di + 2 * N)
                    + di * d + 3 * H + di)     # out_proj, A/D/dt_bias, norm

        for i in range(L):
            is_attn = True
            if self.attn_layer_period:
                is_attn = (i % self.attn_layer_period) == self.attn_layer_offset
            if self.family == "ssm":
                is_attn = False
            total += attn_params() if is_attn else mamba_params()
            if self.family == "ssm":
                continue                       # mamba2: no separate FFN
            is_moe = self.moe_num_experts > 0 and (i % self.moe_period == self.moe_period - 1)
            total += moe_ffn() if is_moe else dense_ffn()
            if is_moe and self.moe_dense_residual:
                total += dense_ffn(self.dense_residual_ff or self.d_ff)
            total += 2 * d                     # norms
        total += d                             # final norm
        if self.is_encdec:
            # encoder layers: self-attn + ffn (+ cross-attn already in dec L)
            total += self.encoder_layers * (attn_params() + dense_ffn() + 2 * d)
            total += self.num_layers * attn_params()   # decoder cross-attn
        return int(total)

    def active_param_count(self) -> int:
        """Active (per-token) params — MoE counts only top-k experts."""
        if self.moe_num_experts == 0:
            return self.param_count()
        full = self.param_count()
        per_expert = (3 if self.activation in ("silu", "gelu") else 2) \
            * self.d_model * self.d_ff
        n_moe_layers = sum(
            1 for i in range(self.num_layers)
            if (i % self.moe_period == self.moe_period - 1))
        inactive = n_moe_layers * (self.moe_num_experts - self.moe_top_k) * per_expert
        return int(full - inactive)


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str          # train|prefill|decode


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


def reduced(cfg: ArchConfig, *, layers: int = 2, d_model: int = 64,
            vocab: int = 256) -> ArchConfig:
    """CPU-smoke variant: same family & feature flags, tiny dims."""
    heads = max(1, min(cfg.num_heads, 4))
    kv = max(1, min(cfg.num_kv_heads, heads))
    kw = dict(
        name=cfg.name + "-reduced",
        num_layers=max(layers, cfg.attn_layer_period or layers),
        d_model=d_model,
        num_heads=heads,
        num_kv_heads=kv,
        head_dim=d_model // heads,
        d_ff=d_model * 2,
        vocab_size=vocab,
        padded_num_heads=heads,
        compute_dtype="float32",
    )
    if cfg.moe_num_experts:
        kw["moe_num_experts"] = min(cfg.moe_num_experts, 4)
        kw["moe_top_k"] = min(cfg.moe_top_k, 2)
        kw["moe_group"] = 16
        # no capacity drops at smoke scale: keeps decode == prefill exact
        kw["moe_capacity_factor"] = 8.0
        if cfg.moe_dense_residual:
            kw["dense_residual_ff"] = d_model
    if cfg.ssm_state:
        kw["ssm_state"] = 16
        kw["ssm_head_dim"] = 16
        kw["ssm_chunk"] = 8
    if cfg.encoder_layers:
        kw["encoder_layers"] = 2
        kw["encoder_seq"] = 16
    if cfg.max_position:
        kw["max_position"] = 256
    if cfg.num_patches:
        kw["num_patches"] = 8
    if cfg.sliding_window:
        kw["sliding_window"] = 8
    return replace(cfg, **kw)
