"""arctic-480b — 128 experts top-2 + dense residual branch, GQA 56q/8kv.
[hf:Snowflake/snowflake-arctic-base; hf]  Heads pad 56→64 for TP."""
from .base import ArchConfig
from .registry import register

CONFIG = register(ArchConfig(
    name="arctic-480b", family="moe",
    num_layers=35, d_model=7168, num_heads=56, num_kv_heads=8,
    d_ff=4864, vocab_size=32000,
    moe_num_experts=128, moe_top_k=2, moe_period=1,
    moe_dense_residual=True, dense_residual_ff=7168 * 2,
    activation="silu", padded_num_heads=64,
    optimizer="adafactor",
))
