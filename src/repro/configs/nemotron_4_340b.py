"""nemotron-4-340b — dense, GQA (96q/8kv), squared-ReLU (ungated) FFN.
[arXiv:2402.16819]  Giant: adafactor states + FSDP (DESIGN.md §4)."""
from .base import ArchConfig
from .registry import register

CONFIG = register(ArchConfig(
    name="nemotron-4-340b", family="dense",
    num_layers=96, d_model=18432, num_heads=96, num_kv_heads=8,
    d_ff=73728, vocab_size=256000,
    activation="squared_relu", rope_theta=1e4,
    optimizer="adafactor",
))
