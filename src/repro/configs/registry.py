"""Config registry: ``get_config(name)`` / ``get_reduced(name)`` / list.

Every assigned architecture registers an :class:`ArchConfig` here; the
paper's own model (snn-mnist) is a separate family handled by
``configs.snn_mnist``.
"""

from __future__ import annotations

from .base import ArchConfig, SHAPES, reduced

__all__ = ["register", "get_config", "get_reduced", "list_archs", "SHAPES",
           "shape_cells", "cell_is_live"]

_REGISTRY: dict[str, ArchConfig] = {}


def register(cfg: ArchConfig) -> ArchConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ArchConfig:
    _ensure_loaded()
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; have {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def get_reduced(name: str, **kw) -> ArchConfig:
    return reduced(get_config(name), **kw)


def list_archs() -> list[str]:
    _ensure_loaded()
    return sorted(_REGISTRY)


# Archs that can run the 524k-token decode cell (sub-quadratic context):
# SSM (O(1) state) and the mamba-dominated hybrid.  Pure full-attention
# archs skip it (DESIGN.md §7).
LONG_CONTEXT_OK = {"mamba2-1.3b", "jamba-v0.1-52b"}


def cell_is_live(arch: str, shape: str) -> bool:
    if shape == "long_500k":
        return arch in LONG_CONTEXT_OK
    return True


def shape_cells() -> list[tuple[str, str]]:
    """All 40 (arch, shape) cells; use cell_is_live to filter runnable ones."""
    _ensure_loaded()
    return [(a, s) for a in list_archs() if _REGISTRY[a].family != "snn"
            for s in SHAPES]


_loaded = False


def _ensure_loaded():
    global _loaded
    if _loaded:
        return
    _loaded = True
    from . import (arctic_480b, dbrx_132b, gemma2_9b,  # noqa: F401
                   jamba_v01_52b, llama3_8b, llava_next_34b,  # noqa: F401
                   mamba2_1p3b, nemotron_4_340b, qwen3_4b,  # noqa: F401
                   snn_mnist, whisper_small)  # noqa: F401
