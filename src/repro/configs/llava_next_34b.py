"""llava-next-34b — VLM: dense LM backbone + anyres patch embeddings.
Vision tower is a STUB: input_specs() provides precomputed patch embeddings
(B, num_patches, d_model). [hf:llava-hf/llava-v1.6]  Heads pad 56→64."""
from .base import ArchConfig
from .registry import register

CONFIG = register(ArchConfig(
    name="llava-next-34b", family="vlm",
    num_layers=60, d_model=7168, num_heads=56, num_kv_heads=8,
    d_ff=20480, vocab_size=64000,
    activation="silu", rope_theta=5e6,
    frontend="vision", num_patches=2880, padded_num_heads=64,
    optimizer="adafactor",
))
