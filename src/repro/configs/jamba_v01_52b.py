"""jamba-v0.1-52b — hybrid Mamba+attention 1:7, MoE 16e top-2 every other
layer. [arXiv:2403.19887; hf]  8-layer block: attn at offset 4, rest mamba;
odd layers MoE."""
from .base import ArchConfig
from .registry import register

CONFIG = register(ArchConfig(
    name="jamba-v0.1-52b", family="hybrid",
    num_layers=32, d_model=4096, num_heads=32, num_kv_heads=8,
    d_ff=14336, vocab_size=65536,
    moe_num_experts=16, moe_top_k=2, moe_period=2,
    ssm_state=16, ssm_head_dim=64, ssm_expand=2, ssm_conv=4, ssm_chunk=256,
    attn_layer_period=8, attn_layer_offset=4,
    optimizer="adafactor",
))
