"""snn-mnist — the paper's own model (Poisson-encoded LIF classifier).

Not an LM: 784→10 fully connected LIF layer, 20-timestep window, 8-bit
weights (9-bit signed codes), shift-4 decay (β = 1/16), threshold 128.
Registered so ``--arch snn-mnist`` selects it in the launchers; the 40
dry-run cells are the 10 LM archs — this config is exercised by the paper
benchmarks and its own batch-parallel dry-run entry.
"""

from __future__ import annotations

from dataclasses import dataclass

from ..core.lif import LIFConfig
from ..core.snn import SNNConfig
from .base import ArchConfig
from .registry import register

# LM-shaped registry entry (family "snn") so arch listings include it.
CONFIG = register(ArchConfig(
    name="snn-mnist", family="snn",
    num_layers=1, d_model=784, num_heads=1, num_kv_heads=1,
    head_dim=1, d_ff=0, vocab_size=10,
    optimizer="adamw", remat=False, scan_layers=False,
))

# The real configuration object used by the SNN engine.  ``backend`` picks
# the integer-engine realisation (fused megakernel | staged Pallas kernels |
# pure-jnp reference); "auto" resolves to fused on TPU, reference on CPU.
SNN_CONFIG = SNNConfig(
    layer_sizes=(784, 10),
    num_steps=20,
    lif=LIFConfig(decay_shift=4, v_threshold=128, v_rest=0),
    weight_bits=8,
    qat=True,
    readout="count",
    active_pruning=False,
    backend="auto",
)

SNN_CONFIG_PRUNED = SNNConfig(
    layer_sizes=(784, 10),
    num_steps=20,
    lif=LIFConfig(decay_shift=4, v_threshold=128, v_rest=0),
    weight_bits=8,
    qat=True,
    readout="first_spike",
    active_pruning=True,
    backend="auto",
)

# Streaming-serving mesh knobs (serve.ShardedSNNStreamEngine).  The lane
# tile is data-parallel: ``axis_name`` shards the batch axis of every
# LaneState leaf.  ``model_devices > 1`` adds a second mesh axis
# (``model_axis_name``) that shards each layer's output-neuron weight
# columns across devices (spike exchange at layer boundaries) — the 2-D
# (data × model) mesh that keeps WIDE-class stacks VMEM-resident.
# ``num_devices=None`` lets the data axis absorb every device the model
# axis leaves over; the engine asserts divisibility.
@dataclass(frozen=True)
class SNNStreamMeshConfig:
    axis_name: str = "data"
    num_devices: int | None = None     # data-axis width (None = the rest)
    model_axis_name: str = "model"
    model_devices: int = 1             # model-axis width (1 = pure data)
    # None defers to the engine: a dispatch-cache hit supplies the tuned
    # value, otherwise the historical defaults (8 lanes, 4-step chunks).
    lanes_per_device: int | None = None  # slots per DATA-axis device block
    chunk_steps: int | None = None     # window steps per device dispatch
    overlap: bool = True               # speculative chunk k+1 dispatch
    # Telemetry-driven dispatch tuning (serve.telemetry): None reads the
    # REPRO_ADAPTIVE_DISPATCH env default — frozen (static threshold +
    # chunk length, zero readbacks) unless the env flips it on.  Adaptive
    # mode is value-neutral: it only moves performance-facing knobs.
    adaptive: "AdaptiveDispatchConfig | None" = None
    # Persisted autotuner output (repro.tune): a DispatchCache instance, a
    # path to the versioned JSON file, or None to read REPRO_DISPATCH_CACHE
    # (False disables even the env).  Tuned shapes fill the None knobs
    # above; explicit knob values always win.
    dispatch_cache: "object | None" = None


SNN_STREAM_MESH = SNNStreamMeshConfig()

# Priority classes of the serving tier, ordered lowest → highest: under
# overload the router sheds from the left (batch work is the first to
# go), deadline admission applies to every class equally.  Deployments
# that need more tiers replace the tuple wholesale — the router treats it
# as an ordered vocabulary, nothing is hard-coded to these three names.
TIER_PRIORITY_CLASSES = ("batch", "standard", "interactive")


# Serving-tier knobs (serve.SNNServingTier): the fleet front end that
# sprays requests across ``num_engines`` per-host engines, applies the
# SLO admission policy, and drives zero-drain weight rollouts.  Deadlines
# are in window steps (the currency of RequestResult.steps); ``None``
# means the class of requests carries no deadline and is never
# deadline-shed.  ``queue_limit`` caps each engine's host queue — the
# overload boundary where lowest-priority-first shedding starts;
# ``None`` queues without bound (and only deadline shedding applies).
@dataclass(frozen=True)
class SNNServingTierConfig:
    num_engines: int = 2
    # None defers to the per-engine dispatch-cache decision (tuned shapes
    # on a hit, the historical 8-lane / 4-step defaults otherwise).
    lanes_per_engine: int | None = None
    chunk_steps: int | None = None
    priority_classes: tuple = TIER_PRIORITY_CLASSES
    default_priority: str = "standard"
    default_deadline_steps: int | None = None
    queue_limit: int | None = 64
    shedding: bool = True
    # sharded=True carves the visible devices into num_engines contiguous
    # slices — each engine is a ShardedSNNStreamEngine over its own mesh
    # (a simulated per-host lane mesh; CI runs two 4-device hosts).
    sharded: bool = False
    devices_per_engine: int | None = None
    adaptive: "AdaptiveDispatchConfig | None" = None
    # Fault tolerance (serve.faults): ``fault_plan`` arms a deterministic
    # injection schedule (a FaultPlan, or the compact env-spec string
    # "seed=11,dispatch=0.03"); None leaves engines to arm from the
    # REPRO_FAULT_PLAN env, and injection-free otherwise.  ``fault_cfg``
    # tunes the recovery policy (retry budget, backoff, demotion /
    # promotion thresholds, watchdog deadline, quarantine count); None
    # uses FaultToleranceConfig defaults.
    fault_plan: "FaultPlan | str | None" = None
    fault_cfg: "FaultToleranceConfig | None" = None
    # Persisted autotuner output (repro.tune), threaded to every engine in
    # the fleet: DispatchCache | path | None (env REPRO_DISPATCH_CACHE) |
    # False (disabled).  Per-engine hit/miss decisions are recorded on
    # ``SNNServingTier.cache_decisions``.
    dispatch_cache: "object | None" = None
    # Recovery knobs, exposed individually so deployments tune them
    # without constructing a FaultToleranceConfig by hand.  ``None``
    # keeps the FaultToleranceConfig default; any non-None value is
    # folded into the config built by :meth:`resolve_fault_cfg` (which
    # also runs the validation: every count >= 1, retries/respawns >= 0,
    # heartbeat_deadline_s > heartbeat_interval_s > 0).  Setting any of
    # these alongside an explicit ``fault_cfg`` is a configuration
    # conflict and raises — one source of truth per deployment.
    watchdog_chunks: int | None = None
    max_retries: int | None = None
    backoff_base: int | None = None
    backoff_max: int | None = None
    demote_after: int | None = None
    promote_after: int | None = None
    fail_after: int | None = None
    quarantine_after: int | None = None
    heartbeat_interval_s: float | None = None
    heartbeat_deadline_s: float | None = None
    max_respawns: int | None = None

    _KNOB_FIELDS = ("watchdog_chunks", "max_retries", "backoff_base",
                    "backoff_max", "demote_after", "promote_after",
                    "fail_after", "quarantine_after",
                    "heartbeat_interval_s", "heartbeat_deadline_s",
                    "max_respawns")

    def resolve_fault_cfg(self):
        """The effective FaultToleranceConfig: ``fault_cfg`` verbatim, or
        one built from the individual knob overrides (validated by the
        FaultToleranceConfig constructor)."""
        overrides = {k: getattr(self, k) for k in self._KNOB_FIELDS
                     if getattr(self, k) is not None}
        if self.fault_cfg is not None:
            if overrides:
                raise ValueError(
                    f"SNNServingTierConfig sets both fault_cfg and the "
                    f"individual recovery knobs {sorted(overrides)} — "
                    f"pick one source of truth (put the values in the "
                    f"fault_cfg, or drop it and use the knobs)")
            return self.fault_cfg
        if not overrides:
            return None
        from ..serve.faults import FaultToleranceConfig
        return FaultToleranceConfig(**overrides)

    def __post_init__(self):
        # eager validation: a bad knob combination fails at config
        # construction, not at first tier/cluster build
        self.resolve_fault_cfg()


SNN_SERVING_TIER = SNNServingTierConfig()


def make_serving_tier(params_q: dict, snn_cfg: SNNConfig = SNN_CONFIG,
                      knobs: SNNServingTierConfig = SNN_SERVING_TIER,
                      **tier_kw):
    """Build a ``serve.SNNServingTier`` from the knobs — the deployment
    surface for the fleet front end, mirroring ``make_stream_engine``."""
    from ..serve import SNNServingTier
    return SNNServingTier(
        params_q, snn_cfg, num_engines=knobs.num_engines,
        lanes_per_engine=knobs.lanes_per_engine,
        chunk_steps=knobs.chunk_steps,
        priority_classes=knobs.priority_classes,
        default_priority=knobs.default_priority,
        default_deadline_steps=knobs.default_deadline_steps,
        queue_limit=knobs.queue_limit, shedding=knobs.shedding,
        sharded=knobs.sharded,
        devices_per_engine=knobs.devices_per_engine,
        adaptive=knobs.adaptive, fault_plan=knobs.fault_plan,
        fault_cfg=knobs.resolve_fault_cfg(),
        dispatch_cache=knobs.dispatch_cache, **tier_kw)


# Process-level cluster knobs (serve.ClusterCoordinator): the failover
# tier above the in-process serving tier — ``num_workers`` engine
# subprocesses supervised over heartbeat RPC, lane checkpoints shipped
# every round, accounting write-ahead to ``ledger_dir``.  The recovery
# policy (heartbeat interval/deadline, respawn budget) comes from the
# tier knobs' resolve_fault_cfg() via make_cluster.
@dataclass(frozen=True)
class SNNClusterConfig:
    num_workers: int = 2
    lanes_per_worker: int = 4
    chunk_steps: int = 4
    backend: str | None = None
    fault_plan: "FaultPlan | str | None" = None
    ledger_dir: str | None = None      # required at build time

    def __post_init__(self):
        if self.num_workers < 1:
            raise ValueError(
                f"num_workers must be >= 1, got {self.num_workers}")
        if self.lanes_per_worker < 1:
            raise ValueError(
                f"lanes_per_worker must be >= 1, got "
                f"{self.lanes_per_worker}")


SNN_CLUSTER = SNNClusterConfig()


def make_cluster(params_q: dict, snn_cfg: SNNConfig = SNN_CONFIG,
                 knobs: SNNClusterConfig = SNN_CLUSTER,
                 tier_knobs: SNNServingTierConfig = SNN_SERVING_TIER,
                 **cluster_kw):
    """Build a ``serve.ClusterCoordinator`` from the knobs.

    The recovery policy threads through ``tier_knobs.resolve_fault_cfg()``
    — the same validated source the in-process tier uses, so heartbeat /
    respawn / watchdog settings are configured once for both paths.
    """
    from ..serve import ClusterCoordinator
    cluster_kw.setdefault("ledger_dir", knobs.ledger_dir)
    return ClusterCoordinator(
        params_q, snn_cfg, num_workers=knobs.num_workers,
        lanes_per_worker=knobs.lanes_per_worker,
        chunk_steps=knobs.chunk_steps, backend=knobs.backend,
        fault_plan=knobs.fault_plan,
        fault_cfg=tier_knobs.resolve_fault_cfg(),
        **cluster_kw)


def make_stream_mesh(knobs: SNNStreamMeshConfig = SNN_STREAM_MESH):
    """Build the serving lane mesh from the knobs (AxisType-free fallback
    via distributed.sharding, so it works on the pinned 0.4.x jax).

    ``model_devices == 1`` keeps the historical 1-D data mesh;
    ``model_devices > 1`` builds the validated 2-D (data × model) mesh.
    """
    import jax

    if knobs.model_devices > 1:
        from ..distributed.sharding import make_2d_device_mesh
        return make_2d_device_mesh(
            data_devices=knobs.num_devices,
            model_devices=knobs.model_devices,
            axis_names=(knobs.axis_name, knobs.model_axis_name))
    from ..distributed.sharding import make_device_mesh
    n = knobs.num_devices or len(jax.devices())
    return make_device_mesh((n,), (knobs.axis_name,),
                            devices=jax.devices()[:n])


def make_stream_engine(params_q: dict, snn_cfg: SNNConfig = SNN_CONFIG,
                       knobs: SNNStreamMeshConfig = SNN_STREAM_MESH,
                       **engine_kw):
    """Build a ``serve.ShardedSNNStreamEngine`` from the mesh knobs — the
    one place a deployment configures the lane mesh (knob changes flow
    through here; constructing the engine directly bypasses them)."""
    from ..serve import ShardedSNNStreamEngine
    return ShardedSNNStreamEngine(
        params_q, snn_cfg, mesh=make_stream_mesh(knobs),
        axis_name=knobs.axis_name,
        model_axis_name=knobs.model_axis_name,
        lanes_per_device=knobs.lanes_per_device,
        chunk_steps=knobs.chunk_steps, overlap=knobs.overlap,
        adaptive=knobs.adaptive,
        dispatch_cache=knobs.dispatch_cache, **engine_kw)


# Hidden-layer stack (beyond the paper's topology): exercises the
# multi-layer fused megakernel — inter-layer spike traffic stays on-chip,
# which is where staged execution pays 2·T·B·N HBM bytes per hop.
SNN_CONFIG_DEEP = SNNConfig(
    layer_sizes=(784, 128, 64, 10),
    num_steps=20,
    lif=LIFConfig(decay_shift=4, v_threshold=128, v_rest=0),
    weight_bits=8,
    qat=True,
    readout="count",
    active_pruning=False,
    backend="auto",
)

# Widened SNN_CONFIG_DEEP whose int8-packed resident footprint
# (~13.5 MiB by kernels.fused_snn.stack_vmem_bytes for the padded
# 896→2048→2048→128 stack — the packed weights alone are 12 MiB) exceeds
# the fused kernel's VMEM residency budget: single-device ``auto`` on TPU
# resolves it to the ``fused_streamed`` backend — weights stay in HBM and
# are double-buffered through VMEM slab scratch, still ONE launch per
# chunk — and an explicit single-device ``fused`` request raises.  On a
# 4-way model axis (``resolve_backend(..., model_shards=4)``) each device
# holds only its 2048/4-column weight shard (~3.4 MiB), the per-shard
# footprint fits the budget, and ``auto`` resolves to VMEM-resident
# ``fused`` — the stack the 2-D (data × model) mesh exists for.
SNN_CONFIG_WIDE = SNNConfig(
    layer_sizes=(784, 2048, 2048, 10),
    num_steps=20,
    lif=LIFConfig(decay_shift=4, v_threshold=128, v_rest=0),
    weight_bits=8,
    qat=True,
    readout="count",
    active_pruning=False,
    backend="auto",
)
