"""Configs: ArchConfig/ShapeConfig dataclasses + per-arch modules + registry."""

from .base import SHAPES, ArchConfig, ShapeConfig, reduced
from .registry import (cell_is_live, get_config, get_reduced, list_archs,
                       shape_cells)

__all__ = ["SHAPES", "ArchConfig", "ShapeConfig", "reduced", "cell_is_live",
           "get_config", "get_reduced", "list_archs", "shape_cells"]
