"""gemma2-9b — dense, local/global alternating, logit softcaps, sandwich
norms, gated-gelu, tied embeddings. [arXiv:2408.00118; hf]"""
from .base import ArchConfig
from .registry import register

CONFIG = register(ArchConfig(
    name="gemma2-9b", family="dense",
    num_layers=42, d_model=3584, num_heads=16, num_kv_heads=8,
    d_ff=14336, vocab_size=256000,
    activation="gelu", attn_softcap=50.0, final_softcap=30.0,
    sliding_window=4096, local_global_period=2,
    sandwich_norm=True, embed_scale=True, tie_embeddings=True,
    rope_theta=1e4, optimizer="adamw",
))
