"""llama3-8b — dense, GQA (32q/8kv), 128k vocab. [arXiv:2407.21783]"""
from .base import ArchConfig
from .registry import register

CONFIG = register(ArchConfig(
    name="llama3-8b", family="dense",
    num_layers=32, d_model=4096, num_heads=32, num_kv_heads=8,
    d_ff=14336, vocab_size=128256,
    activation="silu", rope_theta=5e5,
    optimizer="adamw",
))
