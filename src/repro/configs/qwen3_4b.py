"""qwen3-4b — dense, GQA (32q/8kv), qk-norm. [hf:Qwen/Qwen3-8B; hf]"""
from .base import ArchConfig
from .registry import register

CONFIG = register(ArchConfig(
    name="qwen3-4b", family="dense",
    num_layers=36, d_model=2560, num_heads=32, num_kv_heads=8,
    d_ff=9728, vocab_size=151936,
    qk_norm=True, activation="silu", rope_theta=1e6,
    optimizer="adamw",
))
