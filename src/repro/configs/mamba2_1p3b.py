"""mamba2-1.3b — attention-free SSM (SSD). [arXiv:2405.21060]
48L, d_model 2048, d_inner 4096, 64 heads of 64, state 128."""
from .base import ArchConfig
from .registry import register

CONFIG = register(ArchConfig(
    name="mamba2-1.3b", family="ssm",
    num_layers=48, d_model=2048, num_heads=0, num_kv_heads=0,
    head_dim=1,  # unused (attention-free)
    d_ff=0, vocab_size=50280,
    ssm_state=128, ssm_head_dim=64, ssm_expand=2, ssm_conv=4, ssm_chunk=256,
    optimizer="adamw",
))
