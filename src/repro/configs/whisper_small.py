"""whisper-small — encoder-decoder audio transformer, MHA (12 heads),
learned positions, layernorm.  Conv frontend is a STUB: input_specs()
provides precomputed frame embeddings (B, 1500, 768). [arXiv:2212.04356]
Q/KV heads pad 12→16 for TP (DESIGN.md §8)."""
from .base import ArchConfig
from .registry import register

CONFIG = register(ArchConfig(
    name="whisper-small", family="audio",
    num_layers=12, d_model=768, num_heads=12, num_kv_heads=12,
    d_ff=3072, vocab_size=51865,
    encoder_layers=12, encoder_seq=1500,
    norm_type="layernorm", activation="gelu", max_position=32768,
    frontend="audio", padded_num_heads=16,
    optimizer="adamw",
))
