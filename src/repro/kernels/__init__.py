"""Pallas TPU kernels for the paper's compute hot-spots.

Three kernels (each: <name>.py kernel + ops.py wrapper + ref.py oracle):
  poisson_encode — fused xorshift32 PRNG + comparator (paper Fig. 2)
  lif_step       — fused T-step integrate→leak→fire→reset (paper Fig. 1)
  spike_matmul   — event-driven ΣW·S (masked-add and MXU realisations)

Validated in interpret mode on CPU; BlockSpecs target TPU VMEM tiling.
"""

from . import lif_step, ops, poisson_encode, ref, spike_matmul

__all__ = ["lif_step", "ops", "poisson_encode", "ref", "spike_matmul"]
