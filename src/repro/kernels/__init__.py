"""Pallas TPU kernels for the paper's compute hot-spots.

Four kernels (each: <name>.py kernel + ops.py wrapper + ref.py oracle):
  poisson_encode — fused xorshift32 PRNG + comparator (paper Fig. 2)
  lif_step       — fused T-step integrate→leak→fire→reset (paper Fig. 1)
  spike_matmul   — event-driven ΣW·S (masked-add and MXU realisations)
  fused_snn      — encode→LIF megakernel: the whole window in one launch,
                   spikes never written to HBM (paper §V-B locality)

Validated in interpret mode on CPU; BlockSpecs target TPU VMEM tiling.
"""

from . import fused_snn, lif_step, ops, poisson_encode, ref, spike_matmul

__all__ = ["fused_snn", "lif_step", "ops", "poisson_encode", "ref",
           "spike_matmul"]
