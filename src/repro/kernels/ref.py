"""Pure-jnp oracles for every Pallas kernel in this package.

These are the semantics contracts: each kernel's test sweeps shapes/dtypes
and asserts exact equality (integer datapaths) or allclose (float) against
these functions.  They intentionally re-derive the math independently of
``repro.core`` so that kernel bugs and core bugs cannot cancel.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["poisson_encode_ref", "lif_forward_ref", "spike_matmul_ref",
           "fused_snn_ref", "fused_snn_stack_ref", "weight_pack_ref"]


def weight_pack_ref(w_q):
    """Oracle for ``kernels.fused_snn.pack_weights``.

    The fused kernels keep weights resident as two int8 planes —
    ``hi = w >> 1`` (arithmetic shift) and ``lo = w & 1`` — reconstructed
    per tile as ``w = 2*hi + lo``.  That split is exact for every code in
    the paper's signed 9-bit range [-256, 255] (``quantize_params``'
    output contract) and for nothing wider: hi must fit int8.  Returns
    ``(hi, lo)`` int8 numpy planes, derived independently of the kernel
    module.
    """
    import numpy as np
    w = np.asarray(w_q, np.int64)
    if w.min() < -256 or w.max() > 255:
        raise ValueError("weight codes outside the signed 9-bit range "
                         "[-256, 255] cannot be int8-packed exactly")
    hi = w >> 1
    lo = w - 2 * hi                        # ∈ {0, 1}
    return hi.astype(np.int8), lo.astype(np.int8)


def poisson_encode_ref(pixels_u8: jax.Array, state_u32: jax.Array,
                       num_steps: int):
    """xorshift32-driven Poisson encoding. Returns (spikes u8 (T,...), state)."""

    def step(s, _):
        s = s ^ (s << 13)
        s = s ^ (s >> 17)
        s = s ^ (s << 5)
        r = (s >> 24).astype(jnp.uint8)
        return s, (pixels_u8 > r).astype(jnp.uint8)

    state_f, spikes = jax.lax.scan(step, state_u32, None, length=num_steps)
    return spikes, state_f


def lif_forward_ref(spikes_t: jax.Array, w_q: jax.Array, *, decay_shift: int,
                    v_threshold: int, v_rest: int = 0,
                    v_min: int = -(1 << 20), v_max: int = (1 << 20) - 1,
                    active_pruning: bool = False):
    """T-step integer LIF layer.

    spikes_t: (T, B, N_in) uint8/bool; w_q: (N_in, N_out) int.
    Returns (out_spikes u8 (T,B,N_out), v_trace i32 (T,B,N_out), v_final i32).
    """
    T, B, _ = spikes_t.shape
    n_out = w_q.shape[1]
    v0 = jnp.full((B, n_out), v_rest, jnp.int32)
    en0 = jnp.ones((B, n_out), bool)

    def step(carry, s_t):
        v, en = carry
        cur = jnp.dot(s_t.astype(jnp.int32), w_q.astype(jnp.int32))
        cur = jnp.where(en, cur, 0)
        v_int = jnp.clip(v + cur, v_min, v_max)
        v_leak = v_int - (v_int >> decay_shift)
        fired = jnp.logical_and(v_leak >= v_threshold, en)
        v_new = jnp.where(fired, jnp.int32(v_rest), v_leak)
        v_new = jnp.where(en, v_new, v)
        if active_pruning:
            en = jnp.logical_and(en, jnp.logical_not(fired))
        return (v_new, en), (fired.astype(jnp.uint8), v_new)

    (v_f, _), (spk, vtr) = jax.lax.scan(step, (v0, en0), spikes_t)
    return spk, vtr, v_f


def fused_snn_ref(pixels_u8: jax.Array, state_u32: jax.Array,
                  w_q: jax.Array, *, num_steps: int, decay_shift: int,
                  v_threshold: int, v_rest: int = 0,
                  v_min: int = -(1 << 20), v_max: int = (1 << 20) - 1,
                  active_pruning: bool = False):
    """Oracle for the fused encode→LIF megakernel (fused_snn.py).

    Re-derives the whole window — PRNG, comparator, Σ W·S, leak, fire,
    reset, pruning gate, add counter — in one scan, independently of both
    ``repro.core`` and the staged oracles above.

    Returns (counts i32 (B,N_out), v_trace i32 (T,B,N_out),
             first_spike_t i32 (B,N_out), v_final i32 (B,N_out),
             active_adds i32 (T,B), state u32 (B,N_in)).
    """
    B = pixels_u8.shape[0]
    n_out = w_q.shape[1]
    w = w_q.astype(jnp.int32)
    v0 = jnp.full((B, n_out), v_rest, jnp.int32)
    en0 = jnp.ones((B, n_out), bool)
    cnt0 = jnp.zeros((B, n_out), jnp.int32)
    first0 = jnp.full((B, n_out), num_steps, jnp.int32)

    def step(carry, t):
        s, v, en, cnt, first = carry
        s = s ^ (s << 13)
        s = s ^ (s >> 17)
        s = s ^ (s << 5)
        spk = pixels_u8 > (s >> 24).astype(jnp.uint8)
        cur = jnp.dot(spk.astype(jnp.int32), w)
        cur = jnp.where(en, cur, 0)
        v_int = jnp.clip(v + cur, v_min, v_max)
        v_leak = v_int - (v_int >> decay_shift)
        fired = jnp.logical_and(v_leak >= v_threshold, en)
        v_new = jnp.where(fired, jnp.int32(v_rest), v_leak)
        v_new = jnp.where(en, v_new, v)
        first = jnp.where(jnp.logical_and(fired, first == num_steps),
                          t.astype(jnp.int32), first)
        cnt = cnt + fired.astype(jnp.int32)
        adds = (jnp.sum(spk.astype(jnp.int32), axis=-1)
                * jnp.sum(en.astype(jnp.int32), axis=-1))
        if active_pruning:
            en = jnp.logical_and(en, jnp.logical_not(fired))
        return (s, v_new, en, cnt, first), (v_new, adds)

    (s_f, v_f, _, cnt_f, first_f), (vtr, adds_t) = jax.lax.scan(
        step, (state_u32, v0, en0, cnt0, first0), jnp.arange(num_steps))
    return cnt_f, vtr, first_f, v_f, adds_t, s_f


def fused_snn_stack_ref(pixels_u8: jax.Array, state_u32: jax.Array,
                        weights, *, num_steps: int, chunk_steps: int | None = None,
                        decay_shift: int, v_threshold: int, v_rest: int = 0,
                        v_min: int = -(1 << 20), v_max: int = (1 << 20) - 1,
                        active_pruning: bool = False,
                        init: dict | None = None):
    """Oracle for the multi-layer resumable megakernel (fused_snn.py).

    Re-derives the whole stack — PRNG, comparator, the per-layer Σ W·S /
    leak / fire / reset / pruning chain, the layer-summed add counter and
    the carried-state semantics — in one scan, independently of
    ``repro.core``.  ``init`` mirrors the kernel's carried state (``v`` /
    ``en`` per-layer tuples, ``counts``, ``first`` with sentinel
    ``num_steps``, ``steps`` (B,)); ``chunk_steps`` is how many steps this
    call executes (default: the full window).

    Returns a dict shaped like ``kernels.ops.fused_snn_stack_op``'s.
    """
    if chunk_steps is None:
        chunk_steps = num_steps
    B = pixels_u8.shape[0]
    L = len(weights)
    ws = [w.astype(jnp.int32) for w in weights]
    n_out = ws[-1].shape[1]
    if init is None:
        init = {
            "v": tuple(jnp.full((B, w.shape[1]), v_rest, jnp.int32)
                       for w in ws),
            "en": tuple(jnp.ones((B, w.shape[1]), bool) for w in ws),
            "counts": jnp.zeros((B, n_out), jnp.int32),
            "first": jnp.full((B, n_out), num_steps, jnp.int32),
            "steps": jnp.zeros((B,), jnp.int32),
        }

    def step(carry, _):
        s, vs, ens, cnt, first, steps = carry
        s = s ^ (s << 13)
        s = s ^ (s >> 17)
        s = s ^ (s << 5)
        x = pixels_u8 > (s >> 24).astype(jnp.uint8)
        adds = jnp.zeros((B,), jnp.int32)
        new_vs, new_ens = [], []
        for l in range(L):
            en = ens[l]
            cur = jnp.dot(x.astype(jnp.int32), ws[l])
            cur = jnp.where(en, cur, 0)
            v_int = jnp.clip(vs[l] + cur, v_min, v_max)
            v_leak = v_int - (v_int >> decay_shift)
            fired = jnp.logical_and(v_leak >= v_threshold, en)
            v_new = jnp.where(fired, jnp.int32(v_rest), v_leak)
            v_new = jnp.where(en, v_new, vs[l])
            adds = adds + (jnp.sum(x.astype(jnp.int32), axis=-1)
                           * jnp.sum(en.astype(jnp.int32), axis=-1))
            if active_pruning:
                en = jnp.logical_and(en, jnp.logical_not(fired))
            new_vs.append(v_new)
            new_ens.append(en)
            x = fired
        cnt = cnt + x.astype(jnp.int32)
        first = jnp.where(jnp.logical_and(x, first == num_steps),
                          steps[:, None], first)
        carry = (s, tuple(new_vs), tuple(new_ens), cnt, first, steps + 1)
        return carry, (new_vs[-1], adds)

    carry0 = (state_u32, tuple(init["v"]), tuple(init["en"]),
              init["counts"], init["first"], init["steps"].astype(jnp.int32))
    (s_f, vs_f, ens_f, cnt_f, first_f, steps_f), (vtr, adds_t) = \
        jax.lax.scan(step, carry0, None, length=chunk_steps)
    return {
        "spike_counts": cnt_f, "v_trace": vtr, "first_spike_t": first_f,
        "v_final": vs_f[-1], "active_adds": adds_t, "prng_state": s_f,
        "v": vs_f, "en": ens_f, "steps": steps_f,
    }


def spike_matmul_ref(spikes: jax.Array, w_q: jax.Array) -> jax.Array:
    """Binary-spike × integer-weight contraction with int32 accumulation.

    spikes: (B, N_in) in {0,1}; w_q: (N_in, N_out) int. Returns (B, N_out) i32.
    """
    return jnp.dot(spikes.astype(jnp.int32), w_q.astype(jnp.int32))
