"""Pure-jnp oracles for every Pallas kernel in this package.

These are the semantics contracts: each kernel's test sweeps shapes/dtypes
and asserts exact equality (integer datapaths) or allclose (float) against
these functions.  They intentionally re-derive the math independently of
``repro.core`` so that kernel bugs and core bugs cannot cancel.
"""

from __future__ import annotations

import os

import jax
import jax.numpy as jnp

from ..core.telemetry import ChunkTelemetry

__all__ = ["poisson_encode_ref", "lif_forward_ref", "spike_matmul_ref",
           "fused_snn_ref", "fused_snn_stack_ref", "weight_pack_ref",
           "tile_skips_ref"]

# The megakernel's launch geometry, re-derived here independently of
# kernels.fused_snn (oracle style): 128-lane neuron tiles, 8-row batch
# blocks.  If the kernel's tiling ever changes these must be updated in
# lockstep — which is the point: a silent geometry change breaks the
# telemetry bit-identity tests instead of going unnoticed.
_REF_LANE = 128
_REF_BLOCK_B = 8


def tile_skips_ref(x: jax.Array, en: jax.Array, *,
                   sparse_skip: bool) -> jax.Array:
    """Oracle for the kernel's per-layer tile-skip telemetry counter.

    ``x``: (B, n_in) bool input spikes; ``en``: (B, n_out) bool enables
    (true sizes — padding is re-derived here).  Returns (n_blocks,) i32
    skipped (K-tile, N-tile) pairs per batch block: a pair is skipped
    when its 128-wide K slice carries no spike in any lane of the 8-row
    block OR its 128-wide output slice is fully pruned across the block.
    Derived independently of both ``kernels.fused_snn`` and
    ``core.telemetry`` so kernel bugs and mirror bugs cannot cancel.
    """
    B = x.shape[0]
    bB = _REF_BLOCK_B
    Bp = B + (-B) % bB

    def pad(a, n_lane):
        out = jnp.zeros((Bp, n_lane + (-n_lane) % _REF_LANE), bool)
        return out.at[:B, :n_lane].set(a.astype(bool))

    xp, ep = pad(x, x.shape[1]), pad(en, en.shape[1])
    nb = Bp // bB
    nkt, nnt = xp.shape[1] // _REF_LANE, ep.shape[1] // _REF_LANE
    any_x = jnp.any(xp.reshape(nb, bB, nkt, _REF_LANE), axis=(1, 3))
    any_e = jnp.any(ep.reshape(nb, bB, nnt, _REF_LANE), axis=(1, 3))
    live = jnp.logical_and(any_x[:, :, None], any_e[:, None, :])
    if not sparse_skip:
        return jnp.zeros((nb,), jnp.int32)
    return jnp.sum(jnp.logical_not(live), axis=(1, 2)).astype(jnp.int32)


def weight_pack_ref(w_q):
    """Oracle for ``kernels.fused_snn.pack_weights``.

    The fused kernels keep weights resident as two int8 planes —
    ``hi = w >> 1`` (arithmetic shift) and ``lo = w & 1`` — reconstructed
    per tile as ``w = 2*hi + lo``.  That split is exact for every code in
    the paper's signed 9-bit range [-256, 255] (``quantize_params``'
    output contract) and for nothing wider: hi must fit int8.  Returns
    ``(hi, lo)`` int8 numpy planes, derived independently of the kernel
    module.
    """
    import numpy as np
    w = np.asarray(w_q, np.int64)
    if w.min() < -256 or w.max() > 255:
        raise ValueError("weight codes outside the signed 9-bit range "
                         "[-256, 255] cannot be int8-packed exactly")
    hi = w >> 1
    lo = w - 2 * hi                        # ∈ {0, 1}
    return hi.astype(np.int8), lo.astype(np.int8)


def poisson_encode_ref(pixels_u8: jax.Array, state_u32: jax.Array,
                       num_steps: int):
    """xorshift32-driven Poisson encoding. Returns (spikes u8 (T,...), state)."""

    def step(s, _):
        s = s ^ (s << 13)
        s = s ^ (s >> 17)
        s = s ^ (s << 5)
        r = (s >> 24).astype(jnp.uint8)
        return s, (pixels_u8 > r).astype(jnp.uint8)

    state_f, spikes = jax.lax.scan(step, state_u32, None, length=num_steps)
    return spikes, state_f


def lif_forward_ref(spikes_t: jax.Array, w_q: jax.Array, *, decay_shift: int,
                    v_threshold: int, v_rest: int = 0,
                    v_min: int = -(1 << 20), v_max: int = (1 << 20) - 1,
                    active_pruning: bool = False):
    """T-step integer LIF layer.

    spikes_t: (T, B, N_in) uint8/bool; w_q: (N_in, N_out) int.
    Returns (out_spikes u8 (T,B,N_out), v_trace i32 (T,B,N_out), v_final i32).
    """
    T, B, _ = spikes_t.shape
    n_out = w_q.shape[1]
    v0 = jnp.full((B, n_out), v_rest, jnp.int32)
    en0 = jnp.ones((B, n_out), bool)

    def step(carry, s_t):
        v, en = carry
        cur = jnp.dot(s_t.astype(jnp.int32), w_q.astype(jnp.int32))
        cur = jnp.where(en, cur, 0)
        v_int = jnp.clip(v + cur, v_min, v_max)
        v_leak = v_int - (v_int >> decay_shift)
        fired = jnp.logical_and(v_leak >= v_threshold, en)
        v_new = jnp.where(fired, jnp.int32(v_rest), v_leak)
        v_new = jnp.where(en, v_new, v)
        if active_pruning:
            en = jnp.logical_and(en, jnp.logical_not(fired))
        return (v_new, en), (fired.astype(jnp.uint8), v_new)

    (v_f, _), (spk, vtr) = jax.lax.scan(step, (v0, en0), spikes_t)
    return spk, vtr, v_f


def fused_snn_ref(pixels_u8: jax.Array, state_u32: jax.Array,
                  w_q: jax.Array, *, num_steps: int, decay_shift: int,
                  v_threshold: int, v_rest: int = 0,
                  v_min: int = -(1 << 20), v_max: int = (1 << 20) - 1,
                  active_pruning: bool = False):
    """Oracle for the fused encode→LIF megakernel (fused_snn.py).

    Re-derives the whole window — PRNG, comparator, Σ W·S, leak, fire,
    reset, pruning gate, add counter — in one scan, independently of both
    ``repro.core`` and the staged oracles above.

    Returns (counts i32 (B,N_out), v_trace i32 (T,B,N_out),
             first_spike_t i32 (B,N_out), v_final i32 (B,N_out),
             active_adds i32 (T,B), state u32 (B,N_in)).
    """
    B = pixels_u8.shape[0]
    n_out = w_q.shape[1]
    w = w_q.astype(jnp.int32)
    v0 = jnp.full((B, n_out), v_rest, jnp.int32)
    en0 = jnp.ones((B, n_out), bool)
    cnt0 = jnp.zeros((B, n_out), jnp.int32)
    first0 = jnp.full((B, n_out), num_steps, jnp.int32)

    def step(carry, t):
        s, v, en, cnt, first = carry
        s = s ^ (s << 13)
        s = s ^ (s >> 17)
        s = s ^ (s << 5)
        spk = pixels_u8 > (s >> 24).astype(jnp.uint8)
        cur = jnp.dot(spk.astype(jnp.int32), w)
        cur = jnp.where(en, cur, 0)
        v_int = jnp.clip(v + cur, v_min, v_max)
        v_leak = v_int - (v_int >> decay_shift)
        fired = jnp.logical_and(v_leak >= v_threshold, en)
        v_new = jnp.where(fired, jnp.int32(v_rest), v_leak)
        v_new = jnp.where(en, v_new, v)
        first = jnp.where(jnp.logical_and(fired, first == num_steps),
                          t.astype(jnp.int32), first)
        cnt = cnt + fired.astype(jnp.int32)
        adds = (jnp.sum(spk.astype(jnp.int32), axis=-1)
                * jnp.sum(en.astype(jnp.int32), axis=-1))
        if active_pruning:
            en = jnp.logical_and(en, jnp.logical_not(fired))
        return (s, v_new, en, cnt, first), (v_new, adds)

    (s_f, v_f, _, cnt_f, first_f), (vtr, adds_t) = jax.lax.scan(
        step, (state_u32, v0, en0, cnt0, first0), jnp.arange(num_steps))
    return cnt_f, vtr, first_f, v_f, adds_t, s_f


def fused_snn_stack_ref(pixels_u8: jax.Array, state_u32: jax.Array,
                        weights, *, num_steps: int, chunk_steps: int | None = None,
                        decay_shift: int, v_threshold: int, v_rest: int = 0,
                        v_min: int = -(1 << 20), v_max: int = (1 << 20) - 1,
                        active_pruning: bool = False,
                        sparse_skip: bool | None = None,
                        init: dict | None = None):
    """Oracle for the multi-layer resumable megakernel (fused_snn.py).

    Re-derives the whole stack — PRNG, comparator, the per-layer Σ W·S /
    leak / fire / reset / pruning chain, the layer-summed add counter,
    the carried-state semantics, the per-layer peak-membrane accumulator
    AND the telemetry side channel (per-step spike/enable counts per
    lane, skipped tile pairs per block via :func:`tile_skips_ref`) — in
    one scan, independently of ``repro.core``.  ``init`` mirrors the
    kernel's carried state (``v`` / ``en`` / ``v_peak`` per-layer tuples,
    ``counts``, ``first`` with sentinel ``num_steps``, ``steps`` (B,));
    ``chunk_steps`` is how many steps this call executes (default: the
    full window).  ``sparse_skip`` only affects the telemetry tile
    counter (None resolves the same REPRO_SPARSE_SKIP env rule as the
    launcher, so oracle and kernel agree under the CI forcing).

    Returns a dict shaped like ``kernels.ops.fused_snn_stack_op``'s.
    """
    if chunk_steps is None:
        chunk_steps = num_steps
    if sparse_skip is None:
        sparse_skip = os.environ.get("REPRO_SPARSE_SKIP", "1") != "0"
    B = pixels_u8.shape[0]
    L = len(weights)
    ws = [w.astype(jnp.int32) for w in weights]
    n_out = ws[-1].shape[1]
    vp0 = jnp.iinfo(jnp.int32).min
    if init is None:
        init = {
            "v": tuple(jnp.full((B, w.shape[1]), v_rest, jnp.int32)
                       for w in ws),
            "en": tuple(jnp.ones((B, w.shape[1]), bool) for w in ws),
            "counts": jnp.zeros((B, n_out), jnp.int32),
            "first": jnp.full((B, n_out), num_steps, jnp.int32),
            "steps": jnp.zeros((B,), jnp.int32),
        }
    vp_init = init.get("v_peak")
    if vp_init is None:
        vp_init = tuple(jnp.full((B, w.shape[1]), vp0, jnp.int32)
                        for w in ws)

    def step(carry, _):
        s, vs, ens, vps, cnt, first, steps = carry
        s = s ^ (s << 13)
        s = s ^ (s >> 17)
        s = s ^ (s << 5)
        x = pixels_u8 > (s >> 24).astype(jnp.uint8)
        adds = jnp.zeros((B,), jnp.int32)
        new_vs, new_ens, new_vps = [], [], []
        tel_spk, tel_en, tel_tiles = [], [], []
        for l in range(L):
            en = ens[l]
            tel_tiles.append(tile_skips_ref(x, en, sparse_skip=sparse_skip))
            cur = jnp.dot(x.astype(jnp.int32), ws[l])
            cur = jnp.where(en, cur, 0)
            v_int = jnp.clip(vs[l] + cur, v_min, v_max)
            v_leak = v_int - (v_int >> decay_shift)
            fired = jnp.logical_and(v_leak >= v_threshold, en)
            v_new = jnp.where(fired, jnp.int32(v_rest), v_leak)
            v_new = jnp.where(en, v_new, vs[l])
            n_spk = jnp.sum(x.astype(jnp.int32), axis=-1)
            n_en = jnp.sum(en.astype(jnp.int32), axis=-1)
            adds = adds + n_spk * n_en
            tel_spk.append(n_spk)
            tel_en.append(n_en)
            if active_pruning:
                en = jnp.logical_and(en, jnp.logical_not(fired))
            new_vs.append(v_new)
            new_ens.append(en)
            new_vps.append(jnp.maximum(vps[l], v_new))
            x = fired
        cnt = cnt + x.astype(jnp.int32)
        first = jnp.where(jnp.logical_and(x, first == num_steps),
                          steps[:, None], first)
        carry = (s, tuple(new_vs), tuple(new_ens), tuple(new_vps), cnt,
                 first, steps + 1)
        return carry, (new_vs[-1], adds, jnp.stack(tel_spk),
                       jnp.stack(tel_en), jnp.stack(tel_tiles))

    carry0 = (state_u32, tuple(init["v"]), tuple(init["en"]),
              tuple(vp_init), init["counts"], init["first"],
              init["steps"].astype(jnp.int32))
    ((s_f, vs_f, ens_f, vps_f, cnt_f, first_f, steps_f),
     (vtr, adds_t, tspk, ten, ttile)) = \
        jax.lax.scan(step, carry0, None, length=chunk_steps)
    return {
        "spike_counts": cnt_f, "v_trace": vtr, "first_spike_t": first_f,
        "v_final": vs_f[-1], "active_adds": adds_t, "prng_state": s_f,
        "v": vs_f, "en": ens_f, "v_peak": vps_f, "steps": steps_f,
        "telemetry": ChunkTelemetry(n_spk=tspk, n_en=ten,
                                    tiles_skipped=ttile),
    }


def spike_matmul_ref(spikes: jax.Array, w_q: jax.Array) -> jax.Array:
    """Binary-spike × integer-weight contraction with int32 accumulation.

    spikes: (B, N_in) in {0,1}; w_q: (N_in, N_out) int. Returns (B, N_out) i32.
    """
    return jnp.dot(spikes.astype(jnp.int32), w_q.astype(jnp.int32))
