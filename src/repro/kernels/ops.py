"""Jit'd public wrappers for the Pallas kernels.

Handles: CPU-vs-TPU dispatch (interpret mode on CPU so the whole framework
runs in this container), shape padding to tile multiples, density-based
masked/MXU dispatch for the spike matmul, and unpadding of results.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from . import fused_snn, lif_step, poisson_encode, spike_matmul

__all__ = ["poisson_encode_op", "lif_forward_op", "spike_matmul_op",
           "fused_snn_op"]


def _use_interpret() -> bool:
    return jax.default_backend() != "tpu"


def _pad_to(x: jax.Array, axis: int, mult: int):
    size = x.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


@partial(jax.jit, static_argnames=("num_steps", "interpret"))
def poisson_encode_op(pixels_u8: jax.Array, state_u32: jax.Array,
                      num_steps: int, *, interpret: bool | None = None):
    """Batched hardware-faithful Poisson encoding via the Pallas kernel."""
    interpret = _use_interpret() if interpret is None else interpret
    B, N = pixels_u8.shape
    bB, bN = poisson_encode.DEFAULT_BLOCK
    px = _pad_to(_pad_to(pixels_u8, 0, bB), 1, bN)
    st = _pad_to(_pad_to(state_u32, 0, bB), 1, bN)
    spikes, state = poisson_encode.poisson_encode_pallas(
        px, st, num_steps, interpret=interpret)
    return spikes[:, :B, :N], state[:B, :N]


@partial(jax.jit, static_argnames=(
    "decay_shift", "v_threshold", "v_rest", "v_min", "v_max",
    "active_pruning", "interpret"))
def lif_forward_op(spikes_t: jax.Array, w_q: jax.Array, *, decay_shift: int,
                   v_threshold: int, v_rest: int = 0,
                   v_min: int = -(1 << 20), v_max: int = (1 << 20) - 1,
                   active_pruning: bool = False,
                   interpret: bool | None = None):
    """Fused T-step LIF layer via the Pallas kernel. See lif_step.py."""
    interpret = _use_interpret() if interpret is None else interpret
    T, B, n_in = spikes_t.shape
    n_out = w_q.shape[1]
    bB, bN = lif_step.DEFAULT_BLOCK
    s = _pad_to(spikes_t, 1, bB)
    w = _pad_to(w_q, 1, bN)
    spk, vtr, vfin = lif_step.lif_forward_pallas(
        s, w, decay_shift=decay_shift, v_threshold=v_threshold,
        v_rest=v_rest, v_min=v_min, v_max=v_max,
        active_pruning=active_pruning, interpret=interpret)
    return spk[:, :B, :n_out], vtr[:, :B, :n_out], vfin[:B, :n_out]


@partial(jax.jit, static_argnames=(
    "num_steps", "decay_shift", "v_threshold", "v_rest", "v_min", "v_max",
    "active_pruning", "interpret"))
def fused_snn_op(pixels_u8: jax.Array, state_u32: jax.Array, w_q: jax.Array,
                 *, num_steps: int, decay_shift: int, v_threshold: int,
                 v_rest: int = 0, v_min: int = -(1 << 20),
                 v_max: int = (1 << 20) - 1, active_pruning: bool = False,
                 interpret: bool | None = None):
    """Whole encode→LIF window in one Pallas launch (see fused_snn.py).

    Returns a dict with ``spike_counts`` (B, N_out) i32, ``v_trace``
    (T, B, N_out) i32, ``first_spike_t`` (B, N_out) i32, ``v_final``
    (B, N_out) i32, ``active_adds`` (T, B) i32 and ``prng_state``
    (B, N_in) u32 — the (T, B, N_in) spike tensor is never materialised.
    """
    interpret = _use_interpret() if interpret is None else interpret
    B, n_in = pixels_u8.shape
    n_out = w_q.shape[1]
    bB, bN = fused_snn.DEFAULT_BLOCK
    # Zero-padded pixel/state lanes never spike (0 > r is false, and 0 is
    # the xorshift fixed point), so padding is invisible to the datapath.
    px = _pad_to(_pad_to(pixels_u8, 0, bB), 1, 128)
    st = _pad_to(_pad_to(state_u32, 0, bB), 1, 128)
    w = _pad_to(_pad_to(w_q, 0, 128), 1, bN)
    cnt, vtr, first, vfin, adds, st_out = fused_snn.fused_snn_forward_pallas(
        px, st, w, num_steps=num_steps, decay_shift=decay_shift,
        v_threshold=v_threshold, v_rest=v_rest, v_min=v_min, v_max=v_max,
        active_pruning=active_pruning, n_out_true=n_out,
        interpret=interpret)
    return {
        "spike_counts": cnt[:B, :n_out],
        "v_trace": vtr[:, :B, :n_out],
        "first_spike_t": first[:B, :n_out],
        "v_final": vfin[:B, :n_out],
        "active_adds": adds[:, :B],
        "prng_state": st_out[:B, :n_in],
    }


@partial(jax.jit, static_argnames=("mode", "interpret"))
def spike_matmul_op(spikes: jax.Array, w_q: jax.Array, *,
                    mode: str = "auto", interpret: bool | None = None):
    """Event-driven spike×weight contraction.

    mode="auto" picks the masked (event-driven) path for small layers and
    the MXU path otherwise; density is a compile-time proxy here (runtime
    density dispatch would need a cond over both kernels — the serving stack
    does that at the batch level instead).
    """
    interpret = _use_interpret() if interpret is None else interpret
    if mode == "auto":
        n_in = spikes.shape[-1]
        mode = "masked" if n_in <= 1024 else "mxu"
    B, n_in = spikes.shape
    n_out = w_q.shape[1]
    bB, bN, bK = spike_matmul.DEFAULT_BLOCK
    s = _pad_to(_pad_to(spikes, 0, bB), 1, bK)
    w = _pad_to(_pad_to(w_q, 0, bK), 1, bN)
    out = spike_matmul.spike_matmul_pallas(s, w, mode=mode,
                                           interpret=interpret)
    return out[:B, :n_out]
