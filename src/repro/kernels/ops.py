"""Jit'd public wrappers for the Pallas kernels.

Handles: CPU-vs-TPU dispatch (interpret mode on CPU so the whole framework
runs in this container), shape padding to tile multiples, density-based
masked/MXU dispatch for the spike matmul, and unpadding of results.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from . import fused_snn, lif_step, poisson_encode, spike_matmul

__all__ = ["poisson_encode_op", "lif_forward_op", "spike_matmul_op",
           "fused_snn_op", "fused_snn_stack_op"]


def _use_interpret() -> bool:
    return jax.default_backend() != "tpu"


def _pad_to(x: jax.Array, axis: int, mult: int):
    size = x.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


@partial(jax.jit, static_argnames=("num_steps", "interpret"))
def poisson_encode_op(pixels_u8: jax.Array, state_u32: jax.Array,
                      num_steps: int, *, interpret: bool | None = None):
    """Batched hardware-faithful Poisson encoding via the Pallas kernel."""
    interpret = _use_interpret() if interpret is None else interpret
    B, N = pixels_u8.shape
    bB, bN = poisson_encode.DEFAULT_BLOCK
    px = _pad_to(_pad_to(pixels_u8, 0, bB), 1, bN)
    st = _pad_to(_pad_to(state_u32, 0, bB), 1, bN)
    spikes, state = poisson_encode.poisson_encode_pallas(
        px, st, num_steps, interpret=interpret)
    return spikes[:, :B, :N], state[:B, :N]


@partial(jax.jit, static_argnames=(
    "decay_shift", "v_threshold", "v_rest", "v_min", "v_max",
    "active_pruning", "interpret"))
def lif_forward_op(spikes_t: jax.Array, w_q: jax.Array, *, decay_shift: int,
                   v_threshold: int, v_rest: int = 0,
                   v_min: int = -(1 << 20), v_max: int = (1 << 20) - 1,
                   active_pruning: bool = False,
                   interpret: bool | None = None):
    """Fused T-step LIF layer via the Pallas kernel. See lif_step.py."""
    interpret = _use_interpret() if interpret is None else interpret
    T, B, n_in = spikes_t.shape
    n_out = w_q.shape[1]
    bB, bN = lif_step.DEFAULT_BLOCK
    s = _pad_to(spikes_t, 1, bB)
    w = _pad_to(w_q, 1, bN)
    spk, vtr, vfin = lif_step.lif_forward_pallas(
        s, w, decay_shift=decay_shift, v_threshold=v_threshold,
        v_rest=v_rest, v_min=v_min, v_max=v_max,
        active_pruning=active_pruning, interpret=interpret)
    return spk[:, :B, :n_out], vtr[:, :B, :n_out], vfin[:B, :n_out]


@partial(jax.jit, static_argnames=(
    "num_steps", "chunk_steps", "decay_shift", "v_threshold", "v_rest",
    "v_min", "v_max", "active_pruning", "patience", "readout", "interpret"))
def fused_snn_stack_op(pixels_u8: jax.Array, state_u32: jax.Array,
                       weights, *, num_steps: int, chunk_steps: int | None = None,
                       decay_shift: int, v_threshold: int, v_rest: int = 0,
                       v_min: int = -(1 << 20), v_max: int = (1 << 20) - 1,
                       active_pruning: bool = False, init: dict | None = None,
                       gate: dict | None = None, patience: int = 0,
                       readout: str = "count",
                       interpret: bool | None = None):
    """Multi-layer encode→LIF stack in one resumable Pallas launch.

    Args:
      weights: tuple of per-layer (n_l, n_{l+1}) int16/int8 matrices.
      num_steps: the full window length T (first-spike sentinel and, when
        gated, the per-lane step bound).
      chunk_steps: how many steps THIS launch executes (default: the whole
        window).  Carry ``init``/``gate`` between launches for bit-identical
        chunked execution.
      init: optional carried state dict with ``v``/``en`` (per-layer tuples,
        (B, n_l) i32 / bool), ``counts``/``first`` ((B, n_out) i32, first
        sentinel = num_steps) and ``steps`` ((B,) i32).
      gate: optional per-lane stability-gate state (``active`` bool (B,),
        ``prev``/``streak`` i32 (B,)) — when given, the kernel runs the
        serving early-exit gate each step and freezes retired lanes.

    Returns a dict with ``spike_counts``/``first_spike_t``/``v_final``
    ((B, n_out) i32), ``v_trace`` ((chunk, B, n_out) i32), ``active_adds``
    ((chunk, B) i32, summed over layers), ``prng_state`` ((B, n_in) u32),
    the carried ``v``/``en``/``steps`` state and (if gated) ``gate``.
    The inter-layer spike tensors are never materialised.
    """
    interpret = _use_interpret() if interpret is None else interpret
    if chunk_steps is None:
        chunk_steps = num_steps
    B, n_in = pixels_u8.shape
    L = len(weights)
    sizes = [n_in] + [w.shape[1] for w in weights]
    n_out = sizes[-1]
    bB = fused_snn.block_b_for(B)
    lane = fused_snn.LANE
    Bp = B + (-B) % bB

    # Zero-padded pixel/state lanes never spike (0 > r is false, and 0 is
    # the xorshift fixed point), so batch/input padding is invisible to the
    # datapath; padded neurons are masked out of the enable sets below so
    # they cannot fire and do not count toward the executed-add channel.
    px = _pad_to(_pad_to(pixels_u8, 0, bB), 1, lane)
    st = _pad_to(_pad_to(state_u32, 0, bB), 1, lane)
    ws = tuple(_pad_to(_pad_to(w, 0, lane), 1, lane) for w in weights)

    def valid_mask(n_true, n_pad):
        col = jnp.arange(n_pad, dtype=jnp.int32)[None, :]
        return jnp.broadcast_to(col < n_true, (Bp, n_pad))

    if init is None:
        v_in = tuple(jnp.full((Bp, ws[l].shape[1]), v_rest, jnp.int32)
                     for l in range(L))
        en_in = tuple(valid_mask(sizes[l + 1], ws[l].shape[1])
                      for l in range(L))
        cnt_in = jnp.zeros((Bp, ws[-1].shape[1]), jnp.int32)
        first_in = jnp.full((Bp, ws[-1].shape[1]), num_steps, jnp.int32)
        steps_in = jnp.zeros((Bp, 1), jnp.int32)
    else:
        v_in = tuple(_pad_to(_pad_to(init["v"][l], 0, bB), 1, lane)
                     for l in range(L))
        en_in = tuple(
            _pad_to(_pad_to(init["en"][l].astype(bool), 0, bB), 1, lane)
            for l in range(L))
        cnt_in = _pad_to(_pad_to(init["counts"], 0, bB), 1, lane)
        first_in = _pad_to(_pad_to(init["first"], 0, bB), 1, lane)
        steps_in = _pad_to(init["steps"].astype(jnp.int32)[:, None], 0, bB)
    en_in = tuple(e.astype(jnp.uint8) for e in en_in)

    gate_in = None
    if gate is not None:
        gate_in = (
            _pad_to(gate["active"].astype(jnp.int32)[:, None], 0, bB),
            _pad_to(gate["prev"].astype(jnp.int32)[:, None], 0, bB),
            _pad_to(gate["streak"].astype(jnp.int32)[:, None], 0, bB),
        )

    outs = fused_snn.fused_snn_stack_pallas(
        px, st, ws, v_in, en_in, cnt_in, first_in, steps_in, gate_in,
        chunk_steps=chunk_steps, window_steps=num_steps,
        decay_shift=decay_shift, v_threshold=v_threshold, v_rest=v_rest,
        v_min=v_min, v_max=v_max, active_pruning=active_pruning,
        patience=patience, readout=readout, block_b=bB,
        interpret=interpret)
    cnt, vtr, first, adds, st_out, v_fin, en_fin, steps_out = outs[:8]
    res = {
        "spike_counts": cnt[:B, :n_out],
        "v_trace": vtr[:, :B, :n_out],
        "first_spike_t": first[:B, :n_out],
        "v_final": v_fin[-1][:B, :n_out],
        "active_adds": adds[:, :B],
        "prng_state": st_out[:B, :n_in],
        "v": tuple(v_fin[l][:B, :sizes[l + 1]] for l in range(L)),
        "en": tuple(en_fin[l][:B, :sizes[l + 1]].astype(bool)
                    for l in range(L)),
        "steps": steps_out[:B, 0],
    }
    if gate is not None:
        act, prev, streak = outs[8]
        res["gate"] = {"active": act[:B, 0] != 0, "prev": prev[:B, 0],
                       "streak": streak[:B, 0]}
    return res


@partial(jax.jit, static_argnames=(
    "num_steps", "decay_shift", "v_threshold", "v_rest", "v_min", "v_max",
    "active_pruning", "interpret"))
def fused_snn_op(pixels_u8: jax.Array, state_u32: jax.Array, w_q: jax.Array,
                 *, num_steps: int, decay_shift: int, v_threshold: int,
                 v_rest: int = 0, v_min: int = -(1 << 20),
                 v_max: int = (1 << 20) - 1, active_pruning: bool = False,
                 interpret: bool | None = None):
    """Single-layer whole-window convenience wrapper over the stack op.

    Returns a dict with ``spike_counts`` (B, N_out) i32, ``v_trace``
    (T, B, N_out) i32, ``first_spike_t`` (B, N_out) i32, ``v_final``
    (B, N_out) i32, ``active_adds`` (T, B) i32 and ``prng_state``
    (B, N_in) u32 — the (T, B, N_in) spike tensor is never materialised.
    """
    return fused_snn_stack_op(
        pixels_u8, state_u32, (w_q,), num_steps=num_steps,
        decay_shift=decay_shift, v_threshold=v_threshold, v_rest=v_rest,
        v_min=v_min, v_max=v_max, active_pruning=active_pruning,
        interpret=interpret)


@partial(jax.jit, static_argnames=("mode", "interpret"))
def spike_matmul_op(spikes: jax.Array, w_q: jax.Array, *,
                    mode: str = "auto", interpret: bool | None = None):
    """Event-driven spike×weight contraction.

    mode="auto" picks the masked (event-driven) path for small layers and
    the MXU path otherwise; density is a compile-time proxy here (runtime
    density dispatch would need a cond over both kernels — the serving stack
    does that at the batch level instead).
    """
    interpret = _use_interpret() if interpret is None else interpret
    if mode == "auto":
        n_in = spikes.shape[-1]
        mode = "masked" if n_in <= 1024 else "mxu"
    B, n_in = spikes.shape
    n_out = w_q.shape[1]
    bB, bN, bK = spike_matmul.DEFAULT_BLOCK
    s = _pad_to(_pad_to(spikes, 0, bB), 1, bK)
    w = _pad_to(_pad_to(w_q, 0, bK), 1, bN)
    out = spike_matmul.spike_matmul_pallas(s, w, mode=mode,
                                           interpret=interpret)
    return out[:B, :n_out]
