"""Jit'd public wrappers for the Pallas kernels.

Handles: CPU-vs-TPU dispatch (interpret mode on CPU so the whole framework
runs in this container), shape padding to tile multiples, density-based
masked/MXU dispatch for the spike matmul, and unpadding of results.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from ..core.telemetry import (ChunkTelemetry, MatmulTelemetry,
                              DEFAULT_SPIKE_DENSITY_THRESHOLD,
                              resolve_density_threshold, resolve_sparse_skip)
from . import fused_snn, lif_step, poisson_encode, spike_matmul

__all__ = ["poisson_encode_op", "lif_forward_op", "spike_matmul_op",
           "fused_snn_op", "fused_snn_stack_op", "partial_contraction_op",
           "validate_weight_codes",
           "SPIKE_DENSITY_THRESHOLD", "resolve_density_threshold"]

# Below this per-tile spike density the masked (event-driven) spike-matmul
# kernel wins over the MXU dot; the ``mode="auto"`` runtime dispatch in
# :func:`spike_matmul_op` branches on the *observed* density of the batch.
# Kept under its historical name for backward compatibility — it is now
# only the DEFAULT: the live value comes from ``SNNConfig``'s
# ``spike_density_threshold`` / the ``REPRO_SPIKE_DENSITY_THRESHOLD`` env
# override (``core.telemetry.resolve_density_threshold``), and the serving
# controller may retune it from observed traffic.
SPIKE_DENSITY_THRESHOLD = DEFAULT_SPIKE_DENSITY_THRESHOLD

# window-start sentinel for the carried peak-membrane accumulator: the
# first real membrane value always wins the max-fold
V_PEAK_INIT = jnp.iinfo(jnp.int32).min


def _use_interpret() -> bool:
    return jax.default_backend() != "tpu"


def validate_weight_codes(weights) -> None:
    """Raise if concrete weights fall outside the int8-packable range.

    The fused kernels store weights as two int8 planes (``hi = w >> 1``,
    ``lo = w & 1``), exact only for the paper's signed 9-bit codes
    [-256, 255] (``core.snn.quantize_params``' output contract) — a wider
    code would wrap the hi plane SILENTLY, where the pre-packing int16
    kernel was exact.  Checked wherever the weights are concrete (engine
    construction, un-jitted ``snn_apply_int``/``snn_window_chunk`` calls);
    under a caller's jit the values are tracers and the contract is
    trusted.
    """
    for i, w in enumerate(weights):
        if isinstance(w, jax.core.Tracer):
            continue
        lo, hi = int(jnp.min(w)), int(jnp.max(w))
        if lo < -256 or hi > 255:
            raise ValueError(
                f"layer {i} weight codes span [{lo}, {hi}] — outside the "
                f"signed 9-bit range [-256, 255] the fused kernels' int8 "
                f"packing represents exactly (quantize_params' contract); "
                f"use the staged or reference backend for wider codes")


# Trace-time env resolution of the tile-skip flag — the canonical rule
# lives in core.telemetry so the jnp telemetry mirrors resolve identically.
_resolve_sparse_skip = resolve_sparse_skip


def _pad_to(x: jax.Array, axis: int, mult: int):
    size = x.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


@partial(jax.jit, static_argnames=("num_steps", "interpret"))
def poisson_encode_op(pixels_u8: jax.Array, state_u32: jax.Array,
                      num_steps: int, *, interpret: bool | None = None):
    """Batched hardware-faithful Poisson encoding via the Pallas kernel."""
    interpret = _use_interpret() if interpret is None else interpret
    B, N = pixels_u8.shape
    bB, bN = poisson_encode.DEFAULT_BLOCK
    px = _pad_to(_pad_to(pixels_u8, 0, bB), 1, bN)
    st = _pad_to(_pad_to(state_u32, 0, bB), 1, bN)
    spikes, state = poisson_encode.poisson_encode_pallas(
        px, st, num_steps, interpret=interpret)
    return spikes[:, :B, :N], state[:B, :N]


@partial(jax.jit, static_argnames=(
    "decay_shift", "v_threshold", "v_rest", "v_min", "v_max",
    "active_pruning", "interpret"))
def lif_forward_op(spikes_t: jax.Array, w_q: jax.Array, *, decay_shift: int,
                   v_threshold: int, v_rest: int = 0,
                   v_min: int = -(1 << 20), v_max: int = (1 << 20) - 1,
                   active_pruning: bool = False,
                   interpret: bool | None = None):
    """Fused T-step LIF layer via the Pallas kernel. See lif_step.py."""
    interpret = _use_interpret() if interpret is None else interpret
    T, B, n_in = spikes_t.shape
    n_out = w_q.shape[1]
    bB, bN = lif_step.DEFAULT_BLOCK
    s = _pad_to(spikes_t, 1, bB)
    w = _pad_to(w_q, 1, bN)
    spk, vtr, vfin = lif_step.lif_forward_pallas(
        s, w, decay_shift=decay_shift, v_threshold=v_threshold,
        v_rest=v_rest, v_min=v_min, v_max=v_max,
        active_pruning=active_pruning, interpret=interpret)
    return spk[:, :B, :n_out], vtr[:, :B, :n_out], vfin[:B, :n_out]


def partial_contraction_op(spikes: jax.Array, en: jax.Array,
                           w_q: jax.Array, *,
                           sparse_skip: bool | None = None,
                           interpret: bool | None = None):
    """One layer's Σ W·S against an output-column weight shard, via Pallas.

    The model-axis datapath's per-device contraction: ``spikes`` (B, n_in)
    bool is the FULL gathered input-spike vector, ``en`` (B, n_out_sh)
    bool and ``w_q`` (n_in, n_out_sh) cover only this device's
    output-neuron shard.  Pads batch to the launch block and both neuron
    axes to 128 (padded pixels never spike, padded neurons are disabled),
    packs the shard's weights into the two int8 planes per call, launches
    :func:`fused_snn.partial_contraction_pallas` and unpads.  Bit-exact
    equal to ``core.lif.synaptic_current_int(spikes, w_q)`` on the shard
    — integer accumulation, no rounding — which is what makes the model-
    sharded fused path == the jnp reference == the single-device kernel.

    Returns ``(current, skipped)``: (B, n_out_sh) int32 and the
    per-batch-block skipped-tile-pair counts (n_blocks,) int32 with
    exactly the geometry ``core.telemetry.layer_tile_skips`` mirrors for
    this shard's (B, n_in, n_out_sh) launch.  Designed to be called
    inside a caller's jit/scan/shard_map (no jit wrapper of its own).
    """
    interpret = _use_interpret() if interpret is None else interpret
    ss = _resolve_sparse_skip(sparse_skip)
    B = spikes.shape[0]
    n_out = w_q.shape[1]
    bB = fused_snn.block_b_for(B)
    x = _pad_to(_pad_to(spikes.astype(jnp.uint8), 0, bB), 1, fused_snn.LANE)
    e = _pad_to(_pad_to(en.astype(jnp.uint8), 0, bB), 1, fused_snn.LANE)
    wp = fused_snn.pack_weights(
        _pad_to(_pad_to(w_q, 0, fused_snn.LANE), 1, fused_snn.LANE))
    cur, skipped = fused_snn.partial_contraction_pallas(
        x, e, wp, sparse_skip=ss, block_b=bB, interpret=interpret)
    return cur[:B, :n_out], skipped


@partial(jax.jit, static_argnames=(
    "num_steps", "chunk_steps", "decay_shift", "v_threshold", "v_rest",
    "v_min", "v_max", "active_pruning", "patience", "readout",
    "sparse_skip", "streamed", "interpret", "block_b"))
def fused_snn_stack_op(pixels_u8: jax.Array, state_u32: jax.Array,
                       weights, *, num_steps: int, chunk_steps: int | None = None,
                       decay_shift: int, v_threshold: int, v_rest: int = 0,
                       v_min: int = -(1 << 20), v_max: int = (1 << 20) - 1,
                       active_pruning: bool = False, init: dict | None = None,
                       gate: dict | None = None, patience: int = 0,
                       readout: str = "count",
                       sparse_skip: bool | None = None,
                       streamed: bool = False,
                       interpret: bool | None = None,
                       block_b: int | None = None):
    """Multi-layer encode→LIF stack in one resumable Pallas launch.

    Args:
      weights: tuple of per-layer (n_l, n_{l+1}) int16/int8 matrices
        holding the paper's signed 9-bit codes (range [-256, 255] — the
        ``core.snn.quantize_params`` contract; packing into the kernel's
        resident int8 planes is exact only on that range).
      num_steps: the full window length T (first-spike sentinel and, when
        gated, the per-lane step bound).
      chunk_steps: how many steps THIS launch executes (default: the whole
        window).  Carry ``init``/``gate`` between launches for bit-identical
        chunked execution.
      init: optional carried state dict with ``v``/``en`` (per-layer tuples,
        (B, n_l) i32 / bool), ``counts``/``first`` ((B, n_out) i32, first
        sentinel = num_steps) and ``steps`` ((B,) i32).  May also carry
        ``v_peak`` (per-layer (B, n_l) i32 running peak membranes);
        omitted, the peaks restart from the INT32_MIN sentinel.
      gate: optional per-lane stability-gate state (``active`` bool (B,),
        ``prev``/``streak`` i32 (B,)) — when given, the kernel runs the
        serving early-exit gate each step and freezes retired lanes.
      sparse_skip: event-driven tile skipping inside the kernel —
        bit-identical to dense execution either way (None = the
        REPRO_SPARSE_SKIP env default, on).
      streamed: keep the packed weight planes in HBM and double-buffer
        128-row slabs through VMEM scratch (the ``fused_streamed``
        backend for stacks over the residency budget).
      block_b: batch-block (MXU tile height) override for the launch
        grid — a tunable dispatch shape (the autotuner searches it).
        None derives the historical ``fused_snn.block_b_for(B)``
        heuristic.  Bit-identical for any valid value: blocking only
        changes launch geometry (and the telemetry tile-leaf shape that
        mirrors it), never the integer datapath.

    Returns a dict with ``spike_counts``/``first_spike_t``/``v_final``
    ((B, n_out) i32), ``v_trace`` ((chunk, B, n_out) i32), ``active_adds``
    ((chunk, B) i32, summed over layers), ``prng_state`` ((B, n_in) u32),
    the carried ``v``/``en``/``v_peak``/``steps`` state, ``telemetry``
    (a ``core.telemetry.ChunkTelemetry`` — the kernel's activity side
    channel) and (if gated) ``gate``.  The inter-layer spike tensors are
    never materialised.
    """
    interpret = _use_interpret() if interpret is None else interpret
    sparse_skip = _resolve_sparse_skip(sparse_skip)
    if chunk_steps is None:
        chunk_steps = num_steps
    B, n_in = pixels_u8.shape
    L = len(weights)
    sizes = [n_in] + [w.shape[1] for w in weights]
    n_out = sizes[-1]
    if block_b is None:
        bB = fused_snn.block_b_for(B)
    else:
        bB = int(block_b)
        if bB < 8 or bB % 8:
            raise ValueError(
                f"block_b={block_b} is not a positive multiple of 8 (the "
                f"kernel's sublane granularity) — pass None for the "
                f"derived default")
    lane = fused_snn.LANE
    Bp = B + (-B) % bB

    # Zero-padded pixel/state lanes never spike (0 > r is false, and 0 is
    # the xorshift fixed point), so batch/input padding is invisible to the
    # datapath; padded neurons are masked out of the enable sets below so
    # they cannot fire and do not count toward the executed-add channel.
    px = _pad_to(_pad_to(pixels_u8, 0, bB), 1, lane)
    st = _pad_to(_pad_to(state_u32, 0, bB), 1, lane)
    ws = tuple(fused_snn.pack_weights(_pad_to(_pad_to(w, 0, lane), 1, lane))
               for w in weights)

    def valid_mask(n_true, n_pad):
        # padded neurons AND padded batch rows are disabled — the rows so
        # the tile-skip predicates (and their telemetry mirror) see the
        # identical enable geometry whether the state is fresh or carried
        # (_pad_to pads carried enables with False rows)
        col = jnp.arange(n_pad, dtype=jnp.int32)[None, :]
        row = jnp.arange(Bp, dtype=jnp.int32)[:, None]
        return jnp.logical_and(col < n_true, row < B)

    def vp_fresh():
        return tuple(jnp.full((Bp, ws[l].shape[2]), V_PEAK_INIT, jnp.int32)
                     for l in range(L))

    if init is None:
        v_in = tuple(jnp.full((Bp, ws[l].shape[2]), v_rest, jnp.int32)
                     for l in range(L))
        en_in = tuple(valid_mask(sizes[l + 1], ws[l].shape[2])
                      for l in range(L))
        vp_in = vp_fresh()
        cnt_in = jnp.zeros((Bp, ws[-1].shape[2]), jnp.int32)
        first_in = jnp.full((Bp, ws[-1].shape[2]), num_steps, jnp.int32)
        steps_in = jnp.zeros((Bp, 1), jnp.int32)
    else:
        v_in = tuple(_pad_to(_pad_to(init["v"][l], 0, bB), 1, lane)
                     for l in range(L))
        en_in = tuple(
            _pad_to(_pad_to(init["en"][l].astype(bool), 0, bB), 1, lane)
            for l in range(L))
        vp_in = (vp_fresh() if init.get("v_peak") is None else
                 tuple(_pad_to(_pad_to(init["v_peak"][l], 0, bB), 1, lane)
                       for l in range(L)))
        cnt_in = _pad_to(_pad_to(init["counts"], 0, bB), 1, lane)
        first_in = _pad_to(_pad_to(init["first"], 0, bB), 1, lane)
        steps_in = _pad_to(init["steps"].astype(jnp.int32)[:, None], 0, bB)
    en_in = tuple(e.astype(jnp.uint8) for e in en_in)

    gate_in = None
    if gate is not None:
        gate_in = (
            _pad_to(gate["active"].astype(jnp.int32)[:, None], 0, bB),
            _pad_to(gate["prev"].astype(jnp.int32)[:, None], 0, bB),
            _pad_to(gate["streak"].astype(jnp.int32)[:, None], 0, bB),
        )

    outs = fused_snn.fused_snn_stack_pallas(
        px, st, ws, v_in, en_in, vp_in, cnt_in, first_in, steps_in, gate_in,
        chunk_steps=chunk_steps, window_steps=num_steps,
        decay_shift=decay_shift, v_threshold=v_threshold, v_rest=v_rest,
        v_min=v_min, v_max=v_max, active_pruning=active_pruning,
        patience=patience, readout=readout, sparse_skip=sparse_skip,
        streamed=streamed, block_b=bB, interpret=interpret)
    (cnt, vtr, first, adds, st_out, v_fin, en_fin, vp_fin, tel,
     steps_out) = outs[:10]
    tspk, ten, ttile = tel
    res = {
        "spike_counts": cnt[:B, :n_out],
        "v_trace": vtr[:, :B, :n_out],
        "first_spike_t": first[:B, :n_out],
        "v_final": v_fin[-1][:B, :n_out],
        "active_adds": adds[:, :B],
        "prng_state": st_out[:B, :n_in],
        "v": tuple(v_fin[l][:B, :sizes[l + 1]] for l in range(L)),
        "en": tuple(en_fin[l][:B, :sizes[l + 1]].astype(bool)
                    for l in range(L)),
        "v_peak": tuple(vp_fin[l][:B, :sizes[l + 1]] for l in range(L)),
        "telemetry": ChunkTelemetry(n_spk=tspk[:, :, :B],
                                    n_en=ten[:, :, :B],
                                    tiles_skipped=ttile),
        "steps": steps_out[:B, 0],
    }
    if gate is not None:
        act, prev, streak = outs[10]
        res["gate"] = {"active": act[:B, 0] != 0, "prev": prev[:B, 0],
                       "streak": streak[:B, 0]}
    return res


@partial(jax.jit, static_argnames=(
    "num_steps", "decay_shift", "v_threshold", "v_rest", "v_min", "v_max",
    "active_pruning", "sparse_skip", "streamed", "interpret"))
def fused_snn_op(pixels_u8: jax.Array, state_u32: jax.Array, w_q: jax.Array,
                 *, num_steps: int, decay_shift: int, v_threshold: int,
                 v_rest: int = 0, v_min: int = -(1 << 20),
                 v_max: int = (1 << 20) - 1, active_pruning: bool = False,
                 sparse_skip: bool | None = None, streamed: bool = False,
                 interpret: bool | None = None):
    """Single-layer whole-window convenience wrapper over the stack op.

    Returns a dict with ``spike_counts`` (B, N_out) i32, ``v_trace``
    (T, B, N_out) i32, ``first_spike_t`` (B, N_out) i32, ``v_final``
    (B, N_out) i32, ``active_adds`` (T, B) i32 and ``prng_state``
    (B, N_in) u32 — the (T, B, N_in) spike tensor is never materialised.
    """
    return fused_snn_stack_op(
        pixels_u8, state_u32, (w_q,), num_steps=num_steps,
        decay_shift=decay_shift, v_threshold=v_threshold, v_rest=v_rest,
        v_min=v_min, v_max=v_max, active_pruning=active_pruning,
        sparse_skip=sparse_skip, streamed=streamed, interpret=interpret)


def spike_matmul_op(spikes: jax.Array, w_q: jax.Array, *,
                    mode: str = "auto",
                    density_threshold: float | None = None,
                    with_telemetry: bool = False,
                    interpret: bool | None = None):
    """Event-driven spike×weight contraction.

    ``mode="auto"`` dispatches at RUNTIME on the observed spike density of
    the batch: a ``lax.cond`` picks the masked (event-driven) kernel below
    the dispatch threshold and the MXU dot above it.  Both kernels compute
    the identical int32 contraction (S ∈ {0,1} makes the masked add and
    the dot arithmetically the same), so the dispatch can never change
    results — only which datapath executes.  ``mode="masked"`` /
    ``mode="mxu"`` force one branch.

    ``density_threshold`` is the dispatch boundary: None resolves through
    config/env/default (``core.telemetry.resolve_density_threshold``) —
    the serving controller's retuned value
    (``SNNStreamEngine.dispatch_threshold``) arrives through this
    argument.  It enters the jitted computation as a TRACED scalar
    operand, not a static argument, so the controller walking it per
    chunk never recompiles.  ``with_telemetry=True`` additionally returns
    a ``core.telemetry.MatmulTelemetry`` (observed density + branch
    taken), the per-call twin of the fused kernel's chunk side channel.
    """
    return _spike_matmul_impl(
        spikes, w_q,
        jnp.float32(resolve_density_threshold(density_threshold)),
        mode=mode, with_telemetry=with_telemetry, interpret=interpret)


@partial(jax.jit, static_argnames=("mode", "with_telemetry", "interpret"))
def _spike_matmul_impl(spikes: jax.Array, w_q: jax.Array,
                       threshold: jax.Array, *, mode: str,
                       with_telemetry: bool, interpret: bool | None):
    interpret = _use_interpret() if interpret is None else interpret
    B, n_in = spikes.shape
    n_out = w_q.shape[1]
    bB, bN, bK = spike_matmul.DEFAULT_BLOCK
    s = _pad_to(_pad_to(spikes, 0, bB), 1, bK)
    w = _pad_to(_pad_to(w_q, 0, bK), 1, bN)
    density = jnp.mean((spikes != 0).astype(jnp.float32))
    if mode == "auto":
        used_masked = density < threshold
        out = jax.lax.cond(
            used_masked,
            lambda s, w: spike_matmul.spike_matmul_pallas(
                s, w, mode="masked", interpret=interpret),
            lambda s, w: spike_matmul.spike_matmul_pallas(
                s, w, mode="mxu", interpret=interpret),
            s, w)
    else:
        used_masked = jnp.asarray(mode == "masked")
        out = spike_matmul.spike_matmul_pallas(s, w, mode=mode,
                                               interpret=interpret)
    out = out[:B, :n_out]
    if with_telemetry:
        return out, MatmulTelemetry(density=density, used_masked=used_masked)
    return out
