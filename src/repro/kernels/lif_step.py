"""Pallas TPU kernel: fused T-step integer LIF layer (integrate→leak→fire→reset).

RTL datapath (paper Fig. 1): Weight-Reg → Adder → Accumulator → shift-based
Decay → Comparator → reset, sequenced by a local FSM over timesteps.

TPU mapping (the hardware-adaptation core of this repro):
  * The int16 weight matrix tile stays **resident in VMEM for all T steps**
    — the analogue of the RTL's on-chip BRAM weight bank ("no external
    memory access", paper §V-B).  Spikes stream in; membrane state lives in
    a VMEM scratch accumulator, exactly like the Accumulator register.
  * The synaptic sum Σ W·S with S ∈ {0,1} is a dot against an int8 spike
    vector — the MXU executes it as wide integer MACs, but since one operand
    is binary the effective arithmetic is the paper's "adds only" datapath;
    the energy model (core.energy) accounts it that way.
  * Leak = arithmetic right shift, fire = compare, reset = select: all VPU
    byte-lane ops, fused into the same pipeline stage as the MXU drain.
  * Active pruning is an enable mask in VMEM scratch, gating both the
    current and the state write-back — the clock-gate bit of §III-D.

Grid: (B/bB, N_out/bN); contraction dim N_in is kept whole in VMEM (the
SNN-scale layers the paper targets fit comfortably: 784×128 int16 = 200 KB).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["lif_forward_pallas"]

DEFAULT_BLOCK = (8, 128)  # (batch tile, out-neuron tile)


def _lif_kernel(spikes_ref, w_ref, spk_out_ref, vtr_out_ref, vfin_out_ref,
                *, num_steps: int, decay_shift: int, v_threshold: int,
                v_rest: int, v_min: int, v_max: int, active_pruning: bool):
    w = w_ref[...].astype(jnp.int32)              # (N_in, bN) resident all T
    bB = spk_out_ref.shape[1]
    bN = spk_out_ref.shape[2]

    v0 = jnp.full((bB, bN), v_rest, jnp.int32)
    en0 = jnp.ones((bB, bN), jnp.bool_)

    def body(t, carry):
        v, en = carry
        s_t = spikes_ref[t, :, :].astype(jnp.int32)      # (bB, N_in)
        # Σ W·S — binary operand ⇒ adds-only datapath (MXU int path on TPU).
        cur = jax.lax.dot_general(
            s_t, w, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.int32)
        cur = jnp.where(en, cur, 0)                      # pruning clock-gate
        v_int = jnp.clip(v + cur, v_min, v_max)          # saturating Adder
        v_leak = v_int - (v_int >> decay_shift)          # Decay-Reg shift
        fired = jnp.logical_and(v_leak >= v_threshold, en)   # Comparator
        v_new = jnp.where(fired, jnp.int32(v_rest), v_leak)  # hard reset
        v_new = jnp.where(en, v_new, v)                  # frozen when gated
        spk_out_ref[t, :, :] = fired.astype(jnp.uint8)
        vtr_out_ref[t, :, :] = v_new
        if active_pruning:
            en = jnp.logical_and(en, jnp.logical_not(fired))
        return (v_new, en)

    v_f, _ = jax.lax.fori_loop(0, num_steps, body, (v0, en0))
    vfin_out_ref[...] = v_f


def lif_forward_pallas(spikes_t: jax.Array, w_q: jax.Array, *,
                       decay_shift: int, v_threshold: int, v_rest: int = 0,
                       v_min: int = -(1 << 20), v_max: int = (1 << 20) - 1,
                       active_pruning: bool = False,
                       block=DEFAULT_BLOCK, interpret: bool = False):
    """spikes_t: (T, B, N_in) u8; w_q: (N_in, N_out) int16/int8.

    Returns (out_spikes u8 (T,B,N_out), v_trace i32 (T,B,N_out), v_final i32 (B,N_out)).
    """
    T, B, n_in = spikes_t.shape
    n_out = w_q.shape[1]
    bB, bN = block
    grid = (pl.cdiv(B, bB), pl.cdiv(n_out, bN))

    kernel = functools.partial(
        _lif_kernel, num_steps=T, decay_shift=decay_shift,
        v_threshold=v_threshold, v_rest=v_rest, v_min=v_min, v_max=v_max,
        active_pruning=active_pruning)

    spk, vtr, vfin = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            # Full T and full N_in per batch tile; only batch dim is split.
            pl.BlockSpec((T, bB, n_in), lambda i, j: (0, i, 0)),
            pl.BlockSpec((n_in, bN), lambda i, j: (0, j)),
        ],
        out_specs=[
            pl.BlockSpec((T, bB, bN), lambda i, j: (0, i, j)),
            pl.BlockSpec((T, bB, bN), lambda i, j: (0, i, j)),
            pl.BlockSpec((bB, bN), lambda i, j: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((T, B, n_out), jnp.uint8),
            jax.ShapeDtypeStruct((T, B, n_out), jnp.int32),
            jax.ShapeDtypeStruct((B, n_out), jnp.int32),
        ],
        interpret=interpret,
    )(spikes_t, w_q)
    return spk, vtr, vfin
