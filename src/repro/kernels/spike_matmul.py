"""Pallas TPU kernel: event-driven spike × weight accumulation.

The paper's headline arithmetic claim (Table II): `multiplications = 0` —
the synaptic sum Σᵢ Wᵢ·Sᵢ with binary S is a *masked add*, not a MAC.  This
kernel provides both TPU realisations of that insight:

  * ``mode="masked"`` — the literal RTL datapath: for each input line i,
    `acc += S_i ? W_i : 0` as a VPU select+add over the weight row.  This is
    the faithful model (and the energy-accounting ground truth), efficient
    when spike density is low and N_in is modest.
  * ``mode="mxu"`` — the TPU-native realisation: an int8 dot_general on the
    MXU with int32 accumulation.  Arithmetically identical (S ∈ {0,1});
    this is what a production TPU deployment would run at high density.

``ops.spike_matmul`` dispatches between them on expected spike density —
the kernel-level analogue of event-driven vs dense execution.

Grid: (B/bB, N_out/bN, N_in/bK) with K-accumulation across the innermost
grid dimension (output revisited per k-step, standard Pallas matmul idiom).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["spike_matmul_pallas"]

DEFAULT_BLOCK = (8, 128, 256)  # (bB, bN, bK)


def _spike_mm_kernel(s_ref, w_ref, out_ref, *, mode: str, nk: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    s = s_ref[...]                       # (bB, bK) uint8
    w = w_ref[...].astype(jnp.int32)     # (bK, bN)

    if mode == "mxu":
        acc = jax.lax.dot_general(
            s.astype(jnp.int32), w, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.int32)
    else:  # masked: literal select+add datapath, no multiplies
        bK = s.shape[1]

        def body(i, acc):
            s_i = s[:, i].astype(jnp.int32)          # (bB,)
            row = w[i, :]                            # (bN,)
            contrib = jnp.where(s_i[:, None] > 0, row[None, :], 0)
            return acc + contrib

        acc = jax.lax.fori_loop(
            0, bK, body, jnp.zeros(out_ref.shape, jnp.int32))

    out_ref[...] += acc


def spike_matmul_pallas(spikes: jax.Array, w_q: jax.Array, *,
                        mode: str = "mxu", block=DEFAULT_BLOCK,
                        interpret: bool = False) -> jax.Array:
    """spikes: (B, N_in) u8 in {0,1}; w_q: (N_in, N_out) int. → (B, N_out) i32."""
    B, n_in = spikes.shape
    n_out = w_q.shape[1]
    bB, bN, bK = block
    bK = min(bK, n_in)
    grid = (pl.cdiv(B, bB), pl.cdiv(n_out, bN), pl.cdiv(n_in, bK))

    kernel = functools.partial(_spike_mm_kernel, mode=mode, nk=grid[2])
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bB, bK), lambda i, j, k: (i, k)),
            pl.BlockSpec((bK, bN), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bB, bN), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((B, n_out), jnp.int32),
        interpret=interpret,
    )(spikes, w_q)
