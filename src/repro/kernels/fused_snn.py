"""Pallas TPU megakernel: fused Poisson-encode → LIF *stack* in ONE launch.

The paper's efficiency argument (§V-B) is that the encoder and the LIF
datapath share a chip, so the spike stream never crosses an external-memory
boundary.  The staged kernels (poisson_encode.py + lif_step.py) break that
property on TPU: the full ``(T, B, N)`` spike tensor round-trips through
HBM between every pair of launches — and for multi-layer stacks the
inter-layer spike traffic dominates (Bouvier et al. 2020; Abderrahmane et
al. 2019).  This kernel restores the RTL's event-stream locality for an
**arbitrary layer stack**, and makes the paper's two *sparsity* mechanisms
— Poisson spike sparsity and active pruning — real skipped compute:

  * pixels and the per-pixel xorshift32 PRNG lanes are loaded into VMEM
    once and stay there for the whole chunk (the free-running LFSR bank of
    Fig. 2);
  * every layer's weight matrix is resident as the paper's native 8-bit
    fixed-point codes: the 9-bit signed weight codes are **packed into two
    int8 planes** (``hi = w >> 1``, ``lo = w & 1``; see
    :func:`pack_weights`) and widened to int32 only per 128×128 tile, per
    use — 2 bytes/weight resident instead of the 6 (int16 storage + a
    whole-matrix int32 cast) the first revision kept live, which is what
    lets ~3× deeper/wider stacks fit the VMEM residency budget;
  * the per-layer Σ W·S contraction is tiled 128×128 and **event-driven**
    (``sparse_skip=True``): a K-tile whose spike block is all-zero, or an
    output tile whose enable block is fully pruned, is skipped via
    ``lax.cond`` — no MXU pass, no widen — instead of merely having its
    result masked.  Skipped tiles contribute exactly zero to the integer
    accumulator and zero executed adds, so the sparse path is bit-identical
    to the dense one (results AND energy counters; integer addition is
    exact and associative);
  * each timestep generates the input spike vector in registers/VMEM and
    walks it through a *static Python layer loop*; the fired vector feeds
    the next layer directly.  Inter-layer spikes are **never written to
    HBM**.
  * ``streamed=True`` runs stacks that exceed the residency budget in one
    launch anyway: the packed weight planes stay in HBM and a
    **double-buffered DMA pipeline** copies one 128-row K-slab at a time
    into a 2-slot VMEM scratch, with the next slab's copy overlapped
    against the current slab's contraction (and the tile-skip predicates
    still gating the compute).
  * the kernel is **resumable**: it accepts initial per-layer membrane and
    enable state, per-layer peak-membrane accumulators, the PRNG lanes,
    the spike-count / first-spike registers and a per-lane step counter,
    and returns the advanced versions — so a T-step window split into
    chunks is bit-identical to one launch (serve.snn_engine streams
    through this).  The carried peak accumulator is what lets the
    ``membrane`` readout stream without a per-step trace buffer.
  * every launch also emits the **telemetry side channel**
    (``core.telemetry.ChunkTelemetry``): per-step, per-layer input-spike
    counts and prune-enable occupancy per lane, plus the per-block MXU
    tile pairs the event-driven contraction skipped — the measured
    activity the serving layer's adaptive dispatch controller consumes.
    The jnp backends re-derive the identical record, so telemetry is
    bit-checkable exactly like the datapath.
  * optionally the kernel also runs the serving-layer **stability gate**
    per step (``gated=True``): a lane whose running prediction has been
    stable for ``patience`` steps freezes in place (PRNG, membranes,
    counters), mirroring ``serve.snn_engine.stream_chunk``'s jnp fallback
    bit-for-bit.

Only per-neuron outputs come back: final-layer spike counts, first-spike
times and membrane trace, per-layer membrane/enable state, the per-step
executed-add count (energy side channel, summed over layers) and the
advanced PRNG state.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["fused_snn_stack_pallas", "pack_weights", "stack_vmem_bytes",
           "layer_shard_ways", "partial_contraction_pallas",
           "block_b_for", "VMEM_BUDGET_BYTES", "DEFAULT_BLOCK_B", "LANE"]

DEFAULT_BLOCK_B = 8     # batch tile per program
LANE = 128              # TPU lane width: every neuron axis pads to this

# Conservative share of the ~16 MB/core VMEM the resident stack may claim
# (weights + state + trace + temporaries).  ``core.snn.resolve_backend``
# streams the weights (``fused_streamed``) or falls back to staged when
# the estimate exceeds this.
VMEM_BUDGET_BYTES = 12 << 20


def _pad128(n: int) -> int:
    return n + (-n) % LANE


def block_b_for(batch: int | None) -> int:
    """Batch block actually launched for a ``batch``-row tile.

    The default block, shrunk to the 8-row-sublane-padded batch when that
    is smaller — the single source of truth shared by the launcher
    (kernels.ops.fused_snn_stack_op) and the VMEM feasibility estimate
    (core.snn.fused_unsupported_reason), so the footprint a sharded
    caller validates with ``local_batch`` is exactly the block its
    per-device launch allocates.  With the current 8-row default the two
    coincide for every batch; the clamp matters the day DEFAULT_BLOCK_B
    grows past the sublane minimum.
    """
    if batch is None:
        return DEFAULT_BLOCK_B
    return min(DEFAULT_BLOCK_B, max(8, int(batch) + (-int(batch)) % 8))


def pack_weights(w_q: jax.Array) -> jax.Array:
    """Pack 9-bit signed weight codes into two int8 planes.

    ``w = 2*hi + lo`` with ``hi = w >> 1`` (arithmetic) and ``lo = w & 1``
    — exact for every code in the paper's signed 9-bit range [-256, 255]
    (``core.snn.quantize_params``' output contract), which is what lets
    the resident stack live at 2 bytes/weight instead of int16 + a
    whole-matrix int32 cast.  Returns ``(2, n_in, n_out)`` int8 with
    plane 0 = hi, plane 1 = lo; the kernel widens per 128×128 tile, per
    use (:func:`_widen_tile`).
    """
    w32 = w_q.astype(jnp.int32)
    hi = jnp.right_shift(w32, 1)
    lo = w32 - 2 * hi                      # ∈ {0, 1}
    return jnp.stack([hi.astype(jnp.int8), lo.astype(jnp.int8)])


def _widen_tile(packed: jax.Array) -> jax.Array:
    """(2, k, n) int8 planes → (k, n) int32 weight tile (w = 2*hi + lo)."""
    return (packed[0].astype(jnp.int32) * 2 + packed[1].astype(jnp.int32))


def layer_shard_ways(layer_sizes, model_shards: int):
    """Effective model-axis shard count per layer (len = n_layers).

    A layer's output-neuron dimension shards ``model_shards``-way only
    when the RAW width divides evenly — contiguous column slices of
    identical width are what make the sharded integer contraction
    concatenate back to the single-device result bit-for-bit.  A layer
    that doesn't divide (e.g. the 10-class head on a 4-way axis)
    replicates instead: every model peer holds its full weight matrix,
    computes the identical output redundantly, and skips the spike
    exchange entirely.  Shared by the VMEM feasibility estimate, the
    sharded stack step (``core.snn.snn_int_stack_step_sharded``) and the
    engine's per-layer weight placement, so all three agree on which
    layers actually split.
    """
    if model_shards <= 1:
        return tuple(1 for _ in layer_sizes[1:])
    return tuple(int(model_shards) if int(n) % int(model_shards) == 0 else 1
                 for n in layer_sizes[1:])


def stack_vmem_bytes(layer_sizes, block_b: int = DEFAULT_BLOCK_B,
                     num_steps: int = 1, streamed: bool = False,
                     model_shards: int = 1) -> int:
    """Estimate of the kernel's resident VMEM footprint for one program.

    Counts the padded int8-packed weight planes (2 bytes/weight resident;
    replaced by the 2-slot DMA slab scratch when ``streamed``), pixels +
    PRNG lanes, per-layer membrane/enable state, the final-layer trace
    block, the single per-use widened int32 weight tile and a working-set
    allowance for the per-step spike/current temporaries.  Kept in
    lockstep with the launcher: same padding, same ``block_b_for`` block,
    same scratch shapes as :func:`fused_snn_stack_pallas` allocates.

    With ``model_shards > 1`` the estimate is the PER-DEVICE footprint on
    a model axis: each layer that divides (:func:`layer_shard_ways`)
    contributes only its output-column shard — weight planes, membrane /
    enable state and current all shrink by the shard count (padded back
    to the 128-lane boundary), while the input-spike side stays full
    (every device holds the gathered spike vector).  Layers that don't
    divide stay whole.  ``model_shards=1`` reproduces the historical
    single-device estimate exactly.
    """
    sizes_raw = [int(n) for n in layer_sizes]
    ways = layer_shard_ways(sizes_raw, model_shards)
    sizes = [_pad128(n) for n in sizes_raw]
    shard_outs = [_pad128(n // w) for n, w in zip(sizes_raw[1:], ways)]
    bB = block_b
    L = len(sizes) - 1
    max_out = max(shard_outs)
    total = sizes[0] * bB * (1 + 4)                      # pixels + PRNG
    for n_in, n_out in zip(sizes[:-1], shard_outs):
        if not streamed:
            total += n_in * n_out * 2                    # packed int8 hi+lo
        total += bB * n_out * (4 + 4 + 1 + 4)            # v + v_peak + en + current
    if streamed:
        total += 2 * 2 * LANE * max_out                  # 2-slot DMA slabs
    total += LANE * max_out * 4                          # widened i32 tile
    total += num_steps * bB * shard_outs[-1] * 4         # v_trace block
    total += num_steps * L * (2 * bB + 1) * 4            # telemetry blocks
    total += bB * max(sizes[0], max_out) * 8             # spike temporaries
    return total


def _first_argmax(x: jax.Array, n_true: int) -> jax.Array:
    """First index of the row max — matches jnp.argmax tie-breaking.

    x: (bB, n) int32.  Returns (bB, 1) int32.  Implemented with iota+min so
    it lowers cleanly inside a Pallas TPU kernel.
    """
    bB, n = x.shape
    m = jnp.max(x, axis=-1, keepdims=True)
    col = jax.lax.broadcasted_iota(jnp.int32, (bB, n), 1)
    return jnp.min(jnp.where(x == m, col, n_true), axis=-1, keepdims=True)


def _tiled_contraction(x, en, read_tile, n_out_pad: int, sparse_skip: bool,
                       pre_k=None):
    """Event-driven Σ W·S over 128×128 tiles (K-outer, N-inner).

    ``x``: (bB, n_in_pad) bool spikes; ``en``: (bB, n_out_pad) bool enable;
    ``read_tile(kt, nt)`` returns the packed (2, LANE, LANE) int8 weight
    tile; ``pre_k(kt)`` (streamed mode) runs unconditionally at the top of
    each K iteration — it advances the DMA double buffer, so the K-outer
    order is what lets one 2-slot scratch cover arbitrarily wide layers.
    With ``sparse_skip`` each (kt, nt) tile pair runs under a
    ``lax.cond``: skipped when the K-tile carries no spike in any lane OR
    the output tile is fully pruned across the block.  Both predicates
    only ever skip tiles whose contribution is exactly zero (no spikes →
    zero rows; fully pruned → the result is zeroed by the enable mask),
    so dense and sparse execution are bit-identical — the skip saves the
    widen + MXU pass, not correctness (integer addition is exact, so the
    K-tiled accumulation order cannot change results either).

    Returns ``(result, skipped)`` where ``skipped`` is the scalar i32
    count of tile pairs the predicates skipped this call (0 when
    ``sparse_skip`` is off) — the telemetry side channel's per-block
    tile counter, emitted instead of staying a kernel-private decision.
    """
    bB, n_in_pad = x.shape
    nkt, nnt = n_in_pad // LANE, n_out_pad // LANE
    zeros = jnp.zeros((bB, LANE), jnp.int32)
    accs = [zeros] * nnt
    skipped = jnp.int32(0)
    for kt in range(nkt):
        if pre_k is not None:
            pre_k(kt)
        x_t = x[:, kt * LANE:(kt + 1) * LANE]
        for nt in range(nnt):
            en_t = en[:, nt * LANE:(nt + 1) * LANE]

            def tile(x_t=x_t, kt=kt, nt=nt):
                w32 = _widen_tile(read_tile(kt, nt))
                return jax.lax.dot_general(
                    x_t.astype(jnp.int32), w32, (((1,), (0,)), ((), ())),
                    preferred_element_type=jnp.int32)

            if sparse_skip:
                live = jnp.logical_and(jnp.any(x_t), jnp.any(en_t))
                skipped = skipped + (1 - live.astype(jnp.int32))
                accs[nt] = accs[nt] + jax.lax.cond(live, tile,
                                                   lambda: zeros)
            else:
                accs[nt] = accs[nt] + tile()
    out = accs[0] if nnt == 1 else jnp.concatenate(accs, axis=-1)
    return out, skipped


def _partial_kernel(x_ref, en_ref, w_ref, out_ref, skip_ref, *,
                    sparse_skip: bool):
    x = x_ref[...] != 0
    en = en_ref[...] != 0

    def read_tile(kt, nt):
        return w_ref[:, kt * LANE:(kt + 1) * LANE, nt * LANE:(nt + 1) * LANE]

    cur, skipped = _tiled_contraction(x, en, read_tile, w_ref.shape[2],
                                      sparse_skip)
    out_ref[...] = cur
    skip_ref[0, 0] = skipped


def partial_contraction_pallas(x_u8: jax.Array, en_u8: jax.Array,
                               w_packed: jax.Array, *,
                               sparse_skip: bool = True,
                               block_b: int = DEFAULT_BLOCK_B,
                               interpret: bool = False):
    """One layer's per-device partial Σ W·S over an output-column shard.

    The model-axis datapath building block: each device calls this with
    the FULL input-spike vector ``x_u8`` (B, n_in_pad) and the packed
    weight planes of ITS output-neuron shard ``w_packed``
    (2, n_in_pad, n_out_shard_pad) — concatenating the per-device results
    over the model axis in shard order IS the single-device contraction,
    bit-for-bit, because the column shards are disjoint and integer
    accumulation is exact.  Unlike :func:`fused_snn_stack_pallas` this is
    one layer, one step: the spike exchange between layers happens
    OUTSIDE the launch (``jax.lax.all_gather`` under ``shard_map`` in
    ``core.snn.snn_int_stack_step_sharded``) — kernel-level inter-chip
    RDMA collectives are TPU-only and would break the CPU-interpretable
    bit-identity contract every backend here honors.

    Same event-driven tile skipping as the megakernel
    (:func:`_tiled_contraction`, ``en_u8`` = the shard's enable columns),
    and the same telemetry: returns ``(current, skipped)`` with
    ``current`` (B, n_out_shard_pad) int32 and ``skipped`` (n_blocks,)
    int32 — this shard's skipped tile pairs per batch block, which the
    model-sharded telemetry record concatenates on the block axis.
    """
    B, n_in_pad = x_u8.shape
    n_out_pad = w_packed.shape[2]
    bB = block_b
    grid = (pl.cdiv(B, bB),)
    n_blocks = grid[0]
    kernel = functools.partial(_partial_kernel, sparse_skip=sparse_skip)
    out, skipped = pl.pallas_call(
        kernel, grid=grid,
        in_specs=[
            pl.BlockSpec((bB, n_in_pad), lambda i: (i, 0)),
            pl.BlockSpec((bB, n_out_pad), lambda i: (i, 0)),
            pl.BlockSpec(w_packed.shape, lambda i: (0, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bB, n_out_pad), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, n_out_pad), jnp.int32),
            jax.ShapeDtypeStruct((n_blocks, 1), jnp.int32),
        ],
        interpret=interpret)(x_u8, en_u8, w_packed)
    return out, skipped[:, 0]


def _stack_kernel(*refs, num_layers: int, chunk_steps: int, window_steps: int,
                  decay_shift: int, v_threshold: int, v_rest: int,
                  v_min: int, v_max: int, active_pruning: bool,
                  gated: bool, patience: int, readout: str,
                  sparse_skip: bool, streamed: bool):
    L = num_layers
    it = iter(refs)
    px_ref, st_ref = next(it), next(it)
    w_refs = [next(it) for _ in range(L)]   # packed (2, K, N) int8 planes
    v_refs = [next(it) for _ in range(L)]
    en_refs = [next(it) for _ in range(L)]
    vp_refs = [next(it) for _ in range(L)]  # per-layer peak membranes
    cnt_ref, first_ref, steps_ref = next(it), next(it), next(it)
    if gated:
        act_ref, gprev_ref, gstreak_ref = next(it), next(it), next(it)
    cnt_out, vtr_out, first_out, adds_out, st_out = (
        next(it), next(it), next(it), next(it), next(it))
    v_outs = [next(it) for _ in range(L)]
    en_outs = [next(it) for _ in range(L)]
    vp_outs = [next(it) for _ in range(L)]
    tspk_out, ten_out, ttile_out = next(it), next(it), next(it)
    steps_out = next(it)
    if gated:
        act_out, gprev_out, gstreak_out = next(it), next(it), next(it)

    px = px_ref[...]                                   # (bB, n_in) uint8
    n_pads = [w.shape[2] for w in w_refs]              # padded layer widths
    n_out = cnt_ref.shape[1]
    # streamed mode: (layer, K-slab) pairs in execution order — the DMA
    # pipeline walks them with a 2-slot double buffer each step.
    slabs = [(l, kt) for l in range(L)
             for kt in range(w_refs[l].shape[1] // LANE)]

    def run(w_scr=None, sems=None):
        def slab_dma(i: int):
            l, kt = slabs[i]
            slot = i % 2
            return pltpu.make_async_copy(
                w_refs[l].at[:, pl.ds(kt * LANE, LANE), pl.ds(0, n_pads[l])],
                w_scr.at[slot, :, :, pl.ds(0, n_pads[l])],
                sems.at[slot])

        carry0 = (
            st_ref[...],
            tuple(v_refs[l][...] for l in range(L)),
            tuple(en_refs[l][...] != 0 for l in range(L)),
            tuple(vp_refs[l][...] for l in range(L)),
            cnt_ref[...],
            first_ref[...],
            steps_ref[...],                            # (bB, 1) i32
        )
        if gated:
            carry0 = carry0 + (act_ref[...] != 0, gprev_ref[...],
                               gstreak_ref[...])

        def body(t, carry):
            if gated:
                (s, vs, ens, vps, cnt, first, steps, act, gprev,
                 gstreak) = carry
            else:
                s, vs, ens, vps, cnt, first, steps = carry

            # --- encoder: xorshift32 step + 8-bit comparator (Fig. 2) ----
            s_new = s ^ (s << 13)
            s_new = s_new ^ (s_new >> 17)
            s_new = s_new ^ (s_new << 5)
            r = (s_new >> 24).astype(jnp.uint8)
            x = px > r                                 # (bB, n_in) on-chip
            if streamed:
                slab_dma(0).start()                    # warm the pipeline

            # --- static layer loop: spikes stay in VMEM between layers ---
            adds_t = jnp.zeros(steps.shape, jnp.int32)  # (bB, 1)
            new_vs, new_ens, new_vps = [], [], []
            spk_t, en_t_tel, skip_t = [], [], []       # telemetry rows
            base = 0                                   # streamed slab cursor
            for l in range(L):
                en = ens[l]
                if streamed:
                    # Double-buffered HBM→VMEM slab pipeline: each K
                    # iteration kicks off the NEXT slab's copy (into the
                    # other scratch slot) before waiting on the current
                    # one, so the copy of slab p+1 overlaps the
                    # contraction against slab p.  ``base`` indexes this
                    # layer's first entry in the step's (layer, K-slab)
                    # order.
                    def pre_k(kt, base=base):
                        if base + kt + 1 < len(slabs):
                            slab_dma(base + kt + 1).start()
                        slab_dma(base + kt).wait()

                    def read_tile(kt, nt, l=l, base=base):
                        return w_scr[(base + kt) % 2, :, :,
                                     nt * LANE:(nt + 1) * LANE]
                    base += w_refs[l].shape[1] // LANE
                else:
                    pre_k = None

                    def read_tile(kt, nt, l=l):
                        return w_refs[l][:, kt * LANE:(kt + 1) * LANE,
                                         nt * LANE:(nt + 1) * LANE]

                cur, skipped = _tiled_contraction(x, en, read_tile,
                                                  n_pads[l], sparse_skip,
                                                  pre_k)
                cur = jnp.where(en, cur, 0)            # pruning clock-gate
                v_int = jnp.clip(vs[l] + cur, v_min, v_max)
                v_leak = v_int - (v_int >> decay_shift)
                fired = jnp.logical_and(v_leak >= v_threshold, en)
                v_new = jnp.where(fired, jnp.int32(v_rest), v_leak)
                v_new = jnp.where(en, v_new, vs[l])    # frozen when gated
                # energy: adds executed = input spikes × enabled outputs.
                # Identical on the sparse path: a skipped tile pair has
                # either zero spikes or zero enabled outputs, so its
                # n_spk·n_en term of the Σ_{kt,nt} expansion is zero —
                # the dense product below already counts only executed
                # work.
                n_spk = jnp.sum(x.astype(jnp.int32), axis=-1, keepdims=True)
                n_en = jnp.sum(en.astype(jnp.int32), axis=-1, keepdims=True)
                adds_t = adds_t + n_spk * n_en
                spk_t.append(n_spk[:, 0])
                en_t_tel.append(n_en[:, 0])
                skip_t.append(skipped)
                if active_pruning:
                    en = jnp.logical_and(en, jnp.logical_not(fired))
                new_vs.append(v_new)
                new_ens.append(en)
                new_vps.append(jnp.maximum(vps[l], v_new))
                x = fired                              # next layer's input

            # --- final-layer readout registers ---------------------------
            cnt_new = cnt + x.astype(jnp.int32)
            first_new = jnp.where(
                jnp.logical_and(x, first == window_steps), steps, first)
            v_last = new_vs[-1]

            # telemetry rows for this step: (L, bB) spike/enable counts and
            # (L,) per-block skipped tiles.  The tile row stays unmasked in
            # gated mode — it records what the block's contraction actually
            # executed/skipped, and frozen lanes still sit in the block.
            tel_spk = jnp.stack(spk_t)
            tel_en = jnp.stack(en_t_tel)
            ttile_out[t, :, 0] = jnp.stack(skip_t)

            if gated:
                # stability gate, mirroring serve.snn_engine.stream_chunk's
                # jnp fallback bit-for-bit (same op order, tie-breaking).
                has_spike = jnp.max(cnt_new, axis=-1, keepdims=True) > 0
                if readout == "first_spike":
                    large = jnp.int32(1 << 24)
                    score = jnp.where(
                        cnt_new > 0, large + (window_steps - first_new),
                        jnp.clip(v_last, -large + 1, large - 1))
                    pred = _first_argmax(score, n_out)
                elif readout == "membrane":
                    # streamed peak-membrane readout off the carried
                    # accumulator — no trace buffer needed
                    pred = _first_argmax(new_vps[-1], n_out)
                else:                                  # count
                    pred = _first_argmax(cnt_new, n_out)
                streak_raw = jnp.where(pred == gprev, gstreak + 1, 0)
                done = streak_raw >= patience
                gprev_new = jnp.where(has_spike, pred, -1)
                gstreak_new = jnp.where(has_spike, streak_raw, 0)
                done = jnp.logical_and(done, has_spike)
                steps_new = steps + act.astype(jnp.int32)
                still = jnp.logical_and(act, jnp.logical_not(done))
                still = jnp.logical_and(still, steps_new < window_steps)

                def keep(new, old):
                    return jnp.where(act, new, old)

                s_new = keep(s_new, s)
                new_vs = [keep(nv, ov) for nv, ov in zip(new_vs, vs)]
                new_ens = [jnp.where(act, ne, oe)
                           for ne, oe in zip(new_ens, ens)]
                new_vps = [keep(nv, ov) for nv, ov in zip(new_vps, vps)]
                cnt_new = keep(cnt_new, cnt)
                first_new = keep(first_new, first)
                gprev_new = keep(gprev_new, gprev)
                gstreak_new = keep(gstreak_new, gstreak)
                vtr_out[t, :, :] = new_vs[-1]
                adds_out[t, :] = jnp.where(act, adds_t, 0)[:, 0]
                # frozen lanes execute nothing, so their telemetry rows are
                # zero — matching the frozen executed-add channel above
                lane_act = act[:, 0][None, :]          # (1, bB)
                tspk_out[t, :, :] = jnp.where(lane_act, tel_spk, 0)
                ten_out[t, :, :] = jnp.where(lane_act, tel_en, 0)
                return (s_new, tuple(new_vs), tuple(new_ens),
                        tuple(new_vps), cnt_new, first_new, steps_new,
                        still, gprev_new, gstreak_new)

            vtr_out[t, :, :] = v_last
            adds_out[t, :] = adds_t[:, 0]
            tspk_out[t, :, :] = tel_spk
            ten_out[t, :, :] = tel_en
            return (s_new, tuple(new_vs), tuple(new_ens), tuple(new_vps),
                    cnt_new, first_new, steps + 1)

        carry_f = jax.lax.fori_loop(0, chunk_steps, body, carry0)
        if gated:
            (s_f, vs_f, ens_f, vps_f, cnt_f, first_f, steps_f, act_f, gp_f,
             gs_f) = carry_f
            act_out[...] = act_f.astype(jnp.int32)
            gprev_out[...] = gp_f
            gstreak_out[...] = gs_f
        else:
            s_f, vs_f, ens_f, vps_f, cnt_f, first_f, steps_f = carry_f
        cnt_out[...] = cnt_f
        first_out[...] = first_f
        st_out[...] = s_f
        steps_out[...] = steps_f
        for l in range(num_layers):
            v_outs[l][...] = vs_f[l]
            en_outs[l][...] = ens_f[l].astype(jnp.uint8)
            vp_outs[l][...] = vps_f[l]

    if streamed:
        max_out = max(n_pads)
        pl.run_scoped(
            run,
            w_scr=pltpu.VMEM((2, 2, LANE, max_out), jnp.int8),
            sems=pltpu.SemaphoreType.DMA((2,)))
    else:
        run()


def fused_snn_stack_pallas(pixels_u8: jax.Array, state_u32: jax.Array,
                           weights_packed, v_init, en_init, vp_init,
                           counts_init: jax.Array,
                           first_init: jax.Array, steps_init: jax.Array,
                           gate_init=None, *, chunk_steps: int,
                           window_steps: int, decay_shift: int,
                           v_threshold: int, v_rest: int = 0,
                           v_min: int = -(1 << 20),
                           v_max: int = (1 << 20) - 1,
                           active_pruning: bool = False, patience: int = 0,
                           readout: str = "count",
                           sparse_skip: bool = True, streamed: bool = False,
                           block_b: int = DEFAULT_BLOCK_B,
                           interpret: bool = False):
    """Run ``chunk_steps`` timesteps of the full encode→LIF stack.

    All arrays must already be padded: batch to ``block_b``, every neuron
    axis to 128 (use ``kernels.ops.fused_snn_stack_op``, which also masks
    padded neurons out of the enable sets and packs the weights).

      pixels_u8/state_u32: (B, n_in)
      weights_packed: [(2, n_l, n_{l+1}) int8] from :func:`pack_weights`
      v_init/en_init: per-layer (B, n_{l+1}) int32 / uint8
      vp_init: per-layer (B, n_{l+1}) int32 carried peak membranes
        (INT32_MIN at window start — max-folded per step, so a chunked
        window's running peak is bit-identical to the one-shot maximum)
      counts_init/first_init: (B, n_out) int32 (first sentinel=window_steps)
      steps_init: (B, 1) int32 — per-lane absolute step counter
      gate_init: None, or (active u8, prev i32, streak i32) each (B, 1)

    ``sparse_skip`` gates the event-driven tile skipping (bit-identical
    either way); ``streamed`` keeps the packed weight planes in HBM and
    double-buffers 128-row slabs through VMEM scratch — the path for
    stacks whose resident footprint exceeds the VMEM budget.

    Returns (counts, v_trace (chunk,B,n_out), first, adds (chunk,B),
    state_u32', v_final tuple, en_final tuple (uint8), v_peak tuple,
    (tel_spk (chunk,L,B), tel_en (chunk,L,B),
    tel_tiles (chunk,L,n_blocks)), steps', and — when gated —
    (active', prev', streak')).
    """
    B, n_in = pixels_u8.shape
    L = len(weights_packed)
    sizes = [n_in] + [w.shape[2] for w in weights_packed]
    n_out = sizes[-1]
    gated = gate_init is not None
    grid = (pl.cdiv(B, block_b),)
    bB = block_b

    kernel = functools.partial(
        _stack_kernel, num_layers=L, chunk_steps=chunk_steps,
        window_steps=window_steps, decay_shift=decay_shift,
        v_threshold=v_threshold, v_rest=v_rest, v_min=v_min, v_max=v_max,
        active_pruning=active_pruning, gated=gated, patience=patience,
        readout=readout, sparse_skip=sparse_skip, streamed=streamed)

    def row(shape):      # batch-tiled 2-D state block
        return pl.BlockSpec((bB,) + shape[1:], lambda i: (i,) + (0,) * (len(shape) - 1))

    def whole(shape):    # fully VMEM-resident (packed weight planes)
        return pl.BlockSpec(shape, lambda i: (0,) * len(shape))

    # Streamed weights never enter VMEM whole: the kernel DMAs 128-row
    # slabs out of HBM/ANY on demand.
    w_spec = ((lambda w: pl.BlockSpec(memory_space=pltpu.ANY)) if streamed
              else (lambda w: whole(w.shape)))

    in_specs = [row(pixels_u8.shape), row(state_u32.shape)]
    in_specs += [w_spec(w) for w in weights_packed]
    in_specs += [row(v.shape) for v in v_init]
    in_specs += [row(e.shape) for e in en_init]
    in_specs += [row(v.shape) for v in vp_init]
    in_specs += [row(counts_init.shape), row(first_init.shape),
                 row(steps_init.shape)]
    inputs = ([pixels_u8, state_u32] + list(weights_packed) + list(v_init)
              + list(en_init) + list(vp_init)
              + [counts_init, first_init, steps_init])
    if gated:
        in_specs += [row(g.shape) for g in gate_init]
        inputs += list(gate_init)

    out_specs = [
        row((B, n_out)),
        pl.BlockSpec((chunk_steps, bB, n_out), lambda i: (0, i, 0)),
        row((B, n_out)),
        pl.BlockSpec((chunk_steps, bB), lambda i: (0, i)),
        row((B, n_in)),
    ]
    out_shape = [
        jax.ShapeDtypeStruct((B, n_out), jnp.int32),
        jax.ShapeDtypeStruct((chunk_steps, B, n_out), jnp.int32),
        jax.ShapeDtypeStruct((B, n_out), jnp.int32),
        jax.ShapeDtypeStruct((chunk_steps, B), jnp.int32),
        jax.ShapeDtypeStruct((B, n_in), jnp.uint32),
    ]
    for l in range(L):
        out_specs.append(row((B, sizes[l + 1])))
        out_shape.append(jax.ShapeDtypeStruct((B, sizes[l + 1]), jnp.int32))
    for l in range(L):
        out_specs.append(row((B, sizes[l + 1])))
        out_shape.append(jax.ShapeDtypeStruct((B, sizes[l + 1]), jnp.uint8))
    for l in range(L):                     # per-layer peak membranes
        out_specs.append(row((B, sizes[l + 1])))
        out_shape.append(jax.ShapeDtypeStruct((B, sizes[l + 1]), jnp.int32))
    # telemetry side channel: per-step/layer spike + enable counts per
    # lane, skipped tile pairs per batch block
    n_blocks = grid[0]
    out_specs += [
        pl.BlockSpec((chunk_steps, L, bB), lambda i: (0, 0, i)),
        pl.BlockSpec((chunk_steps, L, bB), lambda i: (0, 0, i)),
        pl.BlockSpec((chunk_steps, L, 1), lambda i: (0, 0, i)),
    ]
    out_shape += [
        jax.ShapeDtypeStruct((chunk_steps, L, B), jnp.int32),
        jax.ShapeDtypeStruct((chunk_steps, L, B), jnp.int32),
        jax.ShapeDtypeStruct((chunk_steps, L, n_blocks), jnp.int32),
    ]
    out_specs.append(row((B, 1)))
    out_shape.append(jax.ShapeDtypeStruct((B, 1), jnp.int32))
    if gated:
        for _ in range(3):
            out_specs.append(row((B, 1)))
            out_shape.append(jax.ShapeDtypeStruct((B, 1), jnp.int32))

    outs = pl.pallas_call(
        kernel, grid=grid, in_specs=in_specs, out_specs=out_specs,
        out_shape=out_shape, interpret=interpret)(*inputs)

    cnt, vtr, first, adds, st_out = outs[:5]
    v_fin = tuple(outs[5:5 + L])
    en_fin = tuple(outs[5 + L:5 + 2 * L])
    vp_fin = tuple(outs[5 + 2 * L:5 + 3 * L])
    tel = tuple(outs[5 + 3 * L:8 + 3 * L])
    steps_out = outs[8 + 3 * L]
    if gated:
        return (cnt, vtr, first, adds, st_out, v_fin, en_fin, vp_fin, tel,
                steps_out, tuple(outs[9 + 3 * L:12 + 3 * L]))
    return (cnt, vtr, first, adds, st_out, v_fin, en_fin, vp_fin, tel,
            steps_out)
