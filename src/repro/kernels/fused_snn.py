"""Pallas TPU megakernel: fused Poisson-encode → LIF window in ONE launch.

The paper's efficiency argument (§V-B) is that the encoder and the LIF
datapath share a chip, so the spike stream never crosses an external-memory
boundary.  The staged kernels (poisson_encode.py + lif_step.py) break that
property on TPU: the full ``(T, B, N_in)`` spike tensor round-trips through
HBM between the two launches — for the paper config that is T× more traffic
than the pixels themselves.  This kernel restores the RTL's event-stream
locality:

  * pixels and the per-pixel xorshift32 PRNG lanes are loaded into VMEM
    once and stay there for the whole T-step window (the free-running LFSR
    bank of Fig. 2);
  * the int16 weight tile is resident across the window (the BRAM weight
    bank of Fig. 1);
  * each timestep generates the spike vector in registers/VMEM, feeds it
    straight into the Σ W·S contraction (MXU int path — "adds only" since
    one operand is binary), then the shift-leak / fire / reset / pruning
    VPU stages — and discards it.  Spikes are **never written to HBM**.
  * only the per-neuron outputs come back: spike counts, first-spike
    times, the (T, B, N_out) membrane trace (N_out ≪ N_in), the final
    membrane state, the per-step executed-add count (energy side channel)
    and the advanced PRNG state.

Grid: (B/bB, N_out/bN) with the output tile innermost so the per-step add
counter can be accumulated across N_out tiles (standard revisit idiom).
``n_out_true`` masks padded output columns out of the enable set so the
energy accounting stays bit-identical to the unpadded reference.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["fused_snn_forward_pallas"]

DEFAULT_BLOCK = (8, 128)  # (batch tile, out-neuron tile)


def _fused_kernel(px_ref, st_ref, w_ref,
                  cnt_ref, vtr_ref, first_ref, vfin_ref, adds_ref, st_out_ref,
                  *, num_steps: int, decay_shift: int, v_threshold: int,
                  v_rest: int, v_min: int, v_max: int, active_pruning: bool,
                  n_out_true: int):
    j = pl.program_id(1)
    px = px_ref[...]                              # (bB, n_in) uint8
    w = w_ref[...].astype(jnp.int32)              # (n_in, bN) resident all T
    bB, bN = cnt_ref.shape

    # Padded output columns are never enabled: they cannot fire and do not
    # count toward the executed-add side channel.
    col = j * bN + jax.lax.broadcasted_iota(jnp.int32, (bB, bN), 1)
    valid = col < n_out_true

    s0 = st_ref[...]                              # (bB, n_in) uint32
    v0 = jnp.full((bB, bN), v_rest, jnp.int32)
    cnt0 = jnp.zeros((bB, bN), jnp.int32)
    first0 = jnp.full((bB, bN), num_steps, jnp.int32)

    def body(t, carry):
        s, v, en, cnt, first = carry
        # --- encoder: xorshift32 step + 8-bit comparator (Fig. 2) ---
        s = s ^ (s << 13)
        s = s ^ (s >> 17)
        s = s ^ (s << 5)
        r = (s >> 24).astype(jnp.uint8)
        spk = px > r                              # (bB, n_in) — stays on-chip
        # --- Σ W·S: binary operand ⇒ adds-only datapath (MXU int path) ---
        cur = jax.lax.dot_general(
            spk.astype(jnp.int32), w, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.int32)
        cur = jnp.where(en, cur, 0)               # pruning clock-gate
        # --- LIF: saturating add, shift leak, compare, hard reset ---
        v_int = jnp.clip(v + cur, v_min, v_max)
        v_leak = v_int - (v_int >> decay_shift)
        fired = jnp.logical_and(v_leak >= v_threshold, en)
        v_new = jnp.where(fired, jnp.int32(v_rest), v_leak)
        v_new = jnp.where(en, v_new, v)           # frozen when gated
        vtr_ref[t, :, :] = v_new
        # --- spike register / first-spike latch (readout state) ---
        first = jnp.where(jnp.logical_and(fired, first == num_steps),
                          jnp.int32(t), first)
        cnt = cnt + fired.astype(jnp.int32)
        # --- energy side channel: adds executed = input spikes × enabled ---
        n_spk = jnp.sum(spk.astype(jnp.int32), axis=-1)      # (bB,)
        n_en = jnp.sum(en.astype(jnp.int32), axis=-1)        # this j tile
        adds_t = n_spk * n_en
        adds_ref[t, :] = jnp.where(j == 0, adds_t, adds_ref[t, :] + adds_t)
        if active_pruning:
            en = jnp.logical_and(en, jnp.logical_not(fired))
        return (s, v_new, en, cnt, first)

    s_f, v_f, _, cnt_f, first_f = jax.lax.fori_loop(
        0, num_steps, body, (s0, v0, valid, cnt0, first0))
    cnt_ref[...] = cnt_f
    first_ref[...] = first_f
    vfin_ref[...] = v_f
    st_out_ref[...] = s_f


def fused_snn_forward_pallas(pixels_u8: jax.Array, state_u32: jax.Array,
                             w_q: jax.Array, *, num_steps: int,
                             decay_shift: int, v_threshold: int,
                             v_rest: int = 0, v_min: int = -(1 << 20),
                             v_max: int = (1 << 20) - 1,
                             active_pruning: bool = False,
                             n_out_true: int | None = None,
                             block=DEFAULT_BLOCK, interpret: bool = False):
    """pixels/state: (B, N_in); w_q: (N_in, N_out) int16/int8.

    Returns (counts i32 (B,N_out), v_trace i32 (T,B,N_out),
             first_spike_t i32 (B,N_out), v_final i32 (B,N_out),
             active_adds i32 (T,B), state u32 (B,N_in)).
    """
    B, n_in = pixels_u8.shape
    n_out = w_q.shape[1]
    if n_out_true is None:
        n_out_true = n_out
    bB, bN = block
    grid = (pl.cdiv(B, bB), pl.cdiv(n_out, bN))

    kernel = functools.partial(
        _fused_kernel, num_steps=num_steps, decay_shift=decay_shift,
        v_threshold=v_threshold, v_rest=v_rest, v_min=v_min, v_max=v_max,
        active_pruning=active_pruning, n_out_true=n_out_true)

    cnt, vtr, first, vfin, adds, st_out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bB, n_in), lambda i, j: (i, 0)),
            pl.BlockSpec((bB, n_in), lambda i, j: (i, 0)),
            pl.BlockSpec((n_in, bN), lambda i, j: (0, j)),
        ],
        out_specs=[
            pl.BlockSpec((bB, bN), lambda i, j: (i, j)),
            pl.BlockSpec((num_steps, bB, bN), lambda i, j: (0, i, j)),
            pl.BlockSpec((bB, bN), lambda i, j: (i, j)),
            pl.BlockSpec((bB, bN), lambda i, j: (i, j)),
            # revisited across j (innermost) — accumulates the add counter
            pl.BlockSpec((num_steps, bB), lambda i, j: (0, i)),
            pl.BlockSpec((bB, n_in), lambda i, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, n_out), jnp.int32),
            jax.ShapeDtypeStruct((num_steps, B, n_out), jnp.int32),
            jax.ShapeDtypeStruct((B, n_out), jnp.int32),
            jax.ShapeDtypeStruct((B, n_out), jnp.int32),
            jax.ShapeDtypeStruct((num_steps, B), jnp.int32),
            jax.ShapeDtypeStruct((B, n_in), jnp.uint32),
        ],
        interpret=interpret,
    )(pixels_u8, state_u32, w_q)
    return cnt, vtr, first, vfin, adds, st_out
