"""Pallas TPU kernel: fused xorshift32 + Poisson spike generation.

RTL block (paper Fig. 2): per-pixel PRNG lane → 8-bit comparator → spike.
TPU mapping: pixels and PRNG states live in VMEM tiles; the whole T-step
window is generated in one kernel launch so the PRNG state never round-trips
to HBM — the analogue of the RTL's free-running LFSR bank.  All ops are VPU
bitwise/compare ops; there is no MXU work, so the kernel is purely
memory-bound on the spike output: bytes_out = T·B·N, which is exactly the
event-stream bandwidth of the hardware encoder.

Block layout: grid over (B/bB, N/bN); each instance holds a (bB, bN) uint32
state tile in VMEM and emits a (T, bB, bN) uint8 spike tile.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["poisson_encode_pallas"]

# TPU-native tile: 8 sublanes × 128 lanes; uint8 spikes pack (32, 128) tiles
# but (8,128) keeps the index math simple and still vector-aligned.
DEFAULT_BLOCK = (8, 128)


def _encode_kernel(pixels_ref, state_ref, spikes_ref, state_out_ref, *,
                   num_steps: int):
    """One (bB, bN) tile: run T xorshift steps, emit spikes per step."""
    px = pixels_ref[...]              # (bB, bN) uint8
    s0 = state_ref[...]               # (bB, bN) uint32

    def body(t, s):
        # xorshift32: x ^= x<<13; x ^= x>>17; x ^= x<<5  (mod 2^32)
        s = s ^ (s << 13)
        s = s ^ (s >> 17)
        s = s ^ (s << 5)
        r = (s >> 24).astype(jnp.uint8)          # comparator draws top byte
        spikes_ref[t, :, :] = (px > r).astype(jnp.uint8)
        return s

    s_final = jax.lax.fori_loop(0, num_steps, body, s0)
    state_out_ref[...] = s_final


def poisson_encode_pallas(pixels_u8: jax.Array, state_u32: jax.Array,
                          num_steps: int, *, block=DEFAULT_BLOCK,
                          interpret: bool = False):
    """pixels/state: (B, N). Returns (spikes u8 (T, B, N), state u32 (B, N))."""
    B, N = pixels_u8.shape
    bB, bN = block
    grid = (pl.cdiv(B, bB), pl.cdiv(N, bN))

    kernel = functools.partial(_encode_kernel, num_steps=num_steps)
    spikes, state_out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bB, bN), lambda i, j: (i, j)),
            pl.BlockSpec((bB, bN), lambda i, j: (i, j)),
        ],
        out_specs=[
            pl.BlockSpec((num_steps, bB, bN), lambda i, j: (0, i, j)),
            pl.BlockSpec((bB, bN), lambda i, j: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((num_steps, B, N), jnp.uint8),
            jax.ShapeDtypeStruct((B, N), jnp.uint32),
        ],
        interpret=interpret,
    )(pixels_u8, state_u32)
    return spikes, state_out
