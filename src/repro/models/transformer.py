"""Unified LM: one model covering all assigned families via ArchConfig.

Families: dense (qwen3/llama3/gemma2/nemotron), moe (dbrx/arctic), ssm
(mamba2), hybrid (jamba), enc-dec audio (whisper, stub frontend), vlm
(llava, stub frontend).

Layer stacking: layers are grouped into *blocks* — the smallest repeating
pattern of layer kinds (gemma2: [local, global]; jamba: 8-layer mamba/attn/
moe pattern; homogeneous archs: 1) — and the block sequence runs under
``lax.scan`` with parameters stacked on a leading ``n_blocks`` axis.  One
HLO layer body regardless of depth ⇒ compile time and HLO size are O(block),
and remat applies per block.

Modes: "train" (no cache), "prefill" (returns cache), "decode" (one token,
consumes/returns cache).  Caches are pytrees stacked over blocks, matching
the scan layout.
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from ..distributed.sharding import shard
from . import attention as attn_mod
from . import ffn as ffn_mod
from . import mamba as mamba_mod
from .layers import embed_init, dense_init, layernorm, rmsnorm, softcap

__all__ = ["LayerSpec", "layer_plan", "block_size", "lm_init", "lm_apply",
           "init_cache", "Transformer"]


@dataclass(frozen=True)
class LayerSpec:
    kind: str                  # "attn" | "mamba"
    window: int | None = None  # sliding window (gemma2 local layers)
    ffn: str | None = "dense"  # "dense" | "moe" | None
    cross: bool = False        # decoder cross-attention (whisper)


def layer_plan(cfg) -> list[LayerSpec]:
    plan = []
    for i in range(cfg.num_layers):
        if cfg.family == "ssm":
            kind = "mamba"
        elif cfg.attn_layer_period:
            kind = ("attn" if i % cfg.attn_layer_period == cfg.attn_layer_offset
                    else "mamba")
        else:
            kind = "attn"
        window = None
        if cfg.local_global_period and kind == "attn":
            if i % cfg.local_global_period != cfg.local_global_period - 1:
                window = cfg.sliding_window
        ffn = None if cfg.family == "ssm" else "dense"
        if cfg.moe_num_experts and (i % cfg.moe_period == cfg.moe_period - 1):
            ffn = "moe"
        plan.append(LayerSpec(kind=kind, window=window, ffn=ffn,
                              cross=cfg.is_encdec))
    return plan


def block_size(plan: list[LayerSpec]) -> int:
    n = len(plan)
    for p in range(1, n + 1):
        if n % p == 0 and all(plan[i] == plan[i % p] for i in range(n)):
            return p
    return n


def _norm_params(cfg):
    if cfg.norm_type == "layernorm":
        return {"scale": jnp.ones((cfg.d_model,), jnp.float32),
                "bias": jnp.zeros((cfg.d_model,), jnp.float32)}
    return {"scale": jnp.zeros((cfg.d_model,), jnp.float32)}


def _norm_apply(p: dict, x: jax.Array) -> jax.Array:
    if "bias" in p:
        return layernorm(x, p["scale"], p["bias"])
    return rmsnorm(x, p["scale"])


def _layer_params(key: jax.Array, cfg, spec: LayerSpec) -> dict:
    ks = iter(jax.random.split(key, 8))
    p: dict = {"ln1": _norm_params(cfg)}
    if spec.kind == "attn":
        p["attn"] = attn_mod.attention_params(next(ks), cfg)
    else:
        p["mamba"] = mamba_mod.mamba_params(next(ks), cfg)
    if cfg.sandwich_norm:
        p["ln1_post"] = _norm_params(cfg)
    if spec.cross:
        p["ln_cross"] = _norm_params(cfg)
        p["cross"] = attn_mod.attention_params(next(ks), cfg, cross=True)
    if spec.ffn is not None:
        p["ln2"] = _norm_params(cfg)
        if spec.ffn == "moe":
            p["moe"] = ffn_mod.moe_params(next(ks), cfg)
            if cfg.moe_dense_residual:
                p["mlp"] = ffn_mod.ffn_params(next(ks), cfg,
                                              d_ff=cfg.dense_residual_ff)
        else:
            p["mlp"] = ffn_mod.ffn_params(next(ks), cfg)
        if cfg.sandwich_norm:
            p["ln2_post"] = _norm_params(cfg)
    return p


def _layer_cache(batch: int, max_len: int, cfg, spec: LayerSpec,
                 dtype=jnp.bfloat16) -> dict:
    c: dict = {}
    kvp = (cfg.padded_num_heads if cfg.num_kv_heads == cfg.num_heads
           else cfg.num_kv_heads)
    if spec.kind == "attn":
        c["self"] = attn_mod.init_attn_cache(batch, max_len, kvp,
                                             cfg.head_dim, dtype)
    else:
        c["self"] = mamba_mod.init_mamba_cache(batch, cfg, dtype)
    if spec.cross:
        c["cross"] = attn_mod.init_attn_cache(batch, cfg.encoder_seq, kvp,
                                              cfg.head_dim, dtype)
    return c


def _apply_layer(p: dict, spec: LayerSpec, x: jax.Array, *, cfg, mode: str,
                 positions: jax.Array, cache: dict | None,
                 cur_len: jax.Array | None, enc_out: jax.Array | None):
    aux = {"lb_loss": jnp.zeros((), jnp.float32),
           "router_z": jnp.zeros((), jnp.float32)}
    new_cache: dict = {}

    h = _norm_apply(p["ln1"], x)
    # Megatron-SP boundary: the norm ran on the sequence-sharded residual;
    # gather the full sequence HERE, on the bf16 activation, so the
    # all-gather is explicit and half-precision (GSPMD otherwise picks the
    # fp32 point inside the mixer).
    h = shard(h, "batch", None, "embed")
    if spec.kind == "attn":
        a, c_new = attn_mod.attention(
            p["attn"], h, cfg=cfg, mode=mode, positions=positions,
            cache=cache.get("self") if cache else None, cur_len=cur_len,
            layer_window=spec.window,
            rope_enabled=cfg.max_position == 0)
        if c_new is not None:
            new_cache["self"] = c_new
    else:
        if mode == "decode":
            a, c_new = mamba_mod.mamba_decode_step(
                p["mamba"], h, cfg, cache["self"])
        else:
            a, c_new = mamba_mod.mamba_apply(
                p["mamba"], h, cfg,
                cache=cache.get("self") if cache else None,
                want_cache=(mode == "prefill"))
        if c_new is not None:
            new_cache["self"] = c_new
    if "ln1_post" in p:
        a = _norm_apply(p["ln1_post"], a)
    a = shard(a, "batch", "seq_act", "embed")   # SP re-scatter (RS in bwd)
    x = x + a

    if spec.cross:
        h = _norm_apply(p["ln_cross"], x)
        a, cc_new = attn_mod.attention(
            p["cross"], h, cfg=cfg, mode=mode, positions=positions,
            cache=cache.get("cross") if cache else None, cur_len=cur_len,
            kv_source=enc_out, is_cross=True, rope_enabled=False)
        if cc_new is not None:
            new_cache["cross"] = cc_new
        x = x + a

    if spec.ffn is not None:
        h = _norm_apply(p["ln2"], x)
        h = shard(h, "batch", None, "embed")    # SP gather before FFN
        if spec.ffn == "moe":
            f, moe_aux = ffn_mod.moe_apply(p["moe"], h, cfg,
                                           group_size=cfg.moe_group)
            aux = {k: aux[k] + moe_aux[k] for k in aux}
            if "mlp" in p:                       # arctic dense residual
                f = f + ffn_mod.ffn_apply(p["mlp"], h, cfg)
        else:
            f = ffn_mod.ffn_apply(p["mlp"], h, cfg)
        if "ln2_post" in p:
            f = _norm_apply(p["ln2_post"], f)
        f = shard(f, "batch", "seq_act", "embed")   # SP re-scatter
        x = x + f

    x = shard(x, "batch", "seq_act", "embed")
    return x, new_cache, aux


# ---------------------------------------------------------------------------
# Whisper-style encoder (bidirectional, stub frontend provides embeddings)
# ---------------------------------------------------------------------------

def _encoder_params(key: jax.Array, cfg) -> dict:
    keys = jax.random.split(key, cfg.encoder_layers + 1)
    layers = []
    for i in range(cfg.encoder_layers):
        ks = jax.random.split(keys[i], 2)
        layers.append({
            "ln1": _norm_params(cfg),
            "attn": attn_mod.attention_params(ks[0], cfg),
            "ln2": _norm_params(cfg),
            "mlp": ffn_mod.ffn_params(ks[1], cfg),
        })
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *layers)
    return {"layers": stacked, "final_norm": _norm_params(cfg)}


def _encode(params: dict, frames: jax.Array, cfg) -> jax.Array:
    x = frames.astype(cfg.dtype)

    def body(x, lp):
        h = _norm_apply(lp["ln1"], x)
        a = attn_mod.encoder_attention(lp["attn"], h, cfg=cfg)
        x = x + a
        h = _norm_apply(lp["ln2"], x)
        x = x + ffn_mod.ffn_apply(lp["mlp"], h, cfg)
        return x, None

    fn = jax.checkpoint(body) if cfg.remat else body
    x, _ = jax.lax.scan(fn, x, params["layers"])
    return _norm_apply(params["final_norm"], x)


# ---------------------------------------------------------------------------
# Full model
# ---------------------------------------------------------------------------

def lm_init(key: jax.Array, cfg) -> dict:
    plan = layer_plan(cfg)
    bs = block_size(plan)
    n_blocks = len(plan) // bs
    keys = jax.random.split(key, n_blocks * bs + 4)

    blocks = []
    for b in range(n_blocks):
        block = {f"p{j}": _layer_params(keys[b * bs + j], cfg, plan[j])
                 for j in range(bs)}
        blocks.append(block)
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *blocks)

    params = {
        "embed": embed_init(keys[-1], cfg.padded_vocab, cfg.d_model),
        "blocks": stacked,
        "final_norm": _norm_params(cfg),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(keys[-2], (cfg.d_model, cfg.padded_vocab))
    if cfg.max_position:
        params["pos_embed"] = embed_init(keys[-3], cfg.max_position,
                                         cfg.d_model)
    if cfg.is_encdec:
        params["encoder"] = _encoder_params(keys[-4], cfg)
    return params


def init_cache(cfg, batch: int, max_len: int, dtype=jnp.bfloat16) -> dict:
    plan = layer_plan(cfg)
    bs = block_size(plan)
    n_blocks = len(plan) // bs
    one = {f"p{j}": _layer_cache(batch, max_len, cfg, plan[j], dtype)
           for j in range(bs)}
    return jax.tree.map(lambda x: jnp.broadcast_to(x[None], (n_blocks,) + x.shape),
                        one)


def lm_apply(params: dict, batch: dict, cfg, *, mode: str = "train",
             cache: dict | None = None, cur_len: jax.Array | None = None):
    """Forward pass.

    batch: {"tokens": (B,S) int32} (+"patches" (B,P,D) for vlm prefill/train,
    +"frames" (B,S_enc,D) for enc-dec).
    Returns (logits (B,S,Vp), new_cache | None, aux).
    """
    plan = layer_plan(cfg)
    bs = block_size(plan)
    dt = cfg.dtype

    tokens = batch["tokens"]
    B = tokens.shape[0]
    emb = shard(params["embed"], "vocab", "embed").astype(dt)
    x = jnp.take(emb, tokens, axis=0)
    if cfg.embed_scale:
        x = x * jnp.asarray(cfg.d_model ** 0.5, dt)

    if "patches" in batch and batch["patches"] is not None:
        x = jnp.concatenate([batch["patches"].astype(dt), x], axis=1)

    S = x.shape[1]
    if mode == "decode":
        assert cur_len is not None
        positions = cur_len[:, None]
    else:
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None],
                                     (B, S))
    if cfg.max_position:
        pe = params["pos_embed"].astype(dt)
        x = x + jnp.take(pe, jnp.clip(positions, 0, cfg.max_position - 1),
                         axis=0)

    enc_out = None
    if cfg.is_encdec:
        if mode == "decode":
            enc_out = None          # cross K/V live in the cache
        else:
            enc_out = _encode(params["encoder"], batch["frames"], cfg)

    x = shard(x.astype(dt), "batch", "seq_act", "embed")

    def block_body(carry, xs):
        x, lb, rz = carry
        bp, bc = xs
        new_bc = {}
        for j in range(bs):
            c_j = bc[f"p{j}"] if bc is not None else None
            x, nc, aux = _apply_layer(
                bp[f"p{j}"], plan[j], x, cfg=cfg, mode=mode,
                positions=positions, cache=c_j, cur_len=cur_len,
                enc_out=enc_out)
            if nc:
                new_bc[f"p{j}"] = nc
            lb = lb + aux["lb_loss"]
            rz = rz + aux["router_z"]
        return (x, lb, rz), (new_bc if new_bc else None)

    body = jax.checkpoint(block_body) if (cfg.remat and mode == "train") \
        else block_body
    zero = jnp.zeros((), jnp.float32)
    xs = (params["blocks"], cache)
    (x, lb, rz), new_cache = jax.lax.scan(body, (x, zero, zero), xs)

    x = _norm_apply(params["final_norm"], x)
    head = (params["embed"].T if cfg.tie_embeddings else params["lm_head"])
    logits = jnp.einsum("bsd,dv->bsv", x, head.astype(dt))
    if cfg.final_softcap:
        logits = softcap(logits.astype(jnp.float32), cfg.final_softcap)
    logits = shard(logits, "batch", None, "vocab")
    aux = {"lb_loss": lb, "router_z": rz}
    return logits, new_cache, aux


class Transformer:
    """Thin OO facade used by the launchers (init/apply/cache)."""

    def __init__(self, cfg):
        self.cfg = cfg

    def init(self, key):
        return lm_init(key, self.cfg)

    def apply(self, params, batch, **kw):
        return lm_apply(params, batch, self.cfg, **kw)

    def init_cache(self, batch, max_len, dtype=jnp.bfloat16):
        return init_cache(self.cfg, batch, max_len, dtype)
