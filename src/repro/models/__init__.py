"""Model zoo: shared layers, attention, FFN/MoE, Mamba-2 SSD, and the
unified transformer covering every assigned architecture family."""

from . import attention, ffn, layers, mamba, transformer
from .transformer import Transformer, init_cache, lm_apply, lm_init

__all__ = ["attention", "ffn", "layers", "mamba", "transformer",
           "Transformer", "init_cache", "lm_apply", "lm_init"]
