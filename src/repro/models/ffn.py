"""Feed-forward blocks: gated/ungated dense MLP and capacity-based MoE.

Dense: silu/gelu configs use the gated (w1·act ⊙ w3)·w2 form (llama/qwen/
gemma); squared-relu (nemotron) and relu use the 2-matrix form.

MoE (dbrx 16e top-4, arctic 128e top-2 + dense residual, jamba 16e top-2):
token-choice top-k routing with per-group expert capacity, realised as the
GSPMD-canonical dispatch/combine einsums (Switch/GLaM style):

    tokens are viewed as (G groups × Sg tokens), G sharded over the data
    axes, experts sharded over "model" (EP).  dispatch (G,Sg,E,C) routes
    tokens into per-expert capacity slots — the (gsec,gsd->egcd) einsum IS
    the all-to-all in GSPMD — experts run dense matmuls on their (G,C)
    slots, and combine brings results back weighted by router probs.

Group size bounds the dispatch-mask memory (k·cf·Sg² per group); overflow
tokens beyond capacity are dropped (standard; the residual stream carries
them).  Capacity is rounded up to a multiple of 4 for lane alignment.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ..distributed.sharding import shard
from .layers import activation_fn, dense_init

__all__ = ["ffn_params", "ffn_apply", "moe_params", "moe_apply", "is_gated"]


def is_gated(activation: str) -> bool:
    return activation in ("silu", "gelu")


def ffn_params(key: jax.Array, cfg, d_ff: int | None = None) -> dict:
    d, f = cfg.d_model, d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    p = {"w1": dense_init(ks[0], (d, f)), "w2": dense_init(ks[1], (f, d))}
    if is_gated(cfg.activation):
        p["w3"] = dense_init(ks[2], (d, f))
    return p


def ffn_apply(params: dict, x: jax.Array, cfg) -> jax.Array:
    dt = x.dtype
    act = activation_fn(cfg.activation)
    h = act(x @ params["w1"].astype(dt))
    if "w3" in params:
        h = h * (x @ params["w3"].astype(dt))
    h = shard(h, "batch", None, "mlp")
    return h @ params["w2"].astype(dt)


# ---------------------------------------------------------------------------
# Mixture of Experts
# ---------------------------------------------------------------------------

def moe_params(key: jax.Array, cfg) -> dict:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.moe_num_experts
    ks = jax.random.split(key, 4)
    p = {
        "router": dense_init(ks[0], (d, e)),
        "w1": dense_init(ks[1], (e, d, f), in_axis=1),
        "w2": dense_init(ks[2], (e, f, d), in_axis=1),
    }
    if is_gated(cfg.activation):
        p["w3"] = dense_init(ks[3], (e, d, f), in_axis=1)
    return p


def _capacity(sg: int, top_k: int, num_experts: int, factor: float) -> int:
    c = int(sg * top_k * factor / num_experts) + 1
    return max(4, (c + 3) // 4 * 4)


def moe_apply(params: dict, x: jax.Array, cfg, *, group_size: int = 1024):
    """x: (B, S, D) -> (B, S, D), plus aux losses dict.

    Returns (y, aux) where aux = {"lb_loss": load-balance loss (Switch),
    "router_z": router z-loss} — added to the training objective.
    """
    dt = x.dtype
    b, s, d = x.shape
    e, k = cfg.moe_num_experts, cfg.moe_top_k
    tokens = b * s
    sg = min(group_size, s)
    assert tokens % sg == 0, (tokens, sg)
    g = tokens // sg
    c = _capacity(sg, k, e, cfg.moe_capacity_factor)

    xg = x.reshape(g, sg, d)
    xg = shard(xg, "batch", None, None)

    logits = (xg.astype(jnp.float32) @ params["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)                     # (G,Sg,E)

    # top-k choice per token
    top_p, top_e = jax.lax.top_k(probs, k)                      # (G,Sg,k)
    top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)      # renormalise

    # position of each (token, choice) in its expert's capacity buffer:
    # rank among all choices of the same expert within the group, in
    # (token-major, choice-minor) priority order.
    choice_eh = jax.nn.one_hot(top_e, e, dtype=jnp.int32)       # (G,Sg,k,E)
    flat = choice_eh.reshape(g, sg * k, e)
    pos_in_expert = jnp.cumsum(flat, axis=1) - flat             # (G,Sg*k,E)
    pos = jnp.sum(flat * pos_in_expert, axis=-1).reshape(g, sg, k)
    keep = pos < c                                              # capacity drop

    # dispatch/combine tensors (G,Sg,E,C)
    pos_oh = jax.nn.one_hot(pos, c, dtype=dt)                   # (G,Sg,k,C)
    disp_k = choice_eh.astype(dt)[..., None] * pos_oh[..., None, :] \
        * keep[..., None, None].astype(dt)                      # (G,Sg,k,E,C)
    dispatch = jnp.sum(disp_k, axis=2)                          # (G,Sg,E,C)
    combine = jnp.sum(disp_k * top_p[..., None, None].astype(dt), axis=2)

    dispatch = shard(dispatch, "batch", None, "experts", None)
    combine = shard(combine, "batch", None, "experts", None)

    # the dispatch einsum == all-to-all under (G→data, E→model) sharding
    ein = jnp.einsum("gsec,gsd->egcd", dispatch, xg)            # (E,G,C,D)
    ein = shard(ein, "experts", "batch", None, None)

    act = activation_fn(cfg.activation)
    h = act(jnp.einsum("egcd,edf->egcf", ein, params["w1"].astype(dt)))
    if "w3" in params:
        h = h * jnp.einsum("egcd,edf->egcf", ein, params["w3"].astype(dt))
    h = shard(h, "experts", "batch", None, None)   # E already owns "model"
    out_e = jnp.einsum("egcf,efd->egcd", h, params["w2"].astype(dt))
    out_e = shard(out_e, "experts", "batch", None, None)

    y = jnp.einsum("gsec,egcd->gsd", combine, out_e)            # back to tokens
    y = y.reshape(b, s, d)

    # Switch-style load-balance loss + router z-loss
    me = jnp.mean(probs, axis=(0, 1))                           # (E,)
    ce = jnp.mean(jnp.sum(jax.nn.one_hot(top_e[..., 0], e), axis=-2)
                  / sg, axis=0)                                 # fraction routed
    lb = e * jnp.sum(me * ce)
    zl = jnp.mean(jax.scipy.special.logsumexp(logits, axis=-1) ** 2)
    return y, {"lb_loss": lb, "router_z": zl}
