"""Mamba-2 (SSD, state-space duality) mixer — mamba2-1.3b and jamba layers.

Chunked SSD forward for train/prefill (quadratic within a chunk, linear
recurrence across chunks) and an O(1)-state decode step.  The cross-chunk
recurrence is the same leaky-integrator scan as the paper's LIF neuron
(DESIGN.md §6): state ← decay·state + input-drive, here with input-dependent
decay, run under ``lax.scan`` with the state resident — the identical
blocking strategy the LIF Pallas kernel uses.

Projections are separate matrices per component (z, x, B, C, dt) instead of
one fused in_proj: mathematically identical, and it keeps every matmul
output sharded on a single clean logical axis (inner dims → "model" TP;
B/C at N≈128 are replicated).

Shapes: d_inner = expand·d_model, H = d_inner/head_dim heads, N = ssm_state.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from ..distributed.sharding import shard
from .layers import dense_init, rmsnorm

__all__ = ["mamba_params", "mamba_apply", "mamba_decode_step", "MambaCache",
           "init_mamba_cache", "ssd_chunked"]


class MambaCache(NamedTuple):
    ssm: jax.Array        # (B, H, P, N) state
    conv_x: jax.Array     # (B, W-1, d_inner) conv tail for x
    conv_b: jax.Array     # (B, W-1, N)
    conv_c: jax.Array     # (B, W-1, N)


def init_mamba_cache(batch: int, cfg, dtype=jnp.float32) -> MambaCache:
    h, p, n = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    w = cfg.ssm_conv
    return MambaCache(
        ssm=jnp.zeros((batch, h, p, n), jnp.float32),
        conv_x=jnp.zeros((batch, w - 1, cfg.d_inner), dtype),
        conv_b=jnp.zeros((batch, w - 1, n), dtype),
        conv_c=jnp.zeros((batch, w - 1, n), dtype),
    )


def mamba_params(key: jax.Array, cfg) -> dict:
    d, di, n, h, w = (cfg.d_model, cfg.d_inner, cfg.ssm_state,
                      cfg.ssm_heads, cfg.ssm_conv)
    ks = jax.random.split(key, 10)
    return {
        "wz": dense_init(ks[0], (d, di)),
        "wx": dense_init(ks[1], (d, di)),
        "wb": dense_init(ks[2], (d, n)),
        "wc": dense_init(ks[3], (d, n)),
        "wdt": dense_init(ks[4], (d, h)),
        "conv_x": dense_init(ks[5], (w, di), in_axis=0),
        "conv_b": dense_init(ks[6], (w, n), in_axis=0),
        "conv_c": dense_init(ks[7], (w, n), in_axis=0),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, h, dtype=jnp.float32)),
        "D": jnp.ones((h,), jnp.float32),
        "dt_bias": jnp.log(jnp.expm1(
            jnp.logspace(-3, -0.7, h, dtype=jnp.float32))),  # dt in [1e-3,0.2]
        "norm": jnp.zeros((di,), jnp.float32),
        "out": dense_init(ks[8], (di, d)),
    }


def _causal_conv(x: jax.Array, w: jax.Array, tail: jax.Array | None = None):
    """Depthwise causal conv as a sum of shifts (window is tiny: 4).

    x: (B, S, C); w: (W, C); tail: (B, W-1, C) state from the previous
    segment (zeros for a fresh sequence).  Returns (y (B,S,C), new_tail).
    """
    bw = w.shape[0]
    if tail is None:
        tail = jnp.zeros((x.shape[0], bw - 1, x.shape[2]), x.dtype)
    ext = jnp.concatenate([tail, x], axis=1)          # (B, S+W-1, C)
    s = x.shape[1]
    y = sum(ext[:, i:i + s, :] * w[i][None, None, :] for i in range(bw))
    return jax.nn.silu(y), ext[:, -(bw - 1):, :] if bw > 1 else tail


def _segsum(a: jax.Array) -> jax.Array:
    """Stable 'segment sum': out[..., i, j] = sum a[..., j+1..i], -inf for j>i.

    a: (..., L). Returns (..., L, L) lower-triangular log-decay matrix.
    """
    L = a.shape[-1]
    cs = jnp.cumsum(a, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]        # sum over (j, i]
    mask = jnp.tril(jnp.ones((L, L), bool), k=0)
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(x: jax.Array, a: jax.Array, b: jax.Array, c: jax.Array,
                chunk: int, h0: jax.Array | None = None):
    """SSD: y[t] = Σ_{s≤t} c[t]ᵀ (Π_{r∈(s,t]} exp(a[r])) b[s] x[s]  per head.

    x: (B,S,H,P) — inputs already scaled by dt;
    a: (B,S,H)   — log-decay per step (dt·A, negative);
    b, c: (B,S,N) — input/output mixing (shared across heads, ngroups=1);
    h0: optional (B,H,P,N) initial state.
    Returns (y (B,S,H,P), h_final (B,H,P,N)).
    """
    B, S, H, P = x.shape
    N = b.shape[-1]
    S_in = S
    pad = (-S) % chunk
    if pad:
        # decay-neutral padding: a=0 (no decay), x=b=c=0 (no drive/readout)
        # keeps h_final exact for the unpadded prefix.
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        a = jnp.pad(a, ((0, 0), (0, pad), (0, 0)))
        b = jnp.pad(b, ((0, 0), (0, pad), (0, 0)))
        c = jnp.pad(c, ((0, 0), (0, pad), (0, 0)))
        S = S + pad
    nc = S // chunk

    xc = x.reshape(B, nc, chunk, H, P)
    ac = a.reshape(B, nc, chunk, H).transpose(0, 1, 3, 2)     # (B,nc,H,L)
    bc = b.reshape(B, nc, chunk, N)
    cc = c.reshape(B, nc, chunk, N)

    # within-chunk (diagonal block) term
    Lmat = jnp.exp(_segsum(ac))                               # (B,nc,H,L,L)
    y_diag = jnp.einsum("bzln,bzsn,bzhls,bzshp->bzlhp",
                        cc, bc, Lmat, xc)

    # per-chunk end-states and decays
    a_cum = jnp.cumsum(ac, axis=-1)                           # (B,nc,H,L)
    a_tot = a_cum[..., -1]                                    # (B,nc,H)
    decay_states = jnp.exp(a_tot[..., None] - a_cum)          # (B,nc,H,L)
    states = jnp.einsum("bzln,bzhl,bzlhp->bzhpn",
                        bc, decay_states, xc)                 # (B,nc,H,P,N)

    # cross-chunk leaky-integrator recurrence (the LIF-shaped scan)
    def step(h, inp):
        st, at = inp                                          # (B,H,P,N),(B,H)
        h_new = h * jnp.exp(at)[..., None, None] + st
        return h_new, h                                        # emit state *before* chunk

    h_init = (jnp.zeros((B, H, P, N), x.dtype) if h0 is None
              else h0.astype(x.dtype))
    states_t = states.transpose(1, 0, 2, 3, 4)                # (nc,B,H,P,N)
    atot_t = a_tot.transpose(1, 0, 2)                         # (nc,B,H)
    h_final, h_prev = jax.lax.scan(step, h_init, (states_t, atot_t))
    h_prev = h_prev.transpose(1, 0, 2, 3, 4)                  # (B,nc,H,P,N)

    # contribution of carried-in state to each chunk
    y_off = jnp.einsum("bzln,bzhpn,bzhl->bzlhp",
                       cc, h_prev, jnp.exp(a_cum))
    y = (y_diag + y_off).reshape(B, S, H, P)
    return y[:, :S_in], h_final


def _project(params, u, dt):
    z = u @ params["wz"].astype(dt)
    x = u @ params["wx"].astype(dt)
    b = u @ params["wb"].astype(dt)
    c = u @ params["wc"].astype(dt)
    delta = u @ params["wdt"].astype(dt)
    return z, x, b, c, delta


def mamba_apply(params: dict, u: jax.Array, cfg, *,
                cache: MambaCache | None = None, want_cache: bool = False):
    """Full-sequence mixer (train / prefill). u: (B, S, D) normed input.

    Returns (y (B,S,D), new_cache | None).
    """
    dt = u.dtype
    B, S, _ = u.shape
    H, P, N = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state

    z, x, b, c, delta = _project(params, u, dt)
    x = shard(x, "batch", None, "mlp")
    x, tail_x = _causal_conv(x, params["conv_x"].astype(dt),
                             cache.conv_x if cache else None)
    b, tail_b = _causal_conv(b, params["conv_b"].astype(dt),
                             cache.conv_b if cache else None)
    c, tail_c = _causal_conv(c, params["conv_c"].astype(dt),
                             cache.conv_c if cache else None)

    delta = jax.nn.softplus(delta.astype(jnp.float32)
                            + params["dt_bias"][None, None, :])
    a = -jnp.exp(params["A_log"])[None, None, :]              # (1,1,H)
    a_log_step = (delta * a)                                  # (B,S,H) fp32

    xh_raw = x.reshape(B, S, H, P).astype(jnp.float32)
    xh = shard(xh_raw * delta[..., None], "batch", None, "heads", None)
    y, h_final = ssd_chunked(xh, a_log_step,
                             b.astype(jnp.float32), c.astype(jnp.float32),
                             cfg.ssm_chunk,
                             cache.ssm if cache else None)
    y = y + params["D"][None, None, :, None] * xh_raw   # skip connection
    y = y.reshape(B, S, cfg.d_inner).astype(dt)
    y = rmsnorm(y * jax.nn.silu(z), params["norm"])
    out = y @ params["out"].astype(dt)

    new_cache = None
    if want_cache:
        new_cache = MambaCache(ssm=h_final.astype(jnp.float32),
                               conv_x=tail_x, conv_b=tail_b, conv_c=tail_c)
    return out, new_cache


def mamba_decode_step(params: dict, u: jax.Array, cfg, cache: MambaCache):
    """One-token decode. u: (B, 1, D). Returns (y (B,1,D), new_cache)."""
    dt = u.dtype
    B = u.shape[0]
    H, P, N = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    w = cfg.ssm_conv

    z, x, b, c, delta = _project(params, u, dt)

    def conv_step(xt, tail, wconv):
        ext = jnp.concatenate([tail, xt], axis=1)             # (B, W, C)
        y = jnp.einsum("bwc,wc->bc", ext, wconv.astype(dt))
        return jax.nn.silu(y)[:, None, :], ext[:, 1:, :]

    x, tail_x = conv_step(x, cache.conv_x, params["conv_x"])
    b, tail_b = conv_step(b, cache.conv_b, params["conv_b"])
    c, tail_c = conv_step(c, cache.conv_c, params["conv_c"])

    delta = jax.nn.softplus(delta[:, 0].astype(jnp.float32)
                            + params["dt_bias"][None, :])      # (B,H)
    a = -jnp.exp(params["A_log"])[None, :]                     # (1,H)
    da = jnp.exp(delta * a)                                    # (B,H)

    xh = x[:, 0].reshape(B, H, P).astype(jnp.float32)          # (B,H,P)
    bf = b[:, 0].astype(jnp.float32)                           # (B,N)
    cf = c[:, 0].astype(jnp.float32)
    drive = jnp.einsum("bhp,bn->bhpn", xh * delta[..., None], bf)
    h_new = cache.ssm * da[..., None, None] + drive
    y = jnp.einsum("bhpn,bn->bhp", h_new, cf) + params["D"][None, :, None] * xh
    y = y.reshape(B, 1, cfg.d_inner).astype(dt)
    y = rmsnorm(y * jax.nn.silu(z), params["norm"])
    out = y @ params["out"].astype(dt)
    return out, MambaCache(ssm=h_new, conv_x=tail_x, conv_b=tail_b,
                           conv_c=tail_c)
