"""Shared model primitives: norms, rotary, activations, initializers.

All functions are pure; parameters are plain dict pytrees.  Compute dtype is
controlled by the caller (configs default to bf16 compute / fp32 params).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


__all__ = [
    "rmsnorm", "layernorm", "rope", "apply_rope", "activation_fn",
    "dense_init", "embed_init", "softcap",
]


def rmsnorm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    out = x32 * jax.lax.rsqrt(var + eps)
    # gemma-style (1+scale); configs store scale-1 so zero-init is identity
    return (out * (1.0 + scale.astype(jnp.float32))).astype(dt)


def layernorm(x: jax.Array, scale: jax.Array, bias: jax.Array,
              eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    out = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return (out * scale + bias).astype(dt)


def softcap(x: jax.Array, cap: float) -> jax.Array:
    """Gemma-2 logit soft-capping: cap·tanh(x/cap)."""
    return cap * jnp.tanh(x / cap)


def rope(positions: jax.Array, head_dim: int, theta: float) -> tuple[jax.Array, jax.Array]:
    """Rotary tables for given positions: returns (sin, cos) of shape (..., hd/2)."""
    half = head_dim // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freqs  # (..., half)
    return jnp.sin(ang), jnp.cos(ang)


def apply_rope(x: jax.Array, sin: jax.Array, cos: jax.Array) -> jax.Array:
    """x: (..., heads, head_dim); sin/cos: broadcastable (..., 1, hd/2)."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def activation_fn(name: str):
    if name == "silu":
        return jax.nn.silu
    if name == "gelu":
        return jax.nn.gelu
    if name == "squared_relu":   # nemotron-4
        return lambda x: jnp.square(jax.nn.relu(x))
    if name == "relu":
        return jax.nn.relu
    raise ValueError(f"unknown activation {name}")


def dense_init(key: jax.Array, shape: tuple[int, ...], in_axis: int = 0,
               dtype=jnp.float32) -> jax.Array:
    """Truncated-normal fan-in init (MaxText-style scale)."""
    fan_in = shape[in_axis]
    std = (1.0 / fan_in) ** 0.5
    return (std * jax.random.truncated_normal(key, -2, 2, shape)).astype(dtype)


def embed_init(key: jax.Array, vocab: int, dim: int, dtype=jnp.float32) -> jax.Array:
    return (jax.random.normal(key, (vocab, dim)) * 0.02).astype(dtype)
