"""Grouped-query attention with the features the assigned archs need.

Covers: GQA (qwen3/llama3/gemma2/nemotron/dbrx/arctic/jamba/llava), MHA
(whisper), qk-norm (qwen3), attention-logit softcapping (gemma2), sliding-
window local layers (gemma2), RoPE, cross-attention (whisper decoder), and
three execution modes:

  * ``train``    — full causal self-attention, no cache,
  * ``prefill``  — causal self-attention that also writes the KV cache,
  * ``decode``   — one-token query against a (possibly sequence-sharded)
                   KV cache.

TPU/memory strategy: queries are processed in chunks (``q_chunk``) under
``lax.scan`` (actually lax.map), so the (Sq, Sk) score matrix never
materialises beyond (q_chunk, Sk) — the jnp-level analogue of flash
attention's tiling, sized so a chunk's scores fit VMEM-scale working sets.
Softmax statistics are exact per chunk (each chunk sees all its keys).

Sharding (logical axes; see distributed/sharding.py):
  train/prefill — q/k/v/scores sharded over "heads"→model,
  decode        — cache sharded over "kv_seq"→model (flash-decoding style);
                  GSPMD inserts the small max/sum all-reduces for the
                  sharded softmax.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from ..distributed.sharding import shard
from .layers import apply_rope, dense_init, rmsnorm, rope, softcap

__all__ = ["attention_params", "attention", "AttnCache", "init_attn_cache"]


class AttnCache(NamedTuple):
    k: jax.Array      # (B, S_max, KVp, hd)
    v: jax.Array      # (B, S_max, KVp, hd)


def init_attn_cache(batch: int, max_len: int, num_kv: int, head_dim: int,
                    dtype=jnp.bfloat16) -> AttnCache:
    z = jnp.zeros((batch, max_len, num_kv, head_dim), dtype)
    return AttnCache(k=z, v=z)


def attention_params(key: jax.Array, cfg, *, cross: bool = False) -> dict:
    """Weights for one attention block, padded for TP divisibility.

    q: (D, Hp, hd); k/v: (D, KVp, hd); o: (Hp, hd, D).
    KVp == num_kv_heads unless the layer is MHA (kv == heads), in which case
    kv pads together with q so the GQA group size stays integral.
    """
    d, hd = cfg.d_model, cfg.head_dim
    hp = cfg.padded_num_heads
    kvp = hp if cfg.num_kv_heads == cfg.num_heads else cfg.num_kv_heads
    ks = jax.random.split(key, 6)
    p = {
        "wq": dense_init(ks[0], (d, hp, hd)),
        "wk": dense_init(ks[1], (d, kvp, hd)),
        "wv": dense_init(ks[2], (d, kvp, hd)),
        "wo": dense_init(ks[3], (hp, hd, d), in_axis=0),
    }
    if cfg.qk_norm and not cross:
        p["q_norm"] = jnp.zeros((hd,), jnp.float32)
        p["k_norm"] = jnp.zeros((hd,), jnp.float32)
    return p


def _repeat_kv(x: jax.Array, n_rep: int) -> jax.Array:
    """(B, S, KV, hd) -> (B, S, KV*n_rep, hd) by repeating each kv head."""
    if n_rep == 1:
        return x
    b, s, kv, hd = x.shape
    return jnp.broadcast_to(x[:, :, :, None, :], (b, s, kv, n_rep, hd)) \
              .reshape(b, s, kv * n_rep, hd)


def _chunked_scores_attend(q, k, v, *, q_positions, causal: bool,
                           window: int | None, cap: float | None,
                           kv_valid_len, q_chunk: int):
    """Tiled softmax(QKᵀ)V.  q: (B,Sq,H,hd), k/v: (B,Sk,H,hd).

    q_positions: (B, Sq) absolute positions of the queries (for causal and
    sliding-window masks against key positions 0..Sk-1).
    kv_valid_len: None or (B,) — keys at index >= valid_len are masked
    (decode with a pre-allocated cache).
    """
    b, sq, h, hd = q.shape
    sk = k.shape[1]
    scale = hd ** -0.5
    kpos = jnp.arange(sk, dtype=jnp.int32)

    def one_chunk(args):
        qc, qpos = args                       # (B, cq, H, hd), (B, cq)
        s = jnp.einsum("bqhd,bshd->bhqs", qc.astype(jnp.bfloat16),
                       k.astype(jnp.bfloat16),
                       preferred_element_type=jnp.float32) * scale
        if cap is not None:
            s = softcap(s, cap)
        mask = jnp.ones((b, 1, qc.shape[1], sk), bool)
        if causal:
            mask &= kpos[None, None, None, :] <= qpos[:, None, :, None]
        if window is not None:
            mask &= kpos[None, None, None, :] > (qpos[:, None, :, None] - window)
        if kv_valid_len is not None:
            mask &= kpos[None, None, None, :] < kv_valid_len[:, None, None, None]
        s = jnp.where(mask, s, -1e30)
        p = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bhqs,bshd->bqhd", p.astype(jnp.bfloat16),
                       v.astype(jnp.bfloat16),
                       preferred_element_type=jnp.float32)
        return o.astype(q.dtype)

    if sq <= q_chunk:
        return one_chunk((q, q_positions))

    while sq % q_chunk:          # largest divisor ≤ requested chunk
        q_chunk -= 1
    nc = sq // q_chunk
    qs = q.reshape(b, nc, q_chunk, h, hd).transpose(1, 0, 2, 3, 4)
    ps = q_positions.reshape(b, nc, q_chunk).transpose(1, 0, 2)
    out = jax.lax.map(one_chunk, (qs, ps))     # (nc, B, cq, H, hd)
    return out.transpose(1, 0, 2, 3, 4).reshape(b, sq, h, hd)


def _gqa_decode_attend(q, k, v, *, n_rep: int, q_positions,
                       window: int | None, cap: float | None,
                       kv_valid_len, causal: bool = True):
    """One-token attention against a sequence-sharded cache, WITHOUT
    materialising repeated KV heads.

    q: (B, 1, H, hd) with H = KV·n_rep; k/v: (B, S, KV, hd) sharded on S
    ("kv_seq"→model).  q is reshaped into (KV, group) — scores stay sharded
    on S, and the softmax over the sharded axis lowers to partial
    max/sum + tiny all-reduces (flash-decoding).  This replaces a
    repeat_kv broadcast that forced GSPMD to all-gather the whole cache.
    """
    b, _, h, hd = q.shape
    sk = k.shape[1]
    kv = k.shape[2]
    qg = q.reshape(b, kv, n_rep, hd)
    scale = hd ** -0.5

    s = jnp.einsum("bkgd,bskd->bkgs", qg.astype(jnp.bfloat16),
                   k.astype(jnp.bfloat16),
                   preferred_element_type=jnp.float32) * scale
    if cap is not None:
        s = softcap(s, cap)
    kpos = jnp.arange(sk, dtype=jnp.int32)
    mask = jnp.ones((b, 1, 1, sk), bool)
    qpos = q_positions[:, 0]
    if causal:
        mask &= kpos[None, None, None, :] <= qpos[:, None, None, None]
    if window is not None:
        mask &= kpos[None, None, None, :] > (qpos[:, None, None, None] - window)
    if kv_valid_len is not None:
        mask &= kpos[None, None, None, :] < kv_valid_len[:, None, None, None]
    s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgs,bskd->bkgd", p.astype(jnp.bfloat16),
                   v.astype(jnp.bfloat16),
                   preferred_element_type=jnp.float32)
    return o.reshape(b, 1, h, hd).astype(q.dtype)


def attention(params: dict, x: jax.Array, *, cfg, mode: str,
              positions: jax.Array, cache: AttnCache | None = None,
              cur_len: jax.Array | None = None,
              layer_window: int | None = None,
              kv_source: jax.Array | None = None,
              is_cross: bool = False,
              rope_enabled: bool = True,
              q_chunk: int = 1024):
    """One attention block.

    Args:
      x: (B, Sq, D) residual-stream input (already normed).
      mode: "train" | "prefill" | "decode".
      positions: (B, Sq) absolute positions of x's tokens.
      cache/cur_len: decode-mode KV cache and (B,) valid lengths;
        prefill mode returns a fresh cache.
      layer_window: sliding window size for local layers (None = global).
      kv_source: if given, keys/values come from this sequence instead of x
        (cross-attention). Cross K/V are cached at prefill.
    Returns (out (B,Sq,D), new_cache | None).
    """
    hp = cfg.padded_num_heads
    kvp = hp if cfg.num_kv_heads == cfg.num_heads else cfg.num_kv_heads
    n_rep = hp // kvp
    dt = x.dtype
    cross = is_cross or kv_source is not None

    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"].astype(dt))
    if cross and mode == "decode":
        k_new = v_new = None           # cross K/V precomputed at prefill
    else:
        src = kv_source if cross else x
        k_new = jnp.einsum("bsd,dhk->bshk", src, params["wk"].astype(dt))
        v_new = jnp.einsum("bsd,dhk->bshk", src, params["wv"].astype(dt))

    if "q_norm" in params:
        q = rmsnorm(q, params["q_norm"])
        if k_new is not None:
            k_new = rmsnorm(k_new, params["k_norm"])

    if rope_enabled and not cross:
        sin, cos = rope(positions, cfg.head_dim, cfg.rope_theta)
        sin, cos = sin[:, :, None, :], cos[:, :, None, :]
        q = apply_rope(q, sin, cos)
        if k_new is not None:
            kpos = positions if mode != "decode" else positions
            ksin, kcos = rope(kpos, cfg.head_dim, cfg.rope_theta)
            k_new = apply_rope(k_new, ksin[:, :, None, :], kcos[:, :, None, :])

    q = shard(q, "batch", None, "heads", None)

    new_cache = None
    if mode == "decode":
        assert cache is not None and cur_len is not None
        if k_new is not None and not cross:
            # scatter this step's K/V at cur_len: a true scatter (touches
            # one slot) instead of a one-hot full-cache rewrite — the
            # decode step's HBM traffic is then the cache READ only.
            b = x.shape[0]
            bidx = jnp.arange(b, dtype=jnp.int32)
            new_cache = AttnCache(
                k=cache.k.at[bidx, cur_len].set(k_new[:, 0].astype(cache.k.dtype)),
                v=cache.v.at[bidx, cur_len].set(v_new[:, 0].astype(cache.v.dtype)))
        else:
            new_cache = cache
        k_full = shard(new_cache.k, "batch", "kv_seq", None, None)
        v_full = shard(new_cache.v, "batch", "kv_seq", None, None)
        valid = None if cross else cur_len + 1
        if cross:
            valid = cur_len * 0 + k_full.shape[1]  # whole encoder context
        out = _gqa_decode_attend(
            q, k_full.astype(dt), v_full.astype(dt), n_rep=n_rep,
            q_positions=positions, window=layer_window,
            cap=cfg.attn_softcap, kv_valid_len=valid, causal=not cross)
    else:
        k_new = shard(k_new, "batch", None, "kv", None)
        v_new = shard(v_new, "batch", None, "kv", None)
        k_att = _repeat_kv(k_new, n_rep)
        v_att = _repeat_kv(v_new, n_rep)
        out = _chunked_scores_attend(
            q, k_att, v_att, q_positions=positions,
            causal=not cross and not (cfg.is_encdec and mode == "train_encoder"),
            window=layer_window, cap=cfg.attn_softcap,
            kv_valid_len=None, q_chunk=q_chunk)
        if mode == "prefill":
            new_cache = AttnCache(k=shard(k_new, "batch", "kv_seq", None, None),
                                  v=shard(v_new, "batch", "kv_seq", None, None))

    out = shard(out, "batch", None, "heads", None)
    y = jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(dt))
    return y, new_cache


def encoder_attention(params: dict, x: jax.Array, *, cfg,
                      q_chunk: int = 1024):
    """Bidirectional self-attention (whisper encoder)."""
    hp = cfg.padded_num_heads
    kvp = hp if cfg.num_kv_heads == cfg.num_heads else cfg.num_kv_heads
    n_rep = hp // kvp
    dt = x.dtype
    b, s, _ = x.shape
    pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"].astype(dt))
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"].astype(dt))
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"].astype(dt))
    q = shard(q, "batch", None, "heads", None)
    out = _chunked_scores_attend(
        q, _repeat_kv(k, n_rep), _repeat_kv(v, n_rep), q_positions=pos,
        causal=False, window=None, cap=cfg.attn_softcap,
        kv_valid_len=None, q_chunk=q_chunk)
    return jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(dt))
