"""Training step: loss, gradient accumulation, clipping, optimizer update.

Structure (all inside one jit):

  * microbatch ``lax.scan``: the global batch is split into
    ``num_microbatches`` slices; each slice's gradient is accumulated into
    an fp32 tree sharded like the parameters.  This bounds activation
    memory (remat is per layer-block inside the model) and — because the
    accumulator is a scan carry — lets XLA's latency-hiding scheduler
    overlap microbatch k's gradient reduction with k+1's compute.
  * optional int8 error-feedback gradient compression across the "pod"
    axis (optim/compression.py) — the cross-pod-bandwidth trick; the
    intra-pod reduction stays exact.
  * global-norm clipping, then the optimizer update.

Loss: next-token cross-entropy with the padded-vocab tail masked, plus MoE
load-balance and router-z auxiliaries.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from ..models.transformer import lm_apply
from ..optim import optimizer as opt_mod
from ..optim import compression

__all__ = ["TrainSettings", "TrainState", "make_train_step", "init_state",
           "make_optimizer", "cross_entropy"]

Pytree = Any


@dataclass(frozen=True)
class TrainSettings:
    learning_rate: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    num_microbatches: int = 1
    lb_coef: float = 0.01          # MoE load-balance loss weight
    zl_coef: float = 1e-3          # router z-loss weight
    grad_compression: str = "none"  # "none" | "int8_ef" (needs "pod" axis)
    pod_axis: str = "pod"
    # Stream the optimizer update over the stacked layer-block axis: the
    # fp32 update temporaries then scale with ONE block's parameters, not
    # the whole model's — the memory knob that lets ≥100B configs fit.
    stream_optimizer: bool = True
    # Gradient-accumulator dtype. fp32 is the default; bf16 halves the
    # largest whole-model temp for ≥150B configs (MaxText-style knob) at
    # the cost of accumulation precision over the microbatch loop.
    accum_dtype: str = "float32"
    # Mixed-precision shadow: cast fp32 master params to this dtype ONCE
    # per step, before the microbatch loop — every FSDP all-gather then
    # moves bf16 instead of fp32 (halves the dominant collective term on
    # the giant train cells). None disables (grads/tests stay fp32-exact).
    cast_params: str | None = None


class TrainState(NamedTuple):
    step: jax.Array
    params: Pytree
    opt_state: Pytree
    comp_err: Pytree | None        # error-feedback residual (or None)


def make_optimizer(cfg, s: TrainSettings) -> opt_mod.Optimizer:
    sched = opt_mod.linear_warmup_cosine(s.learning_rate, s.warmup_steps,
                                         s.total_steps)
    if cfg.optimizer == "adafactor":
        return opt_mod.adafactor(sched, weight_decay=s.weight_decay)
    if cfg.optimizer == "sgd":
        return opt_mod.sgd(sched)
    return opt_mod.adamw(sched, weight_decay=s.weight_decay)


def init_state(key: jax.Array, cfg, s: TrainSettings,
               init_fn=None) -> TrainState:
    from ..models.transformer import lm_init
    params = (init_fn or (lambda k: lm_init(k, cfg)))(key)
    opt = make_optimizer(cfg, s)
    comp = (compression.init_state(params).error
            if s.grad_compression == "int8_ef" else None)
    return TrainState(step=jnp.zeros((), jnp.int32), params=params,
                      opt_state=opt.init(params), comp_err=comp)


def cross_entropy(logits: jax.Array, labels: jax.Array,
                  vocab_size: int) -> tuple[jax.Array, jax.Array]:
    """Mean next-token CE + accuracy, vocab-sharding-friendly.

    Never gathers over the (possibly "model"-sharded) vocab axis: the label
    logit is extracted with a shard-local one-hot mask + max-reduce instead
    of take_along_axis/argmax, so GSPMD lowers the whole loss to partial
    reductions + scalar-sized all-reduces.  Padded-vocab tail masked out.
    """
    vp = logits.shape[-1]
    logits = logits.astype(jnp.float32)
    vidx = jax.lax.broadcasted_iota(jnp.int32, (1, 1, vp), 2)
    if vp > vocab_size:
        logits = jnp.where(vidx >= vocab_size, -1e30, logits)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)          # (B,S)
    onehot = labels[..., None] == vidx                          # (B,S,Vp) bool
    label_logit = jnp.max(jnp.where(onehot, logits, -jnp.inf), axis=-1)
    nll = lse - label_logit
    vmax = jnp.max(logits, axis=-1)
    acc = (label_logit >= vmax).astype(jnp.float32)             # label == argmax
    return nll.mean(), acc.mean()


def make_loss_fn(cfg, s: TrainSettings, apply_fn=None):
    apply_fn = apply_fn or (lambda p, b: lm_apply(p, b, cfg, mode="train")[::2])

    def loss_fn(params, batch):
        logits, aux = apply_fn(params, batch)
        ce, acc = cross_entropy(logits, batch["labels"], cfg.vocab_size)
        loss = ce
        if cfg.moe_num_experts:
            loss = loss + s.lb_coef * aux["lb_loss"] + s.zl_coef * aux["router_z"]
        return loss, {"ce": ce, "acc": acc, **aux}

    return loss_fn


def _split_blocks(tree):
    rest = {k: v for k, v in tree.items() if k != "blocks"}
    return tree["blocks"], rest


def _is_scalar_field(x) -> bool:
    return hasattr(x, "ndim") and x.ndim == 0


def streamed_update(opt, grads, opt_state, params, grad_scale=None):
    """Optimizer update with the "blocks" subtree processed one block slice
    at a time, in place (update temporaries ∝ one block, not the model).

    A ``fori_loop`` whose carry is the params/state trees themselves —
    per-block results are written back with dynamic-update-slice, so XLA
    aliases the carry with the donated inputs (a lax.scan formulation
    would force non-aliasable ys buffers of full-model size).

    Valid because every optimizer here is leaf-wise given the step counter
    (adafactor infers factored-ness from its state shapes, so block slices
    stay consistent with the decision made at init).
    """
    fields = opt_state._asdict()
    scalar_keys = [k for k, v in fields.items() if _is_scalar_field(v)]
    tree_keys = [k for k in fields if k not in scalar_keys]

    g_b, g_r = _split_blocks(grads)
    p_b, p_r = _split_blocks(params)
    s_b = {k: _split_blocks(fields[k])[0] for k in tree_keys}
    s_r = {k: _split_blocks(fields[k])[1] for k in tree_keys}
    nb = jax.tree.leaves(p_b)[0].shape[0]

    def idx(tree, i):
        return jax.tree.map(
            lambda x: jax.lax.dynamic_index_in_dim(x, i, 0, keepdims=False),
            tree)

    def put(tree, vals, i):
        return jax.tree.map(
            lambda acc, v: jax.lax.dynamic_update_index_in_dim(
                acc, v.astype(acc.dtype), i, 0),
            tree, vals)

    def scale_g(t):
        if grad_scale is None:
            return t
        return jax.tree.map(lambda g: g * grad_scale, t)

    def body(i, carry):
        p_acc, s_acc = carry
        g_i = scale_g(idx(g_b, i))
        p_i = idx(p_acc, i)       # block i not yet updated: reads original
        state_i = type(opt_state)(
            **{k: fields[k] for k in scalar_keys},
            **{k: idx(s_acc[k], i) for k in tree_keys})
        upd, new_state = opt.update(g_i, state_i, p_i)
        new_p = opt_mod.apply_updates(p_i, upd)
        p_acc = put(p_acc, new_p, i)
        s_acc = {k: put(s_acc[k], getattr(new_state, k), i)
                 for k in tree_keys}
        return (p_acc, s_acc)

    new_p_b, new_s_b = jax.lax.fori_loop(0, nb, body, (p_b, s_b))

    # non-block leaves in one shot; this call advances the step counter
    rest_state = type(opt_state)(**{k: fields[k] for k in scalar_keys},
                                 **s_r)
    upd_r, new_rest = opt.update(scale_g(g_r), rest_state, p_r)
    new_p_r = opt_mod.apply_updates(p_r, upd_r)

    new_params = dict(new_p_r, blocks=new_p_b)
    new_fields = {k: getattr(new_rest, k) for k in scalar_keys}
    for k in tree_keys:
        new_fields[k] = dict(getattr(new_rest, k), blocks=new_s_b[k])
    return new_params, type(opt_state)(**new_fields)


def make_train_step(cfg, s: TrainSettings, *, apply_fn=None,
                    mesh_has_pod: bool = False, grad_shardings=None):
    """Returns train_step(state, batch) -> (state, metrics), jit-ready.

    ``grad_shardings``: optional pytree of shardings matching params; the
    per-microbatch gradients and the accumulator are constrained to it so
    GSPMD reduce-scatters partial grads into the ZeRO shard instead of
    all-reducing full gradients.
    """
    opt = make_optimizer(cfg, s)
    loss_fn = make_loss_fn(cfg, s, apply_fn)
    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)
    use_comp = s.grad_compression == "int8_ef" and mesh_has_pod

    def constrain(g):
        if grad_shardings is None:
            return g
        return jax.tree.map(jax.lax.with_sharding_constraint, g,
                            grad_shardings)

    def train_step(state: TrainState, batch: Pytree):
        nm = s.num_microbatches
        compute_params = state.params
        if s.cast_params:
            cdt = jnp.dtype(s.cast_params)
            compute_params = jax.tree.map(
                lambda p: p.astype(cdt) if p.dtype == jnp.float32 else p,
                state.params)
            # pin the shadow to the ZeRO shard so the cast happens
            # shard-local and the per-block FSDP all-gather moves bf16
            # (GSPMD otherwise gathers fp32 and converts afterwards)
            compute_params = constrain(compute_params)

        if nm == 1:
            (loss, metrics), grads = grad_fn(compute_params, batch)
            grads = constrain(grads)
        else:
            def split(x):
                return x.reshape((nm, x.shape[0] // nm) + x.shape[1:])

            micro = jax.tree.map(split, batch)
            adt = jnp.dtype(s.accum_dtype)
            g0 = constrain(jax.tree.map(
                lambda p: jnp.zeros(p.shape, adt), state.params))

            def body(acc, mb):
                (l, m), g = grad_fn(compute_params, mb)
                acc = constrain(jax.tree.map(
                    lambda a, gi: a + gi.astype(adt) / nm, acc, g))
                return acc, (l, m)

            grads, (losses, ms) = jax.lax.scan(body, g0, micro)
            loss = losses.mean()
            metrics = jax.tree.map(lambda x: x.mean(), ms)

        comp_err = state.comp_err
        if use_comp:
            # exact intra-pod reduction happened inside grad (GSPMD);
            # compress the cross-pod psum with error feedback.
            grads, cstate = compression.compressed_psum(
                grads, compression.CompressionState(error=comp_err),
                s.pod_axis)
            comp_err = cstate.error

        if (s.stream_optimizer and isinstance(state.params, dict)
                and "blocks" in state.params):
            # clip scale folded into the per-block update: the clipped
            # gradient tree is never materialized whole.
            gnorm = opt_mod.global_norm(grads)
            scale = jnp.minimum(1.0, s.clip_norm / (gnorm + 1e-9))
            params, opt_state = streamed_update(opt, grads, state.opt_state,
                                                state.params,
                                                grad_scale=scale)
        else:
            grads, gnorm = opt_mod.clip_by_global_norm(grads, s.clip_norm)
            updates, opt_state = opt.update(grads, state.opt_state,
                                            state.params)
            params = opt_mod.apply_updates(state.params, updates)
        metrics = dict(metrics, loss=loss, grad_norm=gnorm,
                       step=state.step.astype(jnp.float32))
        return TrainState(step=state.step + 1, params=params,
                          opt_state=opt_state, comp_err=comp_err), metrics

    return train_step
