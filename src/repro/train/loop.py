"""Host-side training loop: stepping, checkpointing, straggler detection.

The loop is deliberately thin — all math lives in the jitted train_step —
and owns the *operational* concerns a 1000-node deployment needs:

  * periodic async checkpointing (checkpoint.manager), resume-by-step;
  * straggler detection: per-step wall time EWMA + variance; a step slower
    than ``mean + k·σ`` is flagged (on a real cluster this feeds the
    controller that triggers pre-emptive restart of the slow host);
  * simulated-failure hook for tests (``fail_at_step``) proving that a
    crash between steps resumes bit-identically from the last checkpoint.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field

import jax
import numpy as np

__all__ = ["StragglerDetector", "TrainLoop"]


@dataclass
class StragglerDetector:
    """EWMA wall-time monitor; flags steps slower than mean + k·std."""

    alpha: float = 0.1
    k_sigma: float = 3.0
    warmup: int = 5
    _mean: float = 0.0
    _var: float = 0.0
    _n: int = 0
    flagged: list = field(default_factory=list)

    def observe(self, step: int, dt: float) -> bool:
        self._n += 1
        if self._n <= self.warmup:
            # prime the statistics without flagging (first steps compile)
            self._mean = dt if self._n == 1 else \
                (1 - self.alpha) * self._mean + self.alpha * dt
            return False
        slow = dt > self._mean + self.k_sigma * max(self._var ** 0.5,
                                                    0.05 * self._mean)
        d = dt - self._mean
        self._mean += self.alpha * d
        self._var = (1 - self.alpha) * (self._var + self.alpha * d * d)
        if slow:
            self.flagged.append((step, dt, self._mean))
        return slow


class TrainLoop:
    def __init__(self, train_step, state, *, ckpt_manager=None,
                 ckpt_every: int = 100, detector: StragglerDetector | None = None,
                 metrics_hook=None):
        self.train_step = train_step
        self.state = state
        self.ckpt = ckpt_manager
        self.ckpt_every = ckpt_every
        self.detector = detector or StragglerDetector()
        self.metrics_hook = metrics_hook
        self.history: list[dict] = []

    def run(self, batches, num_steps: int, *, fail_at_step: int | None = None):
        """Run up to ``num_steps`` steps; returns final state.

        ``fail_at_step`` raises RuntimeError *after* that step's checkpoint
        window — the failure-injection hook used by the restart tests.
        """
        it = iter(batches)
        for i in range(num_steps):
            batch = next(it)
            t0 = time.perf_counter()
            self.state, metrics = self.train_step(self.state, batch)
            jax.block_until_ready(metrics["loss"])
            dt = time.perf_counter() - t0

            step = int(self.state.step)
            slow = self.detector.observe(step, dt)
            rec = {k: float(np.asarray(v)) for k, v in metrics.items()}
            rec.update(step=step, wall_s=dt, straggler=slow)
            self.history.append(rec)
            if self.metrics_hook:
                self.metrics_hook(rec)

            if self.ckpt is not None and step % self.ckpt_every == 0:
                self.ckpt.save(step, self.state)
            if fail_at_step is not None and step >= fail_at_step:
                raise RuntimeError(f"injected failure at step {step}")
        if self.ckpt is not None:
            self.ckpt.save(int(self.state.step), self.state)
            self.ckpt.wait()
        return self.state
