"""Training substrate: jitted train_step (grad-accum, clipping, compression)
and the host-side loop (checkpointing, straggler detection, failure hooks)."""

from .step import (TrainSettings, TrainState, cross_entropy, init_state,
                   make_loss_fn, make_optimizer, make_train_step)
from .loop import StragglerDetector, TrainLoop

__all__ = ["TrainSettings", "TrainState", "cross_entropy", "init_state",
           "make_loss_fn", "make_optimizer", "make_train_step",
           "StragglerDetector", "TrainLoop"]
