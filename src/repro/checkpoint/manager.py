"""Sharded, atomic, async checkpointing with elastic restore.

Layout of one checkpoint (``<dir>/step_<N>/``):

    manifest.json          # tree structure, shapes, dtypes, shard index,
                           # crc32 per file, save-time metadata
    <leaf-id>.s<k>.npy     # one file per (leaf, host-local shard)

Design points for 1000+-node operation (DESIGN.md §4):
  * **Per-shard files** — every host writes only its addressable shards;
    no gather through host 0 (at this container's scale each array has one
    shard, but the format is the multi-host one).
  * **Atomic commit** — writes go to ``step_<N>.tmp``; the directory is
    fsync'd and renamed only after every file + manifest lands.  A crash
    mid-save leaves the previous checkpoint intact.
  * **Elastic restore** — shards record their *logical* index ranges, so a
    restore onto a different mesh shape / device count reassembles from
    logical coordinates (``make_array_from_callback`` with the new
    sharding reads whichever file ranges it needs).
  * **Async** — ``save`` snapshots device arrays to host memory
    synchronously (cheap) and does file IO on a worker thread; ``wait()``
    joins.  Integrity is checked on restore via crc32.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
import zlib
from concurrent.futures import Future, ThreadPoolExecutor

import jax
import numpy as np

__all__ = ["CheckpointManager", "save_pytree", "restore_pytree",
           "latest_step"]


def _leaf_id(path) -> str:
    parts = []
    for e in path:
        if hasattr(e, "key"):
            parts.append(str(e.key))
        elif hasattr(e, "name"):
            parts.append(str(e.name))
        elif hasattr(e, "idx"):
            parts.append(str(e.idx))
    return ".".join(parts) or "root"


def _shard_slices(arr: jax.Array):
    """Yield (shard_index, logical index ranges, numpy data) per local shard."""
    if not isinstance(arr, jax.Array) or not hasattr(arr, "addressable_shards"):
        yield 0, [[0, s] for s in np.shape(arr)], np.asarray(arr)
        return
    seen = set()
    for sh in arr.addressable_shards:
        idx = tuple(
            (0 if sl.start is None else sl.start,
             dim if sl.stop is None else sl.stop)
            for sl, dim in zip(sh.index, arr.shape))
        if idx in seen:          # replicated shards: write once
            continue
        seen.add(idx)
        yield len(seen) - 1, [list(t) for t in idx], np.asarray(sh.data)


def save_pytree(tree, directory: str) -> None:
    """Synchronous sharded save with atomic rename."""
    tmp = directory + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)

    leaves_meta = {}
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    for path, leaf in flat:
        lid = _leaf_id(path)
        shards = []
        for k, idx, data in _shard_slices(leaf):
            fname = f"{lid}.s{k}.npy"
            fpath = os.path.join(tmp, fname)
            np.save(fpath, data)
            with open(fpath, "rb") as f:
                crc = zlib.crc32(f.read())
            shards.append({"file": fname, "index": idx, "crc32": crc})
        leaves_meta[lid] = {
            "shape": list(np.shape(leaf)),
            "dtype": str(np.asarray(jax.device_get(leaf)).dtype)
            if not hasattr(leaf, "dtype") else str(leaf.dtype),
            "shards": shards,
        }

    treedef = jax.tree_util.tree_structure(tree)
    manifest = {"leaves": leaves_meta, "treedef": str(treedef)}
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())

    if os.path.exists(directory):
        shutil.rmtree(directory)
    os.rename(tmp, directory)


def restore_pytree(tree_like, directory: str, shardings=None):
    """Restore into the structure of ``tree_like``.

    ``shardings``: optional matching tree of jax.sharding.Sharding — enables
    elastic restore onto any mesh: each leaf is built via
    ``make_array_from_callback`` reading logical ranges from shard files.
    """
    with open(os.path.join(directory, "manifest.json")) as f:
        manifest = json.load(f)
    leaves = manifest["leaves"]

    def load_leaf(lid: str, like, sharding):
        meta = leaves[lid]
        shape = tuple(meta["shape"])
        dtype = np.dtype(meta["dtype"])
        # assemble the full logical array from shard files (verify crc)
        full = np.zeros(shape, dtype)
        for sh in meta["shards"]:
            fpath = os.path.join(directory, sh["file"])
            with open(fpath, "rb") as f:
                if zlib.crc32(f.read()) != sh["crc32"]:
                    raise IOError(f"checksum mismatch in {fpath}")
            data = np.load(fpath)
            sl = tuple(slice(a, b) for a, b in sh["index"])
            full[sl] = data
        if sharding is not None:
            return jax.make_array_from_callback(
                shape, sharding, lambda idx: full[idx])
        return jax.device_put(full.astype(dtype))

    flat = jax.tree_util.tree_flatten_with_path(tree_like)
    paths = [p for p, _ in flat[0]]
    likes = [l for _, l in flat[0]]
    if shardings is not None:
        shard_flat = jax.tree_util.tree_leaves(
            shardings, is_leaf=lambda x: isinstance(x, jax.sharding.Sharding))
    else:
        shard_flat = [None] * len(likes)
    out = [load_leaf(_leaf_id(p), l, s)
           for p, l, s in zip(paths, likes, shard_flat)]
    return jax.tree_util.tree_unflatten(flat[1], out)


def latest_step(root: str) -> int | None:
    if not os.path.isdir(root):
        return None
    steps = [int(d.split("_", 1)[1]) for d in os.listdir(root)
             if d.startswith("step_") and not d.endswith(".tmp")]
    return max(steps) if steps else None


class CheckpointManager:
    """Async manager: snapshot-to-host synchronously, write on a thread."""

    def __init__(self, root: str, *, max_to_keep: int = 3):
        self.root = root
        self.max_to_keep = max_to_keep
        self._pool = ThreadPoolExecutor(max_workers=1,
                                        thread_name_prefix="ckpt")
        self._pending: list[Future] = []
        self._lock = threading.Lock()
        os.makedirs(root, exist_ok=True)

    def _dir(self, step: int) -> str:
        return os.path.join(self.root, f"step_{step}")

    def save(self, step: int, tree) -> Future:
        host_tree = jax.tree.map(
            lambda x: np.asarray(jax.device_get(x)), tree)

        def work():
            save_pytree(host_tree, self._dir(step))
            self._gc()

        fut = self._pool.submit(work)
        with self._lock:
            self._pending.append(fut)
        return fut

    def wait(self):
        with self._lock:
            pending, self._pending = self._pending, []
        for f in pending:
            f.result()

    def restore(self, tree_like, step: int | None = None, shardings=None):
        step = latest_step(self.root) if step is None else step
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {self.root}")
        return restore_pytree(tree_like, self._dir(step), shardings), step

    def _gc(self):
        steps = sorted(
            int(d.split("_", 1)[1]) for d in os.listdir(self.root)
            if d.startswith("step_") and not d.endswith(".tmp"))
        for s in steps[:-self.max_to_keep]:
            shutil.rmtree(self._dir(s), ignore_errors=True)
