"""Versioned dispatch cache: persisted tuned shapes the engines load at
startup.

The autotuner (``tune.search``) measures real engine runs and writes the
winning shapes — ``chunk_steps``, kernel batch block ``block_b``,
``lanes_per_device``, ``spike_density_threshold``, plus the backend that
feasibility-resolved for them — into a JSON cache under ``results/tune/``
keyed by ``(config fingerprint, device kind, mesh shape, backend
request)``.  Engines resolve the cache at construction (explicit
``dispatch_cache=`` argument → ``REPRO_DISPATCH_CACHE`` env → none) and
record a :class:`CacheDecision` either way: a hit starts the
:class:`~repro.serve.telemetry.TelemetryController` at tuned values and
skips re-deriving the backend; a miss — or a rejected file — falls back
to today's static defaults.  **A bad cache must never take serving
down**: corrupt, stale-codec or future-codec files are rejected with an
actionable message (mirroring the ``serve.wire`` codec-version pattern),
warned about once, and treated as "no cache".

Tuned shapes are value-neutral by construction — chunked execution is
bit-identical under any split, lane placement is invisible to per-request
PRNG purity, and both dispatch datapaths compute the identical integer
contraction — so the cache may only ever change *when* work happens,
never *what* is computed (``benchmarks/bench_autotune.py`` pins this as
the ``tuned_bit_identical`` contract).

No jax at module scope: the cache must be loadable before any device
exists (the cluster coordinator arms workers by env).
"""

from __future__ import annotations

import json
import os
import warnings
from dataclasses import dataclass, field

__all__ = [
    "CACHE_CODEC_VERSION", "ENV_DISPATCH_CACHE",
    "DispatchCacheError", "TunedShapes", "CacheDecision", "DispatchCache",
    "cache_key", "resolve_dispatch_cache", "decide_dispatch",
]

# Bump when the entry layout (fields, meaning, key grammar) changes.
CACHE_CODEC_VERSION = 1

# Engines with no explicit dispatch_cache= argument resolve this env var
# to a cache file path (unset/empty = no cache, static defaults).
ENV_DISPATCH_CACHE = "REPRO_DISPATCH_CACHE"

_BACKENDS = ("fused", "fused_streamed", "staged", "reference")


class DispatchCacheError(ValueError):
    """A cache file or entry that cannot be adopted safely."""


@dataclass(frozen=True)
class TunedShapes:
    """One cache entry: the measured-winning dispatch shapes.

    ``backend`` is the realisation that feasibility-resolved during the
    tuned run on the keyed device kind — consumers under an ``auto``
    request adopt it without re-walking the resolution chain.  The
    seconds-per-retired-request numbers and the winning
    :class:`~repro.tune.timing.TimingRecord` ride along as provenance
    (never consulted for dispatch decisions).
    """

    chunk_steps: int
    block_b: int
    lanes_per_device: int
    spike_density_threshold: float
    backend: str
    seconds_per_retired_request: float | None = None
    baseline_seconds_per_retired_request: float | None = None
    timing: dict | None = None

    def to_json(self) -> dict:
        return {
            "chunk_steps": self.chunk_steps,
            "block_b": self.block_b,
            "lanes_per_device": self.lanes_per_device,
            "spike_density_threshold": self.spike_density_threshold,
            "backend": self.backend,
            "seconds_per_retired_request": self.seconds_per_retired_request,
            "baseline_seconds_per_retired_request":
                self.baseline_seconds_per_retired_request,
            "timing": self.timing,
        }


def _entry_from_json(key: str, d) -> TunedShapes:
    if not isinstance(d, dict):
        raise DispatchCacheError(
            f"cache entry {key!r} is {type(d).__name__}, expected an "
            f"object — regenerate the cache with "
            f"`python -m benchmarks.run --only autotune`")

    def _int(name, lo=1):
        v = d.get(name)
        if not isinstance(v, int) or isinstance(v, bool) or v < lo:
            raise DispatchCacheError(
                f"cache entry {key!r} field {name!r} is {v!r}, expected "
                f"an int >= {lo} — the file is corrupt or hand-edited; "
                f"regenerate it")
        return v

    block_b = _int("block_b")
    if block_b % 8:
        raise DispatchCacheError(
            f"cache entry {key!r} block_b={block_b} is not a multiple of "
            f"8 (the fused kernel's sublane granularity) — regenerate "
            f"the cache")
    thr = d.get("spike_density_threshold")
    if not isinstance(thr, (int, float)) or isinstance(thr, bool) \
            or not (0.0 < float(thr) <= 1.0):
        raise DispatchCacheError(
            f"cache entry {key!r} spike_density_threshold={thr!r} is not "
            f"a density in (0, 1] — regenerate the cache")
    backend = d.get("backend")
    if backend not in _BACKENDS:
        raise DispatchCacheError(
            f"cache entry {key!r} backend={backend!r} is not one of "
            f"{_BACKENDS} — regenerate the cache")
    return TunedShapes(
        chunk_steps=_int("chunk_steps"),
        block_b=block_b,
        lanes_per_device=_int("lanes_per_device"),
        spike_density_threshold=float(thr),
        backend=backend,
        seconds_per_retired_request=d.get("seconds_per_retired_request"),
        baseline_seconds_per_retired_request=d.get(
            "baseline_seconds_per_retired_request"),
        timing=d.get("timing"),
    )


@dataclass(frozen=True)
class CacheDecision:
    """The recorded outcome of one engine's startup cache consultation.

    Always attached to the engine as ``engine.cache_decision`` — a miss
    is a decision too (serving on static defaults, with the reason), so
    "did this fleet actually adopt tuned shapes?" is answerable from the
    running processes, not from guessing at env state.
    """

    hit: bool
    key: str
    reason: str
    source: str | None = None        # cache file path (None = no cache)
    tuned: TunedShapes | None = None


def cache_key(fingerprint: str, device_kind: str,
              mesh_shape, backend: str | None) -> str:
    """Canonical entry key: fingerprint | device kind | mesh | backend.

    ``backend`` here is the *request* ("auto" for unspecified) — the
    resolved realisation lives inside the entry.  The mesh shape is the
    lane mesh the engine runs ((1,) for the single-device engine,
    (data, model) for the sharded one): tuned lane counts are a
    per-device property, so a cache measured on one topology must not
    silently apply to another.
    """
    mesh = "x".join(str(int(m)) for m in tuple(mesh_shape))
    b = "auto" if backend in (None, "auto") else str(backend)
    return f"{fingerprint}|{device_kind}|mesh={mesh}|{b}"


class DispatchCache:
    """In-memory view of one versioned cache file."""

    def __init__(self, entries: dict | None = None,
                 source: str | None = None):
        self.entries: dict[str, TunedShapes] = dict(entries or {})
        self.source = source

    # ---- codec ------------------------------------------------------------

    @classmethod
    def from_json(cls, obj, source: str | None = None) -> "DispatchCache":
        where = source or "<in-memory>"
        if not isinstance(obj, dict):
            raise DispatchCacheError(
                f"dispatch cache {where} is {type(obj).__name__}, "
                f"expected a JSON object — regenerate it with "
                f"`python -m benchmarks.run --only autotune`")
        ver = obj.get("codec_version")
        if not isinstance(ver, int) or isinstance(ver, bool):
            raise DispatchCacheError(
                f"dispatch cache {where} has no integer codec_version — "
                f"not a dispatch cache, or corrupt; regenerate it")
        if ver > CACHE_CODEC_VERSION:
            raise DispatchCacheError(
                f"dispatch cache {where} uses codec v{ver} but this build "
                f"reads v{CACHE_CODEC_VERSION} — it was written by a "
                f"newer build; upgrade, or regenerate the cache with "
                f"this build")
        if ver < CACHE_CODEC_VERSION:
            raise DispatchCacheError(
                f"dispatch cache {where} uses stale codec v{ver} "
                f"(< v{CACHE_CODEC_VERSION}) — the entry layout changed; "
                f"regenerate it with "
                f"`python -m benchmarks.run --only autotune`")
        raw = obj.get("entries")
        if not isinstance(raw, dict):
            raise DispatchCacheError(
                f"dispatch cache {where} has no 'entries' object — "
                f"corrupt; regenerate it")
        entries = {str(k): _entry_from_json(str(k), v)
                   for k, v in raw.items()}
        return cls(entries, source=source)

    def to_json(self) -> dict:
        return {
            "codec_version": CACHE_CODEC_VERSION,
            "entries": {k: self.entries[k].to_json()
                        for k in sorted(self.entries)},
        }

    @classmethod
    def load(cls, path: str) -> "DispatchCache":
        try:
            with open(path) as f:
                obj = json.load(f)
        except OSError as e:
            raise DispatchCacheError(
                f"dispatch cache {path} is unreadable ({e}) — fix the "
                f"path, or unset {ENV_DISPATCH_CACHE}") from e
        except json.JSONDecodeError as e:
            raise DispatchCacheError(
                f"dispatch cache {path} is not valid JSON ({e}) — the "
                f"file is corrupt or truncated; regenerate it with "
                f"`python -m benchmarks.run --only autotune`") from e
        return cls.from_json(obj, source=path)

    def save(self, path: str) -> str:
        os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
        with open(path, "w") as f:
            json.dump(self.to_json(), f, indent=1, sort_keys=True)
        _LOAD_MEMO.pop(os.path.abspath(path), None)
        return path

    # ---- entry access -----------------------------------------------------

    def put(self, key: str, tuned: TunedShapes) -> None:
        self.entries[key] = tuned

    def lookup(self, *, fingerprint: str, device_kind: str,
               mesh_shape, backend: str | None) -> CacheDecision:
        key = cache_key(fingerprint, device_kind, mesh_shape, backend)
        tuned = self.entries.get(key)
        if tuned is None:
            return CacheDecision(
                hit=False, key=key, source=self.source,
                reason=f"no entry for {key!r} "
                       f"({len(self.entries)} entr"
                       f"{'y' if len(self.entries) == 1 else 'ies'} in "
                       f"cache) — serving on static defaults")
        return CacheDecision(
            hit=True, key=key, source=self.source, tuned=tuned,
            reason=f"tuned shapes adopted from {self.source or 'memory'}")


# One decode per (path, mtime): engine fleets construct many engines
# against the same env-armed file and must not re-parse it every time.
_LOAD_MEMO: dict[str, tuple[float, DispatchCache]] = {}


def _load_memoized(path: str) -> DispatchCache:
    ap = os.path.abspath(path)
    try:
        mtime = os.stat(ap).st_mtime
    except OSError:
        mtime = -1.0
    hit = _LOAD_MEMO.get(ap)
    if hit is not None and hit[0] == mtime:
        return hit[1]
    cache = DispatchCache.load(ap)
    _LOAD_MEMO[ap] = (mtime, cache)
    return cache


def resolve_dispatch_cache(spec) -> tuple["DispatchCache | None", str]:
    """Resolve a dispatch-cache spec to ``(cache | None, reason)``.

    ``spec`` may be a :class:`DispatchCache`, a file path, ``None``
    (consult ``REPRO_DISPATCH_CACHE``) or ``False`` (caching explicitly
    off — the autotuner measures candidates with this so an env-armed
    cache can never skew its own regeneration).  A file that fails to
    decode is **rejected loudly** — one ``UserWarning`` with the
    actionable message — and serving proceeds cacheless on static
    defaults; a bad cache must degrade the tuning, never the service.
    """
    if spec is False:
        return None, "dispatch cache explicitly disabled — static defaults"
    if isinstance(spec, DispatchCache):
        return spec, f"explicit cache ({len(spec.entries)} entries)"
    if spec is None:
        path = os.environ.get(ENV_DISPATCH_CACHE, "").strip()
        if not path:
            return None, "no dispatch cache configured — static defaults"
        origin = f"{ENV_DISPATCH_CACHE}={path}"
    else:
        path, origin = str(spec), str(spec)
    try:
        cache = _load_memoized(path)
    except DispatchCacheError as e:
        msg = (f"dispatch cache {origin} rejected: {e} — serving falls "
               f"back to static defaults")
        warnings.warn(msg, UserWarning, stacklevel=3)
        return None, msg
    return cache, f"loaded {origin} ({len(cache.entries)} entries)"


def decide_dispatch(spec, *, cfg, backend, mesh_shape,
                    device_kind: str | None = None) -> CacheDecision:
    """One-call engine-side consultation: resolve + fingerprint + lookup."""
    from .fingerprint import config_fingerprint
    if device_kind is None:
        from .timing import device_kind_now
        device_kind = device_kind_now()
    fp = config_fingerprint(cfg)
    cache, reason = resolve_dispatch_cache(spec)
    if cache is None:
        return CacheDecision(
            hit=False, reason=reason,
            key=cache_key(fp, device_kind, mesh_shape, backend))
    return cache.lookup(fingerprint=fp, device_kind=device_kind,
                        mesh_shape=mesh_shape, backend=backend)
