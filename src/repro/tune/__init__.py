"""Measured autotuning: wall-clock search over the dispatch space and the
persisted dispatch cache engines load at startup.

Three layers, importable independently:

* :mod:`repro.tune.timing` — the deterministic timing harness (warmup +
  median-of-k on the monotonic clock, device-kind/interpret provenance
  tags) shared with ``benchmarks/common.py``;
* :mod:`repro.tune.cache` / :mod:`repro.tune.fingerprint` — the
  versioned dispatch-cache codec and the config fingerprint it is keyed
  by (no jax at import: the serving stack consults these at engine
  construction);
* :mod:`repro.tune.search` — the telemetry-seeded
  seconds-per-retired-request search over
  ``(chunk_steps, block_b, lanes_per_device, spike_density_threshold)``
  (imports the serving stack lazily).
"""

from .cache import (CACHE_CODEC_VERSION, ENV_DISPATCH_CACHE, CacheDecision,
                    DispatchCache, DispatchCacheError, TunedShapes,
                    cache_key, decide_dispatch, resolve_dispatch_cache)
from .fingerprint import config_fingerprint, fingerprint_payload
from .search import (ArrivalSchedule, AutotuneConfig, AutotuneResult,
                     Candidate, autotune_engine, prune_grids,
                     serve_schedule, write_cache)
from .timing import TimingRecord, device_kind_now, measure

__all__ = [
    "CACHE_CODEC_VERSION", "ENV_DISPATCH_CACHE",
    "ArrivalSchedule", "AutotuneConfig", "AutotuneResult",
    "CacheDecision", "Candidate", "DispatchCache", "DispatchCacheError",
    "TimingRecord", "TunedShapes",
    "autotune_engine", "cache_key", "config_fingerprint",
    "decide_dispatch", "device_kind_now", "fingerprint_payload",
    "measure", "prune_grids", "resolve_dispatch_cache", "serve_schedule",
    "write_cache",
]
