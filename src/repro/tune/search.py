"""Telemetry-seeded wall-clock search over the dispatch space.

The runtime already exposes every knob this module tunes — ``chunk_steps``
(host-sync granularity of the streaming window), the kernel batch block
``block_b`` (MXU tile height of the fused launch), ``lanes_per_device``
(continuous-batching tile width) and ``spike_density_threshold`` (the
masked-vs-MXU dispatch boundary) — and PR 5 proved every one of them
value-neutral.  What none of them had is a *measured* setting: the
controller walks them by fixed law, the benches reported analytic bytes.
This module closes that gap the way the SNN design-space-exploration
literature does (Abderrahmane et al.; SparrowSNN's HW/SW co-tuning):
time **real engine runs** against a deterministic open-loop arrival
schedule and pick the shapes that minimize **seconds per retired
request**.

The sweep is kept tractable by seeding it from telemetry rather than
enumerating the full grid: a short probe run with the adaptive
:class:`~repro.serve.telemetry.TelemetryController` yields the observed
density EWMA and mean retirement steps, which prune the threshold grid
to the two values bracketing the observed density (every threshold on
the same side of the traffic density dispatches identically — one
representative per equivalence class suffices) and drop chunk lengths
far past the observed retirement horizon.  The **default shapes are
always a candidate** and measured first: they are both the bit-identity
baseline every candidate must reproduce exactly and the floor the winner
is compared against, so within a tuning session the winner is never
slower than the defaults by construction.

Determinism: the schedule's pixels and arrival pattern come from a
seeded generator, engines are seeded, and the candidate order is sorted —
re-running the tuner on the same machine walks the same candidates in
the same order (only the wall-clock samples differ).

jax and the serving stack are imported lazily: ``tune.cache`` must stay
importable from ``core.snn`` without dragging ``serve`` in at module
scope.
"""

from __future__ import annotations

import itertools
import math
from dataclasses import dataclass, field, replace as dc_replace

import numpy as np

from .cache import DispatchCache, TunedShapes, cache_key
from .fingerprint import config_fingerprint
from .timing import device_kind_now, measure

__all__ = [
    "ArrivalSchedule", "AutotuneConfig", "AutotuneResult", "Candidate",
    "autotune_engine", "prune_grids", "serve_schedule", "write_cache",
]


@dataclass(frozen=True)
class ArrivalSchedule:
    """Deterministic open-loop arrival process for candidate timing.

    ``per_round`` requests are submitted at every scheduling round
    regardless of completions (open-loop: the offered load never adapts
    to the engine under test, so faster shapes genuinely retire the
    backlog sooner instead of being handed less work).  Pixels are drawn
    once from ``numpy.random.default_rng(seed)`` so every candidate —
    and every repeat — serves byte-identical traffic.
    """

    n_requests: int = 32
    per_round: int = 2
    seed: int = 1234

    def pixels(self, n_in: int) -> list:
        rng = np.random.default_rng(self.seed)
        return [rng.integers(0, 256, size=n_in, dtype=np.uint8)
                for _ in range(self.n_requests)]


def serve_schedule(engine, schedule: ArrivalSchedule, pixels: list) -> dict:
    """Drive one engine through the schedule; returns its results dict."""
    i = 0
    while i < schedule.n_requests:
        for _ in range(schedule.per_round):
            if i < schedule.n_requests:
                engine.submit(pixels[i], request_id=i)
                i += 1
        engine.step()
    return engine.run()


@dataclass(frozen=True)
class Candidate:
    """One point of the dispatch space under test."""

    chunk_steps: int
    block_b: int
    lanes_per_device: int
    threshold: float

    def to_json(self) -> dict:
        return {"chunk_steps": self.chunk_steps, "block_b": self.block_b,
                "lanes_per_device": self.lanes_per_device,
                "threshold": self.threshold}


@dataclass(frozen=True)
class AutotuneConfig:
    """Search-space grids + measurement knobs."""

    chunk_steps_grid: tuple = (2, 3, 4, 6, 8)
    block_b_grid: tuple = (8, 16)
    lanes_grid: tuple = (4, 8, 16)
    threshold_grid: tuple = (0.1, 0.25, 0.4)
    schedule: ArrivalSchedule = field(default_factory=ArrivalSchedule)
    repeats: int = 3
    warmup: int = 1
    # telemetry seeding: prune the grids from a probe run's observed
    # density / retirement EWMAs before measuring anything
    telemetry_prune: bool = True
    # hard cap on measured candidates (default shapes always included)
    max_candidates: int = 12


@dataclass(frozen=True)
class AutotuneResult:
    """Everything one tuning session learned (records are provenance)."""

    tuned: TunedShapes
    default: Candidate
    baseline_spr: float              # default shapes, s/retired-request
    records: list                    # per-candidate measurement dicts
    probe: dict                      # telemetry-seeding observations
    pruned: dict                     # per-axis grid sizes before/after
    bit_identical: bool              # every candidate == default bits
    fingerprint: str
    device_kind: str


def _default_candidate(cfg) -> Candidate:
    """Today's static shapes: what an engine runs with no cache."""
    from ..core.telemetry import resolve_density_threshold
    from ..kernels.fused_snn import block_b_for
    lanes = 8                            # SNNStreamEngine's default tile
    return Candidate(
        chunk_steps=min(4, cfg.num_steps),
        block_b=block_b_for(lanes),
        lanes_per_device=lanes,
        threshold=float(resolve_density_threshold(
            cfg.spike_density_threshold)))


def prune_grids(tune_cfg: AutotuneConfig, cfg, *,
                density_ewma: float | None,
                service_steps: float | None) -> tuple[dict, dict]:
    """Telemetry-seeded grid pruning.  Returns (grids, prune_report).

    * thresholds: every value on the same side of the observed density
      EWMA dispatches every chunk identically, so only the two values
      bracketing the EWMA survive (plus clipping to the config window).
    * chunk lengths: lanes retire after ~``service_steps`` on average —
      chunks much longer than that horizon only burn frozen-lane steps,
      so lengths past ``2 × service_steps`` are dropped (never below the
      shortest grid entry, never above the window).
    * lanes: a tile wider than the whole offered schedule can never
      fill; such widths are dropped.
    """
    sched = tune_cfg.schedule
    thr = sorted(set(float(t) for t in tune_cfg.threshold_grid))
    chunks = sorted(set(int(c) for c in tune_cfg.chunk_steps_grid
                        if 1 <= c <= cfg.num_steps))
    lanes = sorted(set(int(b) for b in tune_cfg.lanes_grid))
    blocks = sorted(set(int(b) for b in tune_cfg.block_b_grid))
    report = {"threshold": [len(thr)], "chunk_steps": [len(chunks)],
              "lanes_per_device": [len(lanes)], "block_b": [len(blocks)]}
    if tune_cfg.telemetry_prune and density_ewma is not None:
        below = [t for t in thr if t <= density_ewma]
        above = [t for t in thr if t > density_ewma]
        thr = ([max(below)] if below else []) + \
              ([min(above)] if above else [])
    if tune_cfg.telemetry_prune and service_steps is not None and chunks:
        horizon = max(min(chunks), int(math.ceil(2.0 * service_steps)))
        chunks = [c for c in chunks if c <= horizon] or [min(chunks)]
    lanes = [b for b in lanes if b <= sched.n_requests] or \
        ([min(lanes)] if lanes else [])
    report["threshold"].append(len(thr))
    report["chunk_steps"].append(len(chunks))
    report["lanes_per_device"].append(len(lanes))
    report["block_b"].append(len(blocks))
    return ({"threshold": thr, "chunk_steps": chunks, "lanes": lanes,
             "blocks": blocks}, report)


def _result_bits(results: dict) -> dict:
    """The bit-identity projection of an engine's results dict."""
    return {int(rid): (int(r.pred), int(r.steps))
            for rid, r in results.items()}


def autotune_engine(params_q: dict, cfg, *,
                    tune_cfg: AutotuneConfig | None = None,
                    backend: str | None = None,
                    patience: int = 2, seed: int = 0,
                    make_engine=None) -> AutotuneResult:
    """Measure the dispatch space on real engine runs; return the winner.

    ``make_engine(candidate, adaptive_cfg)`` may be supplied to tune a
    different engine construction (the sharded engine, a tier); the
    default builds a single-device :class:`~repro.serve.SNNStreamEngine`
    with the candidate's shapes.  The returned
    :class:`~repro.tune.cache.TunedShapes` carries the backend the
    winning engine actually resolved, so a cache consumer under ``auto``
    adopts it without re-walking the feasibility chain.
    """
    from ..serve.snn_engine import SNNStreamEngine
    from ..serve.telemetry import AdaptiveDispatchConfig
    tc = tune_cfg or AutotuneConfig()
    sched = tc.schedule
    pixels = sched.pixels(cfg.layer_sizes[0])
    frozen = AdaptiveDispatchConfig(adaptive=False)

    if make_engine is None:
        def make_engine(cand: Candidate, adaptive):
            c = (cfg if cand.threshold is None else
                 dc_replace(cfg, spike_density_threshold=cand.threshold))
            return SNNStreamEngine(
                params_q, c, batch_size=cand.lanes_per_device,
                chunk_steps=cand.chunk_steps, block_b=cand.block_b,
                patience=patience, seed=seed, backend=backend,
                adaptive=adaptive, dispatch_cache=False)

    default = _default_candidate(cfg)

    # ---- probe: one adaptive run seeds the grid pruning -------------------
    probe_eng = make_engine(default, AdaptiveDispatchConfig(adaptive=True))
    serve_schedule(probe_eng, sched, pixels)
    probe = {
        "density_ewma": probe_eng.controller.density_ewma,
        "service_steps_ewma": probe_eng._service_ewma,
        "chunk_steps_final": probe_eng.controller.chunk_steps,
        "backend": probe_eng.backend,
    }

    grids, prune_report = prune_grids(
        tc, cfg, density_ewma=probe["density_ewma"],
        service_steps=probe["service_steps_ewma"])

    cands = [Candidate(chunk_steps=c, block_b=b, lanes_per_device=l,
                       threshold=t)
             for c, b, l, t in itertools.product(
                 grids["chunk_steps"], grids["blocks"], grids["lanes"],
                 grids["threshold"])]
    cands = [c for c in cands if c != default]
    cands.sort(key=lambda c: (c.chunk_steps, c.block_b,
                              c.lanes_per_device, c.threshold))
    cands = [default] + cands[:max(0, tc.max_candidates - 1)]

    # ---- measure: default first (it is the bit-identity baseline) ---------
    device_kind = device_kind_now()
    records: list[dict] = []
    baseline_bits: dict | None = None
    baseline_spr: float | None = None
    all_identical = True
    for cand in cands:
        holder: dict = {}

        def run_once(cand=cand, holder=holder):
            eng = make_engine(cand, frozen)
            holder["results"] = serve_schedule(eng, sched, pixels)
            holder["backend"] = eng.backend

        run_once()                       # resolve backend + first compile
        resolved = holder["backend"]
        interpret = (resolved in ("fused", "fused_streamed")
                     and device_kind != "tpu")
        rec = measure(run_once, repeats=tc.repeats, warmup=tc.warmup,
                      interpret=interpret, device_kind=device_kind)
        bits = _result_bits(holder["results"])
        if baseline_bits is None:
            baseline_bits = bits
        identical = bits == baseline_bits
        all_identical = all_identical and identical
        spr = rec.median_s / max(1, sched.n_requests)
        if cand == default:
            baseline_spr = spr
        records.append({"candidate": cand.to_json(), "backend": resolved,
                        "seconds_per_retired_request": spr,
                        "matches_baseline": identical,
                        "timing": rec.to_json()})

    # ---- pick: fastest candidate that reproduced the baseline bits --------
    # (ties inside one stddev prefer the default — no churn for noise)
    eligible = [(r, c) for r, c in zip(records, cands)
                if r["matches_baseline"]]
    winner_rec, winner = min(
        eligible, key=lambda rc: (rc[0]["seconds_per_retired_request"],
                                  rc[1] != default, repr(rc[1])))
    tuned = TunedShapes(
        chunk_steps=winner.chunk_steps, block_b=winner.block_b,
        lanes_per_device=winner.lanes_per_device,
        spike_density_threshold=float(winner.threshold),
        backend=winner_rec["backend"],
        seconds_per_retired_request=winner_rec[
            "seconds_per_retired_request"],
        baseline_seconds_per_retired_request=baseline_spr,
        timing=winner_rec["timing"])
    return AutotuneResult(
        tuned=tuned, default=default, baseline_spr=baseline_spr,
        records=records, probe=probe, pruned=prune_report,
        bit_identical=all_identical,
        fingerprint=config_fingerprint(cfg), device_kind=device_kind)


def write_cache(result: AutotuneResult, path: str, *,
                backend_request: str | None = "auto",
                mesh_shapes=((1,),)) -> DispatchCache:
    """Persist a tuning session's winner under every requested mesh key.

    The tuner measures on a single-device engine; callers that verified
    the shapes on a sharded topology pass its mesh shape too so fleet
    engines hit the same entry (lane counts are per-device, so seeding a
    sharded key from a single-device session is exactly the per-device
    claim the bench's sharded bit-identity check confirms).  Merges into
    an existing cache file when one is present and valid.
    """
    try:
        cache = DispatchCache.load(path)
    except Exception:
        cache = DispatchCache()
    for mesh_shape in mesh_shapes:
        cache.put(cache_key(result.fingerprint, result.device_kind,
                            mesh_shape, backend_request), result.tuned)
    cache.save(path)
    return cache
