"""Deterministic wall-clock timing harness (the measured half of tune/).

Every measurement in this repo — the autotuner's candidate runs and the
``benchmarks/`` suites (``benchmarks/common.py`` re-exports this module
as the shared harness) — goes through :func:`measure`: ``warmup``
un-timed calls first (jit compilation and cache warm never pollute a
sample), then ``repeats`` timed calls on the monotonic clock, reported
as the **median** with the per-measurement stddev alongside.  The median
is the robust central estimate for a small k under scheduler noise; the
stddev is what lets a consumer judge whether two medians are actually
distinguishable.

Every :class:`TimingRecord` is tagged with ``device_kind`` (the jax
backend the call ran on) and ``interpret`` (whether the timed path ran
Pallas kernels in interpret mode).  An interpret-mode CPU number is a
correctness artifact, not a device timing — the tag is what lets
``benchmarks/check_tracked.py`` pin contract booleans while exempting
wall-clock fields from cross-machine drift, and what stops a CPU CI run
from being mistaken for a TPU measurement.
"""

from __future__ import annotations

import time
from dataclasses import dataclass

import numpy as np

__all__ = ["TimingRecord", "measure", "device_kind_now"]


def device_kind_now() -> str:
    """The jax backend this process dispatches to ("cpu"/"tpu"/"gpu")."""
    import jax
    return str(jax.default_backend())


@dataclass(frozen=True)
class TimingRecord:
    """One timed measurement: median-of-k wall-clock plus its provenance."""

    median_s: float          # median of the timed samples
    stddev_s: float          # population stddev of the timed samples
    samples_s: tuple         # every timed sample, in call order
    repeats: int
    warmup: int
    device_kind: str         # jax backend the calls dispatched to
    interpret: bool          # True = Pallas interpret mode was in the path

    @property
    def us(self) -> float:
        """Median in microseconds (the bench suites' historical unit)."""
        return self.median_s * 1e6

    def to_json(self) -> dict:
        return {
            "median_s": self.median_s,
            "stddev_s": self.stddev_s,
            "samples_s": list(self.samples_s),
            "repeats": self.repeats,
            "warmup": self.warmup,
            "device_kind": self.device_kind,
            "interpret": self.interpret,
        }


def _block(result) -> None:
    """Wait for device work hiding behind async jax dispatch."""
    import jax
    try:
        jax.block_until_ready(result)
    except (TypeError, ValueError):
        # host-side results (dicts of dataclasses, plain python) are
        # already synchronous — nothing to wait for
        pass


def measure(fn, *args, repeats: int = 3, warmup: int = 1,
            interpret: bool = False,
            device_kind: str | None = None) -> TimingRecord:
    """Median-of-``repeats`` wall-clock of ``fn(*args)`` after ``warmup``.

    ``interpret`` must be set by the caller when the timed path runs
    Pallas kernels off-TPU (interpret mode): the record carries the tag
    so downstream consumers never mistake a correctness-path timing for
    a device timing.  ``device_kind`` defaults to the live jax backend.
    """
    if repeats < 1:
        raise ValueError(f"repeats must be >= 1, got {repeats}")
    if warmup < 0:
        raise ValueError(f"warmup must be >= 0, got {warmup}")
    for _ in range(warmup):
        _block(fn(*args))
    samples = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        _block(fn(*args))
        samples.append(time.perf_counter() - t0)
    return TimingRecord(
        median_s=float(np.median(samples)),
        stddev_s=float(np.std(samples)),
        samples_s=tuple(float(s) for s in samples),
        repeats=repeats,
        warmup=warmup,
        device_kind=(device_kind_now() if device_kind is None
                     else device_kind),
        interpret=bool(interpret),
    )
