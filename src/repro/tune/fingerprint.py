"""Config fingerprinting for the dispatch cache.

A cache entry is only as trustworthy as its key: tuned shapes measured
for one network must never be adopted by a different one.  The
fingerprint is a SHA-256 over the **canonical JSON** of every
``SNNConfig`` field that changes what the datapath computes or how big
its launches are — topology, window length, LIF constants, quantization
width, readout, pruning, dot implementation, sparse skipping and the
static dispatch threshold.  Fields that are pure training-side concerns
(``qat``, ``surrogate_slope``, ``train_threshold``) and the backend
*request* (the cache key carries the backend separately) are excluded:
two configs that serve identically share a fingerprint even if they
were trained differently.

Conservatism is deliberate: a fingerprint that splits two equivalent
configs costs one cache miss (static defaults — always safe); one that
merges two different configs would leak tuned shapes across networks.
When in doubt a field goes IN.
"""

from __future__ import annotations

import hashlib
import json

__all__ = ["config_fingerprint", "fingerprint_payload"]


def fingerprint_payload(cfg) -> dict:
    """The identity-bearing fields of an ``SNNConfig``, JSON-canonical."""
    lif = cfg.lif
    return {
        "layer_sizes": [int(s) for s in cfg.layer_sizes],
        "num_steps": int(cfg.num_steps),
        "lif": {
            "decay_shift": int(lif.decay_shift),
            "v_threshold": int(lif.v_threshold),
            "v_rest": int(lif.v_rest),
            "v_min": int(lif.v_min),
            "v_max": int(lif.v_max),
        },
        "weight_bits": int(cfg.weight_bits),
        "readout": str(cfg.readout),
        "active_pruning": bool(cfg.active_pruning),
        "dot_impl": str(cfg.dot_impl),
        "fuse_encoder": bool(cfg.fuse_encoder),
        "sparse_skip": (None if cfg.sparse_skip is None
                        else bool(cfg.sparse_skip)),
        "spike_density_threshold": (
            None if cfg.spike_density_threshold is None
            else float(cfg.spike_density_threshold)),
        "emit_trace": bool(cfg.emit_trace),
    }


def config_fingerprint(cfg) -> str:
    """Short stable hex fingerprint of the config's serving identity."""
    blob = json.dumps(fingerprint_payload(cfg), sort_keys=True,
                      separators=(",", ":"))
    return hashlib.sha256(blob.encode("utf-8")).hexdigest()[:16]
