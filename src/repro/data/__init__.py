"""Data substrate: procedural digit dataset (MNIST stand-in), synthetic LM
token stream, and the host-sharded input pipeline."""

from . import digits, pipeline, tokens

__all__ = ["digits", "pipeline", "tokens"]
