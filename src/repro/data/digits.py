"""Procedural 28×28 digit dataset — an offline MNIST stand-in.

This container has no network access and no bundled MNIST, so the paper's
static-image workload is reproduced with a procedural renderer: each digit
class 0–9 is a stroke skeleton (polylines + elliptical arcs in a unit box),
rasterised with a soft-brush distance field and randomly perturbed per
sample (affine jitter, stroke width, intensity, pixel noise).  The task is
the same 10-class 784-input classification problem at a comparable
difficulty, and the loader transparently prefers a real ``mnist.npz`` if one
is present (``REPRO_MNIST_PATH``), making real MNIST a drop-in.

Also provides the paper's Fig.-8 corruption suite: rotation, pixel shift,
Gaussian noise, occlusion.
"""

from __future__ import annotations

import math
import os
from dataclasses import dataclass

import numpy as np

__all__ = [
    "DigitDataset", "make_dataset", "corrupt",
    "rotate_images", "shift_images", "noise_images", "occlude_images",
]

IMG = 28


def _arc(cx, cy, rx, ry, a0, a1, n=40):
    t = np.linspace(a0, a1, n)
    return np.stack([cx + rx * np.cos(t), cy + ry * np.sin(t)], axis=1)


def _line(x0, y0, x1, y1, n=24):
    t = np.linspace(0.0, 1.0, n)
    return np.stack([x0 + (x1 - x0) * t, y0 + (y1 - y0) * t], axis=1)


def _skeleton(digit: int) -> np.ndarray:
    """Stroke sample points for one digit, in [0,1]² (y down)."""
    P = []
    if digit == 0:
        P.append(_arc(0.5, 0.5, 0.26, 0.38, 0, 2 * math.pi, 80))
    elif digit == 1:
        P.append(_line(0.52, 0.12, 0.52, 0.88))
        P.append(_line(0.38, 0.26, 0.52, 0.12))
    elif digit == 2:
        P.append(_arc(0.5, 0.32, 0.25, 0.2, math.pi, 2.25 * math.pi, 40))
        P.append(_line(0.72, 0.42, 0.28, 0.85))
        P.append(_line(0.28, 0.85, 0.75, 0.85))
    elif digit == 3:
        P.append(_arc(0.47, 0.3, 0.24, 0.19, 0.75 * math.pi, 2.4 * math.pi, 40))
        P.append(_arc(0.47, 0.68, 0.26, 0.21, 1.6 * math.pi, 3.2 * math.pi, 40))
    elif digit == 4:
        P.append(_line(0.62, 0.1, 0.25, 0.62))
        P.append(_line(0.25, 0.62, 0.78, 0.62))
        P.append(_line(0.62, 0.1, 0.62, 0.9))
    elif digit == 5:
        P.append(_line(0.7, 0.12, 0.32, 0.12))
        P.append(_line(0.32, 0.12, 0.3, 0.45))
        P.append(_arc(0.48, 0.64, 0.24, 0.23, 1.25 * math.pi, 2.85 * math.pi, 48))
    elif digit == 6:
        P.append(_arc(0.52, 0.3, 0.3, 0.35, 0.9 * math.pi, 1.6 * math.pi, 30))
        P.append(_arc(0.5, 0.66, 0.22, 0.2, 0, 2 * math.pi, 56))
    elif digit == 7:
        P.append(_line(0.25, 0.13, 0.75, 0.13))
        P.append(_line(0.75, 0.13, 0.42, 0.88))
    elif digit == 8:
        P.append(_arc(0.5, 0.3, 0.2, 0.17, 0, 2 * math.pi, 48))
        P.append(_arc(0.5, 0.68, 0.24, 0.2, 0, 2 * math.pi, 56))
    elif digit == 9:
        P.append(_arc(0.5, 0.32, 0.22, 0.2, 0, 2 * math.pi, 56))
        P.append(_arc(0.45, 0.45, 0.28, 0.42, -0.15 * math.pi, 0.45 * math.pi, 28))
    else:
        raise ValueError(digit)
    return np.concatenate(P, axis=0)


_SKELETONS = [_skeleton(d) for d in range(10)]


def _render(points: np.ndarray, rng: np.random.Generator) -> np.ndarray:
    """Rasterise jittered stroke points to a 28×28 float image in [0,1]."""
    # Random affine: rotation, anisotropic scale, shear, translation.
    # Jitter magnitudes tuned so a linear probe scores ≈92% (MNIST-like
    # difficulty), keeping accuracy numbers comparable to the paper's.
    ang = rng.uniform(-0.24, 0.24)
    sx, sy = rng.uniform(0.80, 1.15, 2)
    shear = rng.uniform(-0.22, 0.22)
    ca, sa = math.cos(ang), math.sin(ang)
    A = np.array([[ca * sx, -sa * sy + shear], [sa * sx, ca * sy]])
    c = points.mean(0)
    # Per-point wobble deforms the stroke itself (handwriting variation).
    wob = rng.normal(0, 0.005, points.shape).cumsum(0)
    wob -= wob.mean(0)
    pts = (points + wob - c) @ A.T + c + rng.uniform(-0.07, 0.07, 2)

    # Distance field to stroke samples.
    gy, gx = np.mgrid[0:IMG, 0:IMG]
    grid = np.stack([gx, gy], axis=-1).reshape(-1, 2) / (IMG - 1)
    d2 = ((grid[:, None, :] - pts[None, :, :]) ** 2).sum(-1)
    dmin = np.sqrt(d2.min(axis=1))
    width = rng.uniform(0.026, 0.055)
    img = np.clip(1.25 - dmin / width, 0.0, 1.0) ** 1.5
    img = img.reshape(IMG, IMG)
    img *= rng.uniform(0.7, 1.0)                        # intensity jitter
    img += rng.normal(0, 0.05, img.shape)               # sensor noise
    return np.clip(img, 0.0, 1.0).astype(np.float32)


@dataclass(frozen=True)
class DigitDataset:
    x_train: np.ndarray  # (n, 784) float32 in [0,1]
    y_train: np.ndarray  # (n,) int32
    x_test: np.ndarray
    y_test: np.ndarray

    @property
    def n_train(self) -> int:
        return self.x_train.shape[0]


def make_dataset(n_train: int = 6000, n_test: int = 1000,
                 seed: int = 0) -> DigitDataset:
    """Build the dataset (or load real MNIST from REPRO_MNIST_PATH if set)."""
    path = os.environ.get("REPRO_MNIST_PATH", "")
    if path and os.path.exists(path):
        z = np.load(path)
        return DigitDataset(
            x_train=z["x_train"].reshape(-1, IMG * IMG).astype(np.float32) / 255.0,
            y_train=z["y_train"].astype(np.int32),
            x_test=z["x_test"].reshape(-1, IMG * IMG).astype(np.float32) / 255.0,
            y_test=z["y_test"].astype(np.int32),
        )

    rng = np.random.default_rng(seed)
    n = n_train + n_test
    labels = rng.integers(0, 10, n).astype(np.int32)
    imgs = np.empty((n, IMG * IMG), np.float32)
    for i, lab in enumerate(labels):
        imgs[i] = _render(_SKELETONS[lab], rng).reshape(-1)
    return DigitDataset(
        x_train=imgs[:n_train], y_train=labels[:n_train],
        x_test=imgs[n_train:], y_test=labels[n_train:],
    )


# ---------------------------------------------------------------------------
# Fig.-8 corruption suite
# ---------------------------------------------------------------------------

def rotate_images(x: np.ndarray, degrees: float = 15.0) -> np.ndarray:
    """Nearest-neighbour rotation about the image centre."""
    ang = math.radians(degrees)
    ca, sa = math.cos(ang), math.sin(ang)
    imgs = x.reshape(-1, IMG, IMG)
    gy, gx = np.mgrid[0:IMG, 0:IMG]
    cy = cx = (IMG - 1) / 2.0
    sx = ca * (gx - cx) + sa * (gy - cy) + cx
    sy = -sa * (gx - cx) + ca * (gy - cy) + cy
    sxi = np.clip(np.round(sx).astype(int), 0, IMG - 1)
    syi = np.clip(np.round(sy).astype(int), 0, IMG - 1)
    valid = (sx >= 0) & (sx <= IMG - 1) & (sy >= 0) & (sy <= IMG - 1)
    out = imgs[:, syi, sxi] * valid[None]
    return out.reshape(x.shape).astype(np.float32)


def shift_images(x: np.ndarray, frac: float = 0.2) -> np.ndarray:
    """Shift right/down by frac of the image size (zero fill)."""
    s = int(round(IMG * frac))
    imgs = x.reshape(-1, IMG, IMG)
    out = np.zeros_like(imgs)
    if s < IMG:
        out[:, s:, s:] = imgs[:, : IMG - s, : IMG - s]
    return out.reshape(x.shape)


def noise_images(x: np.ndarray, sigma: float = 0.3, seed: int = 0) -> np.ndarray:
    rng = np.random.default_rng(seed)
    return np.clip(x + rng.normal(0, sigma, x.shape), 0, 1).astype(np.float32)


def occlude_images(x: np.ndarray, size: int = 9, seed: int = 0) -> np.ndarray:
    """Black square patch at a random location per image."""
    rng = np.random.default_rng(seed)
    imgs = x.reshape(-1, IMG, IMG).copy()
    for i in range(imgs.shape[0]):
        r0 = rng.integers(0, IMG - size)
        c0 = rng.integers(0, IMG - size)
        imgs[i, r0:r0 + size, c0:c0 + size] = 0.0
    return imgs.reshape(x.shape)


def corrupt(x: np.ndarray, kind: str, seed: int = 0) -> np.ndarray:
    if kind == "rotation":
        return rotate_images(x, 15.0)
    if kind == "shift":
        return shift_images(x, 0.2)
    if kind == "noise":
        return noise_images(x, 0.3, seed)
    if kind == "occlusion":
        return occlude_images(x, 9, seed)
    if kind == "clean":
        return x
    raise ValueError(kind)
