"""Synthetic LM token pipeline.

Offline container ⇒ no corpora; the LM-family architectures train/serve on a
synthetic-but-structured token stream: a Zipf-distributed unigram base with
injected copy/recall structure (random motif repetition) so the loss is
learnable and non-degenerate, which is what the end-to-end driver and the
dry-runs need.  Deterministic per (seed, host_id) and cheap enough to
generate on the fly inside the input pipeline.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

__all__ = ["TokenStreamConfig", "token_batches", "sample_tokens"]


@dataclass(frozen=True)
class TokenStreamConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    zipf_a: float = 1.2
    motif_len: int = 16
    motif_prob: float = 0.25
    seed: int = 0


def sample_tokens(cfg: TokenStreamConfig, rng: np.random.Generator,
                  batch: int) -> np.ndarray:
    """(batch, seq_len+1) int32 tokens — +1 so inputs/labels can be split."""
    L = cfg.seq_len + 1
    # Zipf base (clipped to vocab; reserve 0 as pad/bos).
    toks = rng.zipf(cfg.zipf_a, size=(batch, L)).astype(np.int64)
    toks = 1 + (toks - 1) % (cfg.vocab_size - 1)
    # Inject motif repetitions: copy an earlier span forward.
    n_motifs = max(1, int(cfg.motif_prob * L / cfg.motif_len))
    for b in range(batch):
        for _ in range(n_motifs):
            if L <= 2 * cfg.motif_len:
                break
            src = rng.integers(0, L - 2 * cfg.motif_len)
            dst = rng.integers(src + cfg.motif_len, L - cfg.motif_len)
            toks[b, dst:dst + cfg.motif_len] = toks[b, src:src + cfg.motif_len]
    return toks.astype(np.int32)


def token_batches(cfg: TokenStreamConfig, *, host_id: int = 0,
                  num_hosts: int = 1):
    """Infinite iterator of per-host batches.

    Yields dict(tokens=(B_host, S), labels=(B_host, S)) — the global batch is
    striped across hosts; each host seeds independently so restarts are
    reproducible from (seed, host_id, step) without coordination.
    """
    assert cfg.global_batch % num_hosts == 0
    b_host = cfg.global_batch // num_hosts
    step = 0
    while True:
        rng = np.random.default_rng(
            (cfg.seed * 1_000_003 + step) * 4096 + host_id)
        toks = sample_tokens(cfg, rng, b_host)
        yield {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
        step += 1
