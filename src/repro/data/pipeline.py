"""Input pipeline: host-sharded batching, device placement, prefetch.

Small by design — the heavy lifting is in the generators (digits.py,
tokens.py); this module owns the *distribution* concerns:

  * global-batch → per-host striping (``host_shard``),
  * building globally-sharded ``jax.Array``s from per-host shards
    (``make_global_array``) so pjit sees one logical batch,
  * a background-thread prefetcher to overlap host data generation with
    device compute (the input-pipeline half of compute/comm overlap).
"""

from __future__ import annotations

import queue
import threading
from typing import Iterator

import jax
import numpy as np

__all__ = ["host_shard", "make_global_array", "prefetch", "digit_batches"]


def host_shard(array: np.ndarray, host_id: int, num_hosts: int) -> np.ndarray:
    """Contiguous stripe of the leading (batch) axis for this host."""
    n = array.shape[0]
    assert n % num_hosts == 0, (n, num_hosts)
    per = n // num_hosts
    return array[host_id * per:(host_id + 1) * per]


def make_global_array(local: np.ndarray, mesh: jax.sharding.Mesh,
                      pspec: jax.sharding.PartitionSpec) -> jax.Array:
    """Assemble a global jax.Array from this host's shard (multi-host safe)."""
    sharding = jax.sharding.NamedSharding(mesh, pspec)
    global_shape = (local.shape[0] * (jax.process_count()),) + local.shape[1:]
    if jax.process_count() == 1:
        return jax.device_put(local, sharding)
    return jax.make_array_from_process_local_data(sharding, local, global_shape)


def prefetch(it: Iterator, depth: int = 2) -> Iterator:
    """Background-thread prefetch: overlaps batch generation with compute."""
    q: queue.Queue = queue.Queue(maxsize=depth)
    _END = object()

    def worker():
        try:
            for item in it:
                q.put(item)
        finally:
            q.put(_END)

    t = threading.Thread(target=worker, daemon=True)
    t.start()
    while True:
        item = q.get()
        if item is _END:
            return
        yield item


def digit_batches(x: np.ndarray, y: np.ndarray, batch: int, seed: int = 0,
                  epochs: int | None = None) -> Iterator[dict]:
    """Shuffled epoch iterator over the digit dataset."""
    rng = np.random.default_rng(seed)
    n = x.shape[0]
    epoch = 0
    while epochs is None or epoch < epochs:
        perm = rng.permutation(n)
        for i in range(0, n - batch + 1, batch):
            idx = perm[i:i + batch]
            yield {"pixels": x[idx], "labels": y[idx]}
        epoch += 1
