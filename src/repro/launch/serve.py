"""Batched serving driver: prefill + decode with early-exit retirement.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-4b --requests 8 \
      --prompt-len 32 --gen 24
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from ..configs import get_reduced
from ..serve import generate, stability_gate
from .mesh import make_local_mesh
from ..distributed.sharding import make_rules, use_rules

__all__ = ["main"]


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-4b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=24)
    ap.add_argument("--patience", type=int, default=3)
    ap.add_argument("--reduced", action="store_true", default=True)
    args = ap.parse_args(argv)

    cfg = get_reduced(args.arch)
    from ..models import lm_init
    key = jax.random.PRNGKey(0)
    params = lm_init(key, cfg)
    prompts = {"tokens": jax.random.randint(
        key, (args.requests, args.prompt_len), 0, cfg.vocab_size)}
    if cfg.is_encdec:
        prompts["frames"] = np.full(
            (args.requests, cfg.encoder_seq, cfg.d_model), 0.02, np.float32)

    mesh = make_local_mesh()
    rules = make_rules(mesh, fsdp=False)
    with mesh, use_rules(rules):
        t0 = time.perf_counter()
        toks, active = generate(
            params, prompts, cfg, steps=args.gen,
            max_len=args.prompt_len + args.gen + 1,
            early_exit_fn=stability_gate(args.requests, args.patience))
        toks.block_until_ready()
        dt = time.perf_counter() - t0

    active = np.asarray(active)
    total_steps = active.sum()
    dense_steps = args.requests * args.gen
    print(f"generated {toks.shape} in {dt:.2f}s")
    print(f"active sequence-steps: {total_steps}/{dense_steps} "
          f"({100 * total_steps / dense_steps:.0f}% — early exit saved "
          f"{100 * (1 - total_steps / dense_steps):.0f}%)")
    print("per-step active:", active.tolist())


if __name__ == "__main__":
    main()
