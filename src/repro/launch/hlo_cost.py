"""Trip-count-aware cost model over compiled (partitioned) HLO text.

``compiled.cost_analysis()`` counts every while-loop body exactly ONCE,
which under-reports looped programs (microbatch scan × layer-block scan ×
q-chunk maps) by orders of magnitude.  This parser walks the HLO module,
recovers each loop's static trip count from its condition computation
(canonical ``compare(iv, constant N), direction=LT`` form emitted by
lax.scan/fori_loop/lax.map), and accumulates:

  * flops            — 2·R·K per dot (R = result elements, K = contracted
                       elements); elementwise ops ignored (dots dominate all
                       assigned workloads)
  * bytes            — operand + result bytes per *materialised*
                       instruction (fusion internals are free, the fusion
                       node itself is counted at its call site)
  * collective bytes — per op kind, max(result, operands) with a 2× ring
                       multiplier for all-reduce

each multiplied by the product of enclosing trip counts.  Everything is
per-device (the module is the SPMD-partitioned one).
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field

__all__ = ["hlo_cost", "HloCost"]

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "token": 0, "s4": 1, "u4": 1,
}

_SHAPE = r"(?:pred|bf16|f16|f32|f64|s4|s8|s16|s32|s64|u4|u8|u16|u32|u64|c64|c128|token)\[[\d,]*\]"
_SHAPE_RE = re.compile(r"(pred|bf16|f16|f32|f64|s4|s8|s16|s32|s64|u4|u8|u16|u32|u64|c64|c128|token)\[([\d,]*)\]")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(\(?.*?\)?)\s*([\w\-]+)\((.*)$")
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*(?:\(|\.v\d+\s*\()")
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")


def _shape_elems_bytes(text: str) -> tuple[float, float]:
    """Total (elements, bytes) over every shape literal in ``text``."""
    el = by = 0.0
    for dt, dims in _SHAPE_RE.findall(text):
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        el += n
        by += n * _DTYPE_BYTES[dt]
    return el, by


@dataclass
class _Instr:
    name: str
    op: str
    result: str       # result shape text
    rest: str         # full remainder of line (operands + attrs)
    is_root: bool = False


@dataclass
class _Comp:
    name: str
    instrs: list = field(default_factory=list)
    shapes: dict = field(default_factory=dict)   # instr name -> result text


@dataclass
class HloCost:
    flops: float = 0.0
    bytes: float = 0.0
    collectives: dict = field(default_factory=dict)

    @property
    def collective_total(self) -> float:
        return sum(self.collectives.values())


_BLOCK_COMMENT_RE = re.compile(r"/\*.*?\*/")


def _parse_computations(hlo: str) -> dict[str, _Comp]:
    comps: dict[str, _Comp] = {}
    cur: _Comp | None = None
    for raw in hlo.splitlines():
        line = _BLOCK_COMMENT_RE.sub("", raw.rstrip())
        s = line.strip()
        if not s or s.startswith("//"):
            continue
        if not line.startswith(" ") and ("{" in s) and ("=" not in s.split("{")[0]):
            m = _COMP_HDR_RE.match(s)
            if m:
                cur = _Comp(m.group(1))
                comps[cur.name] = cur
                continue
        if s == "}" or s.startswith("} "):
            continue
        if cur is None:
            continue
        m = _INSTR_RE.match(s)
        if m:
            name, result, op, rest = m.groups()
            ins = _Instr(name, op, result, rest)
            ins.is_root = s.lstrip().startswith("ROOT")
            cur.instrs.append(ins)
            cur.shapes[name] = result
    return comps


def _called(rest: str, attr: str) -> str | None:
    m = re.search(attr + r"=%?([\w.\-]+)", rest)
    return m.group(1) if m else None


def _trip_count(cond: _Comp, comps: dict) -> int:
    """Static trip count from a canonical LT-compare loop condition.

    Only the condition's ROOT compare (the value the while tests) is
    trusted — unrelated constants in the condition must not be mistaken
    for bounds.  lax.scan/fori_loop/lax.map all lower to
    ``ROOT compare(iv, constant N), direction=LT``.
    """
    const_by_name = {}
    for ins in cond.instrs:
        if ins.op == "constant":
            mm = re.search(r"constant\((\d+)\)", "constant(" + ins.rest)
            if mm:
                const_by_name[ins.name] = int(mm.group(1))

    def is_lt_compare(ins: _Instr) -> bool:
        if ins.op == "compare" and "direction=LT" in ins.rest:
            return True
        if ins.op == "fusion":           # ROOT wrapped_compare fusion
            callee = _called(ins.rest, "calls")
            if callee and callee in comps:
                return any(i.op == "compare" and "direction=LT" in i.rest
                           for i in comps[callee].instrs)
        return False

    compares = [i for i in cond.instrs if is_lt_compare(i)]
    roots = [i for i in compares if i.is_root]
    for ins in roots or compares:
        for nm, val in const_by_name.items():
            if re.search(r"%?" + re.escape(nm) + r"\b", ins.rest):
                return max(val, 1)
    return 1


def _dot_flops(ins: _Instr, comp: _Comp) -> float:
    r_el, _ = _shape_elems_bytes(ins.result)
    # contraction size from lhs shape and lhs_contracting_dims
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", ins.rest)
    ops = re.findall(r"%([\w.\-]+)", ins.rest.split("),")[0] + ")")
    if not m or not ops:
        return 2.0 * r_el
    lhs_shape = comp.shapes.get(ops[0], "")
    sm = _SHAPE_RE.search(lhs_shape)
    if not sm:
        return 2.0 * r_el
    dims = [int(d) for d in sm.group(2).split(",") if d]
    k = 1
    for i in m.group(1).split(","):
        if i != "" and int(i) < len(dims):
            k *= dims[int(i)]
    return 2.0 * r_el * k


def _operand_bytes(ins: _Instr, comp: _Comp) -> float:
    total = 0.0
    arglist = ins.rest.split("),")[0]
    for nm in re.findall(r"%([\w.\-]+)", arglist):
        if nm in comp.shapes:
            total += _shape_elems_bytes(comp.shapes[nm])[1]
    return total


def _io_bytes(ins: _Instr, comp: _Comp, *, dus_root: bool = False) -> float:
    """HBM traffic estimate for one materialised instruction.

    In-place credit: dynamic-update-slice (and fusions rooted in one, the
    canonical scan write-back) updates a slice of a buffer XLA aliases in
    place — traffic is the slice, not the whole buffer.  Generally, when
    one operand matches the result size exactly (accumulator patterns),
    that operand is treated as aliased and counted once.
    """
    res = _shape_elems_bytes(ins.result)[1]
    arglist = ins.rest.split("),")[0]
    ops = [_shape_elems_bytes(comp.shapes[nm])[1]
           for nm in re.findall(r"%([\w.\-]+)", arglist)
           if nm in comp.shapes]
    if ins.op == "dynamic-slice":
        return 2.0 * res                       # read slice + write result
    if ins.op == "dynamic-update-slice" or dus_root:
        small = sum(b for b in ops if b < res) or res * 0.01
        return 2.0 * small                     # slice read + slice write
    total = res + sum(ops)
    if res in ops:                             # in-place accumulator credit
        total -= res
    return total


def _fusion_root_is_dus(ins: _Instr, comps: dict) -> bool:
    callee = _called(ins.rest, "calls")
    if callee and callee in comps:
        for i in comps[callee].instrs:
            if i.is_root:
                return i.op == "dynamic-update-slice"
    return False


def _fusion_io_bytes(ins: _Instr, comp: _Comp, comps: dict) -> float:
    """Fusion HBM traffic with slice-awareness.

    An operand that is only dynamic-sliced inside the fused computation
    (the canonical scan-xs read: gte(stacked params) -> dynamic-slice ->
    convert) contributes the SLICE bytes, not the whole stacked buffer.
    """
    if _fusion_root_is_dus(ins, comps):
        return _io_bytes(ins, comp, dus_root=True)
    res = _shape_elems_bytes(ins.result)[1]
    callee = comps.get(_called(ins.rest, "calls") or "")
    arglist = ins.rest.split("),")[0]
    op_names = [nm for nm in re.findall(r"%([\w.\-]+)", arglist)
                if nm in comp.shapes]
    total = res
    for pos, nm in enumerate(op_names):
        full = _shape_elems_bytes(comp.shapes[nm])[1]
        eff = full
        if callee is not None:
            # find the callee parameter with this position; if its only
            # consumer is a dynamic-slice, charge the slice size
            pname = None
            for i in callee.instrs:
                if i.op == "parameter" and i.rest.startswith(f"{pos})"):
                    pname = i.name
                    break
            if pname is not None:
                uses = [i for i in callee.instrs
                        if re.search(r"%" + re.escape(pname) + r"\b",
                                     i.rest) and i.op != "parameter"]
                if uses and all(u.op == "dynamic-slice" for u in uses):
                    eff = sum(_shape_elems_bytes(u.result)[1] for u in uses)
        if eff == res and full == res:
            eff = 0.0                      # in-place accumulator credit
        total += eff
    return total


def _comp_cost(comp: _Comp, comps: dict, cache: dict, *,
               fused: bool = False, _stack: frozenset = frozenset()) -> HloCost:
    if comp.name in cache:
        return cache[comp.name]
    if comp.name in _stack:      # defensive: malformed/cyclic call graph
        return HloCost(collectives={k: 0.0 for k in _COLLECTIVES})
    _stack = _stack | {comp.name}
    out = HloCost(collectives={k: 0.0 for k in _COLLECTIVES})
    for ins in comp.instrs:
        if ins.op == "dot":
            out.flops += _dot_flops(ins, comp)
            if not fused:
                out.bytes += _io_bytes(ins, comp)
        elif ins.op == "fusion":
            callee = _called(ins.rest, "calls")
            if callee and callee in comps:
                sub = _comp_cost(comps[callee], comps, cache, fused=True, _stack=_stack)
                out.flops += sub.flops
                for k, v in sub.collectives.items():
                    out.collectives[k] += v
            out.bytes += _fusion_io_bytes(ins, comp, comps)
        elif ins.op == "while":
            body = _called(ins.rest, "body")
            cond = _called(ins.rest, "condition")
            trips = _trip_count(comps[cond], comps) if cond in comps else 1
            sub = _comp_cost(comps[body], comps, cache, _stack=_stack) if body in comps \
                else HloCost(collectives={k: 0.0 for k in _COLLECTIVES})
            out.flops += trips * sub.flops
            out.bytes += trips * sub.bytes
            for k, v in sub.collectives.items():
                out.collectives[k] += trips * v
        elif ins.op in ("call", "custom-call"):
            callee = _called(ins.rest, "to_apply")
            if callee and callee in comps:
                sub = _comp_cost(comps[callee], comps, cache, _stack=_stack)
                out.flops += sub.flops
                out.bytes += sub.bytes
                for k, v in sub.collectives.items():
                    out.collectives[k] += v
        elif ins.op.rstrip("-start") in _COLLECTIVES or \
                ins.op in _COLLECTIVES or ins.op.endswith("-start") and \
                ins.op[:-6] in _COLLECTIVES:
            kind = ins.op[:-6] if ins.op.endswith("-start") else ins.op
            if kind in _COLLECTIVES:
                res_b = _shape_elems_bytes(ins.result)[1]
                op_b = _operand_bytes(ins, comp)
                b = max(res_b, op_b)
                if kind == "all-reduce":
                    b *= 2.0
                out.collectives[kind] += b
                if not fused:
                    out.bytes += res_b + op_b
        else:
            if not fused and ins.op not in (
                    "parameter", "constant", "get-tuple-element", "tuple",
                    "bitcast", "after-all"):
                out.bytes += _io_bytes(ins, comp)
    cache[comp.name] = out
    return out


def _entry_name(hlo: str) -> str | None:
    m = re.search(r"^ENTRY\s+%?([\w.\-]+)", hlo, re.M)
    return m.group(1) if m else None


def hlo_cost(hlo_text: str) -> HloCost:
    import sys
    if sys.getrecursionlimit() < 20000:
        sys.setrecursionlimit(20000)
    comps = _parse_computations(hlo_text)
    entry = _entry_name(hlo_text)
    if entry is None or entry not in comps:
        # fall back: the computation with the most instructions
        entry = max(comps, key=lambda k: len(comps[k].instrs))
    return _comp_cost(comps[entry], comps, {})


def top_costs(hlo_text: str, k: int = 20):
    """Profiling view: top instructions by trip-multiplied bytes and by
    flops, with (multiplier, computation, op, metadata op_name) — the
    'profile' the §Perf hypothesis loop reads."""
    import sys
    if sys.getrecursionlimit() < 20000:
        sys.setrecursionlimit(20000)
    comps = _parse_computations(hlo_text)
    entry = _entry_name(hlo_text)
    if entry is None or entry not in comps:
        entry = max(comps, key=lambda kk: len(comps[kk].instrs))

    rows = []

    def walk(comp: _Comp, mult: float, stack: frozenset):
        if comp.name in stack:
            return
        stack = stack | {comp.name}
        for ins in comp.instrs:
            if ins.op == "fusion":
                callee = _called(ins.rest, "calls")
                b = _fusion_io_bytes(ins, comp, comps)
                fl = 0.0
                if callee and callee in comps:
                    sub = _comp_cost(comps[callee], comps, {}, fused=True)
                    fl = sub.flops
                rows.append((b * mult, fl * mult, mult, comp.name, ins))
            elif ins.op == "while":
                body = _called(ins.rest, "body")
                cond = _called(ins.rest, "condition")
                trips = _trip_count(comps[cond], comps) if cond in comps else 1
                if body in comps:
                    walk(comps[body], mult * trips, stack)
            elif ins.op in ("call", "custom-call"):
                callee = _called(ins.rest, "to_apply")
                if callee and callee in comps:
                    walk(comps[callee], mult, stack)
            elif ins.op == "dot":
                rows.append((_io_bytes(ins, comp) * mult,
                             _dot_flops(ins, comp) * mult, mult,
                             comp.name, ins))
            elif ins.op.replace("-start", "") in _COLLECTIVES:
                b = max(_shape_elems_bytes(ins.result)[1],
                        _operand_bytes(ins, comp))
                rows.append((b * mult, 0.0, mult, comp.name, ins))
            elif ins.op not in ("parameter", "constant",
                                "get-tuple-element", "tuple", "bitcast",
                                "after-all"):
                rows.append((_io_bytes(ins, comp) * mult, 0.0, mult,
                             comp.name, ins))

    walk(comps[entry], 1.0, frozenset())

    def fmt(r):
        b, fl, mult, cname, ins = r
        meta = re.search(r'op_name="([^"]*)"', ins.rest)
        return {"bytes": b, "flops": fl, "mult": mult, "op": ins.op,
                "comp": cname, "name": ins.name,
                "shape": ins.result[:60],
                "op_name": (meta.group(1)[:90] if meta else "")}

    by_bytes = [fmt(r) for r in sorted(rows, key=lambda r: -r[0])[:k]]
    by_flops = [fmt(r) for r in sorted(rows, key=lambda r: -r[1])[:k]]
    colls = [r for r in rows if r[4].op.replace("-start", "") in _COLLECTIVES]
    by_coll = [fmt(r) for r in sorted(colls, key=lambda r: -r[0])[:k]]
    return {"by_bytes": by_bytes, "by_flops": by_flops,
            "by_collective": by_coll}
