"""Production meshes.

Defined as FUNCTIONS (never module-level constants) so importing this
module touches no jax device state — required because the dry-run must set
XLA_FLAGS before the first jax initialisation.

Target hardware: TPU v5e pods, 256 chips/pod, 16×16 ICI torus.
  single-pod:  (16, 16)       axes ("data", "model")
  multi-pod:   (2, 16, 16)    axes ("pod", "data", "model") — "pod" is pure
               data parallel over the inter-pod (DCN/DCI) links; gradient
               reduction over it optionally runs int8 error-feedback
               compression (optim/compression.py).
"""

from __future__ import annotations

import jax

from ..distributed.sharding import make_device_mesh

__all__ = ["make_production_mesh", "make_local_mesh", "mesh_axis_sizes"]


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_device_mesh(shape, axes)


def make_local_mesh():
    """Whatever devices exist, as a 1×N ("data","model") mesh (tests/CPU)."""
    n = len(jax.devices())
    return make_device_mesh((1, n), ("data", "model"))


def mesh_axis_sizes(mesh) -> dict:
    return dict(zip(mesh.axis_names, mesh.devices.shape))
