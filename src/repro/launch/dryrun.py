import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", ""))

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each live cell this builds the REAL jitted step (train_step including
the optimizer update for train shapes; prefill / decode_step for serving
shapes) with production in/out shardings, lowers it against
ShapeDtypeStruct inputs (no allocation), compiles it, and records:

  * memory_analysis  — per-device argument/output/temp bytes (proves fit),
  * cost_analysis    — per-device HLO FLOPs and bytes accessed,
  * collective bytes — parsed from the partitioned HLO, per collective op
    kind (all-gather / all-reduce / reduce-scatter / all-to-all /
    collective-permute), with ring-traffic multipliers,

into one JSON per cell under --out.  benchmarks/roofline.py consumes these.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun                 # everything
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-4b --shape train_4k --mesh multi
"""

import argparse
import json
import re
import sys
import time

import jax
import jax.numpy as jnp

from ..configs import SHAPES, cell_is_live, get_config, list_archs
from ..distributed.partition import (batch_specs, cache_specs, param_specs,
                                     to_shardings, train_state_specs)
from ..distributed.sharding import make_rules, use_rules
from ..serve.engine import ServeState, make_decode_step, make_prefill
from ..train.step import TrainSettings, init_state, make_train_step
from .mesh import make_production_mesh
from .specs import (abstract_params, decode_state_spec, num_microbatches,
                    prefill_inputs, train_inputs)

__all__ = ["run_cell", "main"]

_DTYPE_BYTES = {
    "pred": 0.125, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2,
    "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(pred|bf16|f16|f32|f64|s8|s16|s32|s64|u8|u16|u32|u64|c64|c128)\[([\d,]*)\]")
_COLL_RE = re.compile(
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")


def _shape_bytes(m: re.Match) -> float:
    dt, dims = m.groups()
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES[dt]


def collective_bytes(hlo_text: str) -> dict:
    """Per-device collective traffic by op kind, from partitioned HLO.

    Convention: bytes = max(result bytes, operand bytes) per op — covers
    both all-gather (result is the big side) and reduce-scatter (operand
    is); all-reduce counts 2× (ring reduce-scatter + all-gather).
    """
    out = {k: 0.0 for k in ("all-gather", "all-reduce", "reduce-scatter",
                            "all-to-all", "collective-permute")}
    counts = dict.fromkeys(out, 0)
    for line in hlo_text.splitlines():
        mo = _COLL_RE.search(line)
        if not mo or "-done" in line.split("=")[0]:
            continue
        kind = mo.group(1)
        shapes = _SHAPE_RE.findall(line)
        if not shapes:
            continue
        head = line.split(mo.group(0))[0]
        res = sum(_shape_bytes(m) for m in _SHAPE_RE.finditer(head))
        total = sum(_shape_bytes(m) for m in _SHAPE_RE.finditer(line))
        opnd = total - res
        b = max(res, opnd)
        if kind == "all-reduce":
            b *= 2.0
        out[kind] += b
        counts[kind] += 1
    out["total"] = sum(out.values())
    out["counts"] = counts
    return out


def _bf16_params(params_sds):
    def one(l):
        dt = jnp.bfloat16 if jnp.issubdtype(l.dtype, jnp.floating) else l.dtype
        return jax.ShapeDtypeStruct(l.shape, dt)
    return jax.tree.map(one, params_sds)


def build_cell(arch: str, shape_name: str, mesh, rules):
    """Returns (jitted, example_args) for the cell — not yet lowered."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    data_ways = 1
    for ax in ("pod", "data"):
        if ax in mesh.axis_names:
            data_ways *= mesh.shape[ax]

    if shape.kind == "train":
        nm = num_microbatches(cfg, shape, data_ways)
        accum = "bfloat16" if cfg.param_count() > 150e9 else "float32"
        settings = TrainSettings(num_microbatches=nm, accum_dtype=accum,
                                 cast_params="bfloat16")
        state_sds = jax.eval_shape(
            lambda k: init_state(k, cfg, settings),
            jax.ShapeDtypeStruct((2,), jnp.uint32))
        batch_sds = train_inputs(cfg, shape)
        st_specs = train_state_specs(cfg, cfg.optimizer, state_sds)
        st_sh = to_shardings(mesh, rules, st_specs, state_sds)
        b_sh = to_shardings(mesh, rules, batch_specs(batch_sds), batch_sds)
        step = make_train_step(cfg, settings,
                               grad_shardings=st_sh.params)
        jitted = jax.jit(step, in_shardings=(st_sh, b_sh),
                         out_shardings=(st_sh, None), donate_argnums=(0,))
        return jitted, (state_sds, batch_sds), {"num_microbatches": nm}

    params_sds = _bf16_params(abstract_params(cfg))
    p_specs = param_specs(cfg, params_sds)
    p_sh = to_shardings(mesh, rules, p_specs, params_sds)

    if shape.kind == "prefill":
        batch_sds = prefill_inputs(cfg, shape)
        b_sh = to_shardings(mesh, rules, batch_specs(batch_sds), batch_sds)
        prefill = make_prefill(cfg, max_len=shape.seq_len)
        jitted = jax.jit(prefill, in_shardings=(p_sh, b_sh))
        return jitted, (params_sds, batch_sds), {}

    # decode
    state_sds = decode_state_spec(cfg, shape)
    c_specs = cache_specs(cfg, state_sds.cache, decode=True)
    vec = ("batch",)
    st_specs = ServeState(cache=c_specs, cur_len=vec, last_token=vec,
                          done=vec)
    st_sh = to_shardings(mesh, rules, st_specs, state_sds)
    decode = make_decode_step(cfg)
    jitted = jax.jit(decode, in_shardings=(p_sh, st_sh),
                     out_shardings=(st_sh, None), donate_argnums=(1,))
    return jitted, (params_sds, state_sds), {}


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             *, save_hlo: str | None = None, hlo_dir: str | None = None,
             sequence_parallel: bool | None = None) -> dict:
    mesh = make_production_mesh(multi_pod=multi_pod)
    # SP shards the residual stream over "model" between layer blocks —
    # it divides the remat-saved activation stash by the TP degree
    # (Megatron-SP), which is what lets the ≥100B training cells fit.
    if sequence_parallel is None:
        sequence_parallel = SHAPES[shape_name].kind == "train"
    # Serving: keep params TP-sharded but REPLICATED over data when the
    # bf16 copy fits (≤4 GiB/chip) — removes the per-block FSDP all-gather
    # from every decode step (§Perf: was the dominant collective on small/
    # mid archs). Giants keep ZeRO-inference gathers.
    fsdp = True
    if SHAPES[shape_name].kind != "train":
        cfg_ = get_config(arch)
        tp = mesh.shape.get("model", 1)
        fsdp = cfg_.param_count() * 2 / tp > 4e9
    rules = make_rules(mesh, fsdp=fsdp, sequence_parallel=sequence_parallel)
    t0 = time.time()
    with mesh, use_rules(rules):
        jitted, args, extra = build_cell(arch, shape_name, mesh, rules)
        lowered = jitted.lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

        mem = compiled.memory_analysis()
        ca = compiled.cost_analysis()
        ca = ca[0] if isinstance(ca, list) else ca
        hlo = compiled.as_text()
        coll = collective_bytes(hlo)
        # trip-count-aware per-device cost (XLA's cost_analysis counts
        # while bodies once; hlo_cost multiplies by static trip counts)
        from .hlo_cost import hlo_cost
        hc = hlo_cost(hlo)
        if save_hlo:
            with open(save_hlo, "w") as f:
                f.write(hlo)
        if hlo_dir:
            import gzip
            os.makedirs(hlo_dir, exist_ok=True)
            tag = f"{arch}.{shape_name}.{'multi' if multi_pod else 'single'}"
            with gzip.open(os.path.join(hlo_dir, tag + ".hlo.gz"), "wt") as f:
                f.write(hlo)

    n_dev = mesh.devices.size
    rec = {
        "arch": arch, "shape": shape_name,
        "mesh": "multi" if multi_pod else "single",
        "devices": n_dev,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "peak_bytes": (mem.argument_size_in_bytes
                           + mem.output_size_in_bytes
                           + mem.temp_size_in_bytes
                           - mem.alias_size_in_bytes),
        },
        "cost": {
            # raw XLA numbers (loop bodies counted once — underestimates)
            "xla_flops_per_device": ca.get("flops", 0.0),
            "xla_bytes_per_device": ca.get("bytes accessed", 0.0),
            # trip-count-corrected (the numbers the roofline uses)
            "flops_per_device": hc.flops,
            "bytes_per_device": hc.bytes,
        },
        "collectives_per_device": dict(hc.collectives,
                                       total=hc.collective_total),
        "collectives_body_once": coll,
        **extra,
    }
    return rec


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="both")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--save-hlo", default=None,
                    help="dump partitioned HLO text to this path")
    ap.add_argument("--hlo-dir", default="results/hlo",
                    help="archive gzipped partitioned HLO per cell (enables "
                         "offline re-costing without recompiling)")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args(argv)

    archs = [args.arch] if args.arch else \
        [a for a in list_archs() if get_config(a).family != "snn"]
    shapes = [args.shape] if args.shape else list(SHAPES)
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    os.makedirs(args.out, exist_ok=True)
    failures = []
    for arch in archs:
        for shape in shapes:
            if not cell_is_live(arch, shape):
                print(f"SKIP  {arch} × {shape} (long-context n/a, DESIGN §7)")
                continue
            for mp in meshes:
                tag = f"{arch}.{shape}.{'multi' if mp else 'single'}"
                path = os.path.join(args.out, tag + ".json")
                if os.path.exists(path) and not args.force:
                    print(f"CACHED {tag}")
                    continue
                try:
                    rec = run_cell(arch, shape, mp, save_hlo=args.save_hlo,
                                   hlo_dir=args.hlo_dir)
                    with open(path, "w") as f:
                        json.dump(rec, f, indent=1)
                    gb = rec["memory"]["peak_bytes"] / 2**30
                    print(f"OK    {tag}: peak {gb:.2f} GiB/dev, "
                          f"{rec['cost']['flops_per_device']:.3g} flops/dev, "
                          f"compile {rec['compile_s']}s", flush=True)
                except Exception as e:  # noqa: BLE001 — report & continue
                    failures.append((tag, repr(e)))
                    print(f"FAIL  {tag}: {e!r}", flush=True)
    if failures:
        print(f"\n{len(failures)} failures:")
        for t, e in failures:
            print(" ", t, e[:200])
        sys.exit(1)
    print("\nall requested cells compiled")


if __name__ == "__main__":
    main()
