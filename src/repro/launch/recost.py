"""Offline re-costing: recompute the cost fields of dry-run JSONs from the
archived gzipped HLO (results/hlo/) without recompiling anything.

  PYTHONPATH=src python -m repro.launch.recost --out results/dryrun --hlo results/hlo
"""

from __future__ import annotations

import argparse
import glob
import gzip
import json
import os

from .hlo_cost import hlo_cost


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--hlo", default="results/hlo")
    args = ap.parse_args(argv)

    n = 0
    for jpath in sorted(glob.glob(os.path.join(args.out, "*.json"))):
        tag = os.path.basename(jpath)[:-5]
        hpath = os.path.join(args.hlo, tag + ".hlo.gz")
        if not os.path.exists(hpath):
            print(f"no HLO for {tag}; skip")
            continue
        with gzip.open(hpath, "rt") as f:
            hc = hlo_cost(f.read())
        rec = json.load(open(jpath))
        rec["cost"]["flops_per_device"] = hc.flops
        rec["cost"]["bytes_per_device"] = hc.bytes
        rec["collectives_per_device"] = dict(hc.collectives,
                                             total=hc.collective_total)
        with open(jpath, "w") as f:
            json.dump(rec, f, indent=1)
        n += 1
        print(f"recosted {tag}: flops/dev={hc.flops:.3g} "
              f"coll/dev={hc.collective_total:.3g}")
    print(f"{n} cells recosted")


if __name__ == "__main__":
    main()
