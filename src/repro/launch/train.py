"""End-to-end training driver.

Runs REAL steps (CPU-sized configs by default — reduced variants of the
assigned archs, or the paper's SNN via examples/train_snn_mnist.py) with
the production machinery: sharded train_step, checkpointing, straggler
detection, resume.

  PYTHONPATH=src python -m repro.launch.train --arch qwen3-4b --steps 50 \
      --reduced --batch 8 --seq 64 --ckpt-dir /tmp/run1
"""

from __future__ import annotations

import argparse

import jax
import numpy as np

from ..checkpoint import CheckpointManager, latest_step
from ..configs import get_config, get_reduced
from ..data import tokens as tok
from ..data.pipeline import prefetch
from ..distributed.partition import to_shardings, train_state_specs
from ..distributed.sharding import make_rules, use_rules
from ..train import (StragglerDetector, TrainLoop, TrainSettings, init_state,
                     make_train_step)
from .mesh import make_local_mesh

__all__ = ["main", "train"]


def make_batches(cfg, batch: int, seq: int, seed: int = 0):
    stream = tok.TokenStreamConfig(vocab_size=cfg.vocab_size, seq_len=seq,
                                   global_batch=batch, seed=seed)
    for b in prefetch(tok.token_batches(stream)):
        out = {"tokens": b["tokens"], "labels": b["labels"]}
        if cfg.frontend == "vision":
            p = min(cfg.num_patches, seq // 2)
            out["patches"] = np.full((batch, p, cfg.d_model), 0.02, np.float32)
            out["tokens"] = out["tokens"][:, : seq - p]
        if cfg.is_encdec:
            out["frames"] = np.full((batch, cfg.encoder_seq, cfg.d_model),
                                    0.02, np.float32)
        yield out


def train(arch: str, *, steps: int = 50, batch: int = 8, seq: int = 64,
          reduced: bool = True, ckpt_dir: str | None = None,
          ckpt_every: int = 20, lr: float = 1e-3, microbatches: int = 1,
          metrics_hook=None):
    cfg = get_reduced(arch) if reduced else get_config(arch)
    settings = TrainSettings(learning_rate=lr, warmup_steps=max(steps // 10, 1),
                             total_steps=steps, num_microbatches=microbatches)

    mesh = make_local_mesh()
    rules = make_rules(mesh, fsdp=True)
    with mesh, use_rules(rules):
        state = init_state(jax.random.PRNGKey(0), cfg, settings)
        st_specs = train_state_specs(cfg, cfg.optimizer, state)
        st_sh = to_shardings(mesh, rules, st_specs, state)
        step = jax.jit(make_train_step(cfg, settings),
                       in_shardings=(st_sh, None), out_shardings=(st_sh, None),
                       donate_argnums=(0,))

        mgr = None
        if ckpt_dir:
            mgr = CheckpointManager(ckpt_dir)
            if latest_step(ckpt_dir) is not None:
                state, at = mgr.restore(state)
                print(f"resumed from step {at}")

        loop = TrainLoop(step, state, ckpt_manager=mgr,
                         ckpt_every=ckpt_every,
                         detector=StragglerDetector(),
                         metrics_hook=metrics_hook)
        final = loop.run(make_batches(cfg, batch, seq), steps)
    return final, loop.history


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-4b")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    args = ap.parse_args(argv)

    def hook(rec):
        if rec["step"] % 10 == 0 or rec["step"] <= 2:
            print(f"step {rec['step']:5d}  loss {rec['loss']:.4f}  "
                  f"acc {rec['acc']:.3f}  {rec['wall_s']*1e3:.0f} ms"
                  + ("  [straggler]" if rec["straggler"] else ""))

    _, hist = train(args.arch, steps=args.steps, batch=args.batch,
                    seq=args.seq, reduced=args.reduced,
                    ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every,
                    lr=args.lr, microbatches=args.microbatches,
                    metrics_hook=hook)
    print(f"final loss {hist[-1]['loss']:.4f}  "
          f"(first {hist[0]['loss']:.4f})")


if __name__ == "__main__":
    main()
