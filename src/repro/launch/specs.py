"""Abstract input/state specs for every (arch × shape) dry-run cell.

``input_specs(cfg, shape)`` returns ShapeDtypeStruct stand-ins for every
model input — weak-type-correct, shardable, zero device allocation.  The
modality frontends are stubs per the assignment: audio/vision cells receive
precomputed frame/patch embeddings.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig, ShapeConfig
from ..models.transformer import init_cache
from ..serve.engine import ServeState

__all__ = ["train_inputs", "prefill_inputs", "decode_state_spec",
           "abstract_params", "num_microbatches"]


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def _token_inputs(cfg: ArchConfig, b: int, s: int, *, labels: bool) -> dict:
    d: dict = {}
    if cfg.frontend == "vision":
        p = min(cfg.num_patches, s - 1)
        d["patches"] = _sds((b, p, cfg.d_model), jnp.bfloat16)
        d["tokens"] = _sds((b, s - p), jnp.int32)
        if labels:
            d["labels"] = _sds((b, s), jnp.int32)
        return d
    if cfg.is_encdec:
        d["frames"] = _sds((b, cfg.encoder_seq, cfg.d_model), jnp.bfloat16)
    d["tokens"] = _sds((b, s), jnp.int32)
    if labels:
        d["labels"] = _sds((b, s), jnp.int32)
    return d


def train_inputs(cfg: ArchConfig, shape: ShapeConfig) -> dict:
    return _token_inputs(cfg, shape.global_batch, shape.seq_len, labels=True)


def prefill_inputs(cfg: ArchConfig, shape: ShapeConfig) -> dict:
    return _token_inputs(cfg, shape.global_batch, shape.seq_len, labels=False)


def decode_state_spec(cfg: ArchConfig, shape: ShapeConfig) -> ServeState:
    """Abstract ServeState with a max_len = shape.seq_len cache."""
    b, s = shape.global_batch, shape.seq_len
    cache = jax.eval_shape(
        lambda: init_cache(cfg, b, s, dtype=jnp.bfloat16))
    return ServeState(
        cache=cache,
        cur_len=_sds((b,), jnp.int32),
        last_token=_sds((b,), jnp.int32),
        done=_sds((b,), jnp.bool_),
    )


def abstract_params(cfg: ArchConfig):
    from ..models.transformer import lm_init
    key = jax.eval_shape(lambda: jax.random.PRNGKey(0))
    return jax.eval_shape(lambda k: lm_init(k, cfg),
                          jax.ShapeDtypeStruct((2,), jnp.uint32))


def num_microbatches(cfg: ArchConfig, shape: ShapeConfig,
                     data_ways: int) -> int:
    """Grad-accum depth: targets ≈1-4 sequences per data shard/microbatch."""
    per_shard = max(shape.global_batch // data_ways, 1)
    n = cfg.param_count()
    # §Perf iteration: per_mb 1→2 for ≥150B halves the number of FSDP
    # parameter regathers (the dominant collective) at ~2× activation
    # stash, which SP keeps affordable.
    if n > 150e9:
        per_mb = 2
    elif n > 20e9:
        per_mb = 2
    else:
        per_mb = 4
    nm = max(per_shard // per_mb, 1)
    while shape.global_batch % (nm * data_ways) and nm > 1:
        nm -= 1
    return nm
