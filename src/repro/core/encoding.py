"""Poisson spike encoding (paper §III-C, Fig. 2).

Static images carry no temporal structure, so the RTL converts pixel
intensity into firing *rate*: at every timestep, each pixel's PRNG lane draws
an 8-bit value R and emits a spike iff ``I > R``.  Brighter pixel ⇒ higher
spike probability ⇒ denser spike train.  P(spike) = I/256 exactly (for the
idealised uniform R); with the xorshift lanes it is I/256 up to PRNG bias.

Two encoder variants:

* :func:`poisson_encode_hw` — bit-exact model of the hardware: per-pixel
  xorshift32 lanes, top-byte comparison.  Use for RTL-equivalence tests and
  inference benchmarking.
* :func:`poisson_encode_jax` — same distribution but driven by
  ``jax.random`` (cheap to split per batch/step); used during surrogate
  gradient training where PRNG bit-compatibility is irrelevant.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import prng

__all__ = [
    "poisson_encode_hw",
    "poisson_encode_jax",
    "spike_train_rates",
]


def poisson_encode_hw(pixels_u8: jax.Array, state: jax.Array, num_steps: int):
    """Hardware-faithful Poisson encoding.

    Args:
      pixels_u8: uint8 intensities, any shape ``(...,)`` (normalised 0..255).
      state: uint32 xorshift state, same shape as ``pixels_u8``.
      num_steps: number of timesteps T.

    Returns:
      (spikes, final_state): ``spikes`` is bool ``(T, ...)``; state for
      continuation (the RTL free-runs its PRNG between images).
    """
    if pixels_u8.dtype != jnp.uint8:
        raise TypeError(f"pixels must be uint8, got {pixels_u8.dtype}")

    def body(s, _):
        s = prng.xorshift32_step(s)
        r = prng.uniform_u8(s)
        spike = pixels_u8 > r
        return s, spike

    final_state, spikes = jax.lax.scan(body, state, None, length=num_steps)
    return spikes, final_state


def poisson_encode_jax(pixels01: jax.Array, key: jax.Array, num_steps: int) -> jax.Array:
    """Training-path Poisson encoding from float intensities in [0, 1].

    Returns float spikes ``(T, ...)`` in {0.0, 1.0} (float so the surrogate
    gradient machinery can treat them as activations).
    """
    u = jax.random.uniform(key, (num_steps,) + pixels01.shape)
    return (pixels01[None] > u).astype(jnp.float32)


def spike_train_rates(spikes: jax.Array) -> jax.Array:
    """Empirical firing rate per lane: mean over the time axis (axis 0)."""
    return jnp.mean(spikes.astype(jnp.float32), axis=0)
