"""Operation counting and energy modelling (paper §V, Table II).

The paper's efficiency claim is op-structural: the SNN executes *zero*
multiplications and a spike-sparsity-dependent number of integer additions,
versus the dense ANN's fixed 784×10 MAC grid.  Since dynamic power is not
observable on TPU, we reproduce the claim the way the paper itself argues it:
count the operations each datapath executes and convert with published
per-op energy costs (Horowitz, ISSCC 2014, 45 nm — the standard reference
for this style of accounting).

Also extended (framework feature) to MoE models, where "active expert
FLOPs / total expert FLOPs" plays the role of spike sparsity.
"""

from __future__ import annotations

from dataclasses import dataclass

import jax.numpy as jnp
import numpy as np

__all__ = [
    "EnergyModel",
    "OpCounts",
    "ann_op_counts",
    "snn_op_counts",
    "snn_memory_bytes",
    "ann_memory_bytes",
]

# Horowitz ISSCC'14 (45 nm, pJ). int8 add 0.03, int32 add 0.1, int8 mult 0.2,
# fp32 add 0.9, fp32 mult 3.7.
_PJ = {
    "int8_add": 0.03,
    "int32_add": 0.1,
    "int8_mult": 0.2,
    "fp32_add": 0.9,
    "fp32_mult": 3.7,
    "shift": 0.01,       # barrel shifter, below an int8 add
    "compare": 0.03,     # magnitude comparator ≈ int add
}


@dataclass(frozen=True)
class OpCounts:
    multiplications: int
    additions: int
    shifts: int = 0
    comparisons: int = 0

    def energy_pj(self, mult_kind: str, add_kind: str) -> float:
        return (self.multiplications * _PJ[mult_kind]
                + self.additions * _PJ[add_kind]
                + self.shifts * _PJ["shift"]
                + self.comparisons * _PJ["compare"])


@dataclass(frozen=True)
class EnergyModel:
    """Bundles per-inference op counts into the paper's comparison table."""

    ann: OpCounts
    snn: OpCounts

    @property
    def ann_energy_pj(self) -> float:
        return self.ann.energy_pj("fp32_mult", "fp32_add")

    @property
    def snn_energy_pj(self) -> float:
        # SNN adds are int32 accumulator adds; no multiplies by construction.
        return self.snn.energy_pj("int8_mult", "int32_add")

    @property
    def energy_ratio(self) -> float:
        return self.ann_energy_pj / max(self.snn_energy_pj, 1e-12)


def ann_op_counts(n_in: int = 784, n_out: int = 10,
                  hidden: tuple[int, ...] = (32,)) -> OpCounts:
    """Dense MLP baseline: one MAC per weight + one add per bias.

    The paper's quoted numbers decode exactly to a 784→32→10 MLP:
    25,408 mults = 784·32 + 32·10 and 25,450 adds = 25,408 + 42 biases.
    """
    sizes = (n_in,) + tuple(hidden) + (n_out,)
    mults = sum(a * b for a, b in zip(sizes[:-1], sizes[1:]))
    biases = sum(sizes[1:])
    return OpCounts(multiplications=mults, additions=mults + biases,
                    comparisons=n_out)


def snn_op_counts(active_adds_per_step: np.ndarray | jnp.ndarray,
                  n_neurons: int = 10, num_steps: int | None = None,
                  enabled_per_step: np.ndarray | None = None) -> OpCounts:
    """SNN op count from the integer engine's measured event stream.

    ``active_adds_per_step``: (T,) or (T, batch) — executed synaptic adds
    (spikes × enabled targets), as returned by ``run_lif_int``.
    Each enabled neuron also performs one shift (leak) and one comparison
    (threshold) per step.
    """
    a = np.asarray(active_adds_per_step)
    if a.ndim > 1:
        a = a.mean(axis=tuple(range(1, a.ndim)))  # mean over batch
    T = num_steps if num_steps is not None else a.shape[0]
    adds = float(a.sum())
    if enabled_per_step is not None:
        en = float(np.asarray(enabled_per_step).sum())
    else:
        en = float(T * n_neurons)
    return OpCounts(multiplications=0, additions=int(round(adds)),
                    shifts=int(en), comparisons=int(en))


def snn_memory_bytes(n_in: int = 784, n_out: int = 10, weight_bits: int = 9) -> float:
    """Paper §V-B: 784×10×9 bits ≈ 8.6 KB on-chip."""
    return n_in * n_out * weight_bits / 8.0


def ann_memory_bytes(n_in: int = 784, n_out: int = 10,
                     hidden: tuple[int, ...] = (32,)) -> float:
    """Baseline ANN footprint: fp32 weights + biases.

    784→32→10 fp32 = 25,450 params × 4 B = 101,800 B = 99.4 KiB — exactly the
    paper's Table II entry.
    """
    sizes = (n_in,) + tuple(hidden) + (n_out,)
    params = sum(a * b for a, b in zip(sizes[:-1], sizes[1:])) + sum(sizes[1:])
    return params * 4.0
