"""Structured kernel↔host telemetry: the runtime's activity side channel.

The paper's efficiency story is *event-driven* — work should track the
spike activity the hardware actually observes (Bouvier et al. 2020 call
activity monitoring the standard control plane of neuromorphic runtimes;
SparrowSNN feeds measured spike statistics back into scheduling).  Until
this module existed, the runtime steered itself with compile-time guesses:
the masked-vs-MXU dispatch threshold was a hard-coded constant and the
fused kernel's tile-skip decisions were invisible to the host even though
the kernel computes every ingredient per step.

:class:`ChunkTelemetry` is the structured record every integer-engine
backend emits for a window chunk — per-step, per-layer spike counts,
prune-enable occupancy and (derived) executed adds per lane, plus the
per-block MXU tile pairs the event-driven contraction skipped.  The
contract that makes it trustworthy is that telemetry is **bit-checkable
cross-backend**: the fused megakernel emits it as extra kernel outputs,
and the staged / reference / jnp-scan paths re-derive the identical
numbers from their own state (``kernels.ref`` re-derives the tile
geometry independently, double-entry-bookkeeping style), so a telemetry
regression is caught exactly like a datapath regression.

On top of the record, ``serve.telemetry`` builds the adaptive controller
that retunes the dispatch threshold and picks chunk lengths from live
traffic; this module only defines the channel and the pure helpers shared
by every producer.
"""

from __future__ import annotations

import os
from typing import NamedTuple

import jax
import jax.numpy as jnp

__all__ = [
    "ChunkTelemetry",
    "EngineLoad",
    "MatmulTelemetry",
    "DEFAULT_SPIKE_DENSITY_THRESHOLD",
    "engine_load_from_wire",
    "engine_load_to_wire",
    "estimate_eta_steps",
    "load_score",
    "resolve_density_threshold",
    "resolve_sparse_skip",
    "layer_tile_skips",
    "tiles_total",
    "telemetry_partition_specs",
    "concat_telemetry",
]

# The compile-time guess this subsystem exists to replace: below this
# per-batch spike density the masked (event-driven) spike-matmul kernel
# wins over the MXU dot.  Kept under its historical home as
# ``kernels.ops.SPIKE_DENSITY_THRESHOLD`` too — it is now only the
# *default* for ``SNNConfig.spike_density_threshold`` / the env override,
# and the serving controller may retune the live value.
DEFAULT_SPIKE_DENSITY_THRESHOLD = 0.25


def resolve_density_threshold(threshold: float | None = None) -> float:
    """Explicit value → env ``REPRO_SPIKE_DENSITY_THRESHOLD`` → default.

    The resolution order mirrors ``REPRO_SPARSE_SKIP``: an explicit config
    value always wins, the env var lets CI sweep the dispatch boundary
    across a whole run without touching call sites, and the exported
    module constant keeps its historical meaning as the default.
    """
    if threshold is not None:
        return float(threshold)
    env = os.environ.get("REPRO_SPIKE_DENSITY_THRESHOLD")
    if env:
        return float(env)
    return DEFAULT_SPIKE_DENSITY_THRESHOLD


def resolve_sparse_skip(sparse_skip: bool | None) -> bool:
    """None → the REPRO_SPARSE_SKIP env default (on unless set to "0").

    Resolved at trace time (``sparse_skip`` is a static argument
    everywhere), which is what lets CI force the dense and sparse tile
    paths across a whole test run without touching call sites.  The
    single source of truth shared by the kernel launcher
    (``kernels.ops``) and the jnp telemetry mirrors below.
    """
    if sparse_skip is None:
        return os.environ.get("REPRO_SPARSE_SKIP", "1") != "0"
    return bool(sparse_skip)


class ChunkTelemetry(NamedTuple):
    """Per-chunk activity record, identical across all four backends.

    Shapes (``chunk`` = steps this launch executed, ``L`` = layers,
    ``B`` = lanes, ``n_blocks`` = batch-block programs of the fused
    launch geometry):

      n_spk          (chunk, L, B) int32 — input spikes layer ``l``
                     consumed at step ``t`` for each lane (layer 0 =
                     encoder output).  Zeroed for lanes the stability
                     gate had already frozen, matching the executed-add
                     channel.
      n_en           (chunk, L, B) int32 — prune-enable occupancy: how
                     many of layer ``l``'s neurons were still enabled.
                     Zeroed for frozen lanes.
      tiles_skipped  (chunk, L, n_blocks) int32 — 128×128 MXU tile pairs
                     the event-driven contraction skipped per batch
                     block (0 everywhere when ``sparse_skip`` is off).
                     Block-level by construction: the skip predicate
                     spans all lanes of a block, so this leaf tracks the
                     launch geometry, not individual lanes.

    ``adds`` is derived, not stored: per lane the executed synaptic adds
    of layer ``l`` are exactly ``n_spk · n_en`` (a skipped tile pair has
    zero of one factor), so the record stays minimal and the invariant
    "telemetry adds == the frozen energy counters" is checkable rather
    than tautological.
    """

    n_spk: jax.Array
    n_en: jax.Array
    tiles_skipped: jax.Array

    @property
    def adds(self) -> jax.Array:
        """Executed synaptic adds per (step, layer, lane) — n_spk · n_en."""
        return self.n_spk * self.n_en

    def densities(self, layer_sizes) -> jax.Array:
        """Observed input-spike density per (step, layer, lane) in [0, 1].

        Layer ``l``'s fan-in is ``layer_sizes[l]`` — the quantity the
        masked-vs-MXU dispatch threshold is compared against.
        """
        fan_in = jnp.asarray(layer_sizes[:-1], jnp.float32)
        return self.n_spk.astype(jnp.float32) / fan_in[None, :, None]


class EngineLoad(NamedTuple):
    """Host-side load summary of one serving engine (router currency).

    Every field is either free host bookkeeping (occupancy, queue depth —
    the engine already tracks both) or an estimate the telemetry loop
    maintains without extra device syncs: ``mean_service_steps`` is the
    EWMA of window steps retired requests actually consumed (early exit
    makes this traffic-dependent — exactly why routing on the *measured*
    rate beats routing on ``num_steps``), ``density_ewma`` is the
    adaptive controller's estimate (``None`` when frozen or unobserved).
    The serving tier sprays requests by :func:`load_score` and gates
    admission with :func:`estimate_eta_steps` — both pure functions of
    this record, so routing decisions are deterministic and replayable.
    """

    lanes_total: int               # batch-tile slots the engine owns
    lanes_busy: int                # slots currently bound to a request
    queue_depth: int               # host-queue requests not yet admitted
    mean_service_steps: float      # EWMA of consumed steps per request
    retired_total: int             # requests completed since construction
    density_ewma: float | None     # controller estimate (None if frozen)
    # Health surface (serve.faults) — defaulted so positional construction
    # of the historical six-field record keeps meaning "healthy engine".
    consecutive_faults: int = 0    # dispatch faults since the last clean chunk
    demotion_level: int = 0        # rungs down the backend degradation ladder
    watchdog_margin: int | None = None  # chunks left before the hang deadline
    alive: bool = True             # False once the engine declared failure

    @property
    def occupancy(self) -> float:
        """Fraction of lane slots currently serving a request."""
        return self.lanes_busy / max(1, self.lanes_total)


def engine_load_to_wire(load: EngineLoad) -> dict:
    """JSON-safe dict of one load record (the cluster RPC surface).

    Every field is already a JSON scalar (ints, floats, bools, None), so
    ``_asdict`` is the whole codec — kept as a named function so the RPC
    layer depends on the *contract* (roundtrips through
    :func:`engine_load_from_wire` reproduce the record exactly and the
    routing scores computed from it) rather than a NamedTuple detail.
    """
    return dict(load._asdict())


def engine_load_from_wire(d: dict) -> EngineLoad:
    """Inverse of :func:`engine_load_to_wire` (exact roundtrip)."""
    return EngineLoad(**d)


def _effective_service_steps(load: EngineLoad) -> float:
    """Sanitized mean service window for the routing estimators.

    A just-constructed engine has retired nothing, and an ``EngineLoad``
    assembled by an external coordinator may carry a zero, negative or
    non-finite ``mean_service_steps`` (empty EWMA serialized as 0.0 /
    NaN).  Feeding that into :func:`load_score` made a cold engine's
    score collapse to 0 (or NaN) regardless of its queue, so it
    spuriously beat every warmed healthy engine; :func:`estimate_eta_steps`
    likewise returned 0 / NaN instead of a usable wait bound.  Any value
    that cannot be a measured window (non-finite or ≤ 0) falls back to
    one step — the smallest window a request can consume — so a cold
    engine's outstanding work still counts, while every legitimately
    measured mean (engines seed the EWMA with ``num_steps``) passes
    through untouched and the historical scoring formula is preserved
    bit-for-bit for healthy warmed records.
    """
    mean = float(load.mean_service_steps)
    if not (0.0 < mean < float("inf")):   # ≤0, NaN and ±inf all fail this
        return 1.0
    return mean


def load_score(load: EngineLoad) -> float:
    """Expected outstanding work per lane slot, in window steps.

    Busy lanes owe on average half a service window; queued requests owe
    a full one.  Normalizing by the slot count makes engines of different
    widths comparable, and scaling by the *measured* mean service steps
    lets an engine whose traffic exits early absorb proportionally more
    load.  Pure and deterministic — the router's least-loaded comparison
    (ties broken by engine index) is reproducible in CI.

    The health surface folds in as an additive degradation charge: each
    rung down the backend ladder counts like half the tile being busy and
    each consecutive unresolved fault like a quarter, so a degraded
    engine keeps serving but stops being anyone's first choice; a dead
    engine scores infinite and can never win a least-loaded comparison.
    A fully healthy record scores exactly what the historical six-field
    formula scored, keeping the tier's routing-determinism contract.
    """
    if not load.alive:
        return float("inf")
    owed = 0.5 * load.lanes_busy + load.queue_depth
    degraded = (0.5 * load.demotion_level
                + 0.25 * load.consecutive_faults) * load.lanes_total
    return ((owed + degraded) * _effective_service_steps(load)
            / max(1, load.lanes_total))


def estimate_eta_steps(load: EngineLoad) -> float:
    """Expected window steps until a NEW admission would complete.

    Queue-wave model: a request entering the host queue waits zero waves
    if a lane slot is free, else one wave per ``lanes_total`` requests
    already ahead of it, each wave lasting the measured mean service
    window; its own service appends one more.  Deliberately coarse — the
    admission policy needs a monotone, deterministic feasibility
    estimate, not a simulator — and conservative in the right direction:
    early-exit traffic shortens the measured wave, never lengthens it.

    Cold-engine edge: a record whose service EWMA is still empty (zero /
    NaN mean) estimates with a one-step wave via
    :func:`_effective_service_steps`, so the ETA is always finite and
    ≥ 1 — an admission gate comparing it against a deadline never sees
    0 or NaN from an engine that simply hasn't retired anything yet.
    """
    free = load.lanes_total - load.lanes_busy
    if load.queue_depth < free:
        waves = 0
    else:
        waves = 1 + (load.queue_depth - free) // max(1, load.lanes_total)
    return (waves + 1) * _effective_service_steps(load)


class MatmulTelemetry(NamedTuple):
    """Side channel of one ``spike_matmul_op(mode="auto")`` dispatch."""

    density: jax.Array     # f32 scalar — observed batch spike density
    used_masked: jax.Array  # bool scalar — which datapath the cond took


def _pad_axis(x: jax.Array, axis: int, mult: int) -> jax.Array:
    pad = (-x.shape[axis]) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def tiles_total(layer_sizes) -> tuple[int, ...]:
    """Total 128×128 tile pairs per layer, per batch block, per step."""
    from ..kernels.fused_snn import LANE, _pad128
    sizes = [_pad128(int(n)) for n in layer_sizes]
    return tuple((k // LANE) * (n // LANE)
                 for k, n in zip(sizes[:-1], sizes[1:]))


def layer_tile_skips(x: jax.Array, en: jax.Array, *,
                     sparse_skip: bool) -> jax.Array:
    """jnp mirror of the fused kernel's per-layer tile-skip predicates.

    ``x``: (B, n_in) bool input spikes; ``en``: (B, n_out) bool enables.
    Returns (n_blocks,) int32 — skipped (K-tile, N-tile) pairs per batch
    block, with exactly the launch geometry ``kernels.ops`` pads to:
    neuron axes to 128 (padded pixels never spike, padded neurons are
    disabled), lanes to the ``block_b_for`` batch block.  A pair is
    skipped when its K-tile carries no spike in any lane of the block OR
    its output tile is fully pruned across the block — the
    ``lax.cond`` predicate of ``fused_snn._tiled_contraction``, which is
    why this pure function is bit-checkable against the kernel's own
    counter.  All-jnp, so it runs inside scan/jit/shard_map bodies.
    """
    from ..kernels.fused_snn import LANE, block_b_for
    B = x.shape[0]
    bB = block_b_for(B)
    xp = _pad_axis(_pad_axis(x.astype(bool), 0, bB), 1, LANE)
    ep = _pad_axis(_pad_axis(en.astype(bool), 0, bB), 1, LANE)
    nb = xp.shape[0] // bB
    nkt, nnt = xp.shape[1] // LANE, ep.shape[1] // LANE
    any_x = jnp.any(xp.reshape(nb, bB, nkt, LANE), axis=(1, 3))  # (nb, nkt)
    any_e = jnp.any(ep.reshape(nb, bB, nnt, LANE), axis=(1, 3))  # (nb, nnt)
    live = jnp.logical_and(any_x[:, :, None], any_e[:, None, :])
    if not sparse_skip:
        return jnp.zeros((nb,), jnp.int32)
    return jnp.sum(jnp.logical_not(live), axis=(1, 2)).astype(jnp.int32)


def telemetry_partition_specs(axis_name: str | None = "data",
                              model_axis: str | None = None):
    """PartitionSpecs of a ChunkTelemetry on a lane (× model) mesh.

    The per-lane leaves shard on the lane axis (last); the tile leaf
    shards on its batch-*block* axis, which nests inside the lane axis
    (device-local blocks concatenate to the global block list).  With a
    ``model_axis`` the per-lane counts stay data-sharded only — every
    model peer derives them from the *full* gathered spike vector, so
    they are replicated over the model axis — while the tile leaf
    concatenates per-shard skip counts on the block axis, data-outer /
    model-inner: each model peer counts the tile pairs of its own weight
    shard's contraction geometry.  No leaf looks across devices, so the
    record composes with the engines' ``shard_map`` chunk.
    """
    from jax.sharding import PartitionSpec as P
    p = P(None, None, axis_name)
    tiles_axes = axis_name if model_axis is None else (axis_name, model_axis)
    return ChunkTelemetry(n_spk=p, n_en=p,
                          tiles_skipped=P(None, None, tiles_axes))


def concat_telemetry(chunks) -> ChunkTelemetry:
    """Concatenate per-chunk records along the step axis.

    Telemetry is per-step, so the concatenation over any split of a
    window is bit-identical to the one-shot record — the same invariant
    the carried lane state satisfies.
    """
    chunks = list(chunks)
    return ChunkTelemetry(*[jnp.concatenate([getattr(c, f) for c in chunks],
                                            axis=0)
                            for f in ChunkTelemetry._fields])
