"""Fixed-point arithmetic utilities (paper §III-A, §V-B).

The RTL stores synaptic weights as 8/9-bit signed fixed point and membrane
potentials in a wider accumulator register.  These helpers implement the
quantisation used to move between the float training world and the integer
inference world, including the stochastic-rounding variant referenced from
Shinji et al. 2024 ([5] in the paper).

Conventions
-----------
* ``Q(w, bits, scale)``: integer code ``q = clip(round(w / scale))`` with
  ``q ∈ [-2^(bits-1), 2^(bits-1)-1]``.
* Per-tensor or per-output-neuron (axis) scales are supported; the RTL uses a
  single global scale chosen at synthesis time, which is the default here.
"""

from __future__ import annotations

from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp

__all__ = [
    "QuantParams",
    "choose_scale",
    "quantize",
    "dequantize",
    "quantize_stochastic",
    "fake_quant",
    "int8_matmul",
]


@dataclass(frozen=True)
class QuantParams:
    """Static description of a fixed-point format."""

    bits: int = 8
    axis: int | None = None  # None => per-tensor scale

    @property
    def qmin(self) -> int:
        return -(1 << (self.bits - 1))

    @property
    def qmax(self) -> int:
        return (1 << (self.bits - 1)) - 1


def choose_scale(w: jax.Array, qp: QuantParams) -> jax.Array:
    """Symmetric max-abs scale (what a synthesis-time calibration would pick)."""
    if qp.axis is None:
        amax = jnp.max(jnp.abs(w))
    else:
        reduce_axes = tuple(i for i in range(w.ndim) if i != qp.axis)
        amax = jnp.max(jnp.abs(w), axis=reduce_axes, keepdims=True)
    amax = jnp.maximum(amax, 1e-12)
    return (amax / qp.qmax).astype(jnp.float32)


def quantize(w: jax.Array, qp: QuantParams, scale: jax.Array | None = None):
    """Round-to-nearest-even quantisation. Returns (int codes, scale)."""
    scale = choose_scale(w, qp) if scale is None else scale
    q = jnp.clip(jnp.round(w / scale), qp.qmin, qp.qmax)
    dtype = jnp.int8 if qp.bits <= 8 else (jnp.int16 if qp.bits <= 16 else jnp.int32)
    return q.astype(dtype), scale


def quantize_stochastic(w: jax.Array, qp: QuantParams, key: jax.Array,
                        scale: jax.Array | None = None):
    """Stochastic rounding (Shinji et al. 2024 style): E[q*scale] == w."""
    scale = choose_scale(w, qp) if scale is None else scale
    x = w / scale
    lo = jnp.floor(x)
    p_up = x - lo
    up = jax.random.uniform(key, x.shape) < p_up
    q = jnp.clip(lo + up.astype(x.dtype), qp.qmin, qp.qmax)
    dtype = jnp.int8 if qp.bits <= 8 else (jnp.int16 if qp.bits <= 16 else jnp.int32)
    return q.astype(dtype), scale


def dequantize(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


@partial(jax.custom_vjp, nondiff_argnums=(1,))
def fake_quant(w: jax.Array, bits: int = 8) -> jax.Array:
    """Straight-through-estimator fake quantisation (for QAT of the SNN)."""
    qp = QuantParams(bits=bits)
    q, s = quantize(w, qp)
    return dequantize(q, s)


def _fq_fwd(w, bits):
    return fake_quant(w, bits), None


def _fq_bwd(bits, _res, g):
    return (g,)


fake_quant.defvjp(_fq_fwd, _fq_bwd)


def int8_matmul(x_q: jax.Array, w_q: jax.Array, x_scale, w_scale) -> jax.Array:
    """Integer matmul with int32 accumulation, rescaled to float.

    Mirrors the RTL accumulator: products never leave the integer domain
    until the final rescale.  On TPU this lowers to the int8 MXU path.
    """
    acc = jax.lax.dot_general(
        x_q, w_q,
        dimension_numbers=(((x_q.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32,
    )
    return acc.astype(jnp.float32) * (x_scale * w_scale)
