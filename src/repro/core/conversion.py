"""ANN→SNN conversion (the paper's implied offline training flow).

The RTL performs inference only; weights arrive trained.  The classic route
for rate-coded SNNs (Diehl et al. 2015) is: train a ReLU ANN, then reuse its
weights in the LIF network after *data-based normalisation* — rescaling each
layer so the maximum pre-activation maps just below the firing threshold,
which makes LIF firing rates approximate ReLU activations.

Provided so that both training flows exist:
  * surrogate-gradient BPTT (core.snn) — direct SNN training;
  * ANN→SNN conversion (this module) — the paper's likely flow.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["ann_init", "ann_apply", "ann_loss", "convert_ann_to_snn"]


def ann_init(key: jax.Array, sizes: tuple[int, ...] = (784, 10)) -> dict:
    layers = []
    for fan_in, fan_out in zip(sizes[:-1], sizes[1:]):
        key, k1 = jax.random.split(key)
        w = jax.random.normal(k1, (fan_in, fan_out)) * jnp.sqrt(2.0 / fan_in)
        layers.append({"w": w, "b": jnp.zeros((fan_out,))})
    return {"layers": layers}


def ann_apply(params: dict, x: jax.Array) -> jax.Array:
    """ReLU MLP; returns logits. x: (batch, n_in) in [0,1]."""
    h = x
    n = len(params["layers"])
    for i, layer in enumerate(params["layers"]):
        h = h @ layer["w"] + layer["b"]
        if i < n - 1:
            h = jax.nn.relu(h)
    return h


def ann_loss(params: dict, x: jax.Array, labels: jax.Array):
    logits = ann_apply(params, x)
    logp = jax.nn.log_softmax(logits, -1)
    nll = -jnp.take_along_axis(logp, labels[:, None], -1).mean()
    acc = jnp.mean((jnp.argmax(logits, -1) == labels).astype(jnp.float32))
    return nll, {"loss": nll, "acc": acc}


def convert_ann_to_snn(params: dict, calib_x: jax.Array,
                       percentile: float = 99.9) -> dict:
    """Data-based weight normalisation (Diehl et al. 2015).

    Rescales each layer by the p-th percentile of its pre-activations on a
    calibration batch so that LIF rates (∈[0,1]) track ReLU activations.
    Biases are folded away (the RTL has none): they are dropped after being
    absorbed into the effective threshold via the normalisation — acceptable
    for the paper's bias-free topology, reported otherwise.

    Returns float SNN params {"layers": [{"w": ...}]} for core.snn
    (threshold = 1.0 semantics), ready for ``quantize_params``.
    """
    h = calib_x
    out_layers = []
    prev_scale = 1.0
    n = len(params["layers"])
    for i, layer in enumerate(params["layers"]):
        pre = h @ layer["w"] + layer["b"]
        lam = jnp.percentile(pre, percentile)
        lam = jnp.maximum(lam, 1e-6)
        # w_snn = w * prev_scale / lam : inputs were scaled by 1/prev_scale,
        # outputs must cross 1.0 when the ANN pre-activation crosses lam.
        w_snn = layer["w"] * (prev_scale / lam)
        out_layers.append({"w": w_snn})
        if i < n - 1:
            h = jax.nn.relu(pre)
        prev_scale = lam
    return {"layers": out_layers}
