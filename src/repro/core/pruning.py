"""Active pruning controller (paper §III-D, Fig. 3).

The RTL layer controller aggregates output spikes in a Spike Register and
feeds them back as enable gates: once a neuron has fired (i.e. contributed a
classification vote), its datapath is clock-gated for the rest of the
inference window, eliminating its switching power.

On TPU the same logic is a carried boolean mask (see ``run_lif_int``'s
``active_pruning`` flag).  This module adds the *layer-level* controller
semantics on top:

* :class:`PruningController` — spike register + enable feedback + readout.
* :func:`first_spike_readout` — classification from the spike register
  (earliest-firing neuron wins; membrane potential breaks ties), which is the
  readout the pruned RTL actually supports (each neuron fires ≤ once).
* :func:`stability_early_exit` — the batch-level generalisation used by the
  serving stack (``serve/early_exit.py``): an *input* is retired once its
  predicted class has been stable for ``patience`` steps.  This is the
  framework-level analogue of "sleep sooner to save power" (paper §IV-C).
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

__all__ = [
    "PruningState",
    "init_pruning_state",
    "controller_step",
    "first_spike_readout",
    "count_readout",
    "membrane_readout",
    "peak_membrane_readout",
    "stability_early_exit",
]


class PruningState(NamedTuple):
    enable: jax.Array        # bool (..., N): per-neuron clock gates
    spike_reg: jax.Array     # int32 (..., N): aggregated spike counts
    first_spike_t: jax.Array  # int32 (..., N): timestep of first spike (T_max if never)


def init_pruning_state(shape: tuple[int, ...], horizon: int) -> PruningState:
    return PruningState(
        enable=jnp.ones(shape, dtype=bool),
        spike_reg=jnp.zeros(shape, dtype=jnp.int32),
        first_spike_t=jnp.full(shape, horizon, dtype=jnp.int32),
    )


def controller_step(state: PruningState, fired: jax.Array, t: jax.Array,
                    *, prune: bool = True) -> PruningState:
    """One controller cycle: latch spikes, record first-spike time, gate."""
    spike_reg = state.spike_reg + fired.astype(jnp.int32)
    # Record the first firing time (a neuron that already spiked keeps its t).
    first_t = jnp.where(jnp.logical_and(fired, state.spike_reg == 0),
                        jnp.int32(t), state.first_spike_t)
    enable = state.enable
    if prune:
        enable = jnp.logical_and(enable, jnp.logical_not(fired))
    return PruningState(enable=enable, spike_reg=spike_reg, first_spike_t=first_t)


def first_spike_readout(state: PruningState, v_final: jax.Array,
                        horizon: int) -> jax.Array:
    """Earliest-firing neuron wins; membrane potential breaks never-fired ties.

    Under active pruning each neuron fires at most once, so spike counts are
    uninformative; *when* it fired is the signal (time-to-first-spike code).
    Never-fired neurons rank below all fired ones, ordered by membrane V.
    """
    fired = state.spike_reg > 0
    # Score: fired neurons get (horizon - first_t) * LARGE  (earlier = larger);
    # unfired ones get their (sub-threshold) membrane potential.
    large = jnp.asarray(1 << 24, dtype=jnp.int32)
    score = jnp.where(
        fired,
        (horizon - state.first_spike_t) * large,
        jnp.clip(v_final, -large + 1, large - 1),
    )
    return jnp.argmax(score, axis=-1)


def count_readout(out_spikes_t: jax.Array) -> jax.Array:
    """Rate readout: argmax of spike counts over the window (no pruning)."""
    counts = jnp.sum(out_spikes_t.astype(jnp.int32), axis=0)
    return jnp.argmax(counts, axis=-1)


def membrane_readout(v_trace_t: jax.Array) -> jax.Array:
    """Argmax of time-integrated membrane potential (ANN-conversion readout)."""
    return jnp.argmax(jnp.sum(v_trace_t.astype(jnp.int64), axis=0), axis=-1)


def peak_membrane_readout(v_trace_t: jax.Array) -> jax.Array:
    """Argmax of peak membrane potential over the window.

    The ``membrane`` readout of the integer engine (core.snn.readout_pred):
    the max-fold is associative, so a per-layer running-peak accumulator
    carried across window chunks reproduces it exactly without a trace
    buffer — which is what lets this readout stream through the serving
    engines (the streamed twin of the v_peak state in
    kernels.fused_snn / serve.snn_engine.LaneState).
    """
    return jnp.argmax(jnp.max(v_trace_t, axis=0), axis=-1)


def stability_early_exit(pred_t: jax.Array, patience: int) -> jax.Array:
    """Earliest timestep at which the running prediction became final.

    ``pred_t``: int (T, batch) per-step predictions.  Returns (batch,) int32 —
    the first t such that pred is constant from t-patience+1..t and never
    changes after t; T if never stable.  Used to quantify the latency the
    active-pruning/early-exit mechanism saves (paper Fig. 6/7).
    """
    T = pred_t.shape[0]
    final = pred_t[-1]
    agrees = pred_t == final[None]            # (T, batch)
    # suffix_all[t] = all agree from t..T-1
    suffix_all = jnp.flip(jnp.cumprod(jnp.flip(agrees, 0), axis=0), 0).astype(bool)
    first_stable = jnp.argmax(suffix_all, axis=0)  # first True (0 if all True)
    never = jnp.logical_not(jnp.any(suffix_all, axis=0))
    t_exit = jnp.minimum(first_stable + patience - 1, T - 1)
    return jnp.where(never, T, t_exit + 1).astype(jnp.int32)
