"""Leaky Integrate-and-Fire neuron dynamics (paper §III-A, §III-B, Fig. 1/4).

Two datapaths, sharing one timestep semantics:

* **Integer datapath** (:func:`lif_step_int`, :func:`run_lif_int`): the
  bit-exact model of the RTL.  Membrane potential lives in an int32
  "Accumulator" register; synaptic weights are int8/int16 codes; the leak is
  an arithmetic right shift (β = 2⁻ⁿ); fire is a ≥ comparison against the
  Threshold-Reg; reset is a hard write of V_rest.  No multiplications occur
  anywhere: the input current is a masked sum of weights (spikes are binary).

* **Float datapath** (:func:`lif_step_float`, :func:`run_lif_float`): same
  dynamics in float with a surrogate-gradient spike function, used to train
  weights with BPTT.  After training, weights are quantised
  (``core.fixed_point``) and executed on the integer datapath.

Timestep ordering (matches the RTL FSM: Integrate → Leak → Fire/Reset):

    I[t]   = Σ_i W_i · S_i[t]                 (Adder, spike-gated)
    V'     = V[t-1] + I[t]                    (Accumulator)
    V''    = V' - (V' >> n)                   (Decay-Reg / ALU shift)
    fire   = V'' ≥ V_th                       (Comparator)
    V[t]   = fire ? V_rest : V''              (hard reset)

Active pruning (§III-D) enters as an ``enable`` mask: a disabled neuron's
accumulator is frozen and it cannot fire — modelling the gated clock.
"""

from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

__all__ = [
    "LIFConfig",
    "LIFStateInt",
    "LIFStateFloat",
    "lif_step_int",
    "run_lif_int",
    "spike_surrogate",
    "lif_step_float",
    "run_lif_float",
]


@dataclass(frozen=True)
class LIFConfig:
    """Static LIF hyper-parameters (synthesis-time constants in the RTL)."""

    decay_shift: int = 4          # n in β = 2⁻ⁿ  (Decay-Reg)
    v_threshold: int = 128        # Threshold-Reg (paper Fig. 4 uses 128)
    v_rest: int = 0               # restart potential; 0 by design (paper §III-A)
    v_min: int = -(1 << 20)       # accumulator saturation floor (int path)
    v_max: int = (1 << 20) - 1    # accumulator saturation ceiling

    @property
    def beta(self) -> float:
        return 2.0 ** (-self.decay_shift)


class LIFStateInt(NamedTuple):
    v: jax.Array        # int32 membrane accumulator, shape (..., N)
    enable: jax.Array   # bool, per-neuron clock-gate (True = active)


class LIFStateFloat(NamedTuple):
    v: jax.Array        # float membrane potential


def init_state_int(shape: tuple[int, ...], cfg: LIFConfig) -> LIFStateInt:
    return LIFStateInt(
        v=jnp.full(shape, cfg.v_rest, dtype=jnp.int32),
        enable=jnp.ones(shape, dtype=bool),
    )


def init_state_float(shape: tuple[int, ...], cfg: LIFConfig) -> LIFStateFloat:
    return LIFStateFloat(v=jnp.full(shape, float(cfg.v_rest), dtype=jnp.float32))


# ---------------------------------------------------------------------------
# Integer (RTL-faithful) datapath
# ---------------------------------------------------------------------------

def synaptic_current_int(spikes: jax.Array, w_q: jax.Array,
                         dot_impl: str = "int32") -> jax.Array:
    """I = Σ_i W_i · S_i with S ∈ {0,1} — multiplier-free.

    ``spikes``: bool/int ``(..., n_in)``; ``w_q``: int ``(n_in, n_out)``.
    Expressed as a masked sum with int32 accumulation; XLA on TPU lowers the
    {0,1}·int contraction to the integer MXU path, which is exactly the
    "adds only" cost model the paper uses (see core.energy).  Weights stay
    in their storage dtype (int16 for the paper's 9-bit signed codes —
    deliberately NOT narrowed to int8, which would overflow codes ≥ 128).

    dot_impl="f32" routes the contraction through the f32 unit — BIT-EXACT
    for this datapath (|Σ| ≤ n_in·2^8 < 2^24, every intermediate is an
    integer exactly representable in f32) and much faster on hosts whose
    integer matmul has no BLAS path (§Perf: the hardware-adaptation move —
    RTL uses adders, TPU the int MXU, CPU the FP unit; same arithmetic).
    """
    if dot_impl == "f32":
        acc = jax.lax.dot_general(
            spikes.astype(jnp.float32), w_q.astype(jnp.float32),
            dimension_numbers=(((spikes.ndim - 1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        return acc.astype(jnp.int32)
    s = spikes.astype(jnp.int32)
    return jax.lax.dot_general(
        s, w_q.astype(jnp.int32),
        dimension_numbers=(((s.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32,
    )


def lif_step_int(state: LIFStateInt, current: jax.Array, cfg: LIFConfig):
    """One RTL timestep on the integer datapath.

    Returns (new_state, fired) where ``fired`` is bool (..., N).
    Disabled neurons neither integrate nor fire (frozen accumulator).
    """
    v_prev = state.v
    # Integrate (Adder): saturating add, as the RTL accumulator clamps.
    v_int = jnp.clip(v_prev + current, cfg.v_min, cfg.v_max)
    # Leak (ALU shift): arithmetic right shift on two's complement.
    v_leak = v_int - (v_int >> cfg.decay_shift)
    # Fire (Comparator) + hard reset.
    fired = v_leak >= cfg.v_threshold
    v_new = jnp.where(fired, jnp.int32(cfg.v_rest), v_leak)
    # Active pruning gate: frozen when disabled.
    v_out = jnp.where(state.enable, v_new, v_prev)
    fired = jnp.logical_and(fired, state.enable)
    return LIFStateInt(v=v_out, enable=state.enable), fired


def run_lif_int(
    spikes_t: jax.Array,
    w_q: jax.Array,
    cfg: LIFConfig,
    *,
    active_pruning: bool = False,
    init: LIFStateInt | None = None,
    dot_impl: str = "int32",
):
    """Run T timesteps of the integer LIF layer.

    Args:
      spikes_t: bool ``(T, ..., n_in)`` input spike train.
      w_q: int8/int16 ``(n_in, n_out)`` synaptic weights.
      active_pruning: if True, a neuron that fires is clock-gated for the
        remainder of the window (paper §III-D).

    Returns dict with:
      ``spikes``  (T, ..., n_out) bool output spike train
      ``v_trace`` (T, ..., n_out) int32 membrane trajectory (Fig. 4)
      ``state``   final LIFStateInt
      ``active_adds`` per-step count of executed synaptic additions
                      (the quantity the energy model integrates).
    """
    batch_shape = spikes_t.shape[1:-1]
    n_out = w_q.shape[-1]
    state0 = init if init is not None else init_state_int(batch_shape + (n_out,), cfg)

    n_in = w_q.shape[0]

    def body(state, s_t):
        current = synaptic_current_int(s_t, w_q, dot_impl)
        # Pruned neurons do not accumulate: their adds are gated off.
        current = jnp.where(state.enable, current, 0)
        new_state, fired = lif_step_int(state, current, cfg)
        if active_pruning:
            new_state = new_state._replace(
                enable=jnp.logical_and(new_state.enable, jnp.logical_not(fired))
            )
        # Op accounting: each input spike costs one add per *enabled* output.
        n_spk = jnp.sum(s_t.astype(jnp.int32), axis=-1)          # (...,)
        n_en = jnp.sum(state.enable.astype(jnp.int32), axis=-1)  # (...,)
        adds = n_spk * n_en
        return new_state, (fired, new_state.v, adds)

    state_f, (spk, vtr, adds) = jax.lax.scan(body, state0, spikes_t)
    return {"spikes": spk, "v_trace": vtr, "state": state_f, "active_adds": adds,
            "n_in": n_in}


# ---------------------------------------------------------------------------
# Float (training) datapath with surrogate gradient
# ---------------------------------------------------------------------------

@partial(jax.custom_vjp, nondiff_argnums=(1,))
def spike_surrogate(v_minus_th: jax.Array, slope: float = 4.0) -> jax.Array:
    """Heaviside spike with a fast-sigmoid surrogate derivative.

    Forward: 1[v ≥ v_th].  Backward: d/dv σ_fast = slope / (1 + slope|x|)²
    (Zenke & Ganguli 2018) — the standard choice for BPTT through LIF.
    """
    return (v_minus_th >= 0).astype(v_minus_th.dtype)


def _spk_fwd(x, slope):
    return spike_surrogate(x, slope), x


def _spk_bwd(slope, x, g):
    grad = slope / (1.0 + slope * jnp.abs(x)) ** 2
    return (g * grad,)


spike_surrogate.defvjp(_spk_fwd, _spk_bwd)


def lif_step_float(state: LIFStateFloat, current: jax.Array, cfg: LIFConfig,
                   slope: float = 4.0):
    """Float twin of :func:`lif_step_int` (same op ordering, soft gradients)."""
    v_int = state.v + current
    v_leak = v_int - v_int * cfg.beta        # == v_int * (1 - 2^-n)
    spike = spike_surrogate(v_leak - float(cfg.v_threshold), slope)
    # Hard reset through a straight-through multiply keeps gradients flowing
    # along the no-reset path.
    v_new = v_leak * (1.0 - spike) + float(cfg.v_rest) * spike
    return LIFStateFloat(v=v_new), spike


def run_lif_float(spikes_t: jax.Array, w: jax.Array, cfg: LIFConfig,
                  slope: float = 4.0):
    """Run T float LIF steps. Returns (out_spikes (T,...,N), v_trace, final)."""
    batch_shape = spikes_t.shape[1:-1]
    n_out = w.shape[-1]
    state0 = init_state_float(batch_shape + (n_out,), cfg)

    def body(state, s_t):
        current = s_t @ w
        new_state, spike = lif_step_float(state, current, cfg, slope)
        return new_state, (spike, new_state.v)

    state_f, (spk, vtr) = jax.lax.scan(body, state0, spikes_t)
    return spk, vtr, state_f
