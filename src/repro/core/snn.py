"""The paper's SNN as a composable JAX module.

Network topology (paper §IV-A): Poisson encoder → fully-connected 784→10 LIF
layer → spike-register readout, over a T-timestep window.  The module
generalises to arbitrary layer stacks (hidden LIF layers) so the framework
can scale the idea, but the paper configuration is the single FC layer.

Three executables are exposed:

* :func:`snn_apply_float` — differentiable forward (surrogate gradients),
  used for BPTT training.  Optionally trains *through* fake-quantised weights
  (QAT) so the trained weights survive int8 conversion.
* :func:`snn_apply_int` — the bit-exact fixed-point inference engine
  (the actual reproduction target), including active pruning and the
  op-count/energy side channel.
* :func:`snn_loss` / :func:`snn_train_step` helpers for the training loop.

Weights layout: ``params = {"layers": [{"w": (n_in, n_out)}, ...]}``.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import NamedTuple

import jax
import jax.numpy as jnp

from . import encoding, fixed_point, lif, pruning
from .telemetry import (ChunkTelemetry, layer_tile_skips, resolve_sparse_skip)

__all__ = [
    "SNNConfig",
    "snn_init",
    "snn_apply_float",
    "snn_apply_int",
    "snn_loss",
    "quantize_params",
    "encode_lif_timestep",
    "snn_int_stack_step",
    "snn_int_stack_step_sharded",
    "resolve_backend",
    "fused_unsupported_reason",
    "readout_pred",
    "SNNWindowState",
    "snn_window_init",
    "snn_window_chunk",
]


@dataclass(frozen=True)
class SNNConfig:
    layer_sizes: tuple[int, ...] = (784, 10)   # paper: single FC 784→10
    num_steps: int = 20                        # simulation window (paper §IV-C)
    lif: lif.LIFConfig = field(default_factory=lif.LIFConfig)
    weight_bits: int = 8                       # paper: 8-bit codes (9 incl. sign ref)
    qat: bool = True                           # train through fake-quant
    surrogate_slope: float = 4.0
    readout: str = "count"                     # count|first_spike|membrane
    active_pruning: bool = False
    dot_impl: str = "int32"                    # int32 | f32 (bit-exact fast path)
    fuse_encoder: bool = False                 # PRNG+encode inside the LIF scan
    # Integer-engine backend: which realisation of the RTL datapath runs.
    #   fused          — one resumable Pallas launch for the whole
    #               encode→LIF window across the full layer stack, weights
    #               resident as int8-packed planes; neither the input nor
    #               any inter-layer spike tensor ever touches HBM (§V-B)
    #   fused_streamed — the same single launch for stacks OVER the VMEM
    #               residency budget: packed weights stay in HBM and a
    #               double-buffered DMA pipeline slabs them through a
    #               2-slot VMEM scratch, overlapped with the step loop
    #   staged    — Pallas encoder kernel + per-layer Pallas LIF kernel
    #               (every hop's spike train round-trips between launches)
    #   reference — pure-jnp scans (core.encoding / core.lif); the bit-exact
    #               oracle and the fast path on hosts without a TPU
    #   auto      — on TPU: fused for any stack that fits the residency
    #               budget, else fused_streamed when the streaming scratch
    #               fits, else staged; reference elsewhere (Pallas
    #               interpret mode is a correctness tool, not a fast CPU
    #               path)
    backend: str = "auto"
    # Event-driven tile skipping inside the fused kernels: zero-spike
    # K-tiles and fully-pruned output tiles skip the MXU pass entirely
    # (bit-identical either way — skipped tiles contribute exactly zero).
    # None defers to the REPRO_SPARSE_SKIP env default (on).
    sparse_skip: bool | None = None
    # Masked-vs-MXU dispatch boundary for the runtime density dispatch
    # (kernels.ops.spike_matmul_op mode="auto") and the baseline the
    # serving controller (serve.telemetry) retunes from live traffic.
    # None resolves REPRO_SPIKE_DENSITY_THRESHOLD → the historical
    # kernels.ops.SPIKE_DENSITY_THRESHOLD default (0.25).  Value-neutral
    # by construction: both datapaths compute the identical contraction.
    spike_density_threshold: float | None = None
    emit_trace: bool = True                    # False: no v/spike-train outputs
                                               # (prediction-only serving)
    # Float-threshold used during training; the int path scales it (below).
    train_threshold: float = 1.0

    @property
    def n_in(self) -> int:
        return self.layer_sizes[0]

    @property
    def n_classes(self) -> int:
        return self.layer_sizes[-1]


def snn_init(key: jax.Array, cfg: SNNConfig) -> dict:
    layers = []
    sizes = cfg.layer_sizes
    for i, (fan_in, fan_out) in enumerate(zip(sizes[:-1], sizes[1:])):
        key, sub = jax.random.split(key)
        # LeCun-style init scaled for spiking inputs (rate ≲ 0.5).
        w = jax.random.normal(sub, (fan_in, fan_out), jnp.float32)
        w = w * (2.0 / jnp.sqrt(fan_in))
        layers.append({"w": w})
    return {"layers": layers}


def _train_lif_cfg(cfg: SNNConfig) -> lif.LIFConfig:
    """Float-threshold LIF used in training (V_th=1.0 instead of 128)."""
    return lif.LIFConfig(
        decay_shift=cfg.lif.decay_shift,
        v_threshold=cfg.train_threshold,  # type: ignore[arg-type]
        v_rest=0,
    )


def snn_apply_float(params: dict, pixels01: jax.Array, key: jax.Array,
                    cfg: SNNConfig):
    """Differentiable forward. pixels01: (batch, n_in) in [0,1].

    Returns dict(rates=(batch, n_classes) mean firing rates,
                 spikes=(T, batch, n_classes)).
    """
    spikes = encoding.poisson_encode_jax(pixels01, key, cfg.num_steps)
    tcfg = _train_lif_cfg(cfg)
    for layer in params["layers"]:
        w = layer["w"]
        if cfg.qat:
            w = fixed_point.fake_quant(w, cfg.weight_bits)
        spikes, v_trace, _ = lif.run_lif_float(spikes, w, tcfg, cfg.surrogate_slope)
    rates = jnp.mean(spikes, axis=0)
    return {"rates": rates, "spikes": spikes, "v_trace": v_trace}


def quantize_params(params: dict, cfg: SNNConfig):
    """Float→fixed-point conversion for the integer engine.

    Scales weights so the float threshold (1.0) maps to the integer
    Threshold-Reg value (e.g. 128): w_q = round(w / s), s chosen per layer
    such that the *effective* threshold matches the RTL register.
    """
    out = []
    # Gain that maps the float threshold (1.0) onto the Threshold-Reg (128):
    # integer weight codes are w·gain, so Σ w_q·S crosses 128 exactly when the
    # float accumulator would cross 1.0 (up to rounding).
    gain = float(cfg.lif.v_threshold) / cfg.train_threshold
    # Paper §V-B: 9-bit signed weight codes (784×10×9 bits ≈ 8.6 KB).
    code_bits = cfg.weight_bits + 1
    qmin, qmax = -(1 << (code_bits - 1)), (1 << (code_bits - 1)) - 1
    for layer in params["layers"]:
        w = layer["w"]
        if cfg.qat:
            w = fixed_point.fake_quant(w, cfg.weight_bits)
        w_q = jnp.clip(jnp.round(w * gain), qmin, qmax).astype(jnp.int16)
        out.append({"w_q": w_q, "scale": jnp.float32(1.0 / gain)})
    return {"layers": out}


def fused_unsupported_reason(cfg: SNNConfig, n_layers: int,
                             layer_sizes: tuple[int, ...] | None = None,
                             trace_steps: int | None = None,
                             local_batch: int | None = None,
                             streamed: bool = False,
                             model_shards: int = 1,
                             block_b: int | None = None) -> str | None:
    """Why the fused megakernel cannot run this configuration (None = ok).

    The kernel handles arbitrary layer stacks, but it keeps every weight
    matrix (int8-packed, 2 bytes/weight) and per-layer state resident
    on-chip for the whole launch — a stack whose footprint exceeds the
    VMEM budget cannot run resident-fused.  With ``streamed=True`` the
    check is for the ``fused_streamed`` realisation instead: weights live
    in HBM and only the 2-slot DMA slab scratch plus the per-layer state
    must fit, so much wider/deeper stacks pass.  ``trace_steps`` is the
    per-launch membrane-trace length: the full window for one-shot
    execution (default), or ``chunk_steps`` for chunked/streaming callers,
    whose launches only ever allocate a chunk of trace.  ``local_batch``
    is the per-device batch tile: VMEM is a per-device resource, so a
    sharded caller (serve.ShardedSNNStreamEngine) validates against the
    launch one device actually executes — ``kernels.fused_snn.block_b_for``
    maps the local tile to the batch block that launch allocates (never
    derived from the global lane count).  ``model_shards`` scopes the
    check the same way along the neuron axis: on a ``model_shards``-way
    model mesh axis each device holds only an output-column shard of
    every layer that divides (``kernels.fused_snn.layer_shard_ways``), so
    feasibility is judged against the per-device shard footprint — how a
    WIDE stack that overflows single-device VMEM becomes resident-fused
    on a 4-way model axis.  ``block_b`` pins the batch block the launch
    will actually use (a tuned dispatch-cache shape) instead of the
    ``block_b_for(local_batch)`` heuristic — feasibility must be judged
    against the geometry the kernel really allocates.
    """
    from ..kernels import fused_snn
    if n_layers < 1:
        return "the network has no layers"
    if model_shards < 1:
        return f"model_shards={model_shards} is not a positive shard count"
    sizes = layer_sizes
    if sizes is None and len(cfg.layer_sizes) - 1 == n_layers:
        sizes = cfg.layer_sizes
    if sizes is None:
        return None                      # shapes unknown — assume it fits
    need = fused_snn.stack_vmem_bytes(
        sizes,
        (fused_snn.block_b_for(local_batch) if block_b is None
         else int(block_b)),
        cfg.num_steps if trace_steps is None else trace_steps,
        streamed=streamed, model_shards=model_shards)
    if need > fused_snn.VMEM_BUDGET_BYTES:
        kind = "streamed working set" if streamed else \
            "resident stack footprint"
        shard = (f" on a {model_shards}-way model axis"
                 if model_shards > 1 else "")
        return (f"{kind} ~{need / 2**20:.1f} MiB for "
                f"layer_sizes={tuple(sizes)}{shard} exceeds the "
                f"{fused_snn.VMEM_BUDGET_BYTES / 2**20:.0f} MiB VMEM "
                f"budget")
    return None


def resolve_backend(cfg: SNNConfig, backend: str | None = None,
                    n_layers: int = 1, *,
                    layer_sizes: tuple[int, ...] | None = None,
                    trace_steps: int | None = None,
                    local_batch: int | None = None,
                    model_shards: int = 1,
                    block_b: int | None = None,
                    dispatch_cache=None,
                    mesh_shape=(1,)) -> str:
    """Pick the integer-engine backend actually run on this host.

    ``auto`` resolves on TPU through the chain fused → fused_streamed →
    staged: the resident megakernel for any stack whose int8-packed
    footprint fits VMEM, the weight-streaming megakernel for oversized
    stacks whose DMA working set still fits, and the staged per-layer
    kernels only past that; elsewhere it resolves to the pure-jnp
    reference scans (Pallas interpret mode is far slower than XLA on CPU —
    it is a correctness tool, not a serving path).  Explicitly requesting
    ``fused`` (or ``fused_streamed``) for a configuration that realisation
    cannot run raises instead of silently degrading.  ``local_batch``
    scopes the VMEM feasibility check to one device's batch tile (see
    :func:`fused_unsupported_reason`) — data-parallel sharding never
    *shrinks* what fits, but the check must not be run against the global
    lane count either.  ``model_shards`` likewise scopes it to the
    per-device weight shard of a model mesh axis: a WIDE stack that
    resolves ``fused_streamed`` single-device resolves resident ``fused``
    on a 4-way model axis, because each device only keeps a quarter of
    every shardable layer on-chip.

    ``dispatch_cache`` (a ``repro.tune.DispatchCache``, a cache-file
    path, or ``None``) short-circuits an ``auto`` resolution: a cache
    hit for this config's fingerprint on this device kind carries the
    backend that feasibility-resolved during the tuned run, so the VMEM
    chain is consulted once at tuning time instead of recomputed at
    every startup.  A fused-family cached backend is still gated by one
    cheap feasibility check against the *cached* shapes (a mismatched
    or hand-edited cache must fall back to the normal chain, never
    crash); explicit backend requests ignore the cache entirely.
    ``block_b`` pins the tuned batch block for the feasibility math.
    """
    b = backend if backend is not None else cfg.backend
    on_tpu = jax.default_backend() == "tpu"

    if b == "auto" and dispatch_cache is not None:
        from ..tune.cache import decide_dispatch
        decision = decide_dispatch(dispatch_cache, cfg=cfg, backend="auto",
                                   mesh_shape=mesh_shape)
        if decision.hit:
            t = decision.tuned
            cached_ok = t.backend in ("staged", "reference") or (
                on_tpu and fused_unsupported_reason(
                    cfg, n_layers, layer_sizes, trace_steps,
                    t.lanes_per_device if local_batch is None
                    else local_batch,
                    streamed=(t.backend == "fused_streamed"),
                    model_shards=model_shards, block_b=t.block_b) is None)
            if cached_ok:
                return t.backend

    reason = fused_unsupported_reason(cfg, n_layers, layer_sizes,
                                      trace_steps, local_batch,
                                      model_shards=model_shards,
                                      block_b=block_b)

    def streamed_reason():
        return fused_unsupported_reason(cfg, n_layers, layer_sizes,
                                        trace_steps, local_batch,
                                        streamed=True,
                                        model_shards=model_shards,
                                        block_b=block_b)

    if b == "auto":
        if not on_tpu:
            b = "reference"
        elif reason is None:
            b = "fused"
        elif streamed_reason() is None:
            b = "fused_streamed"
        else:
            b = "staged"
    if b == "fused" and reason is not None:
        raise ValueError(
            f"backend='fused' was explicitly requested but the fused "
            f"megakernel does not support this configuration: {reason} — "
            f"use backend='fused_streamed' or 'staged'")
    if b == "fused_streamed":
        sreason = streamed_reason()
        if sreason is not None:
            raise ValueError(
                f"backend='fused_streamed' was explicitly requested but "
                f"even the weight-streaming megakernel cannot run this "
                f"configuration: {sreason} — use backend='staged'")
    if b not in ("fused", "fused_streamed", "staged", "reference"):
        raise ValueError(f"unknown SNN backend {b!r}")
    return b


def readout_pred(counts: jax.Array, first_t: jax.Array, v_final: jax.Array,
                 readout: str, num_steps: int,
                 v_trace: jax.Array | None = None,
                 v_peak: jax.Array | None = None) -> jax.Array:
    """Per-lane prediction under the configured readout.

    The single source of truth shared by ``snn_apply_int``, the streaming
    engine's stability gate / harvest path, and (mirrored op-for-op) the
    gated fused kernel.  ``count``: spike-register argmax.  ``first_spike``:
    earliest-spiking class, membrane potential as the no-spike tiebreak.
    ``membrane``: peak-membrane readout — from the carried per-lane peak
    accumulator ``v_peak`` (the streaming form: max is associative, so the
    chunked running peak is bit-identical to the one-shot maximum) or,
    when only a trace is at hand, from ``max(v_trace)`` over time.
    """
    if readout == "count":
        return jnp.argmax(counts, axis=-1)
    if readout == "membrane":
        if v_peak is not None:
            return jnp.argmax(v_peak, axis=-1)
        return pruning.peak_membrane_readout(v_trace)
    # Two score tiers: any class that spiked outranks every membrane-only
    # class (spiked tier is additive, large + (T - first), so it cannot
    # overflow int32 for any realistic window — (T - first)·large would
    # wrap already at T = 128).
    large = jnp.int32(1 << 24)
    score = jnp.where(counts > 0, large + (num_steps - first_t),
                      jnp.clip(v_final, -large + 1, large - 1))
    return jnp.argmax(score, axis=-1)


def snn_apply_int(params_q: dict, pixels_u8: jax.Array, prng_state: jax.Array,
                  cfg: SNNConfig, *, backend: str | None = None):
    """Bit-exact fixed-point inference (the RTL-equivalent engine).

    All backends (see :class:`SNNConfig.backend`; ``backend`` here overrides
    the config) implement the identical integer datapath and produce
    bit-identical spike counts / traces for the same PRNG seeds.

    Args:
      params_q: from :func:`quantize_params`.
      pixels_u8: (batch, n_in) uint8.
      prng_state: (batch, n_in) uint32 xorshift lanes.

    Returns dict(pred, spike_counts, v_trace, v_final, active_adds,
                 input_spikes, first_spike_t, prng_state, v_peak,
                 telemetry).  ``input_spikes`` is None on the fused
    backend — the spike train intentionally never exists as a tensor
    there.  ``v_peak`` is the per-layer peak-membrane tuple;
    ``telemetry`` a ``core.telemetry.ChunkTelemetry`` — both produced
    bit-identically by every backend (the fused kernels emit them as
    kernel outputs, the jnp paths re-derive them), so the activity side
    channel is cross-checkable exactly like the datapath.  Both are None
    when ``cfg.emit_trace`` is off.
    """
    b = resolve_backend(cfg, backend, len(params_q["layers"]),
                        layer_sizes=_param_sizes(params_q))
    if b in ("fused", "fused_streamed"):
        res = _apply_int_fused(params_q, pixels_u8, prng_state, cfg,
                               streamed=(b == "fused_streamed"))
    elif b == "staged":
        res = _apply_int_staged(params_q, pixels_u8, prng_state, cfg)
    else:
        res = _apply_int_reference(params_q, pixels_u8, prng_state, cfg)

    # NB: no non-array metadata in the result — callers jit this function.
    vp = res.get("v_peak")
    res["pred"] = readout_pred(res["spike_counts"], res["first_spike_t"],
                               res["v_final"], cfg.readout, cfg.num_steps,
                               v_trace=res["v_trace"],
                               v_peak=None if vp is None else vp[-1])
    return res


def _param_sizes(params_q: dict) -> tuple[int, ...]:
    return tuple([params_q["layers"][0]["w_q"].shape[0]]
                 + [l["w_q"].shape[1] for l in params_q["layers"]])


def _apply_int_fused(params_q, pixels_u8, prng_state, cfg: SNNConfig, *,
                     streamed: bool = False):
    """Fused Pallas megakernel: the whole window, all layers, one launch
    (weights resident, or HBM-streamed when ``streamed``)."""
    from ..kernels import ops
    ops.validate_weight_codes(
        tuple(layer["w_q"] for layer in params_q["layers"]))
    k = ops.fused_snn_stack_op(
        pixels_u8, prng_state,
        tuple(layer["w_q"] for layer in params_q["layers"]),
        num_steps=cfg.num_steps, decay_shift=cfg.lif.decay_shift,
        v_threshold=cfg.lif.v_threshold, v_rest=cfg.lif.v_rest,
        v_min=cfg.lif.v_min, v_max=cfg.lif.v_max,
        active_pruning=cfg.active_pruning,
        sparse_skip=cfg.sparse_skip, streamed=streamed)
    return {
        "spike_counts": k["spike_counts"],
        "v_trace": k["v_trace"],
        "v_final": k["v_final"],
        "active_adds": k["active_adds"],
        "input_spikes": None,
        "first_spike_t": k["first_spike_t"],
        "prng_state": k["prng_state"],
        "v_peak": k["v_peak"],
        "telemetry": k["telemetry"],
    }


def _derive_stack_telemetry(layer_ins, layer_outs, layer_vtr,
                            cfg: SNNConfig):
    """Telemetry + peaks re-derived from the staged/reference spike trains.

    The jnp mirror of the fused kernel's side channel: per layer, a
    neuron is enabled at step t iff it has not fired before t (or pruning
    is off), the input-spike count is the layer's consumed activity, and
    the tile counter replays the launch-geometry skip predicates via
    ``core.telemetry.layer_tile_skips`` on the same spike/enable state —
    which is what makes telemetry bit-checkable across all four backends.
    Returns ``(ChunkTelemetry, v_peak tuple)``.
    """
    ss = resolve_sparse_skip(cfg.sparse_skip)
    n_spk_l, n_en_l, tiles_l, peaks = [], [], [], []
    for x, out, vtr in zip(layer_ins, layer_outs, layer_vtr):
        xb = x.astype(bool)
        if cfg.active_pruning:
            out_i = out.astype(jnp.int32)
            en = (jnp.cumsum(out_i, axis=0) - out_i) == 0   # (T, B, n_out)
        else:
            en = jnp.ones(out.shape, bool)
        n_spk_l.append(jnp.sum(xb.astype(jnp.int32), axis=-1))   # (T, B)
        n_en_l.append(jnp.sum(en.astype(jnp.int32), axis=-1))
        tiles_l.append(jax.vmap(
            lambda xt, et: layer_tile_skips(xt, et, sparse_skip=ss))(xb, en))
        peaks.append(jnp.max(vtr, axis=0))
    tel = ChunkTelemetry(n_spk=jnp.stack(n_spk_l, axis=1),
                         n_en=jnp.stack(n_en_l, axis=1),
                         tiles_skipped=jnp.stack(tiles_l, axis=1))
    return tel, tuple(peaks)


def _apply_int_staged(params_q, pixels_u8, prng_state, cfg: SNNConfig):
    """Staged Pallas kernels: encoder launch + one LIF launch per layer."""
    from ..kernels import ops
    spikes, prng_next = ops.poisson_encode_op(
        pixels_u8, prng_state, cfg.num_steps)
    x = spikes
    layer_ins, layer_outs, layer_vtr = [], [], []
    for layer in params_q["layers"]:
        layer_ins.append(x)
        x, v_trace, v_final = ops.lif_forward_op(
            x, layer["w_q"], decay_shift=cfg.lif.decay_shift,
            v_threshold=cfg.lif.v_threshold, v_rest=cfg.lif.v_rest,
            v_min=cfg.lif.v_min, v_max=cfg.lif.v_max,
            active_pruning=cfg.active_pruning)
        layer_outs.append(x)
        layer_vtr.append(v_trace)
    # Energy + activity side channels, re-derived from the spike streams
    # (the fused kernel's counters, double-entry style): telemetry adds
    # summed over layers ARE the executed-add channel.
    telemetry, v_peak = _derive_stack_telemetry(layer_ins, layer_outs,
                                                layer_vtr, cfg)
    adds = jnp.sum(telemetry.adds, axis=1)                     # (T, B)
    out_spikes = x
    counts = jnp.sum(out_spikes.astype(jnp.int32), axis=0)
    t_idx = jnp.arange(cfg.num_steps, dtype=jnp.int32)[:, None, None]
    first_t = jnp.min(jnp.where(out_spikes, t_idx, cfg.num_steps), axis=0)
    return {
        "spike_counts": counts,
        "v_trace": v_trace,
        "v_final": v_final,
        "active_adds": adds,
        "input_spikes": spikes,
        "first_spike_t": first_t,
        "prng_state": prng_next,
        "v_peak": v_peak,
        "telemetry": telemetry,
    }


def _apply_int_reference(params_q, pixels_u8, prng_state, cfg: SNNConfig):
    """Pure-jnp scans (the original engine), incl. the fuse_encoder path."""
    if cfg.fuse_encoder and len(params_q["layers"]) == 1:
        # single fused scan: xorshift -> compare -> ΣW·S -> LIF, per step —
        # the (T, B, n_in) spike train never round-trips through memory
        # (§Perf; exactly what the RTL datapath does cycle by cycle).
        res, prng_next = _fused_encode_lif(
            params_q["layers"][0]["w_q"], pixels_u8, prng_state, cfg)
        spikes = res["input_spikes"]
        adds = res["active_adds"]
        layer_ins = [spikes]
        layer_outs = [res["spikes"]]
        layer_vtr = [res["v_trace"]]
    else:
        spikes, prng_next = encoding.poisson_encode_hw(
            pixels_u8, prng_state, cfg.num_steps)
        res = None
        adds = 0
        x = spikes
        layer_ins, layer_outs, layer_vtr = [], [], []
        for layer in params_q["layers"]:
            layer_ins.append(x)
            res = lif.run_lif_int(x, layer["w_q"], cfg.lif,
                                  active_pruning=cfg.active_pruning,
                                  dot_impl=cfg.dot_impl)
            # executed adds summed over layers (fused-kernel counter parity)
            adds = adds + res["active_adds"]
            x = res["spikes"]
            layer_outs.append(x)
            layer_vtr.append(res["v_trace"])

    if layer_vtr[0] is not None:
        telemetry, v_peak = _derive_stack_telemetry(layer_ins, layer_outs,
                                                    layer_vtr, cfg)
    else:                                  # emit_trace=False serving mode
        telemetry, v_peak = None, None
    out_spikes = res["spikes"]                       # (T, batch, n_out)
    counts = jnp.sum(out_spikes.astype(jnp.int32), axis=0)
    T = cfg.num_steps
    t_idx = jnp.arange(T, dtype=jnp.int32)[:, None, None]
    first_t = jnp.min(jnp.where(out_spikes, t_idx, T), axis=0)
    return {
        "spike_counts": counts,
        "v_trace": res["v_trace"],
        "v_final": res["state"].v,
        "active_adds": adds,
        "input_spikes": spikes,
        "first_spike_t": first_t,
        "prng_state": prng_next,
        "v_peak": v_peak,
        "telemetry": telemetry,
    }


def encode_lif_timestep(rng: jax.Array, pixels_u8: jax.Array,
                        state: lif.LIFStateInt, w_q: jax.Array,
                        lif_cfg: lif.LIFConfig, *, dot_impl: str = "int32",
                        active_pruning: bool = False):
    """One fused encoder+LIF timestep: PRNG step → spike compare → Σ W·S →
    integrate/leak/fire/reset → pruning gate.

    The single source of truth for the per-step datapath shared by the
    jnp fused scan below and the streaming engine's window chunk
    (serve.snn_engine.stream_chunk) — both must stay bit-identical to the
    staged pipeline.  Returns (rng, new_state, fired, input_spikes).
    """
    from . import prng as prng_mod
    rng = prng_mod.xorshift32_step(rng)
    s_t = pixels_u8 > prng_mod.uniform_u8(rng)
    current = lif.synaptic_current_int(s_t, w_q, dot_impl)
    current = jnp.where(state.enable, current, 0)
    new_state, fired = lif.lif_step_int(state, current, lif_cfg)
    if active_pruning:
        new_state = new_state._replace(
            enable=jnp.logical_and(new_state.enable,
                                   jnp.logical_not(fired)))
    return rng, new_state, fired, s_t


def _fused_encode_lif(w_q: jax.Array, pixels_u8: jax.Array,
                      prng_state: jax.Array, cfg: SNNConfig):
    """One scan per timestep: PRNG step, spike compare, synaptic sum, LIF
    update.  Bit-identical to the unfused pipeline (same op order)."""
    batch_shape = pixels_u8.shape[:-1]
    n_out = w_q.shape[-1]
    state0 = lif.init_state_int(batch_shape + (n_out,), cfg.lif)

    def body(carry, _):
        rng, state = carry
        rng, new_state, fired, s_t = encode_lif_timestep(
            rng, pixels_u8, state, w_q, cfg.lif, dot_impl=cfg.dot_impl,
            active_pruning=cfg.active_pruning)
        n_spk = jnp.sum(s_t.astype(jnp.int32), axis=-1)
        n_en = jnp.sum(state.enable.astype(jnp.int32), axis=-1)
        ys = (fired, new_state.v, n_spk * n_en, s_t) if cfg.emit_trace \
            else (fired,)
        return (rng, new_state), ys

    (rng_f, state_f), ys = jax.lax.scan(
        body, (prng_state, state0), None, length=cfg.num_steps)
    if cfg.emit_trace:
        spk, vtr, adds, s_all = ys
    else:
        (spk,), vtr, adds, s_all = ys, None, None, None
    res = {"spikes": spk, "v_trace": vtr, "state": state_f,
           "active_adds": adds, "n_in": w_q.shape[0], "input_spikes": s_all}
    return res, rng_f


def snn_int_stack_step(rng: jax.Array, pixels_u8: jax.Array,
                       states: tuple, weights: tuple,
                       lif_cfg: lif.LIFConfig, *, dot_impl: str = "int32",
                       active_pruning: bool = False,
                       sparse_skip: bool | None = None):
    """One fused timestep through the WHOLE layer stack.

    Layer 0 runs :func:`encode_lif_timestep` (the encoder+LIF single source
    of truth); deeper layers feed each fired vector straight into the next
    Σ W·S — the jnp mirror of the multi-layer megakernel's static layer
    loop.  Returns ``(rng, new_states, fired_out, adds, tel)`` where
    ``adds`` is the executed-add count summed over layers (energy side
    channel) and ``tel`` is this step's telemetry row — ``n_spk``/``n_en``
    (L, B) i32 and ``tiles`` (L, n_blocks) i32, the jnp mirror of the
    megakernel's side channel (``sparse_skip`` resolves the same
    REPRO_SPARSE_SKIP env rule, so the tile counter matches the kernel's
    under the CI forcing).
    """
    ss = resolve_sparse_skip(sparse_skip)
    rng, st0, fired, s_t = encode_lif_timestep(
        rng, pixels_u8, states[0], weights[0], lif_cfg, dot_impl=dot_impl,
        active_pruning=active_pruning)
    n_spk = [jnp.sum(s_t.astype(jnp.int32), axis=-1)]
    n_en = [jnp.sum(states[0].enable.astype(jnp.int32), axis=-1)]
    tiles = [layer_tile_skips(s_t, states[0].enable, sparse_skip=ss)]
    adds = n_spk[0] * n_en[0]
    new_states = [st0]
    x = fired
    for st, layer_w in zip(states[1:], weights[1:]):
        n_spk.append(jnp.sum(x.astype(jnp.int32), axis=-1))
        n_en.append(jnp.sum(st.enable.astype(jnp.int32), axis=-1))
        tiles.append(layer_tile_skips(x, st.enable, sparse_skip=ss))
        current = lif.synaptic_current_int(x, layer_w, dot_impl)
        current = jnp.where(st.enable, current, 0)
        new_st, fired = lif.lif_step_int(st, current, lif_cfg)
        adds = adds + n_spk[-1] * n_en[-1]
        if active_pruning:
            new_st = new_st._replace(
                enable=jnp.logical_and(new_st.enable,
                                       jnp.logical_not(fired)))
        new_states.append(new_st)
        x = fired
    tel = {"n_spk": jnp.stack(n_spk), "n_en": jnp.stack(n_en),
           "tiles": jnp.stack(tiles)}
    return rng, tuple(new_states), x, adds, tel


def snn_int_stack_step_sharded(rng: jax.Array, pixels_u8: jax.Array,
                               states: tuple, weights: tuple,
                               lif_cfg: lif.LIFConfig, *,
                               model_axis: str, ways: tuple[int, ...],
                               dot_impl: str = "int32",
                               active_pruning: bool = False,
                               sparse_skip: bool | None = None,
                               contraction: str = "jnp",
                               interpret: bool | None = None):
    """One stack timestep on a model mesh axis — the sharded twin of
    :func:`snn_int_stack_step`, to be traced inside ``shard_map``.

    Layer state, pixels and PRNG lanes arrive FULL (replicated over
    ``model_axis`` — the ``LaneState`` checkpoint stays placement-
    independent); each ``weights[l]`` is the device-LOCAL view: the
    output-column shard for layers ``ways[l] > 1``
    (``kernels.fused_snn.layer_shard_ways``), the whole matrix for
    layers that replicate.  Per sharded layer the device slices its own
    membrane/enable columns (``jax.lax.axis_index``), runs the partial
    Σ W·S of the full input-spike vector against its weight shard —
    ``contraction="pallas"`` launches
    ``kernels.ops.partial_contraction_op``, ``"jnp"`` the reference
    integer dot, bit-identical either way — steps LIF on the shard
    (elementwise, so the shard of the update == the update of the
    shard), then ``jax.lax.all_gather``s the fired/membrane shards back
    to full along the neuron axis.  Disjoint column shards in
    axis-index order concatenate to exactly the single-device integer
    contraction, so every derived quantity (pruning, counts, gate,
    telemetry) is computed on full arrays redundantly by every model
    peer and stays bit-identical to :func:`snn_int_stack_step`.
    Replicated layers skip the exchange entirely.

    Returns ``(rng, new_states, fired_out, adds, tel)`` exactly like the
    unsharded step; the ``tiles`` telemetry row covers THIS device's
    contraction geometry (its shard's skipped tile pairs), which the
    model-sharded chunk concatenates on the block axis.
    """
    from . import prng as prng_mod
    from ..kernels import ops as kops
    ss = resolve_sparse_skip(sparse_skip)
    rng = prng_mod.xorshift32_step(rng)
    x = pixels_u8 > prng_mod.uniform_u8(rng)

    def contract(spikes, en, w_loc):
        if contraction == "pallas":
            return kops.partial_contraction_op(
                spikes, en, w_loc, sparse_skip=ss, interpret=interpret)
        cur = lif.synaptic_current_int(spikes, w_loc, dot_impl)
        return cur, layer_tile_skips(spikes, en, sparse_skip=ss)

    n_spk, n_en, tiles, new_states = [], [], [], []
    adds = jnp.zeros(pixels_u8.shape[:-1], jnp.int32)
    for st, w_loc, w_ways in zip(states, weights, ways):
        n_spk.append(jnp.sum(x.astype(jnp.int32), axis=-1))
        n_en.append(jnp.sum(st.enable.astype(jnp.int32), axis=-1))
        adds = adds + n_spk[-1] * n_en[-1]
        if w_ways == 1:
            current, skipped = contract(x, st.enable, w_loc)
            tiles.append(skipped)
            current = jnp.where(st.enable, current, 0)
            new_st, fired = lif.lif_step_int(st, current, lif_cfg)
        else:
            shard_n = w_loc.shape[1]
            off = jax.lax.axis_index(model_axis) * shard_n
            v_sh = jax.lax.dynamic_slice_in_dim(st.v, off, shard_n, axis=-1)
            en_sh = jax.lax.dynamic_slice_in_dim(st.enable, off, shard_n,
                                                 axis=-1)
            current_sh, skipped = contract(x, en_sh, w_loc)
            tiles.append(skipped)
            current_sh = jnp.where(en_sh, current_sh, 0)
            new_sh, fired_sh = lif.lif_step_int(
                lif.LIFStateInt(v=v_sh, enable=en_sh), current_sh, lif_cfg)
            # spike exchange: every model peer recovers the full fired
            # vector (next layer's input) and membrane row, shards
            # concatenating in axis-index order == the weight slicing
            v_full = jax.lax.all_gather(new_sh.v, model_axis, axis=-1,
                                        tiled=True)
            fired = jax.lax.all_gather(fired_sh, model_axis, axis=-1,
                                       tiled=True)
            new_st = lif.LIFStateInt(v=v_full, enable=st.enable)
        if active_pruning:
            new_st = new_st._replace(
                enable=jnp.logical_and(new_st.enable,
                                       jnp.logical_not(fired)))
        new_states.append(new_st)
        x = fired
    tel = {"n_spk": jnp.stack(n_spk), "n_en": jnp.stack(n_en),
           "tiles": jnp.stack(tiles)}
    return rng, tuple(new_states), x, adds, tel


class SNNWindowState(NamedTuple):
    """Resumable mid-window state of the integer engine (a pytree).

    Carried between :func:`snn_window_chunk` calls so a T-step window can be
    executed in chunks with results bit-identical to one shot — the
    device-side contract behind the streaming engine.
    """

    rng: jax.Array          # (B, n_in) uint32 xorshift lanes
    v: tuple                # per-layer (B, n_l) int32 membranes
    en: tuple               # per-layer (B, n_l) bool clock-gates
    v_peak: tuple           # per-layer (B, n_l) int32 running peak membranes
    counts: jax.Array       # (B, n_out) int32 final-layer spike registers
    first: jax.Array        # (B, n_out) int32, sentinel = cfg.num_steps
    steps: jax.Array        # (B,) int32 window steps executed


def snn_window_init(params_q: dict, prng_state: jax.Array,
                    cfg: SNNConfig) -> SNNWindowState:
    """Fresh start-of-window state for a batch of ``prng_state.shape[0]``."""
    batch = prng_state.shape[0]
    sizes = _param_sizes(params_q)
    return SNNWindowState(
        rng=prng_state,
        v=tuple(jnp.full((batch, n), cfg.lif.v_rest, jnp.int32)
                for n in sizes[1:]),
        en=tuple(jnp.ones((batch, n), bool) for n in sizes[1:]),
        v_peak=tuple(jnp.full((batch, n), jnp.iinfo(jnp.int32).min,
                              jnp.int32) for n in sizes[1:]),
        counts=jnp.zeros((batch, sizes[-1]), jnp.int32),
        first=jnp.full((batch, sizes[-1]), cfg.num_steps, jnp.int32),
        steps=jnp.zeros((batch,), jnp.int32),
    )


def snn_window_chunk(params_q: dict, pixels_u8: jax.Array,
                     state: SNNWindowState, cfg: SNNConfig, *,
                     chunk_steps: int, backend: str | None = None):
    """Advance the window by ``chunk_steps`` steps with carried state.

    Dispatches to the resumable fused megakernel (resident or
    weight-streamed) or the pure-jnp reference scan (all bit-identical;
    the staged kernels cannot resume mid-window — requesting them
    explicitly raises, and an ``auto`` resolution that lands on staged —
    a stack too large even for weight streaming on TPU — falls back to
    the chunk-capable reference scan).  Returns ``(new_state, chunk)``
    where ``chunk`` holds the per-step ``v_trace`` (chunk, B, n_out),
    ``active_adds`` (chunk, B) and ``telemetry``
    (``core.telemetry.ChunkTelemetry``) for this segment — concatenated
    over any split of the window, all three are bit-identical to the
    one-shot record, on every chunk-capable backend.
    """
    weights = tuple(layer["w_q"] for layer in params_q["layers"])
    requested = backend if backend is not None else cfg.backend
    if requested == "staged":
        raise ValueError("chunked window execution supports the 'fused', "
                         "'fused_streamed' and 'reference' backends only "
                         "(the staged kernels cannot resume mid-window)")
    b = resolve_backend(cfg, backend, len(weights),
                        layer_sizes=_param_sizes(params_q),
                        trace_steps=chunk_steps)
    if b == "staged":                      # auto picked it; we can't run it
        b = "reference"
    if b in ("fused", "fused_streamed"):
        from ..kernels import ops
        ops.validate_weight_codes(weights)
        k = ops.fused_snn_stack_op(
            pixels_u8, state.rng, weights, num_steps=cfg.num_steps,
            chunk_steps=chunk_steps, decay_shift=cfg.lif.decay_shift,
            v_threshold=cfg.lif.v_threshold, v_rest=cfg.lif.v_rest,
            v_min=cfg.lif.v_min, v_max=cfg.lif.v_max,
            active_pruning=cfg.active_pruning,
            sparse_skip=cfg.sparse_skip,
            streamed=(b == "fused_streamed"),
            init={"v": state.v, "en": state.en, "v_peak": state.v_peak,
                  "counts": state.counts, "first": state.first,
                  "steps": state.steps})
        new_state = SNNWindowState(
            rng=k["prng_state"], v=k["v"], en=k["en"], v_peak=k["v_peak"],
            counts=k["spike_counts"], first=k["first_spike_t"],
            steps=k["steps"])
        return new_state, {"v_trace": k["v_trace"],
                           "active_adds": k["active_adds"],
                           "telemetry": k["telemetry"]}

    def body(carry, _):
        st = carry
        layer_states = tuple(lif.LIFStateInt(v=v, enable=e)
                             for v, e in zip(st.v, st.en))
        rng, new_states, fired, adds, tel = snn_int_stack_step(
            st.rng, pixels_u8, layer_states, weights, cfg.lif,
            dot_impl=cfg.dot_impl, active_pruning=cfg.active_pruning,
            sparse_skip=cfg.sparse_skip)
        counts = st.counts + fired.astype(jnp.int32)
        first = jnp.where(
            jnp.logical_and(fired, st.first == cfg.num_steps),
            st.steps[:, None], st.first)
        new = SNNWindowState(
            rng=rng,
            v=tuple(s.v for s in new_states),
            en=tuple(s.enable for s in new_states),
            v_peak=tuple(jnp.maximum(p, s.v)
                         for p, s in zip(st.v_peak, new_states)),
            counts=counts, first=first, steps=st.steps + 1)
        return new, (new_states[-1].v, adds, tel["n_spk"], tel["n_en"],
                     tel["tiles"])

    new_state, (vtr, adds, tspk, ten, ttile) = jax.lax.scan(
        body, state, None, length=chunk_steps)
    return new_state, {"v_trace": vtr, "active_adds": adds,
                       "telemetry": ChunkTelemetry(
                           n_spk=tspk, n_en=ten, tiles_skipped=ttile)}


def snn_loss(params: dict, pixels01: jax.Array, labels: jax.Array,
             key: jax.Array, cfg: SNNConfig):
    """Rate-coded cross-entropy: softmax over time-summed spike counts.

    A small L2 on rates discourages saturation (all-neurons-always-fire).
    """
    out = snn_apply_float(params, pixels01, key, cfg)
    # counts in [0, T] -> logits; scale keeps softmax in a sane range.
    logits = out["rates"] * float(cfg.num_steps) * 0.5
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, labels[:, None], axis=-1).mean()
    reg = 1e-3 * jnp.mean(out["rates"] ** 2)
    acc = jnp.mean((jnp.argmax(logits, -1) == labels).astype(jnp.float32))
    return nll + reg, {"loss": nll, "acc": acc}
