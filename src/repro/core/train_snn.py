"""Offline SNN training (the flow the paper assumes; weights arrive
trained in the RTL).  Two routes, both ending in 9-bit fixed-point codes
for the integer engine:

  * surrogate-gradient BPTT (direct SNN training, QAT through fake-quant);
  * ANN→SNN conversion (train ReLU MLP, Diehl-normalise, quantize).

``fit_or_load`` caches trained weights under results/ so benchmarks and
examples share one model.
"""

from __future__ import annotations

import os

import jax
import jax.numpy as jnp
import numpy as np

from ..data import digits
from ..data.pipeline import digit_batches
from ..optim import optimizer as opt_mod
from . import conversion, snn

__all__ = ["train_bptt", "train_converted", "fit_or_load", "int_accuracy"]


def _augment(pixels: np.ndarray, rng: np.random.Generator) -> np.ndarray:
    """Light train-time corruption (random occlusion patches + noise):
    the standard recipe that buys the paper's Fig-8 robustness."""
    x = pixels.reshape(-1, 28, 28).copy()
    n = x.shape[0]
    occ = rng.random(n) < 0.35
    for i in np.where(occ)[0]:
        s = rng.integers(5, 10)
        r0, c0 = rng.integers(0, 28 - s, 2)
        x[i, r0:r0 + s, c0:c0 + s] = 0.0
    x += rng.normal(0, 0.08, x.shape) * (rng.random((n, 1, 1)) < 0.5)
    return np.clip(x, 0, 1).reshape(n, -1).astype(np.float32)


def train_bptt(cfg: snn.SNNConfig, ds: digits.DigitDataset, *,
               steps: int = 1500, batch: int = 128, lr: float = 2e-3,
               seed: int = 0, log_every: int = 0, augment: bool = True):
    """Surrogate-gradient BPTT with QAT. Returns float params."""
    key = jax.random.PRNGKey(seed)
    params = snn.snn_init(key, cfg)
    opt = opt_mod.adamw(opt_mod.cosine_schedule(lr, steps), weight_decay=1e-4)
    state = opt.init(params)
    aug_rng = np.random.default_rng(seed + 1)

    @jax.jit
    def step(params, state, pixels, labels, key):
        (loss, aux), grads = jax.value_and_grad(snn.snn_loss, has_aux=True)(
            params, pixels, labels, key, cfg)
        grads, _ = opt_mod.clip_by_global_norm(grads, 1.0)
        updates, state = opt.update(grads, state, params)
        return opt_mod.apply_updates(params, updates), state, aux

    it = digit_batches(ds.x_train, ds.y_train, batch, seed=seed)
    for i in range(steps):
        b = next(it)
        px = _augment(b["pixels"], aug_rng) if augment else b["pixels"]
        key, sub = jax.random.split(key)
        params, state, aux = step(params, state,
                                  jnp.asarray(px),
                                  jnp.asarray(b["labels"]), sub)
        if log_every and (i + 1) % log_every == 0:
            print(f"  bptt step {i+1}: loss {float(aux['loss']):.4f} "
                  f"acc {float(aux['acc']):.3f}")
    return params


def train_converted(cfg: snn.SNNConfig, ds: digits.DigitDataset, *,
                    steps: int = 1500, batch: int = 128, lr: float = 2e-3,
                    seed: int = 0):
    """ANN→SNN route: ReLU MLP + Diehl normalisation. Returns float params."""
    key = jax.random.PRNGKey(seed)
    params = conversion.ann_init(key, cfg.layer_sizes)
    opt = opt_mod.adamw(opt_mod.cosine_schedule(lr, steps), weight_decay=1e-4)
    state = opt.init(params)

    @jax.jit
    def step(params, state, x, y):
        (loss, aux), grads = jax.value_and_grad(
            conversion.ann_loss, has_aux=True)(params, x, y)
        updates, state = opt.update(grads, state, params)
        return opt_mod.apply_updates(params, updates), state, aux

    it = digit_batches(ds.x_train, ds.y_train, batch, seed=seed)
    for _ in range(steps):
        b = next(it)
        params, state, aux = step(params, state, jnp.asarray(b["pixels"]),
                                  jnp.asarray(b["labels"]))
    calib = jnp.asarray(ds.x_train[:512])
    return conversion.convert_ann_to_snn(params, calib)


def int_accuracy(params_q: dict, cfg: snn.SNNConfig, x: np.ndarray,
                 y: np.ndarray, *, num_steps: int | None = None,
                 seed: int = 1234, batch: int = 500):
    """Accuracy of the bit-exact integer engine; returns (acc, aux dict)."""
    import dataclasses
    from . import prng
    if num_steps is not None:
        cfg = dataclasses.replace(cfg, num_steps=num_steps)
    preds, adds = [], []
    apply_jit = jax.jit(
        lambda p, px, st: snn.snn_apply_int(p, px, st, cfg))
    for i in range(0, len(y), batch):
        px = jnp.asarray((x[i:i + batch] * 255).astype(np.uint8))
        st = prng.seed_state(seed + i, px.shape)
        out = apply_jit(params_q, px, st)
        preds.append(np.asarray(out["pred"]))
        adds.append(np.asarray(out["active_adds"]).sum(0))
    acc = float((np.concatenate(preds) == y[:len(np.concatenate(preds))]).mean())
    return acc, {"adds_per_img": float(np.concatenate(adds).mean())}


def fit_or_load(cfg: snn.SNNConfig | None = None, *, route: str = "bptt",
                cache: str = "results/snn_weights.npz",
                steps: int = 1500, seed: int = 0, force: bool = False):
    """Train (or load cached) paper-topology weights; returns
    (float_params, quantized_params, dataset)."""
    from ..configs.snn_mnist import SNN_CONFIG
    cfg = cfg or SNN_CONFIG
    ds = digits.make_dataset(seed=0)
    if os.path.exists(cache) and not force:
        z = np.load(cache)
        params = {"layers": [{"w": jnp.asarray(z[f"w{i}"])}
                             for i in range(len(z.files))]}
    else:
        if route == "convert":
            params = train_converted(cfg, ds, steps=steps, seed=seed)
        else:
            params = train_bptt(cfg, ds, steps=steps, seed=seed)
        os.makedirs(os.path.dirname(cache) or ".", exist_ok=True)
        np.savez(cache, **{f"w{i}": np.asarray(l["w"])
                           for i, l in enumerate(params["layers"])})
    return params, snn.quantize_params(params, cfg), ds
