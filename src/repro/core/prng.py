"""Bit-exact 32-bit XOR-shift PRNG (paper §III-C).

The RTL uses a 32-bit xorshift register (Marsaglia 2003, the canonical
13/17/5 triple) to drive the on-chip Poisson encoder.  We reproduce it
bit-exactly with ``jnp.uint32`` ops so that, given the same seed layout, the
JAX model and the SystemVerilog testbench generate identical spike trains.

State layout: one independent 32-bit register per pixel (the RTL instantiates
one PRNG lane per input channel), vectorised as a ``uint32`` array.  Seeds of
zero are remapped (xorshift has a zero fixed point, as does the RTL, which
seeds registers from a non-zero LFSR preload).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "seed_state",
    "xorshift32_step",
    "xorshift32_sequence",
    "uniform_u8",
]

# Golden constant used by the RTL preloader to displace zero seeds.
_ZERO_SEED_REMAP = np.uint32(0x9E3779B9)  # 2**32 / golden ratio


def seed_state(key_or_int, shape: tuple[int, ...]) -> jax.Array:
    """Build a per-lane uint32 xorshift state array.

    Accepts either a python int (hashed counter seeding, matching the RTL's
    LFSR preload chain) or a ``jax.random`` key (used by the training paths,
    where bit-compatibility with RTL is not required).
    """
    if isinstance(key_or_int, (int, np.integer)):
        n = int(np.prod(shape)) if shape else 1
        with np.errstate(over="ignore"):  # intentional mod-2^64 wraparound
            lane = np.arange(n, dtype=np.uint64)
            s = (np.uint64(key_or_int) * np.uint64(0x9E3779B97F4A7C15)
                 + lane * np.uint64(0xBF58476D1CE4E5B9))
            # SplitMix64-style finalizer, truncated to 32 bits.
            s ^= s >> np.uint64(30)
            s *= np.uint64(0xBF58476D1CE4E5B9)
            s ^= s >> np.uint64(27)
            s *= np.uint64(0x94D049BB133111EB)
            s ^= s >> np.uint64(31)
        state = (s & np.uint64(0xFFFFFFFF)).astype(np.uint32).reshape(shape)
        state = np.where(state == 0, _ZERO_SEED_REMAP, state)
        return jnp.asarray(state)
    # jax key path
    bits = jax.random.bits(key_or_int, shape, dtype=jnp.uint32)
    return jnp.where(bits == 0, jnp.uint32(_ZERO_SEED_REMAP), bits)


def xorshift32_step(state: jax.Array) -> jax.Array:
    """One xorshift32 update: x ^= x<<13; x ^= x>>17; x ^= x<<5 (mod 2^32)."""
    if state.dtype != jnp.uint32:
        raise TypeError(f"xorshift32 state must be uint32, got {state.dtype}")
    x = state
    x = x ^ (x << 13)
    x = x ^ (x >> 17)
    x = x ^ (x << 5)
    return x


def xorshift32_sequence(state: jax.Array, num_steps: int) -> tuple[jax.Array, jax.Array]:
    """Run ``num_steps`` updates; returns (final_state, stacked outputs [T, ...])."""

    def body(s, _):
        s = xorshift32_step(s)
        return s, s

    final, seq = jax.lax.scan(body, state, None, length=num_steps)
    return final, seq


def uniform_u8(state: jax.Array) -> jax.Array:
    """Map a 32-bit state to the 8-bit comparison value used by the encoder.

    The RTL compares pixel intensity (0..255) against the PRNG's top byte —
    taking the high bits is standard practice because xorshift's low bits are
    weaker.  Returns uint8 in [0, 255].
    """
    return (state >> 24).astype(jnp.uint8)
