"""Core library: the paper's contribution (Poisson-encoded fixed-point SNN).

Layout:
  prng         — bit-exact xorshift32 (the RTL's PRNG)
  encoding     — Poisson spike encoder (hardware-faithful + training variants)
  lif          — LIF neuron dynamics: integer (RTL-equivalent) + float (BPTT)
  pruning      — active pruning controller + readouts + early-exit
  snn          — the composable SNN module (init/apply/loss/quantize)
  conversion   — ANN→SNN weight conversion (Diehl-style normalisation)
  fixed_point  — quantisation utilities (incl. stochastic rounding, QAT)
  energy       — op counting + Horowitz energy model (paper Table II)
  telemetry    — structured kernel↔host activity side channel
"""

from . import (conversion, encoding, energy, fixed_point, lif, pruning, prng,
               snn, telemetry)

__all__ = [
    "conversion", "encoding", "energy", "fixed_point", "lif", "pruning",
    "prng", "snn", "telemetry",
]
