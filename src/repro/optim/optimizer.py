"""Optimizers (optax-free: the container has no optax, so we own the math).

API mirrors optax minimally:  ``opt = adamw(...); state = opt.init(params);
updates, state = opt.update(grads, state, params); params = apply_updates``.
All states are pytrees of arrays → they shard/checkpoint like params.

Included: sgd (momentum), adamw, adafactor (factored second moment — the
memory plan for the ≥100B archs), global-norm clipping, schedules.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

__all__ = [
    "Optimizer", "sgd", "adamw", "adafactor", "apply_updates",
    "clip_by_global_norm", "global_norm",
    "cosine_schedule", "linear_warmup_cosine", "constant_schedule",
]

Pytree = Any


@dataclass(frozen=True)
class Optimizer:
    init: Callable[[Pytree], Pytree]
    update: Callable[..., tuple[Pytree, Pytree]]  # (grads, state, params) -> (updates, state)


def global_norm(tree: Pytree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves))


def clip_by_global_norm(grads: Pytree, max_norm: float) -> tuple[Pytree, jax.Array]:
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree.map(lambda g: g * scale, grads), norm


def apply_updates(params: Pytree, updates: Pytree) -> Pytree:
    return jax.tree.map(lambda p, u: (p + u).astype(p.dtype), params, updates)


# ---------------------------------------------------------------------------
# Schedules
# ---------------------------------------------------------------------------

def constant_schedule(lr: float):
    return lambda step: jnp.asarray(lr, jnp.float32)


def cosine_schedule(lr: float, total_steps: int, final_frac: float = 0.1):
    def fn(step):
        t = jnp.clip(step / max(total_steps, 1), 0.0, 1.0)
        cos = 0.5 * (1 + jnp.cos(jnp.pi * t))
        return lr * (final_frac + (1 - final_frac) * cos)
    return fn


def linear_warmup_cosine(lr: float, warmup: int, total_steps: int,
                         final_frac: float = 0.1):
    cos = cosine_schedule(lr, max(total_steps - warmup, 1), final_frac)
    def fn(step):
        w = jnp.clip(step / max(warmup, 1), 0.0, 1.0)
        return jnp.where(step < warmup, lr * w, cos(step - warmup))
    return fn


# ---------------------------------------------------------------------------
# SGD / AdamW
# ---------------------------------------------------------------------------

class SGDState(NamedTuple):
    step: jax.Array
    momentum: Pytree


def sgd(schedule, momentum: float = 0.9, nesterov: bool = False) -> Optimizer:
    def init(params):
        return SGDState(jnp.zeros((), jnp.int32),
                        jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params))

    def update(grads, state, params=None):
        lr = schedule(state.step)
        mom = jax.tree.map(lambda m, g: momentum * m + g.astype(jnp.float32),
                           state.momentum, grads)
        if nesterov:
            upd = jax.tree.map(lambda m, g: -(lr * (momentum * m + g)), mom, grads)
        else:
            upd = jax.tree.map(lambda m: -lr * m, mom)
        return upd, SGDState(state.step + 1, mom)

    return Optimizer(init, update)


class AdamWState(NamedTuple):
    step: jax.Array
    mu: Pytree
    nu: Pytree


def adamw(schedule, b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
          weight_decay: float = 0.0) -> Optimizer:
    def init(params):
        z = lambda p: jnp.zeros_like(p, jnp.float32)
        return AdamWState(jnp.zeros((), jnp.int32),
                          jax.tree.map(z, params), jax.tree.map(z, params))

    def update(grads, state, params):
        step = state.step + 1
        lr = schedule(state.step)
        mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g.astype(jnp.float32),
                          state.mu, grads)
        nu = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * jnp.square(g.astype(jnp.float32)),
                          state.nu, grads)
        bc1 = 1 - b1 ** step.astype(jnp.float32)
        bc2 = 1 - b2 ** step.astype(jnp.float32)

        def u(m, v, p):
            upd = (m / bc1) / (jnp.sqrt(v / bc2) + eps)
            if weight_decay:
                upd = upd + weight_decay * p.astype(jnp.float32)
            return -lr * upd

        return jax.tree.map(u, mu, nu, params), AdamWState(step, mu, nu)

    return Optimizer(init, update)


# ---------------------------------------------------------------------------
# Adafactor (Shazeer & Stern 2018) — factored second moment: the optimizer
# state for a (n, m) matrix is n + m floats instead of n·m, which is what
# lets the 340B/480B configs fit the 16 GB/chip budget (see DESIGN.md §4).
# ---------------------------------------------------------------------------

class AdafactorState(NamedTuple):
    step: jax.Array
    vr: Pytree   # row second-moment (or full moment for <2D leaves)
    vc: Pytree   # col second-moment (dummy for <2D leaves)


def adafactor(schedule, decay: float = 0.8, eps: float = 1e-30,
              clip_threshold: float = 1.0, min_dim_factored: int = 128,
              weight_decay: float = 0.0) -> Optimizer:
    def _factored(p):
        # Factor the trailing dim against everything before it: covers both
        # plain (in, out) matrices and head-split / block-stacked tensors
        # like (L, d, heads, hd) — the leading dims behave as batch dims in
        # the rank-1 reconstruction (they broadcast through r·c).
        if p.ndim < 2 or p.shape[-1] < min_dim_factored:
            return False
        lead = 1
        for s in p.shape[:-1]:
            lead *= s
        return lead >= min_dim_factored

    def init(params):
        def vrow(p):
            if _factored(p):
                return jnp.zeros(p.shape[:-1], jnp.float32)
            return jnp.zeros_like(p, jnp.float32)

        def vcol(p):
            if _factored(p):
                return jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)
            # dummy; leading dim kept so block-stacked leaves stay scannable
            return jnp.zeros(p.shape[:1] or (1,), jnp.float32)

        return AdafactorState(jnp.zeros((), jnp.int32),
                              jax.tree.map(vrow, params),
                              jax.tree.map(vcol, params))

    def update(grads, state, params):
        step = state.step + 1
        lr = schedule(state.step)
        # beta2 ramps toward 1 (Shazeer-Stern schedule).
        beta2 = 1.0 - step.astype(jnp.float32) ** (-decay)

        def upd(g, vr, vc, p):
            g = g.astype(jnp.float32)
            g2 = jnp.square(g) + eps
            # factored-ness is inferred from the state shape so that block
            # slices of stacked leaves (train.streamed_update) stay
            # consistent with the decision made at init time.
            is_factored = (p.ndim >= 2 and vr.shape == p.shape[:-1]
                           and vr.shape != p.shape)
            if is_factored:
                new_vr = beta2 * vr + (1 - beta2) * jnp.mean(g2, axis=-1)
                new_vc = beta2 * vc + (1 - beta2) * jnp.mean(g2, axis=-2)
                # rank-1 reconstruction of the preconditioner
                r = new_vr / jnp.maximum(
                    jnp.mean(new_vr, axis=-1, keepdims=True), eps)
                u = g / (jnp.sqrt(r)[..., None] * jnp.sqrt(new_vc)[..., None, :] + eps)
            else:
                new_vr = beta2 * vr + (1 - beta2) * g2
                new_vc = vc
                u = g / (jnp.sqrt(new_vr) + eps)
            # update clipping by RMS
            rms = jnp.sqrt(jnp.mean(jnp.square(u)) + eps)
            u = u / jnp.maximum(1.0, rms / clip_threshold)
            if weight_decay:
                u = u + weight_decay * p.astype(jnp.float32)
            return -lr * u, new_vr, new_vc

        flat_p, treedef = jax.tree.flatten(params)
        flat_g = treedef.flatten_up_to(grads)
        flat_vr = treedef.flatten_up_to(state.vr)
        flat_vc = treedef.flatten_up_to(state.vc)
        outs = [upd(g, vr, vc, p) for g, vr, vc, p in
                zip(flat_g, flat_vr, flat_vc, flat_p)]
        updates = treedef.unflatten([o[0] for o in outs])
        new_vr = treedef.unflatten([o[1] for o in outs])
        new_vc = treedef.unflatten([o[2] for o in outs])
        return updates, AdafactorState(step, new_vr, new_vc)

    return Optimizer(init, update)
