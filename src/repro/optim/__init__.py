"""Optimizer substrate: sgd/adamw/adafactor, schedules, clipping, and int8
error-feedback gradient compression for the cross-pod reduction."""

from . import compression, optimizer
from .optimizer import (adafactor, adamw, apply_updates, clip_by_global_norm,
                        constant_schedule, cosine_schedule, global_norm,
                        linear_warmup_cosine, sgd)

__all__ = [
    "compression", "optimizer", "adafactor", "adamw", "apply_updates",
    "clip_by_global_norm", "constant_schedule", "cosine_schedule",
    "global_norm", "linear_warmup_cosine", "sgd",
]
