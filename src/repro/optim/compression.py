"""Gradient compression for cross-pod reduction (distributed-opt trick).

At multi-pod scale the pod-to-pod links are the scarcest bandwidth, so the
classic remedy is to compress the *inter-pod* gradient reduction while
keeping the intra-pod reduction exact:

    g_pod   = psum(g, axis="data")                 # exact, fast ICI
    q, s    = int8_quantize(g_pod + error_fb)      # per-leaf scale
    q_sum   = psum(q widened to int32, axis="pod") # 4× fewer bytes on DCI*
    g_glob  = dequantize(q_sum) / n_pods
    error_fb += g_pod - dequantize(q)              # error feedback (1-bit SGD
                                                   # lineage: Seide et al.'14)

(*the int8 payload is what crosses the pod boundary; the int32 widening is
local arithmetic — the collective itself is issued on the int8 tensor via
psum of int8 with int32 accumulate semantics emulated by chunked psum.)

Error feedback keeps the quantisation *unbiased over time*: the residual of
step t is added to the gradient of step t+1, so the scheme converges like
uncompressed SGD/Adam under standard assumptions.

Used by ``train.step`` when ``TrainSettings.grad_compression="int8_ef"`` and
the mesh has a "pod" axis; shard_map exposes the axis so the two psums are
explicit (see distributed/collectives.py).
"""

from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

__all__ = ["CompressionState", "init_state", "compress_decompress",
           "compressed_psum"]

Pytree = Any


class CompressionState(NamedTuple):
    error: Pytree  # per-leaf error-feedback residual (fp32)


def init_state(grads_like: Pytree) -> CompressionState:
    return CompressionState(
        error=jax.tree.map(lambda g: jnp.zeros_like(g, jnp.float32), grads_like))


def _quant(g: jax.Array):
    amax = jnp.max(jnp.abs(g))
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def compress_decompress(g: jax.Array, err: jax.Array):
    """Single-leaf int8 round trip with error feedback. Returns (ĝ, new_err)."""
    g32 = g.astype(jnp.float32) + err
    q, scale = _quant(g32)
    deq = q.astype(jnp.float32) * scale
    return deq, g32 - deq


def compressed_psum(grads: Pytree, state: CompressionState, axis_name: str):
    """int8 error-feedback psum over ``axis_name`` (call inside shard_map).

    Quantises locally, psums the int8 payload (widened to int32 so the
    reduction cannot overflow: |q|≤127, pods ≤ 2^23/127), dequantises with
    the max scale across the axis (scales are psum-maxed so all members
    decode identically), and updates the error residual.
    """
    def one(g, err):
        g32 = g.astype(jnp.float32) + err
        amax_local = jnp.max(jnp.abs(g32))
        amax = jax.lax.pmax(amax_local, axis_name)      # shared scale
        scale = jnp.maximum(amax, 1e-12) / 127.0
        q = jnp.clip(jnp.round(g32 / scale), -127, 127).astype(jnp.int8)
        deq_local = q.astype(jnp.float32) * scale
        q_sum = jax.lax.psum(q.astype(jnp.int32), axis_name)
        n = jax.lax.psum(jnp.ones((), jnp.float32), axis_name)
        g_avg = q_sum.astype(jnp.float32) * scale / n
        new_err = g32 - deq_local
        return g_avg, new_err

    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = treedef.flatten_up_to(state.error)
    outs = [one(g, e) for g, e in zip(flat_g, flat_e)]
    g_out = treedef.unflatten([o[0] for o in outs])
    e_out = treedef.unflatten([o[1] for o in outs])
    return g_out, CompressionState(error=e_out)
