"""Deterministic fault injection + the serving fault-tolerance vocabulary.

The serving stack through PR 6 assumes every engine, device and kernel
launch succeeds forever: one failed dispatch loses every in-flight window
on that engine and wedges the router.  Hardware-SNN deployments lean on
exactly the opposite — the paper's active-pruning mechanism *disables*
neurons post-classification rather than failing hard, and SparrowSNN
co-designs around partial-availability operation on battery-edge devices
— so the serving tier should survive faults the way the datapath survives
pruning.  This module provides the two halves of that layer:

**Deterministic fault injection** — :class:`FaultPlan` is a seeded,
replayable schedule of injected failures (transient dispatch exceptions,
engine hangs past a chunk deadline, device loss with or without lane
state, corrupted telemetry chunks, poison requests that fault wherever
they are dispatched).  A :class:`FaultInjector` binds one engine to the
plan and is consulted by ``SNNStreamEngine._dispatch_chunk`` before and
after every launch — single-device and sharded paths alike.  Fault
decisions are pure functions of ``(plan seed, engine id, consult index,
attempt)``, so a replayed run injects the identical fault sequence: CI
can run the whole router/engine suite under a seeded plan
(``REPRO_FAULT_PLAN=seed=11,dispatch=0.03``) and require bit-identical
results, because every recovery path is value-neutral by construction.

**Recovery vocabulary** — the typed exceptions the engines raise
(:class:`DispatchFault` transient, :class:`DeviceLostFault` permanent,
:class:`PoisonDispatchError` request-attributed, :class:`EngineFailure`
the escalation the tier's failover consumes), the per-engine
:class:`EngineHealthState` the health surface is built from, the
:class:`FaultToleranceConfig` policy knobs (retry budget, deterministic
backoff, demotion/promotion thresholds, watchdog deadline, quarantine
count), and :class:`FaultRecord` — the never-silent accounting entry for
a window that could not be served (mirroring ``router.ShedRecord``:
``results ∪ shed ∪ faulted`` exactly partitions the submitted ids).

Nothing here imports jax at module scope: the plan/health machinery is
pure host bookkeeping, importable from configs and benchmarks alike.
"""

from __future__ import annotations

import os
from dataclasses import dataclass, field

import numpy as np

__all__ = [
    "DispatchFault", "DeviceLostFault", "PoisonDispatchError",
    "EngineFailure", "FaultEvent", "FaultPlan", "FaultPlanSpecError",
    "FaultInjector", "FaultToleranceConfig", "EngineHealthState",
    "FaultRecord", "telemetry_ok", "injector_from_env",
    "REPRO_FAULT_PLAN_ENV", "FAULT_PLAN_GRAMMAR",
]

REPRO_FAULT_PLAN_ENV = "REPRO_FAULT_PLAN"

# The accepted REPRO_FAULT_PLAN grammar — quoted verbatim by every spec
# rejection so a typo'd key fails with the fix in the message.
FAULT_PLAN_GRAMMAR = (
    "seed=<int> | dispatch=<rate in [0,1]> | telemetry=<rate in [0,1]> | "
    "worker_kill=<worker>@<round> | worker_hang=<worker>@<round> | "
    "coordinator_kill=<round>   (comma-separated; worker_kill/worker_hang/"
    "coordinator_kill may repeat)")


# ---- typed faults ---------------------------------------------------------

class FaultError(RuntimeError):
    """Base of every injected/declared serving fault."""


class DispatchFault(FaultError):
    """Transient chunk-dispatch failure (retryable; the backoff path)."""

    def __init__(self, msg: str, *, engine: int, seq: int, attempt: int):
        super().__init__(msg)
        self.engine, self.seq, self.attempt = engine, seq, attempt


class DeviceLostFault(FaultError):
    """Permanent device loss.  ``state_lost=True`` additionally marks the
    lane state unrecoverable — the in-flight windows cannot be evacuated
    and must be shed with :class:`FaultRecord`\\ s."""

    def __init__(self, msg: str, *, engine: int, state_lost: bool = False):
        super().__init__(msg)
        self.engine, self.state_lost = engine, state_lost


class PoisonDispatchError(FaultError):
    """A specific request faults every launch that includes it.  Raised
    before the launch (the lane state is intact), carrying the request id
    so the tier can evict the lane, retry it elsewhere, and quarantine it
    after ``FaultToleranceConfig.quarantine_after`` faults."""

    def __init__(self, msg: str, *, request_id: int, engine: int):
        super().__init__(msg)
        self.request_id, self.engine = request_id, engine


class EngineFailure(FaultError):
    """An engine declared itself failed — the tier's failover trigger.

    ``reason`` is ``"device_lost"``, ``"hang"`` (chunk-deadline watchdog
    tripped) or ``"dispatch_exhausted"`` (transient faults persisted past
    the retry/demotion budget).  ``state_lost`` says whether the lane
    snapshot survives for evacuation.
    """

    def __init__(self, msg: str, *, engine: int, reason: str,
                 state_lost: bool = False):
        super().__init__(msg)
        self.engine, self.reason, self.state_lost = engine, reason, state_lost


# ---- the plan -------------------------------------------------------------

class FaultPlanSpecError(ValueError):
    """A malformed ``REPRO_FAULT_PLAN``-style spec, rejected loudly.

    Names the offending key/value and quotes the accepted grammar — a
    typo (``dipsatch=0.03``) must fail the run, never silently arm
    nothing while CI believes chaos is on.
    """

    def __init__(self, key: str, detail: str):
        self.key = key
        super().__init__(
            f"bad {REPRO_FAULT_PLAN_ENV} entry {key!r}: {detail} — "
            f"accepted grammar: {FAULT_PLAN_GRAMMAR}")


@dataclass(frozen=True)
class FaultEvent:
    """One scheduled fault.  ``chunk`` coordinates are engine-local
    dispatch-consult indices (the injector counts every ``before``
    consultation, retries included — deterministic because the serving
    loops are single-threaded); ``last_chunk=None`` means the fault
    persists forever (a "kill"), a bounded range models a transient
    brown-out the retry/backoff/ladder machinery should ride through.

    kinds: ``dispatch`` (transient launch exception), ``hang`` (the
    device stalls — dispatches make no progress until the watchdog
    trips), ``device_loss`` (permanent; ``state_lost`` optionally
    destroys the lane snapshot), ``telemetry`` (the side-channel record
    of this chunk comes back corrupted), ``poison`` (every launch
    containing ``request_id`` faults, on any engine).
    ``backends`` restricts a ``dispatch`` fault to specific chunk
    backends — the degradation-ladder tests use it to fail the fused
    launch persistently while the demoted rungs stay clean.

    Process-level kinds (serve.cluster — coordinates are the
    coordinator's **global scheduling round**, which never resets across
    worker respawns, so a windowed kill fires in exactly one
    incarnation): ``worker_kill`` (the worker process exits hard before
    running the round's chunk; ``engine`` is the worker slot and
    ``state_lost`` additionally discards the coordinator's shipped
    checkpoint — simulating correlated loss of host and replica),
    ``worker_hang`` (the worker stops responding — the heartbeat-drop
    fault; the coordinator's deadline detects it), ``coordinator_kill``
    (the coordinator itself dies at the top of the round — recovery must
    come from the write-ahead ledger).
    """

    kind: str                        # dispatch|hang|device_loss|telemetry|poison
    engine: int | None = None        # None = any engine
    first_chunk: int = 0
    last_chunk: int | None = None    # inclusive; None = forever
    request_id: int | None = None    # poison target
    backends: tuple | None = None    # dispatch: only these backends fault
    state_lost: bool = False         # device_loss: snapshot unrecoverable

    def _active(self, engine: int, seq: int) -> bool:
        if self.engine is not None and engine != self.engine:
            return False
        if seq < self.first_chunk:
            return False
        return self.last_chunk is None or seq <= self.last_chunk


class FaultPlan:
    """Seeded, replayable schedule of injected failures.

    Two layers compose: explicit :class:`FaultEvent`\\ s (targeted kills
    and brown-outs — what the failover contract tests drive) and seeded
    *rates* (``dispatch_rate``/``telemetry_rate`` — background chaos for
    whole-suite CI runs).  Rate decisions hash ``(seed, engine, consult
    index, attempt)`` through an independent PRNG stream per coordinate,
    so the same plan replayed injects the identical faults, and a retry
    (new attempt) re-rolls rather than deterministically re-faulting.

    ``REPRO_FAULT_PLAN`` activates a plan for every engine constructed
    without an explicit injector, spec ``seed=11,dispatch=0.03,
    telemetry=0.02`` — rates only, because transient faults are the one
    class a *standalone* engine fully absorbs (hangs/device loss need a
    tier to evacuate to).
    """

    def __init__(self, events: tuple = (), *, seed: int = 0,
                 dispatch_rate: float = 0.0, telemetry_rate: float = 0.0):
        self.events = tuple(events)
        self.seed = int(seed)
        self.dispatch_rate = float(dispatch_rate)
        self.telemetry_rate = float(telemetry_rate)

    # -- construction ------------------------------------------------------
    @classmethod
    def from_spec(cls, spec: str) -> "FaultPlan":
        """Parse the compact ``k=v[,k=v...]`` env spec.

        Strict: unknown keys, malformed values and out-of-range rates
        all raise :class:`FaultPlanSpecError` quoting the accepted
        grammar (:data:`FAULT_PLAN_GRAMMAR`).  Beyond the seeded rates,
        the spec can schedule the process-level faults the cluster
        chaos lane drives: ``worker_kill=1@3`` kills worker 1 at global
        round 3, ``worker_hang=0@2`` drops worker 0's heartbeat from
        round 2, ``coordinator_kill=5`` crashes the coordinator at the
        top of round 5.
        """
        rates = {"seed": "seed", "dispatch": "dispatch_rate",
                 "telemetry": "telemetry_rate"}
        kw: dict = {"seed": 0, "dispatch_rate": 0.0, "telemetry_rate": 0.0}
        events: list[FaultEvent] = []
        for part in spec.split(","):
            part = part.strip()
            if not part:
                continue
            k, eq, v = part.partition("=")
            if not eq:
                raise FaultPlanSpecError(part, "missing '=<value>'")
            if k in rates:
                try:
                    val = int(v) if k == "seed" else float(v)
                except ValueError:
                    raise FaultPlanSpecError(
                        part, f"value {v!r} is not "
                        f"{'an integer' if k == 'seed' else 'a number'}"
                    ) from None
                if k != "seed" and not 0.0 <= val <= 1.0:
                    raise FaultPlanSpecError(
                        part, f"rate {val} outside [0, 1]")
                kw[rates[k]] = val
            elif k in ("worker_kill", "worker_hang"):
                w, at, r = v.partition("@")
                try:
                    if not at:
                        raise ValueError
                    worker, rnd = int(w), int(r)
                except ValueError:
                    raise FaultPlanSpecError(
                        part, f"value {v!r} is not '<worker>@<round>' "
                        f"(two integers)") from None
                if worker < 0 or rnd < 0:
                    raise FaultPlanSpecError(
                        part, "worker and round must be >= 0")
                events.append(FaultEvent(
                    kind=k, engine=worker, first_chunk=rnd,
                    last_chunk=rnd))
            elif k == "coordinator_kill":
                try:
                    rnd = int(v)
                except ValueError:
                    raise FaultPlanSpecError(
                        part, f"value {v!r} is not an integer round"
                    ) from None
                if rnd < 0:
                    raise FaultPlanSpecError(part, "round must be >= 0")
                events.append(FaultEvent(
                    kind=k, first_chunk=rnd, last_chunk=rnd))
            else:
                known = sorted(rates) + ["worker_kill", "worker_hang",
                                         "coordinator_kill"]
                raise FaultPlanSpecError(
                    part, f"unknown key {k!r} (known keys: {known})")
        return cls(tuple(events), seed=kw.pop("seed"), **kw)

    @classmethod
    def from_env(cls) -> "FaultPlan | None":
        spec = os.environ.get(REPRO_FAULT_PLAN_ENV)
        return cls.from_spec(spec) if spec else None

    # -- queries (pure in (engine, seq, attempt)) --------------------------
    def _roll(self, *coords: int) -> float:
        return float(np.random.default_rng(
            (self.seed,) + tuple(int(c) for c in coords)).random())

    def poison_rid(self, engine: int, seq: int, rids) -> int | None:
        for ev in self.events:
            if (ev.kind == "poison" and ev._active(engine, seq)
                    and ev.request_id in rids):
                return ev.request_id
        return None

    def device_loss(self, engine: int, seq: int) -> FaultEvent | None:
        for ev in self.events:
            if ev.kind == "device_loss" and ev._active(engine, seq):
                return ev
        return None

    def hang(self, engine: int, seq: int) -> bool:
        return any(ev.kind == "hang" and ev._active(engine, seq)
                   for ev in self.events)

    def dispatch_fault(self, engine: int, seq: int, attempt: int,
                       backend: str) -> bool:
        for ev in self.events:
            if (ev.kind == "dispatch" and ev._active(engine, seq)
                    and (ev.backends is None or backend in ev.backends)):
                return True
        if self.dispatch_rate > 0.0:
            return self._roll(engine, seq, attempt, 0) < self.dispatch_rate
        return False

    def corrupt_telemetry(self, engine: int, seq: int) -> bool:
        if any(ev.kind == "telemetry" and ev._active(engine, seq)
               for ev in self.events):
            return True
        if self.telemetry_rate > 0.0:
            return self._roll(engine, seq, 1) < self.telemetry_rate
        return False

    # -- process-level queries (serve.cluster; coords = global round) ------
    def worker_kill(self, worker: int, rnd: int) -> "FaultEvent | None":
        for ev in self.events:
            if ev.kind == "worker_kill" and ev._active(worker, rnd):
                return ev
        return None

    def worker_hang(self, worker: int, rnd: int) -> bool:
        return any(ev.kind == "worker_hang" and ev._active(worker, rnd)
                   for ev in self.events)

    def coordinator_kill(self, rnd: int) -> bool:
        # engine is irrelevant for the coordinator's own death; _active's
        # engine filter is bypassed by matching the event's own slot
        return any(ev.kind == "coordinator_kill"
                   and ev._active(ev.engine if ev.engine is not None
                                  else 0, rnd)
                   for ev in self.events)

    def engine_relevant(self, engine: int) -> bool:
        """Whether a *worker-local* engine injector would ever fire —
        rates, or any non-process event that can reach ``engine``."""
        if self.dispatch_rate > 0.0 or self.telemetry_rate > 0.0:
            return True
        return any(
            ev.kind in ("dispatch", "hang", "device_loss", "telemetry",
                        "poison")
            and (ev.engine is None or ev.engine == engine)
            for ev in self.events)


class FaultInjector:
    """One engine's binding to a :class:`FaultPlan`.

    The engine consults :meth:`before_dispatch` ahead of every launch
    attempt (it raises the scheduled typed fault, or returns ``"hang"``
    when the device should stall this chunk) and passes each launch's
    telemetry through :meth:`filter_telemetry` (which corrupts the record
    when the plan says so — the engine's own validation must catch it).
    The injector owns the monotone consult counter, so the fault
    coordinates are a pure function of the (single-threaded) call
    sequence.
    """

    def __init__(self, plan: FaultPlan, engine_id: int = 0):
        self.plan = plan
        self.engine_id = int(engine_id)
        self.consults = 0               # dispatch-consult index ("chunk")

    def before_dispatch(self, attempt: int, *, backend: str, rids) -> str:
        e, seq = self.engine_id, self.consults
        self.consults += 1
        loss = self.plan.device_loss(e, seq)
        if loss is not None:
            raise DeviceLostFault(
                f"injected device loss on engine {e} at consult {seq}",
                engine=e, state_lost=loss.state_lost)
        rid = self.plan.poison_rid(e, seq, rids)
        if rid is not None:
            raise PoisonDispatchError(
                f"injected poison fault for request {rid} on engine {e}",
                request_id=rid, engine=e)
        if self.plan.dispatch_fault(e, seq, attempt, backend):
            raise DispatchFault(
                f"injected dispatch fault on engine {e} at consult {seq} "
                f"(attempt {attempt}, backend {backend!r})",
                engine=e, seq=seq, attempt=attempt)
        return "hang" if self.plan.hang(e, seq) else "ok"

    def filter_telemetry(self, tel):
        """Possibly corrupt one chunk's telemetry record (plan-driven)."""
        if tel is None or not self.plan.corrupt_telemetry(
                self.engine_id, self.consults - 1):
            return tel
        # flip the spike-count leaf negative: impossible under the
        # telemetry contract, so host validation must reject the record
        return tel._replace(n_spk=-(np.abs(np.asarray(tel.n_spk)) + 1))


def injector_from_env(engine_id: int) -> FaultInjector | None:
    """The env-armed injector for engines built without an explicit one."""
    plan = FaultPlan.from_env()
    return None if plan is None else FaultInjector(plan, engine_id)


def telemetry_ok(tel) -> bool:
    """Host-side validity check of a chunk's telemetry record.

    The side channel's contract makes corruption cheap to detect: every
    leaf is a count, so any negative entry (or NaN smuggled through a
    float cast) falsifies the record.  Engines validate only when a fault
    harness is armed — the check forces a device→host readback.
    """
    if tel is None:
        return False
    for leaf in (tel.n_spk, tel.n_en, tel.tiles_skipped):
        a = np.asarray(leaf)
        if not np.issubdtype(a.dtype, np.integer) or (a < 0).any():
            return False
    return True


# ---- policy + health ------------------------------------------------------

@dataclass(frozen=True)
class FaultToleranceConfig:
    """Recovery-policy knobs shared by the engines and the tier.

    Backoff is deterministic and counted in *scheduling rounds* (the
    tier's lockstep step currency), not wall-clock: after a round whose
    immediate retries all faulted, the engine sits out
    ``min(backoff_base << burst, backoff_max)`` rounds before retrying —
    replayable, and bounded so a recovering engine rejoins quickly.

    The heartbeat knobs drive the *process-level* watchdog
    (serve.cluster): the coordinator pings idle workers every
    ``heartbeat_interval_s`` and declares a worker hung when any RPC
    frame takes longer than ``heartbeat_deadline_s`` — wall-clock, not
    rounds, because a hung process produces no rounds to count.  These
    are deliberately generous defaults (detection latency only — which
    round a hang is *declared* in stays deterministic, because a hung
    worker stops responding at a plan-scheduled round and never
    responds again).  ``max_respawns`` bounds restart-and-readopt per
    worker slot.

    Every knob is validated at construction (the config travels over
    RPC and through ``SNNServingTierConfig`` — a bad value must fail
    where it was written, not rounds later inside a recovery path).
    """

    max_retries: int = 2        # immediate same-round retries per dispatch
    fail_after: int = 6         # consecutive faults ⇒ EngineFailure
    backoff_base: int = 1       # rounds; doubles per faulting round
    backoff_max: int = 4        # rounds; the bound on the backoff
    demote_after: int = 2       # consecutive faults ⇒ step down the ladder
    promote_after: int = 4      # clean chunks ⇒ probe one rung back up
    watchdog_chunks: int = 4    # stalled chunks ⇒ declare the engine hung
    quarantine_after: int = 3   # per-request faults ⇒ quarantine (tier)
    heartbeat_interval_s: float = 0.05  # coordinator→worker idle ping period
    heartbeat_deadline_s: float = 10.0  # RPC deadline ⇒ worker declared hung
    max_respawns: int = 1       # restart-and-readopt budget per worker slot

    def __post_init__(self):
        for name in ("fail_after", "backoff_base", "backoff_max",
                     "demote_after", "promote_after", "watchdog_chunks",
                     "quarantine_after"):
            if getattr(self, name) < 1:
                raise ValueError(
                    f"FaultToleranceConfig.{name} must be >= 1, got "
                    f"{getattr(self, name)}")
        for name in ("max_retries", "max_respawns"):
            if getattr(self, name) < 0:
                raise ValueError(
                    f"FaultToleranceConfig.{name} must be >= 0, got "
                    f"{getattr(self, name)}")
        if not self.heartbeat_interval_s > 0:
            raise ValueError(
                f"FaultToleranceConfig.heartbeat_interval_s must be > 0, "
                f"got {self.heartbeat_interval_s}")
        if not self.heartbeat_deadline_s > self.heartbeat_interval_s:
            raise ValueError(
                f"FaultToleranceConfig.heartbeat_deadline_s "
                f"({self.heartbeat_deadline_s}) must exceed "
                f"heartbeat_interval_s ({self.heartbeat_interval_s}) — a "
                f"deadline shorter than the ping period declares every "
                f"healthy worker hung")


@dataclass
class EngineHealthState:
    """Mutable per-engine fault/demotion bookkeeping (host-only).

    The load-visible slice of this state rides on
    ``core.telemetry.EngineLoad`` (consecutive faults, demotion level,
    watchdog margin, liveness) so ``load_score`` steers traffic away from
    degraded engines; ``events`` is the auditable transition log
    (demotions, promotions, failures), mirrored into the telemetry
    controller's history where the dispatch decisions already live.
    """

    alive: bool = True
    demotion_level: int = 0        # index into the engine's backend ladder
    consecutive_faults: int = 0
    total_faults: int = 0
    telemetry_faults: int = 0      # corrupted side-channel records dropped
    clean_chunks: int = 0          # consecutive clean chunks at this level
    stalled_chunks: int = 0        # consecutive no-progress chunks (hang)
    events: list = field(default_factory=list)

    def record_fault(self, kind: str, detail: str = "") -> None:
        self.total_faults += 1
        self.consecutive_faults += 1
        self.clean_chunks = 0
        self.events.append({"event": "fault", "kind": kind,
                            "detail": detail})

    def record_clean(self) -> None:
        self.consecutive_faults = 0
        self.clean_chunks += 1


@dataclass(frozen=True)
class FaultRecord:
    """Why a request was lost to a fault (the recorded, auditable drop —
    the fault-path sibling of ``router.ShedRecord``; ``results ∪ shed ∪
    faulted`` exactly partitions a tier's submitted ids).

    ``reason``: ``"state_lost"`` (its engine died with the lane snapshot
    unrecoverable), ``"engine_lost"`` (its engine died and no healthy
    engine remained to evacuate to), ``"no_capacity"`` (submitted while
    every engine was dead), or ``"quarantined"`` (faulted
    ``quarantine_after`` times across engines — a poison request).
    ``replay_seed`` is the PRNG seed its window runs under
    (``tier.seed + request_id``), so a quarantined request is exactly
    reproducible offline.
    """

    request_id: int
    reason: str
    engine: int | None = None       # the engine whose fault dropped it
    faults: int = 0                 # faults attributed to this request
    replay_seed: int | None = None
    detail: str = ""
