"""Versioned wire codec + length-prefixed framing for the cluster RPC.

The process-level failover layer (``serve.cluster``) moves three kinds of
state across a process boundary: ``LaneState`` rows (the chunk-boundary
checkpoint — evacuating a dead host's lanes into a healthy host's
adoption queue), weight planes (``WeightBank`` version replay on a
respawned worker), and ``EngineLoad`` records (the routing surface over
RPC).  Everything here is JSON-representable on purpose — the container
ships no msgpack, and JSON keeps the ledger (``serve.ledger``) and the
RPC frames human-debuggable — with numpy arrays carried as
``{dtype, shape, b64(raw bytes)}`` so the roundtrip is **bit-identical**:
the decoded row has the same dtypes, shapes and bytes as the source, and
adopting it resumes the window bit-exactly (the chunked==one-shot
invariant makes the row a complete, placement-independent checkpoint).

The lane codec is **versioned**: :data:`WIRE_CODEC_VERSION` is stamped
into every encoded row and :func:`lane_from_wire` refuses rows from a
*newer* codec with an actionable message — a mixed-version fleet must
fail loudly at the boundary, not silently misinterpret checkpoint bytes.

Framing is 4-byte big-endian length + JSON body.  The reader exists in
two flavours: the worker blocks forever (its liveness is the
coordinator's problem), the coordinator reads under a wall-clock
deadline (the heartbeat: a worker that cannot produce its frame within
``heartbeat_deadline_s`` is declared hung — the PR 7 watchdog semantics
across a process boundary).  The writer mirrors that split: the
coordinator passes the same deadline to :func:`write_msg` so a hung
worker whose pipe buffer has filled cannot block the coordinator inside
``os.write`` — overdue writes and overdue reads both mean "hung".

No jax at module scope: the coordinator never touches a device, and the
ledger-recovery path must be importable before any worker exists.
"""

from __future__ import annotations

import base64
import dataclasses
import json
import os
import select
import struct

import numpy as np

__all__ = [
    "WIRE_CODEC_VERSION", "WireError",
    "array_to_wire", "array_from_wire",
    "lane_to_wire", "lane_from_wire",
    "params_to_wire", "params_from_wire",
    "planes_to_wire", "planes_from_wire",
    "snn_cfg_to_wire", "snn_cfg_from_wire",
    "fault_cfg_to_wire", "fault_cfg_from_wire",
    "plan_to_wire", "plan_from_wire",
    "result_to_wire", "result_from_wire",
    "write_msg", "read_msg",
]

# Bump when the LaneState row layout (fields, dtypes, meaning) changes.
WIRE_CODEC_VERSION = 1


class WireError(ValueError):
    """A frame or encoded object that cannot be (de)serialized safely."""


# ---- arrays ---------------------------------------------------------------

def array_to_wire(a) -> dict:
    """Encode one numpy array (or scalar) dtype/shape/byte-exactly."""
    a = np.asarray(a)
    return {"dtype": str(a.dtype), "shape": list(a.shape),
            "b64": base64.b64encode(
                np.ascontiguousarray(a).tobytes()).decode("ascii")}


def array_from_wire(d: dict) -> np.ndarray:
    a = np.frombuffer(base64.b64decode(d["b64"]),
                      dtype=np.dtype(d["dtype"]))
    # .copy(): frombuffer views are read-only, and adopted rows are
    # written into the host lane tile field-by-field
    return a.reshape(tuple(d["shape"])).copy()


# ---- LaneState rows -------------------------------------------------------

def lane_to_wire(row) -> dict:
    """One host ``LaneState`` row (``engine.snapshot_lanes`` /
    ``checkpoint_lanes`` element) → versioned JSON-safe dict."""
    leaves = {}
    for f in row._fields:
        v = getattr(row, f)
        leaves[f] = ([array_to_wire(x) for x in v] if isinstance(v, tuple)
                     else array_to_wire(v))
    return {"codec": WIRE_CODEC_VERSION, "leaves": leaves}


def lane_from_wire(d: dict):
    """Decode a wire row back into a host ``LaneState`` (bit-identical).

    Rejects rows stamped with a codec version this build does not know:
    a newer coordinator/worker may have changed the row layout, and
    guessing at unknown checkpoint bytes would corrupt a window silently.
    """
    from .snn_engine import LaneState
    if not isinstance(d, dict) or "codec" not in d:
        raise WireError(
            "not a lane checkpoint: missing the 'codec' version stamp "
            "(expected the dict produced by lane_to_wire)")
    ver = d["codec"]
    if not isinstance(ver, int) or ver < 1:
        raise WireError(f"lane checkpoint carries invalid codec version "
                        f"{ver!r} (expected an integer >= 1)")
    if ver > WIRE_CODEC_VERSION:
        raise WireError(
            f"lane checkpoint uses wire codec version {ver}, but this "
            f"build understands versions <= {WIRE_CODEC_VERSION} — the "
            f"peer that produced it is newer; upgrade this "
            f"coordinator/worker (or roll the peer back) before "
            f"evacuating lanes across the pair")
    leaves = d.get("leaves", {})
    missing = [f for f in LaneState._fields if f not in leaves]
    if missing:
        raise WireError(f"lane checkpoint (codec {ver}) is missing "
                        f"fields {missing} — truncated or corrupt row")
    kw = {}
    for f in LaneState._fields:
        v = leaves[f]
        kw[f] = (tuple(array_from_wire(x) for x in v)
                 if isinstance(v, list) else array_from_wire(v))
    return LaneState(**kw)


# ---- params / weight planes ----------------------------------------------

def params_to_wire(params_q: dict) -> dict:
    return {"layers": [
        {"w_q": array_to_wire(np.asarray(layer["w_q"])),
         "scale": float(np.asarray(layer["scale"]))}
        for layer in params_q["layers"]]}


def params_from_wire(d: dict) -> dict:
    return {"layers": [
        {"w_q": array_from_wire(layer["w_q"]),
         "scale": np.float32(layer["scale"])}
        for layer in d["layers"]]}


def planes_to_wire(planes: tuple) -> list:
    """A bare weight-plane tuple (the ``WeightBank.ensure`` payload)."""
    return [array_to_wire(np.asarray(w)) for w in planes]


def planes_from_wire(d: list) -> tuple:
    return tuple(array_from_wire(w) for w in d)


# ---- configs / plans ------------------------------------------------------

def snn_cfg_to_wire(cfg) -> dict:
    return dataclasses.asdict(cfg)


def snn_cfg_from_wire(d: dict):
    from ..core.lif import LIFConfig
    from ..core.snn import SNNConfig
    d = dict(d)
    d["lif"] = LIFConfig(**d["lif"])
    d["layer_sizes"] = tuple(d["layer_sizes"])
    return SNNConfig(**d)


def fault_cfg_to_wire(cfg) -> dict | None:
    return None if cfg is None else dataclasses.asdict(cfg)


def fault_cfg_from_wire(d: dict | None):
    from .faults import FaultToleranceConfig
    return None if d is None else FaultToleranceConfig(**d)


def plan_to_wire(plan) -> dict | None:
    if plan is None:
        return None
    return {"seed": plan.seed, "dispatch_rate": plan.dispatch_rate,
            "telemetry_rate": plan.telemetry_rate,
            "events": [dataclasses.asdict(ev) for ev in plan.events]}


def plan_from_wire(d: dict | None):
    from .faults import FaultEvent, FaultPlan
    if d is None:
        return None
    events = []
    for ev in d["events"]:
        ev = dict(ev)
        if ev.get("backends") is not None:
            ev["backends"] = tuple(ev["backends"])
        events.append(FaultEvent(**ev))
    return FaultPlan(tuple(events), seed=d["seed"],
                     dispatch_rate=d["dispatch_rate"],
                     telemetry_rate=d["telemetry_rate"])


# ---- results --------------------------------------------------------------

def result_to_wire(res) -> dict:
    return {"request_id": int(res.request_id), "pred": int(res.pred),
            "spike_counts": np.asarray(res.spike_counts).tolist(),
            "steps": int(res.steps), "adds": int(res.adds),
            "early_exit": bool(res.early_exit),
            "weight_version": int(res.weight_version)}


def result_from_wire(d: dict):
    from .snn_engine import RequestResult
    return RequestResult(
        request_id=int(d["request_id"]), pred=int(d["pred"]),
        spike_counts=np.asarray(d["spike_counts"], np.int32),
        steps=int(d["steps"]), adds=int(d["adds"]),
        early_exit=bool(d["early_exit"]),
        weight_version=int(d["weight_version"]))


# ---- framing --------------------------------------------------------------

_HEADER = struct.Struct(">I")


def write_msg(fd: int, obj, timeout_s: float | None = None) -> None:
    """Write one length-prefixed JSON frame to a raw fd (pipe).

    ``timeout_s=None`` blocks forever (worker side).  A finite timeout
    is the coordinator's heartbeat deadline applied to the *write* side:
    a stalled peer that stops draining its pipe fills the kernel buffer
    (~64KB), and a large frame (weight planes, rollout params) would
    otherwise block the coordinator in ``os.write`` forever — past the
    deadline this raises :class:`TimeoutError` exactly like the read
    side, so "any RPC overdue is declared hung" covers both directions.
    """
    import time
    body = json.dumps(obj, separators=(",", ":")).encode("utf-8")
    data = _HEADER.pack(len(body)) + body
    view = memoryview(data)
    deadline = None if timeout_s is None else time.monotonic() + timeout_s
    while view:
        if deadline is None:
            n = os.write(fd, view)
        else:
            left = deadline - time.monotonic()
            if left <= 0:
                raise TimeoutError("frame write exceeded the heartbeat "
                                   "deadline")
            _, w, _ = select.select([], [fd], [], left)
            if not w:
                raise TimeoutError("frame write exceeded the heartbeat "
                                   "deadline")
            # select-writable guarantees PIPE_BUF bytes of space, so a
            # chunk bounded by it cannot block a blocking-mode pipe even
            # when the peer never drains another byte
            n = os.write(fd, view[:select.PIPE_BUF])
        view = view[n:]


def _read_exact(fd: int, n: int, deadline: float | None,
                clock) -> bytes:
    """Read exactly ``n`` bytes; EOFError on closed pipe, TimeoutError
    past ``deadline`` (an absolute ``clock()`` instant)."""
    chunks, got = [], 0
    while got < n:
        if deadline is not None:
            left = deadline - clock()
            if left <= 0:
                raise TimeoutError("frame read exceeded the heartbeat "
                                   "deadline")
            r, _, _ = select.select([fd], [], [], left)
            if not r:
                raise TimeoutError("frame read exceeded the heartbeat "
                                   "deadline")
        b = os.read(fd, n - got)
        if not b:
            raise EOFError("pipe closed mid-frame (peer process exited)")
        chunks.append(b)
        got += len(b)
    return b"".join(chunks)


def read_msg(fd: int, timeout_s: float | None = None):
    """Read one frame.  ``timeout_s=None`` blocks forever (worker side);
    a finite timeout is the coordinator's heartbeat deadline — the whole
    frame (header + body) must arrive within it."""
    import time
    deadline = None if timeout_s is None else time.monotonic() + timeout_s
    header = _read_exact(fd, _HEADER.size, deadline, time.monotonic)
    (length,) = _HEADER.unpack(header)
    body = _read_exact(fd, length, deadline, time.monotonic)
    return json.loads(body.decode("utf-8"))
