"""Early exit: the serving-layer generalisation of the paper's active
pruning (§III-D / §IV-C "identify quickly, sleep sooner").

In the RTL, a neuron that has fired is clock-gated for the rest of the
window.  At the serving layer the same idea retires *requests*: a sequence
whose prediction has been stable for ``patience`` consecutive steps (or
that emitted EOS) stops consuming decode steps — its cache writes and
compute are gated off (see serve.engine.make_decode_step), and the freed
slots shrink the active batch.  The measurable win is the same quantity the
paper plots in Fig. 6/7: accuracy (or completion) per unit time/energy.
"""

from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

__all__ = ["eos_gate", "stability_gate", "StabilityState"]


def eos_gate(eos_id: int) -> Callable:
    def gate(last_token: jax.Array, logits: jax.Array) -> jax.Array:
        return last_token == eos_id
    return gate


class StabilityState:
    """Stateful gate: retire when argmax prediction unchanged ``patience``×.

    Mirrors core.pruning.stability_early_exit but runs online during
    decode (no need to see the whole window).
    """

    def __init__(self, batch: int, patience: int = 3):
        self.patience = patience
        self.prev = jnp.full((batch,), -1, jnp.int32)
        self.streak = jnp.zeros((batch,), jnp.int32)

    def __call__(self, last_token: jax.Array, logits: jax.Array) -> jax.Array:
        pred = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        same = pred == self.prev
        self.streak = jnp.where(same, self.streak + 1, 0)
        self.prev = pred
        return self.streak >= self.patience


def stability_gate(batch: int, patience: int = 3) -> StabilityState:
    return StabilityState(batch, patience)
