"""Early exit: the serving-layer generalisation of the paper's active
pruning (§III-D / §IV-C "identify quickly, sleep sooner").

In the RTL, a neuron that has fired is clock-gated for the rest of the
window.  At the serving layer the same idea retires *requests*: a sequence
whose prediction has been stable for ``patience`` consecutive steps (or
that emitted EOS) stops consuming decode steps — its cache writes and
compute are gated off (see serve.engine.make_decode_step), and the freed
slots shrink the active batch.  The measurable win is the same quantity the
paper plots in Fig. 6/7: accuracy (or completion) per unit time/energy.

The stability gate is a **pure** ``(state, pred) -> (state, done)``
function over a :class:`StabilityGateState` pytree, so it can live inside
``jax.jit`` / ``jax.lax.scan`` bodies — in particular inside the batched
streaming SNN engine's window loop (serve.snn_engine) and the fused decode
loop.  :class:`StabilityState` remains as a thin stateful convenience
wrapper for the host-side ``generate`` loop.
"""

from __future__ import annotations

from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

__all__ = [
    "eos_gate",
    "stability_gate",
    "StabilityGateState",
    "stability_init",
    "stability_specs",
    "stability_step",
    "StabilityState",
]


def eos_gate(eos_id: int) -> Callable:
    def gate(last_token: jax.Array, logits: jax.Array) -> jax.Array:
        return last_token == eos_id
    return gate


class StabilityGateState(NamedTuple):
    """Per-lane gate state: previous prediction and its run length."""

    prev: jax.Array      # int32 (B,): last prediction (-1 = none yet)
    streak: jax.Array    # int32 (B,): consecutive identical predictions


def stability_init(batch: int) -> StabilityGateState:
    return StabilityGateState(
        prev=jnp.full((batch,), -1, jnp.int32),
        streak=jnp.zeros((batch,), jnp.int32),
    )


def stability_specs(axis_name: str | None = None) -> StabilityGateState:
    """PartitionSpecs for the gate state on a data mesh.

    The gate is strictly per-lane — ``stability_step`` never looks across
    the batch axis — so both leaves shard on the mesh's batch axis and the
    gate computes identically on any lane slice.  This is the property the
    sharded streaming engine (serve.snn_engine) relies on to run the
    in-kernel early exit under ``shard_map`` without collectives.
    """
    from jax.sharding import PartitionSpec as P
    return StabilityGateState(prev=P(axis_name), streak=P(axis_name))


def stability_step(state: StabilityGateState, pred: jax.Array,
                   patience: int) -> tuple[StabilityGateState, jax.Array]:
    """One gate update.  Pure — safe under jit/scan/vmap.

    ``pred``: int (B,) current per-lane prediction.  Returns the new state
    and ``done``: bool (B,), True once the prediction has repeated
    ``patience`` times (i.e. been stable for patience+1 observations).
    """
    pred = pred.astype(jnp.int32)
    streak = jnp.where(pred == state.prev, state.streak + 1, 0)
    return StabilityGateState(prev=pred, streak=streak), streak >= patience


class StabilityState:
    """Stateful convenience wrapper over the pure gate, matching the
    ``early_exit_fn(last_token, logits) -> done`` callable contract of
    ``serve.engine.generate``.  Mirrors core.pruning.stability_early_exit
    but runs online during decode (no need to see the whole window)."""

    def __init__(self, batch: int, patience: int = 3):
        self.patience = patience
        self.state = stability_init(batch)

    @property
    def prev(self) -> jax.Array:
        return self.state.prev

    @property
    def streak(self) -> jax.Array:
        return self.state.streak

    def __call__(self, last_token: jax.Array, logits: jax.Array) -> jax.Array:
        pred = jnp.argmax(logits, axis=-1)
        self.state, done = stability_step(self.state, pred, self.patience)
        return done


def stability_gate(batch: int, patience: int = 3) -> StabilityState:
    return StabilityState(batch, patience)
