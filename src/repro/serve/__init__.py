"""Serving substrate: prefill/decode engine, sequence-sharded KV cache,
early-exit request retirement (the paper's active-pruning analogue)."""

from .engine import (ServeState, generate, make_decode_step, make_prefill,
                     pad_cache_to)
from .early_exit import eos_gate, stability_gate

__all__ = ["ServeState", "generate", "make_decode_step", "make_prefill",
           "pad_cache_to", "eos_gate", "stability_gate"]
