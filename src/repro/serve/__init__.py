"""Serving substrate: prefill/decode engine, sequence-sharded KV cache,
early-exit request retirement (the paper's active-pruning analogue), and
the batched streaming SNN engine (continuous batching over window chunks).

Two request shapes share one early-exit mechanism:
  * LM requests — ``generate`` + ``make_prefill``/``make_decode_step``
    (engine.py), early exit retires stable/EOS sequences.
  * SNN image requests — ``SNNStreamEngine`` (snn_engine.py), early exit
    retires stable classifications mid-window and lane compaction admits
    queued images into the freed batch-tile slots.
"""

from .cluster import ClusterCoordinator, CoordinatorCrash
from .early_exit import (StabilityGateState, eos_gate, stability_gate,
                         stability_init, stability_specs, stability_step)
from .engine import (ServeState, generate, make_decode_step, make_prefill,
                     pad_cache_to)
from .faults import (DeviceLostFault, DispatchFault, EngineFailure,
                     EngineHealthState, FaultEvent, FaultInjector, FaultPlan,
                     FaultPlanSpecError, FaultRecord, FaultToleranceConfig,
                     PoisonDispatchError)
from .ledger import Ledger, LedgerCorruptError, read_ledger, recover_accounting
from .wire import (WIRE_CODEC_VERSION, WireError, lane_from_wire,
                   lane_to_wire)
from .rollout import RolloutEvent, RolloutInProgressError, WeightBank
from .router import ShedRecord, SNNServingTier
from .snn_engine import (RequestResult, ShardedSNNStreamEngine,
                         SNNStreamEngine)
from .telemetry import (AdaptiveDispatchConfig, ChunkSummary,
                        TelemetryController, summarize_chunk)

__all__ = ["ServeState", "generate", "make_decode_step", "make_prefill",
           "pad_cache_to", "eos_gate", "stability_gate",
           "StabilityGateState", "stability_init", "stability_specs",
           "stability_step", "SNNStreamEngine", "ShardedSNNStreamEngine",
           "SNNServingTier", "ShedRecord", "RolloutEvent",
           "RolloutInProgressError", "WeightBank",
           "RequestResult", "AdaptiveDispatchConfig", "ChunkSummary",
           "TelemetryController", "summarize_chunk",
           "FaultPlan", "FaultEvent", "FaultInjector", "FaultRecord",
           "FaultToleranceConfig", "EngineHealthState", "EngineFailure",
           "DispatchFault", "DeviceLostFault", "PoisonDispatchError",
           "FaultPlanSpecError", "ClusterCoordinator", "CoordinatorCrash",
           "Ledger", "LedgerCorruptError", "read_ledger",
           "recover_accounting", "WIRE_CODEC_VERSION", "WireError",
           "lane_to_wire", "lane_from_wire"]
