"""Zero-drain weight rollout: version-tagged packed weight planes.

Production serving replaces model weights while traffic is in flight.
Draining — refusing admissions until every lane retires, swapping planes,
then re-admitting — costs a full window of fleet throughput per engine and
couples rollout latency to the slowest request.  This module implements
the drain-free alternative the streaming engine's per-lane isolation
makes cheap:

  * every packed weight plane set is **version-tagged** in a
    :class:`WeightBank` (monotone integer versions, the engine's
    device-placed tuples as values);
  * ``LaneState.weight_version`` records, per lane, the bank version the
    request was **admitted** under — in-flight windows finish on their
    admission-time weights, new admissions bind the bank's current
    version;
  * while two (or more) versions have live lanes, the engine dispatches
    one gated chunk per live version — each run freezes the other
    versions' lanes via the existing ``active`` mask, and because a
    frozen lane is bit-for-bit untouched (PRNG, membranes, counters —
    the compaction contract), the per-lane merge in
    :func:`merge_version_chunks` reproduces exactly what each lane would
    compute served alone.  A rollout can therefore **never** change the
    outputs of windows admitted before it (the tier bit-identity test
    pins this);
  * the rollout **completes when the last old-version lane retires**:
    :meth:`WeightBank.gc` drops versions no occupied lane references and
    records the begin/complete transitions in :attr:`WeightBank.history`
    (the observable state machine — ``idle → rolling → idle``).

The temporary cost is one extra chunk launch per additional live version,
only while old lanes are still draining; steady state always runs the
single-version fast path.
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from ..core.telemetry import ChunkTelemetry

__all__ = ["RolloutEvent", "RolloutInProgressError", "WeightBank",
           "merge_version_chunks"]


class RolloutInProgressError(RuntimeError):
    """``begin(exclusive=True)`` found a rollout still draining.

    Carries the live version list so the caller can decide to wait for
    the drain, force the stack anyway, or abort the in-flight rollout.
    """

    def __init__(self, versions: tuple):
        self.versions = tuple(versions)
        super().__init__(
            "rollout already in progress: live versions "
            f"{self.versions} (pass exclusive=False to stack)")


@dataclass(frozen=True)
class RolloutEvent:
    """One transition of the rollout state machine (recorded, auditable)."""

    kind: str          # "begin" | "complete" | "restore" | "abort"
    version: int       # the version published / finished / restored
    retired: tuple = ()  # versions dropped by a completing gc / an abort


class WeightBank:
    """Version-tagged store of device-placed packed weight-plane tuples.

    The bank never interprets the weight tuples — placement (device_put,
    replication over a mesh) is the engine's job via its
    ``_place_weights`` hook; the bank owns the version bookkeeping:
    which versions exist, which one new admissions bind
    (:attr:`current`), and when an old version's last lane retired
    (:meth:`gc`).
    """

    def __init__(self, weights: tuple, version: int = 0):
        self._planes: dict[int, tuple] = {version: weights}
        self.current = version
        self.history: list[RolloutEvent] = []

    # ---- queries --------------------------------------------------------
    @property
    def versions(self) -> tuple[int, ...]:
        """Live versions, ascending (more than one ⇒ a rollout is active)."""
        return tuple(sorted(self._planes))

    @property
    def rolling(self) -> bool:
        """True while any pre-rollout version still holds live lanes."""
        return len(self._planes) > 1

    def weights(self, version: int) -> tuple:
        return self._planes[version]

    # ---- state machine --------------------------------------------------
    def begin(self, weights: tuple, *, exclusive: bool = False) -> int:
        """Publish a new weight version; new admissions bind it.

        Beginning while an earlier rollout is still draining **stacks**:
        three or more versions can be live at once, each draining
        independently as its last lane retires (the gated-dispatch merge
        handles any number of versions, and the back-to-back-rollout
        tier test pins the drain order) — stacking is the deliberate
        default because refusing would couple publish latency to the
        slowest in-flight window.  Callers that want drained-only
        publishes pass ``exclusive=True`` and catch the typed
        :class:`RolloutInProgressError`, which carries the live version
        list.

        The engine validates shape/code compatibility before calling (the
        lane state layout is fixed by ``layer_sizes``, so a rollout can
        retune weights, never retopologize).  Returns the new version.
        """
        if exclusive and self.rolling:
            raise RolloutInProgressError(self.versions)
        v = self.current + 1
        self._planes[v] = weights
        self.current = v
        self.history.append(RolloutEvent(kind="begin", version=v))
        return v

    def ensure(self, version: int, weights: tuple) -> bool:
        """Re-register an old version without republishing it.

        The failover path: a lane evacuated from a dead engine may carry
        a version its adopting engine already garbage-collected.  The
        tier re-installs that version's planes from its host copies so
        the adopted window finishes on its admission-time weights —
        ``current`` (what new admissions bind) is untouched, and the
        ``restore`` event keeps the state machine auditable.  Restoring
        a non-current version re-opens the rolling state until the
        adopted lane retires, which is exactly the "a rollout never
        completes while an old-version lane exists" invariant.  Returns
        True if the version had to be installed.
        """
        if version in self._planes:
            return False
        if version > self.current:
            raise ValueError(
                f"cannot restore version {version} newer than current "
                f"{self.current}")
        self._planes[version] = weights
        self.history.append(RolloutEvent(kind="restore", version=version))
        return True

    def abort(self) -> tuple[int, ...]:
        """Drop every non-current version unconditionally (dead engine).

        When an engine fails mid-rollout its lanes are evacuated or shed
        — nothing on *this* engine will ever dispatch the draining
        versions again, so the planes are freed immediately rather than
        waiting for a compaction-time gc that will never run.  Returns
        the versions dropped.
        """
        dead = tuple(v for v in self._planes if v != self.current)
        for v in dead:
            del self._planes[v]
        if dead:
            self.history.append(RolloutEvent(
                kind="abort", version=self.current, retired=dead))
        return dead

    def gc(self, live_versions: set[int]) -> tuple[int, ...]:
        """Drop versions no occupied lane references (never the current).

        Called at compaction time with the set of versions the occupied
        lanes carry.  Dropping the last old version IS rollout
        completion — recorded as a ``complete`` event.  Returns the
        versions retired by this call.
        """
        dead = tuple(v for v in self._planes
                     if v != self.current and v not in live_versions)
        for v in dead:
            del self._planes[v]
        if dead and not self.rolling:
            self.history.append(RolloutEvent(
                kind="complete", version=self.current, retired=dead))
        return dead


def merge_version_chunks(outputs):
    """Merge per-version gated chunk runs into one lane tile + telemetry.

    ``outputs`` is a list of ``(mask, lanes, telemetry)`` — one entry per
    live version, ``mask`` the (B,) bool "lane belongs to this version"
    selector, ``lanes`` the LaneState that version's run produced (its
    own lanes advanced, every other lane frozen bit-for-bit).  Each lane
    takes every leaf from its *own* version's run, so the merge equals
    serving each version's lanes alone; lanes owned by none of the masks
    (free slots with stale tags) fall through to the first run, where
    they were frozen — i.e. unchanged.

    Telemetry merges by **summation**: a frozen lane reports zero
    activity rows, so each lane's counts appear in exactly one run, and
    the tile counter sums to the total block geometry the version
    launches actually executed (rollout chunks really do launch once per
    live version — the telemetry says so).
    """
    _, merged, tel0 = outputs[0]
    for mask, lanes, _ in outputs[1:]:
        m = jnp.asarray(mask)

        def sel(new, old, m=m):
            return jnp.where(m.reshape(m.shape + (1,) * (new.ndim - 1)),
                             new, old)

        merged = jax.tree.map(sel, lanes, merged)
    tel = ChunkTelemetry(
        n_spk=sum((t.n_spk for _, _, t in outputs[1:]), tel0.n_spk),
        n_en=sum((t.n_en for _, _, t in outputs[1:]), tel0.n_en),
        tiles_skipped=sum((t.tiles_skipped for _, _, t in outputs[1:]),
                          tel0.tiles_skipped),
    )
    return merged, tel
