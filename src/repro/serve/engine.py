"""Serving engine: prefill + decode steps with a pre-allocated KV cache.

``prefill`` runs the full-sequence forward once, writing K/V (and SSM
states) into a cache sized for ``max_len``; ``decode_step`` advances one
token.  Both are pure functions designed to be jitted/pjitted by the
launcher with the cache sharded over "kv_seq" (flash-decoding-style
sequence sharding — the long-context decode path).

Early exit (the paper's active-pruning analogue at the serving layer) lives
in early_exit.py and composes with ``generate``.  The SNN counterpart of
this engine — batched streaming classification with early-exit lane
compaction — is ``snn_engine.SNNStreamEngine``; the underlying integer
datapath is selected by ``core.snn.SNNConfig.backend``
(fused Pallas megakernel | staged kernels | jnp reference).
"""

from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from ..models.transformer import lm_apply

__all__ = ["ServeState", "make_prefill", "make_decode_step", "generate",
           "pad_cache_to"]

Pytree = Any


class ServeState(NamedTuple):
    cache: Pytree
    cur_len: jax.Array       # (B,) valid cache lengths
    last_token: jax.Array    # (B,) most recent token
    done: jax.Array          # (B,) early-exit flags


def pad_cache_to(cache: Pytree, max_len: int) -> Pytree:
    """Grow prefill-created K/V caches (length S) to ``max_len`` slots."""

    def one(path, x):
        names = [getattr(e, "name", getattr(e, "key", "")) for e in path]
        if names and names[-1] in ("k", "v") and "cross" not in names:
            pad = max_len - x.shape[2]
            if pad > 0:
                widths = [(0, 0)] * x.ndim
                widths[2] = (0, pad)
                return jnp.pad(x, widths)
        return x

    return jax.tree_util.tree_map_with_path(one, cache)


def make_prefill(cfg, *, max_len: int):
    def prefill(params, batch):
        tokens = batch["tokens"]
        b = tokens.shape[0]
        logits, cache, _ = lm_apply(params, batch, cfg, mode="prefill")
        cache = pad_cache_to(cache, max_len)
        s = logits.shape[1]
        cur = jnp.full((b,), s, jnp.int32)
        nxt = jnp.argmax(logits[:, -1, :cfg.vocab_size], axis=-1) \
                 .astype(jnp.int32)
        return ServeState(cache=cache, cur_len=cur, last_token=nxt,
                          done=jnp.zeros((b,), bool)), logits

    return prefill


def make_decode_step(cfg):
    def decode_step(params, state: ServeState):
        batch = {"tokens": state.last_token[:, None]}
        logits, cache, _ = lm_apply(params, batch, cfg, mode="decode",
                                    cache=state.cache, cur_len=state.cur_len)
        nxt = jnp.argmax(logits[:, -1, :cfg.vocab_size], axis=-1) \
                 .astype(jnp.int32)
        # retired sequences (early exit) stop writing / advancing
        cache = jax.tree.map(
            lambda new, old: jnp.where(
                _bcast(state.done, new.ndim, 1), old, new),
            cache, state.cache)
        cur = jnp.where(state.done, state.cur_len, state.cur_len + 1)
        nxt = jnp.where(state.done, state.last_token, nxt)
        return ServeState(cache=cache, cur_len=cur, last_token=nxt,
                          done=state.done), logits[:, -1]

    return decode_step


def _bcast(mask: jax.Array, ndim: int, batch_axis: int) -> jax.Array:
    shape = [1] * ndim
    shape[batch_axis] = mask.shape[0]
    return mask.reshape(shape)


def generate(params, batch, cfg, *, steps: int, max_len: int,
             early_exit_fn=None):
    """Greedy generation loop with optional per-sequence early exit.

    early_exit_fn(tokens_so_far (B,t), logits (B,V)) -> (B,) bool — e.g.
    serve.early_exit.stability_gate.  Returns (tokens (B,steps), n_active
    per step (B? no: (steps,) active counts — the energy/latency signal).
    """
    prefill = make_prefill(cfg, max_len=max_len)
    decode = make_decode_step(cfg)
    state, _ = prefill(params, batch)
    toks, actives = [], []
    for _ in range(steps):
        state, logits = decode(params, state)
        if early_exit_fn is not None:
            newly_done = early_exit_fn(state.last_token, logits)
            state = state._replace(done=state.done | newly_done)
        toks.append(state.last_token)
        actives.append(jnp.sum(~state.done))
    return jnp.stack(toks, axis=1), jnp.stack(actives)
