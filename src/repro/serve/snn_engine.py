"""Batched streaming SNN serving engine (paper §IV-C at the request level).

The RTL classifies one image per window.  A TPU serving deployment instead
packs many requests into one batch tile and streams them through the
integer datapath together.  This engine adds the two scheduling ideas that
make that efficient under heavy traffic:

  * **Early exit** — a lane whose running prediction has been stable for
    ``patience`` consecutive steps retires before the window ends (the
    request-level analogue of active pruning; pure gate from
    serve.early_exit, evaluated *inside* the jitted window chunk so a lane
    stops burning adds the step it retires, not at the next host sync).
  * **Lane compaction** — at chunk boundaries, retired lanes are compacted
    out of the batch tile and the freed slots admit queued images, so a
    long-running image never blocks throughput (continuous batching).

The per-lane executed-add counter is the same energy side channel the
paper integrates (§V): a retired lane's counter is frozen, which is the
measurable "sleep sooner" win.

The window chunk is a pure jitted function over explicit lane state, so
the whole engine state is a pytree; only queue admission and result
collection happen on the host.  Full-window (non-streaming) requests
should instead go straight through ``core.snn.snn_apply_int``, which
dispatches to the fused Pallas megakernel via the backend selector.
"""

from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core import lif as lif_mod
from ..core import prng as prng_mod
from ..core.snn import SNNConfig, encode_lif_timestep
from .early_exit import StabilityGateState, stability_init, stability_step

__all__ = ["SNNStreamEngine", "LaneState", "RequestResult", "stream_chunk"]


class LaneState(NamedTuple):
    """Device-side state of one batch tile (all arrays leading dim B)."""

    px: jax.Array          # (B, n_in) uint8 pixels
    rng: jax.Array         # (B, n_in) uint32 xorshift lanes
    v: jax.Array           # (B, n_out) int32 membrane accumulators
    en: jax.Array          # (B, n_out) bool neuron clock-gates
    counts: jax.Array      # (B, n_out) int32 spike registers
    gate_prev: jax.Array   # (B,) int32 stability-gate memory
    gate_streak: jax.Array  # (B,) int32
    steps: jax.Array       # (B,) int32 window steps executed
    adds: jax.Array        # (B,) int32 executed synaptic adds (energy)
    active: jax.Array      # (B,) bool — lane still consuming compute


@dataclass
class RequestResult:
    request_id: int
    pred: int
    spike_counts: np.ndarray
    steps: int             # window steps actually consumed
    adds: int              # synaptic adds executed (energy side channel)
    early_exit: bool       # retired by the stability gate before T


def _init_lanes(batch: int, n_in: int, n_out: int,
                v_rest: int) -> LaneState:
    g = stability_init(batch)
    return LaneState(
        px=jnp.zeros((batch, n_in), jnp.uint8),
        rng=jnp.full((batch, n_in), 1, jnp.uint32),
        v=jnp.full((batch, n_out), v_rest, jnp.int32),
        en=jnp.ones((batch, n_out), bool),
        counts=jnp.zeros((batch, n_out), jnp.int32),
        gate_prev=g.prev,
        gate_streak=g.streak,
        steps=jnp.zeros((batch,), jnp.int32),
        adds=jnp.zeros((batch,), jnp.int32),
        active=jnp.zeros((batch,), bool),
    )


@partial(jax.jit, static_argnames=(
    "chunk_steps", "num_steps", "lif_cfg", "dot_impl", "active_pruning",
    "patience"))
def stream_chunk(lanes: LaneState, w_q: jax.Array, *, chunk_steps: int,
                 num_steps: int, lif_cfg: lif_mod.LIFConfig,
                 dot_impl: str, active_pruning: bool,
                 patience: int) -> LaneState:
    """Advance every active lane by up to ``chunk_steps`` window steps.

    The per-step datapath is ``core.snn.encode_lif_timestep`` — the same
    single source of truth the fused jnp scan uses — with two lane-level
    gates on top: the stability early exit and the T-step window bound.
    A retired/inactive lane is completely frozen — PRNG, membrane,
    counters and the add counter stop, which is what the compaction test
    measures.
    """

    def body(carry, _):
        st = carry
        act = st.active
        neuron = lif_mod.LIFStateInt(v=st.v, enable=st.en)
        rng, neuron, fired, spk = encode_lif_timestep(
            st.rng, st.px, neuron, w_q, lif_cfg, dot_impl=dot_impl,
            active_pruning=active_pruning)
        v_new, en = neuron.v, neuron.enable
        counts = st.counts + fired.astype(jnp.int32)
        adds_t = (jnp.sum(spk.astype(jnp.int32), axis=-1)
                  * jnp.sum(st.en.astype(jnp.int32), axis=-1))
        # stability gate on the running prediction (pure, in-loop); a lane
        # with no output spikes yet has no prediction to be stable about —
        # its gate state stays at init so neither the streak nor the retire
        # can trigger before the first spike (argmax(zeros)=0 is not a
        # stable class-0 vote, and the streak must not pre-accumulate).
        has_spike = jnp.max(counts, axis=-1) > 0
        pred = jnp.argmax(counts, axis=-1).astype(jnp.int32)
        gate, done = stability_step(
            StabilityGateState(prev=st.gate_prev, streak=st.gate_streak),
            pred, patience)
        gate = StabilityGateState(
            prev=jnp.where(has_spike, gate.prev, -1),
            streak=jnp.where(has_spike, gate.streak, 0))
        done = jnp.logical_and(done, has_spike)
        steps = st.steps + act.astype(jnp.int32)
        still = jnp.logical_and(act, jnp.logical_not(done))
        still = jnp.logical_and(still, steps < num_steps)

        def keep(new, old, mask=act):
            return jnp.where(mask.reshape((-1,) + (1,) * (new.ndim - 1)),
                             new, old)

        return LaneState(
            px=st.px,
            rng=keep(rng, st.rng),
            v=keep(v_new, st.v),
            en=keep(en, st.en),
            counts=keep(counts, st.counts),
            gate_prev=keep(gate.prev, st.gate_prev),
            gate_streak=keep(gate.streak, st.gate_streak),
            steps=steps,
            adds=st.adds + jnp.where(act, adds_t, 0),
            active=jnp.where(act, still, st.active),
        ), None

    lanes, _ = jax.lax.scan(body, lanes, None, length=chunk_steps)
    return lanes


class SNNStreamEngine:
    """Continuous-batching front end over the streaming window chunk.

    Usage::

        eng = SNNStreamEngine(params_q, cfg, batch_size=8)
        ids = [eng.submit(img) for img in images]     # queue requests
        results = eng.run()                            # {id: RequestResult}
    """

    def __init__(self, params_q: dict, cfg: SNNConfig, *, batch_size: int = 8,
                 chunk_steps: int = 4, patience: int = 2, seed: int = 0):
        if len(params_q["layers"]) != 1:
            raise ValueError("streaming engine supports the paper's "
                             "single-layer topology")
        if cfg.readout != "count":
            raise ValueError(
                f"streaming engine implements the 'count' readout only; "
                f"got readout={cfg.readout!r} — run first_spike/membrane "
                f"configs through core.snn.snn_apply_int instead")
        self.w_q = params_q["layers"][0]["w_q"]
        self.cfg = cfg
        self.batch_size = batch_size
        self.chunk_steps = chunk_steps
        self.patience = patience
        self.seed = seed
        self.n_in, self.n_out = self.w_q.shape
        self.lanes = _init_lanes(batch_size, self.n_in, self.n_out,
                                 cfg.lif.v_rest)
        self.lane_req: list[int | None] = [None] * batch_size
        self.queue: list[tuple[int, np.ndarray]] = []
        self.results: dict[int, RequestResult] = {}
        self._next_id = 0

    # ---- request intake -------------------------------------------------
    def submit(self, pixels_u8: np.ndarray) -> int:
        """Enqueue one image; returns its request id."""
        pixels_u8 = np.asarray(pixels_u8, np.uint8).reshape(self.n_in)
        rid = self._next_id
        self._next_id += 1
        self.queue.append((rid, pixels_u8))
        return rid

    @property
    def pending(self) -> int:
        return len(self.queue) + sum(r is not None for r in self.lane_req)

    # ---- scheduling -----------------------------------------------------
    def _admit_and_compact(self) -> list[int]:
        """Harvest retired lanes, compact active ones, admit queued images.

        Returns the request ids finished in this call.  Runs on the host at
        chunk boundaries: the batch tile stays dense, so freed slots start
        contributing to throughput on the very next chunk.
        """
        occupied = np.array([r is not None for r in self.lane_req])
        # Cheap pre-check: only the (B,) active mask crosses the device
        # boundary.  The full lane-state round trip below happens only when
        # a lane actually retired or a queued request can be admitted.
        active = np.asarray(self.lanes.active)
        if not (occupied & ~active).any() and not (
                self.queue and not (occupied & active).all()):
            return []
        st = jax.tree.map(lambda a: np.array(a), self.lanes)
        finished_lanes = occupied & ~st.active
        done_ids = []
        for i in np.nonzero(finished_lanes)[0]:
            rid = self.lane_req[int(i)]
            self.results[rid] = RequestResult(
                request_id=rid,
                pred=int(st.counts[i].argmax()),
                spike_counts=st.counts[i].copy(),
                steps=int(st.steps[i]),
                adds=int(st.adds[i]),
                early_exit=int(st.steps[i]) < self.cfg.num_steps,
            )
            done_ids.append(rid)

        # Compact: live lanes first (stable), freed/empty lanes after.
        live = np.nonzero(occupied & st.active)[0]
        free = np.nonzero(~(occupied & st.active))[0]
        order = np.concatenate([live, free]).astype(np.int32)
        st = jax.tree.map(lambda a: a[order], st)
        n_live = len(live)
        self.lane_req = ([self.lane_req[int(i)] for i in live]
                         + [None] * (self.batch_size - n_live))

        # Admit queued requests into the freed tail slots.
        for slot in range(n_live, self.batch_size):
            if not self.queue:
                break
            rid, pixels = self.queue.pop(0)
            st.px[slot] = pixels
            st.rng[slot] = np.asarray(
                prng_mod.seed_state(self.seed + rid, (self.n_in,)))
            st.v[slot] = self.cfg.lif.v_rest
            st.en[slot] = True
            st.counts[slot] = 0
            st.gate_prev[slot] = -1
            st.gate_streak[slot] = 0
            st.steps[slot] = 0
            st.adds[slot] = 0
            st.active[slot] = True
            self.lane_req[slot] = rid

        self.lanes = jax.tree.map(jnp.asarray, st)
        return done_ids

    def step(self) -> list[int]:
        """Admit + run one chunk.  Returns request ids finished so far."""
        done = self._admit_and_compact()
        self.lanes = stream_chunk(
            self.lanes, self.w_q, chunk_steps=self.chunk_steps,
            num_steps=self.cfg.num_steps, lif_cfg=self.cfg.lif,
            dot_impl=self.cfg.dot_impl,
            active_pruning=self.cfg.active_pruning, patience=self.patience)
        return done

    def run(self, max_chunks: int | None = None) -> dict[int, RequestResult]:
        """Drive chunks until every submitted request has a result."""
        limit = max_chunks if max_chunks is not None else (
            (self.pending + self.batch_size)
            * (self.cfg.num_steps // self.chunk_steps + 2))
        for _ in range(limit):
            if self.pending == 0:
                break
            self.step()
        self._admit_and_compact()
        return self.results
