"""Batched streaming SNN serving engine (paper §IV-C at the request level).

The RTL classifies one image per window.  A TPU serving deployment instead
packs many requests into one batch tile and streams them through the
integer datapath together.  This engine adds the two scheduling ideas that
make that efficient under heavy traffic:

  * **Early exit** — a lane whose running prediction has been stable for
    ``patience`` consecutive steps retires before the window ends (the
    request-level analogue of active pruning; pure gate from
    serve.early_exit, evaluated *inside* the device-side window chunk so a
    lane stops burning adds the step it retires, not at the next host
    sync).
  * **Lane compaction** — at chunk boundaries, retired lanes are compacted
    out of the batch tile and the freed slots admit queued images, so a
    long-running image never blocks throughput (continuous batching).

The window chunk dispatches through the integer engine's backends
(core.snn): on TPU the **resumable fused megakernel** advances every lane
``chunk_steps`` steps in one Pallas launch — layer weights stay resident,
inter-layer spikes never touch HBM, and the stability gate runs inside the
kernel so per-step retirement semantics are preserved bit-for-bit.  On
hosts without a TPU the same datapath runs as a pure-jnp scan over
``core.snn.snn_int_stack_step`` (the reference backend) — both paths
produce identical lane-state evolution for the same seeds.

The per-lane executed-add counter is the same energy side channel the
paper integrates (§V): a retired lane's counter is frozen, which is the
measurable "sleep sooner" win.

Every chunk also returns the structured **telemetry side channel**
(``core.telemetry.ChunkTelemetry`` — per-step/layer spike counts, prune
occupancy, skipped MXU tiles), produced bit-identically by the fused
kernels and the jnp fallback.  The engines feed it to a
``serve.telemetry.TelemetryController``: frozen by default (static
threshold + chunk length, zero readbacks — today's behavior bit-for-bit),
or adaptive (``REPRO_ADAPTIVE_DISPATCH=1`` / an explicit
``AdaptiveDispatchConfig``), where live traffic retunes the masked-vs-MXU
dispatch threshold and picks the next chunk length.  Adaptivity is
value-neutral: chunk splits and datapath choice are bit-identical by
construction, so only wall-clock moves.

Readouts: all three stream — ``count`` (spike-register argmax),
``first_spike`` (earliest spiking class, membrane tiebreak — the
active-pruning config's readout) and ``membrane`` (peak-membrane argmax:
the per-layer running peak is carried in ``LaneState.v_peak``, so no
per-step trace ever crosses the chunk boundary).

:class:`ShardedSNNStreamEngine` scales the same engine across a device
mesh: the lane tile is data-parallel (one contiguous slot block per
device, weights replicated) and the chunk runs under ``shard_map`` —
bit-identical to single-device serving because every op here is per-lane.
"""

from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from types import SimpleNamespace
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..core import lif as lif_mod
from ..core import prng as prng_mod
from ..core.snn import (SNNConfig, readout_pred, snn_int_stack_step,
                        snn_int_stack_step_sharded)
from ..core.telemetry import (ChunkTelemetry, EngineLoad,
                              telemetry_partition_specs)
from ..distributed.sharding import make_device_mesh, shard_map_compat
from .early_exit import StabilityGateState, stability_specs, stability_step
from .faults import (DeviceLostFault, DispatchFault, EngineFailure,
                     EngineHealthState, FaultInjector, FaultToleranceConfig,
                     PoisonDispatchError, injector_from_env, telemetry_ok)
from .rollout import WeightBank, merge_version_chunks
from .telemetry import AdaptiveDispatchConfig, TelemetryController, \
    make_controller, \
    summarize_chunk

__all__ = ["SNNStreamEngine", "ShardedSNNStreamEngine", "LaneState",
           "RequestResult", "stream_chunk", "lane_partition_specs",
           "weight_partition_specs", "make_sharded_stream_chunk"]

_V_PEAK_INIT = np.iinfo(np.int32).min   # window-start peak sentinel


class LaneState(NamedTuple):
    """Device-side state of one batch tile (all arrays leading dim B)."""

    px: jax.Array          # (B, n_in) uint8 pixels
    rng: jax.Array         # (B, n_in) uint32 xorshift lanes
    v: tuple               # per-layer (B, n_l) int32 membrane accumulators
    en: tuple              # per-layer (B, n_l) bool neuron clock-gates
    v_peak: tuple          # per-layer (B, n_l) int32 running peak membranes
    counts: jax.Array      # (B, n_out) int32 spike registers
    first: jax.Array       # (B, n_out) int32 first-spike latch (sentinel=T)
    gate_prev: jax.Array   # (B,) int32 stability-gate memory
    gate_streak: jax.Array  # (B,) int32
    steps: jax.Array       # (B,) int32 window steps executed
    adds: jax.Array        # (B,) int32 executed synaptic adds (energy)
    active: jax.Array      # (B,) bool — lane still consuming compute
    weight_version: jax.Array  # (B,) int32 admission-time WeightBank tag


@dataclass
class RequestResult:
    request_id: int
    pred: int
    spike_counts: np.ndarray
    steps: int             # window steps actually consumed
    adds: int              # synaptic adds executed (energy side channel)
    early_exit: bool       # retired by the stability gate before T
    weight_version: int = 0  # weight plane version the window ran on


def _init_lanes(batch: int, layer_sizes: tuple[int, ...], num_steps: int,
                v_rest: int) -> LaneState:
    n_in, n_out = layer_sizes[0], layer_sizes[-1]
    return LaneState(
        px=jnp.zeros((batch, n_in), jnp.uint8),
        rng=jnp.full((batch, n_in), 1, jnp.uint32),
        v=tuple(jnp.full((batch, n), v_rest, jnp.int32)
                for n in layer_sizes[1:]),
        en=tuple(jnp.ones((batch, n), bool) for n in layer_sizes[1:]),
        v_peak=tuple(jnp.full((batch, n), _V_PEAK_INIT, jnp.int32)
                     for n in layer_sizes[1:]),
        counts=jnp.zeros((batch, n_out), jnp.int32),
        first=jnp.full((batch, n_out), num_steps, jnp.int32),
        gate_prev=jnp.full((batch,), -1, jnp.int32),
        gate_streak=jnp.zeros((batch,), jnp.int32),
        steps=jnp.zeros((batch,), jnp.int32),
        adds=jnp.zeros((batch,), jnp.int32),
        active=jnp.zeros((batch,), bool),
        weight_version=jnp.zeros((batch,), jnp.int32),
    )


def _stream_chunk_impl(lanes: LaneState, weights: tuple, *, chunk_steps: int,
                       num_steps: int, lif_cfg: lif_mod.LIFConfig,
                       dot_impl: str, active_pruning: bool, patience: int,
                       readout: str = "count", backend: str = "reference",
                       sparse_skip: bool | None = None,
                       interpret: bool | None = None,
                       model_axis: str | None = None,
                       model_ways: tuple[int, ...] | None = None,
                       block_b: int | None = None):
    """Un-jitted chunk body: every op is per-lane (no cross-batch contact),
    which is what lets the same code run whole-tile under ``jax.jit`` or
    per-device-slice under ``shard_map`` with bit-identical results.
    Returns ``(lanes', telemetry)`` — the telemetry record is produced
    bit-identically by the fused kernels and this jnp fallback (frozen
    lanes report zero activity, matching the frozen add counters; the
    tile counter reflects the block work the launch geometry executed).

    ``model_axis``/``model_ways`` switch the datapath to the model-sharded
    step (``core.snn.snn_int_stack_step_sharded``): ``weights`` are then
    the device-LOCAL per-layer views (output-column shards for layers
    whose ``model_ways`` entry > 1) and the layer loop exchanges spikes
    over ``model_axis``, so this body must be traced inside ``shard_map``
    on a mesh carrying that axis.  The whole-chunk single-launch
    megakernel cannot host the exchange (collectives cannot run inside a
    ``pallas_call``), so a fused backend decomposes into per-(step, layer)
    Pallas partial-contraction launches — still VMEM-resident weights,
    still bit-identical: the gate/freeze logic below runs on full
    (gathered) arrays identically on every model peer.
    """
    if model_axis is not None and backend in ("fused", "fused_streamed"):
        contraction = "pallas"
    else:
        contraction = "jnp"
    if backend in ("fused", "fused_streamed") and model_axis is None:
        from ..kernels import ops
        k = ops.fused_snn_stack_op(
            lanes.px, lanes.rng, weights, num_steps=num_steps,
            chunk_steps=chunk_steps, decay_shift=lif_cfg.decay_shift,
            v_threshold=lif_cfg.v_threshold, v_rest=lif_cfg.v_rest,
            v_min=lif_cfg.v_min, v_max=lif_cfg.v_max,
            active_pruning=active_pruning,
            init={"v": lanes.v, "en": lanes.en, "v_peak": lanes.v_peak,
                  "counts": lanes.counts, "first": lanes.first,
                  "steps": lanes.steps},
            gate={"active": lanes.active, "prev": lanes.gate_prev,
                  "streak": lanes.gate_streak},
            patience=patience, readout=readout, sparse_skip=sparse_skip,
            streamed=(backend == "fused_streamed"), interpret=interpret,
            block_b=block_b)
        return LaneState(
            px=lanes.px, rng=k["prng_state"], v=k["v"], en=k["en"],
            v_peak=k["v_peak"],
            counts=k["spike_counts"], first=k["first_spike_t"],
            gate_prev=k["gate"]["prev"], gate_streak=k["gate"]["streak"],
            steps=k["steps"],
            adds=lanes.adds + jnp.sum(k["active_adds"], axis=0),
            active=k["gate"]["active"],
            weight_version=lanes.weight_version), k["telemetry"]

    def body(carry, _):
        st = carry
        act = st.active
        layer_states = tuple(lif_mod.LIFStateInt(v=v, enable=e)
                             for v, e in zip(st.v, st.en))
        if model_axis is not None:
            rng, new_states, fired, adds_t, tel = \
                snn_int_stack_step_sharded(
                    st.rng, st.px, layer_states, weights, lif_cfg,
                    model_axis=model_axis, ways=model_ways,
                    dot_impl=dot_impl, active_pruning=active_pruning,
                    sparse_skip=sparse_skip, contraction=contraction,
                    interpret=interpret)
        else:
            rng, new_states, fired, adds_t, tel = snn_int_stack_step(
                st.rng, st.px, layer_states, weights, lif_cfg,
                dot_impl=dot_impl, active_pruning=active_pruning,
                sparse_skip=sparse_skip)
        counts = st.counts + fired.astype(jnp.int32)
        first = jnp.where(
            jnp.logical_and(fired, st.first == num_steps),
            st.steps[:, None], st.first)
        v_peak = tuple(jnp.maximum(p, s.v)
                       for p, s in zip(st.v_peak, new_states))
        # stability gate on the running prediction (pure, in-loop); a lane
        # with no output spikes yet has no prediction to be stable about —
        # its gate state stays at init so neither the streak nor the retire
        # can trigger before the first spike (argmax(zeros)=0 is not a
        # stable class-0 vote, and the streak must not pre-accumulate).
        has_spike = jnp.max(counts, axis=-1) > 0
        pred = readout_pred(counts, first, new_states[-1].v, readout,
                            num_steps, v_peak=v_peak[-1]).astype(jnp.int32)
        gate, done = stability_step(
            StabilityGateState(prev=st.gate_prev, streak=st.gate_streak),
            pred, patience)
        gate_prev = jnp.where(has_spike, gate.prev, -1)
        gate_streak = jnp.where(has_spike, gate.streak, 0)
        done = jnp.logical_and(done, has_spike)
        steps = st.steps + act.astype(jnp.int32)
        still = jnp.logical_and(act, jnp.logical_not(done))
        still = jnp.logical_and(still, steps < num_steps)

        def keep(new, old, mask=act):
            return jnp.where(mask.reshape((-1,) + (1,) * (new.ndim - 1)),
                             new, old)

        # telemetry rows: frozen lanes execute nothing → zeroed, mirroring
        # the gated kernel; tiles stay raw (block-level executed work)
        tel_spk = jnp.where(act[None, :], tel["n_spk"], 0)
        tel_en = jnp.where(act[None, :], tel["n_en"], 0)
        return LaneState(
            px=st.px,
            rng=keep(rng, st.rng),
            v=tuple(keep(s.v, ov) for s, ov in zip(new_states, st.v)),
            en=tuple(keep(s.enable, oe)
                     for s, oe in zip(new_states, st.en)),
            v_peak=tuple(keep(nv, ov)
                         for nv, ov in zip(v_peak, st.v_peak)),
            counts=keep(counts, st.counts),
            first=keep(first, st.first),
            gate_prev=keep(gate_prev, st.gate_prev),
            gate_streak=keep(gate_streak, st.gate_streak),
            steps=steps,
            adds=st.adds + jnp.where(act, adds_t, 0),
            active=jnp.where(act, still, st.active),
            weight_version=st.weight_version,
        ), (tel_spk, tel_en, tel["tiles"])

    lanes, (tspk, ten, ttile) = jax.lax.scan(body, lanes, None,
                                             length=chunk_steps)
    return lanes, ChunkTelemetry(n_spk=tspk, n_en=ten, tiles_skipped=ttile)


@partial(jax.jit, static_argnames=(
    "chunk_steps", "num_steps", "lif_cfg", "dot_impl", "active_pruning",
    "patience", "readout", "backend", "sparse_skip", "interpret",
    "block_b"))
def stream_chunk(lanes: LaneState, weights: tuple, *, chunk_steps: int,
                 num_steps: int, lif_cfg: lif_mod.LIFConfig,
                 dot_impl: str, active_pruning: bool, patience: int,
                 readout: str = "count", backend: str = "reference",
                 sparse_skip: bool | None = None,
                 interpret: bool | None = None,
                 block_b: int | None = None):
    """Advance every active lane by up to ``chunk_steps`` window steps.

    ``backend="fused"`` runs the whole chunk — every layer, every step,
    the stability gate included — inside one resumable Pallas launch
    (kernels.fused_snn); ``backend="fused_streamed"`` is the same launch
    with the packed weights double-buffered out of HBM (stacks over the
    VMEM residency budget); ``backend="reference"`` scans the same
    datapath in jnp via ``core.snn.snn_int_stack_step``.  All are
    bit-identical on shared lane state, including mid-chunk retirement: a
    retired or inactive lane is completely frozen — PRNG, membranes,
    counters and the add counter stop, which is what the compaction test
    measures.  ``sparse_skip`` forwards the event-driven tile skipping
    flag (value-neutral).  Returns ``(lanes', ChunkTelemetry)`` — the
    structured activity record the adaptive controller consumes, itself
    bit-identical across the chunk backends.  ``block_b`` forwards the
    tuned batch-block override to the fused launch (value-neutral — it
    only reshapes the launch grid and its telemetry tile mirror).
    """
    return _stream_chunk_impl(
        lanes, weights, chunk_steps=chunk_steps, num_steps=num_steps,
        lif_cfg=lif_cfg, dot_impl=dot_impl, active_pruning=active_pruning,
        patience=patience, readout=readout, backend=backend,
        sparse_skip=sparse_skip, interpret=interpret, block_b=block_b)


def lane_partition_specs(n_layers: int,
                         axis_name: str | None = "data",
                         model_axis: str | None = None) -> LaneState:
    """Per-leaf ``PartitionSpec``s of a data-parallel lane tile.

    Every :class:`LaneState` leaf leads with the batch axis and the chunk
    body never looks across it, so the whole tile shards on one mesh axis;
    quantized weights are the replicated operand.  The gate leaves come
    from ``early_exit.stability_specs`` — the per-lane shardability of the
    in-kernel early exit is that module's contract, not this one's.

    ``model_axis`` is accepted for symmetry with the weight/telemetry
    specs and deliberately changes nothing: the lane checkpoint is
    REPLICATED over the model axis (no leaf mentions it), which is the
    placement-independence contract — a row snapshotted from a
    model-sharded engine adopts into any other engine unchanged, so
    failover/evacuation works identically on 1-D and 2-D meshes.
    """
    del model_axis                       # lane state never shards on it
    p = P(axis_name)
    gate = stability_specs(axis_name)
    return LaneState(
        px=p, rng=p, v=(p,) * n_layers, en=(p,) * n_layers,
        v_peak=(p,) * n_layers,
        counts=p, first=p, gate_prev=gate.prev, gate_streak=gate.streak,
        steps=p, adds=p, active=p, weight_version=p)


def weight_partition_specs(model_ways: tuple[int, ...],
                           model_axis: str | None) -> tuple:
    """Per-layer ``PartitionSpec``s of the quantized weight planes.

    Layers whose effective shard count (``kernels.fused_snn.
    layer_shard_ways``) exceeds 1 split their output-column axis over the
    model mesh axis; non-dividing layers (and every layer on a 1-D data
    mesh) replicate.
    """
    if model_axis is None:
        return tuple(P() for _ in model_ways)
    return tuple(P(None, model_axis) if w > 1 else P() for w in model_ways)


def make_sharded_stream_chunk(mesh: Mesh, axis_name: str, n_layers: int, *,
                              chunk_steps: int, num_steps: int,
                              lif_cfg: lif_mod.LIFConfig, dot_impl: str,
                              active_pruning: bool, patience: int,
                              readout: str = "count",
                              backend: str = "reference",
                              sparse_skip: bool | None = None,
                              interpret: bool | None = None,
                              model_axis: str | None = None,
                              model_ways: tuple[int, ...] | None = None,
                              block_b: int | None = None):
    """Build the (data × model) chunk executor for ``mesh``.

    Returns a jitted ``(lanes, weights) -> (lanes, telemetry)`` whose body
    runs under ``shard_map``: each device executes the fused megakernel
    (or the jnp scan fallback) on its local lane slice with the weights
    replicated — the software analogue of the paper's replicated
    neuron-core lanes.  On a 1-D data mesh no collectives are emitted: the
    stability gate, lane freezing and the telemetry record are
    per-lane/per-block, so the mapped body is embarrassingly parallel and
    bit-identical to the single-device :func:`stream_chunk` on the
    concatenation of the slices (telemetry's tile leaf concatenates the
    device-local block lists — the geometry each device's launch actually
    executed).

    With ``model_axis``/``model_ways`` the weights arrive pre-sharded per
    layer (:func:`weight_partition_specs`: output-column shards over the
    model axis for layers that divide) and the body runs the model-sharded
    datapath — per-device partial contraction, ``all_gather`` spike
    exchange at layer boundaries.  Lane state stays data-sharded /
    model-replicated, the per-lane telemetry counts are derived from full
    gathered arrays (still bit-identical to single-device), and the tile
    leaf concatenates per-shard skip counts data-outer / model-inner on
    the block axis.
    """
    specs = lane_partition_specs(n_layers, axis_name, model_axis)
    tel_specs = telemetry_partition_specs(axis_name, model_axis)
    if model_ways is None:
        w_specs = P()
    else:
        w_specs = weight_partition_specs(model_ways, model_axis)
    body = partial(
        _stream_chunk_impl, chunk_steps=chunk_steps, num_steps=num_steps,
        lif_cfg=lif_cfg, dot_impl=dot_impl, active_pruning=active_pruning,
        patience=patience, readout=readout, backend=backend,
        sparse_skip=sparse_skip, interpret=interpret,
        model_axis=model_axis, model_ways=model_ways, block_b=block_b)
    mapped = shard_map_compat(body, mesh, in_specs=(specs, w_specs),
                              out_specs=(specs, tel_specs))
    return jax.jit(mapped)


class SNNStreamEngine:
    """Continuous-batching front end over the streaming window chunk.

    Usage::

        eng = SNNStreamEngine(params_q, cfg, batch_size=8)
        ids = [eng.submit(img) for img in images]     # queue requests
        results = eng.run()                            # {id: RequestResult}

    ``backend`` picks the chunk executor: ``"fused"`` (resumable Pallas
    megakernel, int8-packed weights resident — interpret mode off-TPU, so
    slow but bit-exact there), ``"fused_streamed"`` (the same launch with
    weights double-buffered out of HBM, for stacks over the VMEM
    residency budget), ``"reference"`` (jnp scan), or None/"auto" (fused →
    fused_streamed on TPU by per-device VMEM feasibility, reference
    elsewhere).  Arbitrary layer stacks are supported — hidden-layer spike
    traffic stays on-chip on the fused paths.  All three config readouts
    stream, including ``membrane`` (peak-membrane argmax off the carried
    ``LaneState.v_peak`` accumulator).

    ``adaptive`` configures the telemetry controller
    (serve.telemetry.TelemetryController): None reads the
    REPRO_ADAPTIVE_DISPATCH env default (frozen off it) — frozen mode
    reproduces the static threshold/chunk choices with zero telemetry
    readbacks; adaptive mode retunes the masked-vs-MXU dispatch threshold
    (``engine.dispatch_threshold``) and picks each next chunk's length
    from the observed density/retirement stream.  Either way results are
    bit-identical — the controller only ever moves value-neutral knobs.
    """

    def __init__(self, params_q: dict, cfg: SNNConfig, *,
                 batch_size: int | None = None,
                 chunk_steps: int | None = None, patience: int = 2,
                 seed: int = 0,
                 backend: str | None = None,
                 local_batch: int | None = None,
                 model_shards: int = 1,
                 adaptive: AdaptiveDispatchConfig | None = None,
                 engine_id: int = 0,
                 injector: FaultInjector | None = None,
                 fault_cfg: FaultToleranceConfig | None = None,
                 initial_weight_version: int = 0,
                 block_b: int | None = None,
                 dispatch_cache=None):
        if cfg.readout not in ("count", "first_spike", "membrane"):
            raise ValueError(
                f"unknown readout {cfg.readout!r}: the streaming engine "
                f"implements 'count', 'first_spike' and 'membrane'")
        from ..core.snn import fused_unsupported_reason
        from ..tune.cache import CacheDecision, decide_dispatch
        weights = tuple(layer["w_q"] for layer in params_q["layers"])
        self.layer_sizes = tuple([weights[0].shape[0]]
                                 + [w.shape[1] for w in weights])
        # ---- dispatch cache (repro.tune): tuned startup shapes ----------
        # Resolved exactly once per engine (explicit argument → the
        # REPRO_DISPATCH_CACHE env → none; the sharded subclass passes a
        # pre-made decision keyed by its 2-D mesh shape) and always
        # recorded as ``self.cache_decision`` — a miss or a rejected file
        # serves today's static defaults, never an error.  Explicit
        # constructor arguments beat tuned values knob by knob.
        if isinstance(dispatch_cache, CacheDecision):
            self.cache_decision = dispatch_cache
        else:
            self.cache_decision = decide_dispatch(
                dispatch_cache, cfg=cfg, backend=backend, mesh_shape=(1,))
        tuned = (self.cache_decision.tuned if self.cache_decision.hit
                 else None)
        if tuned is not None:
            if batch_size is None:
                # single-device serving: the whole tile IS one device's
                # lanes, so the tuned per-device lane count applies as-is
                batch_size = tuned.lanes_per_device
            if chunk_steps is None:
                chunk_steps = tuned.chunk_steps
            if block_b is None:
                block_b = tuned.block_b
        if batch_size is None:
            batch_size = 8
        if chunk_steps is None:
            chunk_steps = 4
        self._block_b = block_b
        # Per-device lane tile (the sharded subclass passes its slice;
        # single-device serving holds the whole tile) — scopes the fused
        # VMEM feasibility checks below to one device's launch.  The
        # sharded subclass likewise passes the model-axis width so the
        # checks run against the per-device weight SHARD: a WIDE stack
        # over single-device VMEM resolves resident fused on a 4-way
        # model axis instead of falling back to fused_streamed.
        self.local_batch = batch_size if local_batch is None else local_batch
        self.model_shards = int(model_shards)

        def reason_for(streamed: bool) -> str | None:
            return fused_unsupported_reason(
                cfg, len(weights), self.layer_sizes,
                trace_steps=chunk_steps, local_batch=self.local_batch,
                streamed=streamed, model_shards=self.model_shards,
                block_b=self._block_b)

        if backend in (None, "auto"):
            # A cache hit whose shapes this engine is actually running
            # (no knob overridden) carries the backend that resolved
            # during the tuned run — adopt it after ONE feasibility
            # check against the cached shapes instead of walking the
            # whole chain; a mismatched entry falls through to the
            # normal resolution below (a bad cache degrades to static
            # behavior, it never crashes serving).
            cached_backend = None
            if (tuned is not None
                    and chunk_steps == tuned.chunk_steps
                    and self._block_b == tuned.block_b
                    and self.local_batch == tuned.lanes_per_device):
                t = tuned.backend
                if t == "reference":
                    cached_backend = t
                elif (t in ("fused", "fused_streamed")
                        and jax.default_backend() == "tpu"
                        and reason_for(t == "fused_streamed") is None):
                    cached_backend = t
            # the resumable-backend mirror of core.snn.resolve_backend's
            # fused → fused_streamed chain (staged cannot resume, so the
            # last resort here is the jnp reference scan)
            if cached_backend is not None:
                backend = cached_backend
            elif jax.default_backend() != "tpu":
                backend = "reference"
            elif reason_for(False) is None:
                backend = "fused"
            elif reason_for(True) is None:
                backend = "fused_streamed"
            else:
                backend = "reference"
        if backend not in ("fused", "fused_streamed", "reference"):
            raise ValueError(
                f"streaming chunk backend must be 'fused', 'fused_streamed'"
                f" or 'reference' (the staged kernels cannot resume "
                f"mid-window); got {backend!r}")
        self.backend = backend
        if backend in ("fused", "fused_streamed"):
            from ..kernels.ops import validate_weight_codes
            validate_weight_codes(weights)  # int8-packing range
            reason = reason_for(backend == "fused_streamed")
            if reason is not None:
                raise ValueError(f"{backend} streaming backend unavailable:"
                                 f" {reason} — use backend='reference'")
        # Degradation ladder (serve.faults): the resumable slice of the
        # resolve_backend chain below the configured backend — staged
        # cannot resume mid-window, so the last rung is always the jnp
        # reference scan; infeasible rungs (a streamed launch over budget)
        # are skipped at construction so a demotion can never fault on
        # feasibility.  health.demotion_level indexes this tuple.
        rungs = ("fused", "fused_streamed", "reference")
        self._ladder = tuple(
            b for b in rungs[rungs.index(backend):]
            if b in (backend, "reference")
            or reason_for(b == "fused_streamed") is None)
        self.engine_id = int(engine_id)
        self.injector = (injector if injector is not None
                         else injector_from_env(engine_id))
        self.fault_cfg = fault_cfg or FaultToleranceConfig()
        self.health = EngineHealthState()
        self._cooldown = 0           # scheduling rounds left to sit out
        self._adoptions: list[tuple[int, LaneState]] = []  # evacuated rows
        # Version-tagged weight store (serve.rollout): new admissions bind
        # bank.current; in-flight lanes keep their admission-time version.
        self.bank = WeightBank(self._place_weights(weights),
                               version=int(initial_weight_version))
        self.cfg = cfg
        self.batch_size = batch_size
        self.patience = patience
        self.seed = seed
        if tuned is not None:
            # tuned statics (threshold always; chunk length unless the
            # caller overrode it — `chunk_steps` is the effective value
            # here either way).  Frozen mode serves these with zero
            # readbacks; adaptive walks its law from this start.
            self.controller = TelemetryController.from_cache(
                SimpleNamespace(
                    chunk_steps=chunk_steps,
                    spike_density_threshold=tuned.spike_density_threshold),
                cfg_adaptive=adaptive, num_steps=cfg.num_steps)
        else:
            self.controller = make_controller(
                adaptive,
                spike_density_threshold=cfg.spike_density_threshold,
                chunk_steps=chunk_steps, num_steps=cfg.num_steps)
        self.n_in, self.n_out = self.layer_sizes[0], self.layer_sizes[-1]
        self.lanes = _init_lanes(batch_size, self.layer_sizes,
                                 cfg.num_steps, cfg.lif.v_rest)
        self.lane_req: list[int | None] = [None] * batch_size
        self.queue: list[tuple[int, np.ndarray]] = []
        self.results: dict[int, RequestResult] = {}
        self._next_id = 0
        # Host mirror of LaneState.weight_version (only admission writes
        # it, so no device sync is ever needed to know which versions are
        # in flight) + the load-summary estimators the router reads.
        self._lane_versions = np.zeros(batch_size, np.int64)
        self._service_ewma: float | None = None
        self._retired_total = 0

    _SERVICE_EWMA_ALPHA = 0.25

    @property
    def weights(self) -> tuple:
        """Device-placed weight planes of the CURRENT bank version (new
        admissions bind these; draining lanes may still run older ones)."""
        return self.bank.weights(self.bank.current)

    def _place_weights(self, weights: tuple) -> tuple:
        """Device-placement hook for a weight-plane tuple (the sharded
        engine replicates over its mesh here)."""
        return tuple(jnp.asarray(w) for w in weights)

    @property
    def chunk_steps(self) -> int:
        """Window steps of the NEXT chunk dispatch — the controller's live
        choice (always the configured static value in frozen mode), so
        the public attribute can never go stale under adaptive tuning."""
        return self.controller.chunk_steps

    @property
    def dispatch_threshold(self) -> float:
        """Live masked-vs-MXU density boundary (static when frozen) —
        the value routing layers pass to ``spike_matmul_op``'s
        ``density_threshold``."""
        return self.controller.dispatch_threshold

    # ---- request intake -------------------------------------------------
    def submit(self, pixels_u8: np.ndarray, *,
               request_id: int | None = None) -> int:
        """Enqueue one image; returns its request id.

        ``request_id`` lets a routing tier impose its GLOBAL id: the PRNG
        seeds from ``seed + request_id``, so a request served by any
        engine of a same-seed fleet computes the identical window — the
        tier-level bit-identity contract rides on this hook.
        """
        pixels_u8 = np.asarray(pixels_u8, np.uint8).reshape(self.n_in)
        if request_id is None:
            rid = self._next_id
        else:
            rid = int(request_id)
            if (rid in self.results or rid in self.lane_req
                    or any(q[0] == rid for q in self.queue)
                    or any(a[0] == rid for a in self._adoptions)):
                raise ValueError(f"request id {rid} already in use")
        self._next_id = max(self._next_id, rid + 1)
        self.queue.append((rid, pixels_u8))
        return rid

    def load_summary(self) -> EngineLoad:
        """Routing-tier load signals — pure host bookkeeping, no syncs.

        Includes the health surface: consecutive-fault count, degradation
        rung and hang-watchdog margin (chunks of no-progress headroom
        left; ``None`` when no fault harness is armed and the watchdog
        therefore never runs), and liveness.  ``load_score`` folds these
        into the routing comparison, steering traffic away from degraded
        engines without any new device syncs.
        """
        return EngineLoad(
            lanes_total=self.batch_size,
            lanes_busy=sum(r is not None for r in self.lane_req),
            queue_depth=len(self.queue) + len(self._adoptions),
            mean_service_steps=(float(self.cfg.num_steps)
                                if self._service_ewma is None
                                else self._service_ewma),
            retired_total=self._retired_total,
            density_ewma=self.controller.density_ewma,
            consecutive_faults=self.health.consecutive_faults,
            demotion_level=self.health.demotion_level,
            watchdog_margin=(None if self.injector is None
                             else self.fault_cfg.watchdog_chunks
                             - self.health.stalled_chunks),
            alive=self.health.alive,
        )

    @property
    def pending(self) -> int:
        return (len(self.queue) + len(self._adoptions)
                + sum(r is not None for r in self.lane_req))

    # ---- readout --------------------------------------------------------
    def _host_pred(self, counts: np.ndarray, first: np.ndarray,
                   v_last: np.ndarray, v_peak: np.ndarray) -> int:
        """Harvest-time prediction for one retired lane."""
        return int(readout_pred(counts, first, v_last, self.cfg.readout,
                                self.cfg.num_steps, v_peak=v_peak))

    # ---- scheduling -----------------------------------------------------
    def _harvest(self, st: LaneState, finished: np.ndarray) -> list[int]:
        """Collect RequestResults for every lane in the ``finished`` mask."""
        done_ids = []
        for i in np.nonzero(finished)[0]:
            rid = self.lane_req[int(i)]
            steps = int(st.steps[i])
            self.results[rid] = RequestResult(
                request_id=rid,
                pred=self._host_pred(st.counts[i], st.first[i],
                                     st.v[-1][i], st.v_peak[-1][i]),
                spike_counts=st.counts[i].copy(),
                steps=steps,
                adds=int(st.adds[i]),
                early_exit=steps < self.cfg.num_steps,
                weight_version=int(st.weight_version[i]),
            )
            done_ids.append(rid)
            self._retired_total += 1
            a = self._SERVICE_EWMA_ALPHA
            self._service_ewma = (float(steps) if self._service_ewma is None
                                  else (1 - a) * self._service_ewma
                                  + a * steps)
        return done_ids

    def _admit_into(self, st: LaneState, slot: int) -> None:
        """Fill host-side lane ``slot`` with the next waiting request.

        Evacuated-lane adoptions take priority over fresh admissions: an
        adopted request already spent window steps elsewhere, so it is
        the oldest work waiting, and its row is written back verbatim —
        mid-window resume is bit-exact because the row IS the complete
        chunk-boundary state.

        For fresh requests the PRNG lanes are seeded from
        ``seed + request_id``, so a request's entire window is a pure
        function of its id — independent of which slot, device, chunk
        *or engine* it lands in.  This is what makes sharded,
        single-device and post-failover serving bit-identical per
        request.
        """
        if self._adoptions:
            rid, row = self._adoptions.pop(0)
            for f in LaneState._fields:
                dst, src = getattr(st, f), getattr(row, f)
                if isinstance(dst, tuple):
                    for d, s in zip(dst, src):
                        d[slot] = s
                else:
                    dst[slot] = src
            self.lane_req[slot] = rid
            return
        rid, pixels = self.queue.pop(0)
        st.px[slot] = pixels
        st.rng[slot] = np.asarray(
            prng_mod.seed_state(self.seed + rid, (self.n_in,)))
        for v in st.v:
            v[slot] = self.cfg.lif.v_rest
        for en in st.en:
            en[slot] = True
        for vp in st.v_peak:
            vp[slot] = _V_PEAK_INIT
        st.counts[slot] = 0
        st.first[slot] = self.cfg.num_steps
        st.gate_prev[slot] = -1
        st.gate_streak[slot] = 0
        st.steps[slot] = 0
        st.adds[slot] = 0
        st.active[slot] = True
        st.weight_version[slot] = self.bank.current
        self.lane_req[slot] = rid

    def _upload(self, st: LaneState) -> LaneState:
        """Host tile → device (the sharded engine re-places onto its mesh)."""
        return jax.tree.map(jnp.asarray, st)

    def _needs_compaction(self) -> bool:
        """Cheap pre-check: only the (B,) active mask crosses the device
        boundary.  The full lane-state round trip happens only when a lane
        actually retired or a queued request can be admitted."""
        occupied = np.array([r is not None for r in self.lane_req])
        active = np.asarray(self.lanes.active)
        waiting = bool(self.queue or self._adoptions)
        return bool((occupied & ~active).any() or (
            waiting and not (occupied & active).all()))

    def _admit_and_compact(self) -> list[int]:
        """Harvest retired lanes, compact active ones, admit queued images.

        Returns the request ids finished in this call.  Runs on the host at
        chunk boundaries: the batch tile stays dense, so freed slots start
        contributing to throughput on the very next chunk.
        """
        if not self._needs_compaction():
            return []
        occupied = np.array([r is not None for r in self.lane_req])
        st = jax.tree.map(lambda a: np.array(a), self.lanes)
        done_ids = self._harvest(st, occupied & ~st.active)

        # Compact: live lanes first (stable), freed/empty lanes after.
        live = np.nonzero(occupied & st.active)[0]
        free = np.nonzero(~(occupied & st.active))[0]
        order = np.concatenate([live, free]).astype(np.int32)
        st = jax.tree.map(lambda a: a[order], st)
        n_live = len(live)
        self.lane_req = ([self.lane_req[int(i)] for i in live]
                         + [None] * (self.batch_size - n_live))

        # Admit waiting work (adoptions first) into the freed tail slots.
        for slot in range(n_live, self.batch_size):
            if not (self.queue or self._adoptions):
                break
            self._admit_into(st, slot)

        self._sync_versions(st)
        self.lanes = self._upload(st)
        return done_ids

    def _sync_versions(self, st: LaneState) -> None:
        """Refresh the host version mirror; retire drained weight planes.

        Called with the compacted host tile just before upload — the only
        moment lane↔version bindings change.  Dropping the last
        old-version plane here IS rollout completion (recorded in
        ``bank.history``): zero drain, because admission never paused.
        """
        self._lane_versions = np.asarray(st.weight_version).astype(np.int64)
        self.bank.gc({int(v) for v, r in zip(self._lane_versions,
                                             self.lane_req)
                      if r is not None})

    # ---- failover (serve.faults) ----------------------------------------
    def snapshot_lanes(self) -> list[tuple[int, LaneState]]:
        """Host snapshot of every in-flight lane — the evacuation source.

        Called by the tier on an engine that declared failure (with its
        lane state intact).  Lanes that already finished are harvested
        into ``results`` first — they need no evacuation — then each
        still-active lane is returned as ``(request_id, row)``, where
        ``row`` is the lane's complete chunk-boundary state (membranes,
        enables, peaks, PRNG, counters, step/add totals, weight version).
        Because chunked execution is bit-identical to one-shot, adopting
        the row on any same-seed engine resumes the window bit-exactly.
        The snapshot empties the engine: every slot is released and the
        version mirror cleared, so a dead engine holds no live versions.
        """
        occupied = np.array([r is not None for r in self.lane_req])
        st = jax.tree.map(lambda a: np.array(a), self.lanes)
        self._harvest(st, occupied & ~st.active)
        rows = []
        for i in np.nonzero(occupied & st.active)[0]:
            idx = int(i)
            rows.append((self.lane_req[idx],
                         jax.tree.map(lambda a, idx=idx: a[idx].copy(), st)))
        self.lane_req = [None] * self.batch_size
        self._lane_versions = np.zeros(self.batch_size, np.int64)
        return rows

    def checkpoint_lanes(self) -> list[tuple[int, LaneState]]:
        """Non-destructive host copy of every in-flight lane.

        Same ``(request_id, row)`` contract as :meth:`snapshot_lanes`,
        but the engine keeps running: slots stay bound and the version
        mirror is untouched.  The cluster coordinator ships these rows
        with every step reply so its shadow copy is always the current
        chunk-boundary checkpoint — a worker killed before its next
        reply resumes from here bit-exactly (the chunked==one-shot
        invariant makes the row placement-independent).
        """
        occupied = np.array([r is not None for r in self.lane_req])
        st = jax.tree.map(lambda a: np.array(a), self.lanes)
        rows = []
        for i in np.nonzero(occupied & st.active)[0]:
            idx = int(i)
            rows.append((self.lane_req[idx],
                         jax.tree.map(lambda a, idx=idx: a[idx].copy(), st)))
        return rows

    def evict_lane(self, request_id: int) -> LaneState:
        """Pull one in-flight lane off the tile (poison-request path).

        Returns the lane's host row (same contract as
        :meth:`snapshot_lanes`) and frees the slot, so the tier can retry
        the request on another engine — or quarantine it — without
        touching any other lane.
        """
        slot = self.lane_req.index(request_id)
        st = jax.tree.map(lambda a: np.array(a), self.lanes)
        row = jax.tree.map(lambda a: a[slot].copy(), st)
        st.active[slot] = False
        self.lane_req[slot] = None
        self._sync_versions(st)
        self.lanes = self._upload(st)
        return row

    def adopt(self, request_id: int, row: LaneState) -> None:
        """Queue an evacuated lane row for admission on this engine.

        Adoptions are admitted ahead of the fresh-request queue at the
        next compaction and resume bit-exactly (see :meth:`_admit_into`).
        The row's weight version must already be in this engine's bank —
        the tier restores garbage-collected versions via ``bank.ensure``
        before adopting, so an old-version lane never silently runs on
        the wrong planes.
        """
        rid = int(request_id)
        if (rid in self.results or rid in self.lane_req
                or any(q[0] == rid for q in self.queue)
                or any(a[0] == rid for a in self._adoptions)):
            raise ValueError(f"request id {rid} already in use")
        v = int(row.weight_version)
        if v not in self.bank.versions:
            raise KeyError(
                f"adopting request {rid} needs weight version {v}, not in "
                f"bank {self.bank.versions} — restore it via bank.ensure()")
        self._adoptions.append((rid, row))
        self._next_id = max(self._next_id, rid + 1)

    def begin_rollout(self, params_q: dict) -> int:
        """Publish new weight planes without draining in-flight windows.

        New admissions bind the returned version immediately; lanes
        already in flight finish on their admission-time planes (the
        version-split dispatch in :meth:`_dispatch_chunk`).  The rollout
        completes — old planes freed, ``bank.history`` records it — when
        the last old-version lane retires.  Topology is fixed: the lane
        state layout is a function of ``layer_sizes``.
        """
        ws = tuple(layer["w_q"] for layer in params_q["layers"])
        sizes = tuple([ws[0].shape[0]] + [w.shape[1] for w in ws])
        if sizes != self.layer_sizes:
            raise ValueError(
                f"rollout cannot change the topology: engine serves "
                f"{self.layer_sizes}, new weights are {sizes}")
        if self.backend in ("fused", "fused_streamed"):
            from ..kernels.ops import validate_weight_codes
            validate_weight_codes(ws)
        return self.bank.begin(self._place_weights(ws))

    def _advance(self, lanes: LaneState, weights: tuple):
        """Dispatch one chunk on the device (async under jax dispatch).

        The chunk length comes from the controller: the configured static
        value when frozen, the live retirement-tuned one when adaptive
        (jit caches one executable per length — the tuning range is small
        and bounded).  Returns ``(lanes', telemetry)``.
        """
        return stream_chunk(
            lanes, weights, chunk_steps=self.controller.chunk_steps,
            num_steps=self.cfg.num_steps, lif_cfg=self.cfg.lif,
            dot_impl=self.cfg.dot_impl,
            active_pruning=self.cfg.active_pruning, patience=self.patience,
            readout=self.cfg.readout, backend=self.backend_effective,
            sparse_skip=self.cfg.sparse_skip, block_b=self._block_b)

    def _dispatch_versions(self, lanes: LaneState):
        """Version-aware chunk dispatch.

        Single live weight version (steady state): one ordinary chunk.
        Mid-rollout: one gated run per live version — each freezes every
        other version's lanes through the existing ``active`` mask, and
        the per-lane merge (``serve.rollout.merge_version_chunks``)
        reconstructs the tile exactly as if each version's lanes had been
        served alone, so a rollout never perturbs pre-rollout windows.
        """
        occ = [r is not None for r in self.lane_req]
        versions = sorted({int(v) for v, o in zip(self._lane_versions, occ)
                           if o})
        if len(versions) <= 1:
            v = versions[0] if versions else self.bank.current
            return self._advance(lanes, self.bank.weights(v))
        outs = []
        for v in versions:
            mask = self._lane_versions == v
            sub = lanes._replace(active=jnp.logical_and(
                lanes.active, jnp.asarray(mask)))
            out, tel = self._advance(sub, self.bank.weights(v))
            outs.append((mask, out, tel))
        return merge_version_chunks(outs)

    # ---- fault-guarded dispatch (serve.faults) --------------------------
    @property
    def backend_effective(self) -> str:
        """The ladder rung chunks currently dispatch on (== the
        configured ``backend`` until faults demote the engine)."""
        return self._ladder[self.health.demotion_level]

    def _health_event(self, ev: dict) -> None:
        """Record a health transition where decisions are audited: the
        health log AND the telemetry controller's history."""
        self.health.events.append(ev)
        self.controller.history.append(ev)

    def _demote(self) -> None:
        lvl = self.health.demotion_level
        self._health_event({"event": "demote", "from": self._ladder[lvl],
                            "to": self._ladder[lvl + 1], "level": lvl + 1})
        self.health.demotion_level = lvl + 1
        # the new rung gets a fresh fault budget and a fresh clean streak
        self.health.consecutive_faults = 0
        self.health.clean_chunks = 0

    def _promote(self) -> None:
        lvl = self.health.demotion_level
        self._health_event({"event": "promote", "from": self._ladder[lvl],
                            "to": self._ladder[lvl - 1], "level": lvl - 1})
        self.health.demotion_level = lvl - 1
        self.health.clean_chunks = 0

    def _fail(self, reason: str, *, state_lost: bool = False):
        self.health.alive = False
        self._health_event({"event": "engine_failure", "reason": reason,
                            "state_lost": state_lost})
        raise EngineFailure(
            f"engine {self.engine_id} failed: {reason}",
            engine=self.engine_id, reason=reason, state_lost=state_lost)

    def _dispatch_chunk(self, lanes: LaneState):
        """Chunk dispatch with the fault harness in the loop.

        With no injector armed this is exactly :meth:`_dispatch_versions`
        — zero overhead, zero readbacks, the historical engine
        bit-for-bit.  Armed, every launch consults the injector and the
        recovery ladder runs:

        * **transient dispatch fault** → up to ``max_retries`` immediate
          re-launches (each a fresh injector roll); retries are the pure
          chunk function on unchanged lane state, so a recovered launch
          is bit-identical to a never-faulted one.  ``demote_after``
          consecutive faults step the backend down the degradation
          ladder; a faulting round past the retry budget backs off a
          bounded, deterministic number of scheduling rounds; and
          ``fail_after`` consecutive faults with no rung left escalate to
          :class:`EngineFailure` (the tier evacuates).
        * **hang** → the chunk makes no progress; ``watchdog_chunks``
          consecutive no-progress chunks trip the chunk-deadline watchdog
          and the engine declares failure *with its lane state intact*.
        * **device loss** → immediate failure, optionally with the lane
          state unrecoverable.
        * **poison request** → the typed per-request fault propagates for
          the tier to evict/quarantine; the launch never ran, so every
          other lane is untouched.
        * **corrupted telemetry** → the record fails host validation and
          is dropped (the controller never observes it); the datapath
          result stands — telemetry is a side channel, not the result.

        Returns ``(lanes', telemetry | None)`` — ``None`` marks a round
        that produced no observable record (hang / backoff / corruption).
        """
        if self.injector is None:
            return self._dispatch_versions(lanes)
        if not self.health.alive:
            raise EngineFailure(
                f"engine {self.engine_id} is dead", engine=self.engine_id,
                reason="dead", state_lost=False)
        ft = self.fault_cfg
        attempt = 0
        while True:
            try:
                tok = self.injector.before_dispatch(
                    attempt, backend=self.backend_effective,
                    rids=[r for r in self.lane_req if r is not None])
            except DeviceLostFault as e:
                self._fail("device_lost", state_lost=e.state_lost)
            except PoisonDispatchError:
                raise
            except DispatchFault as e:
                self.health.record_fault("dispatch", str(e))
                if (self.health.consecutive_faults >= ft.demote_after
                        and self.health.demotion_level + 1
                        < len(self._ladder)):
                    self._demote()
                    attempt = 0
                    continue
                if self.health.consecutive_faults >= ft.fail_after:
                    self._fail("dispatch_exhausted")
                attempt += 1
                if attempt <= ft.max_retries:
                    continue
                # the whole round faulted: deterministic bounded backoff,
                # counted in scheduling rounds (the tier's step currency)
                burst = self.health.consecutive_faults - 1
                self._cooldown = min(ft.backoff_base << min(burst, 8),
                                     ft.backoff_max)
                return lanes, None
            if tok == "hang":
                self.health.stalled_chunks += 1
                if self.health.stalled_chunks >= ft.watchdog_chunks:
                    self._fail("hang")
                return lanes, None
            out, tel = self._dispatch_versions(lanes)
            self.health.stalled_chunks = 0
            tel = self.injector.filter_telemetry(tel)
            if not telemetry_ok(tel):
                self.health.telemetry_faults += 1
                self._health_event({"event": "fault", "kind": "telemetry"})
                tel = None
            else:
                self.health.record_clean()
                if (self.health.demotion_level > 0
                        and self.health.clean_chunks >= ft.promote_after):
                    self._promote()
            return out, tel

    def _observe(self, src: LaneState, nxt: LaneState,
                 tel: ChunkTelemetry) -> None:
        """Feed one chunk's telemetry to the controller (adaptive only —
        frozen mode never forces the device→host readback)."""
        if self.controller.frozen:
            return
        self.controller.observe(summarize_chunk(
            tel, self.layer_sizes,
            steps_before=src.steps, steps_after=nxt.steps,
            active_before=src.active, active_after=nxt.active))

    def step(self) -> list[int]:
        """Admit + run one chunk.  Returns request ids finished so far."""
        done = self._admit_and_compact()
        if self._cooldown > 0:
            # transient-fault backoff: sit this scheduling round out
            self._cooldown -= 1
            return done
        src = self.lanes
        self.lanes, tel = self._dispatch_chunk(src)
        if tel is not None:
            self._observe(src, self.lanes, tel)
        return done

    def run(self, max_chunks: int | None = None) -> dict[int, RequestResult]:
        """Drive chunks until every submitted request has a result."""
        limit = max_chunks if max_chunks is not None else (
            (self.pending + self.batch_size)
            * (self.cfg.num_steps // max(1, self.controller.min_chunk_steps)
               + 2)
            # fault rounds (retry backoff, hang stalls) make no progress;
            # give an armed harness bounded slack instead of a hard wedge
            + (0 if self.injector is None else 64))
        for _ in range(limit):
            if self.pending == 0:
                break
            self.step()
        self._admit_and_compact()
        return self.results


class ShardedSNNStreamEngine(SNNStreamEngine):
    """(Data × model)-parallel lane mesh over the streaming engine.

    The batch tile is sharded over the ``axis_name`` axis of a
    ``jax.sharding.Mesh`` — each device owns ``batch_size // n_devices``
    contiguous lane slots and executes the fused (or jnp-scan fallback)
    chunk on its local slice under ``shard_map``, with the quantized
    weights replicated (the software analogue of replicating the paper's
    neuron core across parallel hardware lanes).  Because every part of
    the chunk — datapath, stability gate, lane freezing, add counter — is
    per-lane, results are bit-identical to :class:`SNNStreamEngine` on the
    same seeds: same predictions, same retirement steps, same frozen
    executed-add counters.

    If the mesh also carries a ``model_axis_name`` axis (build one with
    ``distributed.sharding.make_2d_device_mesh``), each layer whose
    output width divides the axis splits its weight columns across the
    model peers — the multi-core neuron partitioning of the SNN-hardware
    literature: per-device partial contraction of the full input-spike
    vector against the local weight shard, per-shard LIF, then an
    ``all_gather`` spike exchange at the layer boundary so every peer
    enters the next layer with the full fired vector.  Layers that don't
    divide (the 10-class head on a 4-way axis) replicate and skip the
    exchange.  Lane state stays model-replicated, so the ``LaneState``
    checkpoint is placement-independent — ``snapshot_lanes``/``adopt``
    failover works unchanged between 1-D and 2-D engines — and the VMEM
    feasibility check runs against the per-device weight shard, which is
    what lets a WIDE stack serve VMEM-resident ``fused`` on a 4-way
    model axis instead of streaming weights from HBM.  Results stay
    bit-identical: disjoint integer column shards concatenate exactly.

    Scheduling differences from the base engine:

      * **Device-local compaction** — retired lanes are compacted within
        their device's slot block, never across blocks, so lane state is
        re-uploaded onto the same device and no resharding traffic is
        generated at chunk boundaries.
      * **Round-robin admission** — queued requests fill freed slots
        cycling across device blocks, keeping every device's live-lane
        count balanced under partial load.
      * **Admission/compute overlap** — after dispatching chunk *k* the
        engine speculatively enqueues chunk *k+1* on its (not yet ready)
        output, so the devices keep running while the host blocks on the
        chunk-*k* retirement readback and does queue bookkeeping.  If the
        readback shows a retirement or a possible admission, the
        speculative state is discarded and the chunk re-dispatched from
        the compacted tile — speculation is the pure chunk function on
        the same state, so using it never changes results.
        ``stats['spec_used']``/``stats['spec_wasted']`` count the
        outcomes (the benchmark's admission-overlap timing).
    """

    def __init__(self, params_q: dict, cfg: SNNConfig, *,
                 mesh: Mesh | None = None, axis_name: str = "data",
                 model_axis_name: str = "model",
                 lanes_per_device: int | None = None,
                 batch_size: int | None = None,
                 chunk_steps: int | None = None, patience: int = 2,
                 seed: int = 0,
                 backend: str | None = None, overlap: bool = True,
                 adaptive: AdaptiveDispatchConfig | None = None,
                 engine_id: int = 0,
                 injector: FaultInjector | None = None,
                 fault_cfg: FaultToleranceConfig | None = None,
                 initial_weight_version: int = 0,
                 block_b: int | None = None,
                 dispatch_cache=None):
        from ..kernels.fused_snn import layer_shard_ways
        from ..tune.cache import CacheDecision, decide_dispatch
        if mesh is None:
            mesh = make_device_mesh((len(jax.devices()),), (axis_name,))
        if axis_name not in mesh.axis_names:
            raise ValueError(f"mesh {mesh.axis_names} has no "
                             f"{axis_name!r} axis")
        if model_axis_name == axis_name:
            raise ValueError(
                f"model_axis_name {model_axis_name!r} must differ from the "
                f"lane axis {axis_name!r}")
        self.mesh = mesh
        self.axis_name = axis_name
        self.n_devices = mesh.shape[axis_name]
        # Model axis: present in the mesh → each layer that divides holds
        # only an output-column weight shard per device and the chunk
        # exchanges spikes at layer boundaries; absent (or 1-wide) → the
        # historical pure data-parallel engine, bit-for-bit.
        self.model_axis_name = model_axis_name
        self.model_devices = (int(mesh.shape[model_axis_name])
                              if model_axis_name in mesh.axis_names else 1)
        self.model_axis = (model_axis_name if self.model_devices > 1
                           else None)
        w_shapes = [layer["w_q"].shape for layer in params_q["layers"]]
        sizes = tuple([w_shapes[0][0]] + [s[1] for s in w_shapes])
        self.model_ways = layer_shard_ways(sizes, self.model_devices)
        # The cache consultation happens HERE (not in the base __init__)
        # because the tuned per-device lane count must be known before
        # the global tile shape is fixed, and the lookup key carries this
        # engine's 2-D mesh shape — a cache tuned for one topology must
        # miss on another, not silently re-tile it.  The resolved
        # decision is handed to the base constructor so it is only made
        # once.
        if isinstance(dispatch_cache, CacheDecision):
            decision = dispatch_cache
        else:
            decision = decide_dispatch(
                dispatch_cache, cfg=cfg, backend=backend,
                mesh_shape=(self.n_devices, self.model_devices))
        if (decision.hit and batch_size is None
                and lanes_per_device is None):
            lanes_per_device = decision.tuned.lanes_per_device
        if batch_size is None:
            batch_size = (8 if lanes_per_device is None
                          else lanes_per_device) * self.n_devices
        elif (lanes_per_device is not None
              and batch_size != lanes_per_device * self.n_devices):
            raise ValueError(
                f"conflicting tile shape: batch_size={batch_size} but "
                f"lanes_per_device={lanes_per_device} × "
                f"{self.n_devices} devices = "
                f"{lanes_per_device * self.n_devices} — pass one or the "
                f"other")
        if batch_size % self.n_devices:
            raise ValueError(
                f"batch_size={batch_size} must divide evenly over the "
                f"{self.n_devices}-device {axis_name!r} axis")
        self.overlap = overlap
        self.stats = {"chunks": 0, "spec_used": 0, "spec_wasted": 0}
        self._spec: tuple | None = None
        self._spec_src: LaneState | None = None
        self._spec_steps: int | None = None
        super().__init__(params_q, cfg, batch_size=batch_size,
                         chunk_steps=chunk_steps, patience=patience,
                         seed=seed, backend=backend,
                         local_batch=batch_size // self.n_devices,
                         model_shards=self.model_devices,
                         adaptive=adaptive, engine_id=engine_id,
                         injector=injector, fault_cfg=fault_cfg,
                         initial_weight_version=initial_weight_version,
                         block_b=block_b, dispatch_cache=decision)
        specs = lane_partition_specs(len(self.weights), axis_name,
                                     self.model_axis)
        self._shardings = jax.tree.map(
            lambda s: NamedSharding(mesh, s), specs,
            is_leaf=lambda x: isinstance(x, P))
        # one sharded executor per (chunk length, ladder rung) the
        # runtime dispatches (exactly one entry when frozen and healthy)
        self._chunk_fns: dict[tuple[int, str], object] = {}
        self._chunk_fn_for(self.controller.chunk_steps)
        self.lanes = jax.device_put(self.lanes, self._shardings)

    # ---- device placement ----------------------------------------------
    def _place_weights(self, weights: tuple) -> tuple:
        # per-layer placement: output-column shards over the model axis
        # for layers that divide, replicated otherwise (and always
        # replicated on a pure data mesh) — rollout versions land the
        # same way the construction-time planes do
        w_specs = weight_partition_specs(self.model_ways, self.model_axis)
        return tuple(
            jax.device_put(jnp.asarray(w), NamedSharding(self.mesh, s))
            for w, s in zip(weights, w_specs))

    def _chunk_fn_for(self, n_steps: int):
        key = (n_steps, self.backend_effective)
        if key not in self._chunk_fns:
            self._chunk_fns[key] = make_sharded_stream_chunk(
                self.mesh, self.axis_name, len(self.weights),
                chunk_steps=n_steps, num_steps=self.cfg.num_steps,
                lif_cfg=self.cfg.lif, dot_impl=self.cfg.dot_impl,
                active_pruning=self.cfg.active_pruning,
                patience=self.patience, readout=self.cfg.readout,
                backend=self.backend_effective,
                sparse_skip=self.cfg.sparse_skip,
                model_axis=self.model_axis,
                model_ways=self.model_ways if self.model_axis else None,
                block_b=self._block_b)
        return self._chunk_fns[key]

    def _upload(self, st: LaneState) -> LaneState:
        return jax.device_put(st, self._shardings)

    def _advance(self, lanes: LaneState, weights: tuple):
        return self._chunk_fn_for(self.controller.chunk_steps)(
            lanes, weights)

    # ---- scheduling -----------------------------------------------------
    def _admit_and_compact(self) -> list[int]:
        """Block-local compaction + round-robin admission (see class doc)."""
        if not self._needs_compaction():
            return []
        occupied = np.array([r is not None for r in self.lane_req])
        st = jax.tree.map(lambda a: np.array(a), self.lanes)
        done_ids = self._harvest(st, occupied & ~st.active)

        # Compact each device block independently: live lanes first within
        # the block, freed slots after — a lane never changes device.
        order, lane_req, free_slots = [], [], []
        for d in range(self.n_devices):
            lo = d * self.local_batch
            block = np.arange(lo, lo + self.local_batch)
            live = block[occupied[block] & st.active[block]]
            free = block[~(occupied[block] & st.active[block])]
            order.extend(live.tolist() + free.tolist())
            lane_req.extend([self.lane_req[int(i)] for i in live]
                            + [None] * len(free))
            free_slots.append(list(range(lo + len(live),
                                         lo + self.local_batch)))
        st = jax.tree.map(lambda a: a[np.asarray(order, np.int32)], st)
        self.lane_req = lane_req

        # Round-robin admission across device blocks (adoptions first —
        # _admit_into drains them before the fresh queue).
        while (self.queue or self._adoptions) and any(free_slots):
            for d in range(self.n_devices):
                if not (self.queue or self._adoptions):
                    break
                if free_slots[d]:
                    self._admit_into(st, free_slots[d].pop(0))

        self._sync_versions(st)
        self.lanes = self._upload(st)
        return done_ids

    def step(self) -> list[int]:
        """Admit + run one chunk, overlapping the next with host work."""
        done = self._admit_and_compact()
        if self._cooldown > 0:
            self._cooldown -= 1
            return done
        if (self._spec is not None and self.lanes is self._spec_src
                and self._spec_steps == self.controller.chunk_steps):
            # the tile object is the very one the speculative chunk was
            # dispatched from (no compaction replaced it — here OR in any
            # intervening run()/_admit_and_compact call) AND the
            # controller still wants the chunk length the speculation ran
            # at: the speculation IS this step's chunk (same pure
            # function, same input).  The length guard is load-bearing —
            # an adaptive retune landing between dispatch and commit
            # (this engine's own observe, or a tier/coordinator feeding
            # the controller out-of-band) means the speculative state
            # advanced the lanes by the WRONG number of window steps;
            # committing it would silently serve a stale-length chunk.
            src = self._spec_src
            nxt, tel = self._spec
            self.stats["spec_used"] += 1
        else:
            if self._spec is not None:
                self.stats["spec_wasted"] += 1
            src = self.lanes
            nxt, tel = self._dispatch_chunk(src)
        self._spec = self._spec_src = None
        self._spec_steps = None
        self.lanes = nxt
        self.stats["chunks"] += 1
        if tel is not None:
            self._observe(src, nxt, tel)
        # Speculation is off while a fault harness is armed: a speculative
        # launch would consume injector consults (and could fault) one
        # step early, detaching the fault coordinates from the committed
        # dispatch sequence the deterministic-replay contract pins.
        if self.overlap and self.injector is None \
                and (self.queue
                     or any(r is not None for r in self.lane_req)):
            # enqueue chunk k+1 now — the devices stay busy while the next
            # step's host-side readback and queue bookkeeping run (the
            # lane↔version map only changes at compaction, which discards
            # the speculation, so version-split dispatch speculates safely).
            # Record the chunk length this speculation ran at: the commit
            # path discards it (spec_wasted) if a retune moves the
            # controller's choice before the next step.
            self._spec_src = nxt
            self._spec_steps = self.controller.chunk_steps
            self._spec = self._dispatch_chunk(nxt)
        return done
