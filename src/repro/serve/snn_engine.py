"""Batched streaming SNN serving engine (paper §IV-C at the request level).

The RTL classifies one image per window.  A TPU serving deployment instead
packs many requests into one batch tile and streams them through the
integer datapath together.  This engine adds the two scheduling ideas that
make that efficient under heavy traffic:

  * **Early exit** — a lane whose running prediction has been stable for
    ``patience`` consecutive steps retires before the window ends (the
    request-level analogue of active pruning; pure gate from
    serve.early_exit, evaluated *inside* the device-side window chunk so a
    lane stops burning adds the step it retires, not at the next host
    sync).
  * **Lane compaction** — at chunk boundaries, retired lanes are compacted
    out of the batch tile and the freed slots admit queued images, so a
    long-running image never blocks throughput (continuous batching).

The window chunk dispatches through the integer engine's backends
(core.snn): on TPU the **resumable fused megakernel** advances every lane
``chunk_steps`` steps in one Pallas launch — layer weights stay resident,
inter-layer spikes never touch HBM, and the stability gate runs inside the
kernel so per-step retirement semantics are preserved bit-for-bit.  On
hosts without a TPU the same datapath runs as a pure-jnp scan over
``core.snn.snn_int_stack_step`` (the reference backend) — both paths
produce identical lane-state evolution for the same seeds.

The per-lane executed-add counter is the same energy side channel the
paper integrates (§V): a retired lane's counter is frozen, which is the
measurable "sleep sooner" win.

Readouts: ``count`` (spike-register argmax) and ``first_spike`` (earliest
spiking class, membrane tiebreak — the active-pruning config's readout)
both stream; ``membrane`` needs the full trace and is rejected — run those
configs through ``core.snn.snn_apply_int``.
"""

from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core import lif as lif_mod
from ..core import prng as prng_mod
from ..core.snn import SNNConfig, readout_pred, snn_int_stack_step
from .early_exit import StabilityGateState, stability_step

__all__ = ["SNNStreamEngine", "LaneState", "RequestResult", "stream_chunk"]


class LaneState(NamedTuple):
    """Device-side state of one batch tile (all arrays leading dim B)."""

    px: jax.Array          # (B, n_in) uint8 pixels
    rng: jax.Array         # (B, n_in) uint32 xorshift lanes
    v: tuple               # per-layer (B, n_l) int32 membrane accumulators
    en: tuple              # per-layer (B, n_l) bool neuron clock-gates
    counts: jax.Array      # (B, n_out) int32 spike registers
    first: jax.Array       # (B, n_out) int32 first-spike latch (sentinel=T)
    gate_prev: jax.Array   # (B,) int32 stability-gate memory
    gate_streak: jax.Array  # (B,) int32
    steps: jax.Array       # (B,) int32 window steps executed
    adds: jax.Array        # (B,) int32 executed synaptic adds (energy)
    active: jax.Array      # (B,) bool — lane still consuming compute


@dataclass
class RequestResult:
    request_id: int
    pred: int
    spike_counts: np.ndarray
    steps: int             # window steps actually consumed
    adds: int              # synaptic adds executed (energy side channel)
    early_exit: bool       # retired by the stability gate before T


def _init_lanes(batch: int, layer_sizes: tuple[int, ...], num_steps: int,
                v_rest: int) -> LaneState:
    n_in, n_out = layer_sizes[0], layer_sizes[-1]
    return LaneState(
        px=jnp.zeros((batch, n_in), jnp.uint8),
        rng=jnp.full((batch, n_in), 1, jnp.uint32),
        v=tuple(jnp.full((batch, n), v_rest, jnp.int32)
                for n in layer_sizes[1:]),
        en=tuple(jnp.ones((batch, n), bool) for n in layer_sizes[1:]),
        counts=jnp.zeros((batch, n_out), jnp.int32),
        first=jnp.full((batch, n_out), num_steps, jnp.int32),
        gate_prev=jnp.full((batch,), -1, jnp.int32),
        gate_streak=jnp.zeros((batch,), jnp.int32),
        steps=jnp.zeros((batch,), jnp.int32),
        adds=jnp.zeros((batch,), jnp.int32),
        active=jnp.zeros((batch,), bool),
    )


@partial(jax.jit, static_argnames=(
    "chunk_steps", "num_steps", "lif_cfg", "dot_impl", "active_pruning",
    "patience", "readout", "backend", "interpret"))
def stream_chunk(lanes: LaneState, weights: tuple, *, chunk_steps: int,
                 num_steps: int, lif_cfg: lif_mod.LIFConfig,
                 dot_impl: str, active_pruning: bool, patience: int,
                 readout: str = "count", backend: str = "reference",
                 interpret: bool | None = None) -> LaneState:
    """Advance every active lane by up to ``chunk_steps`` window steps.

    ``backend="fused"`` runs the whole chunk — every layer, every step,
    the stability gate included — inside one resumable Pallas launch
    (kernels.fused_snn); ``backend="reference"`` scans the same datapath
    in jnp via ``core.snn.snn_int_stack_step``.  The two are bit-identical
    on shared lane state, including mid-chunk retirement: a retired or
    inactive lane is completely frozen — PRNG, membranes, counters and the
    add counter stop, which is what the compaction test measures.
    """
    if backend == "fused":
        from ..kernels import ops
        k = ops.fused_snn_stack_op(
            lanes.px, lanes.rng, weights, num_steps=num_steps,
            chunk_steps=chunk_steps, decay_shift=lif_cfg.decay_shift,
            v_threshold=lif_cfg.v_threshold, v_rest=lif_cfg.v_rest,
            v_min=lif_cfg.v_min, v_max=lif_cfg.v_max,
            active_pruning=active_pruning,
            init={"v": lanes.v, "en": lanes.en, "counts": lanes.counts,
                  "first": lanes.first, "steps": lanes.steps},
            gate={"active": lanes.active, "prev": lanes.gate_prev,
                  "streak": lanes.gate_streak},
            patience=patience, readout=readout, interpret=interpret)
        return LaneState(
            px=lanes.px, rng=k["prng_state"], v=k["v"], en=k["en"],
            counts=k["spike_counts"], first=k["first_spike_t"],
            gate_prev=k["gate"]["prev"], gate_streak=k["gate"]["streak"],
            steps=k["steps"],
            adds=lanes.adds + jnp.sum(k["active_adds"], axis=0),
            active=k["gate"]["active"])

    def body(carry, _):
        st = carry
        act = st.active
        layer_states = tuple(lif_mod.LIFStateInt(v=v, enable=e)
                             for v, e in zip(st.v, st.en))
        rng, new_states, fired, adds_t = snn_int_stack_step(
            st.rng, st.px, layer_states, weights, lif_cfg,
            dot_impl=dot_impl, active_pruning=active_pruning)
        counts = st.counts + fired.astype(jnp.int32)
        first = jnp.where(
            jnp.logical_and(fired, st.first == num_steps),
            st.steps[:, None], st.first)
        # stability gate on the running prediction (pure, in-loop); a lane
        # with no output spikes yet has no prediction to be stable about —
        # its gate state stays at init so neither the streak nor the retire
        # can trigger before the first spike (argmax(zeros)=0 is not a
        # stable class-0 vote, and the streak must not pre-accumulate).
        has_spike = jnp.max(counts, axis=-1) > 0
        pred = readout_pred(counts, first, new_states[-1].v, readout,
                            num_steps).astype(jnp.int32)
        gate, done = stability_step(
            StabilityGateState(prev=st.gate_prev, streak=st.gate_streak),
            pred, patience)
        gate_prev = jnp.where(has_spike, gate.prev, -1)
        gate_streak = jnp.where(has_spike, gate.streak, 0)
        done = jnp.logical_and(done, has_spike)
        steps = st.steps + act.astype(jnp.int32)
        still = jnp.logical_and(act, jnp.logical_not(done))
        still = jnp.logical_and(still, steps < num_steps)

        def keep(new, old, mask=act):
            return jnp.where(mask.reshape((-1,) + (1,) * (new.ndim - 1)),
                             new, old)

        return LaneState(
            px=st.px,
            rng=keep(rng, st.rng),
            v=tuple(keep(s.v, ov) for s, ov in zip(new_states, st.v)),
            en=tuple(keep(s.enable, oe)
                     for s, oe in zip(new_states, st.en)),
            counts=keep(counts, st.counts),
            first=keep(first, st.first),
            gate_prev=keep(gate_prev, st.gate_prev),
            gate_streak=keep(gate_streak, st.gate_streak),
            steps=steps,
            adds=st.adds + jnp.where(act, adds_t, 0),
            active=jnp.where(act, still, st.active),
        ), None

    lanes, _ = jax.lax.scan(body, lanes, None, length=chunk_steps)
    return lanes


class SNNStreamEngine:
    """Continuous-batching front end over the streaming window chunk.

    Usage::

        eng = SNNStreamEngine(params_q, cfg, batch_size=8)
        ids = [eng.submit(img) for img in images]     # queue requests
        results = eng.run()                            # {id: RequestResult}

    ``backend`` picks the chunk executor: ``"fused"`` (resumable Pallas
    megakernel — interpret mode off-TPU, so slow but bit-exact there),
    ``"reference"`` (jnp scan), or None/"auto" (fused on TPU, reference
    elsewhere).  Arbitrary layer stacks are supported — hidden-layer spike
    traffic stays on-chip on the fused path.
    """

    def __init__(self, params_q: dict, cfg: SNNConfig, *, batch_size: int = 8,
                 chunk_steps: int = 4, patience: int = 2, seed: int = 0,
                 backend: str | None = None):
        if cfg.readout not in ("count", "first_spike"):
            raise ValueError(
                f"streaming engine implements the 'count' and 'first_spike' "
                f"readouts; got readout={cfg.readout!r} — run membrane "
                f"configs through core.snn.snn_apply_int instead")
        if backend in (None, "auto"):
            backend = ("fused" if jax.default_backend() == "tpu"
                       else "reference")
        if backend not in ("fused", "reference"):
            raise ValueError(
                f"streaming chunk backend must be 'fused' or 'reference' "
                f"(the staged kernels cannot resume mid-window); got "
                f"{backend!r}")
        self.backend = backend
        self.weights = tuple(layer["w_q"] for layer in params_q["layers"])
        self.layer_sizes = tuple([self.weights[0].shape[0]]
                                 + [w.shape[1] for w in self.weights])
        if backend == "fused":
            from ..core.snn import fused_unsupported_reason
            reason = fused_unsupported_reason(cfg, len(self.weights),
                                              self.layer_sizes,
                                              trace_steps=chunk_steps)
            if reason is not None:
                raise ValueError(f"fused streaming backend unavailable: "
                                 f"{reason} — use backend='reference'")
        self.cfg = cfg
        self.batch_size = batch_size
        self.chunk_steps = chunk_steps
        self.patience = patience
        self.seed = seed
        self.n_in, self.n_out = self.layer_sizes[0], self.layer_sizes[-1]
        self.lanes = _init_lanes(batch_size, self.layer_sizes,
                                 cfg.num_steps, cfg.lif.v_rest)
        self.lane_req: list[int | None] = [None] * batch_size
        self.queue: list[tuple[int, np.ndarray]] = []
        self.results: dict[int, RequestResult] = {}
        self._next_id = 0

    # ---- request intake -------------------------------------------------
    def submit(self, pixels_u8: np.ndarray) -> int:
        """Enqueue one image; returns its request id."""
        pixels_u8 = np.asarray(pixels_u8, np.uint8).reshape(self.n_in)
        rid = self._next_id
        self._next_id += 1
        self.queue.append((rid, pixels_u8))
        return rid

    @property
    def pending(self) -> int:
        return len(self.queue) + sum(r is not None for r in self.lane_req)

    # ---- readout --------------------------------------------------------
    def _host_pred(self, counts: np.ndarray, first: np.ndarray,
                   v_last: np.ndarray) -> int:
        """Harvest-time prediction for one retired lane."""
        return int(readout_pred(counts, first, v_last, self.cfg.readout,
                                self.cfg.num_steps))

    # ---- scheduling -----------------------------------------------------
    def _admit_and_compact(self) -> list[int]:
        """Harvest retired lanes, compact active ones, admit queued images.

        Returns the request ids finished in this call.  Runs on the host at
        chunk boundaries: the batch tile stays dense, so freed slots start
        contributing to throughput on the very next chunk.
        """
        occupied = np.array([r is not None for r in self.lane_req])
        # Cheap pre-check: only the (B,) active mask crosses the device
        # boundary.  The full lane-state round trip below happens only when
        # a lane actually retired or a queued request can be admitted.
        active = np.asarray(self.lanes.active)
        if not (occupied & ~active).any() and not (
                self.queue and not (occupied & active).all()):
            return []
        st = jax.tree.map(lambda a: np.array(a), self.lanes)
        finished_lanes = occupied & ~st.active
        done_ids = []
        for i in np.nonzero(finished_lanes)[0]:
            rid = self.lane_req[int(i)]
            self.results[rid] = RequestResult(
                request_id=rid,
                pred=self._host_pred(st.counts[i], st.first[i],
                                     st.v[-1][i]),
                spike_counts=st.counts[i].copy(),
                steps=int(st.steps[i]),
                adds=int(st.adds[i]),
                early_exit=int(st.steps[i]) < self.cfg.num_steps,
            )
            done_ids.append(rid)

        # Compact: live lanes first (stable), freed/empty lanes after.
        live = np.nonzero(occupied & st.active)[0]
        free = np.nonzero(~(occupied & st.active))[0]
        order = np.concatenate([live, free]).astype(np.int32)
        st = jax.tree.map(lambda a: a[order], st)
        n_live = len(live)
        self.lane_req = ([self.lane_req[int(i)] for i in live]
                         + [None] * (self.batch_size - n_live))

        # Admit queued requests into the freed tail slots.
        for slot in range(n_live, self.batch_size):
            if not self.queue:
                break
            rid, pixels = self.queue.pop(0)
            st.px[slot] = pixels
            st.rng[slot] = np.asarray(
                prng_mod.seed_state(self.seed + rid, (self.n_in,)))
            for v in st.v:
                v[slot] = self.cfg.lif.v_rest
            for en in st.en:
                en[slot] = True
            st.counts[slot] = 0
            st.first[slot] = self.cfg.num_steps
            st.gate_prev[slot] = -1
            st.gate_streak[slot] = 0
            st.steps[slot] = 0
            st.adds[slot] = 0
            st.active[slot] = True
            self.lane_req[slot] = rid

        self.lanes = jax.tree.map(jnp.asarray, st)
        return done_ids

    def step(self) -> list[int]:
        """Admit + run one chunk.  Returns request ids finished so far."""
        done = self._admit_and_compact()
        self.lanes = stream_chunk(
            self.lanes, self.weights, chunk_steps=self.chunk_steps,
            num_steps=self.cfg.num_steps, lif_cfg=self.cfg.lif,
            dot_impl=self.cfg.dot_impl,
            active_pruning=self.cfg.active_pruning, patience=self.patience,
            readout=self.cfg.readout, backend=self.backend)
        return done

    def run(self, max_chunks: int | None = None) -> dict[int, RequestResult]:
        """Drive chunks until every submitted request has a result."""
        limit = max_chunks if max_chunks is not None else (
            (self.pending + self.batch_size)
            * (self.cfg.num_steps // self.chunk_steps + 2))
        for _ in range(limit):
            if self.pending == 0:
                break
            self.step()
        self._admit_and_compact()
        return self.results
